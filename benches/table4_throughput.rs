//! Bench: Table 4 — fine-tuning throughput and task-accuracy parity
//! across methods (FF / LoRA / circulant×{fft, rfft, ours}).
//!
//! `cargo bench --bench table4_throughput`

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    rdfft::coordinator::experiments::table4(fast);
}
