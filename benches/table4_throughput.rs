//! Bench: Table 4 — fine-tuning throughput and task-accuracy parity
//! across methods (FF / LoRA / circulant×{fft, rfft, ours}), preceded by
//! the batch-engine throughput ablation (scalar row loop vs batch-major
//! vs batch-major + threads, plus the persistent-pool vs per-call
//! scoped-thread scaling grid at threads ∈ {1, 2, 4}), which also writes
//! the machine-readable `BENCH_rdfft.json` (schema v2 — records +
//! acceptance gates — in EXPERIMENTS.md §Perf).
//!
//! `cargo bench --bench table4_throughput`

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let gates_ok = rdfft::coordinator::experiments::bench_rdfft_engine(fast);
    println!();
    rdfft::coordinator::experiments::table4(fast);
    if !gates_ok {
        eprintln!("FAIL: engine gate (batch=1 latency vs scalar, or fused-vs-unfused circulant) regressed");
        std::process::exit(1);
    }
}
