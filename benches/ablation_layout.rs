//! Ablation bench: where does rdFFT's time go, and what does each design
//! choice buy? (The DESIGN.md §Perf ablations.)
//!
//! * permutation vs butterfly cost (bit-reversal is the memory-bound part)
//! * forward vs inverse (paper: inverse is faster)
//! * f32 vs bf16 storage
//! * plan construction vs cached plan (twiddle caching)
//! * packed in-place vs out-of-place rfft at equal math
//!
//! `cargo bench --bench ablation_layout`

use rdfft::coordinator::benchlib::bench;
use rdfft::memtrack::Category;
use rdfft::rdfft::bf16::{rdfft_inplace_bf16, Bf16};
use rdfft::rdfft::{forward, inverse, plan::cached, plan::Plan, rdfft_inplace};

fn main() {
    println!("# Ablations — rdFFT cost decomposition (median ns/op)\n");
    println!(
        "{:<8}{:>12}{:>12}{:>12}{:>12}{:>12}{:>12}{:>14}",
        "n", "bitrev", "fwd-stages", "fwd-total", "inv-total", "bf16-fwd", "rfft-oop", "plan-build"
    );
    for &n in &[256usize, 1024, 4096] {
        let plan = cached(n);
        let x: Vec<f32> = (0..n).map(|i| ((i * 29 + 7) % 83) as f32 / 40.0 - 1.0).collect();

        let mut b1 = x.clone();
        let perm = bench(200, || {
            plan.bit_reverse(&mut b1);
            std::hint::black_box(&b1[0]);
        });
        let mut b2 = x.clone();
        let stages = bench(200, || {
            forward::forward_stages(&plan, &mut b2);
            std::hint::black_box(&b2[0]);
        });
        let mut b3 = x.clone();
        let fwd = bench(200, || {
            rdfft_inplace(&plan, &mut b3);
            std::hint::black_box(&b3[0]);
        });
        let mut b4 = x.clone();
        let inv = bench(200, || {
            inverse::irdfft_inplace(&plan, &mut b4);
            std::hint::black_box(&b4[0]);
        });
        let mut bb: Vec<Bf16> = x.iter().map(|&v| Bf16::from_f32(v)).collect();
        let bf = bench(200, || {
            rdfft_inplace_bf16(&plan, &mut bb);
            std::hint::black_box(&bb[0]);
        });
        let rf = bench(200, || {
            let s = rdfft::baselines::rfft::rfft_alloc(&x, Category::Other);
            std::hint::black_box(&s[0]);
        });
        let pb = bench(200, || {
            let p = Plan::new(n);
            std::hint::black_box(p.n());
        });
        println!(
            "{:<8}{:>12.0}{:>12.0}{:>12.0}{:>12.0}{:>12.0}{:>12.0}{:>14.0}",
            n,
            perm.median_ns,
            stages.median_ns,
            fwd.median_ns,
            inv.median_ns,
            bf.median_ns,
            rf.median_ns,
            pb.median_ns
        );
    }
    println!(
        "\n(read: fwd-total ≈ bitrev + fwd-stages; rfft-oop pays the extra\n\
         allocation+copy; plan-build is why plans are cached)"
    );

    // ------------------------------------------------------------------
    // Batch execution ablation: scalar per-row loop vs the batch-major
    // engine vs engine + threads, plus the persistent-pool vs per-call
    // scoped-thread scaling grid — the shared grid from experiments
    // (fwd+inv roundtrips keep values bounded across timed iterations;
    // also prints the batch=1 latency gate and writes BENCH_rdfft.json
    // with the pool gates). Exits non-zero if a hard gate regresses.
    // ------------------------------------------------------------------
    println!();
    let fast = std::env::args().any(|a| a == "--fast");
    if !rdfft::coordinator::experiments::bench_rdfft_engine(fast) {
        eprintln!("FAIL: engine gate (batch=1 latency vs scalar, or fused-vs-unfused circulant) regressed");
        std::process::exit(1);
    }
}
