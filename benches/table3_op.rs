//! Bench: Table 3 — standalone operator runtime and accuracy.
//!
//! `cargo bench --bench table3_op` prints the same rows as the paper's
//! Table 3 (fft / rfft / ours, forward + inverse, p ∈ {512, 1024, 4096},
//! accuracy vs the f64 oracle). Criterion is unavailable offline; the
//! in-tree harness (`coordinator::benchlib`) provides warmup + calibrated
//! medians.

fn main() {
    rdfft::coordinator::experiments::table3();
}
