"""L2 correctness: adapted transformer shapes, zero-init equivalence,
training dynamics, and the AOT manifest contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["test"]


@pytest.fixture(scope="module")
def params():
    key = jax.random.PRNGKey(0)
    kf, kt = jax.random.split(key)
    return M.init_frozen(CFG, kf), M.init_trainable(CFG, kt)


def toks(seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(0, CFG.vocab, size=(CFG.batch, CFG.seq_len), dtype=np.int32)
    return jnp.asarray(t)


def test_forward_shapes(params):
    frozen, trainable = params
    logits = M.forward(CFG, frozen, trainable, toks())
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_zero_adapters_equal_backbone(params):
    """Zero-initialized adapters must leave the model exactly at the
    frozen backbone (the adapter counterpart of LoRA's zero-B init)."""
    frozen, trainable = params
    with_adapter = M.forward(CFG, frozen, trainable, toks(1))
    without = M.forward(CFG, frozen, {}, toks(1))
    np.testing.assert_allclose(with_adapter, without, rtol=1e-5, atol=1e-5)


def test_loss_is_scalar_and_reasonable(params):
    frozen, trainable = params
    loss = M.loss_fn(CFG, frozen, trainable, toks(2), toks(3))
    assert loss.shape == ()
    # random model on vocab-256: loss ~ ln(256) ≈ 5.55
    assert 3.0 < float(loss) < 8.0


def test_target_masking(params):
    frozen, trainable = params
    t = toks(4)
    full = M.loss_fn(CFG, frozen, trainable, t, t)
    masked_targets = t.at[:, : CFG.seq_len // 2].set(-1)
    half = M.loss_fn(CFG, frozen, trainable, t, masked_targets)
    assert float(full) != float(half)
    all_masked = jnp.full_like(t, -1)
    zero = M.loss_fn(CFG, frozen, trainable, t, all_masked)
    assert float(zero) == 0.0


def test_train_step_reduces_loss_on_fixed_batch(params):
    frozen, trainable = params
    step = jax.jit(M.make_train_step(CFG), static_argnums=())
    tokens = toks(5)
    targets = toks(5)  # memorize a fixed batch
    tr = trainable
    losses = []
    for _ in range(8):
        tr, loss = step(frozen, tr, tokens, targets)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"no learning: {losses}"


def test_gradients_flow_only_to_adapters(params):
    frozen, trainable = params
    g = jax.grad(lambda tr: M.loss_fn(CFG, frozen, tr, toks(6), toks(7)))(trainable)
    total = 0.0
    for k, v in g.items():
        assert k.endswith(".c")
        total += float(jnp.sum(jnp.abs(v)))
    assert total > 0.0, "adapters received no gradient"


def test_trainable_spec_is_sorted_and_complete():
    spec = M.trainable_spec(CFG)
    names = [n for n, _ in spec]
    assert names == sorted(names)
    assert len(names) == CFG.n_layers * len(M.ADAPTED)
    for _, shape in spec:
        assert shape[-1] == CFG.p


def test_presets_validate():
    for name, cfg in M.PRESETS.items():
        cfg.validate()


def test_adapter_changes_output_after_update(params):
    frozen, trainable = params
    step = jax.jit(M.make_train_step(CFG))
    tr2, _ = step(frozen, trainable, toks(8), toks(9))
    before = M.forward(CFG, frozen, trainable, toks(10))
    after = M.forward(CFG, frozen, tr2, toks(10))
    assert float(jnp.max(jnp.abs(before - after))) > 1e-6
