"""Build-path contract tests: the AOT artifacts must be loadable and the
manifest must describe them exactly."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(M.PRESETS["test"], str(out))
    return str(out), manifest


def test_all_artifacts_exist(built):
    out, _ = built
    for f in ["train_step.hlo.txt", "eval_step.hlo.txt", "frozen.bin", "trainable.bin", "manifest.json"]:
        assert os.path.exists(os.path.join(out, f)), f


def test_hlo_text_is_parseable_module(built):
    out, _ = built
    text = open(os.path.join(out, "train_step.hlo.txt")).read()
    assert text.startswith("HloModule"), "must be HLO text, not a serialized proto"
    assert "ENTRY" in text


def test_manifest_matches_binaries(built):
    out, manifest = built
    frozen_elems = sum(int(np.prod(p["shape"])) for p in manifest["frozen"])
    train_elems = sum(int(np.prod(p["shape"])) for p in manifest["trainable"])
    assert os.path.getsize(os.path.join(out, "frozen.bin")) == 4 * frozen_elems
    assert os.path.getsize(os.path.join(out, "trainable.bin")) == 4 * train_elems
    assert manifest["num_frozen_params"] == frozen_elems
    assert manifest["num_trainable_params"] == train_elems


def test_manifest_names_sorted(built):
    _, manifest = built
    for group in ["frozen", "trainable"]:
        names = [p["name"] for p in manifest[group]]
        assert names == sorted(names)


def test_initial_trainable_is_zero(built):
    out, _ = built
    tr = np.fromfile(os.path.join(out, "trainable.bin"), dtype=np.float32)
    assert np.all(tr == 0.0), "adapters must start at zero (backbone-equivalent init)"


def test_parameter_count_ordering(built):
    _, manifest = built
    # adapters must be a small fraction of the backbone (the paper's
    # parameter-efficiency premise)
    assert manifest["num_trainable_params"] * 10 < manifest["num_frozen_params"]
