"""L1 correctness: Pallas rdFFT kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/values; fixed cases pin the paper's worked
examples (Fig. 1's 8/16-point layouts).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import circulant as C
from compile.kernels import rdfft as K
from compile.kernels import ref as R

SIZES = [2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]


def rand(shape, seed=0, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape), dtype=dtype)


# ----------------------------------------------------------------- fixed


@pytest.mark.parametrize("n", SIZES)
def test_forward_matches_ref(n):
    x = rand((3, n), seed=n)
    got = K.rdfft(x)
    want = R.rdfft_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * np.sqrt(n))


@pytest.mark.parametrize("n", SIZES)
def test_roundtrip_identity(n):
    x = rand((2, n), seed=n + 1)
    np.testing.assert_allclose(K.irdfft(K.rdfft(x)), x, rtol=1e-4, atol=1e-5 * n)


def test_packed_layout_8point_example():
    # FFT([1..8]) = [36, -4+9.657j, -4+4j, -4+1.657j, -4, ...]
    # packed: [36, -4, -4, -4, -4, 1.657, 4, 9.657]
    x = jnp.arange(1.0, 9.0)[None]
    got = np.asarray(K.rdfft(x))[0]
    expect = np.array([36, -4, -4, -4, -4, 1.6568542, 4, 9.656854], np.float32)
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-4)


def test_dc_and_nyquist_slots_are_real_parts():
    x = rand((1, 64), seed=9)
    packed = np.asarray(K.rdfft(x))[0]
    spec = np.fft.rfft(np.asarray(x)[0])
    assert abs(packed[0] - spec[0].real) < 1e-4
    assert abs(packed[32] - spec[32].real) < 1e-4
    assert abs(spec[0].imag) < 1e-6 and abs(spec[32].imag) < 1e-5


def test_batch_shapes_preserved():
    for shape in [(64,), (3, 64), (2, 3, 64), (2, 1, 2, 64)]:
        x = rand(shape, seed=1)
        assert K.rdfft(x).shape == shape
        assert K.irdfft(x).shape == shape


def test_bf16_supported_and_close():
    # The paper's point: fft/rfft libraries reject bf16; rdFFT supports it.
    x32 = rand((4, 128), seed=3)
    xb = x32.astype(jnp.bfloat16)
    got = K.rdfft(xb)
    assert got.dtype == jnp.bfloat16
    want = R.rdfft_ref(x32)
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - want))) / scale
    assert err < 0.05, f"bf16 relative error too large: {err}"


def test_spectral_mul_matches_complex_product():
    a = K.rdfft(rand((5, 64), seed=4))
    b = K.rdfft(rand((5, 64), seed=5))
    np.testing.assert_allclose(
        K.spectral_mul(a, b), R.spectral_mul_ref(a, b), rtol=1e-4, atol=1e-3
    )


def test_packed_conj_is_sign_flip_of_upper_half():
    a = K.rdfft(rand((2, 32), seed=6))
    c = C.packed_conj(a)
    np.testing.assert_allclose(np.asarray(c)[:, :17], np.asarray(a)[:, :17])
    np.testing.assert_allclose(np.asarray(c)[:, 17:], -np.asarray(a)[:, 17:])


# ------------------------------------------------------------ hypothesis


@settings(max_examples=40, deadline=None)
@given(
    log_n=st.integers(min_value=1, max_value=9),
    batch=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_forward_and_roundtrip(log_n, batch, seed):
    n = 1 << log_n
    x = rand((batch, n), seed=seed)
    got = K.rdfft(x)
    np.testing.assert_allclose(got, R.rdfft_ref(x), rtol=1e-3, atol=1e-3 * np.sqrt(n))
    np.testing.assert_allclose(K.irdfft(got), x, rtol=1e-3, atol=1e-4 * n)


@settings(max_examples=20, deadline=None)
@given(
    log_n=st.integers(min_value=2, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31),
    scale=st.floats(min_value=1e-3, max_value=1e3),
)
def test_hypothesis_linearity_and_scaling(log_n, seed, scale):
    n = 1 << log_n
    x = rand((2, n), seed=seed)
    y = rand((2, n), seed=seed + 1)
    lhs = K.rdfft(x * scale + y)
    rhs = K.rdfft(x) * scale + K.rdfft(y)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3 * scale * np.sqrt(n))


@settings(max_examples=20, deadline=None)
@given(
    log_p=st.integers(min_value=1, max_value=6),
    rb=st.integers(min_value=1, max_value=3),
    cb=st.integers(min_value=1, max_value=3),
    b=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_block_circulant_forward(log_p, rb, cb, b, seed):
    p = 1 << log_p
    c = rand((rb, cb, p), seed=seed)
    x = rand((b, cb * p), seed=seed + 1)
    got = C.block_circulant_apply(c, x)
    want = R.block_circulant_matvec_ref(c, x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3 * p)


@settings(max_examples=10, deadline=None)
@given(
    log_p=st.integers(min_value=2, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_eq5_gradients_match_autodiff(log_p, seed):
    """Eq. 5 custom-VJP vs differentiating straight through the oracle."""
    p = 1 << log_p
    rb, cb, b = 2, 2, 3
    c = rand((rb, cb, p), seed=seed)
    x = rand((b, cb * p), seed=seed + 1)
    g0 = rand((b, rb * p), seed=seed + 2)
    f = lambda c, x: jnp.sum(C.block_circulant_apply(c, x) * g0)
    fr = lambda c, x: jnp.sum(R.block_circulant_matvec_ref(c, x) * g0)
    dc, dx = jax.grad(f, (0, 1))(c, x)
    dcr, dxr = jax.grad(fr, (0, 1))(c, x)
    np.testing.assert_allclose(dc, dcr, rtol=1e-3, atol=1e-3 * p)
    np.testing.assert_allclose(dx, dxr, rtol=1e-3, atol=1e-3 * p)


def test_parseval_energy_preserved():
    n = 256
    x = rand((1, n), seed=8)
    packed = np.asarray(K.rdfft(x))[0]
    e_time = float(np.sum(np.asarray(x) ** 2))
    e_freq = packed[0] ** 2 + packed[n // 2] ** 2
    e_freq += 2 * float(np.sum(packed[1 : n // 2] ** 2) + np.sum(packed[n // 2 + 1 :] ** 2))
    assert abs(e_time - e_freq / n) / e_time < 1e-4


def test_tiled_grid_path_matches_single_block(monkeypatch):
    """BLOCK_ROWS>0 (the TPU BlockSpec grid path) must agree with the
    CPU single-block default, including the row-padding logic."""
    x = rand((5, 64), seed=11)  # 5 rows -> padded to 8
    want = K.rdfft(x)
    monkeypatch.setattr(K, "BLOCK_ROWS", 8)
    got = K.rdfft(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    back = K.irdfft(got)
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-4)


def test_vmem_report_fields():
    rep = K.vmem_report(4096)
    assert rep["vmem_tile_bytes"] == rep["block_rows"] * 4096 * 4
    assert rep["block_rows"] >= 1
    assert rep["stages"] == 12
    assert rep["arith_intensity"] > 0
