"""AOT compile path (build time only — never on the training path).

Lowers the jitted train/eval steps of ``model.py`` to **HLO text** and
dumps the initial parameter values, producing everything the Rust
coordinator needs:

    artifacts/
      train_step.hlo.txt   SGD step: (frozen…, trainable…, tokens, targets)
                           -> (new_trainable…, loss)
      eval_step.hlo.txt    loss only
      frozen.bin           frozen params, f32 LE, sorted-name order
      trainable.bin        initial adapter params, f32 LE, sorted-name order
      manifest.json        shapes/order/config contract for the Rust side

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --preset test --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(cfg: M.Config, out_dir: str, seed: int = 0) -> dict:
    cfg.validate()
    os.makedirs(out_dir, exist_ok=True)
    key = jax.random.PRNGKey(seed)
    kf, kt = jax.random.split(key)
    frozen = M.init_frozen(cfg, kf)
    trainable = M.init_trainable(cfg, kt)
    frozen_names = sorted(frozen.keys())
    train_names = sorted(trainable.keys())

    step = M.make_train_step(cfg)
    eval_step = M.make_eval_step(cfg)

    nf, nt = len(frozen_names), len(train_names)

    def flat_train(*args):
        fz = dict(zip(frozen_names, args[:nf]))
        tr = dict(zip(train_names, args[nf : nf + nt]))
        tokens, targets = args[nf + nt], args[nf + nt + 1]
        new, loss = step(fz, tr, tokens, targets)
        return tuple(new[n] for n in train_names) + (loss,)

    def flat_eval(*args):
        fz = dict(zip(frozen_names, args[:nf]))
        tr = dict(zip(train_names, args[nf : nf + nt]))
        tokens, targets = args[nf + nt], args[nf + nt + 1]
        return (eval_step(fz, tr, tokens, targets),)

    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    specs = (
        [jax.ShapeDtypeStruct(frozen[n].shape, jnp.float32) for n in frozen_names]
        + [jax.ShapeDtypeStruct(trainable[n].shape, jnp.float32) for n in train_names]
        + [tok_spec, tok_spec]
    )

    print(f"[aot] lowering train_step ({cfg}) ...")
    train_hlo = to_hlo_text(jax.jit(flat_train).lower(*specs))
    with open(os.path.join(out_dir, "train_step.hlo.txt"), "w") as f:
        f.write(train_hlo)
    print(f"[aot]   train_step.hlo.txt: {len(train_hlo)} chars")

    print("[aot] lowering eval_step ...")
    eval_hlo = to_hlo_text(jax.jit(flat_eval).lower(*specs))
    with open(os.path.join(out_dir, "eval_step.hlo.txt"), "w") as f:
        f.write(eval_hlo)
    print(f"[aot]   eval_step.hlo.txt: {len(eval_hlo)} chars")

    def dump(names, tree, path):
        with open(path, "wb") as f:
            for n in names:
                f.write(np.asarray(tree[n], dtype=np.float32).tobytes())

    dump(frozen_names, frozen, os.path.join(out_dir, "frozen.bin"))
    dump(train_names, trainable, os.path.join(out_dir, "trainable.bin"))

    manifest = {
        "config": dataclasses.asdict(cfg),
        "frozen": [{"name": n, "shape": list(frozen[n].shape)} for n in frozen_names],
        "trainable": [
            {"name": n, "shape": list(trainable[n].shape)} for n in train_names
        ],
        "tokens_shape": [cfg.batch, cfg.seq_len],
        "train_outputs": len(train_names) + 1,  # new params + loss
        "num_frozen_params": int(sum(np.prod(frozen[n].shape) for n in frozen_names)),
        "num_trainable_params": int(
            sum(np.prod(trainable[n].shape) for n in train_names)
        ),
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(
        f"[aot] wrote manifest: {manifest['num_frozen_params']} frozen + "
        f"{manifest['num_trainable_params']} trainable params"
    )
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="test", choices=sorted(M.PRESETS.keys()))
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    build(M.PRESETS[args.preset], args.out_dir, args.seed)


if __name__ == "__main__":
    main()
