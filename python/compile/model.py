"""Layer 2: JAX transformer language model with block-circulant adapters.

A GPT-style causal LM whose linear projections are adapted the paper's way
(§3.3 / §5.1.2): the pretrained dense weights are **frozen** and a
block-circulant adapter (computed via the L1 Pallas rdFFT kernels with
Eq. 4/5 forward/backward) is trained on top:

    y = x · W₀ᵀ + BCA_p(x)

The whole SGD train step (forward, backward, parameter update) is a single
jitted function, AOT-lowered once by ``aot.py`` to HLO text; the Rust
coordinator threads the trainable parameters through successive
executions, so Python never runs at training time.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels.circulant import block_circulant_apply


@dataclasses.dataclass(frozen=True)
class Config:
    """Model/ training-step hyperparameters (fixed at AOT time)."""

    vocab: int = 256  # byte-level tokenizer
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    seq_len: int = 128
    batch: int = 8
    p: int = 64  # circulant block size
    lr: float = 0.05

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def validate(self) -> "Config":
        assert self.d_model % self.n_heads == 0
        assert self.d_model % self.p == 0 and self.d_ff % self.p == 0, (
            "d_model and d_ff must be multiples of the circulant block size"
        )
        assert self.p >= 2 and (self.p & (self.p - 1)) == 0
        return self


# Presets used by `make artifacts` / the examples.
PRESETS: dict[str, Config] = {
    # fast preset for CI-style checks
    "test": Config(
        d_model=64, n_layers=2, n_heads=2, d_ff=128, seq_len=32, batch=2, p=16, lr=0.15
    ),
    # the end-to-end training run of EXPERIMENTS.md. Sized for this
    # testbed: the build machine exposes a SINGLE CPU core, so the run is
    # ~4.8M params (a 100M-param run would be ~1000s/step here; see
    # EXPERIMENTS.md for the honest accounting). The architecture and
    # adapter wiring are identical to larger configs — only widths shrink.
    "e2e": Config(d_model=256, n_layers=6, n_heads=4, d_ff=1024, seq_len=128, batch=4, p=64, lr=0.1),
    # the 26M-param config (kept for multi-core machines)
    "e2e-large": Config(d_model=512, n_layers=8, n_heads=8, d_ff=2048, seq_len=128, batch=8, p=128),
    # mid-size preset for throughput benches
    "mid": Config(d_model=256, n_layers=4, n_heads=4, d_ff=1024, seq_len=64, batch=4, p=64),
}


def _split(key, n):
    return jax.random.split(key, n)


def init_frozen(cfg: Config, key) -> dict[str, Any]:
    """The frozen 'pretrained' backbone. In the paper this is RoBERTa /
    LLaMA; here it is randomly initialized and trained never — the adapters
    do all the learning (the substitution DESIGN.md documents)."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    keys = iter(_split(key, 4 + 6 * cfg.n_layers))
    s = 1.0 / math.sqrt(d)
    frozen = {
        "emb": jax.random.normal(next(keys), (v, d)) * 0.02,
        "pos": jax.random.normal(next(keys), (cfg.seq_len, d)) * 0.02,
        "lnf_scale": jnp.ones((d,)),
    }
    for i in range(cfg.n_layers):
        frozen[f"l{i}.wq"] = jax.random.normal(next(keys), (d, d)) * s
        frozen[f"l{i}.wk"] = jax.random.normal(next(keys), (d, d)) * s
        frozen[f"l{i}.wv"] = jax.random.normal(next(keys), (d, d)) * s
        frozen[f"l{i}.wo"] = jax.random.normal(next(keys), (d, d)) * s
        frozen[f"l{i}.w1"] = jax.random.normal(next(keys), (ff, d)) * s
        frozen[f"l{i}.w2"] = jax.random.normal(next(keys), (d, ff)) * (1.0 / math.sqrt(ff))
        frozen[f"l{i}.ln1"] = jnp.ones((d,))
        frozen[f"l{i}.ln2"] = jnp.ones((d,))
    return frozen


#: the projections that receive a circulant adapter, with (rows, cols)
#: expressed in terms of (d_model, d_ff).
ADAPTED = ["wq", "wv", "w1", "w2"]


def init_trainable(cfg: Config, key) -> dict[str, Any]:
    """Zero-initialized circulant adapters (zero spectrum ⇒ the adapted
    model starts exactly at the frozen backbone, like LoRA's zero-B)."""
    d, ff, p = cfg.d_model, cfg.d_ff, cfg.p
    shapes = {
        "wq": (d // p, d // p, p),
        "wv": (d // p, d // p, p),
        "w1": (ff // p, d // p, p),
        "w2": (d // p, ff // p, p),
    }
    del key  # zero init needs no randomness
    train = {}
    for i in range(cfg.n_layers):
        for name in ADAPTED:
            train[f"l{i}.{name}.c"] = jnp.zeros(shapes[name], jnp.float32)
    return train


def _layernorm(x, scale):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale


def _adapted(frozen, trainable, layer: int, name: str, x):
    """Frozen dense projection + circulant adapter (the paper's adapted
    linear)."""
    w0 = frozen[f"l{layer}.{name}"]
    y = x @ w0.T
    c = trainable.get(f"l{layer}.{name}.c")
    if c is not None:
        y = y + block_circulant_apply(c, x)
    return y


def forward(cfg: Config, frozen, trainable, tokens):
    """Causal LM forward. tokens: (B, T) int32 → logits (B, T, vocab)."""
    b, t = tokens.shape
    h = frozen["emb"][tokens] * math.sqrt(cfg.d_model) + frozen["pos"][:t]
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    for i in range(cfg.n_layers):
        x = _layernorm(h, frozen[f"l{i}.ln1"])
        q = _adapted(frozen, trainable, i, "wq", x)
        k = x @ frozen[f"l{i}.wk"].T
        v = _adapted(frozen, trainable, i, "wv", x)
        qh = q.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        kh = k.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        vh = v.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / math.sqrt(cfg.head_dim)
        att = jnp.where(mask[None, None] > 0, att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, vh)
        o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        h = h + o @ frozen[f"l{i}.wo"].T
        x = _layernorm(h, frozen[f"l{i}.ln2"])
        u = _adapted(frozen, trainable, i, "w1", x)
        u = jax.nn.gelu(u)
        h = h + _adapted(frozen, trainable, i, "w2", u)
    h = _layernorm(h, frozen["lnf_scale"])
    return h @ frozen["emb"].T


def loss_fn(cfg: Config, frozen, trainable, tokens, targets):
    """Mean next-token cross entropy. targets: (B, T) int32 (already
    shifted by the data pipeline; positions with target == -1 are
    masked)."""
    logits = forward(cfg, frozen, trainable, tokens)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.maximum(targets, 0)
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = logz - picked
    mask = (targets >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_train_step(cfg: Config):
    """One SGD step over the adapter parameters only (the backbone is
    frozen). Returns (new_trainable..., loss) — the function `aot.py`
    lowers for the Rust training loop."""

    def step(frozen, trainable, tokens, targets):
        loss, grads = jax.value_and_grad(
            lambda tr: loss_fn(cfg, frozen, tr, tokens, targets)
        )(trainable)
        new = jax.tree_util.tree_map(lambda pp, g: pp - cfg.lr * g, trainable, grads)
        return new, loss

    return step


def make_eval_step(cfg: Config):
    def step(frozen, trainable, tokens, targets):
        return loss_fn(cfg, frozen, trainable, tokens, targets)

    return step


def trainable_spec(cfg: Config) -> list[tuple[str, tuple[int, ...]]]:
    """Deterministic (name, shape) order of trainable parameters — the
    contract between `aot.py`'s manifest and the Rust runtime."""
    t = init_trainable(cfg, jax.random.PRNGKey(0))
    names = sorted(t.keys())
    return [(n, tuple(t[n].shape)) for n in names]


def frozen_spec(cfg: Config) -> list[tuple[str, tuple[int, ...]]]:
    f = init_frozen(cfg, jax.random.PRNGKey(0))
    names = sorted(f.keys())
    return [(n, tuple(f[n].shape)) for n in names]
