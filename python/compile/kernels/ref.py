"""Pure-jnp reference oracle for the rdFFT packed layout.

Everything here is *deliberately naive* (built on ``jnp.fft.rfft``): it is
the correctness ground truth the Pallas kernels in ``rdfft.py`` are tested
against (pytest + hypothesis-style sweeps in ``python/tests``), never part
of the lowered model.

Packed layout (paper §4.1): for a length-``n`` real signal whose rFFT is
``y_0..y_{n/2}``, the packed real buffer stores ``Re(y_k)`` at index ``k``
and ``Im(y_k)`` at index ``n-k`` (``1 <= k < n/2``); the always-real DC and
Nyquist coefficients sit at indices ``0`` and ``n/2``.
"""

from __future__ import annotations

import jax.numpy as jnp


def pack_spectrum(spec: jnp.ndarray, n: int) -> jnp.ndarray:
    """Pack an rFFT half-spectrum ``(..., n/2+1)`` complex into the
    ``(..., n)`` real packed layout."""
    re = jnp.real(spec)
    im = jnp.imag(spec)
    # indices 0..n/2 hold the real parts; indices n/2+1..n-1 hold the
    # imaginary parts of y_{n/2-1} .. y_1 (i.e. reversed).
    head = re  # (..., n/2+1)
    tail = im[..., 1 : n // 2][..., ::-1]  # Im(y_{n/2-1}) .. Im(y_1)
    return jnp.concatenate([head, tail], axis=-1)


def unpack_spectrum(packed: jnp.ndarray) -> jnp.ndarray:
    """Decode a packed ``(..., n)`` real buffer into the rFFT half-spectrum
    ``(..., n/2+1)`` complex."""
    n = packed.shape[-1]
    re = packed[..., : n // 2 + 1]
    imag_mid = packed[..., n // 2 + 1 :][..., ::-1]  # Im(y_1)..Im(y_{n/2-1})
    zeros = jnp.zeros_like(packed[..., :1])
    im = jnp.concatenate([zeros, imag_mid, zeros], axis=-1)
    return re + 1j * im


def rdfft_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Packed forward transform of a real signal (last axis)."""
    n = x.shape[-1]
    return pack_spectrum(jnp.fft.rfft(x.astype(jnp.float32), axis=-1), n)


def irdfft_ref(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`rdfft_ref` (last axis)."""
    n = packed.shape[-1]
    return jnp.fft.irfft(unpack_spectrum(packed.astype(jnp.float32)), n=n, axis=-1)


def spectral_mul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Packed-domain elementwise complex product (paper Eq. 4's ⊙)."""
    n = a.shape[-1]
    return pack_spectrum(unpack_spectrum(a) * unpack_spectrum(b), n)


def spectral_conj_mul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Packed-domain ``conj(a) ⊙ b`` (paper Eq. 5's backward product)."""
    n = a.shape[-1]
    return pack_spectrum(jnp.conj(unpack_spectrum(a)) * unpack_spectrum(b), n)


def circulant_matvec_ref(c: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``y = C x`` for the circulant matrix with first column ``c``
    (broadcasts over leading axes of ``x``)."""
    n = c.shape[-1]
    return jnp.fft.irfft(
        jnp.fft.rfft(c, axis=-1) * jnp.fft.rfft(x, axis=-1), n=n, axis=-1
    )


def block_circulant_matvec_ref(c: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Block-circulant product.

    ``c``: ``(rb, cb, p)`` first columns of each circulant block.
    ``x``: ``(..., cb*p)``.
    Returns ``(..., rb*p)``.
    """
    rb, cb, p = c.shape
    xb = x.reshape(x.shape[:-1] + (cb, p))
    ch = jnp.fft.rfft(c, axis=-1)  # (rb, cb, p/2+1)
    xh = jnp.fft.rfft(xb, axis=-1)  # (..., cb, p/2+1)
    yh = jnp.einsum("ijk,...jk->...ik", ch, xh)
    y = jnp.fft.irfft(yh, n=p, axis=-1)
    return y.reshape(x.shape[:-1] + (rb * p,))


def circulant_dense_ref(c: jnp.ndarray) -> jnp.ndarray:
    """Materialize the dense circulant matrix for first column ``c`` —
    used only by tests."""
    n = c.shape[0]
    idx = (jnp.arange(n)[:, None] - jnp.arange(n)[None, :]) % n
    return c[idx]
