"""Block-circulant adapter op built on the Pallas rdFFT kernels, with the
paper's Eq. 5 backward pass as a ``custom_vjp``.

Forward  (Eq. 4):  y_i = IFFT( Σ_j ĉ_ij ⊙ x̂_j )
Backward (Eq. 5):  dx_j = IFFT( Σ_i conj(ĉ_ij) ⊙ ĝ_i )
                   dc_ij = IFFT( Σ_batch conj(x̂_j) ⊙ ĝ_i )

All products run in the packed real layout (conjugation = sign flip of the
upper half — ``packed_conj``), so both passes stay entirely in the real
domain, matching the paper's "consistent forward and backward passes
entirely within the real domain".

Note on in-place semantics: at the XLA level these ops are functional;
the *in-place* property of rdFFT is physical in the Rust core and in the
paper's CUDA kernels, and is expressed here through
``input_output_aliases`` on the underlying ``pallas_call`` (see
``rdfft.py``). What this layer preserves is the *math* and the operator
structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rdfft as K


def packed_conj(a: jnp.ndarray) -> jnp.ndarray:
    """Conjugate a packed spectrum: negate indices n/2+1 .. n-1."""
    n = a.shape[-1]
    return jnp.concatenate([a[..., : n // 2 + 1], -a[..., n // 2 + 1 :]], axis=-1)


def _pair_mul_sum(ch: jnp.ndarray, xh: jnp.ndarray) -> jnp.ndarray:
    """Σ_j ĉ_ij ⊙ x̂_j for packed spectra.

    ``ch``: (rb, cb, p); ``xh``: (B, cb, p). Returns (B, rb, p).
    Packing is linear, so summing packed products equals packing the sum.
    """
    rb, cb, p = ch.shape
    b = xh.shape[0]
    # Broadcast to (B, rb, cb, p) and use the packed-mul kernel once.
    ch_b = jnp.broadcast_to(ch[None], (b, rb, cb, p))
    xh_b = jnp.broadcast_to(xh[:, None], (b, rb, cb, p))
    prod = K.spectral_mul(ch_b, xh_b)
    return prod.sum(axis=2)


@jax.custom_vjp
def block_circulant_apply(c: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``y = W x`` for the block-circulant weight defined by first columns
    ``c``: (rb, cb, p). ``x``: (..., cb*p) → (..., rb*p)."""
    y, _ = _bca_fwd(c, x)
    return y


def _bca_fwd(c, x):
    rb, cb, p = c.shape
    lead = x.shape[:-1]
    xb = x.reshape((-1, cb, p))
    ch = K.rdfft(c)
    xh = K.rdfft(xb)
    yh = _pair_mul_sum(ch, xh)  # (B, rb, p)
    y = K.irdfft(yh).reshape(lead + (rb * p,))
    return y, (ch, xh)


def _bca_bwd(res, g):
    ch, xh = res
    rb, cb, p = ch.shape
    lead = g.shape[:-1]
    gb = g.reshape((-1, rb, p))
    gh = K.rdfft(gb)  # (B, rb, p)
    b = gh.shape[0]
    # dc_ij = IFFT( Σ_b conj(x̂_bj) ⊙ ĝ_bi )
    xh_c = packed_conj(xh)  # (B, cb, p)
    prod = K.spectral_mul(
        jnp.broadcast_to(xh_c[:, None], (b, rb, cb, p)),
        jnp.broadcast_to(gh[:, :, None], (b, rb, cb, p)),
    )
    dc = K.irdfft(prod.sum(axis=0))  # (rb, cb, p)
    # dx_bj = IFFT( Σ_i conj(ĉ_ij) ⊙ ĝ_bi )
    ch_c = packed_conj(ch)
    prod2 = K.spectral_mul(
        jnp.broadcast_to(ch_c[None], (b, rb, cb, p)),
        jnp.broadcast_to(gh[:, :, None], (b, rb, cb, p)),
    )
    dx = K.irdfft(prod2.sum(axis=1)).reshape(lead + (cb * p,))
    return dc, dx


block_circulant_apply.defvjp(_bca_fwd, _bca_bwd)
