"""Pallas rdFFT kernels (Layer 1).

The paper's in-place real-domain FFT, expressed as Pallas kernels so the
L2 JAX model lowers them into the single AOT HLO module the Rust runtime
executes.

Hardware adaptation (paper targets CUDA; DESIGN.md §Hardware-Adaptation):
the CUDA implementation maps butterfly 4-groups to thread blocks with
explicit ``__syncthreads``. On TPU the whole ``p``-point block fits VMEM,
so each Cooley–Tukey stage becomes one *vectorized* slice/concat butterfly
over the block-resident array — log2(n) statically unrolled stages, no
synchronization, batch tiled over the grid via ``BlockSpec``. The symmetric
4-element groups of Proposition 1 appear here as mirrored slices
(``e[..., 1:m//2]`` with ``e[..., :m//2-1:-1]`` etc.), which XLA fuses into
gather-free reversals.

In-place-ness: expressed via ``input_output_aliases={0: 0}`` on
``pallas_call`` — the output buffer *is* the input buffer. Kernels run
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls; see
/opt/xla-example/README.md), so the aliasing is semantic on this testbed
and physical on a real TPU.

All kernels operate on the last axis; leading axes are batch.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Rows per grid step: batch is tiled over the Pallas grid so each program
# instance keeps an (BLOCK_ROWS, n) tile in VMEM. With n <= 4096 f32 that
# is at most 8*4096*4 = 128 KiB, far under the ~16 MiB VMEM budget.
#
# On a real TPU the grid pipelines HBM<->VMEM tile transfers; under
# interpret=True on CPU every grid step lowers to a sequential while-loop
# iteration, which serializes the batch and destroys XLA's ability to
# vectorize over it. RDFFT_BLOCK_ROWS=0 (the CPU default) therefore runs
# the whole array as a single block; set it to 8 when lowering for TPU.
import os as _os

BLOCK_ROWS = int(_os.environ.get("RDFFT_BLOCK_ROWS", "0"))


def _bitrev(x: jnp.ndarray) -> jnp.ndarray:
    """Bit-reversal permutation of the last axis, expressed as a
    reshape/transpose (no gather, no captured index constants — Pallas
    kernels may not close over constants, and on TPU this lowers to pure
    layout ops)."""
    n = x.shape[-1]
    bits = n.bit_length() - 1
    if bits <= 1:
        return x
    lead = x.shape[:-1]
    t = x.reshape(lead + (2,) * bits)
    axes = tuple(range(len(lead))) + tuple(
        len(lead) + bits - 1 - i for i in range(bits)
    )
    return t.transpose(axes).reshape(lead + (n,))


def _twiddles(m: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Forward twiddles W_{2m}^k = (cos, -sin) for k = 1..m/2-1, computed
    from an iota so no constant is captured by the kernel."""
    k = jnp.arange(1, m // 2, dtype=jnp.float32)
    theta = (2.0 * math.pi / (2 * m)) * k
    return jnp.cos(theta), -jnp.sin(theta)


def _forward_stage(x: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """One DIT stage over a flat (..., n) array: combine packed m-blocks
    sitting in adjacent halves of each 2m-block into packed 2m-blocks."""
    nb = n // (2 * m)
    blk = x.reshape(x.shape[:-1] + (nb, 2 * m))
    e = blk[..., :m]
    o = blk[..., m:]
    # k = 0 lane
    e0 = e[..., :1] + o[..., :1]
    o0 = e[..., :1] - o[..., :1]
    if m == 1:
        out = jnp.concatenate([e0, o0], axis=-1)
        return out.reshape(x.shape)
    # 1 <= k < m/2 four-element groups (empty when m == 2)
    if m >= 4:
        wr, wi = _twiddles(m)
        er = e[..., 1 : m // 2]
        ei = e[..., : m // 2 : -1]  # e[m-1] .. e[m/2+1] == ei for k=1..m/2-1
        orr = o[..., 1 : m // 2]
        oi = o[..., : m // 2 : -1]
        tr = wr * orr - wi * oi
        ti = wr * oi + wi * orr
        ykr = er + tr  # -> e_new[k]
        ymkr = er - tr  # -> e_new[m-k]
        yki = ei + ti  # -> o_new[m-k]
        ymki = ti - ei  # -> o_new[k]
        e_new = jnp.concatenate(
            [e0, ykr, e[..., m // 2 : m // 2 + 1], ymkr[..., ::-1]], axis=-1
        )
        o_new = jnp.concatenate(
            [o0, ymki, -o[..., m // 2 : m // 2 + 1], yki[..., ::-1]], axis=-1
        )
    else:  # m == 2: only the k=0 and k=m/2 lanes
        e_new = jnp.concatenate([e0, e[..., 1:2]], axis=-1)
        o_new = jnp.concatenate([o0, -o[..., 1:2]], axis=-1)
    out = jnp.concatenate([e_new, o_new], axis=-1)
    return out.reshape(x.shape)


def _inverse_stage(x: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Exact inverse of :func:`_forward_stage` (carries the 1/2 factor)."""
    nb = n // (2 * m)
    blk = x.reshape(x.shape[:-1] + (nb, 2 * m))
    e = blk[..., :m]
    o = blk[..., m:]
    e0 = 0.5 * (e[..., :1] + o[..., :1])
    o0 = 0.5 * (e[..., :1] - o[..., :1])
    if m == 1:
        out = jnp.concatenate([e0, o0], axis=-1)
        return out.reshape(x.shape)
    if m >= 4:
        wr, wi = _twiddles(m)
        a = e[..., 1 : m // 2]  # er + tr
        b = e[..., : m // 2 : -1]  # er - tr
        c = o[..., : m // 2 : -1]  # ei + ti
        d = o[..., 1 : m // 2]  # ti - ei
        er = 0.5 * (a + b)
        tr = 0.5 * (a - b)
        ti = 0.5 * (c + d)
        ei = 0.5 * (c - d)
        orr = tr * wr + ti * wi
        oi = ti * wr - tr * wi
        e_new = jnp.concatenate(
            [e0, er, e[..., m // 2 : m // 2 + 1], ei[..., ::-1]], axis=-1
        )
        o_new = jnp.concatenate(
            [o0, orr, -o[..., m // 2 : m // 2 + 1], oi[..., ::-1]], axis=-1
        )
    else:  # m == 2
        e_new = jnp.concatenate([e0, e[..., 1:2]], axis=-1)
        o_new = jnp.concatenate([o0, -o[..., 1:2]], axis=-1)
    out = jnp.concatenate([e_new, o_new], axis=-1)
    return out.reshape(x.shape)


def _rdfft_value(x: jnp.ndarray) -> jnp.ndarray:
    """Forward packed transform on a concrete array (used inside kernels)."""
    n = x.shape[-1]
    x = _bitrev(x)
    m = 1
    while m < n:
        x = _forward_stage(x, m, n)
        m *= 2
    return x


def _irdfft_value(x: jnp.ndarray) -> jnp.ndarray:
    """Inverse packed transform on a concrete array."""
    n = x.shape[-1]
    m = n // 2
    while m >= 1:
        x = _inverse_stage(x, m, n)
        m //= 2
    return _bitrev(x)


# ---------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------


def _rdfft_kernel(x_ref, o_ref):
    """Forward kernel body: whole tile resident in VMEM; butterfly math in
    f32 regardless of storage dtype (the bf16 path of the paper)."""
    x = x_ref[...]
    y = _rdfft_value(x.astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


def _irdfft_kernel(x_ref, o_ref):
    x = x_ref[...]
    y = _irdfft_value(x.astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


def _mul_kernel(a_ref, b_ref, o_ref):
    """Packed-domain elementwise complex product kernel (Eq. 4's ⊙),
    writing into a's buffer (input_output_aliases)."""
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    n = a.shape[-1]
    a0 = a[..., :1] * b[..., :1]
    any_ = a[..., n // 2 : n // 2 + 1] * b[..., n // 2 : n // 2 + 1]
    ar = a[..., 1 : n // 2]
    ai = a[..., : n // 2 : -1]
    br = b[..., 1 : n // 2]
    bi = b[..., : n // 2 : -1]
    re = ar * br - ai * bi
    im = ar * bi + ai * br
    out = jnp.concatenate([a0, re, any_, im[..., ::-1]], axis=-1)
    o_ref[...] = out.astype(o_ref.dtype)


def _pad_rows(x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    """Pad the (flattened) batch dim up to a multiple of BLOCK_ROWS."""
    rows = x.shape[0]
    padded = (rows + BLOCK_ROWS - 1) // BLOCK_ROWS * BLOCK_ROWS
    if padded != rows:
        x = jnp.concatenate(
            [x, jnp.zeros((padded - rows,) + x.shape[1:], x.dtype)], axis=0
        )
    return x, rows


def _tiled_call(kernel, *args: jnp.ndarray) -> jnp.ndarray:
    """Run `kernel` over (rows, n) arrays, output aliased onto the first
    input (the in-place contract). Batch is tiled over the grid when
    BLOCK_ROWS > 0 (TPU); a single whole-array block otherwise (CPU)."""
    n = args[0].shape[-1]
    if BLOCK_ROWS <= 0:
        out = pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(args[0].shape, args[0].dtype),
            input_output_aliases={0: 0},
            interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
        )(*args)
        return out
    padded_args = []
    rows = None
    for a in args:
        p, rows = _pad_rows(a)
        padded_args.append(p)
    grid = (padded_args[0].shape[0] // BLOCK_ROWS,)
    spec = pl.BlockSpec((BLOCK_ROWS, n), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(padded_args[0].shape, padded_args[0].dtype),
        grid=grid,
        in_specs=[spec] * len(padded_args),
        out_specs=spec,
        input_output_aliases={0: 0},
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(*padded_args)
    return out[:rows]


def _flatten_batch(x: jnp.ndarray) -> tuple[jnp.ndarray, tuple]:
    lead = x.shape[:-1]
    flat = x.reshape((-1, x.shape[-1])) if lead else x.reshape((1, x.shape[-1]))
    return flat, lead


def rdfft(x: jnp.ndarray) -> jnp.ndarray:
    """In-place packed forward rdFFT over the last axis (any leading
    batch shape; n must be a power of two >= 2)."""
    n = x.shape[-1]
    assert n >= 2 and (n & (n - 1)) == 0, f"size must be a power of two, got {n}"
    flat, lead = _flatten_batch(x)
    out = _tiled_call(_rdfft_kernel, flat)
    return out.reshape(lead + (n,))


def irdfft(x: jnp.ndarray) -> jnp.ndarray:
    """In-place packed inverse rdFFT over the last axis."""
    n = x.shape[-1]
    assert n >= 2 and (n & (n - 1)) == 0
    flat, lead = _flatten_batch(x)
    out = _tiled_call(_irdfft_kernel, flat)
    return out.reshape(lead + (n,))


def spectral_mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Packed-domain elementwise complex product (broadcast-free; shapes
    must match)."""
    assert a.shape == b.shape
    n = a.shape[-1]
    flat_a, lead = _flatten_batch(a)
    flat_b, _ = _flatten_batch(b)
    out = _tiled_call(_mul_kernel, flat_a, flat_b)
    return out.reshape(lead + (n,))


def vmem_report(n: int, dtype_bytes: int = 4) -> dict:
    """Static VMEM/roofline estimate for DESIGN.md: bytes resident per grid
    step and arithmetic intensity of the fused stage pipeline. Uses the
    TPU tiling (8 rows) even when the CPU default BLOCK_ROWS=0 is active —
    the estimate describes the TPU deployment."""
    rows = BLOCK_ROWS if BLOCK_ROWS > 0 else 8
    tile = rows * n * dtype_bytes
    stages = int(math.log2(n))
    flops = rows * (stages * (n // 2) * 10)  # ~10 flops per 4-group
    return {
        "n": n,
        "block_rows": rows,
        "vmem_tile_bytes": tile,
        # stage pipeline keeps the tile resident; HBM traffic is one read +
        # one write of the tile
        "hbm_bytes": 2 * tile,
        "flops": flops,
        "arith_intensity": flops / (2 * tile),
        "stages": stages,
    }
