//! Integration: the Table 1 memory orderings must hold across the whole
//! experiment grid (these are the paper's headline claims, asserted as
//! invariants rather than eyeballed).

use rdfft::autograd::layers::Backend;
use rdfft::autograd::train::{measure_single_layer_with_state, Method};
use rdfft::coordinator::experiments::table1_cells;
use rdfft::memtrack::Category;
use rdfft::rdfft::engine::{self, EngineConfig};
use rdfft::rdfft::plan::cached;

#[test]
fn ours_strictly_below_rfft_below_fft_across_grid() {
    for d in [256usize, 512] {
        for b in [1usize, 4, 16] {
            for p in [64usize, 128] {
                let rows = table1_cells(d, &[b], p);
                let get = |name: &str| {
                    rows.iter().find(|(m, _, _)| m.starts_with(name)).map(|&(_, _, v)| v).unwrap()
                };
                let (fft, rfft, ours) = (get("fft"), get("rfft"), get("ours"));
                assert!(fft > rfft, "D={d} B={b} p={p}: fft {fft} !> rfft {rfft}");
                assert!(rfft > ours, "D={d} B={b} p={p}: rfft {rfft} !> ours {ours}");
            }
        }
    }
}

#[test]
fn ours_peak_is_dominated_by_params_and_grads() {
    // the paper's Table 1: ours ≈ trainable + grads (+ the activations
    // any method must allocate); intermediates ~ 0 during the step.
    let d = 512;
    let p = 128;
    let cell = measure_single_layer_with_state(
        Method::Circulant { backend: Backend::RdFft, p },
        d,
        4,
        1,
    );
    let s = cell.snapshot;
    let params_grads =
        s.at_peak[Category::Trainable.index()] + s.at_peak[Category::Gradients.index()];
    let inter = s.at_peak[Category::Intermediates.index()];
    // intermediates = x + y + g tensors only: 3 * b * d * 4 bytes
    assert!(
        inter <= 3 * 4 * d * 4 + 64,
        "rdfft intermediates at peak should be just the activations: {inter}"
    );
    assert!(params_grads > 0);
}

#[test]
fn memory_reduction_vs_full_finetune_grows_with_dimension() {
    // paper: ×(reduction) numbers grow from D=1024 to D=4096
    let ratio = |d: usize| {
        let ff = measure_single_layer_with_state(Method::FullFinetune, d, 1, 1).peak_bytes;
        let ours = measure_single_layer_with_state(
            Method::Circulant { backend: Backend::RdFft, p: 128 },
            d,
            1,
            1,
        )
        .peak_bytes;
        ff as f64 / ours as f64
    };
    let r_small = ratio(256);
    let r_big = ratio(1024);
    assert!(
        r_big > r_small,
        "reduction factor must grow with D: {r_small:.1} vs {r_big:.1}"
    );
    assert!(r_big > 20.0, "at D=1024 the paper-range reduction should exceed 20x: {r_big:.1}");
}

#[test]
fn batch_growth_hurts_fft_more_than_ours() {
    // paper: fft's advantage disappears at B=256 (crossover) because its
    // transient memory grows with batch much faster than ours. Compare
    // the per-batch *slopes* of the step peak (persistent state excluded):
    // ours adds only the mandatory activations per extra sample; fft adds
    // complex spectra and products on top.
    let d = 512;
    let p = 64;
    let peak = |bk: Backend, b: usize| {
        measure_single_layer_with_state(Method::Circulant { backend: bk, p }, d, b, 1).peak_bytes
            as f64
    };
    let fft_slope = peak(Backend::Fft, 16) - peak(Backend::Fft, 1);
    let ours_slope = peak(Backend::RdFft, 16) - peak(Backend::RdFft, 1);
    assert!(
        fft_slope > 1.5 * ours_slope,
        "fft transient memory must grow with batch much faster than ours: \
         {fft_slope:.0} vs {ours_slope:.0} bytes over 15 samples"
    );
}

#[test]
fn batch_engine_is_allocation_free_outside_thread_spawn() {
    // The engine's per-row work must register zero tracked allocations —
    // the only untracked cost is OS thread spawn above the parallel
    // threshold, which the paper's memory model does not count (it is not
    // tensor memory). Covers serial, threshold-gated, and forced-thread
    // paths.
    let n = 512usize;
    let rows = 16usize;
    let plan = cached(n);
    let base: Vec<f32> = (0..n * rows).map(|i| ((i * 13 + 5) % 97) as f32 / 48.0 - 1.0).collect();
    let configs = [
        EngineConfig::serial(),
        EngineConfig::new(),
        EngineConfig {
            par_min_rows: 2,
            par_min_elems: 0,
            par_chunk_elems: 1,
            max_threads: 4,
            ..EngineConfig::new()
        },
    ];
    for (ci, cfg) in configs.iter().enumerate() {
        let mut buf = base.clone();
        rdfft::memtrack::reset();
        let before = rdfft::memtrack::snapshot().alloc_count;
        engine::forward_batch_with(&plan, &mut buf, cfg);
        engine::inverse_batch_with(&plan, &mut buf, cfg);
        assert_eq!(
            rdfft::memtrack::snapshot().alloc_count,
            before,
            "engine cfg {ci} performed tracked allocations"
        );
        for i in 0..n * rows {
            assert!((buf[i] - base[i]).abs() < 1e-3, "cfg={ci} roundtrip i={i}");
        }
    }
}

#[test]
fn fourstep_tier_is_allocation_free_after_warmup() {
    // The four-step large-n tier's only allocation is each worker's
    // thread-local transpose tile, which is grown once and reused. After
    // a warm-up call on the same thread(s), a steady-state
    // forward+inverse must register zero tracked allocations — the
    // in-place discipline the plan's `heap_bytes` accounting relies on.
    let n = 2048usize;
    let rows = 4usize;
    let plan = cached(n);
    // Materialize the lazy tables BEFORE the warm-up: the table build is
    // a one-time cost, not part of the steady state this test bounds.
    assert!(plan.fourstep_lazy().is_some());
    let cfg = EngineConfig { fourstep_threshold: 1, ..EngineConfig::serial() };
    let base: Vec<f32> = (0..n * rows).map(|i| ((i * 29 + 11) % 89) as f32 / 44.0 - 1.0).collect();
    let mut buf = base.clone();
    // Warm-up: grows the calling thread's tile (serial config => all
    // phases run inline on this thread).
    engine::forward_batch_with(&plan, &mut buf, &cfg);
    engine::inverse_batch_with(&plan, &mut buf, &cfg);
    rdfft::memtrack::reset();
    let before = rdfft::memtrack::snapshot().alloc_count;
    engine::forward_batch_with(&plan, &mut buf, &cfg);
    engine::inverse_batch_with(&plan, &mut buf, &cfg);
    assert_eq!(
        rdfft::memtrack::snapshot().alloc_count,
        before,
        "four-step steady state performed tracked allocations"
    );
    for i in 0..n * rows {
        assert!((buf[i] - base[i]).abs() < 1e-3, "fourstep double roundtrip i={i}");
    }
}

#[test]
fn lora_sits_between_full_finetune_and_ours_at_small_batch() {
    let d = 512;
    let ff = measure_single_layer_with_state(Method::FullFinetune, d, 1, 1).peak_bytes;
    let lora = measure_single_layer_with_state(Method::Lora { rank: 32 }, d, 1, 1).peak_bytes;
    let ours = measure_single_layer_with_state(
        Method::Circulant { backend: Backend::RdFft, p: 128 },
        d,
        1,
        1,
    )
    .peak_bytes;
    assert!(ff > lora, "{ff} !> {lora}");
    assert!(lora > ours, "{lora} !> {ours}");
}
