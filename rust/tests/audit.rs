//! Fixture tests for the static invariant checker (`repro audit`).
//!
//! Every lint gets at least one inline fixture that fires and one that
//! passes; the suppression grammar is exercised both ways (a reasoned
//! `allow` silences, a reason-less one is itself a violation); and a
//! self-audit asserts the committed tree is clean — the same check
//! `scripts/ci.sh` runs as a hard gate.
//!
//! Fixtures are raw strings, which the analyzer's lexer treats as opaque
//! literals — so auditing *this* file never trips over its own fixtures.

use rdfft::analysis::lints::{
    LINT_ALLOC, LINT_BAD_ALLOW, LINT_DETERMINISM, LINT_LOCK, LINT_THREADS, LINT_UNSAFE,
};
use rdfft::analysis::{analyze_source, audit_paths, FileReport};

/// Lint names of the unsuppressed findings, in line order.
fn lints(r: &FileReport) -> Vec<&'static str> {
    r.findings.iter().map(|f| f.lint).collect()
}

// ---------------------------------------------------------------------
// unsafe-needs-safety-comment
// ---------------------------------------------------------------------

#[test]
fn unsafe_without_safety_comment_fires() {
    let r = analyze_source(
        "rust/src/model/fixture.rs",
        r#"
pub fn f(p: *mut f32) {
    unsafe { *p = 0.0; }
}
"#,
    );
    assert_eq!(lints(&r), vec![LINT_UNSAFE]);
    assert_eq!(r.findings[0].line, 3);
}

#[test]
fn safety_comment_above_or_trailing_passes() {
    let r = analyze_source(
        "rust/src/model/fixture.rs",
        r#"
pub fn f(p: *mut f32) {
    // SAFETY: caller guarantees p is valid.
    unsafe { *p = 0.0; }
    unsafe { *p = 1.0; } // SAFETY: same pointer, still valid.
}

/// Docs.
///
/// # Safety
/// `p` must be valid — the doc section reaches through the attribute.
#[inline]
pub unsafe fn g(p: *mut f32) {
    *p = 2.0;
}
"#,
    );
    assert_eq!(lints(&r), Vec::<&str>::new());
}

#[test]
fn safety_text_in_strings_or_trailing_code_does_not_attach() {
    // A SAFETY comment separated from the unsafe by a code line must NOT
    // count (the contiguous block above is broken).
    let r = analyze_source(
        "rust/src/model/fixture.rs",
        r#"
pub fn f(p: *mut f32) {
    // SAFETY: this comment governs the let, not the unsafe below.
    let q = p;
    unsafe { *q = 0.0; }
}
"#,
    );
    assert_eq!(lints(&r), vec![LINT_UNSAFE]);
}

// ---------------------------------------------------------------------
// no-raw-threads
// ---------------------------------------------------------------------

#[test]
fn raw_thread_spawn_fires_outside_pool() {
    let r = analyze_source(
        "rust/src/coordinator/fixture.rs",
        r#"
pub fn f() {
    std::thread::spawn(|| {});
    std::thread::scope(|_s| {});
    let b = std::thread::Builder::new();
    std::thread::sleep(std::time::Duration::from_millis(1)); // not banned
}
"#,
    );
    assert_eq!(lints(&r), vec![LINT_THREADS, LINT_THREADS, LINT_THREADS]);
}

#[test]
fn pool_file_is_allowlisted_wholesale() {
    let r = analyze_source(
        "rust/src/runtime/pool.rs",
        r#"
pub fn f() {
    std::thread::spawn(|| {});
}
"#,
    );
    assert_eq!(lints(&r), Vec::<&str>::new());
}

#[test]
fn server_spawn_session_is_carved_out_but_other_fns_are_not() {
    let src = r#"
pub fn spawn_session() {
    std::thread::spawn(|| {});
}
pub fn other() {
    std::thread::spawn(|| {});
}
"#;
    let r = analyze_source("rust/src/runtime/server.rs", src);
    assert_eq!(lints(&r), vec![LINT_THREADS]);
    assert_eq!(r.findings[0].line, 6);
    // The same source outside server.rs fires twice.
    let r = analyze_source("rust/src/runtime/fixture.rs", src);
    assert_eq!(lints(&r), vec![LINT_THREADS, LINT_THREADS]);
}

// ---------------------------------------------------------------------
// lock-poison-policy
// ---------------------------------------------------------------------

#[test]
fn lock_chained_with_unwrap_or_expect_fires() {
    let r = analyze_source(
        "rust/src/model/fixture.rs",
        r#"
pub fn f(m: &std::sync::Mutex<u32>, rw: &std::sync::RwLock<u32>) {
    let _a = m.lock().unwrap();
    let _b = rw.read().expect("poisoned");
    let _c = rw.write().unwrap();
}
"#,
    );
    assert_eq!(lints(&r), vec![LINT_LOCK, LINT_LOCK, LINT_LOCK]);
}

#[test]
fn poison_recovery_and_io_read_write_pass() {
    let r = analyze_source(
        "rust/src/model/fixture.rs",
        r#"
use std::io::Read;
pub fn f(m: &std::sync::Mutex<u32>, mut s: std::net::TcpStream, buf: &mut [u8]) {
    let _a = m.lock().unwrap_or_else(|p| p.into_inner());
    // io::Read::read takes an argument, so the empty-parens pattern
    // cannot confuse it with RwLock::read.
    let _n = s.read(buf).unwrap();
}
"#,
    );
    assert_eq!(lints(&r), Vec::<&str>::new());
}

// ---------------------------------------------------------------------
// no-alloc-in-hot-path
// ---------------------------------------------------------------------

#[test]
fn marked_fn_with_allocations_fires_per_construct() {
    let r = analyze_source(
        "rust/src/model/fixture.rs",
        r#"
// audit: no_alloc
pub fn hot(xs: &[f32]) -> f32 {
    let v: Vec<f32> = Vec::new();
    let w = vec![0.0f32; 4];
    let mut c = Vec::with_capacity(8);
    c.push(0.0);
    let d = xs.to_vec();
    let e: Vec<f32> = xs.iter().copied().collect();
    let b = Box::new(1.0f32);
    let s = format!("x");
    let f = d.clone();
    v.len() as f32 + w[0] + e[0] + *b + s.len() as f32 + f[0]
}
"#,
    );
    let got = lints(&r);
    assert_eq!(got.len(), 8, "one finding per construct: {:?}", r.findings);
    assert!(got.iter().all(|l| *l == LINT_ALLOC));
}

#[test]
fn unmarked_fn_may_allocate_and_marker_reaches_through_attrs() {
    let r = analyze_source(
        "rust/src/model/fixture.rs",
        r#"
pub fn cold() -> Vec<f32> {
    vec![0.0; 16]
}

/// Doc block.
// audit: no_alloc
#[inline]
#[allow(dead_code)]
pub fn hot(buf: &mut [f32]) {
    for v in buf.iter_mut() {
        *v *= 2.0;
    }
}
"#,
    );
    assert_eq!(lints(&r), Vec::<&str>::new());
}

#[test]
fn marker_governs_only_the_next_fn() {
    let r = analyze_source(
        "rust/src/model/fixture.rs",
        r#"
// audit: no_alloc
pub fn hot(buf: &mut [f32]) {
    buf[0] = 1.0;
}

pub fn after() -> Vec<f32> {
    Vec::new()
}
"#,
    );
    assert_eq!(lints(&r), Vec::<&str>::new());
}

// ---------------------------------------------------------------------
// determinism-lint
// ---------------------------------------------------------------------

#[test]
fn banned_idents_fire_inside_determinism_scope() {
    let src = r#"
use std::collections::HashMap;
pub fn f() {
    let _m: HashMap<u32, u32> = HashMap::new();
    let _t = std::time::Instant::now();
}
"#;
    let r = analyze_source("rust/src/rdfft/fixture.rs", src);
    // HashMap appears three times (use, annotation, constructor) plus
    // one Instant.
    assert_eq!(lints(&r), vec![LINT_DETERMINISM; 4]);
    let r = analyze_source("rust/src/autograd/fixture.rs", src);
    assert_eq!(lints(&r), vec![LINT_DETERMINISM; 4]);
    let r = analyze_source("rust/src/runtime/server.rs", src);
    assert_eq!(lints(&r), vec![LINT_DETERMINISM; 4]);
}

#[test]
fn determinism_lint_is_silent_outside_scope() {
    let src = r#"
use std::collections::HashMap;
pub fn f() {
    let _m: HashMap<u32, u32> = HashMap::new();
    let _t = std::time::Instant::now();
}
"#;
    // baselines/ may use HashMap (out of scope); test files are excluded
    // even under rdfft-looking paths.
    let r = analyze_source("rust/src/baselines/fixture.rs", src);
    assert_eq!(lints(&r), Vec::<&str>::new());
    let r = analyze_source("rust/tests/fixture.rs", src);
    assert_eq!(lints(&r), Vec::<&str>::new());
}

// ---------------------------------------------------------------------
// Suppression grammar
// ---------------------------------------------------------------------

#[test]
fn allow_with_reason_suppresses_and_records_the_waiver() {
    let r = analyze_source(
        "rust/src/coordinator/fixture.rs",
        r#"
pub fn f() {
    // audit: allow(no-raw-threads) bench harness thread, joined below
    std::thread::spawn(|| {});
}
"#,
    );
    assert_eq!(lints(&r), Vec::<&str>::new());
    assert_eq!(r.suppressed.len(), 1);
    assert_eq!(r.suppressed[0].lint, LINT_THREADS);
    assert_eq!(r.suppressed[0].reason, "bench harness thread, joined below");
}

#[test]
fn trailing_allow_targets_its_own_line() {
    let r = analyze_source(
        "rust/src/coordinator/fixture.rs",
        r#"
pub fn f() {
    std::thread::spawn(|| {}); // audit: allow(no-raw-threads) joined by caller
}
"#,
    );
    assert_eq!(lints(&r), Vec::<&str>::new());
    assert_eq!(r.suppressed.len(), 1);
}

#[test]
fn reasonless_allow_is_itself_a_violation_and_does_not_suppress() {
    let r = analyze_source(
        "rust/src/coordinator/fixture.rs",
        r#"
pub fn f() {
    // audit: allow(no-raw-threads)
    std::thread::spawn(|| {});
}
"#,
    );
    // Both the bare waiver and the un-suppressed thread finding surface.
    assert_eq!(lints(&r), vec![LINT_BAD_ALLOW, LINT_THREADS]);
    assert!(r.suppressed.is_empty());
}

#[test]
fn unknown_lint_in_allow_is_a_violation() {
    let r = analyze_source(
        "rust/src/model/fixture.rs",
        r#"
// audit: allow(made-up-lint) because reasons
pub fn f() {}
"#,
    );
    assert_eq!(lints(&r), vec![LINT_BAD_ALLOW]);
}

#[test]
fn allow_must_name_the_matching_lint_and_line() {
    let r = analyze_source(
        "rust/src/coordinator/fixture.rs",
        r#"
pub fn f(m: &std::sync::Mutex<u32>) {
    // audit: allow(lock-poison-policy) wrong lint for the line below
    std::thread::spawn(|| {});
    let _g = m.lock().unwrap();
}
"#,
    );
    // The allow names lock-poison-policy but targets the spawn line, so
    // neither finding is silenced.
    assert_eq!(lints(&r), vec![LINT_THREADS, LINT_LOCK]);
}

#[test]
fn directive_prose_in_docs_is_not_a_directive() {
    // Doc text *mentioning* the grammar (indented or fenced) must not
    // parse as a directive — only comments that start with "audit:".
    let r = analyze_source(
        "rust/src/model/fixture.rs",
        r#"
//! The marker grammar is `// audit: no_alloc` above a fn.
//! And waivers look like `// audit: allow(<lint>) <reason>`.
pub fn f() {}
"#,
    );
    assert_eq!(lints(&r), Vec::<&str>::new());
}

// ---------------------------------------------------------------------
// Lexer integration: comments and strings never produce findings
// ---------------------------------------------------------------------

#[test]
fn code_in_comments_and_strings_is_invisible() {
    let r = analyze_source(
        "rust/src/rdfft/fixture.rs",
        r##"
// std::thread::spawn(|| {}); HashMap::new(); m.lock().unwrap();
pub fn f() -> &'static str {
    let s = "std::thread::spawn HashMap Instant unsafe";
    let t = r#"m.lock().unwrap()"#;
    if s.len() > t.len() { s } else { "x" }
}
"##,
    );
    assert_eq!(lints(&r), Vec::<&str>::new());
}

// ---------------------------------------------------------------------
// Self-audit: the committed tree passes its own gate
// ---------------------------------------------------------------------

#[test]
fn the_repo_tree_is_audit_clean() {
    let base = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let roots = [base.join("src"), base.join("tests")];
    let report = audit_paths(&roots).expect("audit roots exist");
    assert!(report.files > 40, "walked the real tree, got {} files", report.files);
    assert!(
        report.clean(),
        "committed tree must audit clean; violations:\n{}",
        report.render()
    );
    // Waivers stay visible: every suppression carries a non-empty reason.
    assert!(!report.suppressed.is_empty(), "the tree documents its waivers");
    for s in &report.suppressed {
        assert!(!s.reason.is_empty(), "{}:{} has a bare waiver", s.file, s.line);
    }
}
