//! Failure injection: the runtime and manifest loaders must fail loudly
//! and informatively on corrupt artifacts — never load garbage weights.

use rdfft::runtime::{load_param_literals, Manifest, ParamSpec, Runtime};
use std::io::Write;
use std::path::Path;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("rdfft_failinj_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_artifacts_dir_is_a_clean_error() {
    let Err(err) = Runtime::load(Path::new("/nonexistent/artifacts")) else {
        panic!("load of nonexistent dir must fail");
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "error should mention the manifest: {msg}");
}

#[test]
fn truncated_manifest_rejected() {
    let d = tmpdir("truncmanifest");
    std::fs::write(d.join("manifest.json"), b"{\"config\": {\"voc").unwrap();
    assert!(Runtime::load(&d).is_err());
}

#[test]
fn manifest_missing_fields_rejected() {
    assert!(Manifest::parse(r#"{"config": {"vocab": 1}}"#).is_err());
    assert!(Manifest::parse(r#"{"trainable": []}"#).is_err());
    assert!(Manifest::parse("[]").is_err());
    assert!(Manifest::parse("").is_err());
}

#[test]
fn param_file_size_mismatch_rejected() {
    let d = tmpdir("binsize");
    let path = d.join("params.bin");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(&[0u8; 16]).unwrap(); // 4 f32s
    drop(f);
    let specs = vec![ParamSpec { name: "w".into(), shape: vec![2, 4] }]; // needs 8
    let Err(err) = load_param_literals(&path, &specs) else {
        panic!("size mismatch must be rejected");
    };
    assert!(format!("{err}").contains("expected"), "{err}");
}

#[test]
fn param_file_exact_size_accepted_and_shaped() {
    let d = tmpdir("binok");
    let path = d.join("params.bin");
    let vals: Vec<u8> = (0..8).flat_map(|i| (i as f32).to_le_bytes()).collect();
    std::fs::write(&path, &vals).unwrap();
    let specs = vec![
        ParamSpec { name: "a".into(), shape: vec![2, 2] },
        ParamSpec { name: "b".into(), shape: vec![4] },
    ];
    let lits = load_param_literals(&path, &specs).unwrap();
    assert_eq!(lits.len(), 2);
    assert_eq!(lits[0].element_count(), 4);
    assert_eq!(lits[1].to_vec::<f32>().unwrap(), vec![4.0, 5.0, 6.0, 7.0]);
}

#[test]
fn garbage_hlo_text_rejected_at_compile() {
    // full Runtime::load with a manifest that parses but HLO that doesn't
    let d = tmpdir("garbagehlo");
    std::fs::write(
        d.join("manifest.json"),
        r#"{
          "config": {"vocab": 4, "d_model": 2, "n_layers": 1, "n_heads": 1,
                     "d_ff": 2, "seq_len": 2, "batch": 1, "p": 2, "lr": 0.1},
          "frozen": [{"name": "w", "shape": [1]}],
          "trainable": [{"name": "c", "shape": [1]}],
          "tokens_shape": [1, 2],
          "train_outputs": 2,
          "num_frozen_params": 1,
          "num_trainable_params": 1
        }"#,
    )
    .unwrap();
    std::fs::write(d.join("train_step.hlo.txt"), "this is not an HloModule").unwrap();
    std::fs::write(d.join("frozen.bin"), 1.0f32.to_le_bytes()).unwrap();
    std::fs::write(d.join("trainable.bin"), 0.0f32.to_le_bytes()).unwrap();
    assert!(Runtime::load(&d).is_err());
}

#[test]
fn nan_input_does_not_crash_the_transform() {
    // numerical robustness: NaNs propagate (IEEE semantics) but must not
    // corrupt neighbouring lanes' independence or panic.
    use rdfft::rdfft::{irdfft_inplace, plan::cached, rdfft_inplace};
    let n = 64;
    let plan = cached(n);
    let mut buf = vec![1.0f32; n];
    buf[7] = f32::NAN;
    rdfft_inplace(&plan, &mut buf);
    assert!(buf.iter().any(|v| v.is_nan()), "NaN must propagate");
    irdfft_inplace(&plan, &mut buf); // must not panic
}

#[test]
fn denormal_and_extreme_inputs_roundtrip() {
    use rdfft::rdfft::{irdfft_inplace, plan::cached, rdfft_inplace};
    let n = 32;
    let plan = cached(n);
    for scale in [1e-38f32, 1e30f32] {
        let orig: Vec<f32> = (0..n).map(|i| scale * ((i % 5) as f32 - 2.0)).collect();
        let mut buf = orig.clone();
        rdfft_inplace(&plan, &mut buf);
        irdfft_inplace(&plan, &mut buf);
        for i in 0..n {
            let tol = scale * 1e-3 * n as f32;
            assert!((buf[i] - orig[i]).abs() <= tol, "scale={scale} i={i}");
        }
    }
}

#[test]
fn set_trainable_flat_rejects_wrong_lengths() {
    // exercised without artifacts via direct manifest construction is not
    // possible (Runtime fields are private) — covered through the public
    // path in integration_runtime when artifacts exist; here we assert the
    // length law on load_param_literals, the shared code path.
    let d = tmpdir("wronglen");
    let path = d.join("p.bin");
    std::fs::write(&path, [0u8; 12]).unwrap(); // 3 f32
    let specs = vec![ParamSpec { name: "w".into(), shape: vec![4] }];
    assert!(load_param_literals(&path, &specs).is_err());
}
