//! Integration: the worker-pool data-parallel training path.
//!
//! The contract under test (the PR's acceptance criterion): the sharded
//! step's results are **bit-identical at any thread count** — the shard
//! structure is a fixed function of the batch size, shard jobs are
//! replica-free and side-effect-local, and gradients/losses combine via
//! a deterministic fixed-order tree reduction. `--threads 4` must
//! reproduce `--threads 1` exactly, bit for bit, on a heterogeneous
//! 4-layer stack (Dense + LoRA + rdFFT circulant + long-conv); and the
//! sharded path must agree with the classic serial step to float noise.
//!
//! With the SIMD lane kernels these runs exercise the auto-dispatched
//! arm (AVX2+FMA where detected): the bitwise-at-any-thread-count
//! contract survives because the arm is resolved once per process and
//! the shard structure is thread-count-independent — this suite would
//! catch any kernel whose result depended on which worker ran it.

use rdfft::autograd::layers::Backend;
use rdfft::autograd::optim::{OptimKind, OptimizerBank};
use rdfft::autograd::stack::{ShardArena, SpectralStack, StackConfig};
use rdfft::autograd::tensor::Rng;
use rdfft::autograd::train::Method;
use rdfft::memtrack::{self, Category};
use rdfft::runtime::pool::ExecCtx;

/// The heterogeneous tower: Dense + LoRA + rdFFT circulant + long-conv.
/// The long-conv block runs its whole forward/backward in the frequency
/// domain (shard spectra summed before one inverse), so its presence
/// here makes the bitwise-at-any-thread-count contract cover that path.
fn mixed_methods() -> [Method; 4] {
    [
        Method::FullFinetune,
        Method::Lora { rank: 4 },
        Method::Circulant { backend: Backend::RdFft, p: 8 },
        Method::LongConv { k: 9 },
    ]
}

fn mixed_cfg() -> StackConfig {
    StackConfig { d: 32, depth: 4, ctx: 4, seed: 9, ..Default::default() }
}

fn batch(b: usize, ctx: usize, seed: u64) -> (Vec<u8>, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let bytes: Vec<u8> = (0..b * ctx).map(|_| (97 + rng.below(20)) as u8).collect();
    let labels: Vec<usize> =
        (0..b).map(|r| (bytes[r * ctx] as usize + bytes[r * ctx + 1] as usize) % 23).collect();
    (bytes, labels)
}

/// Run `steps` sharded training steps at the given lane count; return the
/// per-step losses and the final flattened parameters.
fn run_sharded(threads: usize, steps: usize) -> (Vec<f32>, Vec<f32>) {
    let exec = ExecCtx::with_threads(threads).with_category(Category::Gradients);
    let mut stack = SpectralStack::new_mixed_with_exec(mixed_cfg(), &mixed_methods(), exec.clone());
    let mut arena = ShardArena::new(&stack, exec.scratch_category());
    let mut bank = OptimizerBank::new(OptimKind::Sgd, 0.2);
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        // odd batch size on purpose: shards of unequal length must stay
        // deterministic too
        let (bytes, labels) = batch(13, 4, 100 + step as u64);
        losses.push(
            stack
                .train_step_sharded(&bytes, &labels, &mut bank, &mut arena)
                .expect("no faults injected"),
        );
    }
    let mut params = Vec::new();
    stack.for_each_param(&mut |p, _| params.extend_from_slice(p));
    (losses, params)
}

#[test]
fn gradients_bit_identical_at_threads_1_2_4() {
    let (l1, p1) = run_sharded(1, 6);
    for t in [2usize, 4] {
        let (lt, pt) = run_sharded(t, 6);
        assert_eq!(l1, lt, "losses at {t} lanes must be bit-identical to 1 lane");
        assert_eq!(p1.len(), pt.len());
        for i in 0..p1.len() {
            assert_eq!(
                p1[i].to_bits(),
                pt[i].to_bits(),
                "param {i} differs at {t} lanes: {} vs {}",
                p1[i],
                pt[i]
            );
        }
    }
}

#[test]
fn sharded_step_is_repeatable_with_simd_dispatch_on() {
    // Dispatch determinism at the trainer level: the kernel arm is
    // resolved once per process, so two fresh sharded runs at 4 lanes
    // (and a third at 2) are bit-identical end-to-end — losses and every
    // parameter — with the SIMD lane kernels active by default.
    let arm_before = rdfft::rdfft::simd::active();
    let (la, pa) = run_sharded(4, 4);
    let (lb, pb) = run_sharded(4, 4);
    assert_eq!(la, lb, "repeated sharded runs must produce identical losses");
    assert_eq!(pa.len(), pb.len());
    for i in 0..pa.len() {
        assert_eq!(pa[i].to_bits(), pb[i].to_bits(), "param {i} differs across repeats");
    }
    assert_eq!(
        rdfft::rdfft::simd::active(),
        arm_before,
        "the dispatch decision must stay pinned for the whole process"
    );
}

#[test]
fn sharded_matches_classic_serial_step_to_float_noise() {
    // Shard accumulation regroups float sums (that's the whole reason the
    // fixed-order reduction exists), so classic-vs-sharded is a tolerance
    // comparison, not a bitwise one.
    let mut classic = SpectralStack::new_mixed(mixed_cfg(), &mixed_methods());
    let exec = ExecCtx::with_threads(2).with_category(Category::Gradients);
    let mut sharded =
        SpectralStack::new_mixed_with_exec(mixed_cfg(), &mixed_methods(), exec.clone());
    let mut arena = ShardArena::new(&sharded, exec.scratch_category());
    let mut bank_c = OptimizerBank::new(OptimKind::Sgd, 0.2);
    let mut bank_s = OptimizerBank::new(OptimKind::Sgd, 0.2);
    for step in 0..5 {
        let (bytes, labels) = batch(16, 4, 500 + step);
        let lc = classic.train_step(&bytes, &labels, &mut bank_c);
        let ls = sharded
            .train_step_sharded(&bytes, &labels, &mut bank_s, &mut arena)
            .expect("no faults injected");
        assert!((lc - ls).abs() < 1e-4, "step {step}: classic {lc} vs sharded {ls}");
    }
    let mut pc = Vec::new();
    classic.for_each_param(&mut |p, _| pc.extend_from_slice(p));
    let mut ps = Vec::new();
    sharded.for_each_param(&mut |p, _| ps.extend_from_slice(p));
    for i in 0..pc.len() {
        assert!((pc[i] - ps[i]).abs() < 1e-4, "param {i}: {} vs {}", pc[i], ps[i]);
    }
}

#[test]
fn sharded_training_reduces_loss_on_the_mixed_stack() {
    let (losses, _) = run_sharded(4, 40);
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(tail < head, "sharded loss must trend down: {head} -> {tail}");
}

#[test]
fn worker_shard_scratch_is_visible_in_memtrack_peak() {
    // The memtrack satellite at the training level: a sharded step's
    // activation scratch is allocated on pool workers, whose deltas must
    // merge back into the submitting thread's peak. Compare against a
    // 1-lane run (all inline, fully tracked by construction): the
    // multi-lane peak must be at least as large (absorb sums worker
    // peaks as concurrent).
    let peak_of = |threads: usize| -> usize {
        memtrack::reset();
        let exec = ExecCtx::with_threads(threads).with_category(Category::Gradients);
        let mut stack =
            SpectralStack::new_mixed_with_exec(mixed_cfg(), &mixed_methods(), exec.clone());
        let mut arena = ShardArena::new(&stack, exec.scratch_category());
        let mut bank = OptimizerBank::new(OptimKind::Sgd, 0.2);
        let (bytes, labels) = batch(16, 4, 3);
        memtrack::reset_peak();
        let _ = stack
            .train_step_sharded(&bytes, &labels, &mut bank, &mut arena)
            .expect("no faults injected");
        let peak = memtrack::snapshot().peak_total;
        drop(arena);
        drop(stack);
        memtrack::reset();
        peak
    };
    let serial_peak = peak_of(1);
    let pooled_peak = peak_of(4);
    assert!(serial_peak > 0);
    assert!(
        pooled_peak >= serial_peak,
        "worker-side activation scratch vanished from the peak: {pooled_peak} < {serial_peak}"
    );
}
