//! Crash-safe checkpoint/resume integration tests.
//!
//! The contract under test: a native training run killed at an arbitrary
//! step and resumed from its newest valid checkpoint produces the
//! **bit-identical** loss trajectory and final parameters of an
//! uninterrupted run — at any thread count, through corrupted/truncated
//! checkpoint files (skipped with fallback), and through injected
//! worker-pool panics (graceful serial-fallback degradation). Kills here
//! are in-process `halt@STEP` faults (a real `abort()` would take the
//! test harness down with it); `repro crashtest` drives the same
//! machinery with real child-process aborts.

use rdfft::autograd::layers::Backend;
use rdfft::autograd::optim::OptimKind;
use rdfft::autograd::stack::StackConfig;
use rdfft::autograd::train::Method;
use rdfft::coordinator::{NativeReport, NativeTrainer, NativeTrainerConfig};
use rdfft::memtrack::Category;
use rdfft::runtime::checkpoint::{checkpoint_path, list_checkpoints};
use rdfft::runtime::FaultPlan;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const STEPS: usize = 14;
const EVERY: usize = 3;

fn cfg(
    threads: usize,
    dir: Option<&Path>,
    resume: bool,
    faults: Arc<FaultPlan>,
) -> NativeTrainerConfig {
    NativeTrainerConfig {
        stack: StackConfig {
            d: 32,
            depth: 2,
            ctx: 4,
            method: Method::Circulant { backend: Backend::RdFft, p: 8 },
            seed: 9,
            ..Default::default()
        },
        optim: OptimKind::Sgd,
        lr: 0.2,
        steps: STEPS,
        batch: 8,
        eval_every: 0,
        eval_batches: 0,
        corpus_bytes: 16 * 1024,
        seed: 9,
        log_csv: None,
        verbose: false,
        threads,
        checkpoint_dir: dir.map(|p| p.to_path_buf()),
        checkpoint_every: EVERY,
        checkpoint_keep: 10,
        resume,
        faults,
    }
}

fn run(c: NativeTrainerConfig) -> (NativeReport, Vec<f32>) {
    let mut t = NativeTrainer::new(c);
    let r = t.run().expect("run failed");
    let (_, params) = t.stack_mut().export_params();
    (r, params)
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("rdfft_ckpt_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Assert the resumed run's replayed losses and final parameters are
/// bit-identical to the uninterrupted reference.
fn assert_bit_identical(
    tag: &str,
    reference: &(NativeReport, Vec<f32>),
    resumed: &(NativeReport, Vec<f32>),
) {
    for &(step, loss) in &resumed.0.losses {
        let rl = reference
            .0
            .losses
            .iter()
            .find(|&&(s, _)| s == step)
            .map(|&(_, l)| l)
            .unwrap_or_else(|| panic!("[{tag}] reference lacks step {step}"));
        assert_eq!(
            loss.to_bits(),
            rl.to_bits(),
            "[{tag}] step {step}: resumed loss {loss} != reference {rl}"
        );
    }
    assert_eq!(reference.1.len(), resumed.1.len(), "[{tag}] param count");
    for i in 0..reference.1.len() {
        assert_eq!(
            reference.1[i].to_bits(),
            resumed.1[i].to_bits(),
            "[{tag}] final param {i}: {} vs {}",
            resumed.1[i],
            reference.1[i]
        );
    }
}

#[test]
fn kill_and_resume_is_bit_identical_at_threads_1_2_4() {
    // One uninterrupted reference (threads=1; sharded results are
    // thread-count-invariant, so it anchors every lane count).
    let reference = run(cfg(1, None, false, Arc::new(FaultPlan::none())));
    assert_eq!(reference.0.losses.len(), STEPS);

    for threads in [1usize, 2, 4] {
        let dir = tmpdir(&format!("halt_t{threads}"));
        // Simulated kill before step 10: steps 1..=9 ran, checkpoints at
        // 3, 6, 9.
        let killed = run(cfg(
            threads,
            Some(&dir),
            false,
            Arc::new(FaultPlan::parse("halt@10").unwrap()),
        ));
        assert_eq!(killed.0.halted_at, Some(10), "threads={threads}");
        assert_eq!(killed.0.losses.len(), 9);
        assert_eq!(killed.0.checkpoints_written, 3);

        let resumed = run(cfg(threads, Some(&dir), true, Arc::new(FaultPlan::none())));
        assert_eq!(resumed.0.resumed_from, Some(9), "threads={threads}");
        assert_eq!(resumed.0.losses.first().map(|&(s, _)| s), Some(10));
        assert_bit_identical(&format!("threads={threads}"), &reference, &resumed);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn corrupted_latest_checkpoint_falls_back_to_previous() {
    let reference = run(cfg(2, None, false, Arc::new(FaultPlan::none())));
    let dir = tmpdir("corrupt");
    let _ = run(cfg(2, Some(&dir), false, Arc::new(FaultPlan::parse("halt@10").unwrap())));

    // Flip one payload bit in the newest checkpoint (step 9).
    let newest = checkpoint_path(&dir, 9);
    let mut bytes = std::fs::read(&newest).unwrap();
    let n = bytes.len();
    bytes[n - 5] ^= 0x08;
    std::fs::write(&newest, &bytes).unwrap();

    let resumed = run(cfg(2, Some(&dir), true, Arc::new(FaultPlan::none())));
    assert_eq!(
        resumed.0.resumed_from,
        Some(6),
        "checksum-corrupted step-9 checkpoint must be skipped"
    );
    assert_bit_identical("corrupted", &reference, &resumed);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_latest_checkpoint_falls_back_to_previous() {
    let reference = run(cfg(1, None, false, Arc::new(FaultPlan::none())));
    let dir = tmpdir("trunc");
    let _ = run(cfg(1, Some(&dir), false, Arc::new(FaultPlan::parse("halt@10").unwrap())));

    // Truncate the newest checkpoint mid-payload (a torn write that
    // somehow landed under the real name — belt and braces beyond the
    // atomic rename).
    let newest = checkpoint_path(&dir, 9);
    let bytes = std::fs::read(&newest).unwrap();
    std::fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();

    let resumed = run(cfg(1, Some(&dir), true, Arc::new(FaultPlan::none())));
    assert_eq!(resumed.0.resumed_from, Some(6));
    assert_bit_identical("truncated", &reference, &resumed);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fingerprint_mismatch_is_a_clear_error_not_a_silent_resume() {
    let dir = tmpdir("fingerprint");
    let _ = run(cfg(1, Some(&dir), false, Arc::new(FaultPlan::parse("halt@10").unwrap())));

    // Same checkpoint dir, different trajectory config (lr changed).
    let mut foreign = cfg(1, Some(&dir), true, Arc::new(FaultPlan::none()));
    foreign.lr = 0.05;
    let err = NativeTrainer::new(foreign).run().expect_err("foreign config must be refused");
    let msg = format!("{err:#}");
    assert!(msg.contains("fingerprint"), "unhelpful error: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_without_a_checkpoint_dir_is_an_error_and_empty_dir_starts_fresh() {
    let mut c = cfg(1, None, true, Arc::new(FaultPlan::none()));
    c.steps = 2;
    let err = NativeTrainer::new(c).run().expect_err("resume without dir");
    assert!(format!("{err:#}").contains("checkpoint directory"));

    let dir = tmpdir("fresh");
    let mut c = cfg(1, Some(&dir), true, Arc::new(FaultPlan::none()));
    c.steps = 2;
    let (r, _) = {
        let mut t = NativeTrainer::new(c);
        let r = t.run().expect("empty dir = fresh start");
        (r, ())
    };
    assert_eq!(r.resumed_from, None);
    assert_eq!(r.losses.first().map(|&(s, _)| s), Some(1));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pool_panic_degrades_to_serial_retry_with_identical_results() {
    let clean = run(cfg(2, None, false, Arc::new(FaultPlan::none())));
    assert_eq!(clean.0.degraded_steps, 0);

    // Panic pool shard job 0 at step 3: the step must complete on the
    // scoped-serial fallback and the whole run must stay bit-identical.
    let degraded = run(cfg(
        2,
        None,
        false,
        Arc::new(FaultPlan::parse("panic-job@3:0").unwrap()),
    ));
    assert_eq!(degraded.0.degraded_steps, 1, "exactly one degraded step");
    assert_eq!(clean.0.losses.len(), degraded.0.losses.len());
    assert_bit_identical("degraded", &clean, &degraded);
}

#[test]
fn repeated_pool_panic_on_one_step_hard_fails() {
    // Two panics pinned to the same shard of the same step: the pool
    // attempt consumes one, the serial retry consumes the other — the
    // step fails twice and the run must surface a hard error.
    let c = cfg(
        2,
        None,
        false,
        Arc::new(FaultPlan::parse("panic-job@3:0,panic-job@3:0").unwrap()),
    );
    let err = NativeTrainer::new(c).run().expect_err("second failure must be fatal");
    let msg = format!("{err:#}");
    assert!(msg.contains("serial fallback"), "unhelpful error: {msg}");
}

#[test]
fn checkpointing_off_allocates_zero_checkpoint_bytes() {
    let (off, _) = run(cfg(1, None, false, Arc::new(FaultPlan::none())));
    assert_eq!(
        off.peak_by_cat[Category::Checkpoint.index()],
        0,
        "no checkpoint allocations when checkpointing is disabled"
    );

    let dir = tmpdir("membudget");
    let (on, _) = run(cfg(1, Some(&dir), false, Arc::new(FaultPlan::none())));
    assert!(
        on.peak_by_cat[Category::Checkpoint.index()] > 0,
        "serialization buffers must be visible under the checkpoint category"
    );
    // Checkpointing must not change the training-state footprint: every
    // non-checkpoint category peak is identical with and without it.
    for (i, (a, b)) in off.peak_by_cat.iter().zip(on.peak_by_cat.iter()).enumerate() {
        if i != Category::Checkpoint.index() {
            assert_eq!(a, b, "category {i} peak changed when checkpointing turned on");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn retention_keeps_only_the_newest_k_files() {
    let dir = tmpdir("retention");
    let mut c = cfg(1, Some(&dir), false, Arc::new(FaultPlan::none()));
    c.checkpoint_keep = 2;
    let (r, _) = run(c);
    // Saves at 3, 6, 9, 12, and the final step 14; keep-2 leaves 12, 14.
    assert_eq!(r.checkpoints_written, 5);
    let steps: Vec<usize> = list_checkpoints(&dir).into_iter().map(|(s, _)| s).collect();
    assert_eq!(steps, vec![12, 14]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_io_failure_warns_but_training_continues() {
    let dir = tmpdir("iofail");
    let (r, _) = run(cfg(
        1,
        Some(&dir),
        false,
        Arc::new(FaultPlan::parse("io-fail@3").unwrap()),
    ));
    // The step-3 save failed (injected); every other save landed and the
    // run finished all its steps.
    assert_eq!(r.losses.len(), STEPS);
    assert_eq!(r.checkpoints_written, 4);
    let steps: Vec<usize> = list_checkpoints(&dir).into_iter().map(|(s, _)| s).collect();
    assert_eq!(steps, vec![6, 9, 12, 14]);
    let _ = std::fs::remove_dir_all(&dir);
}
