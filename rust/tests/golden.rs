//! Golden-vector known-answer tests.
//!
//! The differential suites compare engine paths against *each other* and
//! against in-process oracles; this suite pins the transform against
//! **committed** expected values (`fixtures/golden_rdfft.json`, generated
//! by an independent pure-f64 naive-DFT oracle with a pinned seed), so a
//! correlated regression that drifted every in-process path identically —
//! say a twiddle-table bug shared by scalar and SIMD kernels — can no
//! longer slip through an internally-consistent test run.
//!
//! Every execution arm must reproduce the fixtures within the n-scaled
//! tolerance: the legacy scalar rows, the forced-scalar engine (also
//! asserted bitwise-equal to the scalar rows), the auto-dispatched SIMD
//! engine, the fused circulant pipeline, and the pooled multi-thread
//! path.

use rdfft::rdfft::engine::{self, EngineConfig, SpectralOp};
use rdfft::rdfft::forward::rdfft_batch_scalar;
use rdfft::rdfft::inverse::irdfft_batch_scalar;
use rdfft::rdfft::plan::cached;
use rdfft::runtime::json;
use rdfft::runtime::pool::ExecCtx;

/// One fixture case: exact-in-f32 input, f64-oracle packed spectrum, and
/// the f64-oracle round-trip (== input to f64 precision).
struct Golden {
    n: usize,
    input: Vec<f32>,
    packed: Vec<f64>,
    roundtrip: Vec<f64>,
}

fn load_cases() -> Vec<Golden> {
    let text = include_str!("fixtures/golden_rdfft.json");
    let doc = json::parse(text).expect("fixture must be valid JSON");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str().map(str::to_string)).as_deref(),
        Some("golden_rdfft/v1"),
        "unexpected fixture schema"
    );
    let cases = doc.get("cases").and_then(|c| c.as_arr().map(|a| a.to_vec())).expect("cases");
    let f64s = |v: &json::Json, key: &str| -> Vec<f64> {
        v.get(key)
            .and_then(|a| a.as_arr().map(|a| a.to_vec()))
            .unwrap_or_else(|| panic!("missing {key}"))
            .iter()
            .map(|x| x.as_f64().expect("number"))
            .collect()
    };
    cases
        .iter()
        .map(|c| {
            let n = c.get("n").and_then(|v| v.as_usize()).expect("n");
            let g = Golden {
                n,
                input: f64s(c, "input").iter().map(|&v| v as f32).collect(),
                packed: f64s(c, "packed"),
                roundtrip: f64s(c, "roundtrip"),
            };
            assert_eq!(g.input.len(), n);
            assert_eq!(g.packed.len(), n);
            assert_eq!(g.roundtrip.len(), n);
            g
        })
        .collect()
}

/// n-scaled tolerance for one f32 transform's rounding against the f64
/// oracle, widened by the expected value's magnitude (inputs span ±2, so
/// low-frequency coefficients grow like √n).
fn tol(n: usize, expected: f64) -> f32 {
    1e-4 * (n as f32).sqrt() * (1.0 + expected.abs() as f32)
}

fn assert_matches_packed(got: &[f32], g: &Golden, path: &str) {
    for k in 0..g.n {
        let want = g.packed[k];
        assert!(
            (got[k] as f64 - want).abs() <= tol(g.n, want) as f64,
            "{path}: n={} k={k}: {} vs golden {}",
            g.n,
            got[k],
            want
        );
    }
}

fn assert_matches_roundtrip(got: &[f32], g: &Golden, path: &str) {
    for i in 0..g.n {
        let want = g.roundtrip[i];
        assert!(
            (got[i] as f64 - want).abs() <= tol(g.n, want) as f64,
            "{path}: n={} i={i}: {} vs golden {}",
            g.n,
            got[i],
            want
        );
    }
}

/// A tuning that forces pool fan-out even on small fixture batches.
fn pool_cfg() -> EngineConfig {
    EngineConfig {
        par_min_rows: 2,
        par_min_elems: 0,
        par_chunk_elems: 1,
        max_threads: 4,
        ..EngineConfig::new()
    }
}

/// Sizes at or above the default `fourstep_threshold` route to the
/// four-step tier on every default-config entry point; the per-arm loops
/// below assert the *direct*-tier contracts (scalar-rows bitwise
/// equality), so they skip large-n cases — those get their own
/// tier-explicit tests at the bottom of this file.
const FOURSTEP_N: usize = 1 << 14;

/// Default tuning pinned to the direct stage sweep at any n.
fn direct_cfg() -> EngineConfig {
    EngineConfig { fourstep_threshold: usize::MAX, ..EngineConfig::new() }
}

/// Default tuning pinned to the four-step tier (any n with tables).
fn four_cfg() -> EngineConfig {
    EngineConfig { fourstep_threshold: 1, ..EngineConfig::new() }
}

#[test]
fn forward_spectra_match_golden_on_every_arm() {
    for g in load_cases() {
        if g.n >= FOURSTEP_N {
            continue; // four-step tier: dedicated large-n tests below
        }
        let plan = cached(g.n);

        // Legacy per-row scalar rows — the seed-era kernels.
        let mut scalar = g.input.clone();
        rdfft_batch_scalar(&plan, &mut scalar);
        assert_matches_packed(&scalar, &g, "scalar rows");

        // Forced-scalar engine: bitwise-identical to the scalar rows by
        // contract, and therefore golden too.
        let mut forced = g.input.clone();
        engine::forward_batch_with(&plan, &mut forced, &EngineConfig::forced_scalar());
        assert_eq!(forced, scalar, "force_scalar must be bitwise n={}", g.n);

        // Auto-dispatched SIMD engine.
        let mut auto = g.input.clone();
        engine::forward_batch(&plan, &mut auto);
        assert_matches_packed(&auto, &g, "simd auto");

        // Pooled path: 5 replicated rows fanned out across 4 lanes; every
        // row must still be golden.
        let b = 5;
        let mut pooled: Vec<f32> = g.input.iter().copied().cycle().take(g.n * b).collect();
        let ctx = ExecCtx::with_threads(4).with_engine_config(pool_cfg());
        engine::forward_batch_ctx(&plan, &mut pooled, &ctx);
        for r in 0..b {
            assert_matches_packed(&pooled[r * g.n..(r + 1) * g.n], &g, "pooled");
        }
    }
}

#[test]
fn roundtrips_match_golden_on_every_arm() {
    for g in load_cases() {
        if g.n >= FOURSTEP_N {
            continue; // four-step tier: dedicated large-n tests below
        }
        let plan = cached(g.n);

        let mut scalar = g.input.clone();
        rdfft_batch_scalar(&plan, &mut scalar);
        irdfft_batch_scalar(&plan, &mut scalar);
        assert_matches_roundtrip(&scalar, &g, "scalar rows");

        let mut forced = g.input.clone();
        engine::forward_batch_with(&plan, &mut forced, &EngineConfig::forced_scalar());
        engine::inverse_batch_with(&plan, &mut forced, &EngineConfig::forced_scalar());
        assert_eq!(forced, scalar, "force_scalar roundtrip bitwise n={}", g.n);

        let mut auto = g.input.clone();
        engine::forward_batch(&plan, &mut auto);
        engine::inverse_batch(&plan, &mut auto);
        assert_matches_roundtrip(&auto, &g, "simd auto");
    }
}

#[test]
fn fused_delta_apply_reproduces_golden_roundtrip() {
    // The fused circulant pipeline with the δ spectrum (the ⊙ identity)
    // is a forward+product+inverse sweep — it must land on the committed
    // round-trip values on both dispatch arms.
    for g in load_cases() {
        let plan = cached(g.n);
        let mut delta = vec![0.0f32; g.n];
        delta[0] = 1.0;
        engine::forward_batch(&plan, &mut delta);
        for cfg in [EngineConfig::new(), EngineConfig::forced_scalar()] {
            let mut fused = g.input.clone();
            engine::circulant_apply_batch_with(&plan, &mut fused, &delta, SpectralOp::Mul, &cfg);
            assert_matches_roundtrip(&fused, &g, "fused delta");
        }
    }
}

#[test]
fn default_threshold_keeps_small_n_on_the_direct_tier() {
    // Below the default 16 Ki threshold the default config must be
    // bitwise-identical to an explicitly direct-pinned config: the tier
    // dispatch may not reroute (or perturb) small transforms.
    for g in load_cases() {
        if g.n >= FOURSTEP_N {
            continue;
        }
        let plan = cached(g.n);
        let mut def = g.input.clone();
        engine::forward_batch_with(&plan, &mut def, &EngineConfig::new());
        let mut direct = g.input.clone();
        engine::forward_batch_with(&plan, &mut direct, &direct_cfg());
        assert_eq!(def, direct, "n={} must stay on the direct tier", g.n);
    }
}

#[test]
fn large_n_fourstep_and_direct_tiers_match_golden() {
    // The committed f64-oracle vectors at n = 16 Ki / 64 Ki, checked on
    // BOTH tiers — the default config routes these sizes to the
    // four-step path, the pinned config keeps the direct sweep; each
    // must independently reproduce the oracle, and they must agree with
    // each other much tighter than the oracle tolerance (their only
    // delta is the fused late-stage twiddle product, ~1 ulp per stage).
    let mut saw = 0;
    for g in load_cases() {
        if g.n < FOURSTEP_N {
            continue;
        }
        saw += 1;
        let plan = cached(g.n);
        assert!(plan.fourstep_lazy().is_some(), "n={} must carry tables", g.n);

        let mut four = g.input.clone();
        engine::forward_batch_with(&plan, &mut four, &EngineConfig::new());
        assert_matches_packed(&four, &g, "fourstep");
        let mut direct = g.input.clone();
        engine::forward_batch_with(&plan, &mut direct, &direct_cfg());
        assert_matches_packed(&direct, &g, "direct large-n");
        // The twiddle-product rounding is absolute in the intermediate
        // magnitudes (~ √n · ‖x‖), not relative to each output bin, so
        // the bound carries the same √n factor as the golden tolerance —
        // just 10× tighter.
        let tier_tol = 1e-5 * (g.n as f32).sqrt();
        for k in 0..g.n {
            let d = (four[k] - direct[k]).abs();
            assert!(
                d <= tier_tol * (1.0 + direct[k].abs()),
                "n={} k={k}: tiers drifted apart: {} vs {}",
                g.n,
                four[k],
                direct[k]
            );
        }

        // Default-config roundtrip (four-step both ways) lands on the
        // committed f64 inverse.
        engine::inverse_batch_with(&plan, &mut four, &EngineConfig::new());
        assert_matches_roundtrip(&four, &g, "fourstep roundtrip");
    }
    assert!(saw >= 2, "fixture must carry the large-n cases");
}

#[test]
fn large_n_simd_width_tiers_agree() {
    // Width-8 vs width-4 lanes on the four-step tier: on non-FMA
    // hardware both resolve to bit-identical portable arms; on AVX2+FMA
    // the only delta is FMA contraction in the product/butterfly lanes,
    // bounded well inside the golden tolerance.
    for g in load_cases() {
        if g.n < FOURSTEP_N {
            continue;
        }
        let plan = cached(g.n);
        let w8 = EngineConfig { fourstep_threshold: 1, ..EngineConfig::new() };
        let w4 = EngineConfig { fourstep_threshold: 1, max_simd_width: 4, ..EngineConfig::new() };
        let mut a = g.input.clone();
        engine::forward_batch_with(&plan, &mut a, &w8);
        let mut b = g.input.clone();
        engine::forward_batch_with(&plan, &mut b, &w4);
        for k in 0..g.n {
            assert!(
                (a[k] - b[k]).abs() <= 1e-5 * (1.0 + b[k].abs()) * (g.n as f32).sqrt().max(1.0),
                "n={} k={k}: width tiers disagree: {} vs {}",
                g.n,
                a[k],
                b[k]
            );
        }
        assert_matches_packed(&a, &g, "width-8 fourstep");
        assert_matches_packed(&b, &g, "width-4 fourstep");
    }
}

#[test]
fn large_n_forced_scalar_fourstep_bitwise_across_thread_counts() {
    // The bitwise-determinism contract on the large-n tier: forced
    // scalar, pool fan-out at 1 vs 4 workers (with thresholds lowered so
    // every phase actually splits) — identical bits, and still golden.
    let Some(g) = load_cases().into_iter().find(|g| g.n == FOURSTEP_N) else {
        panic!("fixture must carry the n = 16 Ki case");
    };
    let plan = cached(g.n);
    let b = 3;
    let seed_rows: Vec<f32> = g.input.iter().copied().cycle().take(g.n * b).collect();
    let run = |threads: usize| -> Vec<f32> {
        let cfg = EngineConfig {
            force_scalar: true,
            par_min_rows: 1,
            par_min_elems: 1,
            par_chunk_elems: 1,
            max_threads: threads,
            ..four_cfg()
        };
        let ctx = ExecCtx::with_threads(threads).with_engine_config(cfg);
        let mut buf = seed_rows.clone();
        engine::forward_batch_ctx(&plan, &mut buf, &ctx);
        buf
    };
    let one = run(1);
    let four = run(4);
    assert_eq!(one, four, "four-step must be bitwise across pool sizes");
    for r in 0..b {
        assert_matches_packed(&one[r * g.n..(r + 1) * g.n], &g, "forced-scalar fourstep");
    }
}

#[test]
fn pooled_roundtrip_matches_golden_rows() {
    // Fused apply through the pool across odd batches: every replicated
    // row must still reproduce the committed round-trip.
    for g in load_cases() {
        if g.n > 256 {
            continue; // keep the pooled sweep cheap; large n covered above
        }
        let plan = cached(g.n);
        let mut delta = vec![0.0f32; g.n];
        delta[0] = 1.0;
        engine::forward_batch(&plan, &mut delta);
        let b = 7;
        let mut buf: Vec<f32> = g.input.iter().copied().cycle().take(g.n * b).collect();
        let ctx = ExecCtx::with_threads(4).with_engine_config(pool_cfg());
        engine::circulant_apply_batch_ctx(&plan, &mut buf, &delta, SpectralOp::Mul, &ctx);
        for r in 0..b {
            assert_matches_roundtrip(&buf[r * g.n..(r + 1) * g.n], &g, "pooled fused");
        }
    }
}
