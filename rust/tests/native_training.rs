//! Integration: the pure-Rust training pipeline — loss must go down on a
//! multi-layer circulant model, and the memtrack evidence must show the
//! in-place backend's step-state advantage at the *model* level (the
//! multi-layer extension of Table 1, the PR's acceptance criterion).

use rdfft::autograd::layers::Backend;
use rdfft::autograd::optim::OptimKind;
use rdfft::autograd::stack::StackConfig;
use rdfft::autograd::train::Method;
use rdfft::coordinator::native::{measure_native_run, NativeReport, NativeTrainer, NativeTrainerConfig};
use rdfft::memtrack::Category;

fn run(method: Method, d: usize, depth: usize, batch: usize, steps: usize) -> NativeReport {
    let cfg = NativeTrainerConfig {
        stack: StackConfig { d, depth, ctx: 8, method, seed: 11, ..Default::default() },
        optim: OptimKind::Sgd,
        lr: 0.2,
        steps,
        batch,
        eval_every: 0,
        eval_batches: 0,
        corpus_bytes: 64 * 1024,
        seed: 4,
        log_csv: None,
        verbose: false,
        threads: 0,
        ..Default::default()
    };
    let mut t = NativeTrainer::new(cfg);
    t.run().expect("native run")
}

#[test]
fn multilayer_circulant_trains_100_plus_steps_with_decreasing_loss() {
    let r = run(Method::Circulant { backend: Backend::RdFft, p: 16 }, 64, 2, 16, 120);
    assert_eq!(r.losses.len(), 120);
    assert!(
        r.tail_loss < r.head_loss,
        "loss must trend down over {} steps: {} -> {}",
        r.steps,
        r.head_loss,
        r.tail_loss
    );
    // byte-LM starts near uniform (ln 256 ≈ 5.55); the corpus is low
    // entropy, so 120 steps must make real progress, not a epsilon drop
    assert!(
        r.tail_loss < r.head_loss - 0.5,
        "expected substantive progress: {} -> {}",
        r.head_loss,
        r.tail_loss
    );
}

#[test]
fn all_backends_and_optimizers_reduce_loss_on_the_stack() {
    for method in [
        Method::Circulant { backend: Backend::Fft, p: 16 },
        Method::Circulant { backend: Backend::Rfft, p: 16 },
        Method::Lora { rank: 8 },
    ] {
        let r = run(method, 64, 2, 8, 60);
        assert!(r.tail_loss < r.head_loss, "{}: {} -> {}", r.method, r.head_loss, r.tail_loss);
    }
    // Adam on the rdFFT backend
    let r = measure_native_run(
        StackConfig {
            d: 64,
            depth: 2,
            ctx: 8,
            method: Method::Circulant { backend: Backend::RdFft, p: 16 },
            seed: 2,
            ..Default::default()
        },
        OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        0.01,
        8,
        60,
    );
    assert!(r.tail_loss < r.head_loss, "adam: {} -> {}", r.head_loss, r.tail_loss);
    assert!(r.optimizer_state_bytes > 0);
}

/// The PR's acceptance criterion: at equal width and depth, the circulant
/// rdFFT backend's activation+gradient peak must be strictly below the
/// full-finetune Dense baseline's.
#[test]
fn circulant_activation_grad_peak_strictly_below_dense_at_equal_width() {
    // d=256, depth=3: block gradients (3·d² dense vs 3·d²/p circulant)
    // dominate the shared readout, so the ordering is structural.
    let (d, depth, batch, steps) = (256usize, 3usize, 4usize, 2usize);
    let dense = run(Method::FullFinetune, d, depth, batch, steps);
    let circ = run(Method::Circulant { backend: Backend::RdFft, p: 32 }, d, depth, batch, steps);
    assert!(
        circ.activation_grad_peak() < dense.activation_grad_peak(),
        "circulant act+grad peak {} must be strictly below dense {}",
        circ.activation_grad_peak(),
        dense.activation_grad_peak()
    );
    // ...and the gap is structural, not noise: dense holds depth·d² grad
    // scalars against the circulant's depth·d²/p (plus the shared readout),
    // so demand a wide margin on the gradient axis alone.
    let gi = Category::Gradients.index();
    assert!(
        dense.peak_by_cat[gi] > 2 * circ.peak_by_cat[gi],
        "gradient peak: dense {} vs circulant {}",
        dense.peak_by_cat[gi],
        circ.peak_by_cat[gi]
    );
    // total peak ordering follows too
    assert!(circ.peak_bytes < dense.peak_bytes);
}

#[test]
fn rdfft_backend_peak_not_above_fft_backend_peak_multilayer() {
    let (d, depth, batch, steps) = (128usize, 2usize, 4usize, 3usize);
    let fft = run(Method::Circulant { backend: Backend::Fft, p: 32 }, d, depth, batch, steps);
    let ours = run(Method::Circulant { backend: Backend::RdFft, p: 32 }, d, depth, batch, steps);
    assert!(
        ours.activation_grad_peak() < fft.activation_grad_peak(),
        "ours {} vs fft {}",
        ours.activation_grad_peak(),
        fft.activation_grad_peak()
    );
}

#[test]
fn report_accounting_is_internally_consistent() {
    let r = run(Method::Circulant { backend: Backend::RdFft, p: 16 }, 64, 2, 8, 5);
    assert_eq!(r.at_peak.iter().sum::<usize>(), r.peak_bytes);
    for c in rdfft::memtrack::CATEGORIES {
        assert!(r.peak_by_cat[c.index()] >= r.at_peak[c.index()], "{}", c.name());
    }
    assert!(r.trainable_params > 0);
    assert_eq!(r.optimizer_state_bytes, 0, "sgd holds no state");
}
