//! Serving determinism + memory invariants, end to end.
//!
//! The server's contract (`runtime::server` module docs): every response
//! is a pure function of `(parameters, request bytes)` — bit-identical
//! across arrival-order permutations, coalescing-window composition, and
//! engine thread counts — and steady-state serving performs zero tracked
//! allocations. These tests drive the real stack (circulant rdFFT
//! blocks) through the sync core, the async session, and the TCP line
//! protocol, and compare fingerprints of the full logits rows.

use rdfft::autograd::layers::Backend;
use rdfft::autograd::stack::{SpectralStack, StackConfig};
use rdfft::autograd::train::Method;
use rdfft::memtrack::{self, Category};
use rdfft::runtime::pool::ExecCtx;
use rdfft::runtime::server::{
    serve_tcp, spawn_session, ServeRequest, ServeResponse, SpectralServer,
};

const D: usize = 32;
const CTX: usize = 6;
const N: usize = 22;

fn mk_stack(threads: usize) -> SpectralStack {
    let cfg = StackConfig {
        d: D,
        depth: 2,
        ctx: CTX,
        method: Method::Circulant { backend: Backend::RdFft, p: 8 },
        seed: 5,
        ..Default::default()
    };
    let exec = if threads == 0 { ExecCtx::global() } else { ExecCtx::with_threads(threads) };
    SpectralStack::with_exec(cfg, exec)
}

/// Deterministic request set: request i's context is a fixed byte pattern.
fn requests() -> Vec<ServeRequest> {
    (0..N)
        .map(|i| ServeRequest {
            id: i as u64,
            ctx: (0..CTX).map(|j| ((i * 7 + j * 13) % 251) as u8).collect(),
        })
        .collect()
}

/// Ground truth: the synchronous core at window=1 (no coalescing at all).
fn reference() -> Vec<ServeResponse> {
    let mut server = SpectralServer::new(mk_stack(0), 1).expect("all-circulant stack serves");
    let mut out = Vec::new();
    for r in &requests() {
        server.serve_window(std::slice::from_ref(r), &mut out);
    }
    out
}

/// Run the async session at `window`, submitting ids in `order`, and
/// return the responses sorted by id.
fn run_session(window: usize, threads: usize, order: &[usize]) -> Vec<ServeResponse> {
    let (handle, session) =
        spawn_session(move || mk_stack(threads), window).expect("session starts");
    let reqs = requests();
    let mut tickets = Vec::new();
    for &i in order {
        tickets.push(handle.submit(reqs[i].id, reqs[i].ctx.clone()));
    }
    // Close the final partial window; everything else already coalesced
    // into fixed id windows regardless of the submission order above.
    handle.flush();
    let mut got: Vec<ServeResponse> = tickets.into_iter().map(|t| t.wait().0).collect();
    let stats = session.shutdown();
    assert_eq!(stats.served as usize, N, "every request answered exactly once");
    assert_eq!(stats.steady_state_allocs, 0, "steady-state serving must not allocate");
    got.sort_by_key(|r| r.id);
    got
}

#[test]
fn responses_are_bit_identical_across_arrival_orders() {
    let reference = reference();
    let forward: Vec<usize> = (0..N).collect();
    let reverse: Vec<usize> = (0..N).rev().collect();
    // A stride walk (5 is coprime with 22) — maximally out-of-order
    // without being random, so the test itself stays deterministic.
    let strided: Vec<usize> = (0..N).map(|i| (i * 5) % N).collect();
    for (name, order) in [("forward", forward), ("reverse", reverse), ("strided", strided)] {
        let got = run_session(4, 0, &order);
        assert_eq!(
            got, reference,
            "{name} arrival order changed served bits (window 4 vs window 1 reference)"
        );
    }
}

#[test]
fn responses_are_bit_identical_across_thread_counts_and_windows() {
    let reference = reference();
    let order: Vec<usize> = (0..N).collect();
    for (threads, window) in [(1usize, 4usize), (3, 4), (1, 7), (3, 1)] {
        let got = run_session(window, threads, &order);
        assert_eq!(
            got, reference,
            "threads={threads} window={window} changed served bits"
        );
    }
}

#[test]
fn concurrent_submit_next_and_flush_do_not_race() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    // Regression: auto ids used to be assigned with an atomic *outside*
    // the queue lock, so a flush landing between assignment and insertion
    // advanced the serve cursor past the assigned id; the late insert
    // then panicked under the shared mutex, poisoning it and hanging
    // every outstanding ticket. Hammer that exact interleaving —
    // closed-loop clients on `submit_next` against a fast periodic
    // flusher — and check the served bits still match the reference.
    let reference = reference();
    let (handle, session) = spawn_session(move || mk_stack(0), 3).expect("session starts");
    let reqs = Arc::new(requests());
    let stop = Arc::new(AtomicBool::new(false));
    let flusher = {
        let h = handle.clone();
        let stop = Arc::clone(&stop);
        // audit: allow(no-raw-threads) test flusher races the batcher on purpose; it never runs compute
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                h.flush();
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        })
    };
    const CLIENTS: usize = 4;
    const ROUNDS: usize = 8;
    let mut workers = Vec::new();
    for c in 0..CLIENTS {
        let h = handle.clone();
        let reqs = Arc::clone(&reqs);
        // audit: allow(no-raw-threads) test clients must be real concurrent submitters to reproduce the race
        workers.push(std::thread::spawn(move || {
            let mut got = Vec::new();
            for _ in 0..ROUNDS {
                for i in (c..reqs.len()).step_by(CLIENTS) {
                    let (resp, _) = h.submit_next(reqs[i].ctx.clone()).wait();
                    got.push((i, resp));
                }
            }
            got
        }));
    }
    let mut total = 0usize;
    for w in workers {
        for (i, resp) in w.join().expect("client panicked (queue mutex poisoned?)") {
            // Admission ids depend on timing, but responses are a pure
            // function of the request bytes — match by content index.
            assert_eq!(resp.next_byte, reference[i].next_byte, "request {i} next byte");
            assert_eq!(resp.fingerprint, reference[i].fingerprint, "request {i} served bits");
            total += 1;
        }
    }
    stop.store(true, Ordering::Relaxed);
    flusher.join().expect("flusher panicked");
    assert_eq!(total, N * ROUNDS);
    let stats = session.shutdown();
    assert_eq!(stats.served as usize, N * ROUNDS, "every request answered exactly once");
    assert_eq!(stats.steady_state_allocs, 0, "steady-state serving must not allocate");
}

#[test]
fn sync_serving_is_allocation_free_after_warmup() {
    let mut server = SpectralServer::new(mk_stack(0), 4).expect("serves");
    let reqs = requests();
    let mut out = Vec::with_capacity(N);
    // Warmup tile (first pool dispatch may lazily allocate worker state).
    server.serve_window(&reqs[0..4], &mut out);
    let base = memtrack::snapshot();
    for _ in 0..10 {
        out.clear();
        server.serve_window(&reqs[0..4], &mut out);
        server.serve_window(&reqs[4..8], &mut out);
        server.serve_window(&reqs[8..11], &mut out); // partial tile too
        assert_eq!(out.len(), 11);
    }
    let snap = memtrack::snapshot();
    assert_eq!(
        snap.alloc_count, base.alloc_count,
        "steady-state serve_window performed tracked allocations"
    );
    // The Serve category holds exactly the session arena, constant across
    // requests (the ping-pong tiles + logits are reused, never reallocated).
    assert_eq!(snap.current[Category::Serve.index()], base.current[Category::Serve.index()]);
    assert_eq!(snap.current[Category::Serve.index()], server.arena_tracked_bytes());
    assert!(server.arena_tracked_bytes() > 0, "arena must be tracked under Serve");
}

#[test]
fn tcp_round_trip_matches_in_process_serving() {
    use std::io::{BufRead, BufReader, Write};

    let reference = reference();
    let (handle, session) = spawn_session(move || mk_stack(0), 2).expect("session starts");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().unwrap();
    {
        let h = handle.clone();
        // audit: allow(no-raw-threads) the accept loop blocks forever by design; the test leaks it rather than polluting the pool
        std::thread::spawn(move || {
            let _ = serve_tcp(listener, h);
        });
    }

    let take = 5usize;
    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    // Pipeline `take` hex requests, then a blank line to flush + answer.
    let mut payload = String::new();
    for r in requests().iter().take(take) {
        for b in &r.ctx {
            payload.push_str(&format!("{b:02x}"));
        }
        payload.push('\n');
    }
    payload.push('\n');
    stream.write_all(payload.as_bytes()).unwrap();
    stream.flush().unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    for i in 0..take {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let fields: Vec<&str> = line.split_whitespace().collect();
        assert_eq!(fields.first().copied(), Some("OK"), "line {i}: {line:?}");
        let next_byte: u8 = fields[1].parse().expect("next_byte");
        let fp = u64::from_str_radix(fields[2], 16).expect("fingerprint");
        // Socket ids follow admission order, which equals submission order
        // on a single pipelined connection — so line i answers request i.
        assert_eq!(next_byte, reference[i].next_byte, "request {i} next byte");
        assert_eq!(fp, reference[i].fingerprint, "request {i} served different bits over TCP");
    }
    stream.write_all(b"quit\n").unwrap();

    let stats = session.shutdown();
    assert_eq!(stats.served as usize, take);
    assert_eq!(stats.steady_state_allocs, 0);
    assert!(stats.serve_bytes > 0);
}
