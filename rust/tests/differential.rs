//! Differential tests: every rdFFT engine path is cross-checked against a
//! reference oracle, so any engine change that alters numerics is caught.
//!
//! Oracles, in decreasing independence:
//! * `baselines::complex_fft` — a *separate* radix-2 implementation on
//!   complex buffers (its own twiddle cache, its own butterfly ordering);
//! * `baselines::naive_dft` — O(n²) f64 direct summation;
//! * `baselines::rfft` — shares the rdFFT core, so comparing against it
//!   checks the packed-layout encode/decode contract specifically;
//! * dense materialization (`to_dense`) for the circulant layers.

use rdfft::autograd::layers::{Backend, CirculantLayer, Layer};
use rdfft::autograd::tensor::Rng;
use rdfft::autograd::Tensor;
use rdfft::baselines::{complex_fft, naive_dft, rfft};
use rdfft::memtrack::{self, Category};
use rdfft::rdfft::bf16::Bf16;
use rdfft::rdfft::circulant_bf16::BlockCirculantBf16;
use rdfft::rdfft::{engine, layout, plan::cached, BlockCirculant};

/// `n` uniform draws in (-1, 1) from the crate's shared deterministic RNG.
fn vec_pm1(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

/// n-scaled tolerance: f32 butterfly error grows ~O(log n) with the stage
/// count and ~O(√n) with coefficient magnitude through n = 1024
/// butterflies, so every comparison scales a base epsilon as
/// `base · √n · (log2 n + 1)` instead of using a fixed cutoff — the fixed
/// epsilons were tight at n = 4 and flaky at n = 1024. All seeds in this
/// suite are pinned constants, so CI runs are deterministic.
fn n_tol(n: usize, base: f32) -> f32 {
    base * (n as f32).sqrt() * ((n as f32).log2() + 1.0)
}

/// Sizes the differential sweep covers (ISSUE: n in {4..1024}).
const SIZES: [usize; 9] = [4, 8, 16, 32, 64, 128, 256, 512, 1024];
/// Odd / non-aligned batch counts.
const BATCHES: [usize; 4] = [1, 3, 7, 13];

#[test]
fn forward_batch_matches_independent_complex_fft() {
    for &n in &SIZES {
        for &b in &BATCHES {
            let mut rng = Rng::new((n * 31 + b) as u64);
            let x = vec_pm1(&mut rng, n * b);
            let mut got = x.clone();
            engine::forward_batch(&cached(n), &mut got);
            let tol = n_tol(n, 1e-4);
            for r in 0..b {
                let row = &x[r * n..(r + 1) * n];
                let want = complex_fft::fft_out_of_place(row, Category::Other);
                for k in 0..=n / 2 {
                    let (re, im) = layout::get(&got[r * n..(r + 1) * n], k);
                    assert!(
                        (re - want[k].re).abs() < tol && (im - want[k].im).abs() < tol,
                        "n={n} b={b} row={r} k={k}: ({re},{im}) vs ({},{})",
                        want[k].re,
                        want[k].im
                    );
                }
            }
        }
    }
}

#[test]
fn forward_matches_rfft_packing_contract() {
    // rfft shares the butterfly core, so this pins the packed-layout
    // encode/decode contract: unpacking the engine output must equal the
    // rfft-format spectrum coefficient for coefficient.
    for &n in &SIZES {
        let mut rng = Rng::new(900 + n as u64);
        let x = vec_pm1(&mut rng, n);
        let mut packed = x.clone();
        engine::forward_batch(&cached(n), &mut packed);
        let spec = rfft::rfft_alloc(&x, Category::Other);
        assert_eq!(spec.len(), n / 2 + 1);
        let tol = n_tol(n, 1e-6);
        for k in 0..=n / 2 {
            let (re, im) = layout::get(&packed, k);
            assert!(
                (re - spec[k].0).abs() < tol && (im - spec[k].1).abs() < tol,
                "n={n} k={k}"
            );
        }
    }
}

#[test]
fn inverse_batch_matches_independent_complex_ifft() {
    for &n in &SIZES {
        for &b in &[1usize, 3, 5] {
            let mut rng = Rng::new((n * 7 + b) as u64);
            // Start from spectra of real signals so both inverses apply.
            let time = vec_pm1(&mut rng, n * b);
            let mut packed = time.clone();
            engine::forward_batch(&cached(n), &mut packed);
            let mut got = packed.clone();
            engine::inverse_batch(&cached(n), &mut got);
            for r in 0..b {
                // independent inverse: unpack to full complex, run the
                // complex-fft baseline's ifft
                let full = layout::unpack_full(&packed[r * n..(r + 1) * n]);
                let mut cplx = complex_fft::ComplexVec::zeros(n, Category::Other);
                for k in 0..n {
                    cplx[k] = complex_fft::Complex::new(full[k].0, full[k].1);
                }
                let want = complex_fft::ifft_out_of_place(&cplx, Category::Other);
                let tol = n_tol(n, 3e-6).max(2e-5);
                for i in 0..n {
                    let g = got[r * n + i];
                    assert!(
                        (g - want[i].re).abs() < tol,
                        "n={n} b={b} row={r} i={i}: {g} vs {}",
                        want[i].re
                    );
                    assert!(want[i].im.abs() < tol, "imag leakage n={n} i={i}");
                }
            }
        }
    }
}

#[test]
fn forward_matches_f64_dft_oracle_small_sizes() {
    for &n in &[4usize, 16, 64, 256] {
        let mut rng = Rng::new(5000 + n as u64);
        let x = vec_pm1(&mut rng, n);
        let mut got = x.clone();
        engine::forward_batch(&cached(n), &mut got);
        let want = naive_dft(&x);
        let tol = n_tol(n, 1e-4);
        for k in 0..=n / 2 {
            let (re, im) = layout::get(&got, k);
            assert!((re - want[k].0).abs() < tol, "n={n} k={k} re");
            assert!((im - want[k].1).abs() < tol, "n={n} k={k} im");
        }
    }
}

/// Dense reference for a circulant layer's forward: y = x · Wᵀ where W is
/// the layer's materialized block-circulant weight.
fn dense_forward(w: &[f32], x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    (0..rows).map(|i| (0..cols).map(|j| w[i * cols + j] * x[j]).sum()).collect()
}

#[test]
fn circulant_layer_backends_agree_on_odd_batches() {
    // The fft backend runs on the independent complex-FFT implementation,
    // so rdFFT-vs-fft agreement at the layer level is a true differential
    // check of Eq. 4/5, swept over odd / non-tile-aligned batch counts.
    for &(d, p) in &[(16usize, 8usize), (64, 16), (256, 64)] {
        for &b in &BATCHES {
            let seed = (d + p + b) as u64;
            let mut ours = CirculantLayer::new(Backend::RdFft, d, d, p, seed);
            let mut fft = CirculantLayer::new(Backend::Fft, d, d, p, seed);

            let mut rng = Rng::new(seed);
            let x: Vec<f32> = vec_pm1(&mut rng, b * d);

            let tol = n_tol(p, 3e-5).max(1e-3);
            let y_ours = ours.forward(Tensor::from_vec(b, d, x.clone(), Category::Other));
            let y_fft = fft.forward(Tensor::from_vec(b, d, x.clone(), Category::Other));
            for i in 0..b * d {
                assert!(
                    (y_ours.as_slice()[i] - y_fft.as_slice()[i]).abs() < tol,
                    "d={d} p={p} b={b} i={i}: ours vs fft"
                );
            }

            // backward differential: same upstream grad through both
            let g: Vec<f32> = vec_pm1(&mut rng, b * d);
            let dx_ours = ours.backward(Tensor::from_vec(b, d, g.clone(), Category::Other));
            let dx_fft = fft.backward(Tensor::from_vec(b, d, g, Category::Other));
            for i in 0..b * d {
                assert!(
                    (dx_ours.as_slice()[i] - dx_fft.as_slice()[i]).abs() < tol,
                    "d={d} p={p} b={b} i={i}: dx ours vs fft"
                );
            }
        }
    }
}

#[test]
fn block_circulant_forward_matches_dense_oracle_across_sizes() {
    for &(rows, cols, p) in &[(16usize, 16usize, 4usize), (32, 64, 16), (128, 128, 32)] {
        let mut rng = Rng::new((rows * cols + p) as u64);
        let c = vec_pm1(&mut rng, (rows / p) * (cols / p) * p);
        let bc = BlockCirculant::from_block_columns(rows, cols, p, &c);
        let dense = bc.to_dense();
        let x = vec_pm1(&mut rng, cols);
        let want = dense_forward(&dense, &x, rows, cols);
        let mut xb = x.clone();
        let mut out = vec![0.0f32; rows];
        bc.forward_inplace(&mut xb, &mut out);
        for i in 0..rows {
            assert!(
                (out[i] - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()),
                "{rows}x{cols} p={p} i={i}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Fused circulant pipeline (ISSUE tentpole: fused agrees with the
// unfused forward → product → inverse path across n ∈ {4..1024} and odd
// batches, and allocates nothing after plan construction)
// ---------------------------------------------------------------------

#[test]
fn fused_circulant_apply_matches_unfused_across_sizes_and_odd_batches() {
    use rdfft::rdfft::{spectral, SpectralOp};
    for &n in &SIZES {
        for &b in &BATCHES {
            let mut rng = Rng::new((n * 131 + b) as u64);
            let mut spec = vec_pm1(&mut rng, n);
            engine::forward_batch(&cached(n), &mut spec);
            let x = vec_pm1(&mut rng, n * b);
            for op in [SpectralOp::Mul, SpectralOp::MulConjB] {
                let mut fused = x.clone();
                engine::circulant_apply_batch(&cached(n), &mut fused, &spec, op);
                // Unfused oracle: three full passes.
                let mut reference = x.clone();
                engine::forward_batch(&cached(n), &mut reference);
                for row in reference.chunks_exact_mut(n) {
                    match op {
                        SpectralOp::Mul => spectral::mul_inplace(row, &spec),
                        SpectralOp::MulConjB => spectral::mul_conjb_inplace(row, &spec),
                    }
                }
                engine::inverse_batch(&cached(n), &mut reference);
                let tol = n_tol(n, 1e-6);
                for i in 0..n * b {
                    assert!(
                        (fused[i] - reference[i]).abs() <= tol,
                        "n={n} b={b} op={op:?} i={i}: {} vs {}",
                        fused[i],
                        reference[i]
                    );
                }
            }
        }
    }
}

#[test]
fn fused_block_sweeps_match_unfused_oracles_across_sizes() {
    for &(rows, cols, p) in &[(16usize, 16usize, 4usize), (32, 64, 16), (128, 128, 32)] {
        let mut rng = Rng::new((rows * 17 + cols + p) as u64);
        let c = vec_pm1(&mut rng, (rows / p) * (cols / p) * p);
        let bc = BlockCirculant::from_block_columns(rows, cols, p, &c);
        let x = vec_pm1(&mut rng, cols);
        let g0 = vec_pm1(&mut rng, rows);
        let tol = n_tol(p, 1e-6);

        let mut x_f = x.clone();
        let mut out_f = vec![0.0f32; rows];
        bc.forward_inplace(&mut x_f, &mut out_f);
        let mut x_u = x.clone();
        let mut out_u = vec![0.0f32; rows];
        bc.forward_inplace_unfused(&mut x_u, &mut out_u);
        for i in 0..rows {
            assert!((out_f[i] - out_u[i]).abs() <= tol, "fwd {rows}x{cols} p={p} i={i}");
        }
        for i in 0..cols {
            assert!((x_f[i] - x_u[i]).abs() <= tol, "x-hat {rows}x{cols} p={p} i={i}");
        }

        let mut g_f = g0.clone();
        let mut dx_f = vec![0.0f32; cols];
        let mut dc_f = vec![0.0f32; bc.num_params()];
        bc.backward(&x_f, &mut g_f, &mut dx_f, &mut dc_f);
        let mut g_u = g0.clone();
        let mut dx_u = vec![0.0f32; cols];
        let mut dc_u = vec![0.0f32; bc.num_params()];
        bc.backward_unfused(&x_u, &mut g_u, &mut dx_u, &mut dc_u);
        for i in 0..cols {
            assert!((dx_f[i] - dx_u[i]).abs() <= tol, "dx {rows}x{cols} p={p} i={i}");
        }
        for i in 0..dc_f.len() {
            assert!((dc_f[i] - dc_u[i]).abs() <= tol, "dc {rows}x{cols} p={p} i={i}");
        }
    }
}

#[test]
fn fused_circulant_apply_allocates_nothing_after_plan_construction() {
    use rdfft::rdfft::SpectralOp;
    let n = 512;
    let plan = cached(n); // plan construction happens here
    let mut rng = Rng::new(4242);
    let mut spec = vec_pm1(&mut rng, n);
    engine::forward_batch(&plan, &mut spec);
    let mut buf = vec_pm1(&mut rng, n * 9);
    memtrack::reset();
    let before = memtrack::snapshot().alloc_count;
    engine::circulant_apply_batch(&plan, &mut buf, &spec, SpectralOp::Mul);
    engine::circulant_apply_batch(&plan, &mut buf, &spec, SpectralOp::MulConjB);
    assert_eq!(
        memtrack::snapshot().alloc_count,
        before,
        "fused pipeline must not allocate tracked memory"
    );
}

// ---------------------------------------------------------------------
// SIMD lane kernels vs the scalar oracle (ISSUE satellite: n ∈ {4..4096}
// incl. non-power-of-lane tails, odd batches, forced-scalar vs
// auto-dispatch, zero allocation on the SIMD path, and bitwise identity
// of force_scalar with the pre-SIMD scalar kernels)
// ---------------------------------------------------------------------

use rdfft::rdfft::forward::rdfft_batch_scalar;
use rdfft::rdfft::inverse::irdfft_batch_scalar;
use rdfft::rdfft::simd::{self, Kernels};
use rdfft::rdfft::EngineConfig;

/// The SIMD sweep sizes: every size from one quad below the lane width
/// (all-tail) up to the bench acceptance cell. n ∈ {4, 8} have zero full
/// quads, n = 16 has exactly one with a 3-group tail, and no m-stage's
/// group count is a multiple of 4 — the tails are always exercised.
const SIMD_SIZES: [usize; 11] = [4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096];

#[test]
fn force_scalar_is_bitwise_identical_to_pre_simd_scalar_kernels() {
    // The acceptance contract: `EngineConfig::force_scalar` reproduces
    // the seed-era scalar row loops bit-for-bit, at every size and odd
    // batch, forward and inverse, plain and fused.
    let forced = EngineConfig::forced_scalar();
    for &n in &SIMD_SIZES {
        for &b in &[1usize, 3, 7] {
            let mut rng = Rng::new((n * 53 + b) as u64);
            let x = vec_pm1(&mut rng, n * b);

            let mut scalar = x.clone();
            rdfft_batch_scalar(&cached(n), &mut scalar);
            let mut eng = x.clone();
            engine::forward_batch_with(&cached(n), &mut eng, &forced);
            assert_eq!(eng, scalar, "fwd n={n} b={b}");

            irdfft_batch_scalar(&cached(n), &mut scalar);
            engine::inverse_batch_with(&cached(n), &mut eng, &forced);
            assert_eq!(eng, scalar, "inv n={n} b={b}");
        }
    }
}

#[test]
fn forced_fused_apply_is_bitwise_identical_to_scalar_three_pass() {
    use rdfft::rdfft::{spectral, SpectralOp};
    let forced = EngineConfig::forced_scalar();
    for &n in &[4usize, 16, 128, 1024] {
        let mut rng = Rng::new(606 + n as u64);
        let mut spec = vec_pm1(&mut rng, n);
        rdfft_batch_scalar(&cached(n), &mut spec);
        let x = vec_pm1(&mut rng, n * 5);
        for op in [SpectralOp::Mul, SpectralOp::MulConjB] {
            let mut fused = x.clone();
            engine::circulant_apply_batch_with(&cached(n), &mut fused, &spec, op, &forced);
            let mut reference = x.clone();
            rdfft_batch_scalar(&cached(n), &mut reference);
            for row in reference.chunks_exact_mut(n) {
                match op {
                    SpectralOp::Mul => spectral::mul_inplace(row, &spec),
                    SpectralOp::MulConjB => spectral::mul_conjb_inplace(row, &spec),
                }
            }
            irdfft_batch_scalar(&cached(n), &mut reference);
            assert_eq!(fused, reference, "n={n} op={op:?}");
        }
    }
}

#[test]
fn simd_auto_dispatch_matches_forced_scalar_within_tolerance() {
    // Auto-dispatch may run FMA lanes; agreement with the forced-scalar
    // oracle is bounded by the n-scaled tolerance (and is bitwise
    // whenever the resolved arm is not an FMA tier — asserted, so the
    // portable quad/oct arms can never silently drift).
    let forced = EngineConfig::forced_scalar();
    for &n in &SIMD_SIZES {
        for &b in &[1usize, 3, 7, 13] {
            let mut rng = Rng::new((n * 71 + b) as u64);
            let x = vec_pm1(&mut rng, n * b);
            let mut auto = x.clone();
            engine::forward_batch(&cached(n), &mut auto);
            let mut scal = x.clone();
            engine::forward_batch_with(&cached(n), &mut scal, &forced);
            if !simd::active().uses_fma() {
                assert_eq!(auto, scal, "non-FMA arm must be bitwise n={n} b={b}");
            }
            let tol = n_tol(n, 1e-5);
            for i in 0..n * b {
                assert!(
                    (auto[i] - scal[i]).abs() <= tol,
                    "fwd n={n} b={b} i={i}: {} vs {}",
                    auto[i],
                    scal[i]
                );
            }
            engine::inverse_batch(&cached(n), &mut auto);
            engine::inverse_batch_with(&cached(n), &mut scal, &forced);
            for i in 0..n * b {
                assert!((auto[i] - scal[i]).abs() <= tol, "inv n={n} b={b} i={i}");
            }
        }
    }
}

#[test]
fn simd_path_allocates_nothing_after_plan_construction() {
    use rdfft::rdfft::SpectralOp;
    // The lane kernels are pure register/stack code: the auto-dispatched
    // engine must stay allocation-free like the scalar engine.
    let n = 1024;
    let plan = cached(n);
    let mut rng = Rng::new(777);
    let mut spec = vec_pm1(&mut rng, n);
    engine::forward_batch(&plan, &mut spec);
    let mut buf = vec_pm1(&mut rng, n * 8);
    memtrack::reset();
    let before = memtrack::snapshot().alloc_count;
    engine::forward_batch(&plan, &mut buf);
    engine::inverse_batch(&plan, &mut buf);
    engine::circulant_apply_batch(&plan, &mut buf, &spec, SpectralOp::Mul);
    assert_eq!(
        memtrack::snapshot().alloc_count,
        before,
        "SIMD engine paths must not allocate tracked memory"
    );
}

#[test]
fn simd_dispatch_is_deterministic_across_runs_and_pool_threads() {
    use rdfft::runtime::pool::ExecCtx;
    // The arm resolves once per process, so auto-dispatch results are a
    // pure function of the input: identical across repeated runs and
    // across pool sizes 1 and 4 (same chunking, same kernels).
    let fan_out = EngineConfig {
        par_min_rows: 2,
        par_min_elems: 0,
        par_chunk_elems: 1,
        max_threads: 4,
        ..EngineConfig::new()
    };
    for &n in &[64usize, 512, 4096] {
        let mut rng = Rng::new(n as u64 * 3 + 1);
        let x = vec_pm1(&mut rng, n * 9);
        let ctx1 = ExecCtx::with_threads(1).with_engine_config(fan_out);
        let ctx4 = ExecCtx::with_threads(4).with_engine_config(fan_out);
        let mut a = x.clone();
        engine::forward_batch_ctx(&cached(n), &mut a, &ctx1);
        let mut b = x.clone();
        engine::forward_batch_ctx(&cached(n), &mut b, &ctx4);
        assert_eq!(a, b, "pool width must not change results n={n}");
        for _ in 0..3 {
            let mut again = x.clone();
            engine::forward_batch_ctx(&cached(n), &mut again, &ctx4);
            assert_eq!(again, b, "repeated runs must be bit-identical n={n}");
        }
    }
}

// ---------------------------------------------------------------------
// bf16 path (ISSUE satellite: equivalence + parameter-byte halving)
// ---------------------------------------------------------------------

#[test]
fn bf16_operator_tracks_f32_operator_across_sizes() {
    for &(d, p) in &[(16usize, 8usize), (64, 16), (128, 32)] {
        let mut rng = Rng::new((d + p) as u64);
        let c = vec_pm1(&mut rng, (d / p) * (d / p) * p);
        let x = vec_pm1(&mut rng, d);
        let f32_op = BlockCirculant::from_block_columns(d, d, p, &c);
        let bf_op = BlockCirculantBf16::from_block_columns(d, d, p, &c);

        let mut xf = x.clone();
        let mut yf = vec![0.0f32; d];
        f32_op.forward_inplace(&mut xf, &mut yf);

        let mut xb: Vec<Bf16> = x.iter().map(|&v| Bf16::from_f32(v)).collect();
        let mut yb = vec![Bf16::ZERO; d];
        bf_op.forward_inplace(&mut xb, &mut yb);

        // bf16 keeps ~8 mantissa bits and every butterfly stage rounds:
        // tolerate 10% of the output scale (matches the operator's own
        // unit-test tolerance at these sizes).
        let scale = yf.iter().map(|v| v.abs()).fold(0.5f32, f32::max);
        for i in 0..d {
            assert!(
                (yb[i].to_f32() - yf[i]).abs() < 0.1 * scale,
                "d={d} p={p} i={i}: {} vs {}",
                yb[i].to_f32(),
                yf[i]
            );
        }
    }
}

#[test]
fn bf16_backend_halves_parameter_bytes_tracker_backed() {
    let (d, p) = (64usize, 16usize);
    let mut rng = Rng::new(42);
    let c = vec_pm1(&mut rng, (d / p) * (d / p) * p);

    // f32 operator: 4 bytes per scalar under Trainable.
    memtrack::reset();
    let f32_op = BlockCirculant::from_block_columns(d, d, p, &c);
    let f32_bytes = memtrack::snapshot().current[Category::Trainable.index()];
    assert_eq!(f32_bytes, f32_op.param_bytes());
    assert_eq!(f32_bytes, f32_op.num_params() * 4);
    drop(f32_op);
    assert_eq!(memtrack::snapshot().current[Category::Trainable.index()], 0);

    // bf16 operator: exactly half, and the tracker agrees.
    let bf_op = BlockCirculantBf16::from_block_columns(d, d, p, &c);
    let bf_bytes = memtrack::snapshot().current[Category::Trainable.index()];
    assert_eq!(bf_bytes, bf_op.param_bytes());
    assert_eq!(bf_bytes * 2, f32_bytes, "bf16 must halve parameter bytes");
}
