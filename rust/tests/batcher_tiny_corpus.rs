//! Tiny-corpus sweeps over every Batcher sampling path.
//!
//! The four samplers (`next_batch`, `eval_batch`, `next_context_batch`,
//! `eval_context_batch`) each used to hide a panic on degenerate corpora
//! — usize underflow in the random-start bound, or `% 0` in the eval
//! wrap. These sweeps walk every corpus length from empty up to twice
//! the smallest viable window and pin the contract: a typed
//! [`BatchError`] with exact fields on the small side of the boundary,
//! exact batch geometry on the large side, and **never** a panic.

use rdfft::data::{BatchError, Batcher};

/// Deterministic ASCII corpus of exactly `len` bytes.
fn corpus(len: usize) -> String {
    "abcdefghijklmnopqrstuvwxyz0123456789 ".chars().cycle().take(len).collect()
}

#[test]
fn constructor_and_seq_samplers_across_the_boundary() {
    for seq_len in [1usize, 2, 3, 5, 8] {
        for len in 0..=2 * (seq_len + 2) {
            let text = corpus(len);
            match Batcher::try_new(&text, 2, seq_len, 7) {
                Err(e) => {
                    assert!(
                        len < seq_len + 1,
                        "seq_len {seq_len}: len {len} wrongly rejected: {e}"
                    );
                    assert_eq!(
                        e,
                        BatchError::CorpusTooSmall { tokens: len, needed: seq_len + 1 },
                        "seq_len {seq_len} len {len}"
                    );
                }
                Ok(mut b) => {
                    assert!(len >= seq_len + 1, "seq_len {seq_len}: len {len} wrongly accepted");
                    // Path 1: random training windows. The constructor
                    // bound and the sampler guard coincide, so success is
                    // guaranteed here — with exact geometry.
                    for _ in 0..4 {
                        let (t, g) = b.next_batch().expect("constructor admitted this corpus");
                        assert_eq!(t.len(), 2 * seq_len);
                        assert_eq!(g.len(), 2 * seq_len);
                        // Shifted-target invariant inside each row.
                        for row in 0..2 {
                            for i in 0..seq_len - 1 {
                                assert_eq!(g[row * seq_len + i], t[row * seq_len + i + 1]);
                            }
                        }
                    }
                    // Path 2: deterministic eval windows (stride
                    // seq_len+1 <= len always holds here). Large indices
                    // exercise the wrap; the old `% max_start` panicked
                    // on len == seq_len+1 splits and skipped the final
                    // window otherwise.
                    for index in 0..6 {
                        let (t, g) = b.eval_batch(index).expect("split holds a window");
                        assert_eq!(t.len(), 2 * seq_len);
                        assert_eq!(g.len(), 2 * seq_len);
                    }
                }
            }
        }
    }
}

#[test]
fn context_samplers_across_the_boundary() {
    // Fix the constructor's seq_len at its minimum so the corpus sweep is
    // governed by the *context* windows under test, not construction.
    let seq_len = 1usize;
    for ctx in 1usize..=12 {
        for len in (seq_len + 1)..=2 * (ctx + 2) {
            let text = corpus(len);
            let mut b = Batcher::try_new(&text, 3, seq_len, 11).expect("len >= seq_len + 1");

            // Path 3: random (context, label) windows need ctx + 1 tokens
            // (the old start bound `len - ctx - 1` underflowed on short
            // corpora and excluded the final window on long ones).
            match b.next_context_batch(ctx) {
                Err(e) => {
                    assert!(len < ctx + 1, "ctx {ctx} len {len} wrongly rejected: {e}");
                    assert_eq!(
                        e,
                        BatchError::CorpusTooSmall { tokens: len, needed: ctx + 1 },
                        "ctx {ctx} len {len}"
                    );
                }
                Ok((contexts, labels)) => {
                    assert!(len >= ctx + 1, "ctx {ctx} len {len} wrongly accepted");
                    assert_eq!(contexts.len(), 3 * ctx);
                    assert_eq!(labels.len(), 3);
                    assert!(labels.iter().all(|&l| l < 256));
                }
            }

            // Path 4: deterministic eval windows need ctx + 1 tokens (the
            // one-window split hit `% 0` before the guard existed).
            for index in 0..5 {
                match b.eval_context_batch(index, ctx) {
                    Err(e) => {
                        assert!(len < ctx + 1, "ctx {ctx} len {len} wrongly rejected: {e}");
                        assert_eq!(
                            e,
                            BatchError::EmptyEvalSplit { tokens: len, window: ctx + 1 },
                            "ctx {ctx} len {len}"
                        );
                    }
                    Ok((contexts, labels)) => {
                        assert!(len >= ctx + 1, "ctx {ctx} len {len} wrongly accepted");
                        assert_eq!(contexts.len(), 3 * ctx);
                        assert_eq!(labels.len(), 3);
                    }
                }
            }
        }
    }
}

#[test]
fn typed_errors_are_actionable_and_stable() {
    // The error carries both the have and the need — the CLI surfaces it
    // verbatim, so the message must name the numbers.
    let err = Batcher::try_new("ab", 4, 8, 0).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains('2') && msg.contains('9'), "{msg}");
    // BatchError is a real std error (anyhow `?` conversion at the
    // trainer call sites depends on it).
    let _: &dyn std::error::Error = &err;
}
