//! Property-based sweeps over the core invariants (in-tree substitute for
//! proptest, which is unavailable offline): each property runs against
//! hundreds of seeded random cases across sizes; failures print the seed
//! so cases are reproducible.

use rdfft::baselines::naive_dft;
use rdfft::rdfft::bf16::{irdfft_inplace_bf16, rdfft_inplace_bf16, Bf16};
use rdfft::rdfft::engine::{self, EngineConfig};
use rdfft::rdfft::forward::rdfft_batch_scalar;
use rdfft::rdfft::inverse::irdfft_batch_scalar;
use rdfft::rdfft::{
    irdfft_inplace, layout, plan::cached, rdfft_inplace, spectral, BlockCirculant, Circulant,
};

/// Deterministic per-case RNG.
struct Rng(u64);
impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn f32(&mut self) -> f32 {
        (self.next() >> 40) as f32 / (1u64 << 24) as f32 * 2.0 - 1.0
    }
    fn vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

const SIZES: [usize; 8] = [2, 4, 8, 16, 64, 256, 1024, 4096];

#[test]
fn prop_roundtrip_identity() {
    for case in 0..300u64 {
        let mut rng = Rng::new(case);
        let n = SIZES[rng.below(SIZES.len())];
        let plan = cached(n);
        let x = rng.vec(n);
        let mut buf = x.clone();
        rdfft_inplace(&plan, &mut buf);
        irdfft_inplace(&plan, &mut buf);
        for i in 0..n {
            assert!(
                (buf[i] - x[i]).abs() < 1e-3,
                "case={case} n={n} i={i}: {} vs {}",
                buf[i],
                x[i]
            );
        }
    }
}

#[test]
fn prop_forward_matches_naive_dft() {
    for case in 0..60u64 {
        let mut rng = Rng::new(1000 + case);
        let n = SIZES[rng.below(6)]; // <= 1024 (naive is O(n^2))
        let plan = cached(n);
        let x = rng.vec(n);
        let mut buf = x.clone();
        rdfft_inplace(&plan, &mut buf);
        let want = naive_dft(&x);
        let tol = 1e-3 * (n as f32).sqrt();
        for k in 0..=n / 2 {
            let (re, im) = layout::get(&buf, k);
            assert!((re - want[k].0).abs() < tol, "case={case} n={n} k={k} re");
            assert!((im - want[k].1).abs() < tol, "case={case} n={n} k={k} im");
        }
    }
}

#[test]
fn prop_linearity() {
    for case in 0..200u64 {
        let mut rng = Rng::new(2000 + case);
        let n = SIZES[rng.below(SIZES.len())];
        let plan = cached(n);
        let (a, b) = (rng.f32() * 3.0, rng.f32() * 3.0);
        let x = rng.vec(n);
        let y = rng.vec(n);
        let mut fx = x.clone();
        let mut fy = y.clone();
        rdfft_inplace(&plan, &mut fx);
        rdfft_inplace(&plan, &mut fy);
        let mut z: Vec<f32> = (0..n).map(|i| a * x[i] + b * y[i]).collect();
        rdfft_inplace(&plan, &mut z);
        for i in 0..n {
            assert!(
                (z[i] - (a * fx[i] + b * fy[i])).abs() < 2e-3 * (n as f32).sqrt(),
                "case={case} n={n} i={i}"
            );
        }
    }
}

#[test]
fn prop_parseval() {
    for case in 0..200u64 {
        let mut rng = Rng::new(3000 + case);
        let n = SIZES[rng.below(SIZES.len())];
        let plan = cached(n);
        let x = rng.vec(n);
        let et: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        let mut buf = x.clone();
        rdfft_inplace(&plan, &mut buf);
        let mut ef = (buf[0] as f64).powi(2) + (buf[n / 2] as f64).powi(2);
        for k in 1..n / 2 {
            ef += 2.0 * ((buf[k] as f64).powi(2) + (buf[n - k] as f64).powi(2));
        }
        ef /= n as f64;
        assert!(
            (et - ef).abs() <= 1e-4 * et.max(1.0),
            "case={case} n={n}: {et} vs {ef}"
        );
    }
}

#[test]
fn prop_spectral_mul_is_convolution() {
    // IFFT(â ⊙ b̂) == circular convolution of a and b.
    for case in 0..80u64 {
        let mut rng = Rng::new(4000 + case);
        let n = [4usize, 8, 16, 64, 256][rng.below(5)];
        let plan = cached(n);
        let a = rng.vec(n);
        let b = rng.vec(n);
        let mut fa = a.clone();
        let mut fb = b.clone();
        rdfft_inplace(&plan, &mut fa);
        rdfft_inplace(&plan, &mut fb);
        spectral::mul_inplace(&mut fa, &fb);
        irdfft_inplace(&plan, &mut fa);
        for i in 0..n {
            let want: f32 = (0..n).map(|j| a[j] * b[(i + n - j) % n]).sum();
            assert!(
                (fa[i] - want).abs() < 1e-2 * (n as f32).sqrt(),
                "case={case} n={n} i={i}: {} vs {want}",
                fa[i]
            );
        }
    }
}

#[test]
fn prop_conjugation_time_reversal() {
    // conj in frequency == time reversal: IFFT(conj(x̂))[i] == x[(n-i) % n]
    for case in 0..100u64 {
        let mut rng = Rng::new(5000 + case);
        let n = SIZES[rng.below(6)];
        let plan = cached(n);
        let x = rng.vec(n);
        let mut buf = x.clone();
        rdfft_inplace(&plan, &mut buf);
        layout::conj_inplace(&mut buf);
        irdfft_inplace(&plan, &mut buf);
        for i in 0..n {
            assert!(
                (buf[i] - x[(n - i) % n]).abs() < 1e-3,
                "case={case} n={n} i={i}"
            );
        }
    }
}

#[test]
fn prop_circulant_matches_dense() {
    for case in 0..60u64 {
        let mut rng = Rng::new(6000 + case);
        let n = [4usize, 8, 16, 32, 64][rng.below(5)];
        let c = rng.vec(n);
        let x = rng.vec(n);
        let circ = Circulant::from_first_column(&c);
        let dense = circ.to_dense();
        let mut got = x.clone();
        circ.matvec_inplace(&mut got);
        for i in 0..n {
            let want: f32 = (0..n).map(|j| dense[i * n + j] * x[j]).sum();
            assert!((got[i] - want).abs() < 1e-2, "case={case} n={n} i={i}");
        }
    }
}

#[test]
fn prop_block_circulant_grads_match_finite_difference() {
    for case in 0..10u64 {
        let mut rng = Rng::new(7000 + case);
        let p = [4usize, 8, 16][rng.below(3)];
        let (rows, cols) = (2 * p, 2 * p);
        let cvec = rng.vec((rows / p) * (cols / p) * p);
        let bc = BlockCirculant::from_block_columns(rows, cols, p, &cvec);
        let x = rng.vec(cols);
        let g0 = rng.vec(rows);

        let mut x_hat = x.clone();
        let mut out = vec![0.0; rows];
        bc.forward_inplace(&mut x_hat, &mut out);
        let mut g = g0.clone();
        let mut dx = vec![0.0; cols];
        let mut dc = vec![0.0; bc.num_params()];
        bc.backward(&x_hat, &mut g, &mut dx, &mut dc);

        // dx via finite differences on a few random coordinates
        let f = |x: &[f32]| -> f32 {
            let mut xb = x.to_vec();
            let mut o = vec![0.0; rows];
            bc.forward_inplace(&mut xb, &mut o);
            o.iter().zip(&g0).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2f32;
        for _ in 0..5 {
            let idx = rng.below(cols);
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!(
                (fd - dx[idx]).abs() < 3e-2 * (1.0 + fd.abs()),
                "case={case} p={p} idx={idx}: fd={fd} got={}",
                dx[idx]
            );
        }
    }
}

#[test]
fn prop_bf16_tracks_f32() {
    for case in 0..60u64 {
        let mut rng = Rng::new(8000 + case);
        let n = [16usize, 64, 256, 1024][rng.below(4)];
        let plan = cached(n);
        let x = rng.vec(n);
        let mut f32_buf = x.clone();
        rdfft_inplace(&plan, &mut f32_buf);
        let mut bf: Vec<Bf16> = x.iter().map(|&v| Bf16::from_f32(v)).collect();
        rdfft_inplace_bf16(&plan, &mut bf);
        let scale = (n as f32) * 0.02;
        for i in 0..n {
            assert!(
                (bf[i].to_f32() - f32_buf[i]).abs() < scale.max(0.05),
                "case={case} n={n} i={i}: {} vs {}",
                bf[i].to_f32(),
                f32_buf[i]
            );
        }
        irdfft_inplace_bf16(&plan, &mut bf);
        for i in 0..n {
            assert!(
                (bf[i].to_f32() - x[i]).abs() < 0.06,
                "case={case} roundtrip n={n} i={i}"
            );
        }
    }
}

#[test]
fn prop_bf16_conversion_roundtrip_and_monotone() {
    let mut rng = Rng::new(9000);
    for _ in 0..5000 {
        let v = rng.f32() * 1e6;
        let b = Bf16::from_f32(v);
        let back = b.to_f32();
        // rounding error bounded by 1 part in 2^8
        assert!((back - v).abs() <= v.abs() / 128.0 + f32::MIN_POSITIVE);
        // double conversion is idempotent
        assert_eq!(Bf16::from_f32(back), b);
    }
}

/// Engine tuning variants exercised by the equivalence properties: the
/// default (threshold-gated threads, auto SIMD dispatch), pure serial
/// batch-major, a config that forces threads even on tiny batches (so
/// odd chunk splits are covered deterministically), and the forced-scalar
/// oracle arm (legacy kernels, no SIMD).
fn engine_configs() -> [EngineConfig; 4] {
    [
        EngineConfig::new(),
        EngineConfig::serial(),
        EngineConfig {
            par_min_rows: 2,
            par_min_elems: 0,
            par_chunk_elems: 1,
            max_threads: 3,
            ..EngineConfig::new()
        },
        EngineConfig::forced_scalar(),
    ]
}

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-4 * (1.0 + b.abs())
}

#[test]
fn prop_engine_forward_equals_scalar_rows() {
    // batch-major forward ≡ per-row scalar reference across random sizes
    // n ∈ {2..4096} and batches 1..17 (odd / non-chunk-aligned included)
    for case in 0..120u64 {
        let mut rng = Rng::new(20_000 + case);
        let n = SIZES[rng.below(SIZES.len())];
        let batch = 1 + rng.below(17);
        let x = rng.vec(n * batch);
        let mut want = x.clone();
        rdfft_batch_scalar(&cached(n), &mut want);
        for (ci, cfg) in engine_configs().iter().enumerate() {
            let mut got = x.clone();
            engine::forward_batch_with(&cached(n), &mut got, cfg);
            for i in 0..n * batch {
                assert!(
                    close(got[i], want[i]),
                    "case={case} cfg={ci} n={n} b={batch} i={i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }
}

#[test]
fn prop_engine_inverse_equals_scalar_rows() {
    for case in 0..120u64 {
        let mut rng = Rng::new(21_000 + case);
        let n = SIZES[rng.below(SIZES.len())];
        let batch = 1 + rng.below(17);
        let x = rng.vec(n * batch);
        let mut want = x.clone();
        irdfft_batch_scalar(&cached(n), &mut want);
        for (ci, cfg) in engine_configs().iter().enumerate() {
            let mut got = x.clone();
            engine::inverse_batch_with(&cached(n), &mut got, cfg);
            for i in 0..n * batch {
                assert!(
                    close(got[i], want[i]),
                    "case={case} cfg={ci} n={n} b={batch} i={i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }
}

#[test]
fn prop_engine_roundtrip_identity_all_batches() {
    // forward∘inverse == id through the engine for every batch 1..=17,
    // including n = 2 and batches that don't align with tiles or chunks
    for case in 0..60u64 {
        let mut rng = Rng::new(22_000 + case);
        let n = SIZES[rng.below(SIZES.len())];
        let batch = 1 + rng.below(17);
        let x = rng.vec(n * batch);
        let mut buf = x.clone();
        let plan = cached(n);
        engine::forward_batch(&plan, &mut buf);
        engine::inverse_batch(&plan, &mut buf);
        for i in 0..n * batch {
            assert!(
                (buf[i] - x[i]).abs() < 1e-3,
                "case={case} n={n} b={batch} i={i}"
            );
        }
    }
}

#[test]
fn prop_transform_never_allocates() {
    // run many shapes; the tracker must never see an allocation from
    // inside the transform itself.
    rdfft::memtrack::reset();
    for case in 0..50u64 {
        let mut rng = Rng::new(10_000 + case);
        let n = SIZES[rng.below(SIZES.len())];
        let plan = cached(n);
        let mut buf = rng.vec(n);
        let other = buf.clone(); // caller-side, untracked
        let before = rdfft::memtrack::snapshot().alloc_count;
        rdfft_inplace(&plan, &mut buf);
        spectral::mul_inplace(&mut buf, &other);
        irdfft_inplace(&plan, &mut buf);
        // (the clone above is caller-side and untracked; transform adds 0)
        assert_eq!(rdfft::memtrack::snapshot().alloc_count, before, "case={case} n={n}");
    }
}
