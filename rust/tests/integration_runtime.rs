//! Integration: the full three-layer stack — Rust loads the AOT HLO
//! artifacts (JAX model + Pallas kernels) and trains.
//!
//! Requires `make artifacts` (the test preset). If artifacts are missing
//! the tests are skipped with a notice rather than failing, so `cargo
//! test` works in a fresh checkout; `make test` always builds them first.

use rdfft::coordinator::{Trainer, TrainerConfig};
use rdfft::data::{Batcher, CorpusGen};
use rdfft::runtime::Runtime;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    candidates.into_iter().find(|p| p.join("manifest.json").exists())
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
                return;
            }
        }
    };
}

fn batch_for(rt: &Runtime, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let text = CorpusGen::new(seed).text(64 * 1024);
    let mut b = Batcher::new(&text, rt.manifest.batch, rt.manifest.seq_len, seed);
    b.next_batch().expect("64 KiB corpus fits a window")
}

#[test]
fn loads_and_reports_manifest() {
    let dir = require_artifacts!();
    let rt = Runtime::load(Path::new(&dir)).expect("load runtime");
    assert_eq!(rt.platform().to_lowercase().contains("cpu"), true);
    assert!(rt.manifest.num_trainable_params > 0);
    assert!(rt.manifest.num_frozen_params > rt.manifest.num_trainable_params);
}

#[test]
fn eval_is_deterministic_and_near_uniform_at_init() {
    let dir = require_artifacts!();
    let rt = Runtime::load(Path::new(&dir)).expect("load runtime");
    let (t, g) = batch_for(&rt, 3);
    let l1 = rt.eval_step(&t, &g).unwrap();
    let l2 = rt.eval_step(&t, &g).unwrap();
    assert_eq!(l1, l2, "eval must be deterministic");
    // random init on vocab 256: loss near ln(256) ≈ 5.55
    assert!((3.0..8.0).contains(&l1), "init loss {l1}");
}

#[test]
fn memorizes_a_fixed_batch() {
    let dir = require_artifacts!();
    let mut rt = Runtime::load(Path::new(&dir)).expect("load runtime");
    let (t, g) = batch_for(&rt, 5);
    let first = rt.train_step(&t, &g).unwrap();
    let mut last = first;
    for _ in 0..20 {
        last = rt.train_step(&t, &g).unwrap();
    }
    assert!(
        last < first * 0.95,
        "loss must drop by >=5% when memorizing one batch: {first} -> {last}"
    );
}

#[test]
fn checkpoint_roundtrip_preserves_loss() {
    let dir = require_artifacts!();
    let mut rt = Runtime::load(Path::new(&dir)).expect("load runtime");
    let (t, g) = batch_for(&rt, 7);
    for _ in 0..3 {
        rt.train_step(&t, &g).unwrap();
    }
    let loss_before = rt.eval_step(&t, &g).unwrap();
    let flat = rt.trainable_flat().unwrap();
    // fresh runtime, restore checkpoint
    let mut rt2 = Runtime::load(Path::new(&dir)).expect("load runtime");
    let init_loss = rt2.eval_step(&t, &g).unwrap();
    assert_ne!(loss_before, init_loss, "training must have moved the params");
    rt2.set_trainable_flat(&flat).unwrap();
    let loss_after = rt2.eval_step(&t, &g).unwrap();
    assert!((loss_before - loss_after).abs() < 1e-5, "{loss_before} vs {loss_after}");
}

#[test]
fn trainer_end_to_end_smoke() {
    let dir = require_artifacts!();
    let cfg = TrainerConfig {
        steps: 20,
        eval_every: 10,
        eval_batches: 2,
        corpus_bytes: 128 * 1024,
        seed: 1,
        log_csv: None,
        checkpoint: None,
    };
    let mut trainer = Trainer::new(Path::new(&dir), cfg).expect("trainer");
    let report = trainer.run().expect("train");
    assert_eq!(report.losses.len(), 20);
    assert!(report.final_loss < report.first_loss, "loss should trend down even in 20 steps");
    assert!(report.tokens_per_sec > 0.0);
}

#[test]
fn rejects_malformed_batch_geometry() {
    let dir = require_artifacts!();
    let mut rt = Runtime::load(Path::new(&dir)).expect("load runtime");
    let bad = vec![0i32; 3];
    assert!(rt.train_step(&bad, &bad).is_err());
}
