//! Spectral inference serving: a request queue plus a **deterministic
//! micro-batcher** that coalesces concurrent single-row requests into
//! batch-major tiles the rdFFT engine is fastest at (the fused sweeps
//! amortize one shared `ĉ` spectrum across every row of a tile — the
//! serving-side twin of the batch-FFT reuse argument).
//!
//! Determinism contract
//! --------------------
//! Coalescing happens over **fixed windows of request ids**, never over
//! arrival time: window `k` is the id range `[k·W, (k+1)·W)`, a pure
//! function of the id a request was submitted with. The serve thread
//! processes windows strictly in id order (a reorder buffer absorbs
//! out-of-order arrivals), so which rows share a tile is independent of
//! thread scheduling, client interleaving, and queue depth. Per-row
//! compute is itself row-independent ([`SpectralStack::infer_forward`]),
//! so every response is a pure function of `(parameters, request bytes)`
//! — bit-identical across arrival-order permutations and pool thread
//! counts. [`ServerHandle::flush`] (and shutdown) close the current
//! window early with whatever contiguous prefix has arrived; that changes
//! *batching*, never *results*.
//!
//! Memory contract
//! ---------------
//! A serving session owns one [`InferArena`] (ping-pong activation tiles
//! + logits) tracked under [`Category::Serve`], allocated once and reused
//! for every request. After the warmup window, serving performs **zero**
//! tracked allocations per request — [`ServeStats::steady_state_allocs`]
//! carries the memtrack evidence out of the session. (The invariant
//! covers tracked tensors, the paper's accounting unit; untracked harness
//! bookkeeping — queue nodes, response slots — is outside it.)
//!
//! Threading note: memtrack's tracker is thread-local, so the session's
//! model and arena are built, used, and dropped **on the serve thread**
//! ([`spawn_session`] takes a builder closure for exactly this reason).
//! Engine calls dispatch through the stack's [`ExecCtx`] onto the shared
//! [`WorkerPool`](crate::runtime::pool::WorkerPool) as usual.

use crate::autograd::stack::{InferArena, SpectralStack};
use crate::memtrack::{self, Category};
use std::collections::BTreeMap;
use std::fmt;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Condvar, Mutex};
// audit: allow(determinism-lint) Instant feeds latency metadata only; ServeResponse carries no timing, so response bits never depend on it
use std::time::Instant;

/// Typed serving-construction failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// A block lacks the allocation-free inference hook
    /// (`Layer::infer_forward_residual`), e.g. a LoRA block.
    UnsupportedStack,
    /// The coalescing window must hold at least one request.
    EmptyWindow,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnsupportedStack => write!(
                f,
                "stack has a block without inference support \
                 (serving needs supports_infer_exec on every block)"
            ),
            ServeError::EmptyWindow => {
                write!(f, "coalescing window must hold at least one request")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// One inference request: a dense, client-assigned sequence id (window
/// membership is `id / window` — ids must be dense per session) and a
/// flat context of exactly the model's `ctx` bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeRequest {
    pub id: u64,
    pub ctx: Vec<u8>,
}

/// One inference response. Deliberately carries no timing: two responses
/// compare equal iff the served bits were identical, which is what the
/// determinism tests and `repro slam` assert across interleavings and
/// thread counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeResponse {
    pub id: u64,
    /// Argmax of the logits row (ties break to the lowest byte).
    pub next_byte: u8,
    /// FNV-1a over the full logits row's f32 bit patterns — the
    /// bit-identity witness.
    pub fingerprint: u64,
}

/// Session evidence returned by [`ServerSession::shutdown`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests served.
    pub served: u64,
    /// Tiles run (complete windows + flushed partials).
    pub windows: u64,
    /// Tracked allocations performed *after* the warmup window — the
    /// zero-steady-state-allocation invariant says this is exactly 0.
    pub steady_state_allocs: usize,
    /// Tracked bytes resident in the session arena ([`Category::Serve`]).
    pub serve_bytes: usize,
    /// Peak tracked [`Category::Serve`] bytes over the session.
    pub peak_serve_bytes: usize,
}

/// FNV-1a (64-bit) over the little-endian bit patterns of an f32 slice.
pub fn fingerprint_f32(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// The synchronous deterministic core: one model + one reusable arena,
/// serving id-sorted request slices one fixed tile at a time. The async
/// session ([`spawn_session`]) and the tests drive this same type, so
/// the queue layer can't diverge from what the tests pin down.
pub struct SpectralServer {
    stack: SpectralStack,
    arena: InferArena,
    /// Reused `window*ctx` byte staging tile (padding rows stay zero).
    staging: Vec<u8>,
    window: usize,
}

impl SpectralServer {
    /// Wrap a stack for serving: transforms parameters for immutable
    /// reads ([`SpectralStack::begin_serve`]) and allocates the session
    /// arena under [`Category::Serve`].
    pub fn new(mut stack: SpectralStack, window: usize) -> Result<SpectralServer, ServeError> {
        if window == 0 {
            return Err(ServeError::EmptyWindow);
        }
        if !stack.supports_infer_exec() {
            return Err(ServeError::UnsupportedStack);
        }
        stack.begin_serve();
        let arena = InferArena::new(&stack, window, Category::Serve);
        let staging = vec![0u8; window * stack.config().ctx];
        Ok(SpectralServer { stack, arena, staging, window })
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Context bytes every request must carry.
    pub fn ctx(&self) -> usize {
        self.stack.config().ctx
    }

    pub fn stack(&self) -> &SpectralStack {
        &self.stack
    }

    /// Tracked bytes held by the session arena.
    pub fn arena_tracked_bytes(&self) -> usize {
        self.arena.tracked_bytes()
    }

    /// Serve one tile: up to `window` requests packed batch-major (row i
    /// = request i), short tiles padded with zero contexts whose outputs
    /// are discarded. Appends one response per request to `out`. Performs
    /// zero tracked allocations.
    // audit: no_alloc
    pub fn serve_window(&mut self, reqs: &[ServeRequest], out: &mut Vec<ServeResponse>) {
        assert!(
            !reqs.is_empty() && reqs.len() <= self.window,
            "a tile holds 1..=window requests"
        );
        let ctx = self.ctx();
        self.staging.fill(0);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.ctx.len(), ctx, "request {} context must be exactly {ctx} bytes", r.id);
            self.staging[i * ctx..(i + 1) * ctx].copy_from_slice(&r.ctx);
        }
        self.stack.infer_forward(&self.staging, &mut self.arena);
        for (i, r) in reqs.iter().enumerate() {
            let row = self.arena.logits().row(i);
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (j, &v) in row.iter().enumerate() {
                if v > best_v {
                    best_v = v;
                    best = j;
                }
            }
            out.push(ServeResponse {
                id: r.id,
                next_byte: best as u8,
                fingerprint: fingerprint_f32(row),
            });
        }
    }
}

/// Filled-response slot a [`Ticket`] blocks on: `(response, latency_ns)`.
#[derive(Default)]
struct Slot {
    resp: Mutex<Option<(ServeResponse, u64)>>,
    cv: Condvar,
}

/// A claim on one submitted request's response.
pub struct Ticket {
    slot: Arc<Slot>,
}

impl Ticket {
    /// Block until the request is served; returns the response plus the
    /// submit→serve latency in nanoseconds (measured on the serve
    /// thread, so a late reaper doesn't inflate it).
    pub fn wait(self) -> (ServeResponse, u64) {
        // Poison recovery per the plan-cache policy: the slot holds a
        // plain `Option` that is either written whole or not at all, so
        // it is valid even if another waiter panicked with the lock held
        // — a poisoned mutex must not wedge every outstanding ticket.
        let mut g = self.slot.resp.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(r) = g.take() {
                return r;
            }
            g = self.slot.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }
}

struct Entry {
    ctx: Vec<u8>,
    slot: Arc<Slot>,
    // audit: allow(determinism-lint) submit timestamp is latency metadata only — never reaches response bits
    submitted: Instant,
}

struct State {
    /// Reorder buffer: requests keyed by id, consumed in id order.
    pending: BTreeMap<u64, Entry>,
    /// Next id the serve thread will admit into a tile.
    next_id: u64,
    /// Next auto-assigned id for [`ServerHandle::submit_next`]. Lives
    /// under this lock — assigning and inserting in one critical section
    /// is what keeps auto ids ahead of the serve cursor under concurrent
    /// flushes.
    auto_next: u64,
    /// A flush drains every id below this bound (partial tiles allowed);
    /// once the cursor passes it, fixed windowing resumes.
    flush_until: Option<u64>,
    /// Drain, then exit the serve loop.
    stop: bool,
}

struct Shared {
    mu: Mutex<State>,
    cv: Condvar,
}

/// Cloneable submission side of a serving session.
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
    ctx: usize,
}

impl ServerHandle {
    /// Context bytes every request must carry.
    pub fn ctx(&self) -> usize {
        self.ctx
    }

    /// Submit with an explicit id (the deterministic-harness path: the
    /// caller owns the dense 0..n id assignment, making every window's
    /// membership a pure function of the request set). Panics on a
    /// duplicate or already-served id — both are harness bugs.
    pub fn submit(&self, id: u64, ctx: Vec<u8>) -> Ticket {
        assert_eq!(ctx.len(), self.ctx, "request context must be exactly {} bytes", self.ctx);
        let slot = Arc::new(Slot::default());
        // audit: allow(determinism-lint) submit timestamp is latency metadata only — never reaches response bits
        let entry = Entry { ctx, slot: Arc::clone(&slot), submitted: Instant::now() };
        // Queue state is a plain reorder buffer + cursors — always
        // structurally valid, so recover from poison rather than letting
        // one panicked submitter wedge the whole session.
        let mut st = self.shared.mu.lock().unwrap_or_else(|p| p.into_inner());
        assert!(id >= st.next_id, "request id {id} is already behind the serve cursor");
        let prev = st.pending.insert(id, entry);
        assert!(prev.is_none(), "duplicate request id {id}");
        drop(st);
        self.shared.cv.notify_all();
        Ticket { slot }
    }

    /// Submit with the next server-assigned id (the socket and
    /// closed-loop paths, where ids follow admission order). The id is
    /// assigned and the entry inserted in one queue-lock critical
    /// section, so a concurrent [`Self::flush`] can never advance the
    /// serve cursor past an assigned-but-not-yet-queued id. Don't mix
    /// with [`Self::submit`].
    pub fn submit_next(&self, ctx: Vec<u8>) -> Ticket {
        assert_eq!(ctx.len(), self.ctx, "request context must be exactly {} bytes", self.ctx);
        let slot = Arc::new(Slot::default());
        // audit: allow(determinism-lint) submit timestamp is latency metadata only — never reaches response bits
        let entry = Entry { ctx, slot: Arc::clone(&slot), submitted: Instant::now() };
        let mut st = self.shared.mu.lock().unwrap_or_else(|p| p.into_inner());
        let id = st.auto_next;
        st.auto_next += 1;
        // The cursor only ever advances past inserted ids, and auto ids
        // are dense from 0, so `id >= st.next_id` holds by construction.
        let prev = st.pending.insert(id, entry);
        debug_assert!(prev.is_none(), "auto ids are unique by construction");
        drop(st);
        self.shared.cv.notify_all();
        Ticket { slot }
    }

    /// Close the current window early: serve everything queued *at the
    /// moment of the call* (partial tiles allowed), then resume fixed
    /// windowing — later arrivals coalesce normally instead of degrading
    /// to partial tiles under sustained load. Changes batching only —
    /// responses are batching-invariant.
    pub fn flush(&self) {
        let mut st = self.shared.mu.lock().unwrap_or_else(|p| p.into_inner());
        let last = st.pending.keys().next_back().copied();
        if let Some(last) = last {
            let until = last + 1;
            st.flush_until = Some(st.flush_until.map_or(until, |u| u.max(until)));
            drop(st);
            self.shared.cv.notify_all();
        }
    }
}

/// Join side of a serving session.
pub struct ServerSession {
    join: std::thread::JoinHandle<ServeStats>,
    shared: Arc<Shared>,
}

impl ServerSession {
    /// Drain every queued request (all outstanding tickets get served),
    /// stop the serve thread, and return the session's memtrack evidence.
    pub fn shutdown(self) -> ServeStats {
        {
            let mut st = self.shared.mu.lock().unwrap_or_else(|p| p.into_inner());
            st.stop = true;
        }
        self.shared.cv.notify_all();
        self.join.join().expect("serve thread panicked")
    }
}

/// Start a serving session. The `build` closure runs **on the serve
/// thread** so every tracked tensor (model + arena) is allocated and
/// freed on the thread-local tracker that also observes the serving loop
/// — the thread's memtrack numbers are the whole session's story.
pub fn spawn_session<F>(
    build: F,
    window: usize,
) -> Result<(ServerHandle, ServerSession), ServeError>
where
    F: FnOnce() -> SpectralStack + Send + 'static,
{
    let shared = Arc::new(Shared {
        mu: Mutex::new(State {
            pending: BTreeMap::new(),
            next_id: 0,
            auto_next: 0,
            flush_until: None,
            stop: false,
        }),
        cv: Condvar::new(),
    });
    let loop_shared = Arc::clone(&shared);
    let (ready_tx, ready_rx) = mpsc::channel::<Result<usize, ServeError>>();
    let join = std::thread::spawn(move || {
        let stack = build();
        match SpectralServer::new(stack, window) {
            Ok(server) => {
                let _ = ready_tx.send(Ok(server.ctx()));
                serve_loop(server, &loop_shared)
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                ServeStats::default()
            }
        }
    });
    match ready_rx.recv().expect("serve thread died before reporting readiness") {
        Ok(ctx) => {
            let handle = ServerHandle { shared, ctx };
            Ok((handle, ServerSession { join, shared: Arc::clone(&handle.shared) }))
        }
        Err(e) => {
            let _ = join.join();
            Err(e)
        }
    }
}

/// The serve thread: admit windows strictly in id order, serve each as
/// one tile, fill the waiters' slots. Exits when stopped and drained.
/// The steady-state body reuses the three session vectors and pops the
/// reorder buffer in place — no per-window tracked or untracked
/// allocation (the static twin of `steady_state_allocs == 0`).
// audit: no_alloc
fn serve_loop(mut server: SpectralServer, shared: &Shared) -> ServeStats {
    let w = server.window();
    let mut served = 0u64;
    let mut windows = 0u64;
    // alloc_count after the warmup window; everything past it is
    // steady-state and must allocate nothing tracked.
    let mut baseline: Option<usize> = None;
    // One-time session setup, before the first window is admitted:
    let mut reqs: Vec<ServeRequest> = Vec::with_capacity(w); // audit: allow(no-alloc-in-hot-path) one-time session buffer, reused per window
    // audit: allow(determinism-lint) submit timestamps ride along as latency metadata only
    let mut slots: Vec<(Arc<Slot>, Instant)> = Vec::with_capacity(w); // audit: allow(no-alloc-in-hot-path) one-time session buffer, reused per window
    let mut out: Vec<ServeResponse> = Vec::with_capacity(w); // audit: allow(no-alloc-in-hot-path) one-time session buffer, reused per window
    loop {
        reqs.clear();
        slots.clear();
        out.clear();
        {
            let mut st = shared.mu.lock().unwrap_or_else(|p| p.into_inner());
            loop {
                if !st.pending.is_empty() {
                    // A flush covers only the ids pending when it was
                    // requested; once the cursor passes them, resume
                    // fixed windowing instead of serving partial tiles
                    // indefinitely under sustained load.
                    if let Some(until) = st.flush_until {
                        if st.next_id >= until {
                            st.flush_until = None;
                        }
                    }
                    let base = st.next_id;
                    let complete =
                        (base..base + w as u64).all(|id| st.pending.contains_key(&id));
                    if complete || st.flush_until.is_some() || st.stop {
                        // Complete windows are exactly ids base..base+w;
                        // flush/stop admit the smallest ≤ w pending ids
                        // (a contiguous prefix whenever ids are dense).
                        // Popping the reorder buffer front in place keeps
                        // window admission allocation-free — no per-tile
                        // id list (PR 8 no_alloc finding).
                        while reqs.len() < w {
                            let Some((id, e)) = st.pending.pop_first() else { break };
                            reqs.push(ServeRequest { id, ctx: e.ctx });
                            slots.push((e.slot, e.submitted));
                            st.next_id = st.next_id.max(id + 1);
                        }
                        break;
                    }
                } else {
                    st.flush_until = None;
                    if st.stop {
                        drop(st);
                        let snap = memtrack::snapshot();
                        return ServeStats {
                            served,
                            windows,
                            steady_state_allocs: baseline
                                .map(|b| snap.alloc_count - b)
                                .unwrap_or(0),
                            serve_bytes: server.arena_tracked_bytes(),
                            peak_serve_bytes: snap.peak_by_cat[Category::Serve.index()],
                        };
                    }
                }
                st = shared.cv.wait(st).unwrap_or_else(|p| p.into_inner());
            }
        }
        server.serve_window(&reqs, &mut out);
        windows += 1;
        served += reqs.len() as u64;
        if windows == 1 {
            baseline = Some(memtrack::snapshot().alloc_count);
        }
        for (resp, (slot, t0)) in out.iter().zip(slots.iter()) {
            let latency_ns = t0.elapsed().as_nanos() as u64;
            let mut g = slot.resp.lock().unwrap_or_else(|p| p.into_inner());
            *g = Some((*resp, latency_ns));
            drop(g);
            slot.cv.notify_all();
        }
    }
}

// ---------------------------------------------------------------------
// Local socket leg: a line protocol over TCP for `repro serve`.
//
//   client → server   one request per line: 2·ctx hex chars of context
//   client → server   empty line: flush + answer everything sent so far
//   client → server   "quit": close the connection
//   server → client   "OK <next_byte> <fingerprint:016x> <latency_ns>"
//                     (one per request, in submission order), or
//                     "ERR <reason>" immediately for a malformed line.
//
// Pipelining several request lines before the blank line is what lets a
// *single* client fill a coalescing window; concurrent connections
// coalesce into shared tiles automatically. Socket ids follow admission
// order (`submit_next`), so batching composition depends on arrival —
// responses still don't, per the module determinism contract.
// ---------------------------------------------------------------------

/// Parse a request line: exactly `2*ctx` hex characters.
fn parse_hex_ctx(s: &str, ctx: usize) -> Result<Vec<u8>, String> {
    if s.len() != 2 * ctx {
        return Err(format!("expected {} hex chars (ctx={ctx}), got {}", 2 * ctx, s.len()));
    }
    let bytes = s.as_bytes();
    let nib = |c: u8| -> Result<u8, String> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(format!("invalid hex char {:?}", c as char)),
        }
    };
    let mut out = Vec::with_capacity(ctx);
    for pair in bytes.chunks_exact(2) {
        out.push(nib(pair[0])? << 4 | nib(pair[1])?);
    }
    Ok(out)
}

/// Serve one client connection (one thread per connection).
pub fn handle_connection(stream: TcpStream, handle: ServerHandle) -> std::io::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let ctx = handle.ctx();
    let mut tickets: Vec<Ticket> = Vec::new();
    let mut quit = false;
    for line in reader.lines() {
        let line = line?;
        let t = line.trim();
        if t == "quit" {
            quit = true;
            break;
        }
        if t.is_empty() {
            handle.flush();
            for tk in tickets.drain(..) {
                let (r, latency_ns) = tk.wait();
                writeln!(writer, "OK {} {:016x} {latency_ns}", r.next_byte, r.fingerprint)?;
            }
            writer.flush()?;
            continue;
        }
        match parse_hex_ctx(t, ctx) {
            Ok(bytes) => tickets.push(handle.submit_next(bytes)),
            Err(msg) => {
                writeln!(writer, "ERR {msg}")?;
                writer.flush()?;
            }
        }
    }
    if !quit && !tickets.is_empty() {
        // EOF with unanswered pipelined requests: answer them anyway.
        handle.flush();
        for tk in tickets.drain(..) {
            let (r, latency_ns) = tk.wait();
            writeln!(writer, "OK {} {:016x} {latency_ns}", r.next_byte, r.fingerprint)?;
        }
        writer.flush()?;
    }
    Ok(())
}

/// Accept loop for `repro serve`: one handler thread per connection, all
/// feeding the same session (concurrent connections coalesce). Runs until
/// the listener errors (i.e. effectively forever under the CLI).
pub fn serve_tcp(listener: TcpListener, handle: ServerHandle) -> std::io::Result<()> {
    for stream in listener.incoming() {
        let stream = stream?;
        let h = handle.clone();
        // audit: allow(no-raw-threads) connection handlers only parse lines and park on tickets; all compute stays on the serve thread's ExecCtx
        std::thread::spawn(move || {
            let _ = handle_connection(stream, h);
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_distinguishes_bit_patterns() {
        // -0.0 and 0.0 compare equal as floats but are different bits —
        // the fingerprint is a *bit* identity witness, so it must differ.
        assert_ne!(fingerprint_f32(&[0.0]), fingerprint_f32(&[-0.0]));
        assert_eq!(fingerprint_f32(&[1.5, -2.25]), fingerprint_f32(&[1.5, -2.25]));
        assert_ne!(fingerprint_f32(&[1.5, -2.25]), fingerprint_f32(&[-2.25, 1.5]));
    }

    #[test]
    fn hex_parsing_round_trips_and_rejects_junk() {
        assert_eq!(parse_hex_ctx("00ff10Ab", 4).unwrap(), vec![0x00, 0xff, 0x10, 0xab]);
        assert!(parse_hex_ctx("00ff", 4).is_err(), "wrong length");
        assert!(parse_hex_ctx("00fg10ab", 4).is_err(), "bad nibble");
    }
}
