//! Typed stub for the PJRT/XLA bindings.
//!
//! The offline build environment has no XLA runtime to link against, so
//! this module provides the exact API surface `runtime/mod.rs` consumes.
//! [`Literal`] is a real in-memory implementation (the manifest/param
//! loaders and their failure-injection tests exercise it for real);
//! everything that would need a native PJRT client reports a clean
//! "runtime unavailable" error instead of loading garbage. Swapping in
//! real bindings means deleting this file and pointing the `use … as xla`
//! alias at the actual crate — no other code changes.

use std::borrow::Borrow;
use std::path::Path;

/// Errors from the stubbed XLA layer (rendered with `{:?}` by callers,
/// matching the real bindings' error style).
#[derive(Debug, Clone)]
pub enum XlaError {
    /// The native PJRT runtime is not linked into this build.
    Unavailable(&'static str),
    /// Shape/type mismatch in a literal operation.
    Invalid(String),
}

const NO_RUNTIME: &str =
    "PJRT/XLA native runtime is not linked into this offline build; \
     the pure-Rust rdfft paths (everything outside `runtime`) are unaffected";

/// Element types a [`Literal`] can hold.
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Scalar types storable in a [`Literal`].
pub trait NativeType: Sized + Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Result<Vec<Self>, XlaError>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Result<Vec<Self>, XlaError> {
        match d {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(XlaError::Invalid("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Result<Vec<Self>, XlaError> {
        match d {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(XlaError::Invalid("literal is not i32".into())),
        }
    }
}

/// A host-side tensor literal (fully functional in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Reshape; the element count must be preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(XlaError::Invalid(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        T::unwrap(&self.data)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T, XlaError> {
        T::unwrap(&self.data)?
            .first()
            .copied()
            .ok_or_else(|| XlaError::Invalid("empty literal".into()))
    }

    /// Destructure a tuple literal. The stub never produces tuples
    /// (execution is unavailable), so this always errors.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(XlaError::Unavailable(NO_RUNTIME))
    }
}

/// Parsed HLO module text (held verbatim; compilation is unavailable).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self, XlaError> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| XlaError::Invalid(format!("reading hlo text: {e}")))?;
        if !text.trim_start().starts_with("HloModule") {
            return Err(XlaError::Invalid("not an HloModule text file".into()));
        }
        Ok(HloModuleProto { text })
    }
}

/// An HLO computation wrapper.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle (unreachable in the stub: no client can be
/// constructed, so no execution can produce one).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::Unavailable(NO_RUNTIME))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::Unavailable(NO_RUNTIME))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] fails cleanly in the stub, so
/// `Runtime::load` errors out before any garbage state can be built.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError::Unavailable(NO_RUNTIME))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::Unavailable(NO_RUNTIME))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_f32_and_i32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        let i = Literal::vec1(&[7i32, 8]);
        assert_eq!(i.get_first_element::<i32>().unwrap(), 7);
        assert!(i.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }
}
