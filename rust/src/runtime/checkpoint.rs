//! Crash-safe training checkpoints.
//!
//! A checkpoint is the *complete* native-trainer state — every trainable
//! parameter (via `Layer::for_each_param`, canonical time domain),
//! the optimizer bank's per-tensor step counters and moment buffers, the
//! batcher's RNG cursor, the step number, and a config fingerprint — in
//! one self-validating file:
//!
//! ```text
//! RDFFTCKPT1\n                      magic
//! <u64 LE>                          header length in bytes
//! {...single-line JSON header...}   parsed by runtime::json
//! <params f32 LE><m f32 LE><v f32 LE>   payload sections
//! ```
//!
//! The header records per-section lengths and FNV-1a-64 checksums, the
//! RNG state and optimizer step counters as hex strings (JSON numbers are
//! f64 and cannot carry every u64 exactly), and the fingerprint of the
//! trajectory-affecting config. Writes are atomic (temp file → fsync →
//! rename → directory fsync) so a crash at any instant leaves either the
//! previous checkpoint set or the new one — never a torn file under a
//! checkpoint name. Loads validate everything and return typed
//! [`CheckpointError`]s; [`latest_valid`] scans a directory newest-first,
//! skipping corrupt/truncated files (with notices) and hard-failing only
//! on a fingerprint mismatch — a *valid* checkpoint from a *different*
//! run config must never be silently resumed.
//!
//! Thread count is deliberately **not** part of the fingerprint: the
//! sharded step is bit-identical at any lane count, so resuming a
//! `--threads 4` run with `--threads 1` is exact.

use super::faultinject::FaultPlan;
use super::json::{self, Json};
use crate::memtrack::{Category, Registration};
use std::fmt;
use std::io::Write as _;
use std::path::{Path, PathBuf};

const MAGIC: &[u8] = b"RDFFTCKPT1\n";
const VERSION: usize = 1;

/// Typed checkpoint failure, with enough context to act on.
#[derive(Debug)]
pub enum CheckpointError {
    /// Underlying I/O failure.
    Io { path: PathBuf, err: String },
    /// File shorter than its own declared layout.
    Truncated { path: PathBuf, needed: usize, got: usize },
    /// Not a checkpoint file at all.
    BadMagic { path: PathBuf },
    /// Structurally invalid header (byte offset is file-absolute).
    BadHeader { path: PathBuf, offset: usize, msg: String },
    /// A payload section's checksum does not match its header record.
    ChecksumMismatch { path: PathBuf, section: &'static str },
    /// The checkpoint is valid but belongs to a different run config.
    FingerprintMismatch { path: PathBuf, expected: String, found: String },
    /// A fault-injection spec fired (tests/crashtest only).
    Injected(&'static str),
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io { path, err } => {
                write!(f, "{}: io error: {err}", path.display())
            }
            CheckpointError::Truncated { path, needed, got } => write!(
                f,
                "{}: truncated checkpoint ({got} bytes, layout needs {needed})",
                path.display()
            ),
            CheckpointError::BadMagic { path } => {
                write!(f, "{}: not a checkpoint file (bad magic)", path.display())
            }
            CheckpointError::BadHeader { path, offset, msg } => write!(
                f,
                "{}: invalid checkpoint header at byte {offset}: {msg}",
                path.display()
            ),
            CheckpointError::ChecksumMismatch { path, section } => write!(
                f,
                "{}: checksum mismatch in section {section:?} (corrupted file)",
                path.display()
            ),
            CheckpointError::FingerprintMismatch { path, expected, found } => write!(
                f,
                "{}: config fingerprint mismatch — checkpoint was written by a \
                 different run configuration\n  expected: {expected}\n  found:    {found}",
                path.display()
            ),
            CheckpointError::Injected(what) => {
                write!(f, "injected fault: {what}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

/// FNV-1a 64-bit — tiny, dependency-free, and plenty to catch torn or
/// bit-flipped files (this is corruption *detection*, not cryptography).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn hex64(v: u64) -> String {
    format!("{v:016x}")
}

fn from_hex64(s: &str) -> Option<u64> {
    if s.len() > 16 || s.is_empty() {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

/// Write `bytes` to `path` atomically: temp file in the same directory →
/// write → fsync → rename over the target → best-effort directory fsync.
/// A crash at any point leaves either the old file or the new one intact
/// (plus possibly a stale `.…tmp` the checkpoint scanner ignores).
pub fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("atomic-write");
    let tmp = dir.join(format!(".{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    // Make the rename itself durable (best-effort: not every platform
    // lets you fsync a directory handle).
    if let Ok(d) = std::fs::File::open(&dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Canonical checkpoint file name for a step.
pub fn checkpoint_path(dir: &Path, step: usize) -> PathBuf {
    dir.join(format!("ckpt-{step:08}.ckpt"))
}

/// `ckpt-NNNNNNNN.ckpt` files under `dir`, sorted ascending by step.
pub fn list_checkpoints(dir: &Path) -> Vec<(usize, PathBuf)> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    for e in entries.flatten() {
        let name = e.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(stem) = name.strip_prefix("ckpt-").and_then(|s| s.strip_suffix(".ckpt"))
        else {
            continue;
        };
        if let Ok(step) = stem.parse::<usize>() {
            out.push((step, e.path()));
        }
    }
    out.sort_by_key(|&(s, _)| s);
    out
}

/// A complete trainer snapshot (see the module docs for the file layout).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainCheckpoint {
    /// Training step the snapshot was taken *after* (1-based).
    pub step: usize,
    /// Canonical string of every trajectory-affecting config knob.
    pub fingerprint: String,
    /// Batcher RNG cursor (raw xorshift state).
    pub rng_state: u64,
    /// Per-tensor parameter lengths, `for_each_param` order.
    pub param_lens: Vec<usize>,
    /// All parameters, flattened in visit order (canonical time domain).
    pub params: Vec<f32>,
    /// Per-tensor optimizer step counters.
    pub optim_steps: Vec<u64>,
    /// First-moment buffers, flattened (empty for SGD).
    pub optim_m: Vec<f32>,
    /// Second-moment buffers, flattened (empty for SGD/momentum).
    pub optim_v: Vec<f32>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn floats_to_le(dst: &mut Vec<u8>, src: &[f32]) {
    for v in src {
        dst.extend_from_slice(&v.to_le_bytes());
    }
}

fn le_to_floats(src: &[u8]) -> Vec<f32> {
    src.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

impl TrainCheckpoint {
    /// Serialize to the on-disk byte layout. The staging buffer is
    /// tracked under `Category::Checkpoint` for the lifetime of the
    /// returned registration's scope (callers hold it across the write).
    pub fn to_bytes(&self) -> (Vec<u8>, Registration) {
        let mut params_b = Vec::with_capacity(self.params.len() * 4);
        floats_to_le(&mut params_b, &self.params);
        let mut m_b = Vec::with_capacity(self.optim_m.len() * 4);
        floats_to_le(&mut m_b, &self.optim_m);
        let mut v_b = Vec::with_capacity(self.optim_v.len() * 4);
        floats_to_le(&mut v_b, &self.optim_v);

        let lens: Vec<String> = self.param_lens.iter().map(|l| l.to_string()).collect();
        let osteps: Vec<String> =
            self.optim_steps.iter().map(|s| format!("\"{}\"", hex64(*s))).collect();
        let header = format!(
            concat!(
                "{{\"version\":{},\"step\":{},\"fingerprint\":\"{}\",",
                "\"rng\":\"{}\",\"param_lens\":[{}],\"optim_steps\":[{}],",
                "\"m_len\":{},\"v_len\":{},",
                "\"params_crc\":\"{}\",\"m_crc\":\"{}\",\"v_crc\":\"{}\"}}"
            ),
            VERSION,
            self.step,
            json_escape(&self.fingerprint),
            hex64(self.rng_state),
            lens.join(","),
            osteps.join(","),
            self.optim_m.len(),
            self.optim_v.len(),
            hex64(fnv1a(&params_b)),
            hex64(fnv1a(&m_b)),
            hex64(fnv1a(&v_b)),
        );

        let mut out = Vec::with_capacity(
            MAGIC.len() + 8 + header.len() + params_b.len() + m_b.len() + v_b.len(),
        );
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(header.len() as u64).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&params_b);
        out.extend_from_slice(&m_b);
        out.extend_from_slice(&v_b);
        let reg = Registration::new(out.capacity(), Category::Checkpoint);
        (out, reg)
    }

    /// Parse and validate an on-disk image. `path` is for error context
    /// only.
    pub fn from_bytes(path: &Path, bytes: &[u8]) -> Result<TrainCheckpoint, CheckpointError> {
        let p = || path.to_path_buf();
        if bytes.len() < MAGIC.len() {
            return Err(CheckpointError::Truncated {
                path: p(),
                needed: MAGIC.len(),
                got: bytes.len(),
            });
        }
        if &bytes[..MAGIC.len()] != MAGIC {
            return Err(CheckpointError::BadMagic { path: p() });
        }
        let hdr_off = MAGIC.len() + 8;
        if bytes.len() < hdr_off {
            return Err(CheckpointError::Truncated {
                path: p(),
                needed: hdr_off,
                got: bytes.len(),
            });
        }
        let mut len8 = [0u8; 8];
        len8.copy_from_slice(&bytes[MAGIC.len()..hdr_off]);
        let hdr_len = u64::from_le_bytes(len8);
        // Explicit bounds check BEFORE any slicing: a corrupt length must
        // be a typed error, not a panic.
        let hdr_len = usize::try_from(hdr_len).unwrap_or(usize::MAX);
        if hdr_len > bytes.len().saturating_sub(hdr_off) {
            return Err(CheckpointError::Truncated {
                path: p(),
                needed: hdr_off.saturating_add(hdr_len),
                got: bytes.len(),
            });
        }
        let hdr_bytes = &bytes[hdr_off..hdr_off + hdr_len];
        let hdr_str = std::str::from_utf8(hdr_bytes).map_err(|e| {
            CheckpointError::BadHeader {
                path: p(),
                offset: hdr_off + e.valid_up_to(),
                msg: "header is not UTF-8".to_string(),
            }
        })?;
        let hdr = json::parse(hdr_str).map_err(|e| CheckpointError::BadHeader {
            path: p(),
            offset: hdr_off + e.pos,
            msg: e.msg.clone(),
        })?;
        let bad = |msg: &str| CheckpointError::BadHeader {
            path: path.to_path_buf(),
            offset: hdr_off,
            msg: msg.to_string(),
        };

        let version = hdr
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("missing/invalid \"version\""))?;
        if version != VERSION {
            return Err(bad(&format!("unsupported version {version} (want {VERSION})")));
        }
        let step = hdr
            .get("step")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("missing/invalid \"step\""))?;
        let fingerprint = hdr
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing \"fingerprint\""))?
            .to_string();
        let rng_state = hdr
            .get("rng")
            .and_then(Json::as_str)
            .and_then(from_hex64)
            .ok_or_else(|| bad("missing/invalid \"rng\" (16-digit hex)"))?;
        let param_lens: Vec<usize> = hdr
            .get("param_lens")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing \"param_lens\""))?
            .iter()
            .map(|j| j.as_usize())
            .collect::<Option<Vec<usize>>>()
            .ok_or_else(|| bad("non-integer entry in \"param_lens\""))?;
        let optim_steps: Vec<u64> = hdr
            .get("optim_steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing \"optim_steps\""))?
            .iter()
            .map(|j| j.as_str().and_then(from_hex64))
            .collect::<Option<Vec<u64>>>()
            .ok_or_else(|| bad("non-hex entry in \"optim_steps\""))?;
        let m_len = hdr
            .get("m_len")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("missing/invalid \"m_len\""))?;
        let v_len = hdr
            .get("v_len")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("missing/invalid \"v_len\""))?;
        let crc_of = |key: &'static str| -> Result<u64, CheckpointError> {
            hdr.get(key)
                .and_then(Json::as_str)
                .and_then(from_hex64)
                .ok_or_else(|| bad(&format!("missing/invalid {key:?}")))
        };
        let params_crc = crc_of("params_crc")?;
        let m_crc = crc_of("m_crc")?;
        let v_crc = crc_of("v_crc")?;

        let n_params: usize = param_lens.iter().sum();
        // Overflow-safe payload layout check.
        let payload_floats = n_params
            .checked_add(m_len)
            .and_then(|t| t.checked_add(v_len))
            .ok_or_else(|| bad("section lengths overflow"))?;
        let payload_bytes = payload_floats
            .checked_mul(4)
            .ok_or_else(|| bad("section lengths overflow"))?;
        let payload_off = hdr_off + hdr_len;
        let got = bytes.len() - payload_off;
        if got < payload_bytes {
            return Err(CheckpointError::Truncated {
                path: p(),
                needed: payload_off + payload_bytes,
                got: bytes.len(),
            });
        }
        if got > payload_bytes {
            return Err(bad(&format!(
                "{} trailing payload bytes beyond the declared sections",
                got - payload_bytes
            )));
        }
        let payload = &bytes[payload_off..];
        let (params_b, rest) = payload.split_at(n_params * 4);
        let (m_b, v_b) = rest.split_at(m_len * 4);
        for (section, data, want) in [
            ("params", params_b, params_crc),
            ("optim_m", m_b, m_crc),
            ("optim_v", v_b, v_crc),
        ] {
            if fnv1a(data) != want {
                return Err(CheckpointError::ChecksumMismatch { path: p(), section });
            }
        }
        Ok(TrainCheckpoint {
            step,
            fingerprint,
            rng_state,
            param_lens,
            params: le_to_floats(params_b),
            optim_steps,
            optim_m: le_to_floats(m_b),
            optim_v: le_to_floats(v_b),
        })
    }

    /// Load and validate one checkpoint file.
    pub fn load(path: &Path) -> Result<TrainCheckpoint, CheckpointError> {
        let bytes = std::fs::read(path).map_err(|e| CheckpointError::Io {
            path: path.to_path_buf(),
            err: e.to_string(),
        })?;
        // The read buffer is checkpoint I/O staging: account for it while
        // it lives so restore costs show up in the memory tables too.
        let _reg = Registration::new(bytes.len(), Category::Checkpoint);
        Self::from_bytes(path, &bytes)
    }

    /// Atomically write this checkpoint into `dir` (created on demand),
    /// then prune to the newest `keep` files. `faults` can tear the write
    /// (abort mid-temp-file) or fail it outright — the deterministic
    /// crashes the crashtest drives.
    pub fn save(
        &self,
        dir: &Path,
        keep: usize,
        faults: &FaultPlan,
    ) -> Result<PathBuf, CheckpointError> {
        let io = |e: std::io::Error| CheckpointError::Io {
            path: dir.to_path_buf(),
            err: e.to_string(),
        };
        std::fs::create_dir_all(dir).map_err(io)?;
        if faults.take_io_fail(self.step) {
            return Err(CheckpointError::Injected("checkpoint write io failure"));
        }
        let (bytes, _reg) = self.to_bytes();
        let path = checkpoint_path(dir, self.step);
        if faults.take_torn_write(self.step) {
            // The crash the atomic protocol exists for: half the image in
            // the temp file, then sudden death. The rename never happens,
            // so no checkpoint name ever points at this torn image.
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("ckpt");
            let tmp = dir.join(format!(".{name}.tmp"));
            if let Ok(mut f) = std::fs::File::create(&tmp) {
                let _ = f.write_all(&bytes[..bytes.len() / 2]);
                let _ = f.sync_all();
            }
            eprintln!(
                "[faultinject] torn-write: aborting mid-checkpoint-write at step {}",
                self.step
            );
            std::process::abort();
        }
        atomic_write(&path, &bytes).map_err(|e| CheckpointError::Io {
            path: path.clone(),
            err: e.to_string(),
        })?;
        prune(dir, keep);
        Ok(path)
    }
}

/// Delete all but the newest `keep` checkpoints (best-effort; `keep` is
/// clamped to at least 1 so retention can never delete the file just
/// written).
pub fn prune(dir: &Path, keep: usize) {
    let files = list_checkpoints(dir);
    let keep = keep.max(1);
    if files.len() <= keep {
        return;
    }
    for (_, path) in &files[..files.len() - keep] {
        let _ = std::fs::remove_file(path);
    }
}

/// Find the newest usable checkpoint in `dir`. Corrupt, truncated, or
/// unparseable files are *skipped* (with a notice per skip) and the scan
/// falls back to the next-newest — but a structurally valid checkpoint
/// whose fingerprint does not match is a hard error: silently resuming
/// the wrong run would corrupt the trajectory it claims to continue.
/// `Ok(None)` = nothing to resume (missing dir, empty dir, or every file
/// invalid).
pub fn latest_valid(
    dir: &Path,
    expected_fingerprint: &str,
) -> Result<Option<(TrainCheckpoint, Vec<String>)>, CheckpointError> {
    let mut notices = Vec::new();
    let mut files = list_checkpoints(dir);
    files.reverse();
    for (_, path) in files {
        match TrainCheckpoint::load(&path) {
            Ok(ck) => {
                if ck.fingerprint != expected_fingerprint {
                    return Err(CheckpointError::FingerprintMismatch {
                        path,
                        expected: expected_fingerprint.to_string(),
                        found: ck.fingerprint,
                    });
                }
                return Ok(Some((ck, notices)));
            }
            Err(e) => notices.push(format!("skipping {}: {e}", path.display())),
        }
    }
    // Nothing valid. Surface the skip notices so "no resume" is
    // explainable, but it is not an error: fresh start.
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memtrack;

    fn sample(step: usize) -> TrainCheckpoint {
        TrainCheckpoint {
            step,
            fingerprint: "v1;d=32;test".to_string(),
            rng_state: 0xDEADBEEF12345678,
            param_lens: vec![4, 2],
            params: vec![1.0, -2.5, 3.25, 0.0, 7.5, -0.125],
            optim_steps: vec![u64::MAX, 3],
            optim_m: vec![0.5; 6],
            optim_v: vec![0.25; 6],
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("rdfft_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrips_bit_exactly_including_u64_state() {
        let ck = sample(42);
        let (bytes, _reg) = ck.to_bytes();
        let back = TrainCheckpoint::from_bytes(Path::new("mem"), &bytes).unwrap();
        assert_eq!(back, ck);
        // u64::MAX is not representable as f64 — the hex encoding is what
        // keeps it exact
        assert_eq!(back.optim_steps[0], u64::MAX);
    }

    #[test]
    fn serialization_buffer_is_tracked_under_checkpoint_category() {
        memtrack::reset();
        let ck = sample(1);
        {
            let (bytes, _reg) = ck.to_bytes();
            let snap = memtrack::snapshot();
            assert!(
                snap.current[Category::Checkpoint.index()] >= bytes.len(),
                "staging buffer must be visible under the checkpoint category"
            );
        }
        assert_eq!(memtrack::snapshot().current[Category::Checkpoint.index()], 0);
    }

    #[test]
    fn detects_truncation_at_every_layer() {
        let (bytes, _reg) = sample(7).to_bytes();
        for cut in [3usize, MAGIC.len() + 4, MAGIC.len() + 20, bytes.len() - 5] {
            let err = TrainCheckpoint::from_bytes(Path::new("t"), &bytes[..cut])
                .expect_err("truncated image must not parse");
            match err {
                CheckpointError::Truncated { .. } | CheckpointError::BadHeader { .. } => {}
                other => panic!("cut={cut}: wrong error {other}"),
            }
        }
    }

    #[test]
    fn detects_bit_flips_via_section_checksums() {
        let (bytes, _reg) = sample(7).to_bytes();
        // flip one bit in the params payload (last 10 bytes are optim_v;
        // aim at the middle of the file, inside params)
        let mut corrupt = bytes.clone();
        let idx = bytes.len() - sample(7).optim_v.len() * 4 - sample(7).optim_m.len() * 4 - 2;
        corrupt[idx] ^= 0x10;
        let err = TrainCheckpoint::from_bytes(Path::new("t"), &corrupt).unwrap_err();
        assert!(
            matches!(err, CheckpointError::ChecksumMismatch { section: "params", .. }),
            "{err}"
        );
        // garbage magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(matches!(
            TrainCheckpoint::from_bytes(Path::new("t"), &bad).unwrap_err(),
            CheckpointError::BadMagic { .. }
        ));
    }

    #[test]
    fn atomic_write_leaves_no_temp_and_save_prunes() {
        let dir = tmpdir("retention");
        let plan = FaultPlan::none();
        for step in [2usize, 4, 6, 8] {
            sample(step).save(&dir, 2, &plan).unwrap();
        }
        let files = list_checkpoints(&dir);
        let steps: Vec<usize> = files.iter().map(|&(s, _)| s).collect();
        assert_eq!(steps, vec![6, 8], "keep-2 retention");
        // no stray temp files
        let strays: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(strays.is_empty(), "temp files left behind: {strays:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn latest_valid_skips_corruption_and_rejects_foreign_fingerprints() {
        let dir = tmpdir("fallback");
        let plan = FaultPlan::none();
        sample(5).save(&dir, 10, &plan).unwrap();
        sample(10).save(&dir, 10, &plan).unwrap();
        // corrupt the newest in place
        let newest = checkpoint_path(&dir, 10);
        let mut bytes = std::fs::read(&newest).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0x40;
        std::fs::write(&newest, &bytes).unwrap();
        // a torn temp file must also be ignored by the scan
        std::fs::write(dir.join(".ckpt-00000012.ckpt.tmp"), b"torn").unwrap();

        let (ck, notices) = latest_valid(&dir, "v1;d=32;test").unwrap().unwrap();
        assert_eq!(ck.step, 5, "must fall back past the corrupted newest");
        assert_eq!(notices.len(), 1, "one skip notice: {notices:?}");
        assert!(notices[0].contains("checksum"), "{notices:?}");

        // fingerprint mismatch on the newest valid file is a hard error
        let err = latest_valid(&dir, "some-other-config").unwrap_err();
        assert!(matches!(err, CheckpointError::FingerprintMismatch { .. }), "{err}");
        assert!(format!("{err}").contains("fingerprint"));

        // empty/missing dir: clean None
        assert!(latest_valid(Path::new("/nonexistent/rdfft"), "x").unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
