//! Persistent worker-pool runtime + the `ExecCtx` execution handle.
//!
//! Every threaded code path used to pay a fresh `std::thread::scope`
//! spawn per call — microseconds of kernel work per worker on every
//! batched transform, repeated thousands of times per training run. This
//! module replaces that with **one** set of parked OS threads per pool:
//! jobs are enqueued under a `Mutex` + `Condvar` (channel-free, no
//! external crates, mirroring the engine's no-dependency discipline),
//! workers park on the condvar between jobs, and a per-scope completion
//! latch gives the submitter the same borrows-stay-valid guarantee
//! `std::thread::scope` provides: [`WorkerPool::scope`] does not return
//! until every submitted job has finished, so jobs may borrow stack data.
//!
//! Design points, each with a lifecycle test below:
//!
//! * **Scoped submission.** [`Scope::submit`] accepts non-`'static`
//!   closures; the lifetime is erased internally ([`Scope`] is invariant
//!   in `'scope`, the rayon construction) and re-anchored by the latch
//!   wait in [`WorkerPool::scope`].
//! * **Panic isolation.** A panicking job poisons only itself: the worker
//!   catches the unwind, the latch still releases, and the scope surfaces
//!   the first payload as `Err(`[`JobPanic`]`)` — later jobs and later
//!   scopes are unaffected.
//! * **Nested submission runs inline.** A job that submits to a pool from
//!   a worker thread (e.g. an engine batch call inside a data-parallel
//!   trainer shard) executes the nested job on the spot instead of
//!   queueing it — queue-and-wait from inside a worker could deadlock
//!   once every worker waits on jobs only parked behind itself.
//! * **The submitter helps.** While waiting on the latch, the submitting
//!   thread drains jobs *of its own scope* from the queue, so a pool of
//!   `N-1` workers plus the submitter saturates `N` threads. Only
//!   own-scope jobs are stolen: running another thread's job here would
//!   credit its allocations to the wrong thread-local memory tracker.
//! * **Worker allocations stay visible.** `memtrack`'s tracker is
//!   thread-local, so allocations made inside pool jobs would silently
//!   vanish from the submitter's peak accounting. Workers capture their
//!   per-job tracker delta ([`crate::memtrack::take_job_delta`]); at
//!   scope end the collected deltas merge into the submitting thread
//!   ([`crate::memtrack::merge_worker_deltas`]), modeling at most the
//!   pool's worker count of them as concurrent — a worker runs its jobs
//!   sequentially, so stacking every job's peak would overstate the
//!   footprint when jobs outnumber lanes.
//! * **Graceful shutdown.** Dropping the pool flags shutdown, wakes every
//!   parked worker, and joins them. Scopes borrow the pool, so a drop
//!   can never race an active scope.
//!
//! [`ExecCtx`] is the lightweight handle threaded through the execution
//! layers (engine → layers → stack → trainer): a pool reference, the
//! engine tuning ([`EngineConfig`]), and the memtrack category scratch
//! buffers should be charged to. Cloning is cheap (one `Arc` bump).

use super::faultinject::FaultPlan;
use crate::memtrack::{self, Category};
use crate::rdfft::engine::EngineConfig;
use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::AssertUnwindSafe;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

thread_local! {
    /// True on threads spawned by any [`WorkerPool`]; submissions from
    /// such threads run inline (see the module docs on nesting).
    static IS_POOL_WORKER: Cell<bool> = Cell::new(false);
}

/// A queued unit of work: the type-erased job plus the latch of the scope
/// that submitted it.
struct Job {
    run: Box<dyn FnOnce() + Send + 'static>,
    latch: Arc<ScopeLatch>,
}

/// Queue state guarded by the pool mutex.
struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// State shared between the pool handle and its workers.
struct Shared {
    queue: Mutex<Queue>,
    /// Workers park here between jobs; `push` wakes one.
    work_cv: Condvar,
}

/// Non-poisoning lock: a panic inside a *job* is caught before any pool
/// lock is held, but tests inject panics liberally — recover like the
/// plan cache does instead of cascading.
fn lock_queue(shared: &Shared) -> MutexGuard<'_, Queue> {
    shared.queue.lock().unwrap_or_else(|e| e.into_inner())
}

impl Shared {
    fn push(&self, job: Job) {
        lock_queue(self).jobs.push_back(job);
        self.work_cv.notify_one();
    }

    /// Remove and return one queued job belonging to `latch`'s scope (the
    /// submitter's self-help path). `None` when none of ours is queued.
    fn try_pop_for(&self, latch: &Arc<ScopeLatch>) -> Option<Job> {
        let mut q = lock_queue(self);
        let idx = q.jobs.iter().position(|j| Arc::ptr_eq(&j.latch, latch))?;
        q.jobs.remove(idx)
    }
}

/// Per-scope completion latch: counts outstanding jobs, collects the
/// workers' per-job memtrack deltas, and records the first panic payload.
struct ScopeLatch {
    state: Mutex<LatchState>,
    done_cv: Condvar,
}

struct LatchState {
    pending: usize,
    /// One delta per job that ran on a worker (kept individually so the
    /// scope-end merge can model at most the pool's lane count of them
    /// as concurrent instead of stacking sequential jobs' peaks).
    deltas: Vec<memtrack::WorkerDelta>,
    payload: Option<Box<dyn Any + Send>>,
    failed: usize,
}

impl ScopeLatch {
    fn new() -> Arc<ScopeLatch> {
        Arc::new(ScopeLatch {
            state: Mutex::new(LatchState {
                pending: 0,
                deltas: Vec::new(),
                payload: None,
                failed: 0,
            }),
            done_cv: Condvar::new(),
        })
    }

    fn lock(&self) -> MutexGuard<'_, LatchState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn add_pending(&self) {
        self.lock().pending += 1;
    }

    /// One job finished (`delta` is `Some` when it ran on a worker whose
    /// thread-local tracker captured it; inline/helped jobs tracked
    /// directly on the submitting thread pass `None`).
    fn complete(
        &self,
        delta: Option<memtrack::WorkerDelta>,
        panic: Option<Box<dyn Any + Send>>,
    ) {
        let mut s = self.lock();
        if let Some(d) = delta {
            if !d.is_empty() {
                s.deltas.push(d);
            }
        }
        if let Some(p) = panic {
            s.failed += 1;
            if s.payload.is_none() {
                s.payload = Some(p);
            }
        }
        s.pending -= 1;
        if s.pending == 0 {
            self.done_cv.notify_all();
        }
    }

    /// Record a panic from a job that ran inline (never counted pending).
    fn record_panic(&self, p: Box<dyn Any + Send>) {
        let mut s = self.lock();
        s.failed += 1;
        if s.payload.is_none() {
            s.payload = Some(p);
        }
    }
}

/// Error of a scope in which at least one job panicked. The scope itself
/// completed — every job ran to completion or unwound, the latch
/// released, and the pool stays healthy — so callers can choose between
/// handling the failure and re-raising it ([`JobPanic::resume`]).
pub struct JobPanic {
    /// How many jobs of the scope panicked.
    pub failed: usize,
    payload: Box<dyn Any + Send>,
}

impl JobPanic {
    /// Re-raise the first captured panic on the calling thread —
    /// `std::thread::scope`'s behaviour, used by the engine paths.
    pub fn resume(self) -> ! {
        std::panic::resume_unwind(self.payload)
    }

    /// Best-effort panic message (for logs/tests).
    pub fn message(&self) -> &str {
        if let Some(s) = self.payload.downcast_ref::<&'static str>() {
            s
        } else if let Some(s) = self.payload.downcast_ref::<String>() {
            s
        } else {
            "<non-string panic payload>"
        }
    }
}

impl std::fmt::Debug for JobPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JobPanic(failed={}, {:?})", self.failed, self.message())
    }
}

impl std::fmt::Display for JobPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} pool job(s) panicked: {}", self.failed, self.message())
    }
}

impl std::error::Error for JobPanic {}

/// A persistent pool of parked worker threads. See the module docs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `workers` parked OS threads. `workers == 0` is
    /// a valid serial pool: every submission runs inline on the
    /// submitting thread (the deterministic `--threads 1` baseline).
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rdfft-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// The process-wide default pool (`available_parallelism - 1` workers
    /// — the submitting thread is the final lane), built on first use.
    /// Never dropped; every default engine entry point dispatches here.
    pub fn global() -> &'static Arc<WorkerPool> {
        static GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let cores =
                std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
            Arc::new(WorkerPool::new(cores.saturating_sub(1)))
        })
    }

    /// Number of pool worker threads (the submitting thread adds one more
    /// lane of parallelism on top during a scope).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `op`, allowing it to submit borrowed jobs via the [`Scope`];
    /// returns only after every submitted job has completed. Worker-side
    /// memtrack deltas are merged into the calling thread before
    /// returning. `Err` when at least one job panicked (see
    /// [`JobPanic`]); a panic in `op` itself is re-raised after the latch
    /// wait (jobs never outlive their borrows, even on that path).
    pub fn scope<'scope, OP, R>(&'scope self, op: OP) -> Result<R, JobPanic>
    where
        OP: FnOnce(&Scope<'scope>) -> R + 'scope,
    {
        let scope =
            Scope { pool: self, latch: ScopeLatch::new(), _marker: PhantomData };
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| op(&scope)));
        let (deltas, failure) = self.finish_scope(&scope.latch);
        // At most `workers()` jobs can be live on workers at once; jobs
        // beyond that ran sequentially, so their peaks must not stack.
        memtrack::merge_worker_deltas(&deltas, self.workers());
        let value = match result {
            Ok(v) => v,
            // `op` panicked: jobs it already submitted have been waited
            // for above, so the unwind is safe to continue.
            Err(p) => std::panic::resume_unwind(p),
        };
        match failure {
            None => Ok(value),
            Some((failed, payload)) => Err(JobPanic { failed, payload }),
        }
    }

    /// Wait for the scope's jobs, helping with our own queued jobs while
    /// waiting (see the module docs).
    fn finish_scope(
        &self,
        latch: &Arc<ScopeLatch>,
    ) -> (Vec<memtrack::WorkerDelta>, Option<(usize, Box<dyn Any + Send>)>) {
        loop {
            if let Some(job) = self.shared.try_pop_for(latch) {
                // Helped jobs run on the submitting thread: allocations
                // land in the right tracker directly, no delta needed.
                let r = std::panic::catch_unwind(AssertUnwindSafe(job.run));
                latch.complete(None, r.err());
                continue;
            }
            // None of our jobs is queued: the rest are running on workers
            // (submission is over, nested jobs run inline), so their
            // completions are guaranteed to notify `done_cv`.
            let mut s = latch.lock();
            if s.pending == 0 {
                let deltas = std::mem::take(&mut s.deltas);
                let failure = s.payload.take().map(|p| (s.failed, p));
                return (deltas, failure);
            }
            let _unused =
                latch.done_cv.wait(s).unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        lock_queue(&self.shared).shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WorkerPool(workers={})", self.workers())
    }
}

fn worker_loop(shared: Arc<Shared>) {
    IS_POOL_WORKER.with(|w| w.set(true));
    loop {
        let job = {
            let mut q = lock_queue(&shared);
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break Some(j);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.work_cv.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        let Some(job) = job else { return };
        // Fresh tracker per job: the delta below is exactly this job's
        // allocation activity. Jobs must not move tracked storage across
        // the job boundary (scoped borrows make that the natural shape).
        memtrack::reset();
        let result = std::panic::catch_unwind(AssertUnwindSafe(job.run));
        let delta = memtrack::take_job_delta();
        job.latch.complete(Some(delta), result.err());
    }
}

/// Submission handle passed to the closure of [`WorkerPool::scope`].
/// Invariant in `'scope` (the `PhantomData` below), so a submitted job
/// can never be assumed to live longer than the scope that waits on it.
pub struct Scope<'scope> {
    pool: &'scope WorkerPool,
    latch: Arc<ScopeLatch>,
    _marker: PhantomData<&'scope mut &'scope ()>,
}

impl<'scope> Scope<'scope> {
    /// Submit a job. May borrow anything alive for `'scope`; runs inline
    /// when the pool has no workers or when called from a pool worker
    /// (nested submission — see the module docs).
    pub fn submit<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        if self.pool.workers() == 0 || IS_POOL_WORKER.with(|w| w.get()) {
            if let Err(p) = std::panic::catch_unwind(AssertUnwindSafe(f)) {
                self.latch.record_panic(p);
            }
            return;
        }
        self.latch.add_pending();
        let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(f);
        // SAFETY: the only way this closure outlives `'scope` would be
        // `WorkerPool::scope` returning before the job completes, and
        // `finish_scope` waits for `pending == 0` on every path
        // (including a panicking `op`). `Scope` is invariant in `'scope`,
        // so callers cannot shrink the lifetime after submission.
        let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
        self.pool.shared.push(Job { run: job, latch: Arc::clone(&self.latch) });
    }
}

// ---------------------------------------------------------------------
// ExecCtx
// ---------------------------------------------------------------------

/// The execution-context handle threaded through engine → layers → stack
/// → trainer: which pool to dispatch on, how the engine should tune its
/// chunking, and which memtrack category scratch buffers belong to.
/// Cloning is one `Arc` bump; every layer of a model shares one context.
#[derive(Clone)]
pub struct ExecCtx {
    /// `None` = the process-wide pool, resolved lazily on first use —
    /// merely constructing layers/contexts must never spawn threads.
    pool: Option<Arc<WorkerPool>>,
    cfg: EngineConfig,
    cat: Category,
    /// Deterministic fault schedule (tests/crashtest); empty in normal
    /// runs, where every query is a cheap no-op.
    faults: Arc<FaultPlan>,
}

impl ExecCtx {
    /// The default context: the process-wide pool (created lazily, only
    /// when a call actually parallelizes), default engine tuning, scratch
    /// charged to `Intermediates`. This is what every ctx-less engine
    /// entry point resolves to.
    pub fn global() -> ExecCtx {
        ExecCtx {
            pool: None,
            cfg: EngineConfig::new(),
            cat: Category::Intermediates,
            faults: Arc::new(FaultPlan::none()),
        }
    }

    /// A context with its own pool targeting `threads` total lanes of
    /// parallelism: `threads - 1` pool workers plus the submitting thread
    /// (which helps while waiting). `threads <= 1` yields a serial pool —
    /// every job runs inline in submission order, the deterministic
    /// baseline the data-parallel trainer compares against.
    pub fn with_threads(threads: usize) -> ExecCtx {
        let t = threads.max(1);
        ExecCtx {
            pool: Some(Arc::new(WorkerPool::new(t - 1))),
            cfg: EngineConfig { max_threads: t, ..EngineConfig::new() },
            cat: Category::Intermediates,
            faults: Arc::new(FaultPlan::none()),
        }
    }

    /// Serial context: no workers, engine chunking disabled. The fully
    /// deterministic single-thread oracle.
    pub fn serial() -> ExecCtx {
        ExecCtx {
            pool: Some(Arc::new(WorkerPool::new(0))),
            cfg: EngineConfig::serial(),
            cat: Category::Intermediates,
            faults: Arc::new(FaultPlan::none()),
        }
    }

    /// Replace the engine tuning (builder style).
    pub fn with_engine_config(mut self, cfg: EngineConfig) -> ExecCtx {
        self.cfg = cfg;
        self
    }

    /// Replace the scratch category (builder style).
    pub fn with_category(mut self, cat: Category) -> ExecCtx {
        self.cat = cat;
        self
    }

    /// Attach a fault-injection schedule (builder style). Tests and the
    /// crashtest harness use this; production contexts keep the empty
    /// default plan.
    pub fn with_faults(mut self, faults: Arc<FaultPlan>) -> ExecCtx {
        self.faults = faults;
        self
    }

    /// The pool this context dispatches on. Resolving a global context
    /// materializes the process-wide pool; callers that only *might*
    /// parallelize should prefer [`ExecCtx::dedicated_pool`] and fall
    /// back lazily (as the engine does).
    pub fn pool(&self) -> &WorkerPool {
        match &self.pool {
            Some(p) => p.as_ref(),
            None => WorkerPool::global().as_ref(),
        }
    }

    /// The context's dedicated pool, or `None` for a global context —
    /// lets the engine defer process-wide pool creation until a call
    /// actually fans out.
    pub fn dedicated_pool(&self) -> Option<&WorkerPool> {
        self.pool.as_deref()
    }

    pub fn engine_config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Category for scratch storage allocated on behalf of this context
    /// (the data-parallel trainer's gradient-shard arena, for one).
    pub fn scratch_category(&self) -> Category {
        self.cat
    }

    /// Total parallel lanes this context targets (workers + submitter).
    /// Materializes the global pool for a global context.
    pub fn threads(&self) -> usize {
        self.pool().workers() + 1
    }

    /// The context's fault schedule (empty plan unless a test or the
    /// crashtest harness attached one via [`ExecCtx::with_faults`]).
    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }
}

impl std::fmt::Debug for ExecCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.pool {
            Some(p) => write!(
                f,
                "ExecCtx(threads={}, cat={}, cfg={:?})",
                p.workers() + 1,
                self.cat.name(),
                self.cfg
            ),
            None => write!(f, "ExecCtx(global, cat={}, cfg={:?})", self.cat.name(), self.cfg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memtrack::{self, Category, TrackedVec};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_borrowed_jobs_to_completion() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u32; 64];
        let chunks: Vec<&mut [u32]> = data.chunks_mut(16).collect();
        pool.scope(|sc| {
            for (i, chunk) in chunks.into_iter().enumerate() {
                sc.submit(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 16 + j) as u32;
                    }
                });
            }
        })
        .expect("no job panics");
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32);
        }
    }

    #[test]
    fn drop_while_idle_joins_cleanly() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.workers(), 4);
        drop(pool); // must not hang or panic
        // ... and a used pool also shuts down cleanly
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        pool.scope(|sc| {
            for _ in 0..8 {
                sc.submit(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
        drop(pool);
    }

    #[test]
    fn panicking_job_poisons_only_itself() {
        let pool = WorkerPool::new(2);
        let ran = AtomicUsize::new(0);
        let err = pool
            .scope(|sc| {
                sc.submit(|| panic!("injected job panic"));
                for _ in 0..4 {
                    sc.submit(|| {
                        ran.fetch_add(1, Ordering::SeqCst);
                    });
                }
            })
            .expect_err("one job panicked");
        assert_eq!(err.failed, 1);
        assert!(err.message().contains("injected job panic"), "{err:?}");
        // the latch released (we got here) and the healthy jobs all ran
        assert_eq!(ran.load(Ordering::SeqCst), 4);
        // the pool is still fully usable afterwards
        let again = AtomicUsize::new(0);
        pool.scope(|sc| {
            for _ in 0..3 {
                sc.submit(|| {
                    again.fetch_add(1, Ordering::SeqCst);
                });
            }
        })
        .unwrap();
        assert_eq!(again.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn nested_submission_from_a_worker_runs_inline_without_deadlock() {
        let pool = Arc::new(WorkerPool::new(1)); // one worker: a queued
        // nested job could never run if nesting queued instead of inlining
        let hits = AtomicUsize::new(0);
        let p2 = Arc::clone(&pool);
        pool.scope(|sc| {
            sc.submit(|| {
                // runs on the single worker; nested scope must inline
                p2.scope(|inner| {
                    for _ in 0..4 {
                        inner.submit(|| {
                            hits.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                })
                .unwrap();
            });
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn serial_pool_runs_everything_inline_in_submission_order() {
        let pool = WorkerPool::new(0);
        let order = Mutex::new(Vec::new());
        pool.scope(|sc| {
            for i in 0..5 {
                let o = &order;
                sc.submit(move || o.lock().unwrap_or_else(|p| p.into_inner()).push(i));
            }
        })
        .unwrap();
        assert_eq!(order.into_inner().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn worker_job_allocations_merge_into_submitter_snapshot() {
        // The memtrack satellite: scratch allocated on a pool worker must
        // show up in the submitting thread's Snapshot.
        let pool = WorkerPool::new(2);
        memtrack::reset();
        let base = memtrack::snapshot();
        assert_eq!(base.peak_total, 0);
        pool.scope(|sc| {
            for _ in 0..2 {
                sc.submit(|| {
                    let tmp = TrackedVec::zeros(1024, Category::Intermediates);
                    std::hint::black_box(&tmp[0]);
                });
            }
        })
        .unwrap();
        let s = memtrack::snapshot();
        // At least one 4 KiB scratch buffer must be visible in the peak
        // (jobs the submitter helps with are tracked directly and don't
        // stack with worker deltas, so the exact peak is 4–8 KiB
        // depending on who ran what — the blind spot being fixed is the
        // pre-pool behaviour where the peak stayed at 0).
        assert!(s.peak_total >= 4096, "worker scratch missing from peak: {}", s.peak_total);
        assert!(s.at_peak[Category::Intermediates.index()] >= 4096);
        assert_eq!(s.alloc_count, 2, "every job's allocation must be counted");
        // the scratch was dropped inside the jobs: nothing stays live
        assert_eq!(s.current_total(), 0);
    }

    #[test]
    fn exec_ctx_thread_counts_and_serial_mode() {
        let one = ExecCtx::with_threads(1);
        assert_eq!(one.threads(), 1);
        assert_eq!(one.pool().workers(), 0);
        let four = ExecCtx::with_threads(4);
        assert_eq!(four.threads(), 4);
        assert_eq!(four.engine_config().max_threads, 4);
        let s = ExecCtx::serial();
        assert_eq!(s.threads(), 1);
        let tagged = ExecCtx::serial().with_category(Category::Gradients);
        assert_eq!(tagged.scratch_category(), Category::Gradients);
    }

    #[test]
    fn scope_waits_for_jobs_before_propagating_op_panic() {
        let pool = WorkerPool::new(2);
        let ran = Arc::new(AtomicUsize::new(0));
        let ran2 = Arc::clone(&ran);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _ = pool.scope(|sc| {
                let r = Arc::clone(&ran2);
                sc.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    r.fetch_add(1, Ordering::SeqCst);
                });
                panic!("op panic after submit");
            });
        }));
        assert!(caught.is_err());
        // the submitted job completed before the panic propagated
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }
}
