//! The artifacts manifest: the typed contract between `aot.py` and the
//! Rust runtime (parameter order, shapes, token geometry, config).

use super::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// One named parameter tensor in a fixed position of the argument list.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub p: usize,
    pub lr: f64,
    pub frozen: Vec<ParamSpec>,
    pub trainable: Vec<ParamSpec>,
    pub num_frozen_params: usize,
    pub num_trainable_params: usize,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse_from(&text, &path.display().to_string())
    }

    pub fn parse(text: &str) -> Result<Self> {
        Self::parse_from(text, "<manifest>")
    }

    /// Parse with an origin label so a corrupt manifest names its file
    /// and byte offset in the error.
    pub fn parse_from(text: &str, origin: &str) -> Result<Self> {
        let v = json::parse_from(text, origin).map_err(|e| anyhow!("{e}"))?;
        let cfg = v.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
        let get = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("config missing {k}"))
        };
        let params = |k: &str| -> Result<Vec<ParamSpec>> {
            v.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("manifest missing {k}"))?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("param missing name"))?
                            .to_string(),
                        shape: p
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("param missing shape"))?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                            .collect::<Result<Vec<_>>>()?,
                    })
                })
                .collect()
        };
        Ok(Manifest {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            seq_len: get("seq_len")?,
            batch: get("batch")?,
            p: get("p")?,
            lr: cfg.get("lr").and_then(Json::as_f64).ok_or_else(|| anyhow!("config missing lr"))?,
            frozen: params("frozen")?,
            trainable: params("trainable")?,
            num_frozen_params: v
                .get("num_frozen_params")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            num_trainable_params: v
                .get("num_trainable_params")
                .and_then(Json::as_usize)
                .unwrap_or(0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "config": {"vocab": 256, "d_model": 64, "n_layers": 2, "n_heads": 2,
                 "d_ff": 128, "seq_len": 32, "batch": 2, "p": 16, "lr": 0.05},
      "frozen": [{"name": "emb", "shape": [256, 64]}],
      "trainable": [{"name": "l0.wq.c", "shape": [4, 4, 16]},
                    {"name": "l0.wv.c", "shape": [4, 4, 16]}],
      "tokens_shape": [2, 32],
      "train_outputs": 3,
      "num_frozen_params": 16384,
      "num_trainable_params": 512
    }"#;

    #[test]
    fn parses_full_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.batch, 2);
        assert_eq!(m.seq_len, 32);
        assert!((m.lr - 0.05).abs() < 1e-12);
        assert_eq!(m.frozen.len(), 1);
        assert_eq!(m.frozen[0].elems(), 256 * 64);
        assert_eq!(m.trainable[1].name, "l0.wv.c");
        assert_eq!(m.trainable[1].shape, vec![4, 4, 16]);
    }

    #[test]
    fn rejects_incomplete_manifest() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"config": {}}"#).is_err());
    }
}
