//! Minimal JSON parser (substrate).
//!
//! The build environment is fully offline (no serde), so the manifest
//! contract between `python/compile/aot.py` and the Rust runtime is parsed
//! by this small recursive-descent parser. It supports the full JSON value
//! grammar (objects, arrays, strings with escapes, numbers, bools, null) —
//! enough for any manifest we emit, and unit-tested below.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }
    /// Strict integer extraction: rejects negatives, fractions,
    /// non-finite values, and anything past 2^53 (where f64 can no longer
    /// represent every integer exactly, so `as usize` would silently
    /// fabricate a value). The old lenient cast turned `-1` into 0 and
    /// huge floats into `usize::MAX` — both corruption amplifiers when
    /// the JSON came from a damaged file.
    pub fn as_usize(&self) -> Option<usize> {
        let v = self.as_f64()?;
        if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || v > 9_007_199_254_740_992.0 {
            return None;
        }
        if v > usize::MAX as f64 {
            return None;
        }
        Some(v as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with byte offset and (when known) the document's origin —
/// a file path or other label — so a corrupt manifest or checkpoint
/// header reports *which* file broke and *where*.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
    pub origin: Option<String>,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.origin {
            Some(o) => {
                write!(f, "{o}: json parse error at byte {}: {}", self.pos, self.msg)
            }
            None => write!(f, "json parse error at byte {}: {}", self.pos, self.msg),
        }
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document.
pub fn parse(s: &str) -> Result<Json, JsonError> {
    let b = s.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// Parse with an origin label (usually a file path) stamped onto any
/// error, so load-path failures name the offending file.
pub fn parse_from(s: &str, origin: &str) -> Result<Json, JsonError> {
    parse(s).map_err(|mut e| {
        e.origin = Some(origin.to_string());
        e
    })
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string(), origin: None }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            // JSON has no Infinity/NaN; a parse that overflows to inf
            // (e.g. "1e999") is a malformed document, not a number.
            .filter(|v| v.is_finite())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain chars (handles UTF-8 transparently)
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap().get("e").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn parses_unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn rejects_truncated_documents_with_offset_and_origin() {
        let doc = r#"{"config": {"vocab": 256, "d_model""#;
        let err = parse_from(doc, "artifacts/manifest.json").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("artifacts/manifest.json"), "{msg}");
        assert!(msg.contains(&format!("byte {}", err.pos)), "{msg}");
        assert_eq!(err.pos, doc.len(), "truncation reported at the cut");
    }

    #[test]
    fn rejects_bit_flipped_documents() {
        let clean = r#"{"step": 12, "rng": "00ff"}"#;
        assert!(parse(clean).is_ok());
        // flip a bit in the structural colon — parse must fail, not
        // silently misread
        let mut flipped = clean.to_string().into_bytes();
        let colon = clean.find(':').unwrap();
        flipped[colon] ^= 0x02;
        let s = String::from_utf8(flipped).unwrap();
        assert!(parse(&s).is_err(), "corrupted doc parsed: {s}");
    }

    #[test]
    fn as_usize_is_strict() {
        assert_eq!(parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(parse("0").unwrap().as_usize(), Some(0));
        // a lenient `as usize` cast would turn these into 0 / MAX / junk
        assert_eq!(parse("-1").unwrap().as_usize(), None);
        assert_eq!(parse("2.5").unwrap().as_usize(), None);
        assert_eq!(parse("1e300").unwrap().as_usize(), None);
        assert_eq!(parse("\"12\"").unwrap().as_usize(), None);
    }

    #[test]
    fn rejects_overflowing_numbers() {
        // f64 parse of 1e999 is +inf; JSON has no inf
        assert!(parse("1e999").is_err());
        assert!(parse("-1e999").is_err());
    }

    #[test]
    fn roundtrips_real_manifest_shape() {
        let doc = r#"{
            "config": {"vocab": 256, "d_model": 64, "lr": 0.05},
            "trainable": [{"name": "l0.wq.c", "shape": [4, 4, 16]}],
            "tokens_shape": [2, 32]
        }"#;
        let v = parse(doc).unwrap();
        let cfg = v.get("config").unwrap();
        assert_eq!(cfg.get("vocab").unwrap().as_usize(), Some(256));
        assert!((cfg.get("lr").unwrap().as_f64().unwrap() - 0.05).abs() < 1e-12);
        let t = &v.get("trainable").unwrap().as_arr().unwrap()[0];
        assert_eq!(t.get("name").unwrap().as_str(), Some("l0.wq.c"));
    }
}
