//! Deterministic fault injection for the native training pipeline.
//!
//! Robustness claims are only testable if the failures are reproducible:
//! a [`FaultPlan`] is a small, seeded schedule of failures — "panic pool
//! job 2 at step 3", "abort the process at step 10", "tear the checkpoint
//! write at step 5" — threaded through [`crate::runtime::pool::ExecCtx`]
//! (so the sharded trainer's fan-out can consult it) and held by the
//! native trainer (for process-level kills and checkpoint I/O faults).
//! Every spec fires **exactly once**; with the same plan, the same run
//! fails the same way every time, which is what lets `repro crashtest`
//! assert bit-identical resume trajectories instead of hoping.
//!
//! Fault kinds:
//!
//! - `panic-job@STEP[:JOB]` — panic one shard job of the pool fan-out at
//!   the given training step. Without `:JOB`, the victim is chosen by the
//!   plan's seed (deterministically per step). Exercises the graceful-
//!   degradation path: catch the surfaced `JobPanic`, retry the step once
//!   on the scoped-serial fallback.
//! - `abort@STEP` — `std::process::abort()` at the top of the step (the
//!   SIGKILL-shaped crash the checkpoint subsystem defends against). Used
//!   by `repro crashtest` child processes.
//! - `halt@STEP` — the in-process analogue of `abort`: the trainer stops
//!   before executing the step and returns its partial report. Usable
//!   from `cargo test`, where a real abort would kill the harness.
//! - `torn-write@STEP` — during the checkpoint save at the step, write
//!   roughly half the bytes to the temp file, sync, and abort: the crash
//!   that leaves a torn temp file behind (which the loader must ignore).
//! - `io-fail@STEP` — the checkpoint save at the step returns an injected
//!   I/O error (training logs a warning and continues).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// What to break.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic one pool shard job. `job: None` = seeded choice.
    ShardPanic { job: Option<usize> },
    /// Kill the process at the top of the step.
    Abort,
    /// Stop the trainer at the top of the step (in-process simulated
    /// kill; the run returns a partial report).
    Halt,
    /// Abort mid-checkpoint-write, leaving a torn temp file.
    TornWrite,
    /// Fail the checkpoint write with an injected I/O error.
    IoFail,
}

/// One scheduled failure. Fires at most once.
#[derive(Debug)]
pub struct FaultSpec {
    step: usize,
    kind: FaultKind,
    fired: AtomicBool,
}

impl FaultSpec {
    pub fn new(step: usize, kind: FaultKind) -> FaultSpec {
        FaultSpec { step, kind, fired: AtomicBool::new(false) }
    }
}

/// A seeded failure schedule. Cheap to share (`Arc`), consulted through
/// `&self` only — all mutability is atomic, so the sharded trainer can
/// query it from inside a pool scope without locks.
#[derive(Debug, Default)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
    seed: u64,
    /// The training step currently executing (set by the trainer via
    /// [`FaultPlan::begin_step`]); 0 = no step active, so plans consulted
    /// outside a training loop never fire (steps are 1-based).
    current: AtomicUsize,
}

impl FaultPlan {
    /// An empty plan (nothing ever fires). The default every `ExecCtx`
    /// carries.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn new(specs: Vec<FaultSpec>) -> FaultPlan {
        FaultPlan { specs, seed: 0, current: AtomicUsize::new(0) }
    }

    /// Seed for unpinned choices (the `panic-job@STEP` victim).
    pub fn with_seed(mut self, seed: u64) -> FaultPlan {
        self.seed = seed;
        self
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Parse a comma-separated spec list:
    /// `panic-job@3`, `panic-job@3:1`, `abort@10`, `halt@10`,
    /// `torn-write@5`, `io-fail@5`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut specs = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, at) = part
                .split_once('@')
                .ok_or_else(|| format!("fault spec {part:?} needs NAME@STEP"))?;
            let (step_str, job) = match at.split_once(':') {
                Some((s, j)) => {
                    let j: usize = j
                        .parse()
                        .map_err(|_| format!("fault spec {part:?}: bad job index {j:?}"))?;
                    (s, Some(j))
                }
                None => (at, None),
            };
            let step: usize = step_str
                .parse()
                .map_err(|_| format!("fault spec {part:?}: bad step {step_str:?}"))?;
            if step == 0 {
                return Err(format!("fault spec {part:?}: steps are 1-based"));
            }
            let kind = match name {
                "panic-job" => FaultKind::ShardPanic { job },
                "abort" => FaultKind::Abort,
                "halt" => FaultKind::Halt,
                "torn-write" => FaultKind::TornWrite,
                "io-fail" => FaultKind::IoFail,
                other => {
                    return Err(format!(
                        "unknown fault {other:?} \
                         (panic-job|abort|halt|torn-write|io-fail)"
                    ))
                }
            };
            if job.is_some() && kind != (FaultKind::ShardPanic { job }) {
                return Err(format!("fault spec {part:?}: only panic-job takes :JOB"));
            }
            specs.push(FaultSpec::new(step, kind));
        }
        Ok(FaultPlan::new(specs))
    }

    /// Mark `step` (1-based) as the currently-executing training step.
    /// The trainer calls this at the top of its loop; step-scoped queries
    /// like [`FaultPlan::take_shard_panic`] match against it.
    pub fn begin_step(&self, step: usize) {
        self.current.store(step, Ordering::SeqCst);
    }

    /// Fire-once query: the first un-fired spec at `step` whose kind
    /// matches `pred` fires and returns its kind.
    fn take(&self, step: usize, pred: impl Fn(FaultKind) -> bool) -> Option<FaultKind> {
        for s in &self.specs {
            if s.step == step
                && pred(s.kind)
                && s.fired
                    .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
            {
                return Some(s.kind);
            }
        }
        None
    }

    /// Should shard job `job` (of `num_jobs`) panic at the current step?
    /// Consumes the matching spec when it fires. An unpinned spec picks
    /// its victim from the plan seed and the step — deterministic, but
    /// not hand-chosen ("seeded fault injection").
    pub fn take_shard_panic(&self, job: usize, num_jobs: usize) -> bool {
        let step = self.current.load(Ordering::SeqCst);
        if step == 0 || self.specs.is_empty() {
            return false;
        }
        // Peek before take: only consume the spec when THIS job is the
        // victim, so the query is safe to issue once per job.
        let victim_of = |j: Option<usize>| match j {
            Some(j) => j % num_jobs.max(1),
            None => {
                // splitmix-style scramble of (seed, step): stable per
                // step, spread across steps
                let mut x =
                    self.seed ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15);
                x ^= x >> 30;
                x = x.wrapping_mul(0xBF58476D1CE4E5B9);
                x ^= x >> 27;
                (x % num_jobs.max(1) as u64) as usize
            }
        };
        for s in &self.specs {
            let j = match s.kind {
                FaultKind::ShardPanic { job } => job,
                _ => continue,
            };
            if s.step == step
                && victim_of(j) == job
                && s.fired
                    .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
            {
                return true;
            }
        }
        false
    }

    /// Fire-once: abort scheduled at `step`?
    pub fn take_abort(&self, step: usize) -> bool {
        self.take(step, |k| k == FaultKind::Abort).is_some()
    }

    /// Fire-once: in-process halt scheduled at `step`?
    pub fn take_halt(&self, step: usize) -> bool {
        self.take(step, |k| k == FaultKind::Halt).is_some()
    }

    /// Fire-once: torn checkpoint write scheduled at `step`?
    pub fn take_torn_write(&self, step: usize) -> bool {
        self.take(step, |k| k == FaultKind::TornWrite).is_some()
    }

    /// Fire-once: injected checkpoint I/O failure scheduled at `step`?
    pub fn take_io_fail(&self, step: usize) -> bool {
        self.take(step, |k| k == FaultKind::IoFail).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse("panic-job@3:1, abort@10,halt@7,torn-write@5,io-fail@5")
            .unwrap();
        assert_eq!(p.specs.len(), 5);
        assert!(p.take_abort(10));
        assert!(!p.take_abort(10), "specs fire once");
        assert!(p.take_halt(7));
        assert!(p.take_torn_write(5));
        assert!(p.take_io_fail(5));
        p.begin_step(3);
        assert!(!p.take_shard_panic(0, 8), "job 1 was pinned, not job 0");
        assert!(p.take_shard_panic(1, 8));
        assert!(!p.take_shard_panic(1, 8), "fired");
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("abort").is_err());
        assert!(FaultPlan::parse("abort@x").is_err());
        assert!(FaultPlan::parse("abort@0").is_err());
        assert!(FaultPlan::parse("abort@3:1").is_err());
        assert!(FaultPlan::parse("explode@3").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn unpinned_victim_is_seeded_and_deterministic() {
        let victim = |seed: u64| -> usize {
            let p = FaultPlan::parse("panic-job@4").unwrap().with_seed(seed);
            p.begin_step(4);
            for j in 0..8 {
                if p.take_shard_panic(j, 8) {
                    return j;
                }
            }
            panic!("some job must be the victim");
        };
        assert_eq!(victim(1), victim(1), "same seed, same victim");
        // across many seeds, the choice varies (it is a choice, not a
        // constant)
        let picks: std::collections::BTreeSet<usize> = (0..16).map(victim).collect();
        assert!(picks.len() > 1, "seed must influence the victim");
    }

    #[test]
    fn nothing_fires_outside_an_active_step() {
        let p = FaultPlan::parse("panic-job@2").unwrap();
        assert!(!p.take_shard_panic(0, 8), "no step began");
        p.begin_step(1);
        assert!(!p.take_shard_panic(0, 8), "wrong step");
    }
}
