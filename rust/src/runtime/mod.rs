//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! `python/compile/aot.py` lowers the L2 model (with the L1 Pallas rdFFT
//! kernels inside) to **HLO text** once at build time; this module loads
//! the text with `HloModuleProto::from_text_file`, compiles it on the PJRT
//! CPU client, and exposes typed step functions to the coordinator. Python
//! never runs on the training path — after `make artifacts` the `repro`
//! binary is self-contained.
//!
//! This module also hosts the process's execution runtime proper:
//! [`pool`] — the persistent worker pool plus the [`ExecCtx`] handle that
//! the engine, the layers, and the native trainer dispatch all threaded
//! compute through (no per-call thread spawns anywhere on the hot path).

pub mod checkpoint;
pub mod faultinject;
pub mod json;
pub mod manifest;
pub mod pool;
pub mod server;
pub mod xla_stub;

pub use checkpoint::{CheckpointError, TrainCheckpoint};
pub use faultinject::{FaultKind, FaultPlan, FaultSpec};
pub use manifest::{Manifest, ParamSpec};
pub use pool::{ExecCtx, JobPanic, Scope, WorkerPool};
pub use server::{ServeError, ServeRequest, ServeResponse, ServeStats, SpectralServer};

use anyhow::{anyhow, Context, Result};
// The offline build links the typed stub; a real deployment swaps this
// alias for the actual PJRT bindings crate (see xla_stub.rs docs).
use self::xla_stub as xla;
use std::path::{Path, PathBuf};

/// A loaded training runtime: compiled executables + parameter state
/// threading.
pub struct Runtime {
    client: xla::PjRtClient,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: Option<xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    /// Frozen backbone literals (constant across steps).
    frozen: Vec<xla::Literal>,
    /// Current adapter parameters (threaded output -> input each step).
    trainable: Vec<xla::Literal>,
}

impl Runtime {
    /// Load artifacts produced by `make artifacts` from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .context("loading manifest.json (run `make artifacts` first)")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;

        let compile = |file: &PathBuf| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(file)
                .map_err(|e| anyhow!("parsing {}: {e:?}", file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e:?}", file.display()))
        };

        let train_exe = compile(&dir.join("train_step.hlo.txt"))?;
        let eval_path = dir.join("eval_step.hlo.txt");
        let eval_exe = if eval_path.exists() { Some(compile(&eval_path)?) } else { None };

        let frozen = load_param_literals(&dir.join("frozen.bin"), &manifest.frozen)?;
        let trainable = load_param_literals(&dir.join("trainable.bin"), &manifest.trainable)?;

        Ok(Runtime { client, train_exe, eval_exe, manifest, frozen, trainable })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one SGD train step on a `(batch, seq)` token/target pair.
    /// The updated adapter parameters replace the runtime's state (the
    /// output→input threading that substitutes for buffer donation over
    /// the HLO-text interchange); returns the step loss.
    pub fn train_step(&mut self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        let (b, t) = (self.manifest.batch, self.manifest.seq_len);
        anyhow::ensure!(tokens.len() == b * t, "tokens must be batch*seq");
        anyhow::ensure!(targets.len() == b * t, "targets must be batch*seq");
        let tok = xla::Literal::vec1(tokens)
            .reshape(&[b as i64, t as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let tgt = xla::Literal::vec1(targets)
            .reshape(&[b as i64, t as i64])
            .map_err(|e| anyhow!("{e:?}"))?;

        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(self.frozen.len() + self.trainable.len() + 2);
        args.extend(self.frozen.iter());
        args.extend(self.trainable.iter());
        args.push(&tok);
        args.push(&tgt);

        let result =
            self.train_exe.execute::<&xla::Literal>(&args).map_err(|e| anyhow!("{e:?}"))?;
        let out = result[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        let mut parts = out.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        anyhow::ensure!(
            parts.len() == self.trainable.len() + 1,
            "expected {} outputs, got {}",
            self.trainable.len() + 1,
            parts.len()
        );
        let loss_lit = parts.pop().unwrap();
        let loss: f32 = loss_lit.get_first_element().map_err(|e| anyhow!("{e:?}"))?;
        self.trainable = parts;
        Ok(loss)
    }

    /// Loss on a batch without updating parameters.
    pub fn eval_step(&self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        let exe = self.eval_exe.as_ref().ok_or_else(|| anyhow!("no eval executable"))?;
        let (b, t) = (self.manifest.batch, self.manifest.seq_len);
        let tok = xla::Literal::vec1(tokens)
            .reshape(&[b as i64, t as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let tgt = xla::Literal::vec1(targets)
            .reshape(&[b as i64, t as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.frozen.iter());
        args.extend(self.trainable.iter());
        args.push(&tok);
        args.push(&tgt);
        let result = exe.execute::<&xla::Literal>(&args).map_err(|e| anyhow!("{e:?}"))?;
        let out = result[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        parts[0].get_first_element().map_err(|e| anyhow!("{e:?}"))
    }

    /// Current adapter parameters, flattened f32 in manifest order
    /// (checkpointing).
    pub fn trainable_flat(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for lit in &self.trainable {
            out.extend(lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?);
        }
        Ok(out)
    }

    /// Restore adapter parameters from a flat f32 vector (checkpoint load).
    pub fn set_trainable_flat(&mut self, flat: &[f32]) -> Result<()> {
        let mut lits = Vec::with_capacity(self.manifest.trainable.len());
        let mut off = 0usize;
        for spec in &self.manifest.trainable {
            let n = spec.elems();
            anyhow::ensure!(off + n <= flat.len(), "flat params too short");
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&flat[off..off + n])
                .reshape(&dims)
                .map_err(|e| anyhow!("{e:?}"))?;
            lits.push(lit);
            off += n;
        }
        anyhow::ensure!(off == flat.len(), "flat params too long");
        self.trainable = lits;
        Ok(())
    }
}

/// Read a raw little-endian f32 file into per-parameter literals, shaped
/// per the manifest spec (the `frozen.bin` / `trainable.bin` contract).
pub fn load_param_literals(path: &Path, specs: &[ParamSpec]) -> Result<Vec<xla::Literal>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let total: usize = specs.iter().map(|s| s.elems()).sum();
    anyhow::ensure!(
        bytes.len() == total * 4,
        "{}: expected {} bytes, found {}",
        path.display(),
        total * 4,
        bytes.len()
    );
    let floats: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0usize;
    for spec in specs {
        let n = spec.elems();
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&floats[off..off + n])
            .reshape(&dims)
            .map_err(|e| anyhow!("shaping {}: {e:?}", spec.name))?;
        out.push(lit);
        off += n;
    }
    Ok(out)
}
