//! `repro` — CLI for the rdFFT reproduction.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts:
//!
//! ```text
//! repro train   [--steps N] [--artifacts DIR] [--csv F] [--ckpt F]
//! repro table1  [--fast]        single-layer peak-memory grid
//! repro table2                  full-model memory decomposition
//! repro table3                  operator runtime + accuracy
//! repro table4  [--fast]        throughput + task-accuracy parity
//! repro fig2    [--d D] [--fast] memory breakdown at peak
//! repro audit                   zero-allocation audit
//! repro report                  run everything (fast variants)
//! ```
//!
//! (clap is unavailable in this offline environment; parsing is a small
//! hand-rolled matcher with the same UX.)

use anyhow::{bail, Result};
use std::path::PathBuf;

use rdfft::autograd::layers::Backend;
use rdfft::autograd::optim::OptimKind;
use rdfft::autograd::stack::StackConfig;
use rdfft::autograd::train::Method;
use rdfft::coordinator::{experiments, NativeTrainer, NativeTrainerConfig, Trainer, TrainerConfig};

struct Args {
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    Some(argv[i].clone())
                } else {
                    None
                };
                flags.push((name.to_string(), val));
            }
            i += 1;
        }
        Args { flags }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().find(|(n, _)| n == name).and_then(|(_, v)| v.as_deref())
    }

    /// Numeric flag with parse-or-fail semantics: an absent flag yields
    /// the default, but a present-without-value or malformed one is a
    /// user error — never a silent fallback that trains the wrong run.
    fn get_num(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.iter().find(|(n, _)| n == name) {
            None => Ok(default),
            Some((_, Some(v))) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
            Some((_, None)) => anyhow::bail!("--{name} expects a number"),
        }
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: repro <command> [flags]\n\
         commands:\n\
           train    run the end-to-end training loop over the AOT artifacts\n\
                    [--steps N=300] [--artifacts DIR=artifacts] [--csv FILE]\n\
                    [--ckpt FILE] [--eval-every N=50] [--seed S=0]\n\
           train-native  pure-Rust training on the in-place engine (no PJRT)\n\
                    [--steps N=150] [--d D=64] [--depth K=2] [--ctx C=8]\n\
                    [--batch B=16] [--p P=16] [--method circulant|dense|lora]\n\
                    [--backend ours|fft|rfft] [--optim sgd|momentum|adam]\n\
                    [--lr F] [--csv FILE] [--seed S=0] [--eval-every N=25]\n\
                    [--threads T]  data-parallel step on a persistent\n\
                    worker pool (T lanes; bit-identical losses for any T)\n\
                    [--max-peak-mib M]  (exits non-zero if loss fails to\n\
                    drop or the memtrack peak exceeds M)\n\
                    [--force-scalar]  disable the SIMD lane kernels\n\
                    (also RDFFT_FORCE_SCALAR=1; dispatch is on by default)\n\
           table-native  native multi-layer peak-memory grid [--fast]\n\
           table1   single-layer peak-memory grid   [--fast]\n\
           table2   full-model memory decomposition\n\
           table3   operator runtime + accuracy\n\
           table4   throughput + accuracy parity    [--fast]\n\
           fig2     memory breakdown at peak        [--d D=1024] [--fast]\n\
           audit    zero-allocation audit\n\
           optim    optimizer-state memory ablation\n\
           engine   batch-engine throughput ablation [--fast]\n\
                    [--force-scalar]  pin the legacy scalar kernels\n\
                    (writes BENCH_rdfft.json incl. simd_vs_scalar gates)\n\
           report   all of the above (fast variants)"
    );
    std::process::exit(2);
}

fn cmd_train(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let cfg = TrainerConfig {
        steps: args.get_num("steps", 300)?,
        eval_every: args.get_num("eval-every", 50)?,
        seed: args.get_num("seed", 0)? as u64,
        log_csv: args.get("csv").map(PathBuf::from),
        checkpoint: args.get("ckpt").map(PathBuf::from),
        ..Default::default()
    };
    let mut trainer = Trainer::new(&artifacts, cfg)?;
    let report = trainer.run()?;
    println!(
        "[train] done: loss {:.4} -> {:.4} over {} steps ({:.0} tok/s)",
        report.first_loss, report.final_loss, report.steps, report.tokens_per_sec
    );
    if report.final_loss >= report.first_loss {
        bail!("training did not reduce the loss");
    }
    Ok(())
}

fn cmd_train_native(args: &Args) -> Result<()> {
    let backend = match args.get("backend").unwrap_or("ours") {
        "ours" | "rdfft" => Backend::RdFft,
        "fft" => Backend::Fft,
        "rfft" => Backend::Rfft,
        other => bail!("unknown backend {other:?} (ours|fft|rfft)"),
    };
    let d = args.get_num("d", 64)?;
    let p = args.get_num("p", 16)?;
    let method = match args.get("method").unwrap_or("circulant") {
        "circulant" => Method::Circulant { backend, p },
        "dense" | "full" => Method::FullFinetune,
        "lora" => Method::Lora { rank: args.get_num("rank", 8)? },
        other => bail!("unknown method {other:?} (circulant|dense|lora)"),
    };
    if let Method::Circulant { p, .. } = method {
        if d % p != 0 {
            bail!("--d {d} must be a multiple of --p {p}");
        }
    }
    let (optim, default_lr) = match args.get("optim").unwrap_or("sgd") {
        "sgd" => (OptimKind::Sgd, 0.2),
        "momentum" => (OptimKind::Momentum { beta: 0.9 }, 0.05),
        "adam" => (OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }, 0.01),
        other => bail!("unknown optimizer {other:?} (sgd|momentum|adam)"),
    };
    let lr = match args.get("lr") {
        // A malformed rate must fail loudly, not silently fall back.
        Some(raw) => match raw.parse::<f32>() {
            Ok(v) => v,
            Err(_) => bail!("--lr expects a number, got {raw:?}"),
        },
        None => default_lr,
    };
    // One --seed drives both model init and the corpus/batch stream.
    let seed = args.get_num("seed", 0)? as u64;
    // Absent --threads = serial step; a present-but-malformed lane count
    // is a user error (get_num), never "serial silently".
    let threads = args.get_num("threads", 0)?;
    let cfg = NativeTrainerConfig {
        stack: StackConfig {
            d,
            depth: args.get_num("depth", 2)?,
            ctx: args.get_num("ctx", 8)?,
            method,
            seed,
            ..Default::default()
        },
        optim,
        lr,
        steps: args.get_num("steps", 150)?,
        batch: args.get_num("batch", 16)?,
        eval_every: args.get_num("eval-every", 25)?,
        seed,
        log_csv: args.get("csv").map(PathBuf::from),
        threads,
        ..Default::default()
    };
    let mut trainer = NativeTrainer::new(cfg);
    let report = trainer.run()?;
    println!(
        "[train-native] done: loss {:.4} -> {:.4} (trend {:.4} -> {:.4}) over {} steps, \
         peak {:.2} MiB (act+grad {:.3} MiB), {:.0} tok/s",
        report.first_loss,
        report.final_loss,
        report.head_loss,
        report.tail_loss,
        report.steps,
        report.peak_mib(),
        report.activation_grad_peak() as f64 / (1024.0 * 1024.0),
        report.tokens_per_sec,
    );
    if !report.loss_decreased() {
        bail!(
            "training did not reduce the loss ({:.4} -> {:.4})",
            report.head_loss,
            report.tail_loss
        );
    }
    if let Some(raw) = args.get("max-peak-mib") {
        // A malformed budget must fail loudly, not silently disable the gate.
        let Ok(max) = raw.parse::<f64>() else {
            bail!("--max-peak-mib expects a number in MiB, got {raw:?}");
        };
        if report.peak_mib() > max {
            bail!("memtrack peak {:.2} MiB exceeds the budget {max:.2} MiB", report.peak_mib());
        }
        println!("[train-native] peak {:.2} MiB within budget {max:.2} MiB", report.peak_mib());
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let args = Args::parse(&argv[1..]);
    // Process-wide SIMD kill switch: must run before the first transform
    // so the cached dispatch decision never flips mid-run. The env-var
    // form (RDFFT_FORCE_SCALAR=1) is handled inside the dispatcher and
    // drives the CI force-scalar matrix leg.
    if args.has("force-scalar") {
        rdfft::rdfft::simd::force_scalar_global();
    }
    match cmd.as_str() {
        "train" => cmd_train(&args)?,
        "train-native" => cmd_train_native(&args)?,
        "table-native" => experiments::table_native(args.has("fast")),
        "table1" => experiments::table1(args.has("fast")),
        "table2" => experiments::table2(),
        "table3" => experiments::table3(),
        "table4" => experiments::table4(args.has("fast")),
        "fig2" => experiments::fig2(args.get_num("d", 1024)?, args.has("fast")),
        "audit" => experiments::alloc_audit(),
        "optim" => experiments::optim_ablation(),
        "engine" => {
            if !experiments::bench_rdfft_engine(args.has("fast")) {
                bail!(
                    "engine gate failed: batch=1 latency regressed vs scalar, \
                     or the fused circulant pipeline regressed vs unfused"
                );
            }
        }
        "report" => {
            experiments::table1(true);
            experiments::fig2(1024, true);
            experiments::table2();
            experiments::table3();
            experiments::table4(true);
            experiments::table_native(true);
            experiments::alloc_audit();
            experiments::optim_ablation();
            let _ = experiments::bench_rdfft_engine(true);
        }
        _ => usage(),
    }
    Ok(())
}
