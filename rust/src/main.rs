//! `repro` — CLI for the rdFFT reproduction.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts:
//!
//! ```text
//! repro train   [--steps N] [--artifacts DIR] [--csv F] [--ckpt F]
//! repro table1  [--fast]        single-layer peak-memory grid
//! repro table2                  full-model memory decomposition
//! repro table3                  operator runtime + accuracy
//! repro table4  [--fast]        throughput + task-accuracy parity
//! repro fig2    [--d D] [--fast] memory breakdown at peak
//! repro audit                   zero-allocation audit
//! repro report                  run everything (fast variants)
//! ```
//!
//! (clap is unavailable in this offline environment; parsing is a small
//! hand-rolled matcher with the same UX.)

use anyhow::{bail, Result};
use std::path::PathBuf;

use rdfft::coordinator::{experiments, Trainer, TrainerConfig};

struct Args {
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    Some(argv[i].clone())
                } else {
                    None
                };
                flags.push((name.to_string(), val));
            }
            i += 1;
        }
        Args { flags }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().find(|(n, _)| n == name).and_then(|(_, v)| v.as_deref())
    }

    fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: repro <command> [flags]\n\
         commands:\n\
           train    run the end-to-end training loop over the AOT artifacts\n\
                    [--steps N=300] [--artifacts DIR=artifacts] [--csv FILE]\n\
                    [--ckpt FILE] [--eval-every N=50] [--seed S=0]\n\
           table1   single-layer peak-memory grid   [--fast]\n\
           table2   full-model memory decomposition\n\
           table3   operator runtime + accuracy\n\
           table4   throughput + accuracy parity    [--fast]\n\
           fig2     memory breakdown at peak        [--d D=1024] [--fast]\n\
           audit    zero-allocation audit\n\
           optim    optimizer-state memory ablation\n\
           engine   batch-engine throughput ablation [--fast]\n\
                    (writes BENCH_rdfft.json)\n\
           report   all of the above (fast variants)"
    );
    std::process::exit(2);
}

fn cmd_train(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let cfg = TrainerConfig {
        steps: args.get_usize("steps", 300),
        eval_every: args.get_usize("eval-every", 50),
        seed: args.get_usize("seed", 0) as u64,
        log_csv: args.get("csv").map(PathBuf::from),
        checkpoint: args.get("ckpt").map(PathBuf::from),
        ..Default::default()
    };
    let mut trainer = Trainer::new(&artifacts, cfg)?;
    let report = trainer.run()?;
    println!(
        "[train] done: loss {:.4} -> {:.4} over {} steps ({:.0} tok/s)",
        report.first_loss, report.final_loss, report.steps, report.tokens_per_sec
    );
    if report.final_loss >= report.first_loss {
        bail!("training did not reduce the loss");
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "train" => cmd_train(&args)?,
        "table1" => experiments::table1(args.has("fast")),
        "table2" => experiments::table2(),
        "table3" => experiments::table3(),
        "table4" => experiments::table4(args.has("fast")),
        "fig2" => experiments::fig2(args.get_usize("d", 1024), args.has("fast")),
        "audit" => experiments::alloc_audit(),
        "optim" => experiments::optim_ablation(),
        "engine" => {
            if !experiments::bench_rdfft_engine(args.has("fast")) {
                bail!("engine latency gate failed: batch=1 regressed vs the scalar path");
            }
        }
        "report" => {
            experiments::table1(true);
            experiments::fig2(1024, true);
            experiments::table2();
            experiments::table3();
            experiments::table4(true);
            experiments::alloc_audit();
            experiments::optim_ablation();
            let _ = experiments::bench_rdfft_engine(true);
        }
        _ => usage(),
    }
    Ok(())
}
