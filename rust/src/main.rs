//! `repro` — CLI for the rdFFT reproduction.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts:
//!
//! ```text
//! repro train   [--steps N] [--artifacts DIR] [--csv F] [--ckpt F]
//! repro table1  [--fast]        single-layer peak-memory grid
//! repro table2                  full-model memory decomposition
//! repro table3                  operator runtime + accuracy
//! repro table4  [--fast]        throughput + task-accuracy parity
//! repro fig2    [--d D] [--fast] memory breakdown at peak
//! repro audit   [--json AUDIT.json] static invariant checker
//! repro alloc-audit             zero-allocation audit (dynamic)
//! repro report                  run everything (fast variants)
//! ```
//!
//! (clap is unavailable in this offline environment; parsing is a small
//! hand-rolled matcher with the same UX.)

use anyhow::{bail, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use rdfft::autograd::layers::Backend;
use rdfft::autograd::optim::OptimKind;
use rdfft::autograd::stack::{SpectralStack, StackConfig};
use rdfft::autograd::train::Method;
use rdfft::coordinator::serve_bench::{slam, SlamConfig};
use rdfft::coordinator::{
    experiments, NativeReport, NativeTrainer, NativeTrainerConfig, Trainer, TrainerConfig,
};
use rdfft::runtime::server::{serve_tcp, spawn_session};
use rdfft::runtime::{checkpoint, ExecCtx, FaultPlan};

struct Args {
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    Some(argv[i].clone())
                } else {
                    None
                };
                flags.push((name.to_string(), val));
            }
            i += 1;
        }
        Args { flags }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().find(|(n, _)| n == name).and_then(|(_, v)| v.as_deref())
    }

    /// Numeric flag with parse-or-fail semantics: an absent flag yields
    /// the default, but a present-without-value or malformed one is a
    /// user error — never a silent fallback that trains the wrong run.
    fn get_num(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.iter().find(|(n, _)| n == name) {
            None => Ok(default),
            Some((_, Some(v))) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
            Some((_, None)) => anyhow::bail!("--{name} expects a number"),
        }
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: repro <command> [flags]\n\
         commands:\n\
           train    run the end-to-end training loop over the AOT artifacts\n\
                    [--steps N=300] [--artifacts DIR=artifacts] [--csv FILE]\n\
                    [--ckpt FILE] [--eval-every N=50] [--seed S=0]\n\
           train-native  pure-Rust training on the in-place engine (no PJRT)\n\
                    [--steps N=150] [--d D=64] [--depth K=2] [--ctx C=8]\n\
                    [--batch B=16] [--p P=16]\n\
                    [--method circulant|dense|lora|longconv|mixed]  (--layer\n\
                    is an alias; longconv takes [--k TAPS=16] trainable\n\
                    filter taps; mixed = circulant blocks + longconv top)\n\
                    [--backend ours|fft|rfft] [--optim sgd|momentum|adam]\n\
                    [--lr F] [--csv FILE] [--seed S=0] [--eval-every N=25]\n\
                    [--threads T]  data-parallel step on a persistent\n\
                    worker pool (T lanes; bit-identical losses for any T)\n\
                    [--max-peak-mib M]  (exits non-zero if loss fails to\n\
                    drop or the memtrack peak exceeds M)\n\
                    [--force-scalar]  disable the SIMD lane kernels\n\
                    (also RDFFT_FORCE_SCALAR=1; dispatch is on by default)\n\
                    [--checkpoint-dir DIR]  crash-safe checkpoints (atomic\n\
                    writes, per-section checksums, keep-last-K retention)\n\
                    [--checkpoint-every N=25] [--keep K=3]\n\
                    [--resume]  continue from the newest valid checkpoint\n\
                    (bit-identical to the uninterrupted run)\n\
                    [--fault SPEC] [--fault-seed S]  deterministic fault\n\
                    injection: panic-job@STEP[:JOB] | abort@STEP |\n\
                    halt@STEP | torn-write@STEP | io-fail@STEP (comma-sep)\n\
           crashtest  kill/resume cycles proving bit-identical resume\n\
                    [--threads T=2]  (abort, torn-write, pool-panic, and\n\
                    corrupted-checkpoint scenarios vs an uninterrupted\n\
                    reference run)\n\
           table-native  native multi-layer peak-memory grid [--fast]\n\
           table1   single-layer peak-memory grid   [--fast]\n\
           table2   full-model memory decomposition\n\
           table3   operator runtime + accuracy\n\
           table4   throughput + accuracy parity    [--fast]\n\
           fig2     memory breakdown at peak        [--d D=1024] [--fast]\n\
           audit    static invariant checker over rust/src + rust/tests\n\
                    (unsafe hygiene, no raw threads, lock-poison policy,\n\
                    no_alloc hot-path markers, determinism lints); exits\n\
                    non-zero on any unsuppressed violation\n\
                    [--json FILE]  machine-readable AUDIT.json report\n\
                    [--root DIR]   audit DIR instead of auto-detecting\n\
           alloc-audit  zero-allocation audit (dynamic memtrack probe)\n\
           optim    optimizer-state memory ablation\n\
           engine   batch-engine throughput ablation [--fast]\n\
                    [--force-scalar]  pin the legacy scalar kernels\n\
                    [--fourstep-smoke]  skip timing: four-step large-n\n\
                    tier vs direct sweep correctness check only\n\
                    (writes BENCH_rdfft.json incl. simd_vs_scalar,\n\
                    simd8_vs_simd4 and fourstep_vs_direct gates)\n\
           serve    inference server: line protocol over TCP (hex ctx in,\n\
                    next-byte + fingerprint out; blank line flushes the\n\
                    partial window, 'quit' closes)\n\
                    [--addr A=127.0.0.1:4915] [--window W=1] [--threads T]\n\
                    [--d D=64] [--depth K=2] [--p P=16] [--ctx C=8]\n\
                    [--layer circulant|longconv] [--k TAPS=16] [--seed S=0]\n\
                    (W>1 needs pipelined clients; responses are\n\
                    bit-identical for any W / T / arrival order)\n\
           slam     serving load generator + acceptance gates: coalesced\n\
                    window=W vs single-row throughput, p50/p99 latency,\n\
                    arrival-order + thread-count determinism, and the\n\
                    zero steady-state allocation check; writes\n\
                    BENCH_serve.json and exits non-zero on a hard-gate\n\
                    failure (coalesce_vs_single target 1.2x is advisory,\n\
                    floor 0.9x is hard)\n\
                    [--requests N=512] [--window W=8] [--clients C=4]\n\
                    [--threads T] [--rounds R=3] [--bench FILE]\n\
                    [--max-p99-ms MS] [--d D] [--depth K] [--p P]\n\
                    [--ctx C] [--seed S]\n\
           report   all of the above (fast variants)"
    );
    std::process::exit(2);
}

fn cmd_train(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let cfg = TrainerConfig {
        steps: args.get_num("steps", 300)?,
        eval_every: args.get_num("eval-every", 50)?,
        seed: args.get_num("seed", 0)? as u64,
        log_csv: args.get("csv").map(PathBuf::from),
        checkpoint: args.get("ckpt").map(PathBuf::from),
        ..Default::default()
    };
    let mut trainer = Trainer::new(&artifacts, cfg)?;
    let report = trainer.run()?;
    println!(
        "[train] done: loss {:.4} -> {:.4} over {} steps ({:.0} tok/s)",
        report.first_loss, report.final_loss, report.steps, report.tokens_per_sec
    );
    if report.final_loss >= report.first_loss {
        bail!("training did not reduce the loss");
    }
    Ok(())
}

fn cmd_train_native(args: &Args) -> Result<()> {
    let backend = match args.get("backend").unwrap_or("ours") {
        "ours" | "rdfft" => Backend::RdFft,
        "fft" => Backend::Fft,
        "rfft" => Backend::Rfft,
        other => bail!("unknown backend {other:?} (ours|fft|rfft)"),
    };
    let d = args.get_num("d", 64)?;
    let p = args.get_num("p", 16)?;
    let depth = args.get_num("depth", 2)?;
    // --layer is an alias for --method (the long-conv docs say "--layer
    // longconv"; both spellings select the block type).
    let layer = args.get("layer").or_else(|| args.get("method")).unwrap_or("circulant");
    let method = match layer {
        // "mixed" trains a heterogeneous tower (circulant blocks + a
        // long-conv top block); the base method below only fills
        // StackConfig.method and is overridden per block.
        "circulant" | "mixed" => Method::Circulant { backend, p },
        "dense" | "full" => Method::FullFinetune,
        "lora" => Method::Lora { rank: args.get_num("rank", 8)? },
        "longconv" => Method::LongConv { k: args.get_num("k", 16)? },
        other => bail!("unknown method {other:?} (circulant|dense|lora|longconv|mixed)"),
    };
    match method {
        Method::Circulant { p, .. } if d % p != 0 => {
            bail!("--d {d} must be a multiple of --p {p}");
        }
        Method::LongConv { k } if k == 0 || k > d => {
            bail!("--k {k} must be in 1..=d (d={d})");
        }
        _ => {}
    }
    let block_methods = if layer == "mixed" {
        if depth == 0 {
            bail!("--layer mixed needs --depth >= 1");
        }
        let k = args.get_num("k", 16)?;
        if k == 0 || k > d {
            bail!("--k {k} must be in 1..=d (d={d})");
        }
        let mut ms = vec![Method::Circulant { backend, p }; depth - 1];
        ms.push(Method::LongConv { k });
        Some(ms)
    } else {
        None
    };
    let (optim, default_lr) = match args.get("optim").unwrap_or("sgd") {
        "sgd" => (OptimKind::Sgd, 0.2),
        "momentum" => (OptimKind::Momentum { beta: 0.9 }, 0.05),
        "adam" => (OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }, 0.01),
        other => bail!("unknown optimizer {other:?} (sgd|momentum|adam)"),
    };
    let lr = match args.get("lr") {
        // A malformed rate must fail loudly, not silently fall back.
        Some(raw) => match raw.parse::<f32>() {
            Ok(v) => v,
            Err(_) => bail!("--lr expects a number, got {raw:?}"),
        },
        None => default_lr,
    };
    // One --seed drives both model init and the corpus/batch stream.
    let seed = args.get_num("seed", 0)? as u64;
    // Absent --threads = serial step; a present-but-malformed lane count
    // is a user error (get_num), never "serial silently".
    let threads = args.get_num("threads", 0)?;
    // Deterministic fault schedule (tests/crashtest; empty by default).
    let faults = match args.get("fault") {
        Some(spec) => {
            let plan = FaultPlan::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
            Arc::new(plan.with_seed(args.get_num("fault-seed", 0)? as u64))
        }
        None => {
            if args.has("fault") {
                bail!("--fault expects a spec, e.g. panic-job@3 or abort@10");
            }
            Arc::new(FaultPlan::none())
        }
    };
    let cfg = NativeTrainerConfig {
        stack: StackConfig {
            d,
            depth,
            ctx: args.get_num("ctx", 8)?,
            method,
            seed,
            ..Default::default()
        },
        block_methods,
        optim,
        lr,
        steps: args.get_num("steps", 150)?,
        batch: args.get_num("batch", 16)?,
        eval_every: args.get_num("eval-every", 25)?,
        seed,
        log_csv: args.get("csv").map(PathBuf::from),
        threads,
        checkpoint_dir: args.get("checkpoint-dir").map(PathBuf::from),
        checkpoint_every: args.get_num("checkpoint-every", 25)?,
        checkpoint_keep: args.get_num("keep", 3)?,
        resume: args.has("resume"),
        faults,
        ..Default::default()
    };
    let mut trainer = NativeTrainer::new(cfg);
    let report = trainer.run()?;
    if let Some(from) = report.resumed_from {
        println!(
            "[train-native] resumed at step {} ({} new steps this process)",
            from + 1,
            report.losses.len()
        );
    }
    if report.degraded_steps > 0 {
        println!(
            "[train-native] {} step(s) completed on the serial fallback after a \
             pool panic",
            report.degraded_steps
        );
    }
    if let Some(at) = report.halted_at {
        println!("[train-native] halted by injected fault before step {at}");
    }
    println!(
        "[train-native] done: loss {:.4} -> {:.4} (trend {:.4} -> {:.4}) over {} steps, \
         peak {:.2} MiB (act+grad {:.3} MiB), {:.0} tok/s",
        report.first_loss,
        report.final_loss,
        report.head_loss,
        report.tail_loss,
        report.steps,
        report.peak_mib(),
        report.activation_grad_peak() as f64 / (1024.0 * 1024.0),
        report.tokens_per_sec,
    );
    // The loss-trend gate only applies to complete, from-scratch runs: a
    // resumed run may replay only a short (already-converged) tail, and a
    // fault-halted run is intentionally partial.
    if report.resumed_from.is_none() && report.halted_at.is_none() && !report.loss_decreased()
    {
        bail!(
            "training did not reduce the loss ({:.4} -> {:.4})",
            report.head_loss,
            report.tail_loss
        );
    }
    if let Some(raw) = args.get("max-peak-mib") {
        // A malformed budget must fail loudly, not silently disable the gate.
        let Ok(max) = raw.parse::<f64>() else {
            bail!("--max-peak-mib expects a number in MiB, got {raw:?}");
        };
        if report.peak_mib() > max {
            bail!("memtrack peak {:.2} MiB exceeds the budget {max:.2} MiB", report.peak_mib());
        }
        println!("[train-native] peak {:.2} MiB within budget {max:.2} MiB", report.peak_mib());
    }
    Ok(())
}

/// The fixed small config every crashtest run (in-process and child) uses.
/// The child process is launched through `train-native` flags, so the
/// flag list in [`cmd_crashtest`] must mirror this exactly — the config
/// fingerprint is what lets resume accept the child's checkpoints.
fn crashtest_cfg(
    dir: Option<&Path>,
    threads: usize,
    resume: bool,
    faults: Arc<FaultPlan>,
) -> NativeTrainerConfig {
    NativeTrainerConfig {
        stack: StackConfig {
            d: 32,
            depth: 2,
            ctx: 4,
            method: Method::Circulant { backend: Backend::RdFft, p: 8 },
            seed: 42,
            ..Default::default()
        },
        optim: OptimKind::Sgd,
        lr: 0.2,
        steps: 20,
        batch: 8,
        eval_every: 0,
        // eval_batches stays at the config default (4) to match the
        // child's CLI-built config; eval is off either way (eval_every=0)
        // but the fingerprint records both knobs.
        seed: 42,
        log_csv: None,
        verbose: false,
        threads,
        checkpoint_dir: dir.map(|p| p.to_path_buf()),
        checkpoint_every: 5,
        checkpoint_keep: 10,
        resume,
        faults,
        ..Default::default()
    }
}

/// `repro crashtest`: train → kill → resume cycles asserting the resumed
/// trajectory (per-step losses AND final parameters) is **bit-identical**
/// to an uninterrupted reference run. Kills are real `abort()`s in child
/// processes driven by deterministic fault injection; scenarios cover a
/// clean kill, a torn checkpoint write, a worker-pool panic (graceful
/// degradation) followed by a kill, a corrupted checkpoint file, and a
/// config-fingerprint mismatch.
fn cmd_crashtest(args: &Args) -> Result<()> {
    use std::process::Command;
    let threads = args.get_num("threads", 2)?;
    let exe = std::env::current_exe()?;
    let base = std::env::temp_dir().join(format!("rdfft_crashtest_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base)?;

    println!("[crashtest] reference: uninterrupted 20-step run ({threads} lane(s), no checkpointing)");
    let (ref_losses, ref_params) = {
        let mut t =
            NativeTrainer::new(crashtest_cfg(None, threads, false, Arc::new(FaultPlan::none())));
        let r = t.run()?;
        let (_, params) = t.stack_mut().export_params();
        (r.losses, params)
    };

    let child = |dir: &Path, fault: &str| -> Result<()> {
        println!("[crashtest] child: train-native --fault {fault:?} (expected to die)");
        let status = Command::new(&exe)
            .args([
                "train-native",
                "--steps", "20",
                "--d", "32",
                "--depth", "2",
                "--ctx", "4",
                "--p", "8",
                "--batch", "8",
                "--seed", "42",
                "--eval-every", "0",
                "--threads", &threads.to_string(),
                "--checkpoint-dir", dir.to_str().expect("temp paths are utf-8"),
                "--checkpoint-every", "5",
                "--keep", "10",
                "--fault", fault,
            ])
            .status()?;
        anyhow::ensure!(
            !status.success(),
            "child injected with {fault:?} exited successfully — the fault never fired"
        );
        Ok(())
    };

    let resume = |dir: &Path| -> Result<(NativeReport, Vec<f32>)> {
        let mut t = NativeTrainer::new(crashtest_cfg(
            Some(dir),
            threads,
            true,
            Arc::new(FaultPlan::none()),
        ));
        let r = t.run()?;
        let (_, params) = t.stack_mut().export_params();
        Ok((r, params))
    };

    let verify = |tag: &str, r: &NativeReport, params: &[f32], expect_from: usize| -> Result<()> {
        anyhow::ensure!(
            r.resumed_from == Some(expect_from),
            "[{tag}] resumed from {:?}, expected step {expect_from}",
            r.resumed_from
        );
        for &(step, loss) in &r.losses {
            let rl = ref_losses
                .iter()
                .find(|&&(s, _)| s == step)
                .map(|&(_, l)| l)
                .ok_or_else(|| anyhow::anyhow!("[{tag}] reference lacks step {step}"))?;
            anyhow::ensure!(
                loss.to_bits() == rl.to_bits(),
                "[{tag}] step {step}: resumed loss {loss} != reference {rl} (not bit-identical)"
            );
        }
        anyhow::ensure!(params.len() == ref_params.len(), "[{tag}] param count mismatch");
        for i in 0..params.len() {
            anyhow::ensure!(
                params[i].to_bits() == ref_params[i].to_bits(),
                "[{tag}] final param {i} differs: {} vs {}",
                params[i],
                ref_params[i]
            );
        }
        println!(
            "[crashtest] {tag}: resumed after step {expect_from}; {} replayed losses and \
             {} final params bit-identical to the reference",
            r.losses.len(),
            params.len()
        );
        Ok(())
    };

    // Scenario 1: clean kill at step 10 (before the step runs) — newest
    // checkpoint is step 5.
    let dir_abort = base.join("abort");
    child(&dir_abort, "abort@10")?;
    let (r, p) = resume(&dir_abort)?;
    verify("abort", &r, &p, 5)?;

    // Scenario 2: death MID-checkpoint-write at step 10 — the torn temp
    // file must be ignored and resume must fall back to step 5.
    let dir_torn = base.join("torn");
    child(&dir_torn, "torn-write@10")?;
    let torn_tmp = std::fs::read_dir(&dir_torn)?
        .flatten()
        .any(|e| e.file_name().to_string_lossy().ends_with(".tmp"));
    anyhow::ensure!(torn_tmp, "torn-write must leave a torn temp file behind");
    let (r, p) = resume(&dir_torn)?;
    verify("torn-write", &r, &p, 5)?;

    // Scenario 3: a worker-pool panic at step 3 (step completes on the
    // serial fallback — graceful degradation), then a kill at step 15.
    let dir_panic = base.join("panic");
    child(&dir_panic, "panic-job@3,abort@15")?;
    let (r, p) = resume(&dir_panic)?;
    verify("pool-panic", &r, &p, 10)?;

    // Scenario 4: corrupt the newest checkpoint (bit flip) — the scan
    // must skip it and fall back to the next-newest valid file.
    // dir_abort now holds checkpoints from the completed resume run
    // (steps 10, 15, 20 plus the child's 5).
    let newest = checkpoint::list_checkpoints(&dir_abort)
        .pop()
        .ok_or_else(|| anyhow::anyhow!("no checkpoints after the abort cycle"))?;
    anyhow::ensure!(newest.0 == 20, "newest checkpoint is step {}, expected 20", newest.0);
    let mut bytes = std::fs::read(&newest.1)?;
    let n = bytes.len();
    bytes[n - 7] ^= 0x20;
    std::fs::write(&newest.1, &bytes)?;
    let (r, p) = resume(&dir_abort)?;
    verify("corrupted-latest", &r, &p, 15)?;

    // Scenario 5: a structurally valid checkpoint from a DIFFERENT config
    // must be refused with a fingerprint error, never silently resumed.
    let mut foreign = crashtest_cfg(Some(&dir_torn), threads, true, Arc::new(FaultPlan::none()));
    foreign.lr = 0.05;
    let err = NativeTrainer::new(foreign)
        .run()
        .err()
        .ok_or_else(|| anyhow::anyhow!("resume with a foreign config must fail"))?;
    anyhow::ensure!(
        format!("{err:#}").contains("fingerprint"),
        "foreign-config resume failed for the wrong reason: {err:#}"
    );
    println!("[crashtest] fingerprint: foreign config rejected with a clear error");

    let _ = std::fs::remove_dir_all(&base);
    println!("[crashtest] PASS: all kill/resume cycles bit-identical");
    Ok(())
}

/// `repro audit`: the static invariant checker (`rdfft::analysis`) over
/// the repo's own sources. Prints one line per unsuppressed violation,
/// optionally writes the machine-readable AUDIT.json, and exits
/// non-zero unless the tree is clean — `scripts/ci.sh` runs this as a
/// hard gate before the test suite.
fn cmd_audit(args: &Args) -> Result<()> {
    let roots = match args.get("root") {
        Some(dir) => vec![PathBuf::from(dir)],
        None => {
            if args.has("root") {
                bail!("--root expects a directory");
            }
            rdfft::analysis::default_roots(Path::new("."))?
        }
    };
    let report = rdfft::analysis::audit_paths(&roots)?;
    print!("{}", report.render());
    if let Some(json) = args.get("json") {
        std::fs::write(json, report.to_json())?;
        println!("[audit] wrote {json}");
    } else if args.has("json") {
        bail!("--json expects a file path");
    }
    if !report.clean() {
        bail!("audit found {} unsuppressed violation(s)", report.findings.len());
    }
    Ok(())
}

/// `repro serve`: run the micro-batching inference server on a TCP
/// socket. The session (model + arena) lives on a dedicated serve
/// thread; connection threads only parse lines and park on tickets, so
/// any number of clients share one deterministic batcher.
fn cmd_serve(args: &Args) -> Result<()> {
    let d = args.get_num("d", 64)?;
    let method = match args.get("layer").or_else(|| args.get("method")).unwrap_or("circulant") {
        "circulant" => {
            let p = args.get_num("p", 16)?;
            if d % p != 0 {
                bail!("--d {d} must be a multiple of --p {p}");
            }
            Method::Circulant { backend: Backend::RdFft, p }
        }
        "longconv" => {
            let k = args.get_num("k", 16)?;
            if k == 0 || k > d {
                bail!("--k {k} must be in 1..=d (d={d})");
            }
            Method::LongConv { k }
        }
        other => bail!("unknown serve layer {other:?} (circulant|longconv)"),
    };
    let window = args.get_num("window", 1)?;
    let threads = args.get_num("threads", 0)?;
    let cfg = StackConfig {
        d,
        depth: args.get_num("depth", 2)?,
        ctx: args.get_num("ctx", 8)?,
        method,
        seed: args.get_num("seed", 0)? as u64,
        ..Default::default()
    };
    let (handle, session) = spawn_session(
        move || {
            let exec = if threads == 0 { ExecCtx::global() } else { ExecCtx::with_threads(threads) };
            SpectralStack::with_exec(cfg, exec)
        },
        window,
    )
    .map_err(|e| anyhow::anyhow!("starting serve session: {e}"))?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:4915");
    let listener = std::net::TcpListener::bind(addr)?;
    println!(
        "[serve] listening on {} (ctx {} bytes per hex line, window {window}, d {d})",
        listener.local_addr()?,
        handle.ctx(),
    );
    serve_tcp(listener, handle)?;
    // Unreachable in normal operation (the accept loop runs forever), but
    // keeps shutdown clean if the listener ever errors out.
    session.shutdown();
    Ok(())
}

/// `repro slam`: the serving load generator + acceptance harness
/// (coordinator::serve_bench). Exits non-zero when a hard gate fails,
/// mirroring the `engine` bench's policy.
fn cmd_slam(args: &Args) -> Result<()> {
    let cfg = SlamConfig {
        d: args.get_num("d", 64)?,
        depth: args.get_num("depth", 2)?,
        p: args.get_num("p", 16)?,
        ctx: args.get_num("ctx", 8)?,
        seed: args.get_num("seed", 0)? as u64,
        requests: args.get_num("requests", 512)?,
        window: args.get_num("window", 8)?,
        clients: args.get_num("clients", 4)?,
        threads: args.get_num("threads", 0)?,
        rounds: args.get_num("rounds", 3)?,
        bench_json: Some(PathBuf::from(args.get("bench").unwrap_or("BENCH_serve.json"))),
        max_p99_ms: match args.get("max-p99-ms") {
            Some(raw) => match raw.parse::<f64>() {
                Ok(v) => Some(v),
                Err(_) => bail!("--max-p99-ms expects a number in ms, got {raw:?}"),
            },
            None => {
                if args.has("max-p99-ms") {
                    bail!("--max-p99-ms expects a number in ms");
                }
                None
            }
        },
    };
    if !slam(&cfg)? {
        bail!(
            "slam gate failed: responses incomplete, non-deterministic, steady-state \
             allocations detected, p99 over budget, or coalescing below the 0.9x floor"
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let args = Args::parse(&argv[1..]);
    // Process-wide SIMD kill switch: must run before the first transform
    // so the cached dispatch decision never flips mid-run. The env-var
    // form (RDFFT_FORCE_SCALAR=1) is handled inside the dispatcher and
    // drives the CI force-scalar matrix leg.
    if args.has("force-scalar") {
        rdfft::rdfft::simd::force_scalar_global();
    }
    match cmd.as_str() {
        "train" => cmd_train(&args)?,
        "train-native" => cmd_train_native(&args)?,
        "crashtest" => cmd_crashtest(&args)?,
        "table-native" => experiments::table_native(args.has("fast")),
        "table1" => experiments::table1(args.has("fast")),
        "table2" => experiments::table2(),
        "table3" => experiments::table3(),
        "table4" => experiments::table4(args.has("fast")),
        "fig2" => experiments::fig2(args.get_num("d", 1024)?, args.has("fast")),
        "audit" => cmd_audit(&args)?,
        "alloc-audit" => experiments::alloc_audit(),
        "optim" => experiments::optim_ablation(),
        "engine" => {
            if args.has("fourstep-smoke") {
                if !experiments::fourstep_smoke() {
                    bail!("fourstep smoke failed: large-n tier disagrees with the direct sweep");
                }
            } else if !experiments::bench_rdfft_engine(args.has("fast")) {
                bail!(
                    "engine gate failed: batch=1 latency regressed vs scalar, \
                     the fused circulant pipeline regressed vs unfused, or a \
                     large-n/width-8 hard floor was crossed"
                );
            }
        }
        "serve" => cmd_serve(&args)?,
        "slam" => cmd_slam(&args)?,
        "report" => {
            experiments::table1(true);
            experiments::fig2(1024, true);
            experiments::table2();
            experiments::table3();
            experiments::table4(true);
            experiments::table_native(true);
            experiments::alloc_audit();
            experiments::optim_ablation();
            let _ = experiments::bench_rdfft_engine(true);
        }
        _ => usage(),
    }
    Ok(())
}
