//! Multi-layer spectral model over the layer substrate — the native
//! training pipeline's network.
//!
//! The stack is a byte-level n-gram language model shaped so that every
//! hot tensor flows through the batch-major rdFFT engine when the blocks
//! are circulant:
//!
//! ```text
//! bytes [b, ctx] ──frozen embed+position sum──► features [b, d]
//!    ─► h = ReLU(h + block_0(h))   block ∈ {Dense, LoRA, CirculantLayer}
//!    ─► h = ReLU(h + block_1(h)) ─► … ─► depth blocks
//!    ─► trainable Dense readout [vocab, d] ─► logits [b, vocab]
//! ```
//!
//! Blocks are **residual**: the identity skip plays the frozen backbone
//! every adapter method rides on (LoRA's `W₀ + ΔW` with `W₀ = I` per
//! block), so near-zero-initialized circulant adapters neither attenuate
//! the signal at depth nor block gradient flow.
//!
//! Memory discipline mirrors the single-layer experiments: the frozen
//! embedding is `Weights`, block parameters are `Trainable`, their grad
//! accumulators `Gradients`, and activations `Intermediates`. ReLU state
//! between blocks is a **sign-bit mask** (1 bit per activation, tracked
//! via [`crate::memtrack::Registration`]) rather than a saved activation
//! copy — the incoming activation itself is saved *inside* the next block
//! (in place, for the rdFFT backend), so the stack adds no per-layer
//! activation copies of its own.

use super::layers::{Dense, Layer, ShardSaved};
use super::optim::{tree_reduce_with, OptimizerBank};
use super::tensor::{matmul_nt, relu_inplace, softmax_xent, softmax_xent_shard, Tensor};
use super::train::Method;
use crate::memtrack::{self, Category};
use crate::runtime::pool::{ExecCtx, JobPanic};

/// Configuration of a [`SpectralStack`].
#[derive(Debug, Clone)]
pub struct StackConfig {
    /// Hidden width (must satisfy the block method's constraints, e.g. a
    /// multiple of `p` for circulant blocks).
    pub d: usize,
    /// Number of adapted blocks between embedding and readout.
    pub depth: usize,
    /// Vocabulary (byte tokenizer: 256).
    pub vocab: usize,
    /// Context bytes per prediction.
    pub ctx: usize,
    /// The layer type every block instantiates (the Table-1 method axis).
    pub method: Method,
    pub seed: u64,
}

impl Default for StackConfig {
    fn default() -> Self {
        StackConfig {
            d: 64,
            depth: 2,
            vocab: 256,
            ctx: 8,
            method: Method::Circulant {
                backend: super::layers::Backend::RdFft,
                p: 16,
            },
            seed: 0,
        }
    }
}

/// ReLU applied in place, with the surviving lanes recorded as a bit mask
/// (b·d bits, tracked). Backward zeroes the masked-off lanes of the
/// incoming gradient.
struct ReluMask {
    bits: Vec<u64>,
    len: usize,
    _reg: memtrack::Registration,
}

impl ReluMask {
    fn forward(t: &mut Tensor) -> ReluMask {
        let s = t.as_mut_slice();
        let words = (s.len() + 63) / 64;
        let reg = memtrack::Registration::new(words * 8, Category::Intermediates);
        let mut bits = vec![0u64; words];
        for (i, v) in s.iter_mut().enumerate() {
            if *v > 0.0 {
                bits[i / 64] |= 1u64 << (i % 64);
            } else {
                *v = 0.0;
            }
        }
        ReluMask { bits, len: s.len(), _reg: reg }
    }

    fn backward(&self, g: &mut Tensor) {
        let s = g.as_mut_slice();
        assert_eq!(s.len(), self.len, "gradient shape must match the masked activation");
        for (i, v) in s.iter_mut().enumerate() {
            if self.bits[i / 64] & (1u64 << (i % 64)) == 0 {
                *v = 0.0;
            }
        }
    }
}

/// The multi-layer model: frozen embedding, `depth` adapted blocks with
/// ReLU between them, trainable dense readout.
pub struct SpectralStack {
    cfg: StackConfig,
    /// Frozen byte embedding `[vocab, d]` (the pretrained backbone).
    embed: Tensor,
    /// Per-position scale of the context sum (fixed, so byte order
    /// matters to the features).
    pos_scale: Vec<f32>,
    blocks: Vec<Box<dyn Layer>>,
    readout: Dense,
    /// ReLU masks saved by the last forward, one per block.
    masks: Vec<ReluMask>,
    /// Execution context installed into every block: one pool + tuning
    /// for the whole model instead of ad-hoc `EngineConfig`s per call.
    exec: ExecCtx,
}

impl SpectralStack {
    pub fn new(cfg: StackConfig) -> Self {
        Self::build(cfg, None, ExecCtx::global())
    }

    /// [`SpectralStack::new`] with an explicit execution context (pool +
    /// engine tuning + scratch category), threaded into every block.
    pub fn with_exec(cfg: StackConfig, exec: ExecCtx) -> Self {
        Self::build(cfg, None, exec)
    }

    /// Heterogeneous stack: block `k` uses `methods[k]` instead of
    /// `cfg.method` (e.g. the determinism suite's Dense + LoRA + rdFFT
    /// tower). `methods.len()` must equal `cfg.depth`.
    pub fn new_mixed(cfg: StackConfig, methods: &[Method]) -> Self {
        Self::build(cfg, Some(methods), ExecCtx::global())
    }

    /// [`SpectralStack::new_mixed`] with an explicit execution context.
    pub fn new_mixed_with_exec(cfg: StackConfig, methods: &[Method], exec: ExecCtx) -> Self {
        Self::build(cfg, Some(methods), exec)
    }

    fn build(cfg: StackConfig, methods: Option<&[Method]>, exec: ExecCtx) -> Self {
        if let Some(ms) = methods {
            assert_eq!(ms.len(), cfg.depth, "one method per block");
        }
        let scale = (1.0 / cfg.d as f32).sqrt();
        let embed = Tensor::rand(cfg.vocab, cfg.d, scale, cfg.seed + 100, Category::Weights);
        let pos_scale: Vec<f32> = (0..cfg.ctx).map(|j| 1.0 / (1.0 + j as f32)).collect();
        let blocks: Vec<Box<dyn Layer>> = (0..cfg.depth)
            .map(|k| {
                let m = methods.map(|ms| ms[k]).unwrap_or(cfg.method);
                m.build_with(cfg.d, cfg.seed + k as u64, &exec)
            })
            .collect();
        let readout = Dense::new(cfg.vocab, cfg.d, cfg.seed + 999);
        SpectralStack { cfg, embed, pos_scale, blocks, readout, masks: Vec::new(), exec }
    }

    pub fn config(&self) -> &StackConfig {
        &self.cfg
    }

    /// The execution context the stack's blocks dispatch on.
    pub fn exec(&self) -> &ExecCtx {
        &self.exec
    }

    /// Trainable scalars across blocks and readout.
    pub fn num_trainable(&self) -> usize {
        self.blocks.iter().map(|b| b.num_trainable()).sum::<usize>()
            + self.readout.num_trainable()
    }

    /// Embed a flat `[b * ctx]` byte context batch into `[b, d]` features:
    /// position-scaled sums of frozen embedding rows (no matmul — the
    /// embedding is a lookup, like any LM's input layer).
    pub fn features(&self, ctx_bytes: &[u8]) -> Tensor {
        let ctx = self.cfg.ctx;
        assert!(
            !ctx_bytes.is_empty() && ctx_bytes.len() % ctx == 0,
            "context batch must be a multiple of ctx={ctx}"
        );
        let b = ctx_bytes.len() / ctx;
        let mut h = Tensor::zeros_cat(b, self.cfg.d, Category::Intermediates);
        self.features_into(ctx_bytes, &mut h);
        h
    }

    /// Allocation-free [`SpectralStack::features`]: embeds into a
    /// caller-provided `[b, d]` tensor (the serve arena's ping buffer).
    pub fn features_into(&self, ctx_bytes: &[u8], h: &mut Tensor) {
        let ctx = self.cfg.ctx;
        let b = ctx_bytes.len() / ctx;
        assert_eq!(b * ctx, ctx_bytes.len(), "context batch must be a multiple of ctx={ctx}");
        assert_eq!((h.rows, h.cols), (b, self.cfg.d), "feature buffer shape");
        h.fill(0.0);
        for r in 0..b {
            let row = h.row_mut(r);
            for (j, &byte) in ctx_bytes[r * ctx..(r + 1) * ctx].iter().enumerate() {
                let e = self.embed.row(byte as usize);
                let s = self.pos_scale[j];
                for (o, v) in row.iter_mut().zip(e) {
                    *o += s * v;
                }
            }
        }
    }

    /// Forward the whole stack; returns logits `[b, vocab]`. Saves
    /// backward state (inside the blocks + the ReLU masks).
    pub fn forward(&mut self, ctx_bytes: &[u8]) -> Tensor {
        let mut h = self.features(ctx_bytes);
        self.masks.clear();
        for blk in &mut self.blocks {
            // h ← ReLU(h + block(h)), through the layer's residual hook:
            // the rdFFT circulant block adds the skip as spectra inside
            // its fused single-sweep pipeline (no activation copy); other
            // layers fall back to the clone-and-add default.
            let mut t = blk.forward_residual(h);
            self.masks.push(ReluMask::forward(&mut t));
            h = t;
        }
        self.readout.forward(h)
    }

    /// Backward from the loss gradient w.r.t. the logits; accumulates
    /// parameter gradients in every layer. The grad w.r.t. the features is
    /// discarded (the embedding is frozen).
    pub fn backward(&mut self, dlogits: Tensor) {
        let mut g = self.readout.backward(dlogits);
        for (blk, mask) in self.blocks.iter_mut().rev().zip(self.masks.drain(..).rev()) {
            mask.backward(&mut g);
            // d(h + block(h)) = g + blockᵀ(g), via the residual hook
            // (fused skip gradient for the rdFFT circulant block).
            g = blk.backward_residual(g);
        }
    }

    /// One full training step on a context batch: forward, softmax
    /// cross-entropy, backward, optimizer update (+ grad zeroing).
    /// Returns the batch loss.
    pub fn train_step(
        &mut self,
        ctx_bytes: &[u8],
        labels: &[usize],
        bank: &mut OptimizerBank,
    ) -> f32 {
        let logits = self.forward(ctx_bytes);
        let mut dl = Tensor::zeros_cat(logits.rows, logits.cols, Category::Intermediates);
        let loss = softmax_xent(&logits, labels, &mut dl);
        drop(logits);
        self.backward(dl);
        let mut idx = 0usize;
        self.for_each_param(&mut |p, g| {
            bank.apply(idx, p, g);
            for v in g.iter_mut() {
                *v = 0.0;
            }
            idx += 1;
        });
        loss
    }

    /// True when every block implements the replica-free shard hooks
    /// (the readout always does — the stack drives it directly), i.e.
    /// [`SpectralStack::train_step_sharded`] is available.
    pub fn supports_shard_exec(&self) -> bool {
        self.blocks.iter().all(|b| b.supports_shard_exec())
    }

    /// One data-parallel training step: the batch's rows are split into
    /// the **fixed** shard structure of [`ShardArena`] (a function of the
    /// batch size only — never of the worker count), each shard runs a
    /// replica-free forward+backward as a pool job on the stack's own
    /// [`ExecCtx`] (the one its blocks dispatch on — a single context
    /// governs the whole model, so trainer fan-out and layer engine calls
    /// can never target divergent pools; parameters shared immutably,
    /// saved state and gradient accumulation local to the shard), and the
    /// shard gradients/losses are combined by a deterministic fixed-order
    /// tree reduction. Results are therefore bit-identical run-to-run at
    /// **any** thread count — `--threads 4` reproduces `--threads 1`
    /// exactly.
    ///
    /// A panicking shard job surfaces as `Err(JobPanic)` **before any
    /// reduction or optimizer mutation** — parameters, optimizer state,
    /// and RNG are exactly as they were when the step began, so the
    /// caller can retry the whole step (the native trainer retries once
    /// on [`SpectralStack::train_step_sharded_serial`]). The retried step
    /// is bit-identical to an unfailed one: `begin_shard_step` is
    /// idempotent and the arena re-zeroes.
    pub fn train_step_sharded(
        &mut self,
        ctx_bytes: &[u8],
        labels: &[usize],
        bank: &mut OptimizerBank,
        arena: &mut ShardArena,
    ) -> Result<f32, JobPanic> {
        assert!(
            self.supports_shard_exec(),
            "a block without shard support must train via train_step"
        );
        let b = labels.len();
        assert!(b > 0, "empty batch");
        assert_eq!(ctx_bytes.len(), b * self.cfg.ctx, "context batch must be b*ctx bytes");
        let shard_rows = (b + GRAD_SHARDS - 1) / GRAD_SHARDS;

        // Shared prep on the submitting thread: parameter spectra for the
        // circulant blocks, zeroed shard buffers.
        for blk in &mut self.blocks {
            blk.begin_shard_step();
        }
        arena.zero();

        // Fan the shards out. The final shard runs on this thread too via
        // the pool's self-help while waiting on the latch; worker-side
        // activation scratch merges back into this thread's memtrack at
        // scope end.
        let ctx_len = self.cfg.ctx;
        let stack: &SpectralStack = self;
        let layout = &arena.layout;
        let scope_result = stack.exec.pool().scope(|sc| {
            let mut row0 = 0usize;
            for (shard_idx, (shard, loss_slot)) in
                arena.shards.iter_mut().zip(arena.losses.iter_mut()).enumerate()
            {
                if row0 >= b {
                    break;
                }
                let rows = shard_rows.min(b - row0);
                let bytes = &ctx_bytes[row0 * ctx_len..(row0 + rows) * ctx_len];
                let lbls = &labels[row0..row0 + rows];
                // Fault consult on the submitting thread (fire-once, so
                // one query per shard): the chosen victim panics inside
                // its pool job, exercising the JobPanic surfacing path.
                let boom = stack.exec.faults().take_shard_panic(shard_idx, GRAD_SHARDS);
                sc.submit(move || {
                    if boom {
                        panic!("injected fault: shard job {shard_idx} panic");
                    }
                    *loss_slot = stack.shard_grad_pass(bytes, lbls, shard, layout, b);
                });
                row0 += rows;
            }
        });
        // Surface the panic BEFORE any reduction/optimizer mutation so the
        // model state is untouched and the step can be retried exactly.
        if let Err(p) = scope_result {
            return Err(p);
        }
        Ok(self.reduce_and_apply(arena, bank, b))
    }

    /// Scoped-serial fallback for a step whose pool fan-out panicked: the
    /// identical shard structure and reduction, with every shard pass run
    /// inline on the calling thread. Produces bit-identical results to
    /// [`SpectralStack::train_step_sharded`] (same shard jobs, same
    /// fixed-order combines — only the scheduling differs). Injected
    /// shard faults are still consulted, so a plan scheduling two panics
    /// at one step makes the retry fail too (the repeat-failure
    /// hard-fail path).
    pub fn train_step_sharded_serial(
        &mut self,
        ctx_bytes: &[u8],
        labels: &[usize],
        bank: &mut OptimizerBank,
        arena: &mut ShardArena,
    ) -> f32 {
        assert!(
            self.supports_shard_exec(),
            "a block without shard support must train via train_step"
        );
        let b = labels.len();
        assert!(b > 0, "empty batch");
        assert_eq!(ctx_bytes.len(), b * self.cfg.ctx, "context batch must be b*ctx bytes");
        let shard_rows = (b + GRAD_SHARDS - 1) / GRAD_SHARDS;

        for blk in &mut self.blocks {
            blk.begin_shard_step();
        }
        arena.zero();

        let ctx_len = self.cfg.ctx;
        let stack: &SpectralStack = self;
        let layout = &arena.layout;
        let mut row0 = 0usize;
        for (shard_idx, (shard, loss_slot)) in
            arena.shards.iter_mut().zip(arena.losses.iter_mut()).enumerate()
        {
            if row0 >= b {
                break;
            }
            let rows = shard_rows.min(b - row0);
            let bytes = &ctx_bytes[row0 * ctx_len..(row0 + rows) * ctx_len];
            let lbls = &labels[row0..row0 + rows];
            if stack.exec.faults().take_shard_panic(shard_idx, GRAD_SHARDS) {
                panic!("injected fault: shard job {shard_idx} panic (serial)");
            }
            *loss_slot = stack.shard_grad_pass(bytes, lbls, shard, layout, b);
            row0 += rows;
        }
        self.reduce_and_apply(arena, bank, b)
    }

    /// Shared tail of both sharded step paths: deterministic fixed-order
    /// tree reductions of the shard losses/gradients, per-block gradient
    /// post-processing, and the same fold→apply→zero visitor tail as the
    /// serial step. One implementation guarantees the pool path and the
    /// serial fallback combine results identically.
    fn reduce_and_apply(
        &mut self,
        arena: &mut ShardArena,
        bank: &mut OptimizerBank,
        b: usize,
    ) -> f32 {
        // Deterministic fixed-order tree reductions (losses and grads):
        // the combine sequence depends only on the slot count.
        tree_reduce_with(&mut arena.losses, |a, b| *a += *b);
        let loss_sum = arena.losses[0];
        tree_reduce_with(&mut arena.shards, |dst, src| {
            for (d, s) in dst.grads.iter_mut().zip(&src.grads) {
                d.axpy(s, 1.0);
            }
        });

        // Per-block post-processing of the reduced gradients (the rdFFT
        // blocks apply their one shared inverse transform here), then the
        // same visitor tail as the serial step: fold into the layers' own
        // grad buffers, optimizer update, zero.
        {
            let reduced = &mut arena.shards[0].grads;
            for (k, blk) in self.blocks.iter_mut().enumerate() {
                let (off, a) = (arena.layout.offset[k], arena.layout.arity[k]);
                blk.finish_shard_grads(&mut reduced[off..off + a]);
            }
        }
        let reduced = &arena.shards[0];
        let mut idx = 0usize;
        self.for_each_param(&mut |p, g| {
            let r = reduced.grads[idx].as_slice();
            debug_assert_eq!(r.len(), g.len(), "arena layout must mirror for_each_param");
            for (gv, rv) in g.iter_mut().zip(r) {
                *gv += *rv;
            }
            bank.apply(idx, p, g);
            for v in g.iter_mut() {
                *v = 0.0;
            }
            idx += 1;
        });
        (loss_sum / b as f64) as f32
    }

    /// Forward+backward one shard with every piece of step state local to
    /// the call: parameters read-only, activations/saved tensors owned by
    /// the shard job, parameter gradients accumulated into the shard's
    /// arena buffers. Returns the shard's f64 row-loss sum (gradients are
    /// already scaled by `1/full_batch`, so shards compose exactly).
    fn shard_grad_pass(
        &self,
        ctx_bytes: &[u8],
        labels: &[usize],
        shard: &mut GradShard,
        layout: &ShardLayout,
        full_batch: usize,
    ) -> f64 {
        let mut h = self.features(ctx_bytes);
        let mut saved: Vec<ShardSaved> = Vec::with_capacity(self.blocks.len());
        let mut masks: Vec<ReluMask> = Vec::with_capacity(self.blocks.len());
        for blk in &self.blocks {
            let (mut t, s) = blk.shard_forward_residual(h);
            masks.push(ReluMask::forward(&mut t));
            saved.push(s);
            h = t;
        }
        let logits = self.readout.shard_forward(&h);
        let mut dl = Tensor::zeros_cat(logits.rows, logits.cols, Category::Intermediates);
        let loss = softmax_xent_shard(&logits, labels, &mut dl, full_batch);
        drop(logits);

        // Arena layout: block grad tensors in block order, readout last
        // (precomputed once in ShardArena::new).
        let (block_grads, readout_grads) = shard.grads.split_at_mut(layout.block_tensors);
        let mut g = self.readout.shard_backward(&dl, &h, &mut readout_grads[0]);
        drop(dl);
        drop(h);
        for idx in (0..self.blocks.len()).rev() {
            let mask = masks.pop().expect("one mask per block");
            let sv = saved.pop().expect("one saved state per block");
            mask.backward(&mut g);
            let (off, a) = (layout.offset[idx], layout.arity[idx]);
            g = self.blocks[idx].shard_backward_residual(g, sv, &mut block_grads[off..off + a]);
        }
        loss
    }

    /// Loss on a batch without training (drops all saved state after).
    pub fn eval_loss(&mut self, ctx_bytes: &[u8], labels: &[usize]) -> f32 {
        let logits = self.forward(ctx_bytes);
        let mut scratch = Tensor::zeros_cat(logits.rows, logits.cols, Category::Intermediates);
        let loss = softmax_xent(&logits, labels, &mut scratch);
        self.clear_saved();
        loss
    }

    /// Visit every `(param, grad)` pair: blocks first (in order), then the
    /// readout — the stable order [`OptimizerBank`] requires.
    pub fn for_each_param(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        for blk in &mut self.blocks {
            blk.for_each_param(f);
        }
        self.readout.for_each_param(f);
    }

    pub fn clear_saved(&mut self) {
        for blk in &mut self.blocks {
            blk.clear_saved();
        }
        self.readout.clear_saved();
        self.masks.clear();
    }

    /// Snapshot every trainable parameter (checkpointing): per-tensor
    /// lengths plus the flattened values, both in `for_each_param` visit
    /// order. The visitor guarantees canonical **time-domain** values (it
    /// transforms spectral-resident circulant blocks back first), so the
    /// export is an exact image of the state the optimizer updates.
    pub fn export_params(&mut self) -> (Vec<usize>, Vec<f32>) {
        let mut lens = Vec::new();
        let mut flat = Vec::new();
        self.for_each_param(&mut |p, _g| {
            lens.push(p.len());
            flat.extend_from_slice(p);
        });
        (lens, flat)
    }

    /// Restore parameters from an [`SpectralStack::export_params`]-shaped
    /// flat vector (same visit order, same canonical time domain). Grad
    /// accumulators are zeroed — a freshly resumed step must start from
    /// the same clean slate a live step would. Length mismatches are
    /// rejected without partially mutating anything the caller could
    /// mistake for a successful restore.
    pub fn import_params(&mut self, flat: &[f32]) -> Result<(), String> {
        // Pre-check the total length against the model's own shape so a
        // mismatch fails before any tensor is written.
        let mut need = 0usize;
        self.for_each_param(&mut |p, _g| need += p.len());
        if need != flat.len() {
            return Err(format!(
                "checkpoint carries {} parameter floats, model needs {}",
                flat.len(),
                need
            ));
        }
        let mut off = 0usize;
        self.for_each_param(&mut |p, g| {
            p.copy_from_slice(&flat[off..off + p.len()]);
            off += p.len();
            for v in g.iter_mut() {
                *v = 0.0;
            }
        });
        Ok(())
    }

    /// True when every block implements the allocation-free inference
    /// hook, i.e. [`SpectralStack::infer_forward`] is available (the
    /// readout always is — the stack drives it directly into the arena).
    pub fn supports_infer_exec(&self) -> bool {
        self.blocks.iter().all(|b| b.supports_infer_exec())
    }

    /// One-time preparation before serving: every block transforms its
    /// parameters to the representation inference reads immutably (the
    /// rdFFT block moves `c` to block spectra — the per-model `ĉ` shared
    /// across every coalesced request). Idempotent; call again after any
    /// parameter mutation.
    pub fn begin_serve(&mut self) {
        for blk in &mut self.blocks {
            blk.begin_shard_step();
        }
    }

    /// Inference-only forward of one fixed serve tile: embeds
    /// `arena.tile() * ctx` flat context bytes and runs the residual
    /// blocks + readout entirely inside the arena's ping-pong buffers —
    /// `&self`, nothing saved for backward, zero tracked allocations.
    /// ReLU is applied plainly (no sign-bit mask: there is no backward).
    ///
    /// Every op is row-independent (per-sample fused circulant sweep,
    /// per-row matmul, elementwise ReLU), so each logits row is a pure
    /// function of its own context bytes and the parameters: responses
    /// are bit-identical no matter which other requests share the tile,
    /// in which order requests arrived, or how many pool threads ran the
    /// engine — the serve determinism contract.
    // audit: no_alloc
    pub fn infer_forward(&self, ctx_bytes: &[u8], arena: &mut InferArena) {
        assert_eq!(
            ctx_bytes.len(),
            arena.tile * self.cfg.ctx,
            "serve tile must be padded to exactly tile*ctx bytes"
        );
        self.features_into(ctx_bytes, &mut arena.h);
        for blk in &self.blocks {
            blk.infer_forward_residual(&mut arena.h, &mut arena.y);
            relu_inplace(&mut arena.y);
            std::mem::swap(&mut arena.h, &mut arena.y);
        }
        matmul_nt(&arena.h, self.readout.weight(), &mut arena.logits);
    }
}

/// Reusable per-session inference buffers: two `[tile, d]` ping-pong
/// activation tensors plus the `[tile, vocab]` logits, allocated **once**
/// (tracked under the caller's category — the server uses
/// [`Category::Serve`]) and reused for every request the session serves.
/// The fixed tile height is the coalescing width; partial tiles are
/// padded and the padded rows' outputs ignored.
pub struct InferArena {
    tile: usize,
    h: Tensor,
    y: Tensor,
    logits: Tensor,
}

impl InferArena {
    pub fn new(stack: &SpectralStack, tile: usize, cat: Category) -> InferArena {
        assert!(
            stack.supports_infer_exec(),
            "every block needs inference support to build a serve arena"
        );
        assert!(tile > 0, "serve tile must hold at least one row");
        InferArena {
            tile,
            h: Tensor::zeros_cat(tile, stack.cfg.d, cat),
            y: Tensor::zeros_cat(tile, stack.cfg.d, cat),
            logits: Tensor::zeros_cat(tile, stack.cfg.vocab, cat),
        }
    }

    /// Fixed row count every [`SpectralStack::infer_forward`] call fills.
    pub fn tile(&self) -> usize {
        self.tile
    }

    /// Logits of the last tile served (`[tile, vocab]`).
    pub fn logits(&self) -> &Tensor {
        &self.logits
    }

    /// Tracked bytes held by the arena (reported by the server).
    pub fn tracked_bytes(&self) -> usize {
        (self.h.len() + self.y.len() + self.logits.len()) * 4
    }
}

/// Number of fixed gradient shards per data-parallel step. Deliberately a
/// constant: the shard structure is a function of the batch size alone
/// (never the worker count), which is what makes sharded training
/// bit-identical at any `--threads` value — workers merely execute a
/// fixed set of shard jobs. Parallelism per step is capped at this many
/// jobs; raising it trades arena memory for scaling headroom.
pub const GRAD_SHARDS: usize = 8;

/// One shard's gradient accumulation buffers — one tensor per trainable
/// tensor, in [`Layer::for_each_param`] order (blocks, then readout).
pub struct GradShard {
    grads: Vec<Tensor>,
}

/// Precomputed tensor-to-block mapping of the arena (a pure function of
/// the stack's construction): per block, how many grad tensors it owns
/// and where they start. Computed once in [`ShardArena::new`] so the
/// per-shard jobs never rebuild it.
struct ShardLayout {
    arity: Vec<usize>,
    offset: Vec<usize>,
    /// Total block tensors; the readout's single tensor follows them.
    block_tensors: usize,
}

/// Pooled scratch arena for [`SpectralStack::train_step_sharded`]:
/// [`GRAD_SHARDS`] gradient-shard buffer sets plus the per-shard loss
/// slots, allocated **once** (tracked under the chosen category) and
/// reused every step. Shard jobs still allocate their transient
/// activations per pass (as the serial step does, plus a one-row dx
/// workspace per circulant shard); the arena keeps the *accumulation*
/// state pooled.
pub struct ShardArena {
    shards: Vec<GradShard>,
    losses: Vec<f64>,
    layout: ShardLayout,
}

impl ShardArena {
    /// Size the arena for `stack` (shapes mirror its `for_each_param`
    /// visit). `cat` is the memtrack category the buffers are charged to
    /// — the trainer passes its context's
    /// [`ExecCtx::scratch_category`].
    pub fn new(stack: &SpectralStack, cat: Category) -> ShardArena {
        assert!(
            stack.supports_shard_exec(),
            "every block needs shard support to build a shard arena"
        );
        let mut shapes: Vec<(usize, usize)> = Vec::new();
        let mut arity = Vec::with_capacity(stack.blocks.len());
        let mut offset = Vec::with_capacity(stack.blocks.len());
        for blk in &stack.blocks {
            let block_shapes = blk.grad_shapes();
            offset.push(shapes.len());
            arity.push(block_shapes.len());
            shapes.extend(block_shapes);
        }
        let block_tensors = shapes.len();
        shapes.extend(stack.readout.grad_shapes());
        let shards = (0..GRAD_SHARDS)
            .map(|_| GradShard {
                grads: shapes
                    .iter()
                    .map(|&(r, c)| Tensor::zeros_cat(r, c, cat))
                    .collect(),
            })
            .collect();
        ShardArena {
            shards,
            losses: vec![0.0; GRAD_SHARDS],
            layout: ShardLayout { arity, offset, block_tensors },
        }
    }

    fn zero(&mut self) {
        for sh in &mut self.shards {
            for g in &mut sh.grads {
                g.fill(0.0);
            }
        }
        for l in &mut self.losses {
            *l = 0.0;
        }
    }

    /// Tracked bytes held by the arena (reported by the trainer).
    pub fn tracked_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.grads.iter().map(|g| g.len() * 4).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::super::layers::Backend;
    use super::super::optim::OptimKind;
    use super::*;
    use crate::autograd::tensor::Rng;

    fn batch(b: usize, ctx: usize, seed: u64) -> (Vec<u8>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let bytes: Vec<u8> = (0..b * ctx).map(|_| (97 + rng.below(20)) as u8).collect();
        // deterministic target derived from the context so it is learnable
        let labels: Vec<usize> =
            (0..b).map(|r| (bytes[r * ctx] as usize + bytes[r * ctx + 1] as usize) % 23).collect();
        (bytes, labels)
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let cfg = StackConfig { d: 32, depth: 2, ctx: 4, ..Default::default() };
        let mut s1 = SpectralStack::new(cfg.clone());
        let mut s2 = SpectralStack::new(cfg);
        let (bytes, _) = batch(3, 4, 1);
        let y1 = s1.forward(&bytes);
        let y2 = s2.forward(&bytes);
        assert_eq!((y1.rows, y1.cols), (3, 256));
        assert_eq!(y1.as_slice(), y2.as_slice(), "same seed must give the same logits");
    }

    #[test]
    fn relu_mask_backward_matches_saved_output_rule() {
        use crate::autograd::tensor::relu_backward_inplace;
        let mut t = Tensor::from_vec(
            1,
            6,
            vec![-1.0, 2.0, 0.0, 3.0, -0.5, 1.0],
            Category::Other,
        );
        let reference = {
            let mut y = t.clone_as(Category::Other);
            crate::autograd::tensor::relu_inplace(&mut y);
            y
        };
        let mask = ReluMask::forward(&mut t);
        assert_eq!(t.as_slice(), reference.as_slice());
        let mut g1 = Tensor::from_vec(1, 6, vec![1.0; 6], Category::Other);
        let mut g2 = Tensor::from_vec(1, 6, vec![1.0; 6], Category::Other);
        mask.backward(&mut g1);
        relu_backward_inplace(&mut g2, &reference);
        assert_eq!(g1.as_slice(), g2.as_slice());
    }

    #[test]
    fn stack_memorizes_a_fixed_batch_all_methods() {
        for method in [
            Method::Circulant { backend: Backend::RdFft, p: 8 },
            Method::FullFinetune,
            Method::Lora { rank: 4 },
        ] {
            let cfg = StackConfig { d: 32, depth: 2, ctx: 4, method, seed: 3, ..Default::default() };
            let mut stack = SpectralStack::new(cfg);
            let mut bank = OptimizerBank::new(OptimKind::Sgd, 0.3);
            let (bytes, labels) = batch(8, 4, 7);
            let first = stack.train_step(&bytes, &labels, &mut bank);
            let mut last = first;
            for _ in 0..100 {
                last = stack.train_step(&bytes, &labels, &mut bank);
            }
            assert!(
                last < first * 0.6,
                "{method:?}: memorizing one batch must cut the loss: {first} -> {last}"
            );
        }
    }

    #[test]
    fn adam_also_trains_the_stack() {
        let cfg = StackConfig { d: 32, depth: 2, ctx: 4, seed: 5, ..Default::default() };
        let mut stack = SpectralStack::new(cfg);
        let mut bank =
            OptimizerBank::new(OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }, 0.01);
        let (bytes, labels) = batch(8, 4, 9);
        let first = stack.train_step(&bytes, &labels, &mut bank);
        let mut last = first;
        for _ in 0..100 {
            last = stack.train_step(&bytes, &labels, &mut bank);
        }
        // depth blocks + readout, one tensor each (circulant c + dense w)
        assert_eq!(bank.num_tensors(), 3);
        assert!(bank.state_bytes() > 0, "adam must hold per-tensor state");
        assert!(last < first * 0.6, "adam: {first} -> {last}");
    }

    #[test]
    fn param_visit_order_is_stable_and_complete() {
        let cfg = StackConfig { d: 32, depth: 3, ctx: 4, seed: 2, ..Default::default() };
        let mut stack = SpectralStack::new(cfg);
        let mut sizes = Vec::new();
        stack.for_each_param(&mut |p, g| {
            assert_eq!(p.len(), g.len());
            sizes.push(p.len());
        });
        let mut sizes2 = Vec::new();
        stack.for_each_param(&mut |p, _| sizes2.push(p.len()));
        assert_eq!(sizes, sizes2);
        assert_eq!(sizes.iter().sum::<usize>(), stack.num_trainable());
        assert_eq!(sizes.len(), 4); // 3 circulant blocks + readout
    }

    #[test]
    fn mixed_stack_builds_and_trains() {
        let cfg = StackConfig { d: 32, depth: 3, ctx: 4, seed: 6, ..Default::default() };
        let methods = [
            Method::FullFinetune,
            Method::Lora { rank: 4 },
            Method::Circulant { backend: Backend::RdFft, p: 8 },
        ];
        let mut stack = SpectralStack::new_mixed(cfg, &methods);
        assert!(stack.supports_shard_exec());
        let mut bank = OptimizerBank::new(OptimKind::Sgd, 0.3);
        let (bytes, labels) = batch(8, 4, 13);
        let first = stack.train_step(&bytes, &labels, &mut bank);
        let mut last = first;
        for _ in 0..60 {
            last = stack.train_step(&bytes, &labels, &mut bank);
        }
        assert!(last < first * 0.8, "mixed stack must train: {first} -> {last}");
    }

    #[test]
    fn sharded_step_tracks_classic_step_closely() {
        // Shard accumulation regroups float sums, so classic vs sharded
        // agree to float noise (bitwise identity is across thread counts,
        // asserted in rust/tests/parallel_training.rs).
        let cfg = StackConfig { d: 32, depth: 2, ctx: 4, seed: 8, ..Default::default() };
        let mut classic = SpectralStack::new(cfg.clone());
        let exec = ExecCtx::with_threads(2);
        let mut sharded = SpectralStack::with_exec(cfg, exec.clone());
        let mut arena = ShardArena::new(&sharded, exec.scratch_category());
        let mut bank_c = OptimizerBank::new(OptimKind::Sgd, 0.2);
        let mut bank_s = OptimizerBank::new(OptimKind::Sgd, 0.2);
        for step in 0..4 {
            let (bytes, labels) = batch(16, 4, 40 + step);
            let lc = classic.train_step(&bytes, &labels, &mut bank_c);
            let ls = sharded
                .train_step_sharded(&bytes, &labels, &mut bank_s, &mut arena)
                .expect("no faults injected");
            assert!((lc - ls).abs() < 1e-4, "step {step}: {lc} vs {ls}");
        }
        let mut pc = Vec::new();
        classic.for_each_param(&mut |p, _| pc.extend_from_slice(p));
        let mut ps = Vec::new();
        sharded.for_each_param(&mut |p, _| ps.extend_from_slice(p));
        assert_eq!(pc.len(), ps.len());
        for i in 0..pc.len() {
            assert!((pc[i] - ps[i]).abs() < 1e-4, "param {i}: {} vs {}", pc[i], ps[i]);
        }
    }

    #[test]
    fn eval_loss_leaves_no_saved_state() {
        let cfg = StackConfig { d: 32, depth: 2, ctx: 4, ..Default::default() };
        let mut stack = SpectralStack::new(cfg);
        let (bytes, labels) = batch(4, 4, 11);
        let l1 = stack.eval_loss(&bytes, &labels);
        let l2 = stack.eval_loss(&bytes, &labels);
        // (tolerance, not equality: the circulant parameter buffer
        // roundtrips through the frequency domain between evals)
        assert!((l1 - l2).abs() < 1e-4, "eval must be repeatable: {l1} vs {l2}");
        assert!(stack.masks.is_empty());
    }
}
