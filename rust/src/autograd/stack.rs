//! Multi-layer spectral model over the layer substrate — the native
//! training pipeline's network.
//!
//! The stack is a byte-level n-gram language model shaped so that every
//! hot tensor flows through the batch-major rdFFT engine when the blocks
//! are circulant:
//!
//! ```text
//! bytes [b, ctx] ──frozen embed+position sum──► features [b, d]
//!    ─► h = ReLU(h + block_0(h))   block ∈ {Dense, LoRA, CirculantLayer}
//!    ─► h = ReLU(h + block_1(h)) ─► … ─► depth blocks
//!    ─► trainable Dense readout [vocab, d] ─► logits [b, vocab]
//! ```
//!
//! Blocks are **residual**: the identity skip plays the frozen backbone
//! every adapter method rides on (LoRA's `W₀ + ΔW` with `W₀ = I` per
//! block), so near-zero-initialized circulant adapters neither attenuate
//! the signal at depth nor block gradient flow.
//!
//! Memory discipline mirrors the single-layer experiments: the frozen
//! embedding is `Weights`, block parameters are `Trainable`, their grad
//! accumulators `Gradients`, and activations `Intermediates`. ReLU state
//! between blocks is a **sign-bit mask** (1 bit per activation, tracked
//! via [`crate::memtrack::Registration`]) rather than a saved activation
//! copy — the incoming activation itself is saved *inside* the next block
//! (in place, for the rdFFT backend), so the stack adds no per-layer
//! activation copies of its own.

use super::layers::{Dense, Layer};
use super::optim::OptimizerBank;
use super::tensor::{softmax_xent, Tensor};
use super::train::Method;
use crate::memtrack::{self, Category};

/// Configuration of a [`SpectralStack`].
#[derive(Debug, Clone)]
pub struct StackConfig {
    /// Hidden width (must satisfy the block method's constraints, e.g. a
    /// multiple of `p` for circulant blocks).
    pub d: usize,
    /// Number of adapted blocks between embedding and readout.
    pub depth: usize,
    /// Vocabulary (byte tokenizer: 256).
    pub vocab: usize,
    /// Context bytes per prediction.
    pub ctx: usize,
    /// The layer type every block instantiates (the Table-1 method axis).
    pub method: Method,
    pub seed: u64,
}

impl Default for StackConfig {
    fn default() -> Self {
        StackConfig {
            d: 64,
            depth: 2,
            vocab: 256,
            ctx: 8,
            method: Method::Circulant {
                backend: super::layers::Backend::RdFft,
                p: 16,
            },
            seed: 0,
        }
    }
}

/// ReLU applied in place, with the surviving lanes recorded as a bit mask
/// (b·d bits, tracked). Backward zeroes the masked-off lanes of the
/// incoming gradient.
struct ReluMask {
    bits: Vec<u64>,
    len: usize,
    _reg: memtrack::Registration,
}

impl ReluMask {
    fn forward(t: &mut Tensor) -> ReluMask {
        let s = t.as_mut_slice();
        let words = (s.len() + 63) / 64;
        let reg = memtrack::Registration::new(words * 8, Category::Intermediates);
        let mut bits = vec![0u64; words];
        for (i, v) in s.iter_mut().enumerate() {
            if *v > 0.0 {
                bits[i / 64] |= 1u64 << (i % 64);
            } else {
                *v = 0.0;
            }
        }
        ReluMask { bits, len: s.len(), _reg: reg }
    }

    fn backward(&self, g: &mut Tensor) {
        let s = g.as_mut_slice();
        assert_eq!(s.len(), self.len, "gradient shape must match the masked activation");
        for (i, v) in s.iter_mut().enumerate() {
            if self.bits[i / 64] & (1u64 << (i % 64)) == 0 {
                *v = 0.0;
            }
        }
    }
}

/// The multi-layer model: frozen embedding, `depth` adapted blocks with
/// ReLU between them, trainable dense readout.
pub struct SpectralStack {
    cfg: StackConfig,
    /// Frozen byte embedding `[vocab, d]` (the pretrained backbone).
    embed: Tensor,
    /// Per-position scale of the context sum (fixed, so byte order
    /// matters to the features).
    pos_scale: Vec<f32>,
    blocks: Vec<Box<dyn Layer>>,
    readout: Dense,
    /// ReLU masks saved by the last forward, one per block.
    masks: Vec<ReluMask>,
}

impl SpectralStack {
    pub fn new(cfg: StackConfig) -> Self {
        let scale = (1.0 / cfg.d as f32).sqrt();
        let embed = Tensor::rand(cfg.vocab, cfg.d, scale, cfg.seed + 100, Category::Weights);
        let pos_scale: Vec<f32> = (0..cfg.ctx).map(|j| 1.0 / (1.0 + j as f32)).collect();
        let blocks: Vec<Box<dyn Layer>> =
            (0..cfg.depth).map(|k| cfg.method.build(cfg.d, cfg.seed + k as u64)).collect();
        let readout = Dense::new(cfg.vocab, cfg.d, cfg.seed + 999);
        SpectralStack { cfg, embed, pos_scale, blocks, readout, masks: Vec::new() }
    }

    pub fn config(&self) -> &StackConfig {
        &self.cfg
    }

    /// Trainable scalars across blocks and readout.
    pub fn num_trainable(&self) -> usize {
        self.blocks.iter().map(|b| b.num_trainable()).sum::<usize>()
            + self.readout.num_trainable()
    }

    /// Embed a flat `[b * ctx]` byte context batch into `[b, d]` features:
    /// position-scaled sums of frozen embedding rows (no matmul — the
    /// embedding is a lookup, like any LM's input layer).
    pub fn features(&self, ctx_bytes: &[u8]) -> Tensor {
        let ctx = self.cfg.ctx;
        assert!(
            !ctx_bytes.is_empty() && ctx_bytes.len() % ctx == 0,
            "context batch must be a multiple of ctx={ctx}"
        );
        let b = ctx_bytes.len() / ctx;
        let mut h = Tensor::zeros_cat(b, self.cfg.d, Category::Intermediates);
        for r in 0..b {
            let row = h.row_mut(r);
            for (j, &byte) in ctx_bytes[r * ctx..(r + 1) * ctx].iter().enumerate() {
                let e = self.embed.row(byte as usize);
                let s = self.pos_scale[j];
                for (o, v) in row.iter_mut().zip(e) {
                    *o += s * v;
                }
            }
        }
        h
    }

    /// Forward the whole stack; returns logits `[b, vocab]`. Saves
    /// backward state (inside the blocks + the ReLU masks).
    pub fn forward(&mut self, ctx_bytes: &[u8]) -> Tensor {
        let mut h = self.features(ctx_bytes);
        self.masks.clear();
        for blk in &mut self.blocks {
            // h ← ReLU(h + block(h)), through the layer's residual hook:
            // the rdFFT circulant block adds the skip as spectra inside
            // its fused single-sweep pipeline (no activation copy); other
            // layers fall back to the clone-and-add default.
            let mut t = blk.forward_residual(h);
            self.masks.push(ReluMask::forward(&mut t));
            h = t;
        }
        self.readout.forward(h)
    }

    /// Backward from the loss gradient w.r.t. the logits; accumulates
    /// parameter gradients in every layer. The grad w.r.t. the features is
    /// discarded (the embedding is frozen).
    pub fn backward(&mut self, dlogits: Tensor) {
        let mut g = self.readout.backward(dlogits);
        for (blk, mask) in self.blocks.iter_mut().rev().zip(self.masks.drain(..).rev()) {
            mask.backward(&mut g);
            // d(h + block(h)) = g + blockᵀ(g), via the residual hook
            // (fused skip gradient for the rdFFT circulant block).
            g = blk.backward_residual(g);
        }
    }

    /// One full training step on a context batch: forward, softmax
    /// cross-entropy, backward, optimizer update (+ grad zeroing).
    /// Returns the batch loss.
    pub fn train_step(
        &mut self,
        ctx_bytes: &[u8],
        labels: &[usize],
        bank: &mut OptimizerBank,
    ) -> f32 {
        let logits = self.forward(ctx_bytes);
        let mut dl = Tensor::zeros_cat(logits.rows, logits.cols, Category::Intermediates);
        let loss = softmax_xent(&logits, labels, &mut dl);
        drop(logits);
        self.backward(dl);
        let mut idx = 0usize;
        self.for_each_param(&mut |p, g| {
            bank.apply(idx, p, g);
            for v in g.iter_mut() {
                *v = 0.0;
            }
            idx += 1;
        });
        loss
    }

    /// Loss on a batch without training (drops all saved state after).
    pub fn eval_loss(&mut self, ctx_bytes: &[u8], labels: &[usize]) -> f32 {
        let logits = self.forward(ctx_bytes);
        let mut scratch = Tensor::zeros_cat(logits.rows, logits.cols, Category::Intermediates);
        let loss = softmax_xent(&logits, labels, &mut scratch);
        self.clear_saved();
        loss
    }

    /// Visit every `(param, grad)` pair: blocks first (in order), then the
    /// readout — the stable order [`OptimizerBank`] requires.
    pub fn for_each_param(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        for blk in &mut self.blocks {
            blk.for_each_param(f);
        }
        self.readout.for_each_param(f);
    }

    pub fn clear_saved(&mut self) {
        for blk in &mut self.blocks {
            blk.clear_saved();
        }
        self.readout.clear_saved();
        self.masks.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::layers::Backend;
    use super::super::optim::OptimKind;
    use super::*;
    use crate::autograd::tensor::Rng;

    fn batch(b: usize, ctx: usize, seed: u64) -> (Vec<u8>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let bytes: Vec<u8> = (0..b * ctx).map(|_| (97 + rng.below(20)) as u8).collect();
        // deterministic target derived from the context so it is learnable
        let labels: Vec<usize> =
            (0..b).map(|r| (bytes[r * ctx] as usize + bytes[r * ctx + 1] as usize) % 23).collect();
        (bytes, labels)
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let cfg = StackConfig { d: 32, depth: 2, ctx: 4, ..Default::default() };
        let mut s1 = SpectralStack::new(cfg.clone());
        let mut s2 = SpectralStack::new(cfg);
        let (bytes, _) = batch(3, 4, 1);
        let y1 = s1.forward(&bytes);
        let y2 = s2.forward(&bytes);
        assert_eq!((y1.rows, y1.cols), (3, 256));
        assert_eq!(y1.as_slice(), y2.as_slice(), "same seed must give the same logits");
    }

    #[test]
    fn relu_mask_backward_matches_saved_output_rule() {
        use crate::autograd::tensor::relu_backward_inplace;
        let mut t = Tensor::from_vec(
            1,
            6,
            vec![-1.0, 2.0, 0.0, 3.0, -0.5, 1.0],
            Category::Other,
        );
        let reference = {
            let mut y = t.clone_as(Category::Other);
            crate::autograd::tensor::relu_inplace(&mut y);
            y
        };
        let mask = ReluMask::forward(&mut t);
        assert_eq!(t.as_slice(), reference.as_slice());
        let mut g1 = Tensor::from_vec(1, 6, vec![1.0; 6], Category::Other);
        let mut g2 = Tensor::from_vec(1, 6, vec![1.0; 6], Category::Other);
        mask.backward(&mut g1);
        relu_backward_inplace(&mut g2, &reference);
        assert_eq!(g1.as_slice(), g2.as_slice());
    }

    #[test]
    fn stack_memorizes_a_fixed_batch_all_methods() {
        for method in [
            Method::Circulant { backend: Backend::RdFft, p: 8 },
            Method::FullFinetune,
            Method::Lora { rank: 4 },
        ] {
            let cfg = StackConfig { d: 32, depth: 2, ctx: 4, method, seed: 3, ..Default::default() };
            let mut stack = SpectralStack::new(cfg);
            let mut bank = OptimizerBank::new(OptimKind::Sgd, 0.3);
            let (bytes, labels) = batch(8, 4, 7);
            let first = stack.train_step(&bytes, &labels, &mut bank);
            let mut last = first;
            for _ in 0..100 {
                last = stack.train_step(&bytes, &labels, &mut bank);
            }
            assert!(
                last < first * 0.6,
                "{method:?}: memorizing one batch must cut the loss: {first} -> {last}"
            );
        }
    }

    #[test]
    fn adam_also_trains_the_stack() {
        let cfg = StackConfig { d: 32, depth: 2, ctx: 4, seed: 5, ..Default::default() };
        let mut stack = SpectralStack::new(cfg);
        let mut bank =
            OptimizerBank::new(OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }, 0.01);
        let (bytes, labels) = batch(8, 4, 9);
        let first = stack.train_step(&bytes, &labels, &mut bank);
        let mut last = first;
        for _ in 0..100 {
            last = stack.train_step(&bytes, &labels, &mut bank);
        }
        // depth blocks + readout, one tensor each (circulant c + dense w)
        assert_eq!(bank.num_tensors(), 3);
        assert!(bank.state_bytes() > 0, "adam must hold per-tensor state");
        assert!(last < first * 0.6, "adam: {first} -> {last}");
    }

    #[test]
    fn param_visit_order_is_stable_and_complete() {
        let cfg = StackConfig { d: 32, depth: 3, ctx: 4, seed: 2, ..Default::default() };
        let mut stack = SpectralStack::new(cfg);
        let mut sizes = Vec::new();
        stack.for_each_param(&mut |p, g| {
            assert_eq!(p.len(), g.len());
            sizes.push(p.len());
        });
        let mut sizes2 = Vec::new();
        stack.for_each_param(&mut |p, _| sizes2.push(p.len()));
        assert_eq!(sizes, sizes2);
        assert_eq!(sizes.iter().sum::<usize>(), stack.num_trainable());
        assert_eq!(sizes.len(), 4); // 3 circulant blocks + readout
    }

    #[test]
    fn eval_loss_leaves_no_saved_state() {
        let cfg = StackConfig { d: 32, depth: 2, ctx: 4, ..Default::default() };
        let mut stack = SpectralStack::new(cfg);
        let (bytes, labels) = batch(4, 4, 11);
        let l1 = stack.eval_loss(&bytes, &labels);
        let l2 = stack.eval_loss(&bytes, &labels);
        // (tolerance, not equality: the circulant parameter buffer
        // roundtrips through the frequency domain between evals)
        assert!((l1 - l2).abs() < 1e-4, "eval must be repeatable: {l1} vs {l2}");
        assert!(stack.masks.is_empty());
    }
}
