//! Tracked 2-D tensors and the handful of dense ops the experiments need.
//!
//! Tensors are row-major `[rows, cols]` over [`crate::memtrack::TrackedVec`]
//! storage, so their lifetime is visible to the memory profiler exactly
//! like CUDA allocations are to PyTorch's.

use crate::memtrack::{self, Category, TrackedVec};

/// A tracked row-major 2-D tensor.
pub struct Tensor {
    pub rows: usize,
    pub cols: usize,
    data: TrackedVec,
}

impl Tensor {
    /// Zeros under the current default category (or an explicit one).
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self::zeros_cat(rows, cols, memtrack::default_category())
    }

    pub fn zeros_cat(rows: usize, cols: usize, cat: Category) -> Self {
        Tensor { rows, cols, data: TrackedVec::zeros(rows * cols, cat) }
    }

    pub fn from_vec(rows: usize, cols: usize, v: Vec<f32>, cat: Category) -> Self {
        assert_eq!(v.len(), rows * cols);
        Tensor { rows, cols, data: TrackedVec::from_vec(v, cat) }
    }

    /// Deterministic uniform(-scale, scale) init (xorshift-based; the
    /// experiments need reproducibility, not cryptographic quality).
    pub fn rand(rows: usize, cols: usize, scale: f32, seed: u64, cat: Category) -> Self {
        let mut rng = Rng::new(seed);
        let v: Vec<f32> = (0..rows * cols).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect();
        Self::from_vec(rows, cols, v, cat)
    }

    pub fn len(&self) -> usize {
        self.rows * self.cols
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn category(&self) -> Category {
        self.data.category()
    }
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Deep copy into `cat`.
    pub fn clone_as(&self, cat: Category) -> Tensor {
        Tensor::from_vec(self.rows, self.cols, self.data.to_vec(), cat)
    }

    pub fn fill(&mut self, v: f32) {
        for x in self.as_mut_slice() {
            *x = v;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in self.as_mut_slice() {
            *x *= s;
        }
    }

    /// `self += other * s` (shapes must match).
    pub fn axpy(&mut self, other: &Tensor, s: f32) {
        assert_eq!(self.len(), other.len());
        for (a, b) in self.as_mut_slice().iter_mut().zip(other.as_slice()) {
            *a += b * s;
        }
    }
}

impl std::fmt::Debug for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tensor[{}x{}, {}]", self.rows, self.cols, self.category().name())
    }
}

/// `out = x · wᵀ` — x:[b,in], w:[out,in], out:[b,out]. Blocked over k for
/// cache locality; this is the hot matmul of the dense/LoRA baselines.
pub fn matmul_nt(x: &Tensor, w: &Tensor, out: &mut Tensor) {
    assert_eq!(x.cols, w.cols, "inner dims");
    assert_eq!(out.rows, x.rows);
    assert_eq!(out.cols, w.rows);
    let (b, n_in, n_out) = (x.rows, x.cols, w.rows);
    let xs = x.as_slice();
    let ws = w.as_slice();
    let os = out.as_mut_slice();
    os.fill(0.0);
    for i in 0..b {
        let xrow = &xs[i * n_in..(i + 1) * n_in];
        let orow = &mut os[i * n_out..(i + 1) * n_out];
        for o in 0..n_out {
            let wrow = &ws[o * n_in..(o + 1) * n_in];
            let mut acc = 0.0f32;
            for k in 0..n_in {
                acc += xrow[k] * wrow[k];
            }
            orow[o] = acc;
        }
    }
}

/// `out = g · w` — g:[b,out], w:[out,in], out:[b,in]. The dx of a dense
/// layer.
pub fn matmul_nn(g: &Tensor, w: &Tensor, out: &mut Tensor) {
    assert_eq!(g.cols, w.rows);
    assert_eq!(out.rows, g.rows);
    assert_eq!(out.cols, w.cols);
    let (b, n_out, n_in) = (g.rows, g.cols, w.cols);
    let gs = g.as_slice();
    let ws = w.as_slice();
    let os = out.as_mut_slice();
    os.fill(0.0);
    for i in 0..b {
        let grow = &gs[i * n_out..(i + 1) * n_out];
        let orow = &mut os[i * n_in..(i + 1) * n_in];
        for o in 0..n_out {
            let go = grow[o];
            if go == 0.0 {
                continue;
            }
            let wrow = &ws[o * n_in..(o + 1) * n_in];
            for k in 0..n_in {
                orow[k] += go * wrow[k];
            }
        }
    }
}

/// `dw += gᵀ · x` — g:[b,out], x:[b,in], dw:[out,in]. The dW of a dense
/// layer (accumulating).
pub fn matmul_tn_acc(g: &Tensor, x: &Tensor, dw: &mut Tensor) {
    assert_eq!(g.rows, x.rows);
    assert_eq!(dw.rows, g.cols);
    assert_eq!(dw.cols, x.cols);
    let (b, n_out, n_in) = (g.rows, g.cols, x.cols);
    let gs = g.as_slice();
    let xs = x.as_slice();
    let ds = dw.as_mut_slice();
    for i in 0..b {
        let grow = &gs[i * n_out..(i + 1) * n_out];
        let xrow = &xs[i * n_in..(i + 1) * n_in];
        for o in 0..n_out {
            let go = grow[o];
            if go == 0.0 {
                continue;
            }
            let drow = &mut ds[o * n_in..(o + 1) * n_in];
            for k in 0..n_in {
                drow[k] += go * xrow[k];
            }
        }
    }
}

/// In-place ReLU; returns nothing (mask recomputed in backward from the
/// saved output, the memory-lean formulation).
pub fn relu_inplace(x: &mut Tensor) {
    for v in x.as_mut_slice() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Backward of in-place ReLU given the *output* y: `g := g ⊙ (y > 0)`.
pub fn relu_backward_inplace(g: &mut Tensor, y: &Tensor) {
    assert_eq!(g.len(), y.len());
    for (gv, yv) in g.as_mut_slice().iter_mut().zip(y.as_slice()) {
        if *yv <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Softmax cross-entropy over logits `[b, classes]` with integer labels.
/// Returns mean loss; writes `d(loss)/d(logits)` into `grad` (same shape).
pub fn softmax_xent(logits: &Tensor, labels: &[usize], grad: &mut Tensor) -> f32 {
    let b = logits.rows;
    (softmax_xent_shard(logits, labels, grad, b) / b as f64) as f32
}

/// [`softmax_xent`] over one *shard* of a larger batch: row gradients are
/// scaled by `1/denom` — the **full** batch size, so shard gradients
/// compose exactly with the serial step's — and the return value is the
/// shard's f64 row-loss **sum**, not yet divided, so shard losses can be
/// combined by a deterministic fixed-order reduction before the single
/// division. `softmax_xent` is this with `denom = rows` (same float ops).
pub fn softmax_xent_shard(
    logits: &Tensor,
    labels: &[usize],
    grad: &mut Tensor,
    denom: usize,
) -> f64 {
    assert_eq!(labels.len(), logits.rows);
    assert_eq!(grad.rows, logits.rows);
    assert_eq!(grad.cols, logits.cols);
    assert!(denom >= logits.rows, "denom is the full batch size");
    let b = logits.rows;
    let mut loss = 0.0f64;
    for i in 0..b {
        let row = logits.row(i);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom_z = 0.0f64;
        for &v in row {
            denom_z += ((v - maxv) as f64).exp();
        }
        let logz = denom_z.ln() + maxv as f64;
        loss += logz - row[labels[i]] as f64;
        let grow = grad.row_mut(i);
        for (j, g) in grow.iter_mut().enumerate() {
            let p = (((row[j] as f64) - logz).exp()) as f32;
            *g = (p - if j == labels[i] { 1.0 } else { 0.0 }) / denom as f32;
        }
    }
    loss
}

/// Tiny deterministic RNG (xorshift64*), used everywhere randomness is
/// needed so experiments are reproducible without an external crate.
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }
    /// Raw generator state, for checkpointing. Restore with
    /// [`Rng::from_state`] — NOT with [`Rng::new`], which transforms the
    /// seed and would land on a different stream position.
    pub fn state(&self) -> u64 {
        self.0
    }
    /// Rebuild a generator from a [`Rng::state`] capture (bit-exact
    /// stream continuation).
    pub fn from_state(state: u64) -> Self {
        Rng(state)
    }
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }
    /// Standard normal via Box–Muller.
    pub fn next_gauss(&mut self) -> f32 {
        let u1 = (self.next_f32() + 1e-7).min(1.0);
        let u2 = self.next_f32();
        ((-2.0 * (u1 as f64).ln()).sqrt() * (std::f64::consts::TAU * u2 as f64).cos()) as f32
    }
    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_nt_small() {
        // x = [[1,2],[3,4]], w = [[1,0],[0,1],[1,1]] -> x·wT = [[1,2,3],[3,4,7]]
        let x = Tensor::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0], Category::Other);
        let w = Tensor::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0], Category::Other);
        let mut out = Tensor::zeros_cat(2, 3, Category::Other);
        matmul_nt(&x, &w, &mut out);
        assert_eq!(out.as_slice(), &[1.0, 2.0, 3.0, 3.0, 4.0, 7.0]);
    }

    #[test]
    fn matmul_grads_match_finite_difference() {
        // L = sum((x wT) ⊙ g0); check dW and dx.
        let mut rng = Rng::new(5);
        let x = Tensor::from_vec(3, 4, (0..12).map(|_| rng.next_gauss()).collect(), Category::Other);
        let w = Tensor::from_vec(2, 4, (0..8).map(|_| rng.next_gauss()).collect(), Category::Other);
        let g0 = Tensor::from_vec(3, 2, (0..6).map(|_| rng.next_gauss()).collect(), Category::Other);

        let loss = |w: &Tensor, x: &Tensor| -> f32 {
            let mut out = Tensor::zeros_cat(3, 2, Category::Other);
            matmul_nt(x, w, &mut out);
            out.as_slice().iter().zip(g0.as_slice()).map(|(a, b)| a * b).sum()
        };

        let mut dw = Tensor::zeros_cat(2, 4, Category::Other);
        matmul_tn_acc(&g0, &x, &mut dw);
        let mut dx = Tensor::zeros_cat(3, 4, Category::Other);
        matmul_nn(&g0, &w, &mut dx);

        let eps = 1e-2f32;
        for idx in 0..8 {
            let mut wp = w.clone_as(Category::Other);
            wp.as_mut_slice()[idx] += eps;
            let mut wm = w.clone_as(Category::Other);
            wm.as_mut_slice()[idx] -= eps;
            let fd = (loss(&wp, &x) - loss(&wm, &x)) / (2.0 * eps);
            assert!((fd - dw.as_slice()[idx]).abs() < 1e-2, "dW idx={idx}");
        }
        for idx in 0..12 {
            let mut xp = x.clone_as(Category::Other);
            xp.as_mut_slice()[idx] += eps;
            let mut xm = x.clone_as(Category::Other);
            xm.as_mut_slice()[idx] -= eps;
            let fd = (loss(&w, &xp) - loss(&w, &xm)) / (2.0 * eps);
            assert!((fd - dx.as_slice()[idx]).abs() < 1e-2, "dx idx={idx}");
        }
    }

    #[test]
    fn relu_and_backward() {
        let mut x = Tensor::from_vec(1, 4, vec![-1.0, 2.0, -0.5, 3.0], Category::Other);
        relu_inplace(&mut x);
        assert_eq!(x.as_slice(), &[0.0, 2.0, 0.0, 3.0]);
        let mut g = Tensor::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0], Category::Other);
        relu_backward_inplace(&mut g, &x);
        assert_eq!(g.as_slice(), &[0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn softmax_xent_gradient_sums_to_zero_per_row() {
        let logits = Tensor::from_vec(2, 3, vec![1.0, 2.0, 0.5, -1.0, 0.0, 1.0], Category::Other);
        let mut grad = Tensor::zeros_cat(2, 3, Category::Other);
        let loss = softmax_xent(&logits, &[1, 2], &mut grad);
        assert!(loss > 0.0);
        for i in 0..2 {
            let s: f32 = grad.row(i).iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_xent_matches_finite_difference() {
        let logits = Tensor::from_vec(1, 4, vec![0.3, -0.2, 0.9, 0.0], Category::Other);
        let labels = [2usize];
        let mut grad = Tensor::zeros_cat(1, 4, Category::Other);
        softmax_xent(&logits, &labels, &mut grad);
        let eps = 1e-3f32;
        for idx in 0..4 {
            let mut lp = logits.clone_as(Category::Other);
            lp.as_mut_slice()[idx] += eps;
            let mut lm = logits.clone_as(Category::Other);
            lm.as_mut_slice()[idx] -= eps;
            let mut tmp = Tensor::zeros_cat(1, 4, Category::Other);
            let fd = (softmax_xent(&lp, &labels, &mut tmp) - softmax_xent(&lm, &labels, &mut tmp))
                / (2.0 * eps);
            assert!((fd - grad.as_slice()[idx]).abs() < 1e-3, "idx={idx}");
        }
    }

    #[test]
    fn rng_is_deterministic_and_spread() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut r = Rng::new(2);
        let mean: f32 = (0..1000).map(|_| r.next_f32()).sum::<f32>() / 1000.0;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }
}
