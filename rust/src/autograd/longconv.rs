//! Long-convolution (fftconv-style) sequence-mixing layer.
//!
//! [`LongConvLayer`] treats the feature axis of a `[b, d]` activation as a
//! causal sequence and mixes it with one trainable length-`k` filter:
//!
//! ```text
//! u[t] = Σ_{τ=0..min(t,k-1)} h[τ] · x[t-τ]        (causal, t < d)
//! y    = x + gelu(u)                              (residual form)
//! ```
//!
//! The O(d·k) convolution runs as an O(n log n) circular convolution at
//! `n = next_pow2(d + k - 1)` — zero-padding removes wraparound, so the
//! first `d` outputs are exactly the causal linear convolution. The hot
//! path is the paper's machinery end to end:
//!
//! * forward: rows zero-pad into one `[b, n]` scratch, then a **single**
//!   fused sweep ([`engine::circulant_apply_batch_ctx`] with
//!   [`SpectralOp::Mul`]) does forward stages → packed product with the
//!   cached filter spectrum → inverse stages per cache-resident tile;
//!   GELU and the residual skip are applied during the copy-back out of
//!   the inverse pass (no extra activation tensor);
//! * backward stays in the frequency domain: `dĥ += conj(x̂) ⊙ ĝ` via the
//!   packed [`spectral::conj_mul_acc_with`] kernels (one accumulator row,
//!   one inverse per step), and `dx̂ = ĝ ⊙ conj(ĥ)` via the `MulConjB`
//!   product family, overwriting grad-output in place with `dx`.
//!
//! The trainable parameter is the canonical **time-domain** kernel,
//! stored at padded length `n` with taps `k..n` structurally zero (their
//! gradients are zeroed after every inverse), so the checkpoint contract
//! ([`Layer::for_each_param`]) and the shard-arena shape contract both
//! see one stable `[1, n]` tensor.

use super::layers::{Layer, ShardSaved};
use super::tensor::Tensor;
use crate::memtrack::Category;
use crate::rdfft::plan::cached;
use crate::rdfft::{engine, simd, spectral, Kernels, Plan, SpectralOp};
use crate::runtime::pool::ExecCtx;
use std::cell::RefCell;
use std::sync::Arc;

/// GELU, tanh approximation (the long-convolution literature's standard
/// gate): `0.5·u·(1 + tanh(√(2/π)·(u + 0.044715·u³)))`.
#[inline]
pub fn gelu(u: f32) -> f32 {
    const C: f32 = 0.797_884_56; // √(2/π)
    const A: f32 = 0.044_715;
    let t = (C * (u + A * u * u * u)).tanh();
    0.5 * u * (1.0 + t)
}

/// Exact derivative of [`gelu`] (the tanh form, differentiated — not a
/// further approximation), used by the fused backward gate.
#[inline]
pub fn gelu_prime(u: f32) -> f32 {
    const C: f32 = 0.797_884_56;
    const A: f32 = 0.044_715;
    let inner = C * (u + A * u * u * u);
    let t = inner.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * u * sech2 * C * (1.0 + 3.0 * A * u * u)
}

thread_local! {
    /// Per-thread zero-pad scratch for the allocation-free serve path.
    /// Grown to the largest `b·n` this thread has seen, then reused —
    /// steady-state inference allocates nothing (the fourstep transpose
    /// tile uses the same discipline).
    static PAD: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// Run `f` on this thread's pad scratch, grown to at least `len` floats.
fn with_pad<F: FnOnce(&mut [f32])>(len: usize, f: F) {
    PAD.with(|t| {
        let mut v = t.borrow_mut();
        if v.len() < len {
            v.resize(len, 0.0);
        }
        f(&mut v[..len]);
    });
}

/// One batch of the fused forward, shared **verbatim** by the serial
/// path, the replica-free shard hook, and the serve path, so the three
/// are bit-identical per row: zero-pad rows, one fused
/// forward→product→inverse sweep against the shared filter spectrum,
/// then the GELU (+ optional skip) copy-back. `u_save`, when present,
/// receives the `[b, d]` pre-activations backward needs.
// audit: no_alloc
#[allow(clippy::too_many_arguments)]
fn longconv_forward_rows(
    plan: &Plan,
    d: usize,
    h_spec: &[f32],
    x: &Tensor,
    pad: &mut [f32],
    mut u_save: Option<&mut [f32]>,
    out: &mut Tensor,
    residual: bool,
    exec: &ExecCtx,
) {
    let n = plan.n();
    let b = x.rows;
    debug_assert_eq!(pad.len(), b * n);
    debug_assert_eq!((out.rows, out.cols), (b, d));
    for r in 0..b {
        let row = &mut pad[r * n..(r + 1) * n];
        row[..d].copy_from_slice(x.row(r));
        row[d..].fill(0.0);
    }
    // û ← x̂ ⊙ ĥ, staged and inverted inside one cache-resident sweep.
    engine::circulant_apply_batch_ctx(plan, pad, h_spec, SpectralOp::Mul, exec);
    for r in 0..b {
        let u_row = &pad[r * n..r * n + d];
        if let Some(us) = u_save.as_deref_mut() {
            us[r * d..(r + 1) * d].copy_from_slice(u_row);
        }
        let x_row = x.row(r);
        let o_row = out.row_mut(r);
        for j in 0..d {
            let a = gelu(u_row[j]);
            o_row[j] = if residual { x_row[j] + a } else { a };
        }
    }
}

/// One batch of the frequency-domain backward, shared verbatim by the
/// serial path (accumulating into the layer's own spectral row) and the
/// shard hook (accumulating into the shard arena): gate the incoming
/// gradient through `gelu'(u)`, transform gate and saved input,
/// `dĥ += conj(x̂) ⊙ ĝ` per row, `dx̂ = ĝ ⊙ conj(ĥ)`, inverse, and
/// overwrite `g` in place with `dx` (+ optional skip). `dh_spec` is left
/// as accumulated **spectra** — the caller applies the one shared
/// inverse (serial: per step; sharded: after the tree reduction).
// audit: no_alloc
#[allow(clippy::too_many_arguments)]
fn longconv_backward_rows(
    plan: &Plan,
    d: usize,
    h_spec: &[f32],
    x: &Tensor,
    u: &[f32],
    g: &mut Tensor,
    xpad: &mut [f32],
    gpad: &mut [f32],
    dh_spec: &mut [f32],
    residual: bool,
    kern: Kernels,
    exec: &ExecCtx,
) {
    let n = plan.n();
    let b = g.rows;
    debug_assert_eq!(xpad.len(), b * n);
    debug_assert_eq!(gpad.len(), b * n);
    debug_assert_eq!(u.len(), b * d);
    for r in 0..b {
        let g_row = g.row(r);
        let u_row = &u[r * d..(r + 1) * d];
        let gp = &mut gpad[r * n..(r + 1) * n];
        for j in 0..d {
            gp[j] = g_row[j] * gelu_prime(u_row[j]);
        }
        gp[d..].fill(0.0);
        let xp = &mut xpad[r * n..(r + 1) * n];
        xp[..d].copy_from_slice(x.row(r));
        xp[d..].fill(0.0);
    }
    engine::forward_batch_ctx(plan, gpad, exec);
    engine::forward_batch_ctx(plan, xpad, exec);
    // dĥ += conj(x̂) ⊙ ĝ, row by row, straight into the accumulator.
    for r in 0..b {
        spectral::conj_mul_acc_with(
            kern,
            dh_spec,
            &xpad[r * n..(r + 1) * n],
            &gpad[r * n..(r + 1) * n],
        );
    }
    // dx̂ = ĝ ⊙ conj(ĥ), then one inverse pass; the first d lanes of each
    // row are dx (gradient w.r.t. the zero padding is discarded).
    spectral::mul_conjb_rows_with(kern, gpad, h_spec);
    engine::inverse_batch_ctx(plan, gpad, exec);
    for r in 0..b {
        let dx_row = &gpad[r * n..r * n + d];
        let g_row = g.row_mut(r);
        for j in 0..d {
            g_row[j] = if residual { g_row[j] + dx_row[j] } else { dx_row[j] };
        }
    }
}

/// Trainable causal long-convolution block over the feature axis — see
/// the module docs for the math and the memory discipline.
pub struct LongConvLayer {
    d: usize,
    k: usize,
    n: usize,
    /// Canonical time-domain kernel at padded length `n`; taps `k..n` are
    /// structurally zero (kept zero by tail-zeroed gradients).
    h: Tensor,
    dh: Tensor,
    /// Cached packed spectrum of `h`, refreshed lazily after any
    /// parameter mutation ([`LongConvLayer::ensure_spec`]).
    h_spec: Tensor,
    spec_fresh: bool,
    /// Persistent `[b, n]` zero-pad workspaces for the serial paths
    /// (forward; backward needs a second for x̂ alongside ĝ), grown to
    /// the largest batch seen — steady-state serial steps reuse them.
    pad: Tensor,
    pad2: Tensor,
    /// One spectral row accumulating `dĥ` within a serial backward.
    ws_spec: Tensor,
    plan: Arc<Plan>,
    exec: ExecCtx,
    saved_x: Option<Tensor>,
    saved_u: Option<Tensor>,
}

impl LongConvLayer {
    pub fn new(d: usize, k: usize, seed: u64) -> Self {
        assert!(k >= 1, "long-conv filter needs at least one tap");
        assert!(k <= d, "filter taps ({k}) must not exceed the width ({d})");
        let n = (d + k - 1).next_power_of_two().max(2);
        let mut h = Tensor::rand(1, n, 0.5 / (k as f32).sqrt(), seed, Category::Trainable);
        h.as_mut_slice()[k..].fill(0.0);
        LongConvLayer {
            d,
            k,
            n,
            h,
            dh: Tensor::zeros_cat(1, n, Category::Gradients),
            h_spec: Tensor::zeros_cat(1, n, Category::Other),
            spec_fresh: false,
            pad: Tensor::zeros_cat(0, 0, Category::Other),
            pad2: Tensor::zeros_cat(0, 0, Category::Other),
            ws_spec: Tensor::zeros_cat(1, n, Category::Other),
            plan: cached(n),
            exec: ExecCtx::global(),
            saved_x: None,
            saved_u: None,
        }
    }

    /// Install the execution context all engine calls dispatch on.
    pub fn set_exec(&mut self, exec: ExecCtx) {
        self.exec = exec;
    }
    /// Filter length (trainable taps).
    pub fn taps(&self) -> usize {
        self.k
    }
    /// FFT size: `next_pow2(d + k - 1)` — large enough that the circular
    /// convolution is exactly the causal linear one.
    pub fn fft_size(&self) -> usize {
        self.n
    }

    /// Refresh the cached filter spectrum from the time-domain kernel if
    /// a parameter mutation staled it. The kernel tensor itself **never**
    /// leaves the time domain (unlike the circulant layer's in-place
    /// roundtrip) — `h_spec` is a separate cached view.
    fn ensure_spec(&mut self) {
        if !self.spec_fresh {
            self.h_spec.as_mut_slice().copy_from_slice(self.h.as_slice());
            engine::forward_batch_ctx(&self.plan, self.h_spec.as_mut_slice(), &self.exec);
            self.spec_fresh = true;
        }
    }

    /// Grow a persistent workspace to at least `rows` rows of `n`.
    fn grow_ws(ws: &mut Tensor, rows: usize, n: usize) {
        if ws.rows < rows {
            *ws = Tensor::zeros_cat(rows, n, Category::Other);
        }
    }

    fn forward_impl(&mut self, x: Tensor, residual: bool) -> Tensor {
        assert_eq!(x.cols, self.d, "input width must match the layer");
        self.ensure_spec();
        let b = x.rows;
        Self::grow_ws(&mut self.pad, b, self.n);
        let mut out = Tensor::zeros_cat(b, self.d, Category::Intermediates);
        let mut u = Tensor::zeros_cat(b, self.d, Category::Intermediates);
        longconv_forward_rows(
            &self.plan,
            self.d,
            self.h_spec.as_slice(),
            &x,
            &mut self.pad.as_mut_slice()[..b * self.n],
            Some(u.as_mut_slice()),
            &mut out,
            residual,
            &self.exec,
        );
        self.saved_x = Some(x);
        self.saved_u = Some(u);
        out
    }

    fn backward_impl(&mut self, mut g: Tensor, residual: bool) -> Tensor {
        assert_eq!(g.cols, self.d, "gradient width must match the layer");
        debug_assert!(self.spec_fresh, "backward without a preceding forward");
        let x = self.saved_x.take().expect("forward before backward");
        let u = self.saved_u.take().expect("forward before backward");
        let b = g.rows;
        Self::grow_ws(&mut self.pad, b, self.n);
        Self::grow_ws(&mut self.pad2, b, self.n);
        self.ws_spec.fill(0.0);
        let kern = simd::select(self.exec.engine_config().force_scalar);
        longconv_backward_rows(
            &self.plan,
            self.d,
            self.h_spec.as_slice(),
            &x,
            u.as_slice(),
            &mut g,
            &mut self.pad2.as_mut_slice()[..b * self.n],
            &mut self.pad.as_mut_slice()[..b * self.n],
            self.ws_spec.as_mut_slice(),
            residual,
            kern,
            &self.exec,
        );
        // One inverse over the whole step's accumulated dĥ spectra, tail
        // zeroed (taps k..n are structural zeros of the parameter), then
        // fold into the across-step accumulator.
        engine::inverse_batch_ctx(&self.plan, self.ws_spec.as_mut_slice(), &self.exec);
        self.ws_spec.as_mut_slice()[self.k..].fill(0.0);
        self.dh.axpy(&self.ws_spec, 1.0);
        g
    }

    /// Unfused differential oracle (and bench baseline): the same math as
    /// three whole-buffer passes — forward batch, packed product sweep,
    /// inverse batch — plus a separate GELU/skip pass, with fresh buffers
    /// per call. No fused sweep, no workspace reuse; numerically
    /// tile-for-tile comparable to [`Layer::forward_residual`].
    pub fn forward_residual_unfused(&mut self, x: &Tensor) -> Tensor {
        assert_eq!(x.cols, self.d);
        self.ensure_spec();
        let b = x.rows;
        let mut pad = Tensor::zeros_cat(b, self.n, Category::Intermediates);
        for r in 0..b {
            pad.row_mut(r)[..self.d].copy_from_slice(x.row(r));
        }
        engine::forward_batch_ctx(&self.plan, pad.as_mut_slice(), &self.exec);
        let kern = simd::select(self.exec.engine_config().force_scalar);
        spectral::mul_rows_with(kern, pad.as_mut_slice(), self.h_spec.as_slice());
        engine::inverse_batch_ctx(&self.plan, pad.as_mut_slice(), &self.exec);
        let mut out = Tensor::zeros_cat(b, self.d, Category::Intermediates);
        for r in 0..b {
            let u_row = &pad.row(r)[..self.d];
            let x_row = x.row(r);
            let o_row = out.row_mut(r);
            for j in 0..self.d {
                o_row[j] = x_row[j] + gelu(u_row[j]);
            }
        }
        out
    }
}

impl Layer for LongConvLayer {
    fn forward(&mut self, x: Tensor) -> Tensor {
        self.forward_impl(x, false)
    }

    fn backward(&mut self, grad_out: Tensor) -> Tensor {
        self.backward_impl(grad_out, false)
    }

    fn forward_residual(&mut self, x: Tensor) -> Tensor {
        self.forward_impl(x, true)
    }

    fn backward_residual(&mut self, grad_out: Tensor) -> Tensor {
        self.backward_impl(grad_out, true)
    }

    fn sgd_step(&mut self, lr: f32) {
        // dh's tail is kept zero, so the kernel's structural zero padding
        // survives every update.
        self.h.axpy(&self.dh, -lr);
        self.dh.fill(0.0);
        self.spec_fresh = false;
    }

    fn num_trainable(&self) -> usize {
        self.h.len()
    }

    fn clear_saved(&mut self) {
        self.saved_x = None;
        self.saved_u = None;
    }

    fn for_each_param(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        // The kernel is always canonical time-domain; hand it out
        // directly, then assume the visitor mutated it (optimizer step or
        // checkpoint restore) and stale the cached spectrum.
        f(self.h.as_mut_slice(), self.dh.as_mut_slice());
        self.spec_fresh = false;
    }

    fn supports_shard_exec(&self) -> bool {
        true
    }

    fn grad_shapes(&self) -> Vec<(usize, usize)> {
        vec![(1, self.n)]
    }

    /// Refresh the shared filter spectrum once on the submitting thread;
    /// shard jobs then read it immutably.
    fn begin_shard_step(&mut self) {
        self.ensure_spec();
    }

    fn shard_forward_residual(&self, x: Tensor) -> (Tensor, ShardSaved) {
        debug_assert!(self.spec_fresh, "begin_shard_step must run before shard jobs");
        let b = x.rows;
        let mut out = Tensor::zeros_cat(b, self.d, Category::Intermediates);
        let mut u = Tensor::zeros_cat(b, self.d, Category::Intermediates);
        let mut pad = Tensor::zeros_cat(b, self.n, Category::Intermediates);
        longconv_forward_rows(
            &self.plan,
            self.d,
            self.h_spec.as_slice(),
            &x,
            pad.as_mut_slice(),
            Some(u.as_mut_slice()),
            &mut out,
            true,
            &self.exec,
        );
        (out, Box::new((x, u)))
    }

    /// The serial residual backward with every mutable piece
    /// externalized: dĥ accumulates into the shard's `grads[0]` buffer
    /// (as **spectra** — [`Layer::finish_shard_grads`] applies the one
    /// shared inverse after the tree reduction, exactly where the serial
    /// path inverts its whole-step accumulation), pads are shard-local.
    fn shard_backward_residual(
        &self,
        mut grad_out: Tensor,
        saved: ShardSaved,
        grads: &mut [Tensor],
    ) -> Tensor {
        let (x, u) = *saved
            .downcast::<(Tensor, Tensor)>()
            .expect("long-conv shard state is (x, u)");
        let b = grad_out.rows;
        let mut xpad = Tensor::zeros_cat(b, self.n, Category::Intermediates);
        let mut gpad = Tensor::zeros_cat(b, self.n, Category::Intermediates);
        let kern = simd::select(self.exec.engine_config().force_scalar);
        longconv_backward_rows(
            &self.plan,
            self.d,
            self.h_spec.as_slice(),
            &x,
            u.as_slice(),
            &mut grad_out,
            xpad.as_mut_slice(),
            gpad.as_mut_slice(),
            grads[0].as_mut_slice(),
            true,
            kern,
            &self.exec,
        );
        grad_out
    }

    /// One inverse over the *reduced* dĥ spectra (linearity lets shard
    /// spectra sum before the single IFFT), then the structural tail
    /// zeroing the serial path applies.
    fn finish_shard_grads(&mut self, grads: &mut [Tensor]) {
        engine::inverse_batch_ctx(&self.plan, grads[0].as_mut_slice(), &self.exec);
        grads[0].as_mut_slice()[self.k..].fill(0.0);
    }

    fn supports_infer_exec(&self) -> bool {
        true
    }

    /// Allocation-free twin of [`Layer::shard_forward_residual`]: the
    /// same fused sweep over the shared `ĥ` spectrum through this
    /// thread's persistent pad scratch (grown once, then steady-state
    /// zero-allocation), writing into the serve arena. `x` is read only;
    /// nothing is saved.
    // audit: no_alloc
    fn infer_forward_residual(&self, x: &mut Tensor, out: &mut Tensor) {
        debug_assert!(self.spec_fresh, "begin_shard_step must run before inference");
        debug_assert_eq!(x.cols, self.d);
        debug_assert_eq!(out.cols, self.d);
        let b = x.rows;
        with_pad(b * self.n, |pad| {
            longconv_forward_rows(
                &self.plan,
                self.d,
                self.h_spec.as_slice(),
                x,
                pad,
                None,
                out,
                true,
                &self.exec,
            );
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memtrack;
    use crate::rdfft::engine::EngineConfig;

    fn input(b: usize, d: usize, seed: u64) -> Tensor {
        Tensor::rand(b, d, 1.0, seed, Category::Intermediates)
    }

    fn grad_ones(b: usize, d: usize) -> Tensor {
        let mut g = Tensor::zeros_cat(b, d, Category::Intermediates);
        g.fill(1.0);
        g
    }

    /// n-scaled tolerance: one transform's worth of f32 rounding.
    fn n_tol(n: usize, base: f32) -> f32 {
        base * (n as f32).sqrt() * ((n as f32).log2() + 1.0)
    }

    /// O(d·k) causal reference: u[t] = Σ_τ h[τ]·x[t−τ].
    fn naive_causal(x: &[f32], h: &[f32], k: usize) -> Vec<f32> {
        let d = x.len();
        (0..d)
            .map(|t| (0..k.min(t + 1)).map(|tau| h[tau] * x[t - tau]).sum())
            .collect()
    }

    #[test]
    fn forward_matches_naive_causal_convolution() {
        let (b, d, k) = (3usize, 48usize, 12usize);
        let mut l = LongConvLayer::new(d, k, 7);
        assert_eq!(l.fft_size(), (d + k - 1).next_power_of_two());
        let taps = l.h.as_slice()[..k].to_vec();
        let x = input(b, d, 9);
        let y = l.forward_impl(x.clone_as(Category::Other), false);
        for r in 0..b {
            let want = naive_causal(x.row(r), &taps, k);
            for t in 0..d {
                let expect = gelu(want[t]);
                assert!(
                    (y.row(r)[t] - expect).abs() < n_tol(l.fft_size(), 1e-6) * (1.0 + expect.abs()),
                    "r={r} t={t}: {} vs {expect}",
                    y.row(r)[t]
                );
            }
        }
    }

    #[test]
    fn kernel_padding_is_structurally_zero_through_training() {
        let (b, d, k) = (4usize, 32usize, 8usize);
        let mut l = LongConvLayer::new(d, k, 3);
        let n = l.fft_size();
        assert!(l.h.as_slice()[k..].iter().all(|&v| v == 0.0));
        for step in 0..3 {
            let y = l.forward_residual(input(b, d, 50 + step));
            drop(y);
            let _ = l.backward_residual(grad_ones(b, d));
            // the gradient tail is zeroed before accumulation...
            assert!(
                l.dh.as_slice()[k..].iter().all(|&v| v == 0.0),
                "step {step}: grad tail must stay zero"
            );
            l.sgd_step(0.05);
            // ...so the parameter tail never moves.
            assert!(
                l.h.as_slice()[k..].iter().all(|&v| v == 0.0),
                "step {step}: kernel tail must stay zero"
            );
        }
        assert_eq!(l.num_trainable(), n);
    }

    #[test]
    fn fused_forward_matches_unfused_oracle() {
        let (b, d, k) = (4usize, 96usize, 33usize);
        let mut fused = LongConvLayer::new(d, k, 11);
        let mut unfused = LongConvLayer::new(d, k, 11);
        let x = input(b, d, 13);
        let y_f = fused.forward_residual(x.clone_as(Category::Intermediates));
        let y_u = unfused.forward_residual_unfused(&x);
        let tol = n_tol(fused.fft_size(), 1e-6);
        for i in 0..y_f.len() {
            assert!(
                (y_f.as_slice()[i] - y_u.as_slice()[i]).abs()
                    < tol * (1.0 + y_u.as_slice()[i].abs()),
                "i={i}: {} vs {}",
                y_f.as_slice()[i],
                y_u.as_slice()[i]
            );
        }
    }

    /// Central-difference check of both gradients (filter taps and input)
    /// through the full residual + GELU path.
    #[test]
    fn gradients_match_finite_differences() {
        let (b, d, k) = (2usize, 16usize, 4usize);
        let loss_weights: Vec<f32> = (0..b * d).map(|i| ((i * 7 + 3) % 11) as f32 / 11.0 - 0.4).collect();
        let x0 = input(b, d, 21);
        let loss_of = |l: &mut LongConvLayer, x: &Tensor| -> f64 {
            let y = l.forward_impl(x.clone_as(Category::Other), true);
            l.clear_saved();
            y.as_slice().iter().zip(&loss_weights).map(|(&y, &w)| (y * w) as f64).sum()
        };

        // analytic grads
        let mut l = LongConvLayer::new(d, k, 17);
        let y = l.forward_residual(x0.clone_as(Category::Other));
        drop(y);
        let g = Tensor::from_vec(b, d, loss_weights.clone(), Category::Intermediates);
        let dx = l.backward_residual(g);
        let dh = l.dh.as_slice().to_vec();

        let eps = 1e-2f32;
        // filter taps
        for tap in 0..k {
            let mut lp = LongConvLayer::new(d, k, 17);
            lp.h.as_mut_slice()[tap] += eps;
            let mut lm = LongConvLayer::new(d, k, 17);
            lm.h.as_mut_slice()[tap] -= eps;
            let num = (loss_of(&mut lp, &x0) - loss_of(&mut lm, &x0)) / (2.0 * eps as f64);
            assert!(
                (num - dh[tap] as f64).abs() < 2e-2 * (1.0 + num.abs()),
                "tap {tap}: numeric {num} vs analytic {}",
                dh[tap]
            );
        }
        // a few input coordinates
        let mut lfd = LongConvLayer::new(d, k, 17);
        for &i in &[0usize, 5, d - 1, d + 3, 2 * d - 1] {
            let mut xp = x0.clone_as(Category::Other);
            xp.as_mut_slice()[i] += eps;
            let mut xm = x0.clone_as(Category::Other);
            xm.as_mut_slice()[i] -= eps;
            let num = (loss_of(&mut lfd, &xp) - loss_of(&mut lfd, &xm)) / (2.0 * eps as f64);
            assert!(
                (num - dx.as_slice()[i] as f64).abs() < 2e-2 * (1.0 + num.abs()),
                "x[{i}]: numeric {num} vs analytic {}",
                dx.as_slice()[i]
            );
        }
    }

    /// The replica-free shard hooks must reproduce the serial residual
    /// paths bit-for-bit (one shard covering the batch), like every other
    /// shard-capable layer.
    #[test]
    fn shard_hooks_match_serial_residual_paths() {
        let (b, d, k) = (5usize, 32usize, 9usize);
        let mut reference = LongConvLayer::new(d, k, 23);
        let mut sharded = LongConvLayer::new(d, k, 23);
        assert!(reference.supports_shard_exec());
        let shapes = sharded.grad_shapes();
        assert_eq!(shapes, vec![(1, reference.fft_size())]);

        let x = input(b, d, 31);
        let x2 = x.clone_as(Category::Intermediates);
        let y_ref = reference.forward_residual(x);
        let dx_ref = reference.backward_residual(grad_ones(b, d));
        let mut dg_ref = Vec::new();
        reference.for_each_param(&mut |_, g| dg_ref.push(g.to_vec()));

        let mut grads: Vec<Tensor> =
            shapes.iter().map(|&(r, c)| Tensor::zeros_cat(r, c, Category::Gradients)).collect();
        sharded.begin_shard_step();
        let (y_sh, saved) = sharded.shard_forward_residual(x2);
        assert_eq!(y_ref.as_slice(), y_sh.as_slice(), "forward must be bit-identical");
        let dx_sh = sharded.shard_backward_residual(grad_ones(b, d), saved, &mut grads);
        sharded.finish_shard_grads(&mut grads);
        assert_eq!(dx_ref.as_slice(), dx_sh.as_slice(), "dx must be bit-identical");
        assert_eq!(&dg_ref[0][..], grads[0].as_slice(), "param grads must be bit-identical");
    }

    /// Serve path: bit-identical to the shard forward, and zero tracked
    /// allocations once this thread's pad scratch is warm.
    #[test]
    fn infer_forward_is_bit_identical_and_alloc_free_when_warm() {
        let (b, d, k) = (4usize, 64usize, 16usize);
        let mut l = LongConvLayer::new(d, k, 29);
        l.begin_shard_step();
        let x = input(b, d, 33);
        let (y_ref, _saved) = l.shard_forward_residual(x.clone_as(Category::Intermediates));

        let mut xs = x.clone_as(Category::Serve);
        let mut out = Tensor::zeros_cat(b, d, Category::Serve);
        l.infer_forward_residual(&mut xs, &mut out); // warm-up (grows pad)
        assert_eq!(y_ref.as_slice(), out.as_slice(), "serve must match training forward");
        memtrack::reset_peak();
        let before = memtrack::snapshot().alloc_count;
        let mut xs2 = x.clone_as(Category::Serve);
        let warm_base = memtrack::snapshot().alloc_count;
        l.infer_forward_residual(&mut xs2, &mut out);
        assert_eq!(
            memtrack::snapshot().alloc_count,
            warm_base,
            "steady-state serve pass must not allocate"
        );
        assert_eq!(warm_base - before, 1, "only the test's own input clone allocates");
        assert_eq!(y_ref.as_slice(), out.as_slice());
    }

    /// Checkpoint contract: for_each_param round-trips the canonical
    /// time-domain kernel, and a restore into a fresh layer reproduces
    /// the source layer's outputs bit-for-bit.
    #[test]
    fn checkpoint_roundtrip_is_bit_exact() {
        let (b, d, k) = (3usize, 32usize, 8usize);
        let mut src = LongConvLayer::new(d, k, 41);
        // advance a step so the state isn't the constructor's
        let _ = src.forward_residual(input(b, d, 1));
        let _ = src.backward_residual(grad_ones(b, d));
        src.sgd_step(0.05);
        let mut flat = Vec::new();
        src.for_each_param(&mut |p, _| flat.extend_from_slice(p));
        assert_eq!(flat.len(), src.num_trainable());

        let mut dst = LongConvLayer::new(d, k, 999); // different seed
        let mut off = 0usize;
        dst.for_each_param(&mut |p, g| {
            p.copy_from_slice(&flat[off..off + p.len()]);
            off += p.len();
            g.fill(0.0);
        });
        let x = input(b, d, 2);
        let y_src = src.forward_residual(x.clone_as(Category::Other));
        let y_dst = dst.forward_residual(x);
        assert_eq!(y_src.as_slice(), y_dst.as_slice(), "restored layer must match bitwise");
    }

    /// Width crossing `fourstep_threshold`: the same layer computed on
    /// the four-step tier must agree with the direct tier — the
    /// tier-crossing contract at layer level, on both dispatch legs.
    #[test]
    fn fourstep_and_direct_legs_agree() {
        let (b, d, k) = (2usize, 1024usize, 512usize);
        let mut direct = LongConvLayer::new(d, k, 51);
        let mut four = LongConvLayer::new(d, k, 51);
        let n = direct.fft_size();
        assert_eq!(n, 2048, "test geometry must reach the four-step-capable sizes");
        // direct leg: threshold above n; four-step leg: threshold below n.
        direct.set_exec(
            ExecCtx::serial()
                .with_engine_config(EngineConfig { fourstep_threshold: usize::MAX, ..EngineConfig::serial() }),
        );
        four.set_exec(
            ExecCtx::serial()
                .with_engine_config(EngineConfig { fourstep_threshold: 1024, ..EngineConfig::serial() }),
        );
        let x = input(b, d, 53);
        let before = engine::tier_counts();
        let y_d = direct.forward_residual(x.clone_as(Category::Intermediates));
        let mid = engine::tier_counts().since(before);
        assert_eq!(mid.fourstep, 0, "direct leg must not dispatch four-step");
        let y_f = four.forward_residual(x.clone_as(Category::Intermediates));
        let after = engine::tier_counts().since(before);
        assert!(after.fourstep >= 1, "four-step leg must engage the large-n tier");
        assert_eq!(after.fallback, 0, "no silent fallback on either leg");
        let tol = n_tol(n, 2e-6);
        for i in 0..y_d.len() {
            assert!(
                (y_d.as_slice()[i] - y_f.as_slice()[i]).abs()
                    < tol * (1.0 + y_d.as_slice()[i].abs()),
                "y i={i}: {} vs {}",
                y_d.as_slice()[i],
                y_f.as_slice()[i]
            );
        }
        let dx_d = direct.backward_residual(grad_ones(b, d));
        let dx_f = four.backward_residual(grad_ones(b, d));
        for i in 0..dx_d.len() {
            assert!(
                (dx_d.as_slice()[i] - dx_f.as_slice()[i]).abs()
                    < tol * (1.0 + dx_d.as_slice()[i].abs()),
                "dx i={i}"
            );
        }
        for i in 0..k {
            assert!(
                (direct.dh.as_slice()[i] - four.dh.as_slice()[i]).abs()
                    < tol * (b as f32) * (1.0 + direct.dh.as_slice()[i].abs()),
                "dh i={i}: {} vs {}",
                direct.dh.as_slice()[i],
                four.dh.as_slice()[i]
            );
        }
    }

    #[test]
    fn serial_forward_steady_state_allocates_only_output_and_saved_u() {
        let (b, d, k) = (4usize, 64usize, 16usize);
        let mut l = LongConvLayer::new(d, k, 61);
        // warm-up: grows the persistent pads, caches the spectrum
        let _ = l.forward_residual(input(b, d, 1));
        let _ = l.backward_residual(grad_ones(b, d));
        l.clear_saved();
        let x = input(b, d, 2);
        let g = grad_ones(b, d);
        memtrack::reset_peak();
        let before = memtrack::snapshot().alloc_count;
        let _y = l.forward_residual(x);
        assert_eq!(
            memtrack::snapshot().alloc_count - before,
            2,
            "warm forward allocates the output and the saved pre-activation only"
        );
        let mid = memtrack::snapshot().alloc_count;
        let _dx = l.backward_residual(g);
        assert_eq!(
            memtrack::snapshot().alloc_count,
            mid,
            "warm backward must allocate nothing (dx overwrites grad-output)"
        );
    }
}
