//! Fine-tuning layers with explicit forward/backward and honest
//! allocation behaviour.
//!
//! Each layer mirrors the allocation profile of its PyTorch counterpart so
//! the `memtrack` peaks reproduce Table 1 / Fig 2:
//!
//! * [`Dense`] (full fine-tune): weight + weight-grad + saved input.
//! * [`Lora`]: frozen weight, small trainable factors, but an extra
//!   activation (`x·Aᵀ`) saved for backward.
//! * [`CirculantLayer`] with [`Backend::Fft`]: every FFT promotes to a
//!   fresh complex buffer (2n reals); products/conjugations materialize.
//! * [`CirculantLayer`] with [`Backend::Rfft`]: half-spectra (n+2 reals),
//!   still out-of-place at every step.
//! * [`CirculantLayer`] with [`Backend::RdFft`]: the paper's method —
//!   forward transforms the input inside its own buffer (which *is* the
//!   saved-for-backward tensor), products accumulate straight into the
//!   output, backward overwrites grad-output in place. Beyond the output
//!   tensor any method must produce, **zero** allocations.

use super::tensor::{matmul_nn, matmul_nt, matmul_tn_acc, Tensor};
use crate::baselines::complex_fft::{fft_out_of_place, ifft_out_of_place, ComplexVec};
use crate::baselines::rfft::{irfft_alloc, rfft_alloc, rfft_conj, rfft_mul, RfftVec};
use crate::memtrack::{Category, ScopedCategory};
use crate::rdfft::plan::cached;
use crate::rdfft::{engine, simd, spectral};
use crate::runtime::pool::ExecCtx;
use std::sync::Arc;

/// FFT backend selection for [`CirculantLayer`] — the three columns of
/// Table 1/3/4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// `torch.fft.fft/ifft`: complex, out-of-place.
    Fft,
    /// `torch.fft.rfft/irfft`: half-spectrum, out-of-place.
    Rfft,
    /// rdFFT: real-domain, fully in-place (ours).
    RdFft,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Fft => "fft",
            Backend::Rfft => "rfft",
            Backend::RdFft => "ours",
        }
    }
}

/// Layer-local saved state of one replica-free shard pass: whatever the
/// layer's [`Layer::shard_forward_residual`] needs to hand to the
/// matching [`Layer::shard_backward_residual`], opaque to the stack
/// (each layer downcasts its own type). Lives entirely inside one pool
/// job, so worker-thread memtrack accounting stays balanced.
pub type ShardSaved = Box<dyn std::any::Any + Send>;

/// A trainable layer: forward saves what backward needs; backward consumes
/// the grad w.r.t. the output and returns the grad w.r.t. the input,
/// accumulating parameter gradients internally.
///
/// `Send + Sync` is a supertrait: the data-parallel trainer shares one
/// layer immutably across pool workers (replica-free sharding — the
/// shard hooks below take `&self` and externalize every mutable piece).
pub trait Layer: Send + Sync {
    fn forward(&mut self, x: Tensor) -> Tensor;
    fn backward(&mut self, grad_out: Tensor) -> Tensor;
    /// SGD update from accumulated gradients, then zero them.
    fn sgd_step(&mut self, lr: f32);
    /// Number of trainable scalars.
    fn num_trainable(&self) -> usize;
    /// Drop saved-for-backward state (end of step).
    fn clear_saved(&mut self);
    /// Visit every `(parameter, gradient)` tensor pair, in a stable order,
    /// so external optimizers ([`crate::autograd::optim::OptimizerBank`])
    /// can apply stateful updates and zero the gradients. Implementations
    /// must present parameters in their canonical (time) domain.
    ///
    /// This visitor is also the **checkpoint contract**: crash-safe
    /// snapshots export and restore parameters through it
    /// (`SpectralStack::{export_params, import_params}`), so the visit
    /// order and canonical-domain guarantee must be stable across runs —
    /// a layer that reorders its tensors or exposes a non-canonical
    /// domain silently breaks bit-identical resume.
    fn for_each_param(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32]));

    /// Residual forward `y = x + layer(x)` — the block sweep of
    /// [`crate::autograd::stack::SpectralStack`]. The default clones the
    /// input for the time-domain skip; layers with a fused skip (the
    /// rdFFT circulant layer adds spectra before its single inverse
    /// sweep) override to avoid the activation copy.
    fn forward_residual(&mut self, x: Tensor) -> Tensor {
        residual_forward_fallback(self, x)
    }

    /// Residual backward `dx = g + layerᵀ(g)`, mirroring
    /// [`Layer::forward_residual`]. Default clones the incoming gradient
    /// for the skip path.
    fn backward_residual(&mut self, grad_out: Tensor) -> Tensor {
        residual_backward_fallback(self, grad_out)
    }

    // ------------- replica-free data-parallel hooks -------------
    //
    // The trainer shards a batch's rows across pool workers. Workers
    // share the layer's parameters *immutably* (no model replicas) and
    // keep all per-shard state — saved activations, the gradient
    // accumulation buffers — local to the shard job. Gradients from all
    // shards are then combined by a deterministic fixed-order tree
    // reduction (`autograd::optim::tree_reduce_with`), so results are
    // bit-identical run-to-run at any thread count.

    /// True when this layer implements the shard hooks below. Layers
    /// without support force the trainer onto the serial step.
    fn supports_shard_exec(&self) -> bool {
        false
    }

    /// Shapes `(rows, cols)` of the gradient tensors this layer
    /// accumulates into during a shard pass — identical order and length
    /// to the pairs [`Layer::for_each_param`] visits. Used to size the
    /// pooled shard arena. Empty for layers without shard support.
    fn grad_shapes(&self) -> Vec<(usize, usize)> {
        Vec::new()
    }

    /// One-time per-step preparation on the submitting thread, before
    /// any shard job runs (e.g. the rdFFT layer transforms its parameter
    /// buffer to block spectra so shard jobs can read it immutably).
    fn begin_shard_step(&mut self) {}

    /// Residual forward `y = x + layer(x)` of one shard: parameters
    /// read-only, every saved tensor inside the returned [`ShardSaved`].
    /// Must be bit-identical per row to [`Layer::forward_residual`].
    fn shard_forward_residual(&self, _x: Tensor) -> (Tensor, ShardSaved) {
        unimplemented!("layer has no shard support (see supports_shard_exec)")
    }

    /// Residual backward of one shard: consumes the saved state,
    /// accumulates parameter gradients into `grads` (same order/shapes
    /// as [`Layer::grad_shapes`]; the rdFFT layer accumulates *spectra*
    /// here — see [`Layer::finish_shard_grads`]), returns dx.
    fn shard_backward_residual(
        &self,
        _grad_out: Tensor,
        _saved: ShardSaved,
        _grads: &mut [Tensor],
    ) -> Tensor {
        unimplemented!("layer has no shard support (see supports_shard_exec)")
    }

    /// Convert the tree-reduced shard gradients into the canonical (time)
    /// domain [`Layer::for_each_param`] expects — one call per step, on
    /// the submitting thread, after the reduction. Default: gradients are
    /// already canonical.
    fn finish_shard_grads(&mut self, _grads: &mut [Tensor]) {}

    // ------------- inference-serving hooks -------------
    //
    // The serve path runs forward-only over caller-owned arena tensors:
    // parameters are read immutably (`&self`, shared across a whole
    // serving session), nothing is saved for backward, and no tensor is
    // allocated — which is what lets the server prove zero steady-state
    // allocation per request under `Category::Serve`. Per-row outputs
    // must be bit-identical to the training forward and independent of
    // which other rows share the tile, so micro-batched responses never
    // depend on arrival timing.

    /// True when this layer implements [`Layer::infer_forward_residual`].
    fn supports_infer_exec(&self) -> bool {
        false
    }

    /// Inference-only residual forward `out = x + layer(x)` into a
    /// caller-provided tensor of identical shape. `x` is mutable scratch
    /// and may be destroyed (the rdFFT layer stages `x̂` in `x`'s own
    /// buffer, exactly like the shard path). Spectral layers require a
    /// [`Layer::begin_shard_step`] call first, so the parameter spectra
    /// exist before the first request.
    fn infer_forward_residual(&self, _x: &mut Tensor, _out: &mut Tensor) {
        unimplemented!("layer has no inference support (see supports_infer_exec)")
    }
}

/// The clone-and-add residual forward, shared by the [`Layer`] trait
/// default and the overrides that only fuse some configurations (so the
/// fused and unfused skip semantics can never drift apart).
fn residual_forward_fallback<L: Layer + ?Sized>(layer: &mut L, x: Tensor) -> Tensor {
    let skip = x.clone_as(Category::Intermediates);
    let mut y = layer.forward(x);
    y.axpy(&skip, 1.0);
    y
}

/// The clone-and-add residual backward, mirroring
/// [`residual_forward_fallback`].
fn residual_backward_fallback<L: Layer + ?Sized>(layer: &mut L, grad_out: Tensor) -> Tensor {
    let skip = grad_out.clone_as(Category::Intermediates);
    let mut dx = layer.backward(grad_out);
    dx.axpy(&skip, 1.0);
    dx
}

// ---------------------------------------------------------------------
// Full fine-tuning
// ---------------------------------------------------------------------

/// Dense layer trained in full — the paper's "FF" row. The weight itself
/// is the trainable tensor.
pub struct Dense {
    w: Tensor,      // [out, in], Trainable
    dw: Tensor,     // [out, in], Gradients
    saved_x: Option<Tensor>,
}

impl Dense {
    pub fn new(out_dim: usize, in_dim: usize, seed: u64) -> Self {
        let scale = (1.0 / in_dim as f32).sqrt();
        Dense {
            w: Tensor::rand(out_dim, in_dim, scale, seed, Category::Trainable),
            dw: Tensor::zeros_cat(out_dim, in_dim, Category::Gradients),
            saved_x: None,
        }
    }
    pub fn weight(&self) -> &Tensor {
        &self.w
    }

    /// Replica-free shard forward (no residual): `y = x·Wᵀ` with the
    /// weight read-only. Used directly by the stack's readout.
    pub fn shard_forward(&self, x: &Tensor) -> Tensor {
        let mut out = Tensor::zeros_cat(x.rows, self.w.rows, Category::Intermediates);
        matmul_nt(x, &self.w, &mut out);
        out
    }

    /// Replica-free shard backward (no residual): accumulates `dW += gᵀx`
    /// into the external `dw` buffer and returns `dx = g·W`.
    pub fn shard_backward(&self, g: &Tensor, x: &Tensor, dw: &mut Tensor) -> Tensor {
        matmul_tn_acc(g, x, dw);
        let mut dx = Tensor::zeros_cat(g.rows, self.w.cols, Category::Intermediates);
        matmul_nn(g, &self.w, &mut dx);
        dx
    }
}

impl Layer for Dense {
    fn forward(&mut self, x: Tensor) -> Tensor {
        let mut out = Tensor::zeros_cat(x.rows, self.w.rows, Category::Intermediates);
        matmul_nt(&x, &self.w, &mut out);
        self.saved_x = Some(x);
        out
    }

    fn backward(&mut self, grad_out: Tensor) -> Tensor {
        let x = self.saved_x.take().expect("forward before backward");
        matmul_tn_acc(&grad_out, &x, &mut self.dw);
        let mut dx = Tensor::zeros_cat(grad_out.rows, self.w.cols, Category::Intermediates);
        matmul_nn(&grad_out, &self.w, &mut dx);
        dx
    }

    fn sgd_step(&mut self, lr: f32) {
        self.w.axpy(&self.dw, -lr);
        self.dw.fill(0.0);
    }

    fn num_trainable(&self) -> usize {
        self.w.len()
    }

    fn clear_saved(&mut self) {
        self.saved_x = None;
    }

    fn for_each_param(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        f(self.w.as_mut_slice(), self.dw.as_mut_slice());
    }

    fn supports_shard_exec(&self) -> bool {
        // the residual hooks below assume the block is square (the
        // stack's blocks always are)
        self.w.rows == self.w.cols
    }

    fn grad_shapes(&self) -> Vec<(usize, usize)> {
        vec![(self.w.rows, self.w.cols)]
    }

    /// Same op order as `residual_forward_fallback` + [`Dense::forward`]
    /// (matmul fill, then the skip add), so rows are bit-identical to the
    /// serial path.
    fn shard_forward_residual(&self, x: Tensor) -> (Tensor, ShardSaved) {
        let mut y = self.shard_forward(&x);
        y.axpy(&x, 1.0);
        (y, Box::new(x))
    }

    fn shard_backward_residual(
        &self,
        grad_out: Tensor,
        saved: ShardSaved,
        grads: &mut [Tensor],
    ) -> Tensor {
        let x = *saved.downcast::<Tensor>().expect("Dense shard state is the saved input");
        let mut dx = self.shard_backward(&grad_out, &x, &mut grads[0]);
        dx.axpy(&grad_out, 1.0);
        dx
    }

    fn supports_infer_exec(&self) -> bool {
        self.w.rows == self.w.cols
    }

    /// Allocation-free twin of [`Dense::shard_forward_residual`]: same op
    /// order (matmul fill, then skip add), writing into the serve arena.
    fn infer_forward_residual(&self, x: &mut Tensor, out: &mut Tensor) {
        matmul_nt(x, &self.w, out);
        out.axpy(x, 1.0);
    }
}

/// Frozen dense layer (no gradient to parameters; used as the base model
/// the adapters ride on, and as the frozen readout of the Table 4 task).
pub struct FrozenDense {
    w: Tensor, // [out, in], Weights
    saved_x_rows: usize,
}

impl FrozenDense {
    pub fn new(out_dim: usize, in_dim: usize, seed: u64) -> Self {
        let scale = (1.0 / in_dim as f32).sqrt();
        FrozenDense {
            w: Tensor::rand(out_dim, in_dim, scale, seed, Category::Weights),
            saved_x_rows: 0,
        }
    }

    pub fn forward(&mut self, x: &Tensor) -> Tensor {
        self.saved_x_rows = x.rows;
        let mut out = Tensor::zeros_cat(x.rows, self.w.rows, Category::Intermediates);
        matmul_nt(x, &self.w, &mut out);
        out
    }

    pub fn backward(&self, grad_out: &Tensor) -> Tensor {
        let mut dx = Tensor::zeros_cat(grad_out.rows, self.w.cols, Category::Intermediates);
        matmul_nn(grad_out, &self.w, &mut dx);
        dx
    }
}

// ---------------------------------------------------------------------
// LoRA
// ---------------------------------------------------------------------

/// LoRA adapter over a frozen base weight: `y = x·W₀ᵀ + (x·Aᵀ)·Bᵀ · α/r`.
pub struct Lora {
    w0: Tensor,          // frozen [out, in], Weights
    a: Tensor,           // [r, in], Trainable
    b: Tensor,           // [out, r], Trainable
    da: Tensor,          // Gradients
    db: Tensor,          // Gradients
    scale: f32,
    saved_x: Option<Tensor>,
    saved_xa: Option<Tensor>, // the extra intermediate LoRA must keep
}

impl Lora {
    pub fn new(out_dim: usize, in_dim: usize, rank: usize, seed: u64) -> Self {
        let _g = ScopedCategory::new(Category::Trainable);
        Lora {
            w0: Tensor::rand(out_dim, in_dim, (1.0 / in_dim as f32).sqrt(), seed, Category::Weights),
            a: Tensor::rand(rank, in_dim, (1.0 / in_dim as f32).sqrt(), seed + 1, Category::Trainable),
            b: Tensor::zeros_cat(out_dim, rank, Category::Trainable), // zero-init B
            da: Tensor::zeros_cat(rank, in_dim, Category::Gradients),
            db: Tensor::zeros_cat(out_dim, rank, Category::Gradients),
            scale: 2.0, // α/r fixed at 2 like common LoRA configs
            saved_x: None,
            saved_xa: None,
        }
    }

    pub fn rank(&self) -> usize {
        self.a.rows
    }
}

impl Layer for Lora {
    fn forward(&mut self, x: Tensor) -> Tensor {
        let mut out = Tensor::zeros_cat(x.rows, self.w0.rows, Category::Intermediates);
        matmul_nt(&x, &self.w0, &mut out);
        // xa = x·Aᵀ  [b, r] — saved for backward (LoRA's extra activation)
        let mut xa = Tensor::zeros_cat(x.rows, self.a.rows, Category::Intermediates);
        matmul_nt(&x, &self.a, &mut xa);
        // out += (xa·Bᵀ)·scale
        let mut delta = Tensor::zeros_cat(x.rows, self.b.rows, Category::Intermediates);
        matmul_nt(&xa, &self.b, &mut delta);
        out.axpy(&delta, self.scale);
        self.saved_x = Some(x);
        self.saved_xa = Some(xa);
        out
    }

    fn backward(&mut self, grad_out: Tensor) -> Tensor {
        let x = self.saved_x.take().expect("forward first");
        let xa = self.saved_xa.take().expect("forward first");
        // dB += scale * gᵀ·xa
        let mut g_scaled = grad_out.clone_as(Category::Intermediates);
        g_scaled.scale(self.scale);
        matmul_tn_acc(&g_scaled, &xa, &mut self.db);
        // d(xa) = scale * g·B    [b, r]
        let mut dxa = Tensor::zeros_cat(grad_out.rows, self.b.cols, Category::Intermediates);
        matmul_nn(&g_scaled, &self.b, &mut dxa);
        // dA += dxaᵀ·x
        matmul_tn_acc(&dxa, &x, &mut self.da);
        // dx = g·W0 + dxa·A
        let mut dx = Tensor::zeros_cat(grad_out.rows, self.w0.cols, Category::Intermediates);
        matmul_nn(&grad_out, &self.w0, &mut dx);
        let mut dx2 = Tensor::zeros_cat(grad_out.rows, self.a.cols, Category::Intermediates);
        matmul_nn(&dxa, &self.a, &mut dx2);
        dx.axpy(&dx2, 1.0);
        dx
    }

    fn sgd_step(&mut self, lr: f32) {
        self.a.axpy(&self.da, -lr);
        self.b.axpy(&self.db, -lr);
        self.da.fill(0.0);
        self.db.fill(0.0);
    }

    fn num_trainable(&self) -> usize {
        self.a.len() + self.b.len()
    }

    fn clear_saved(&mut self) {
        self.saved_x = None;
        self.saved_xa = None;
    }

    fn for_each_param(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        f(self.a.as_mut_slice(), self.da.as_mut_slice());
        f(self.b.as_mut_slice(), self.db.as_mut_slice());
    }

    fn supports_shard_exec(&self) -> bool {
        self.w0.rows == self.w0.cols
    }

    fn grad_shapes(&self) -> Vec<(usize, usize)> {
        vec![(self.a.rows, self.a.cols), (self.b.rows, self.b.cols)]
    }

    /// Op-for-op the serial residual forward ([`Lora::forward`] then the
    /// skip add), with `x`/`xa` saved in the shard state instead of
    /// `self`.
    fn shard_forward_residual(&self, x: Tensor) -> (Tensor, ShardSaved) {
        let mut out = Tensor::zeros_cat(x.rows, self.w0.rows, Category::Intermediates);
        matmul_nt(&x, &self.w0, &mut out);
        let mut xa = Tensor::zeros_cat(x.rows, self.a.rows, Category::Intermediates);
        matmul_nt(&x, &self.a, &mut xa);
        let mut delta = Tensor::zeros_cat(x.rows, self.b.rows, Category::Intermediates);
        matmul_nt(&xa, &self.b, &mut delta);
        out.axpy(&delta, self.scale);
        out.axpy(&x, 1.0);
        (out, Box::new((x, xa)))
    }

    fn shard_backward_residual(
        &self,
        grad_out: Tensor,
        saved: ShardSaved,
        grads: &mut [Tensor],
    ) -> Tensor {
        let (x, xa) = *saved
            .downcast::<(Tensor, Tensor)>()
            .expect("LoRA shard state is (x, x·Aᵀ)");
        let mut g_scaled = grad_out.clone_as(Category::Intermediates);
        g_scaled.scale(self.scale);
        // dB += scale · gᵀ·xa — into the shard's buffer, grads[1]
        matmul_tn_acc(&g_scaled, &xa, &mut grads[1]);
        let mut dxa = Tensor::zeros_cat(grad_out.rows, self.b.cols, Category::Intermediates);
        matmul_nn(&g_scaled, &self.b, &mut dxa);
        // dA += dxaᵀ·x — grads[0]
        matmul_tn_acc(&dxa, &x, &mut grads[0]);
        let mut dx = Tensor::zeros_cat(grad_out.rows, self.w0.cols, Category::Intermediates);
        matmul_nn(&grad_out, &self.w0, &mut dx);
        let mut dx2 = Tensor::zeros_cat(grad_out.rows, self.a.cols, Category::Intermediates);
        matmul_nn(&dxa, &self.a, &mut dx2);
        dx.axpy(&dx2, 1.0);
        dx.axpy(&grad_out, 1.0);
        dx
    }
}

// ---------------------------------------------------------------------
// Block-circulant layer, three FFT backends
// ---------------------------------------------------------------------

/// Block-circulant trained layer (`rows × cols` weight, circulant blocks
/// of size `p`), with the FFT backend under test. This is the layer of the
/// paper's single-layer experiments: the trainable parameters are the
/// block spectra/columns (`rows/p · cols/p · p` scalars).
pub struct CirculantLayer {
    backend: Backend,
    rows: usize,
    cols: usize,
    p: usize,
    /// Trainable parameters: time-domain first columns of every circulant
    /// block, for **all** backends (so training trajectories are
    /// bit-for-bit comparable). The rdFFT backend transforms this buffer
    /// to packed spectra *in place* during forward and restores it at the
    /// end of backward; the fft/rfft backends allocate fresh spectra each
    /// step, exactly like their PyTorch counterparts.
    c: Tensor,
    dc: Tensor,
    /// True while `c` holds packed spectra (between an rdFFT forward and
    /// the end of the corresponding backward / `ensure_time_domain`).
    c_in_freq: bool,
    /// Persistent p·cb workspace for the square-case in-place dx
    /// (grad-output is overwritten blockwise; each dx block needs all ĝ
    /// blocks, so one row of scratch is required — the CUDA analogue is
    /// the kernel's shared-memory tile). Allocated once, tracked.
    workspace: Tensor,
    plan: Arc<crate::rdfft::Plan>,
    /// Execution context every engine call of this layer dispatches on
    /// (pool + tuning). Defaults to the global context; the stack
    /// installs its own via [`CirculantLayer::set_exec`] so one `ExecCtx`
    /// governs a whole model instead of ad-hoc `EngineConfig`s per call.
    exec: ExecCtx,
    // saved-for-backward state (backend-dependent)
    saved_x: Option<Tensor>,           // rdfft: block spectra of x (in x's own buffer!)
    saved_rfft_x: Vec<RfftVec>,        // rfft: spectra of x blocks per row
    saved_rfft_c: Vec<RfftVec>,        // rfft: spectra of c blocks
    saved_cplx_x: Vec<ComplexVec>,     // fft: complex spectra of x blocks per row
    saved_cplx_c: Vec<ComplexVec>,     // fft: complex spectra of c blocks
}

impl CirculantLayer {
    pub fn new(backend: Backend, rows: usize, cols: usize, p: usize, seed: u64) -> Self {
        assert!(rows % p == 0 && cols % p == 0, "dims must be multiples of p");
        let rb = rows / p;
        let cb = cols / p;
        // Small random init (adapters typically start near zero; we use a
        // small scale so the layer is non-degenerate in throughput runs).
        let scale = 0.1 / (cb as f32 * (p as f32).sqrt());
        let c = Tensor::rand(1, rb * cb * p, scale, seed, Category::Trainable);
        let dc = Tensor::zeros_cat(1, rb * cb * p, Category::Gradients);
        let workspace = if backend == Backend::RdFft && rows == cols {
            Tensor::zeros_cat(1, cols, Category::Other)
        } else {
            Tensor::zeros_cat(0, 0, Category::Other)
        };
        CirculantLayer {
            backend,
            rows,
            cols,
            p,
            c,
            dc,
            c_in_freq: false,
            workspace,
            plan: cached(p),
            exec: ExecCtx::global(),
            saved_x: None,
            saved_rfft_x: Vec::new(),
            saved_rfft_c: Vec::new(),
            saved_cplx_x: Vec::new(),
            saved_cplx_c: Vec::new(),
        }
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }
    pub fn block_size(&self) -> usize {
        self.p
    }
    /// Install the execution context all engine calls dispatch on.
    pub fn set_exec(&mut self, exec: ExecCtx) {
        self.exec = exec;
    }
    fn rb(&self) -> usize {
        self.rows / self.p
    }
    fn cb(&self) -> usize {
        self.cols / self.p
    }

    // ---------------- rdFFT backend (ours) ----------------

    /// Restore the parameter buffer to the time domain if a forward left
    /// it holding spectra (eval-only use, or inspection).
    pub fn ensure_time_domain(&mut self) {
        if self.c_in_freq {
            engine::inverse_batch_ctx(&self.plan, self.c.as_mut_slice(), &self.exec);
            self.c_in_freq = false;
        }
    }

    /// Transform the parameter buffer to its packed block spectra if it
    /// is still in the time domain.
    fn ensure_freq_domain(&mut self) {
        if !self.c_in_freq {
            engine::forward_batch_ctx(&self.plan, self.c.as_mut_slice(), &self.exec);
            self.c_in_freq = true;
        }
    }

    fn forward_rdfft(&mut self, mut x: Tensor) -> Tensor {
        let b = x.rows;
        // ĉ: transform the parameter buffer itself, in place (one
        // batch-major engine call over all rb*cb blocks). It stays in the
        // frequency domain until the end of backward restores it.
        self.ensure_freq_domain();
        // Fused sweep over all b samples: each sample's input blocks are
        // forward-staged in place (x's buffer ends holding x̂ — the
        // saved-for-backward tensor), the packed products accumulate into
        // its output blocks, and those are inverse-staged — one
        // cache-resident pass per sample instead of three whole-tensor
        // passes. The output activation is mandatory for any method.
        let mut out = Tensor::zeros_cat(b, self.rows, Category::Intermediates);
        engine::block_circulant_forward_batch_ctx(
            &self.plan,
            x.as_mut_slice(),
            out.as_mut_slice(),
            self.c.as_slice(),
            self.rb(),
            self.cb(),
            &self.exec,
        );
        self.saved_x = Some(x);
        out
    }

    /// Residual variant: `out = x + W x` with the skip added in the
    /// frequency domain inside the fused sweep (the transform is linear),
    /// so the stack's block sweep needs **no** time-domain activation
    /// copy. Square layers only.
    fn forward_rdfft_residual(&mut self, mut x: Tensor) -> Tensor {
        debug_assert_eq!(self.rows, self.cols);
        let b = x.rows;
        self.ensure_freq_domain();
        let mut out = Tensor::zeros_cat(b, self.rows, Category::Intermediates);
        engine::block_circulant_forward_residual_batch_ctx(
            &self.plan,
            x.as_mut_slice(),
            out.as_mut_slice(),
            self.c.as_slice(),
            self.rb(),
            self.cb(),
            &self.exec,
        );
        self.saved_x = Some(x);
        out
    }

    /// rdFFT backward. `residual` additionally adds the skip gradient
    /// (`dx = g + Wᵀg`) in the frequency domain inside the fused sweep —
    /// used by [`Layer::backward_residual`]; square layers only.
    fn backward_rdfft(&mut self, mut g: Tensor, residual: bool) -> Tensor {
        let (p, rb, cb) = (self.p, self.rb(), self.cb());
        let b = g.rows;
        let x_hat = self.saved_x.take().expect("forward first");
        // dx: when the layer is square, grad-output's buffer is
        // overwritten in place with dx (the paper's "overwrite grad_output
        // at the final stage of the backward pass"), using the layer's
        // persistent one-row workspace — each dx block needs every ĝ
        // block, so a row of scratch is unavoidable; it is allocated once
        // at construction (the CUDA analogue is shared memory). The whole
        // sample is processed in one fused, cache-resident sweep: forward
        // stages (ĝ), the dĉ accumulation, the conjugated products, and
        // the inverse stages.
        let dx = if self.rows == self.cols {
            let mut dx = g;
            // The per-sample sweep below is serial (dc and the workspace
            // are shared accumulators), so on batches big enough to
            // thread, run the ĝ transform as one threaded whole-tensor
            // pass up front and let the sweep skip its per-row transform
            // — the same ops either way, bit-identically.
            let pre_transformed = engine::default_would_thread(b * cb, p);
            if pre_transformed {
                engine::forward_batch_ctx(&self.plan, dx.as_mut_slice(), &self.exec);
            }
            for r in 0..b {
                let row = dx.row_mut(r);
                circulant_backward_square_row(
                    &self.plan,
                    self.c.as_slice(),
                    p,
                    rb,
                    cb,
                    row,
                    x_hat.row(r),
                    self.dc.as_mut_slice(),
                    self.workspace.as_mut_slice(),
                    !pre_transformed,
                    residual,
                    simd::select(self.exec.engine_config().force_scalar),
                );
            }
            dx
        } else {
            debug_assert!(!residual, "residual backward requires a square layer");
            // Rectangular: dx is a mandatory output allocation. The fused
            // transpose sweep turns g into ĝ in place and produces dx in
            // the same pass.
            let mut dx = Tensor::zeros_cat(b, self.cols, Category::Intermediates);
            engine::block_circulant_transpose_batch_ctx(
                &self.plan,
                g.as_mut_slice(),
                dx.as_mut_slice(),
                self.c.as_slice(),
                rb,
                cb,
                &self.exec,
            );
            // dĉ += conj(x̂) ⊙ ĝ from the spectra the sweep left behind.
            for r in 0..b {
                let xrow = x_hat.row(r);
                let grow = g.row(r);
                for i in 0..rb {
                    for j in 0..cb {
                        let d = &mut self.dc.as_mut_slice()[(i * cb + j) * p..][..p];
                        spectral::conj_mul_acc_with(
                            simd::select(self.exec.engine_config().force_scalar),
                            d,
                            &xrow[j * p..(j + 1) * p],
                            &grow[i * p..(i + 1) * p],
                        );
                    }
                }
            }
            dx
        };
        // Leave the frequency domain: gradient blocks IFFT in place
        // (Eq. 5's final IFFT), parameter blocks IFFT back so SGD happens
        // on time-domain c, identical to the fft/rfft backends.
        engine::inverse_batch_ctx(&self.plan, self.dc.as_mut_slice(), &self.exec);
        engine::inverse_batch_ctx(&self.plan, self.c.as_mut_slice(), &self.exec);
        self.c_in_freq = false;
        dx
    }

    // ---------------- rfft backend ----------------

    fn forward_rfft(&mut self, x: Tensor) -> Tensor {
        let (p, rb, cb) = (self.p, self.rb(), self.cb());
        let b = x.rows;
        // ĉ blocks (out-of-place, n+2 reals each)
        self.saved_rfft_c = (0..rb * cb)
            .map(|bi| rfft_alloc(&self.c.as_slice()[bi * p..(bi + 1) * p], Category::Intermediates))
            .collect();
        // x̂ blocks per row
        self.saved_rfft_x = Vec::with_capacity(b * cb);
        for r in 0..b {
            for j in 0..cb {
                self.saved_rfft_x
                    .push(rfft_alloc(&x.row(r)[j * p..(j + 1) * p], Category::Intermediates));
            }
        }
        let mut out = Tensor::zeros_cat(b, self.rows, Category::Intermediates);
        for r in 0..b {
            for i in 0..rb {
                // accumulate ŷ_i = Σ_j ĉ_ij ⊙ x̂_j in a fresh spectrum
                let mut acc = RfftVec::zeros(p / 2 + 1, Category::Intermediates);
                for j in 0..cb {
                    let prod = rfft_mul(
                        &self.saved_rfft_c[i * cb + j],
                        &self.saved_rfft_x[r * cb + j],
                        Category::Intermediates,
                    );
                    for k in 0..acc.len() {
                        acc[k].0 += prod[k].0;
                        acc[k].1 += prod[k].1;
                    }
                }
                let y = irfft_alloc(&acc, Category::Intermediates);
                out.row_mut(r)[i * p..(i + 1) * p].copy_from_slice(&y);
            }
        }
        out
    }

    fn backward_rfft(&mut self, g: Tensor) -> Tensor {
        let (p, rb, cb) = (self.p, self.rb(), self.cb());
        let b = g.rows;
        // ĝ blocks
        let g_hat: Vec<RfftVec> = (0..b)
            .flat_map(|r| {
                (0..rb)
                    .map(|i| rfft_alloc(&g.row(r)[i * p..(i + 1) * p], Category::Intermediates))
                    .collect::<Vec<_>>()
            })
            .collect();
        // dc_ij = Σ_r irfft(conj(x̂_rj) ⊙ ĝ_ri)
        for i in 0..rb {
            for j in 0..cb {
                let mut acc = RfftVec::zeros(p / 2 + 1, Category::Intermediates);
                for r in 0..b {
                    let conj_x = rfft_conj(&self.saved_rfft_x[r * cb + j], Category::Intermediates);
                    let prod = rfft_mul(&conj_x, &g_hat[r * rb + i], Category::Intermediates);
                    for k in 0..acc.len() {
                        acc[k].0 += prod[k].0;
                        acc[k].1 += prod[k].1;
                    }
                }
                let d = irfft_alloc(&acc, Category::Intermediates);
                let dst = &mut self.dc.as_mut_slice()[(i * cb + j) * p..][..p];
                for (a, v) in dst.iter_mut().zip(d.iter()) {
                    *a += v;
                }
            }
        }
        // dx_rj = irfft(Σ_i conj(ĉ_ij) ⊙ ĝ_ri)
        let mut dx = Tensor::zeros_cat(b, self.cols, Category::Intermediates);
        for r in 0..b {
            for j in 0..cb {
                let mut acc = RfftVec::zeros(p / 2 + 1, Category::Intermediates);
                for i in 0..rb {
                    let conj_c = rfft_conj(&self.saved_rfft_c[i * cb + j], Category::Intermediates);
                    let prod = rfft_mul(&conj_c, &g_hat[r * rb + i], Category::Intermediates);
                    for k in 0..acc.len() {
                        acc[k].0 += prod[k].0;
                        acc[k].1 += prod[k].1;
                    }
                }
                let d = irfft_alloc(&acc, Category::Intermediates);
                dx.row_mut(r)[j * p..(j + 1) * p].copy_from_slice(&d);
            }
        }
        self.saved_rfft_x.clear();
        self.saved_rfft_c.clear();
        dx
    }

    // ---------------- fft backend ----------------

    fn forward_fft(&mut self, x: Tensor) -> Tensor {
        let (p, rb, cb) = (self.p, self.rb(), self.cb());
        let b = x.rows;
        self.saved_cplx_c = (0..rb * cb)
            .map(|bi| {
                fft_out_of_place(&self.c.as_slice()[bi * p..(bi + 1) * p], Category::Intermediates)
            })
            .collect();
        self.saved_cplx_x = Vec::with_capacity(b * cb);
        for r in 0..b {
            for j in 0..cb {
                self.saved_cplx_x
                    .push(fft_out_of_place(&x.row(r)[j * p..(j + 1) * p], Category::Intermediates));
            }
        }
        let mut out = Tensor::zeros_cat(b, self.rows, Category::Intermediates);
        for r in 0..b {
            for i in 0..rb {
                let mut acc = ComplexVec::zeros(p, Category::Intermediates);
                for j in 0..cb {
                    // product materializes (as `a*b` on complex tensors does)
                    let mut prod = ComplexVec::zeros(p, Category::Intermediates);
                    let ch = &self.saved_cplx_c[i * cb + j];
                    let xh = &self.saved_cplx_x[r * cb + j];
                    for k in 0..p {
                        prod[k] = ch[k].mul(xh[k]);
                    }
                    for k in 0..p {
                        acc[k] = acc[k].add(prod[k]);
                    }
                }
                let y = ifft_out_of_place(&acc, Category::Intermediates);
                let orow = &mut out.row_mut(r)[i * p..(i + 1) * p];
                for k in 0..p {
                    orow[k] = y[k].re; // .real materialization
                }
            }
        }
        out
    }

    fn backward_fft(&mut self, g: Tensor) -> Tensor {
        let (p, rb, cb) = (self.p, self.rb(), self.cb());
        let b = g.rows;
        let g_hat: Vec<ComplexVec> = (0..b)
            .flat_map(|r| {
                (0..rb)
                    .map(|i| {
                        fft_out_of_place(&g.row(r)[i * p..(i + 1) * p], Category::Intermediates)
                    })
                    .collect::<Vec<_>>()
            })
            .collect();
        for i in 0..rb {
            for j in 0..cb {
                let mut acc = ComplexVec::zeros(p, Category::Intermediates);
                for r in 0..b {
                    let xh = &self.saved_cplx_x[r * cb + j];
                    let gh = &g_hat[r * rb + i];
                    for k in 0..p {
                        acc[k] = acc[k].add(xh[k].conj().mul(gh[k]));
                    }
                }
                let d = ifft_out_of_place(&acc, Category::Intermediates);
                let dst = &mut self.dc.as_mut_slice()[(i * cb + j) * p..][..p];
                for k in 0..p {
                    dst[k] += d[k].re;
                }
            }
        }
        let mut dx = Tensor::zeros_cat(b, self.cols, Category::Intermediates);
        for r in 0..b {
            for j in 0..cb {
                let mut acc = ComplexVec::zeros(p, Category::Intermediates);
                for i in 0..rb {
                    let ch = &self.saved_cplx_c[i * cb + j];
                    let gh = &g_hat[r * rb + i];
                    for k in 0..p {
                        acc[k] = acc[k].add(ch[k].conj().mul(gh[k]));
                    }
                }
                let d = ifft_out_of_place(&acc, Category::Intermediates);
                let dst = &mut dx.row_mut(r)[j * p..(j + 1) * p];
                for k in 0..p {
                    dst[k] = d[k].re;
                }
            }
        }
        self.saved_cplx_x.clear();
        self.saved_cplx_c.clear();
        dx
    }
}

/// One sample of the square rdFFT backward sweep, shared **verbatim** by
/// the serial path ([`CirculantLayer::backward_rdfft`], accumulating into
/// the layer's own `dc`/workspace) and the replica-free shard hook
/// ([`Layer::shard_backward_residual`], accumulating into shard-local
/// buffers). Their bitwise equality is a load-bearing contract (the
/// data-parallel determinism suite), so the float ops live in exactly one
/// place. Per row: optional in-place ĝ transform, dĉ += conj(x̂)⊙ĝ, the
/// conjugated dx products (+ optional spectral skip) into `ws`, inverse
/// stages, and the in-place overwrite of the grad-output row with dx.
#[allow(clippy::too_many_arguments)]
fn circulant_backward_square_row(
    plan: &crate::rdfft::Plan,
    c_spec: &[f32],
    p: usize,
    rb: usize,
    cb: usize,
    row: &mut [f32],
    xrow: &[f32],
    dc: &mut [f32],
    ws: &mut [f32],
    transform_row: bool,
    residual: bool,
    kern: crate::rdfft::Kernels,
) {
    // ĝ for this sample, in place (row aliases grad-output) — skipped
    // when the caller already transformed the whole tensor.
    if transform_row {
        engine::forward_rows_with(plan, row, cb.max(1), kern);
    }
    // dĉ_ij += conj(x̂_j) ⊙ ĝ_i — straight into the grad buffer while ĝ
    // is hot.
    for i in 0..rb {
        for j in 0..cb {
            let d = &mut dc[(i * cb + j) * p..][..p];
            spectral::conj_mul_acc_with(
                kern,
                d,
                &xrow[j * p..(j + 1) * p],
                &row[i * p..(i + 1) * p],
            );
        }
    }
    // dx_j = IFFT([ĝ_j +] Σ_i conj(ĉ_ij) ⊙ ĝ_i) into the workspace, then
    // overwrite the sample's grad-output row.
    for (j, sb) in ws.chunks_exact_mut(p).enumerate() {
        sb.fill(0.0);
        for i in 0..rb {
            let ch = &c_spec[(i * cb + j) * p..][..p];
            spectral::conj_mul_acc_with(kern, sb, ch, &row[i * p..(i + 1) * p]);
        }
        if residual {
            // Skip-path gradient, added as spectra (linear).
            for (o, v) in sb.iter_mut().zip(&row[j * p..(j + 1) * p]) {
                *o += v;
            }
        }
    }
    engine::inverse_rows_with(plan, ws, cb.max(1), kern);
    row.copy_from_slice(ws);
}

impl Layer for CirculantLayer {
    fn forward(&mut self, x: Tensor) -> Tensor {
        assert_eq!(x.cols, self.cols);
        match self.backend {
            Backend::RdFft => self.forward_rdfft(x),
            Backend::Rfft => self.forward_rfft(x),
            Backend::Fft => self.forward_fft(x),
        }
    }

    fn backward(&mut self, grad_out: Tensor) -> Tensor {
        assert_eq!(grad_out.cols, self.rows);
        match self.backend {
            Backend::RdFft => self.backward_rdfft(grad_out, false),
            Backend::Rfft => self.backward_rfft(grad_out),
            Backend::Fft => self.backward_fft(grad_out),
        }
    }

    fn forward_residual(&mut self, x: Tensor) -> Tensor {
        assert_eq!(x.cols, self.cols);
        if self.backend == Backend::RdFft && self.rows == self.cols {
            // Fused skip: x̂ is added to the output spectra inside the
            // sweep — no time-domain activation copy.
            return self.forward_rdfft_residual(x);
        }
        residual_forward_fallback(self, x)
    }

    fn backward_residual(&mut self, grad_out: Tensor) -> Tensor {
        assert_eq!(grad_out.cols, self.rows);
        if self.backend == Backend::RdFft && self.rows == self.cols {
            return self.backward_rdfft(grad_out, true);
        }
        residual_backward_fallback(self, grad_out)
    }

    fn supports_shard_exec(&self) -> bool {
        // the replica-free hooks read `c` as shared spectra — only the
        // in-place backend keeps parameters in a worker-shareable form
        self.backend == Backend::RdFft && self.rows == self.cols
    }

    fn grad_shapes(&self) -> Vec<(usize, usize)> {
        vec![(self.dc.rows, self.dc.cols)]
    }

    /// Transform `c` to block spectra once on the submitting thread;
    /// shard jobs then read it immutably.
    fn begin_shard_step(&mut self) {
        self.ensure_freq_domain();
    }

    fn shard_forward_residual(&self, mut x: Tensor) -> (Tensor, ShardSaved) {
        debug_assert!(self.c_in_freq, "begin_shard_step must run before shard jobs");
        let b = x.rows;
        let mut out = Tensor::zeros_cat(b, self.rows, Category::Intermediates);
        engine::block_circulant_forward_residual_batch_ctx(
            &self.plan,
            x.as_mut_slice(),
            out.as_mut_slice(),
            self.c.as_slice(),
            self.rb(),
            self.cb(),
            &self.exec,
        );
        // x's buffer now holds x̂ — the shard-local saved-for-backward
        // tensor (exactly what the serial path keeps in `saved_x`)
        (out, Box::new(x))
    }

    fn supports_infer_exec(&self) -> bool {
        self.backend == Backend::RdFft && self.rows == self.cols
    }

    /// Allocation-free twin of [`Layer::shard_forward_residual`]: the
    /// same per-sample fused sweep over the shared `ĉ` spectra, writing
    /// into the serve arena. `x`'s buffer ends up holding `x̂`, which the
    /// forward-only path simply abandons (nothing is saved for backward).
    fn infer_forward_residual(&self, x: &mut Tensor, out: &mut Tensor) {
        debug_assert!(self.c_in_freq, "begin_shard_step must run before inference");
        debug_assert_eq!(x.cols, self.cols);
        debug_assert_eq!(out.cols, self.rows);
        out.fill(0.0);
        engine::block_circulant_forward_residual_batch_ctx(
            &self.plan,
            x.as_mut_slice(),
            out.as_mut_slice(),
            self.c.as_slice(),
            self.rb(),
            self.cb(),
            &self.exec,
        );
    }

    /// The serial [`CirculantLayer::backward_rdfft`] residual sweep with
    /// every mutable piece externalized: dĉ accumulates into the shard's
    /// `grads[0]` buffer (as *spectra* — [`Layer::finish_shard_grads`]
    /// applies the one shared inverse after the tree reduction, exactly
    /// where the serial path inverts its whole-step accumulation), and
    /// the one-row dx workspace is shard-local. Per row, the float ops
    /// and their order match the serial path bit-for-bit.
    fn shard_backward_residual(
        &self,
        mut g: Tensor,
        saved: ShardSaved,
        grads: &mut [Tensor],
    ) -> Tensor {
        let x_hat = *saved.downcast::<Tensor>().expect("rdFFT shard state is x̂");
        let (p, rb, cb) = (self.p, self.rb(), self.cb());
        let b = g.rows;
        let mut ws = Tensor::zeros_cat(1, self.cols, Category::Intermediates);
        let dc = grads[0].as_mut_slice();
        for r in 0..b {
            let row = g.row_mut(r);
            circulant_backward_square_row(
                &self.plan,
                self.c.as_slice(),
                p,
                rb,
                cb,
                row,
                x_hat.row(r),
                dc,
                ws.as_mut_slice(),
                true,
                true,
                simd::select(self.exec.engine_config().force_scalar),
            );
        }
        g
    }

    /// One inverse over the *reduced* dĉ — the linearity of the
    /// transform is what lets shard spectra sum before the single IFFT.
    fn finish_shard_grads(&mut self, grads: &mut [Tensor]) {
        engine::inverse_batch_ctx(&self.plan, grads[0].as_mut_slice(), &self.exec);
    }

    fn sgd_step(&mut self, lr: f32) {
        // All backends train the same time-domain parameters with the same
        // Eq. 5 gradient, so the three training trajectories are
        // numerically interchangeable (Table 4's accuracy-parity claim).
        self.ensure_time_domain();
        self.c.axpy(&self.dc, -1.0 * lr);
        self.dc.fill(0.0);
    }

    fn num_trainable(&self) -> usize {
        self.c.len()
    }

    fn clear_saved(&mut self) {
        self.saved_x = None;
        self.saved_rfft_x.clear();
        self.saved_rfft_c.clear();
        self.saved_cplx_x.clear();
        self.saved_cplx_c.clear();
        self.ensure_time_domain();
    }

    fn for_each_param(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        // The visitor contract hands out time-domain parameters; restore
        // them first if a forward left spectra in the buffer.
        self.ensure_time_domain();
        f(self.c.as_mut_slice(), self.dc.as_mut_slice());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memtrack;

    fn input(b: usize, d: usize, seed: u64) -> Tensor {
        Tensor::rand(b, d, 1.0, seed, Category::Intermediates)
    }

    fn grad_ones(b: usize, d: usize) -> Tensor {
        let mut g = Tensor::zeros_cat(b, d, Category::Intermediates);
        g.fill(1.0);
        g
    }

    /// The three FFT backends must be numerically interchangeable:
    /// identical forward outputs and identical gradients.
    #[test]
    fn backends_agree_forward_and_backward() {
        let (b, d, p) = (3, 64, 16);
        let mut layers: Vec<CirculantLayer> = [Backend::Fft, Backend::Rfft, Backend::RdFft]
            .iter()
            .map(|&bk| CirculantLayer::new(bk, d, d, p, 77))
            .collect();
        let mut outs = Vec::new();
        let mut dxs = Vec::new();
        let mut dcs = Vec::new();
        for l in layers.iter_mut() {
            let y = l.forward(input(b, d, 5));
            let dx = l.backward(grad_ones(b, d));
            outs.push(y.as_slice().to_vec());
            dxs.push(dx.as_slice().to_vec());
            dcs.push(l.dc.as_slice().to_vec());
        }
        for v in 1..3 {
            for i in 0..outs[0].len() {
                assert!(
                    (outs[0][i] - outs[v][i]).abs() < 1e-3,
                    "forward mismatch backend {v} at {i}: {} vs {}",
                    outs[0][i],
                    outs[v][i]
                );
            }
            for i in 0..dxs[0].len() {
                assert!((dxs[0][i] - dxs[v][i]).abs() < 1e-3, "dx mismatch backend {v} at {i}");
            }
            for i in 0..dcs[0].len() {
                assert!((dcs[0][i] - dcs[v][i]).abs() < 1e-3, "dc mismatch backend {v} at {i}");
            }
        }
    }

    /// After a full train step every backend must land on the same
    /// parameters (Table 4's accuracy-parity claim, microscopically).
    #[test]
    fn backends_training_trajectories_match() {
        let (b, d, p) = (2, 32, 8);
        for bk in [Backend::Fft, Backend::Rfft] {
            let mut a = CirculantLayer::new(bk, d, d, p, 9);
            let mut o = CirculantLayer::new(Backend::RdFft, d, d, p, 9);
            for step in 0..3 {
                let x = input(b, d, 100 + step);
                let x2 = x.clone_as(Category::Intermediates);
                let _ = a.forward(x);
                let _ = o.forward(x2);
                let _ = a.backward(grad_ones(b, d));
                let _ = o.backward(grad_ones(b, d));
                a.sgd_step(0.01);
                o.sgd_step(0.01);
            }
            for i in 0..a.c.len() {
                assert!(
                    (a.c.as_slice()[i] - o.c.as_slice()[i]).abs() < 1e-3,
                    "{} vs rdfft param {i}",
                    bk.name()
                );
            }
        }
    }

    /// The paper's headline property: the rdFFT layer's forward performs
    /// exactly ONE tensor allocation (the mandatory output) and the square
    /// backward performs ZERO.
    #[test]
    fn rdfft_layer_is_allocation_free() {
        let (b, d, p) = (4, 128, 32);
        let mut l = CirculantLayer::new(Backend::RdFft, d, d, p, 3);
        let x = input(b, d, 6);
        let g = grad_ones(b, d);
        memtrack::reset_peak();
        let before = memtrack::snapshot().alloc_count;
        let _y = l.forward(x);
        let after_fwd = memtrack::snapshot().alloc_count;
        assert_eq!(after_fwd - before, 1, "forward must allocate only the output tensor");
        let _dx = l.backward(g);
        let after_bwd = memtrack::snapshot().alloc_count;
        assert_eq!(after_bwd, after_fwd, "square backward must allocate nothing");
    }

    /// fft / rfft backends allocate intermediates, and fft allocates more
    /// than rfft (the ordering Table 1 reports).
    #[test]
    fn baseline_backends_allocate_and_order_holds() {
        let (b, d, p) = (4, 128, 32);
        let mut peaks = Vec::new();
        for bk in [Backend::Fft, Backend::Rfft, Backend::RdFft] {
            memtrack::reset();
            let mut l = CirculantLayer::new(bk, d, d, p, 3);
            let x = input(b, d, 6);
            let g = grad_ones(b, d);
            memtrack::reset_peak();
            let y = l.forward(x);
            let dx = l.backward(g);
            let peak = memtrack::snapshot().peak_total;
            drop(y);
            drop(dx);
            peaks.push(peak);
        }
        assert!(peaks[0] > peaks[1], "fft ({}) must exceed rfft ({})", peaks[0], peaks[1]);
        assert!(peaks[1] > peaks[2], "rfft ({}) must exceed ours ({})", peaks[1], peaks[2]);
    }

    #[test]
    fn dense_layer_gradient_descent_reduces_loss() {
        let (b, d) = (8, 16);
        let mut layer = Dense::new(d, d, 1);
        let target = Tensor::rand(b, d, 1.0, 2, Category::Other);
        let mut last = f32::INFINITY;
        for step in 0..150 {
            let x = Tensor::rand(b, d, 1.0, 42, Category::Intermediates); // fixed batch
            let y = layer.forward(x);
            // L = 0.5 * ||y - t||^2 ; dL/dy = y - t
            let mut g = Tensor::zeros_cat(b, d, Category::Intermediates);
            let mut loss = 0.0f32;
            for i in 0..y.len() {
                let e = y.as_slice()[i] - target.as_slice()[i];
                g.as_mut_slice()[i] = e / b as f32;
                loss += 0.5 * e * e / b as f32;
            }
            let _ = layer.backward(g);
            layer.sgd_step(0.05);
            if step > 0 {
                assert!(loss < last * 1.001, "loss must not increase: {loss} vs {last}");
            }
            last = loss;
        }
        assert!(last < 0.5, "loss should have dropped substantially, got {last}");
    }

    #[test]
    fn lora_trains_and_dense_path_frozen() {
        let (b, d, r) = (4, 32, 4);
        let mut layer = Lora::new(d, d, r, 5);
        let w0_before = layer.w0.as_slice().to_vec();
        let x = input(b, d, 7);
        let y = layer.forward(x);
        // zero-init B means the adapter contributes nothing at step 0:
        // y == x·W0ᵀ exactly.
        let x2 = input(b, d, 7);
        let mut base = Tensor::zeros_cat(b, d, Category::Other);
        matmul_nt(&x2, &layer.w0, &mut base);
        for i in 0..y.len() {
            assert!((y.as_slice()[i] - base.as_slice()[i]).abs() < 1e-5);
        }
        let _ = layer.backward(grad_ones(b, d));
        layer.sgd_step(0.1);
        assert_eq!(layer.w0.as_slice(), &w0_before[..], "frozen weight must not move");
        // after one step B is nonzero => adapter active
        assert!(layer.b.as_slice().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn rectangular_circulant_layer_works() {
        let (b, rows, cols, p) = (2, 32, 64, 16);
        for bk in [Backend::Fft, Backend::Rfft, Backend::RdFft] {
            let mut l = CirculantLayer::new(bk, rows, cols, p, 11);
            let y = l.forward(input(b, cols, 13));
            assert_eq!((y.rows, y.cols), (b, rows));
            let dx = l.backward(grad_ones(b, rows));
            assert_eq!((dx.rows, dx.cols), (b, cols));
        }
    }

    /// The fused frequency-domain residual (`forward_residual` /
    /// `backward_residual` on a square rdFFT layer) must agree with the
    /// default clone-and-add skip to transform-roundtrip precision, for
    /// outputs, input grads, and parameter grads.
    #[test]
    fn fused_residual_matches_clone_and_add_reference() {
        let (b, d, p) = (3, 32, 8);
        let mut reference = CirculantLayer::new(Backend::RdFft, d, d, p, 55);
        let mut fused = CirculantLayer::new(Backend::RdFft, d, d, p, 55);
        let x = input(b, d, 66);
        let x2 = x.clone_as(Category::Intermediates);

        let skip = x.clone_as(Category::Other);
        let mut y_ref = reference.forward(x);
        y_ref.axpy(&skip, 1.0);
        let y_fused = fused.forward_residual(x2);
        for i in 0..y_ref.len() {
            assert!(
                (y_ref.as_slice()[i] - y_fused.as_slice()[i]).abs() < 1e-3,
                "y i={i}: {} vs {}",
                y_ref.as_slice()[i],
                y_fused.as_slice()[i]
            );
        }

        let g = grad_ones(b, d);
        let g2 = grad_ones(b, d);
        let gskip = g.clone_as(Category::Other);
        let mut dx_ref = reference.backward(g);
        dx_ref.axpy(&gskip, 1.0);
        let dx_fused = fused.backward_residual(g2);
        for i in 0..dx_ref.len() {
            assert!(
                (dx_ref.as_slice()[i] - dx_fused.as_slice()[i]).abs() < 1e-3,
                "dx i={i}"
            );
        }
        for i in 0..reference.dc.len() {
            assert!(
                (reference.dc.as_slice()[i] - fused.dc.as_slice()[i]).abs() < 1e-3,
                "dc i={i}"
            );
        }
    }

    /// The fused residual path must keep the layer's allocation story:
    /// forward allocates only the output tensor, backward nothing.
    #[test]
    fn fused_residual_is_allocation_free() {
        let (b, d, p) = (4, 64, 16);
        let mut l = CirculantLayer::new(Backend::RdFft, d, d, p, 8);
        let x = input(b, d, 9);
        let g = grad_ones(b, d);
        memtrack::reset_peak();
        let before = memtrack::snapshot().alloc_count;
        let _y = l.forward_residual(x);
        assert_eq!(memtrack::snapshot().alloc_count - before, 1, "output tensor only");
        let _dx = l.backward_residual(g);
        assert_eq!(memtrack::snapshot().alloc_count - before, 1, "backward allocates nothing");
    }

    /// The replica-free shard hooks must reproduce the serial residual
    /// paths bit-for-bit per row — the foundation of the data-parallel
    /// trainer's any-thread-count determinism.
    #[test]
    fn shard_hooks_match_serial_residual_paths() {
        let (b, d) = (5usize, 32usize);
        // Twin layers per method (same seed): the circulant parameter
        // buffer roundtrips through the frequency domain during a step,
        // so reference and shard passes must each start from pristine
        // parameters to compare bitwise.
        fn make_layer(kind: usize, d: usize) -> Box<dyn Layer> {
            match kind {
                0 => Box::new(Dense::new(d, d, 21)),
                1 => Box::new(Lora::new(d, d, 4, 22)),
                _ => Box::new(CirculantLayer::new(Backend::RdFft, d, d, 8, 23)),
            }
        }
        for kind in 0..3usize {
            let make = || make_layer(kind, d);
            let mut reference = make();
            let mut sharded = make();
            assert!(reference.supports_shard_exec());
            let shapes = sharded.grad_shapes();
            assert!(!shapes.is_empty());

            let x = input(b, d, 31);
            let x2 = x.clone_as(Category::Intermediates);
            // serial reference
            let y_ref = reference.forward_residual(x);
            let dx_ref = reference.backward_residual(grad_ones(b, d));
            let mut dg_ref: Vec<Vec<f32>> = Vec::new();
            reference.for_each_param(&mut |_, g| dg_ref.push(g.to_vec()));

            // shard path (one shard covering the whole batch)
            let mut grads: Vec<Tensor> =
                shapes.iter().map(|&(r, c)| Tensor::zeros_cat(r, c, Category::Gradients)).collect();
            sharded.begin_shard_step();
            let (y_sh, saved) = sharded.shard_forward_residual(x2);
            assert_eq!(y_ref.as_slice(), y_sh.as_slice(), "forward must be bit-identical");
            let dx_sh = sharded.shard_backward_residual(grad_ones(b, d), saved, &mut grads);
            sharded.finish_shard_grads(&mut grads);
            assert_eq!(dx_ref.as_slice(), dx_sh.as_slice(), "dx must be bit-identical");
            for (gr, gs) in dg_ref.iter().zip(&grads) {
                assert_eq!(&gr[..], gs.as_slice(), "param grads must be bit-identical");
            }
        }
    }

    #[test]
    fn rdfft_param_buffer_restored_after_backward() {
        let (b, d, p) = (1, 16, 8);
        let mut l = CirculantLayer::new(Backend::RdFft, d, d, p, 21);
        let c_before = l.c.as_slice().to_vec();
        let _ = l.forward(input(b, d, 1));
        assert!(l.c_in_freq);
        let _ = l.backward(grad_ones(b, d));
        assert!(!l.c_in_freq);
        for i in 0..c_before.len() {
            assert!((l.c.as_slice()[i] - c_before[i]).abs() < 1e-4, "param i={i} perturbed");
        }
    }
}
