//! Minimal training substrate with exact memory accounting.
//!
//! The paper's single-layer experiments (Table 1, Fig 2) train one
//! fine-tuned layer — forward through backward — and record the peak
//! memory of each method. This module is that measurement substrate: a
//! layer-granular autograd (explicit `forward` / `backward` with
//! saved-for-backward state, like `torch.autograd.Function`) whose tensors
//! all live in [`crate::memtrack`]-tracked storage, so every method's peak
//! and breakdown is measured on *real executions* of the real math.
//!
//! Layers implemented (the paper's Table 1 rows):
//! * [`layers::Dense`] — full fine-tuning of a dense `out×in` weight;
//! * [`layers::Lora`] — LoRA with rank `r` over a frozen base weight;
//! * [`layers::CirculantLayer`] — block-circulant training with a
//!   selectable FFT backend: `fft` (complex, out-of-place), `rfft`
//!   (half-spectrum, out-of-place), `rdfft` (the paper's in-place method).
//!
//! The same layers power the Table 4 throughput/accuracy runs via
//! [`train`].

pub mod layers;
pub mod longconv;
pub mod optim;
pub mod stack;
pub mod tensor;
pub mod train;

pub use layers::{Backend, CirculantLayer, Dense, FrozenDense, Layer, Lora};
pub use longconv::LongConvLayer;
pub use optim::{tree_reduce_with, OptimKind, Optimizer, OptimizerBank};
pub use stack::{ShardArena, SpectralStack, StackConfig, GRAD_SHARDS};
pub use tensor::Tensor;
