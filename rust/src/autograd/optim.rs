//! Optimizers with exact memory accounting.
//!
//! The paper trains with plain SGD "in all experiments" precisely because
//! stateful optimizers allocate per-parameter state that would swamp the
//! operator-level savings rdFFT buys. This module makes that trade-off
//! *measurable*: every optimizer's state lives in tracked storage
//! (`Category::Other`, like the paper's "others" bucket), so
//! `repro table2`-style accounting can quantify SGD vs momentum vs Adam —
//! the ablation the paper's §5.1.2 setup implies but does not print.

use crate::memtrack::{Category, TrackedVec};

/// Optimizer algorithm + hyperparameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimKind {
    /// Plain SGD — zero state (the paper's choice).
    Sgd,
    /// SGD with momentum — one state buffer per parameter.
    Momentum { beta: f32 },
    /// Adam — two state buffers per parameter (+ bias correction).
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

impl OptimKind {
    pub fn name(&self) -> &'static str {
        match self {
            OptimKind::Sgd => "sgd",
            OptimKind::Momentum { .. } => "momentum",
            OptimKind::Adam { .. } => "adam",
        }
    }

    /// State scalars per parameter scalar (the Table-2 extension column).
    pub fn state_per_param(&self) -> usize {
        match self {
            OptimKind::Sgd => 0,
            OptimKind::Momentum { .. } => 1,
            OptimKind::Adam { .. } => 2,
        }
    }
}

/// An optimizer instance bound to a fixed parameter length.
pub struct Optimizer {
    kind: OptimKind,
    lr: f32,
    step: u64,
    m: Option<TrackedVec>,
    v: Option<TrackedVec>,
}

impl Optimizer {
    /// Allocate optimizer state for `param_len` scalars (tracked under
    /// `Other`, the paper's "others" memory bucket).
    pub fn new(kind: OptimKind, lr: f32, param_len: usize) -> Self {
        let (m, v) = match kind {
            OptimKind::Sgd => (None, None),
            OptimKind::Momentum { .. } => {
                (Some(TrackedVec::zeros(param_len, Category::Other)), None)
            }
            OptimKind::Adam { .. } => (
                Some(TrackedVec::zeros(param_len, Category::Other)),
                Some(TrackedVec::zeros(param_len, Category::Other)),
            ),
        };
        Optimizer { kind, lr, step: 0, m, v }
    }

    pub fn kind(&self) -> OptimKind {
        self.kind
    }

    /// State bytes held by this optimizer.
    pub fn state_bytes(&self) -> usize {
        let len = |t: &Option<TrackedVec>| t.as_ref().map(|v| v.len() * 4).unwrap_or(0);
        len(&self.m) + len(&self.v)
    }

    /// Apply one update: `param -= update(grad)`, in place on the
    /// parameter buffer (no transient allocation for any variant).
    pub fn apply(&mut self, param: &mut [f32], grad: &[f32]) {
        assert_eq!(param.len(), grad.len());
        self.step += 1;
        match self.kind {
            OptimKind::Sgd => {
                for (p, g) in param.iter_mut().zip(grad) {
                    *p -= self.lr * g;
                }
            }
            OptimKind::Momentum { beta } => {
                let m = self.m.as_mut().expect("state");
                assert_eq!(m.len(), param.len());
                for ((p, g), mv) in param.iter_mut().zip(grad).zip(m.iter_mut()) {
                    *mv = beta * *mv + g;
                    *p -= self.lr * *mv;
                }
            }
            OptimKind::Adam { beta1, beta2, eps } => {
                let m = self.m.as_mut().expect("state");
                let v = self.v.as_mut().expect("state");
                assert_eq!(m.len(), param.len());
                let bc1 = 1.0 - beta1.powi(self.step as i32);
                let bc2 = 1.0 - beta2.powi(self.step as i32);
                for i in 0..param.len() {
                    let g = grad[i];
                    m[i] = beta1 * m[i] + (1.0 - beta1) * g;
                    v[i] = beta2 * v[i] + (1.0 - beta2) * g * g;
                    let mhat = m[i] / bc1;
                    let vhat = v[i] / bc2;
                    param[i] -= self.lr * mhat / (vhat.sqrt() + eps);
                }
            }
        }
    }
}

/// Per-tensor optimizer collection for a whole model. A model exposes its
/// parameters through a stable-order visitor
/// ([`crate::autograd::layers::Layer::for_each_param`]); tensor `i`'s
/// optimizer state is created lazily at its first visit, sized to that
/// tensor, and reused on every later step.
pub struct OptimizerBank {
    kind: OptimKind,
    lr: f32,
    opts: Vec<Optimizer>,
}

impl OptimizerBank {
    pub fn new(kind: OptimKind, lr: f32) -> Self {
        OptimizerBank { kind, lr, opts: Vec::new() }
    }

    pub fn kind(&self) -> OptimKind {
        self.kind
    }

    /// Number of parameter tensors seen so far.
    pub fn num_tensors(&self) -> usize {
        self.opts.len()
    }

    /// Total tracked state bytes across all tensors (0 for SGD).
    pub fn state_bytes(&self) -> usize {
        self.opts.iter().map(|o| o.state_bytes()).sum()
    }

    /// Export the bank's full state for checkpointing: per-tensor step
    /// counters, plus the first/second-moment buffers flattened in tensor
    /// order (empty vectors for optimizers that hold no such state).
    pub fn export_state(&self) -> (Vec<u64>, Vec<f32>, Vec<f32>) {
        let mut steps = Vec::with_capacity(self.opts.len());
        let mut m = Vec::new();
        let mut v = Vec::new();
        for o in &self.opts {
            steps.push(o.step);
            if let Some(t) = &o.m {
                m.extend_from_slice(&t[..]);
            }
            if let Some(t) = &o.v {
                v.extend_from_slice(&t[..]);
            }
        }
        (steps, m, v)
    }

    /// Restore an [`OptimizerBank::export_state`] capture. `lens` gives
    /// the per-tensor parameter lengths in visit order (the bank is built
    /// lazily, so a freshly-resumed bank has no tensors yet — this
    /// pre-populates it). Length mismatches are typed errors, never
    /// silent truncation.
    pub fn import_state(
        &mut self,
        steps: &[u64],
        m: &[f32],
        v: &[f32],
        lens: &[usize],
    ) -> Result<(), String> {
        if steps.len() != lens.len() {
            return Err(format!(
                "optimizer state covers {} tensors, model has {}",
                steps.len(),
                lens.len()
            ));
        }
        let per = self.kind.state_per_param();
        let total: usize = lens.iter().sum();
        let expect_m = if per >= 1 { total } else { 0 };
        let expect_v = if per >= 2 { total } else { 0 };
        if m.len() != expect_m {
            return Err(format!(
                "optimizer first-moment state has {} scalars, expected {expect_m}",
                m.len()
            ));
        }
        if v.len() != expect_v {
            return Err(format!(
                "optimizer second-moment state has {} scalars, expected {expect_v}",
                v.len()
            ));
        }
        self.opts.clear();
        let mut off = 0usize;
        for (i, &len) in lens.iter().enumerate() {
            let mut o = Optimizer::new(self.kind, self.lr, len);
            o.step = steps[i];
            if let Some(t) = o.m.as_mut() {
                t.copy_from_slice(&m[off..off + len]);
            }
            if let Some(t) = o.v.as_mut() {
                t.copy_from_slice(&v[off..off + len]);
            }
            self.opts.push(o);
            off += len;
        }
        Ok(())
    }

    /// Apply one update to the `idx`-th parameter tensor. `idx` must
    /// follow the visit order (0, 1, 2, ... on the first step, then the
    /// same order every step) so state lines up with its tensor.
    pub fn apply(&mut self, idx: usize, param: &mut [f32], grad: &[f32]) {
        assert!(
            idx <= self.opts.len(),
            "parameter tensors must be visited in a stable order (got idx {idx} with {} known)",
            self.opts.len()
        );
        if idx == self.opts.len() {
            self.opts.push(Optimizer::new(self.kind, self.lr, param.len()));
        }
        self.opts[idx].apply(param, grad);
    }
}

/// Deterministic fixed-order pairwise tree reduction: after the call,
/// `items[0]` holds the reduction of every item (`combine(dst, src)`
/// folds `src` into `dst`). The combine *sequence* depends only on
/// `items.len()` — stride-doubling pairs `(0,1) (2,3) … (0,2) (4,6) … (0,4) …`
/// — never on thread scheduling, which is what makes the data-parallel
/// trainer's gradient sums bit-identical run-to-run at any worker count.
/// Items past index 0 are left in a combined-into state; callers treat
/// them as scratch (the shard arena re-zeroes every step).
pub fn tree_reduce_with<T>(items: &mut [T], mut combine: impl FnMut(&mut T, &T)) {
    let n = items.len();
    let mut stride = 1usize;
    while stride < n {
        let mut i = 0usize;
        while i + stride < n {
            let (head, tail) = items.split_at_mut(i + stride);
            combine(&mut head[i], &tail[0]);
            i += 2 * stride;
        }
        stride *= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memtrack;

    fn quad_loss(p: &[f32]) -> (f32, Vec<f32>) {
        // L = 0.5 * sum((p - t)^2), t = [1, -2, 3, ...]
        let t: Vec<f32> = (0..p.len()).map(|i| (i as f32 + 1.0) * if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let grad: Vec<f32> = p.iter().zip(&t).map(|(a, b)| a - b).collect();
        let loss = grad.iter().map(|g| 0.5 * g * g).sum();
        (loss, grad)
    }

    #[test]
    fn all_optimizers_minimize_quadratic() {
        for kind in [
            OptimKind::Sgd,
            OptimKind::Momentum { beta: 0.9 },
            OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        ] {
            let mut p = vec![0.0f32; 8];
            let lr = if kind == OptimKind::Sgd { 0.1 } else { 0.05 };
            let mut opt = Optimizer::new(kind, lr, p.len());
            let (first, _) = quad_loss(&p);
            for _ in 0..200 {
                let (_, g) = quad_loss(&p);
                opt.apply(&mut p, &g);
            }
            let (last, _) = quad_loss(&p);
            assert!(last < 0.01 * first, "{}: {first} -> {last}", kind.name());
        }
    }

    #[test]
    fn state_memory_matches_kind() {
        memtrack::reset();
        let n = 1024;
        let sgd = Optimizer::new(OptimKind::Sgd, 0.1, n);
        assert_eq!(sgd.state_bytes(), 0);
        let mom = Optimizer::new(OptimKind::Momentum { beta: 0.9 }, 0.1, n);
        assert_eq!(mom.state_bytes(), n * 4);
        let adam = Optimizer::new(OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }, 0.1, n);
        assert_eq!(adam.state_bytes(), 2 * n * 4);
        // and the tracker saw all of it under Other
        let snap = memtrack::snapshot();
        assert_eq!(snap.current[Category::Other.index()], 3 * n * 4);
    }

    #[test]
    fn momentum_accelerates_over_sgd_on_illconditioned_quadratic() {
        // classic: momentum converges faster on elongated valleys
        let run = |kind: OptimKind| -> f32 {
            let mut p = vec![5.0f32, 5.0];
            let mut opt = Optimizer::new(kind, 0.02, 2);
            for _ in 0..100 {
                // L = 0.5*(10*p0^2 + 0.1*p1^2)
                let g = vec![10.0 * p[0], 0.1 * p[1]];
                opt.apply(&mut p, &g);
            }
            0.5 * (10.0 * p[0] * p[0] + 0.1 * p[1] * p[1])
        };
        let sgd = run(OptimKind::Sgd);
        let mom = run(OptimKind::Momentum { beta: 0.9 });
        assert!(mom < sgd, "momentum {mom} should beat sgd {sgd}");
    }

    #[test]
    fn adam_steps_are_scale_invariant() {
        // Adam's update magnitude must not depend on gradient scale.
        let mut p1 = vec![0.0f32];
        let mut p2 = vec![0.0f32];
        let mut o1 = Optimizer::new(OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-12 }, 0.1, 1);
        let mut o2 = Optimizer::new(OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-12 }, 0.1, 1);
        o1.apply(&mut p1, &[1.0]);
        o2.apply(&mut p2, &[1000.0]);
        assert!((p1[0] - p2[0]).abs() < 1e-4, "{} vs {}", p1[0], p2[0]);
    }

    #[test]
    fn bank_minimizes_two_tensors_and_sizes_state_per_tensor() {
        memtrack::reset();
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 3];
        let mut bank =
            OptimizerBank::new(OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }, 0.05);
        let (first_a, _) = quad_loss(&a);
        let (first_b, _) = quad_loss(&b);
        for _ in 0..300 {
            let (_, ga) = quad_loss(&a);
            let (_, gb) = quad_loss(&b);
            bank.apply(0, &mut a, &ga);
            bank.apply(1, &mut b, &gb);
        }
        assert_eq!(bank.num_tensors(), 2);
        assert_eq!(bank.state_bytes(), 2 * (8 + 3) * 4);
        let (last_a, _) = quad_loss(&a);
        let (last_b, _) = quad_loss(&b);
        assert!(last_a < 0.01 * first_a, "{first_a} -> {last_a}");
        assert!(last_b < 0.01 * first_b, "{first_b} -> {last_b}");
    }

    #[test]
    fn bank_sgd_holds_no_state() {
        let mut p = vec![1.0f32; 16];
        let g = vec![0.5f32; 16];
        let mut bank = OptimizerBank::new(OptimKind::Sgd, 0.1);
        bank.apply(0, &mut p, &g);
        assert_eq!(bank.state_bytes(), 0);
        assert!((p[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn bank_state_roundtrip_resumes_bit_identically() {
        let kind = OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        let lens = [8usize, 3usize];
        let run = |resume_at: Option<usize>| -> (Vec<f32>, Vec<f32>) {
            let mut a = vec![0.0f32; 8];
            let mut b = vec![0.0f32; 3];
            let mut bank = OptimizerBank::new(kind, 0.05);
            for step in 0..20 {
                if Some(step) == resume_at {
                    // export, rebuild a fresh (lazily-empty) bank, import:
                    // the trajectory must continue as if nothing happened
                    let (s, m, v) = bank.export_state();
                    bank = OptimizerBank::new(kind, 0.05);
                    bank.import_state(&s, &m, &v, &lens).unwrap();
                }
                let (_, ga) = quad_loss(&a);
                let (_, gb) = quad_loss(&b);
                bank.apply(0, &mut a, &ga);
                bank.apply(1, &mut b, &gb);
            }
            (a, b)
        };
        let (ra, rb) = run(None);
        let (xa, xb) = run(Some(10));
        for i in 0..ra.len() {
            assert_eq!(ra[i].to_bits(), xa[i].to_bits(), "tensor a scalar {i}");
        }
        for i in 0..rb.len() {
            assert_eq!(rb[i].to_bits(), xb[i].to_bits(), "tensor b scalar {i}");
        }
    }

    #[test]
    fn bank_import_rejects_mismatched_state() {
        let kind = OptimKind::Momentum { beta: 0.9 };
        let mut src = OptimizerBank::new(kind, 0.1);
        let mut p = vec![0.0f32; 4];
        src.apply(0, &mut p, &[1.0; 4]);
        let (s, m, v) = src.export_state();
        // wrong tensor count
        let mut dst = OptimizerBank::new(kind, 0.1);
        assert!(dst.import_state(&s, &m, &v, &[4, 2]).is_err());
        // wrong moment length
        let mut dst = OptimizerBank::new(kind, 0.1);
        assert!(dst.import_state(&s, &m[..2], &v, &[4]).is_err());
        // correct shapes import cleanly
        let mut dst = OptimizerBank::new(kind, 0.1);
        assert!(dst.import_state(&s, &m, &v, &[4]).is_ok());
        assert_eq!(dst.state_bytes(), src.state_bytes());
    }

    #[test]
    #[should_panic]
    fn bank_rejects_out_of_order_tensor_indices() {
        let mut bank = OptimizerBank::new(OptimKind::Sgd, 0.1);
        let mut p = vec![0.0f32; 2];
        bank.apply(3, &mut p, &[0.0, 0.0]);
    }

    #[test]
    fn tree_reduce_sums_any_length_and_is_order_fixed() {
        for n in 0..12usize {
            let mut v: Vec<u64> = (1..=n as u64).collect();
            tree_reduce_with(&mut v, |a, b| *a += *b);
            if n > 0 {
                assert_eq!(v[0], (n as u64) * (n as u64 + 1) / 2, "n={n}");
            }
        }
        // the combine order is a pure function of len: record it
        let mut log = Vec::new();
        let mut idx: Vec<usize> = (0..5).collect();
        tree_reduce_with(&mut idx, |a, b| log.push((*a, *b)));
        assert_eq!(log, vec![(0, 1), (2, 3), (0, 2), (0, 4)]);
    }

    #[test]
    fn tree_reduce_grouping_differs_from_sequential_but_sum_matches() {
        // float regression guard: the tree shape is ((a+b)+(c+d)) — fixed
        let mut v = vec![0.1f32, 0.2, 0.3, 0.4];
        tree_reduce_with(&mut v, |a, b| *a += *b);
        let tree = ((0.1f32 + 0.2) + (0.3 + 0.4)) as f32;
        assert_eq!(v[0], tree);
    }

    #[test]
    fn apply_makes_no_transient_allocations() {
        let n = 4096;
        let mut p = vec![0.1f32; n];
        let g = vec![0.01f32; n];
        let mut opt =
            Optimizer::new(OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }, 0.01, n);
        memtrack::reset_peak();
        let before = memtrack::snapshot().alloc_count;
        for _ in 0..3 {
            opt.apply(&mut p, &g);
        }
        assert_eq!(memtrack::snapshot().alloc_count, before);
    }
}
