//! Experiment drivers over the layer substrate: single-layer memory
//! measurement (Table 1 / Fig 2) and the synthetic classification
//! fine-tuning task (Table 4 accuracy-parity).

use super::layers::{Backend, CirculantLayer, Dense, FrozenDense, Layer, Lora};
use super::longconv::LongConvLayer;
use super::tensor::{relu_backward_inplace, relu_inplace, softmax_xent, Rng, Tensor};
use crate::memtrack::{self, Category, Snapshot};

/// The fine-tuning method under test — the row labels of Table 1/2/4.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    FullFinetune,
    Lora { rank: usize },
    Circulant { backend: Backend, p: usize },
    /// Causal long-convolution (fftconv-style) sequence mixing with a
    /// trainable `k`-tap filter ([`LongConvLayer`]).
    LongConv { k: usize },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::FullFinetune => "full-finetune".into(),
            Method::Lora { rank } => format!("lora_r={rank}"),
            Method::Circulant { backend, p } => format!("{}_p={p}", backend.name()),
            Method::LongConv { k } => format!("longconv_k={k}"),
        }
    }

    pub fn build(&self, d: usize, seed: u64) -> Box<dyn Layer> {
        self.build_with(d, seed, &crate::runtime::pool::ExecCtx::global())
    }

    /// True when square layers built from this method implement the
    /// replica-free shard hooks ([`Layer::supports_shard_exec`]) — lets
    /// the trainer decide on data-parallel mode *before* constructing a
    /// model or spawning a pool. Only the out-of-place circulant
    /// backends lack the hooks.
    pub fn supports_shard_exec(&self) -> bool {
        !matches!(
            self,
            Method::Circulant { backend: Backend::Fft | Backend::Rfft, .. }
        )
    }

    /// [`Method::build`] with an explicit execution context installed
    /// into the layer (the circulant layer dispatches every engine call
    /// on it; the dense/LoRA layers are pure matmuls today and carry no
    /// context of their own).
    pub fn build_with(
        &self,
        d: usize,
        seed: u64,
        exec: &crate::runtime::pool::ExecCtx,
    ) -> Box<dyn Layer> {
        match *self {
            Method::FullFinetune => Box::new(Dense::new(d, d, seed)),
            Method::Lora { rank } => Box::new(Lora::new(d, d, rank, seed)),
            Method::Circulant { backend, p } => {
                let mut layer = CirculantLayer::new(backend, d, d, p, seed);
                layer.set_exec(exec.clone());
                Box::new(layer)
            }
            Method::LongConv { k } => {
                let mut layer = LongConvLayer::new(d, k, seed);
                layer.set_exec(exec.clone());
                Box::new(layer)
            }
        }
    }
}

/// Result of one Table-1 cell: peak bytes during one fwd+bwd step and the
/// category breakdown at the peak.
#[derive(Debug, Clone, Copy)]
pub struct MemoryCell {
    pub peak_bytes: usize,
    pub snapshot: Snapshot,
}

impl MemoryCell {
    pub fn peak_mib(&self) -> f64 {
        self.peak_bytes as f64 / (1024.0 * 1024.0)
    }
}

/// Run one single-layer training step (forward → backward, like the
/// paper: "up to the end of the backward pass") and record peak memory.
///
/// The persistent model state (params + grad buffers) is constructed
/// first; the peak is then measured over input creation, forward, and
/// backward — matching how the paper's profiler session scopes the
/// measurement.
pub fn measure_single_layer(method: Method, d: usize, batch: usize, seed: u64) -> MemoryCell {
    memtrack::reset();
    let mut layer = method.build(d, seed);
    memtrack::reset_peak();
    {
        let x = Tensor::rand(batch, d, 1.0, seed + 1, Category::Intermediates);
        let y = layer.forward(x);
        // loss grad == ones (the profiler experiment's synthetic loss)
        let mut g = Tensor::zeros_cat(batch, d, Category::Intermediates);
        g.fill(1.0);
        drop(y); // y's grad replaces y, as autograd frees the activation
        let _dx = layer.backward(g);
    }
    let snapshot = memtrack::snapshot();
    MemoryCell { peak_bytes: snapshot.peak_total, snapshot }
}

/// Full-lifetime measurement, counting the persistent layer state too —
/// used by the Fig 2 breakdown (weights/trainable/grads/intermediates at
/// the peak moment).
pub fn measure_single_layer_with_state(method: Method, d: usize, batch: usize, seed: u64) -> MemoryCell {
    memtrack::reset();
    let mut layer = method.build(d, seed);
    {
        let x = Tensor::rand(batch, d, 1.0, seed + 1, Category::Intermediates);
        let y = layer.forward(x);
        let mut g = Tensor::zeros_cat(batch, d, Category::Intermediates);
        g.fill(1.0);
        drop(y);
        let _dx = layer.backward(g);
    }
    let snapshot = memtrack::snapshot();
    MemoryCell { peak_bytes: snapshot.peak_total, snapshot }
}

/// Synthetic MRPC-like binary classification: inputs are D-dim feature
/// vectors from two noisy, nonlinearly-entangled clusters; a frozen
/// random projection plays the pretrained backbone and the method under
/// test adapts it (Table 4's accuracy-parity experiment, scaled to this
/// testbed).
pub struct ClassifyTask {
    pub d: usize,
    pub classes: usize,
    train_x: Vec<Vec<f32>>,
    train_y: Vec<usize>,
    test_x: Vec<Vec<f32>>,
    test_y: Vec<usize>,
}

impl ClassifyTask {
    pub fn synthesize(d: usize, n_train: usize, n_test: usize, seed: u64) -> Self {
        let classes = 2;
        let mut rng = Rng::new(seed);
        // class prototypes
        let protos: Vec<Vec<f32>> =
            (0..classes).map(|_| (0..d).map(|_| rng.next_gauss()).collect()).collect();
        // Scale the class separation to Δ ≈ 2.8σ regardless of dimension
        // (per-dim signal 2/√d, unit noise): Bayes-optimal accuracy ≈ 92%,
        // so methods differentiate instead of saturating at 100%.
        let sig = 2.0 / (d as f32).sqrt();
        let gen = |n: usize, rng: &mut Rng| {
            let mut xs = Vec::with_capacity(n);
            let mut ys = Vec::with_capacity(n);
            for i in 0..n {
                let c = i % classes;
                let x: Vec<f32> = (0..d)
                    .map(|j| {
                        let base = protos[c][j] * sig;
                        // nonlinear entanglement + unit noise
                        base + 0.5 * (base * 2.0).sin() + rng.next_gauss()
                    })
                    .collect();
                xs.push(x);
                ys.push(c);
            }
            (xs, ys)
        };
        let (train_x, train_y) = gen(n_train, &mut rng);
        let (test_x, test_y) = gen(n_test, &mut rng);
        ClassifyTask { d, classes, train_x, train_y, test_x, test_y }
    }

    fn batch(&self, idxs: &[usize]) -> (Tensor, Vec<usize>) {
        let mut data = Vec::with_capacity(idxs.len() * self.d);
        let mut labels = Vec::with_capacity(idxs.len());
        for &i in idxs {
            data.extend_from_slice(&self.train_x[i]);
            labels.push(self.train_y[i]);
        }
        (Tensor::from_vec(idxs.len(), self.d, data, Category::Intermediates), labels)
    }
}

/// Outcome of a fine-tuning run on [`ClassifyTask`].
#[derive(Debug, Clone)]
pub struct FinetuneResult {
    pub method: String,
    pub final_train_loss: f32,
    pub test_accuracy: f64,
    pub steps: usize,
    pub tokens_per_sec: f64,
}

/// Fine-tune `method` on the task: frozen backbone → adapted layer →
/// ReLU → frozen readout → softmax-CE. Returns accuracy + throughput.
pub fn finetune_classifier(
    task: &ClassifyTask,
    method: Method,
    steps: usize,
    batch: usize,
    lr: f32,
    seed: u64,
) -> FinetuneResult {
    let d = task.d;
    let mut backbone = FrozenDense::new(d, d, seed + 10);
    let mut layer = method.build(d, seed);
    let mut readout = FrozenDense::new(task.classes, d, seed + 20);

    let mut rng = Rng::new(seed + 30);
    let mut last_loss = 0.0f32;
    // audit: allow(determinism-lint) wall-clock feeds the tokens/sec report only; losses and params are seeded-RNG pure
    let t0 = std::time::Instant::now();
    let mut samples = 0usize;
    for _ in 0..steps {
        let idxs: Vec<usize> = (0..batch).map(|_| rng.below(task.train_x.len())).collect();
        let (x, labels) = task.batch(&idxs);
        samples += batch;
        // forward
        let h0 = backbone.forward(&x);
        let mut h1 = layer.forward(h0);
        relu_inplace(&mut h1);
        let logits = readout.forward(&h1);
        let mut dlogits = Tensor::zeros_cat(batch, task.classes, Category::Intermediates);
        last_loss = softmax_xent(&logits, &labels, &mut dlogits);
        // backward
        let mut dh1 = readout.backward(&dlogits);
        relu_backward_inplace(&mut dh1, &h1);
        drop(h1);
        let _dh0 = layer.backward(dh1);
        layer.sgd_step(lr);
    }
    let secs = t0.elapsed().as_secs_f64();

    // evaluate
    let mut correct = 0usize;
    let bsz = 64usize.min(task.test_x.len());
    let mut i = 0;
    while i < task.test_x.len() {
        let hi = (i + bsz).min(task.test_x.len());
        let mut data = Vec::with_capacity((hi - i) * d);
        for row in &task.test_x[i..hi] {
            data.extend_from_slice(row);
        }
        let x = Tensor::from_vec(hi - i, d, data, Category::Intermediates);
        let h0 = backbone.forward(&x);
        let mut h1 = layer.forward(h0);
        relu_inplace(&mut h1);
        let logits = readout.forward(&h1);
        for (r, want) in (i..hi).enumerate() {
            let row = logits.row(r);
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == task.test_y[want] {
                correct += 1;
            }
        }
        layer.clear_saved();
        i = hi;
    }

    FinetuneResult {
        method: method.label(),
        final_train_loss: last_loss,
        test_accuracy: correct as f64 / task.test_x.len() as f64,
        steps,
        tokens_per_sec: samples as f64 * d as f64 / secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_reports_nonzero_peaks() {
        let cell = measure_single_layer(
            Method::Circulant { backend: Backend::RdFft, p: 32 },
            128,
            2,
            1,
        );
        assert!(cell.peak_bytes > 0);
    }

    #[test]
    fn ours_beats_fft_and_rfft_at_single_layer() {
        let d = 256;
        let b = 4;
        let p = 64;
        let fft = measure_single_layer(Method::Circulant { backend: Backend::Fft, p }, d, b, 1);
        let rfft = measure_single_layer(Method::Circulant { backend: Backend::Rfft, p }, d, b, 1);
        let ours = measure_single_layer(Method::Circulant { backend: Backend::RdFft, p }, d, b, 1);
        assert!(fft.peak_bytes > rfft.peak_bytes);
        assert!(rfft.peak_bytes > ours.peak_bytes);
    }

    #[test]
    fn full_finetune_dominates_adapter_memory_with_state() {
        let d = 256;
        let b = 1;
        let ff = measure_single_layer_with_state(Method::FullFinetune, d, b, 1);
        let ours = measure_single_layer_with_state(
            Method::Circulant { backend: Backend::RdFft, p: 64 },
            d,
            b,
            1,
        );
        assert!(ff.peak_bytes > 10 * ours.peak_bytes);
    }

    #[test]
    fn classifier_learns_above_chance() {
        let task = ClassifyTask::synthesize(32, 512, 256, 3);
        let res = finetune_classifier(
            &task,
            Method::Circulant { backend: Backend::RdFft, p: 16 },
            60,
            16,
            0.3,
            7,
        );
        assert!(
            res.test_accuracy > 0.8,
            "accuracy should be well above chance, got {}",
            res.test_accuracy
        );
    }

    #[test]
    fn backends_reach_same_accuracy() {
        let task = ClassifyTask::synthesize(32, 384, 192, 4);
        let accs: Vec<f64> = [Backend::Fft, Backend::Rfft, Backend::RdFft]
            .iter()
            .map(|&bk| {
                finetune_classifier(&task, Method::Circulant { backend: bk, p: 16 }, 40, 16, 0.3, 7)
                    .test_accuracy
            })
            .collect();
        assert!((accs[0] - accs[2]).abs() < 0.03, "fft vs ours: {accs:?}");
        assert!((accs[1] - accs[2]).abs() < 0.03, "rfft vs ours: {accs:?}");
    }
}
