//! Batch assembly: fixed-geometry `(tokens, targets)` pairs for the AOT
//! train step (shapes are baked into the HLO, so the batcher owns the
//! contract of always producing exactly `(batch, seq_len)`).

use super::ByteTokenizer;
use crate::autograd::tensor::Rng;
use std::fmt;

/// Typed failure from context-batch assembly. Tiny corpora (the
/// `train-native --steps 20 --batch 8` CI smoke on a short text, an empty
/// eval split) must surface a clean, actionable error — not a panic or an
/// out-of-bounds index deep inside the sampler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchError {
    /// The corpus has too few tokens to cut even one training window
    /// (`ctx` context bytes + the next-byte label).
    CorpusTooSmall {
        /// Tokens available.
        tokens: usize,
        /// Minimum tokens a single window needs.
        needed: usize,
    },
    /// The deterministic eval split has no full `(context, label)` window.
    EmptyEvalSplit {
        /// Tokens available in the split.
        tokens: usize,
        /// Window length (`ctx + 1`).
        window: usize,
    },
}

impl fmt::Display for BatchError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            BatchError::CorpusTooSmall { tokens, needed } => write!(
                f,
                "corpus too small for a context batch: {tokens} tokens, \
                 need at least {needed} (context + next-byte label)"
            ),
            BatchError::EmptyEvalSplit { tokens, window } => write!(
                f,
                "eval split too small: {tokens} tokens cannot fit one \
                 {window}-token (context, label) window"
            ),
        }
    }
}

impl std::error::Error for BatchError {}

/// Produces next-token-prediction batches from a token stream.
pub struct Batcher {
    tokens: Vec<i32>,
    batch: usize,
    seq_len: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(text: &str, batch: usize, seq_len: usize, seed: u64) -> Self {
        match Self::try_new(text, batch, seq_len, seed) {
            Ok(b) => b,
            Err(e) => panic!("{e}"),
        }
    }

    /// Non-panicking constructor: callers that must fail cleanly on tiny
    /// corpora (the native trainer's CLI path) get a typed
    /// [`BatchError`] instead of the [`Self::new`] panic.
    pub fn try_new(
        text: &str,
        batch: usize,
        seq_len: usize,
        seed: u64,
    ) -> Result<Self, BatchError> {
        let tokens = ByteTokenizer.encode(text);
        if tokens.len() < seq_len + 1 {
            return Err(BatchError::CorpusTooSmall {
                tokens: tokens.len(),
                needed: seq_len + 1,
            });
        }
        Ok(Batcher { tokens, batch, seq_len, rng: Rng::new(seed) })
    }

    pub fn num_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// The sampler's raw RNG state — the batcher's entire cursor (window
    /// starts are drawn from this stream and nothing else), so persisting
    /// it is what makes a resumed run draw the exact batch sequence the
    /// uninterrupted run would have drawn.
    pub fn rng_state(&self) -> u64 {
        self.rng.state()
    }

    /// Restore the sampler to a [`Batcher::rng_state`] capture.
    pub fn restore_rng_state(&mut self, state: u64) {
        self.rng = Rng::from_state(state);
    }

    /// Sample a batch of random windows; targets are inputs shifted by
    /// one (the last position predicts the next byte after the window).
    /// Returns a typed [`BatchError`] — never panics — when the corpus
    /// cannot fit a single `(seq_len, shifted-target)` window. (The
    /// constructor enforces the same bound, but a direct guard keeps this
    /// sampler panic-free on its own terms: the old unguarded
    /// `tokens.len() - seq_len - 1` underflowed usize on ≤ `seq_len`
    /// tokens.)
    pub fn next_batch(&mut self) -> Result<(Vec<i32>, Vec<i32>), BatchError> {
        // A window reads seq_len inputs + 1 shifted label, so the valid
        // starts are the inclusive range 0..=len-seq_len-1 — a draw
        // modulus of len - seq_len (>= 1 once the guard holds). The old
        // `below(len - seq_len - 1)` excluded the final window, so the
        // row whose target ends on the corpus's last token was never
        // sampled.
        let needed = self.seq_len + 1;
        if self.tokens.len() < needed {
            return Err(BatchError::CorpusTooSmall { tokens: self.tokens.len(), needed });
        }
        let mut toks = Vec::with_capacity(self.batch * self.seq_len);
        let mut tgts = Vec::with_capacity(self.batch * self.seq_len);
        for _ in 0..self.batch {
            let start = self.rng.below(self.tokens.len() - self.seq_len);
            toks.extend_from_slice(&self.tokens[start..start + self.seq_len]);
            tgts.extend_from_slice(&self.tokens[start + 1..start + self.seq_len + 1]);
        }
        Ok((toks, tgts))
    }

    /// Sample a batch of `(context, next-byte)` pairs for the native
    /// n-gram trainer: `batch` flat contexts of `ctx` bytes each plus the
    /// byte that follows every context (as a class label). Returns a
    /// typed [`BatchError`] — never panics — when the corpus cannot fit a
    /// single window.
    pub fn next_context_batch(
        &mut self,
        ctx: usize,
    ) -> Result<(Vec<u8>, Vec<usize>), BatchError> {
        // One window needs ctx context bytes + 1 label byte: valid
        // starts are the inclusive range 0..=len-ctx-1, a draw modulus
        // of len - ctx (>= 1 once len >= ctx + 1). The old
        // `below(len - ctx - 1)` excluded the final window (its label is
        // the corpus's last byte) — same off-by-one fixed in the eval
        // samplers' wrap.
        let needed = ctx + 1;
        if self.tokens.len() < needed {
            return Err(BatchError::CorpusTooSmall { tokens: self.tokens.len(), needed });
        }
        let mut contexts = Vec::with_capacity(self.batch * ctx);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let start = self.rng.below(self.tokens.len() - ctx);
            contexts.extend(self.tokens[start..start + ctx].iter().map(|&t| t as u8));
            labels.push(self.tokens[start + ctx] as usize);
        }
        Ok((contexts, labels))
    }

    /// Deterministic `(context, next-byte)` batches for evaluation
    /// (sequential strided windows, wrapping around the corpus). Returns
    /// a typed [`BatchError`] when the split cannot fit one window (the
    /// old modulo-by-zero panic path).
    pub fn eval_context_batch(
        &self,
        index: usize,
        ctx: usize,
    ) -> Result<(Vec<u8>, Vec<usize>), BatchError> {
        let stride = ctx + 1;
        if self.tokens.len() < stride {
            return Err(BatchError::EmptyEvalSplit {
                tokens: self.tokens.len(),
                window: stride,
            });
        }
        // Valid starts are the inclusive range 0..=max_start (a start of
        // exactly `max_start` reads the final window, ending on the last
        // token), so the wrap modulus is `max_start + 1`. The old
        // `% max_start` silently skipped that final window forever — and
        // `max_start + 1 >= 1` also subsumes the one-window split case
        // that previously needed an explicit `max_start == 0` guard.
        let max_start = self.tokens.len() - stride;
        let mut contexts = Vec::with_capacity(self.batch * ctx);
        let mut labels = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            let start = ((index * self.batch + b) * stride) % (max_start + 1);
            contexts.extend(self.tokens[start..start + ctx].iter().map(|&t| t as u8));
            labels.push(self.tokens[start + ctx] as usize);
        }
        Ok((contexts, labels))
    }

    /// Deterministic sequential batches for evaluation (no overlap
    /// randomness; wraps around). Returns a typed [`BatchError`] when the
    /// split cannot fit one `(seq_len + 1)`-token window — this sibling of
    /// [`Self::eval_context_batch`] kept the exact modulo-by-zero panic
    /// (`% max_start` on a split of exactly `stride` tokens) and usize
    /// underflow that were fixed there, so it now gets the same guard.
    pub fn eval_batch(&self, index: usize) -> Result<(Vec<i32>, Vec<i32>), BatchError> {
        // A row reads `seq_len` inputs plus the shifted targets — exactly
        // `stride` consecutive tokens.
        let stride = self.seq_len + 1;
        if self.tokens.len() < stride {
            return Err(BatchError::EmptyEvalSplit {
                tokens: self.tokens.len(),
                window: stride,
            });
        }
        let mut toks = Vec::with_capacity(self.batch * self.seq_len);
        let mut tgts = Vec::with_capacity(self.batch * self.seq_len);
        // Inclusive start range 0..=max_start, modulus `max_start + 1`
        // (never zero): same final-window fix as `eval_context_batch`.
        let max_start = self.tokens.len() - stride;
        for b in 0..self.batch {
            let start = ((index * self.batch + b) * stride) % (max_start + 1);
            toks.extend_from_slice(&self.tokens[start..start + self.seq_len]);
            tgts.extend_from_slice(&self.tokens[start + 1..start + self.seq_len + 1]);
        }
        Ok((toks, tgts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusGen;

    fn make() -> Batcher {
        let text = CorpusGen::new(1).text(4096);
        Batcher::new(&text, 4, 32, 9)
    }

    #[test]
    fn batch_geometry_is_exact() {
        let mut b = make();
        let (t, g) = b.next_batch().unwrap();
        assert_eq!(t.len(), 4 * 32);
        assert_eq!(g.len(), 4 * 32);
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut b = make();
        let (t, g) = b.next_batch().unwrap();
        // within each row, target[i] should equal token[i+1]
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(g[row * 32 + i], t[row * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn eval_batches_are_deterministic() {
        let b = make();
        assert_eq!(b.eval_batch(3).unwrap(), b.eval_batch(3).unwrap());
        assert_ne!(b.eval_batch(0).unwrap().0, b.eval_batch(1).unwrap().0);
    }

    #[test]
    fn eval_windows_cover_the_final_start() {
        // 10 bytes, ctx = 4 → stride 5, max_start = 5. The old
        // `% max_start` wrap drew starts from 0..5 and — because every
        // candidate start is a multiple of stride=5 — actually pinned every
        // row to start 0, so the label 'j' at the end of the corpus was
        // unreachable no matter how many eval batches ran. The fixed
        // `% (max_start + 1)` wrap draws from 0..=5 and 5·k mod 6 walks the
        // whole range, so the final window (ctx "fghi", label 'j') is
        // evaluated.
        let b = Batcher::new("abcdefghij", 1, 4, 1);
        let mut labels = Vec::new();
        for index in 0..6 {
            let (ctx, lab) = b.eval_context_batch(index, 4).unwrap();
            if lab[0] == b'j' as usize {
                assert_eq!(ctx, b"fghi".to_vec(), "final window context");
            }
            labels.push(lab[0]);
        }
        assert!(labels.contains(&(b'j' as usize)), "final window never evaluated: {labels:?}");
        // The old formula provably could not produce it: (k*5) % 5 == 0
        // for every k, so every batch was the start-0 window (label 'e').
        assert!(labels.iter().any(|&l| l != b'e' as usize));

        // Same inclusive-range fix for the seq_len flavour: seq_len = 4
        // (stride 5) on the same corpus now reaches start 5, whose
        // shifted-target row ends on the final token.
        let mut seen_last = false;
        for index in 0..6 {
            let (toks, tgts) = b.eval_batch(index).unwrap();
            assert_eq!(toks.len(), 4);
            if tgts[3] == b'j' as i32 {
                assert_eq!(toks, vec![b'f' as i32, b'g' as i32, b'h' as i32, b'i' as i32]);
                seen_last = true;
            }
        }
        assert!(seen_last, "eval_batch never reached the final window");
    }

    #[test]
    #[should_panic]
    fn rejects_tiny_corpus() {
        Batcher::new("ab", 1, 32, 0);
    }

    #[test]
    fn context_batch_geometry_and_label_follows_context() {
        let text = CorpusGen::new(2).text(4096);
        let mut b = Batcher::new(&text, 8, 16, 3);
        let (ctxs, labels) = b.next_context_batch(6).unwrap();
        assert_eq!(ctxs.len(), 8 * 6);
        assert_eq!(labels.len(), 8);
        let bytes = text.as_bytes();
        for r in 0..8 {
            let ctx = &ctxs[r * 6..(r + 1) * 6];
            // every (context, label) pair must occur verbatim in the corpus
            let found = bytes.windows(7).any(|w| &w[..6] == ctx && w[6] as usize == labels[r]);
            assert!(found, "row {r} not a corpus window");
        }
    }

    #[test]
    fn eval_context_batches_are_deterministic_and_distinct() {
        let text = CorpusGen::new(2).text(4096);
        let b = Batcher::new(&text, 4, 16, 3);
        assert_eq!(
            b.eval_context_batch(2, 8).unwrap(),
            b.eval_context_batch(2, 8).unwrap()
        );
        assert_ne!(
            b.eval_context_batch(0, 8).unwrap().0,
            b.eval_context_batch(1, 8).unwrap().0
        );
    }

    #[test]
    fn tiny_corpus_yields_typed_errors_not_panics() {
        // A corpus long enough for the seq_len-based constructor but far
        // too short for the requested context window must produce the
        // typed errors (this used to panic / index out of bounds).
        let mut b = Batcher::new("a tiny corpus.", 8, 2, 1);
        let err = b.next_context_batch(64).unwrap_err();
        assert!(matches!(err, BatchError::CorpusTooSmall { needed: 65, .. }), "{err:?}");
        let err = b.eval_context_batch(0, 64).unwrap_err();
        assert!(matches!(err, BatchError::EmptyEvalSplit { window: 65, .. }), "{err:?}");
        // Error text is actionable (mentions both sizes).
        let msg = format!("{}", b.next_context_batch(64).unwrap_err());
        assert!(msg.contains("65") && msg.contains("14"), "{msg}");
        // Construction itself has a non-panicking path too (the native
        // trainer uses it so a tiny corpus is a clean CLI error).
        let err = Batcher::try_new("ab", 1, 32, 0).unwrap_err();
        assert!(matches!(err, BatchError::CorpusTooSmall { needed: 33, .. }), "{err:?}");
    }

    #[test]
    fn rng_state_roundtrip_resumes_the_batch_stream() {
        // Capture mid-stream, then replay from a fresh batcher: the
        // restored sampler must draw the exact same windows (the
        // checkpoint/resume contract).
        let mut a = make();
        let _ = a.next_context_batch(8).unwrap();
        let state = a.rng_state();
        let expect = a.next_context_batch(8).unwrap();
        let mut b = make();
        b.restore_rng_state(state);
        assert_eq!(b.next_context_batch(8).unwrap(), expect);
    }

    #[test]
    fn boundary_corpus_exactly_one_window_works() {
        // len == ctx + 1 is the smallest corpus that can serve windows.
        let mut b = Batcher::new("abcdefgh", 4, 2, 1); // 8 tokens
        let (ctxs, labels) = b.next_context_batch(6).unwrap();
        assert_eq!(ctxs.len(), 4 * 6);
        assert_eq!(labels.len(), 4);
        let (ectx, elab) = b.eval_context_batch(3, 6).unwrap();
        assert_eq!(ectx.len(), 4 * 6);
        assert_eq!(elab.len(), 4);

        // A corpus of exactly ctx+1 tokens holds one window: every row
        // samples it from start 0 instead of erroring (the old random
        // bound `below(len - ctx - 1)` was `below(0)` here — a `% 0`
        // panic), and the eval wrap serves it deterministically.
        let mut one = Batcher::new("abcdefg", 2, 2, 1); // 7 tokens, stride 7
        let (rc, rl) = one.next_context_batch(6).unwrap();
        assert_eq!(rc, b"abcdefabcdef".to_vec());
        assert_eq!(rl, vec![b'g' as usize, b'g' as usize]);
        let (c1, l1) = one.eval_context_batch(5, 6).unwrap();
        assert_eq!(c1, b"abcdefabcdef".to_vec());
        assert_eq!(l1, vec![b'g' as usize, b'g' as usize]);

        // Same for the seq_len flavour: len == seq_len + 1 holds exactly
        // one (inputs, shifted-targets) window, served from start 0 (the
        // old bound underflowed or drew `below(0)` here too).
        let mut seq = Batcher::new("abcdefghi", 1, 8, 1); // 9 tokens
        let (t, g) = seq.next_batch().unwrap();
        let expect_t: Vec<i32> = "abcdefgh".bytes().map(|c| c as i32).collect();
        let expect_g: Vec<i32> = "bcdefghi".bytes().map(|c| c as i32).collect();
        assert_eq!(t, expect_t);
        assert_eq!(g, expect_g);
    }

    #[test]
    fn random_samplers_reach_the_final_window() {
        // 12 tokens, seq_len 8 → valid starts 0..=3. The old draw bound
        // `below(len - seq_len - 1)` covered only 0..=2, so the window
        // whose shifted target ends on the corpus's last token was never
        // sampled — the last byte of every corpus was untrainable.
        let mut b = Batcher::new("abcdefghijkl", 1, 8, 5);
        let mut saw_last = false;
        for _ in 0..64 {
            let (_, tgts) = b.next_batch().unwrap();
            if *tgts.last().unwrap() == b'l' as i32 {
                saw_last = true;
            }
        }
        assert!(saw_last, "next_batch never sampled the final window");

        // Context flavour: valid starts 0..=len-ctx-1; the final label
        // (the corpus's last byte) must be drawable.
        let mut saw_last_label = false;
        for _ in 0..64 {
            let (_, labels) = b.next_context_batch(8).unwrap();
            if labels[0] == b'l' as usize {
                saw_last_label = true;
            }
        }
        assert!(saw_last_label, "next_context_batch never sampled the final label");
    }
}
