//! Batch assembly: fixed-geometry `(tokens, targets)` pairs for the AOT
//! train step (shapes are baked into the HLO, so the batcher owns the
//! contract of always producing exactly `(batch, seq_len)`).

use super::ByteTokenizer;
use crate::autograd::tensor::Rng;

/// Produces next-token-prediction batches from a token stream.
pub struct Batcher {
    tokens: Vec<i32>,
    batch: usize,
    seq_len: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(text: &str, batch: usize, seq_len: usize, seed: u64) -> Self {
        let tokens = ByteTokenizer.encode(text);
        assert!(
            tokens.len() > seq_len + 1,
            "corpus too small: {} tokens for seq_len {}",
            tokens.len(),
            seq_len
        );
        Batcher { tokens, batch, seq_len, rng: Rng::new(seed) }
    }

    pub fn num_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Sample a batch of random windows; targets are inputs shifted by
    /// one (the last position predicts the next byte after the window).
    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(self.batch * self.seq_len);
        let mut tgts = Vec::with_capacity(self.batch * self.seq_len);
        for _ in 0..self.batch {
            let start = self.rng.below(self.tokens.len() - self.seq_len - 1);
            toks.extend_from_slice(&self.tokens[start..start + self.seq_len]);
            tgts.extend_from_slice(&self.tokens[start + 1..start + self.seq_len + 1]);
        }
        (toks, tgts)
    }

    /// Deterministic sequential batches for evaluation (no overlap
    /// randomness; wraps around).
    pub fn eval_batch(&self, index: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(self.batch * self.seq_len);
        let mut tgts = Vec::with_capacity(self.batch * self.seq_len);
        let stride = self.seq_len + 1;
        let max_start = self.tokens.len() - stride;
        for b in 0..self.batch {
            let start = ((index * self.batch + b) * stride) % max_start;
            toks.extend_from_slice(&self.tokens[start..start + self.seq_len]);
            tgts.extend_from_slice(&self.tokens[start + 1..start + self.seq_len + 1]);
        }
        (toks, tgts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusGen;

    fn make() -> Batcher {
        let text = CorpusGen::new(1).text(4096);
        Batcher::new(&text, 4, 32, 9)
    }

    #[test]
    fn batch_geometry_is_exact() {
        let mut b = make();
        let (t, g) = b.next_batch();
        assert_eq!(t.len(), 4 * 32);
        assert_eq!(g.len(), 4 * 32);
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut b = make();
        let (t, g) = b.next_batch();
        // within each row, target[i] should equal token[i+1]
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(g[row * 32 + i], t[row * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn eval_batches_are_deterministic() {
        let b = make();
        assert_eq!(b.eval_batch(3), b.eval_batch(3));
        assert_ne!(b.eval_batch(0).0, b.eval_batch(1).0);
    }

    #[test]
    #[should_panic]
    fn rejects_tiny_corpus() {
        Batcher::new("ab", 1, 32, 0);
    }
}
