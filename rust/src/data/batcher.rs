//! Batch assembly: fixed-geometry `(tokens, targets)` pairs for the AOT
//! train step (shapes are baked into the HLO, so the batcher owns the
//! contract of always producing exactly `(batch, seq_len)`).

use super::ByteTokenizer;
use crate::autograd::tensor::Rng;

/// Produces next-token-prediction batches from a token stream.
pub struct Batcher {
    tokens: Vec<i32>,
    batch: usize,
    seq_len: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(text: &str, batch: usize, seq_len: usize, seed: u64) -> Self {
        let tokens = ByteTokenizer.encode(text);
        assert!(
            tokens.len() > seq_len + 1,
            "corpus too small: {} tokens for seq_len {}",
            tokens.len(),
            seq_len
        );
        Batcher { tokens, batch, seq_len, rng: Rng::new(seed) }
    }

    pub fn num_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Sample a batch of random windows; targets are inputs shifted by
    /// one (the last position predicts the next byte after the window).
    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(self.batch * self.seq_len);
        let mut tgts = Vec::with_capacity(self.batch * self.seq_len);
        for _ in 0..self.batch {
            let start = self.rng.below(self.tokens.len() - self.seq_len - 1);
            toks.extend_from_slice(&self.tokens[start..start + self.seq_len]);
            tgts.extend_from_slice(&self.tokens[start + 1..start + self.seq_len + 1]);
        }
        (toks, tgts)
    }

    /// Sample a batch of `(context, next-byte)` pairs for the native
    /// n-gram trainer: `batch` flat contexts of `ctx` bytes each plus the
    /// byte that follows every context (as a class label).
    pub fn next_context_batch(&mut self, ctx: usize) -> (Vec<u8>, Vec<usize>) {
        assert!(
            self.tokens.len() > ctx + 1,
            "corpus too small: {} tokens for ctx {}",
            self.tokens.len(),
            ctx
        );
        let mut contexts = Vec::with_capacity(self.batch * ctx);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let start = self.rng.below(self.tokens.len() - ctx - 1);
            contexts.extend(self.tokens[start..start + ctx].iter().map(|&t| t as u8));
            labels.push(self.tokens[start + ctx] as usize);
        }
        (contexts, labels)
    }

    /// Deterministic `(context, next-byte)` batches for evaluation
    /// (sequential strided windows, wrapping around the corpus).
    pub fn eval_context_batch(&self, index: usize, ctx: usize) -> (Vec<u8>, Vec<usize>) {
        assert!(self.tokens.len() > ctx + 1);
        let stride = ctx + 1;
        let max_start = self.tokens.len() - stride;
        let mut contexts = Vec::with_capacity(self.batch * ctx);
        let mut labels = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            let start = ((index * self.batch + b) * stride) % max_start;
            contexts.extend(self.tokens[start..start + ctx].iter().map(|&t| t as u8));
            labels.push(self.tokens[start + ctx] as usize);
        }
        (contexts, labels)
    }

    /// Deterministic sequential batches for evaluation (no overlap
    /// randomness; wraps around).
    pub fn eval_batch(&self, index: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(self.batch * self.seq_len);
        let mut tgts = Vec::with_capacity(self.batch * self.seq_len);
        let stride = self.seq_len + 1;
        let max_start = self.tokens.len() - stride;
        for b in 0..self.batch {
            let start = ((index * self.batch + b) * stride) % max_start;
            toks.extend_from_slice(&self.tokens[start..start + self.seq_len]);
            tgts.extend_from_slice(&self.tokens[start + 1..start + self.seq_len + 1]);
        }
        (toks, tgts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusGen;

    fn make() -> Batcher {
        let text = CorpusGen::new(1).text(4096);
        Batcher::new(&text, 4, 32, 9)
    }

    #[test]
    fn batch_geometry_is_exact() {
        let mut b = make();
        let (t, g) = b.next_batch();
        assert_eq!(t.len(), 4 * 32);
        assert_eq!(g.len(), 4 * 32);
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut b = make();
        let (t, g) = b.next_batch();
        // within each row, target[i] should equal token[i+1]
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(g[row * 32 + i], t[row * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn eval_batches_are_deterministic() {
        let b = make();
        assert_eq!(b.eval_batch(3), b.eval_batch(3));
        assert_ne!(b.eval_batch(0).0, b.eval_batch(1).0);
    }

    #[test]
    #[should_panic]
    fn rejects_tiny_corpus() {
        Batcher::new("ab", 1, 32, 0);
    }

    #[test]
    fn context_batch_geometry_and_label_follows_context() {
        let text = CorpusGen::new(2).text(4096);
        let mut b = Batcher::new(&text, 8, 16, 3);
        let (ctxs, labels) = b.next_context_batch(6);
        assert_eq!(ctxs.len(), 8 * 6);
        assert_eq!(labels.len(), 8);
        let bytes = text.as_bytes();
        for r in 0..8 {
            let ctx = &ctxs[r * 6..(r + 1) * 6];
            // every (context, label) pair must occur verbatim in the corpus
            let found = bytes.windows(7).any(|w| &w[..6] == ctx && w[6] as usize == labels[r]);
            assert!(found, "row {r} not a corpus window");
        }
    }

    #[test]
    fn eval_context_batches_are_deterministic_and_distinct() {
        let text = CorpusGen::new(2).text(4096);
        let b = Batcher::new(&text, 4, 16, 3);
        assert_eq!(b.eval_context_batch(2, 8), b.eval_context_batch(2, 8));
        assert_ne!(b.eval_context_batch(0, 8).0, b.eval_context_batch(1, 8).0);
    }
}
