//! Data pipeline: synthetic corpus generation, byte-level tokenization and
//! batch assembly for the end-to-end training runs.
//!
//! The paper fine-tunes on GSM8K / MRPC; those datasets are not available
//! in this offline environment, so the coordinator trains on a synthetic
//! corpus with controllable structure (documented substitution, DESIGN.md
//! §2): a second-order word-level Markov source over a small vocabulary
//! produces text whose per-byte entropy is far below uniform, giving the
//! LM a real signal to learn and a loss curve with the familiar shape.

pub mod batcher;
pub mod corpus;

pub use batcher::{BatchError, Batcher};
pub use corpus::CorpusGen;

/// Byte-level tokenizer (vocab 256): identity on bytes, like the paper's
/// smallest-footprint tokenization. Provided as a struct so alternative
/// tokenizers can slot in behind the same interface.
#[derive(Debug, Default, Clone)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn vocab_size(&self) -> usize {
        256
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t.clamp(0, 255)) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_tokenizer_roundtrips_ascii() {
        let t = ByteTokenizer;
        let s = "the quick brown fox; 123!";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn byte_tokenizer_tokens_in_vocab() {
        let t = ByteTokenizer;
        for tok in t.encode("hello world") {
            assert!((0..256).contains(&tok));
        }
    }
}
