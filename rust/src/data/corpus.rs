//! Synthetic corpus generator: a second-order word-level Markov source.
//!
//! Produces English-like text with strong local statistics (fixed phrase
//! templates + Markov transitions), so a character-level LM trained on it
//! shows a genuine, steadily-decreasing loss curve — the learnability the
//! end-to-end experiment needs, without external datasets.

use crate::autograd::tensor::Rng;

/// Word inventory grouped by syntactic role (tiny PCFG-flavoured Markov).
const DETERMINERS: &[&str] = &["the", "a", "every", "some", "this"];
const ADJECTIVES: &[&str] =
    &["quick", "lazy", "spectral", "circulant", "frozen", "tiny", "deep", "sparse"];
const NOUNS: &[&str] =
    &["fox", "model", "kernel", "matrix", "gradient", "buffer", "layer", "spectrum"];
const VERBS: &[&str] =
    &["jumps", "trains", "transforms", "updates", "computes", "stores", "folds", "packs"];
const ADVERBS: &[&str] = &["quickly", "in place", "efficiently", "twice", "losslessly"];
const CONNECTIVES: &[&str] = &["and", "while", "because", "so", "then"];

/// Streaming generator of synthetic sentences.
pub struct CorpusGen {
    rng: Rng,
}

impl CorpusGen {
    pub fn new(seed: u64) -> Self {
        CorpusGen { rng: Rng::new(seed) }
    }

    fn pick<'a>(&mut self, words: &[&'a str]) -> &'a str {
        words[self.rng.below(words.len())]
    }

    /// One clause: "det [adj] noun verb [adv]".
    fn clause(&mut self) -> String {
        let mut s = String::new();
        s.push_str(self.pick(DETERMINERS));
        s.push(' ');
        if self.rng.next_f32() < 0.6 {
            s.push_str(self.pick(ADJECTIVES));
            s.push(' ');
        }
        s.push_str(self.pick(NOUNS));
        s.push(' ');
        s.push_str(self.pick(VERBS));
        if self.rng.next_f32() < 0.5 {
            s.push(' ');
            s.push_str(self.pick(ADVERBS));
        }
        s
    }

    /// One sentence of 1-3 clauses.
    pub fn sentence(&mut self) -> String {
        let mut s = self.clause();
        while self.rng.next_f32() < 0.35 {
            s.push(' ');
            s.push_str(self.pick(CONNECTIVES));
            s.push(' ');
            s.push_str(&self.clause());
        }
        s.push_str(". ");
        s
    }

    /// Generate at least `min_bytes` of text.
    pub fn text(&mut self, min_bytes: usize) -> String {
        let mut out = String::with_capacity(min_bytes + 64);
        while out.len() < min_bytes {
            out.push_str(&self.sentence());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_length() {
        let mut g = CorpusGen::new(1);
        let t = g.text(10_000);
        assert!(t.len() >= 10_000);
    }

    #[test]
    fn is_deterministic_per_seed() {
        let a = CorpusGen::new(7).text(1000);
        let b = CorpusGen::new(7).text(1000);
        assert_eq!(a, b);
        let c = CorpusGen::new(8).text(1000);
        assert_ne!(a, c);
    }

    #[test]
    fn text_is_low_entropy_relative_to_uniform_bytes() {
        // the whole point: the corpus must be learnable
        let t = CorpusGen::new(2).text(50_000);
        let mut counts = [0usize; 256];
        for &b in t.as_bytes() {
            counts[b as usize] += 1;
        }
        let n = t.len() as f64;
        let entropy: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum();
        assert!(entropy < 5.0, "unigram byte entropy too high: {entropy}");
        // and uses a restricted alphabet
        assert!(counts.iter().filter(|&&c| c > 0).count() < 40);
    }

    #[test]
    fn sentences_end_with_period() {
        let mut g = CorpusGen::new(3);
        for _ in 0..10 {
            assert!(g.sentence().ends_with(". "));
        }
    }
}
