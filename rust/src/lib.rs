//! # rdFFT — Memory-Efficient Training with an In-Place FFT
//!
//! Reproduction of *"Memory-Efficient Training with In-Place FFT
//! Implementation"* (NIPS 2025). The library provides:
//!
//! * [`rdfft`] — the paper's contribution: a **real-domain, fully in-place**
//!   FFT/IFFT pair operating inside the original `n`-real-valued buffer,
//!   with packed-spectrum elementwise ops, circulant / block-circulant
//!   matrix products (forward **and** backward, Eq. 4/5 of the paper), and a
//!   software-`bf16` path.
//! * [`baselines`] — the comparators the paper evaluates against: an
//!   out-of-place complex FFT (`torch.fft.fft` analogue, 2n-real output) and
//!   an out-of-place real FFT (`torch.fft.rfft` analogue, n+2-real output),
//!   plus a naive DFT oracle used for accuracy tables.
//! * [`memtrack`] — a category-tagged tracking allocator that measures peak
//!   memory and per-category breakdowns exactly the way the paper's PyTorch
//!   profiler experiments do (Table 1, Table 2, Fig 2).
//! * [`autograd`] — a minimal tape autograd over tracked tensors with the
//!   paper's fine-tuning layers (full fine-tune, LoRA, circulant adapters in
//!   fft / rfft / rdFFT backends). This is the measurement substrate for the
//!   single-layer experiments.
//! * [`model`] — analytical full-model memory model (LLaMA2-7B,
//!   RoBERTa-large; Table 2) plus the small-transformer config used by the
//!   end-to-end training example.
//! * [`data`] — synthetic corpus / classification data generators and
//!   batching used by the coordinator.
//! * [`runtime`] — the execution runtime: the persistent worker pool +
//!   [`runtime::pool::ExecCtx`] handle every threaded compute path
//!   dispatches through (engine → layers → trainer), plus the PJRT CPU
//!   client wrapper that loads the AOT-compiled HLO artifacts produced by
//!   `python/compile/aot.py` (the L2 JAX model with the L1 Pallas rdFFT
//!   kernel inside) and executes them from Rust.
//! * [`coordinator`] — the L3 training orchestrator: training loop, metrics,
//!   evaluation, and the experiment drivers that regenerate every table and
//!   figure of the paper.
//! * [`analysis`] — the repo's own static invariant checker (`repro audit`):
//!   a dependency-free Rust token scanner + lint engine enforcing unsafe
//!   hygiene, thread/lock discipline, zero-alloc hot-path markers, and
//!   determinism scoping across `rust/src` + `rust/tests`.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! measured-vs-paper results.

pub mod analysis;
pub mod autograd;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod memtrack;
pub mod model;
pub mod rdfft;
pub mod runtime;
