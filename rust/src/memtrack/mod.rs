//! Category-tagged tracking allocator.
//!
//! The paper measures peak GPU memory with the PyTorch memory profiler and
//! breaks it down into *model weights*, *trainable params*, *gradients* and
//! *others / intermediates* (Table 1, Table 2, Fig 2). This module measures
//! the same quantities for our Rust executions: every tensor buffer is
//! registered here with a [`Category`] when allocated and unregistered when
//! dropped; we track the running total, the peak total, and the per-category
//! composition *at the moment of peak* — which is exactly what
//! `torch.cuda.max_memory_allocated` + a category breakdown gives.
//!
//! Tracking is thread-local so `cargo test` threads do not interfere.
//! Pool worker threads are the one sanctioned crossing: each job's
//! activity is captured as a [`WorkerDelta`] and merged back into the
//! *submitting* thread's tracker when the scope completes, so threaded
//! execution never hides scratch from the peak accounting (see
//! `runtime::pool`).

use std::cell::RefCell;

/// Memory category, mirroring the paper's Fig 2 / Table 2 breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    /// Frozen base-model weights.
    Weights,
    /// Trainable parameters (adapter vectors, LoRA factors, or the full
    /// weight matrix under full fine-tuning).
    Trainable,
    /// Gradient buffers of trainable parameters.
    Gradients,
    /// Transient tensors created during forward/backward (activations,
    /// FFT scratch, saved-for-backward values). The paper's "others".
    Intermediates,
    /// Anything else (optimizer state, metrics, ...).
    Other,
    /// Checkpoint serialization buffers (save/restore I/O staging). Kept
    /// separate so the paper-style steady-state tables stay honest: a run
    /// with checkpointing off must show zero bytes here, and a run with
    /// it on shows exactly what the snapshot I/O costs.
    Checkpoint,
    /// Inference-serving session arenas (ping-pong activation tiles and
    /// logits reused across requests). Kept separate so the serve path's
    /// zero-steady-state-allocation invariant is checkable on its own:
    /// bytes here must be constant after warmup, request after request.
    Serve,
}

/// Number of categories (array width of every per-category breakdown).
pub const NUM_CATEGORIES: usize = 7;

pub const CATEGORIES: [Category; NUM_CATEGORIES] = [
    Category::Weights,
    Category::Trainable,
    Category::Gradients,
    Category::Intermediates,
    Category::Other,
    Category::Checkpoint,
    Category::Serve,
];

impl Category {
    pub fn index(self) -> usize {
        match self {
            Category::Weights => 0,
            Category::Trainable => 1,
            Category::Gradients => 2,
            Category::Intermediates => 3,
            Category::Other => 4,
            Category::Checkpoint => 5,
            Category::Serve => 6,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Category::Weights => "weights",
            Category::Trainable => "trainable",
            Category::Gradients => "gradients",
            Category::Intermediates => "intermediates",
            Category::Other => "other",
            Category::Checkpoint => "checkpoint",
            Category::Serve => "serve",
        }
    }
}

/// A point-in-time (or peak) memory snapshot in bytes.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Snapshot {
    /// Current bytes per category.
    pub current: [usize; NUM_CATEGORIES],
    /// Peak total bytes observed since the last [`reset`].
    pub peak_total: usize,
    /// Per-category composition at the moment the peak total was reached.
    pub at_peak: [usize; NUM_CATEGORIES],
    /// Independent per-category peaks.
    pub peak_by_cat: [usize; NUM_CATEGORIES],
    /// Number of allocations since reset (allocation-count claims:
    /// rdFFT performs **zero** intermediate allocations).
    pub alloc_count: usize,
}

impl Snapshot {
    pub fn current_total(&self) -> usize {
        self.current.iter().sum()
    }
    pub fn peak_mib(&self) -> f64 {
        self.peak_total as f64 / (1024.0 * 1024.0)
    }
    pub fn at_peak_mib(&self, c: Category) -> f64 {
        self.at_peak[c.index()] as f64 / (1024.0 * 1024.0)
    }
}

#[derive(Default)]
struct Tracker {
    current: [usize; NUM_CATEGORIES],
    peak_total: usize,
    at_peak: [usize; NUM_CATEGORIES],
    peak_by_cat: [usize; NUM_CATEGORIES],
    alloc_count: usize,
    /// Category override stack (see [`ScopedCategory`]).
    scope: Vec<Category>,
}

thread_local! {
    static TRACKER: RefCell<Tracker> = RefCell::new(Tracker::default());
}

/// Reset all counters (start of an experiment cell).
pub fn reset() {
    TRACKER.with(|t| *t.borrow_mut() = Tracker::default());
}

/// Reset only the peak statistics, keeping live allocations registered.
/// Used to measure the peak of a *phase* (e.g. just the backward pass)
/// while the model's persistent tensors remain counted in `current`.
pub fn reset_peak() {
    TRACKER.with(|t| {
        let mut t = t.borrow_mut();
        let total: usize = t.current.iter().sum();
        t.peak_total = total;
        t.at_peak = t.current;
        t.peak_by_cat = t.current;
        t.alloc_count = 0;
    });
}

/// Register `bytes` of storage under `cat`. Call [`on_free`] with the same
/// arguments when the storage is dropped. Tensor types do this in their
/// constructors/Drop impls; prefer those over calling this directly.
pub fn on_alloc(bytes: usize, cat: Category) {
    TRACKER.with(|t| {
        let mut t = t.borrow_mut();
        let i = cat.index();
        t.current[i] += bytes;
        t.alloc_count += 1;
        let total: usize = t.current.iter().sum();
        if total > t.peak_total {
            t.peak_total = total;
            t.at_peak = t.current;
        }
        if t.current[i] > t.peak_by_cat[i] {
            t.peak_by_cat[i] = t.current[i];
        }
    });
}

/// Unregister `bytes` of storage under `cat`.
pub fn on_free(bytes: usize, cat: Category) {
    TRACKER.with(|t| {
        let mut t = t.borrow_mut();
        let i = cat.index();
        debug_assert!(t.current[i] >= bytes, "free of untracked bytes");
        t.current[i] = t.current[i].saturating_sub(bytes);
    });
}

/// Take a snapshot of the current tracking state.
pub fn snapshot() -> Snapshot {
    TRACKER.with(|t| {
        let t = t.borrow();
        Snapshot {
            current: t.current,
            peak_total: t.peak_total,
            at_peak: t.at_peak,
            peak_by_cat: t.peak_by_cat,
            alloc_count: t.alloc_count,
        }
    })
}

/// Aggregated allocation activity of one pool job that ran on a worker
/// thread. The tracker is thread-local, so without this mechanism any
/// scratch a [`crate::runtime::pool::WorkerPool`] job allocates would
/// silently vanish from the submitting thread's peak accounting. Workers
/// capture a delta per job ([`take_job_delta`]), the scope latch collects
/// them, and the submitting thread folds them into its own tracker at
/// scope end ([`merge_worker_deltas`] — at most the pool's worker count
/// of them modeled as concurrent).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WorkerDelta {
    /// Peak total bytes the job(s) reached on the worker tracker.
    pub peak_total: usize,
    /// Per-category composition at that peak.
    pub at_peak: [usize; NUM_CATEGORIES],
    /// Independent per-category peaks.
    pub peak_by_cat: [usize; NUM_CATEGORIES],
    /// Allocations performed by the job(s).
    pub alloc_count: usize,
}

impl WorkerDelta {
    pub fn is_empty(&self) -> bool {
        self.alloc_count == 0 && self.peak_total == 0
    }

    /// Fold another delta into this one as if the two were concurrent:
    /// peaks add (keeping `at_peak` summing to `peak_total`). The scope
    /// merge ([`merge_worker_deltas`]) applies this to at most the
    /// pool-lane count of job deltas, so sequential jobs on one worker
    /// don't stack.
    pub fn absorb(&mut self, other: &WorkerDelta) {
        self.peak_total += other.peak_total;
        for i in 0..NUM_CATEGORIES {
            self.at_peak[i] += other.at_peak[i];
            self.peak_by_cat[i] += other.peak_by_cat[i];
        }
        self.alloc_count += other.alloc_count;
    }
}

/// Capture the calling (worker) thread's tracker as a mergeable delta and
/// reset it for the next job. The worker resets before each job, so the
/// captured state is exactly that job's activity. Jobs must drop every
/// tracked buffer they allocate before returning (scoped borrows make
/// that the natural shape); live bytes at capture time are dropped from
/// the record.
pub fn take_job_delta() -> WorkerDelta {
    TRACKER.with(|t| {
        let mut t = t.borrow_mut();
        let d = WorkerDelta {
            peak_total: t.peak_total,
            at_peak: t.at_peak,
            peak_by_cat: t.peak_by_cat,
            alloc_count: t.alloc_count,
        };
        *t = Tracker::default();
        d
    })
}

/// Fold one scope's worker-job deltas into the calling thread's tracker,
/// modeling at most `max_concurrent` of them (the pool's worker count) as
/// simultaneously live: the jobs with the largest peaks form the modeled
/// concurrent set — a worker runs its jobs sequentially, so summing
/// *every* job's peak would overstate the footprint whenever jobs exceed
/// lanes (e.g. 8 fixed gradient shards on 1 worker). Allocation counts
/// are exact across all jobs regardless.
pub fn merge_worker_deltas(deltas: &[WorkerDelta], max_concurrent: usize) {
    if deltas.is_empty() {
        return;
    }
    let mut order: Vec<usize> = (0..deltas.len()).collect();
    order.sort_by(|&a, &b| deltas[b].peak_total.cmp(&deltas[a].peak_total));
    let mut combined = WorkerDelta::default();
    for (rank, &i) in order.iter().enumerate() {
        if rank < max_concurrent.max(1) {
            // in the modeled concurrent set: the one canonical fold
            combined.absorb(&deltas[i]);
        } else {
            // sequential overflow: counted, but its peak doesn't stack
            combined.alloc_count += deltas[i].alloc_count;
        }
    }
    merge_worker_delta(&combined);
}

/// Fold a worker-side delta into the calling thread's tracker, as if the
/// worker's transient peak had happened here on top of the current live
/// bytes: the submitting thread was at `current` while its jobs ran, so
/// the process-wide step peak is `current + delta.peak`.
pub fn merge_worker_delta(d: &WorkerDelta) {
    if d.is_empty() {
        return;
    }
    TRACKER.with(|t| {
        let mut t = t.borrow_mut();
        t.alloc_count += d.alloc_count;
        let cur: usize = t.current.iter().sum();
        if cur + d.peak_total > t.peak_total {
            t.peak_total = cur + d.peak_total;
            for i in 0..NUM_CATEGORIES {
                t.at_peak[i] = t.current[i] + d.at_peak[i];
            }
        }
        for i in 0..NUM_CATEGORIES {
            let c = t.current[i] + d.peak_by_cat[i];
            if c > t.peak_by_cat[i] {
                t.peak_by_cat[i] = c;
            }
        }
    });
}

/// The category new tensors default to: the innermost [`ScopedCategory`],
/// or `Intermediates` when no scope is active (transient tensors are the
/// common case inside forward/backward).
pub fn default_category() -> Category {
    TRACKER.with(|t| t.borrow().scope.last().copied().unwrap_or(Category::Intermediates))
}

/// RAII guard that makes `cat` the default category for tensors allocated
/// while it is alive. Nestable.
pub struct ScopedCategory;

impl ScopedCategory {
    pub fn new(cat: Category) -> Self {
        TRACKER.with(|t| t.borrow_mut().scope.push(cat));
        ScopedCategory
    }
}

impl Drop for ScopedCategory {
    fn drop(&mut self) {
        TRACKER.with(|t| {
            t.borrow_mut().scope.pop();
        });
    }
}

/// RAII registration of `bytes` of storage the tracker should count even
/// though the bytes do not live in a [`TrackedVec`] — bf16 parameter
/// buffers (2 bytes/scalar), ReLU sign-bit masks, and similar non-f32
/// storage. Registers on construction, unregisters on drop; cloning
/// re-registers (a clone of the owner duplicates the storage).
pub struct Registration {
    bytes: usize,
    cat: Category,
}

impl Registration {
    pub fn new(bytes: usize, cat: Category) -> Self {
        on_alloc(bytes, cat);
        Registration { bytes, cat }
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for Registration {
    fn drop(&mut self) {
        on_free(self.bytes, self.cat);
    }
}

impl Clone for Registration {
    fn clone(&self) -> Self {
        Registration::new(self.bytes, self.cat)
    }
}

impl std::fmt::Debug for Registration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Registration({}B, {})", self.bytes, self.cat.name())
    }
}

/// A `Vec<f32>` whose backing storage is registered with the tracker.
/// This is the building block for tensors and for the out-of-place FFT
/// baselines (whose extra buffers are precisely what the paper measures).
pub struct TrackedVec {
    data: Vec<f32>,
    cat: Category,
}

impl TrackedVec {
    /// Allocate `len` zeroed f32s under `cat`.
    pub fn zeros(len: usize, cat: Category) -> Self {
        on_alloc(len * 4, cat);
        TrackedVec { data: vec![0.0; len], cat }
    }

    /// Allocate from existing data under `cat`.
    pub fn from_vec(data: Vec<f32>, cat: Category) -> Self {
        on_alloc(data.len() * 4, cat);
        TrackedVec { data, cat }
    }

    pub fn category(&self) -> Category {
        self.cat
    }
}

impl std::ops::Deref for TrackedVec {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl std::ops::DerefMut for TrackedVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

impl Drop for TrackedVec {
    fn drop(&mut self) {
        on_free(self.data.len() * 4, self.cat);
    }
}

impl Clone for TrackedVec {
    fn clone(&self) -> Self {
        TrackedVec::from_vec(self.data.clone(), self.cat)
    }
}

impl std::fmt::Debug for TrackedVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TrackedVec(len={}, cat={})", self.data.len(), self.cat.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak_and_breakdown() {
        reset();
        let w = TrackedVec::zeros(1024, Category::Weights); // 4 KiB
        {
            let _tmp = TrackedVec::zeros(2048, Category::Intermediates); // 8 KiB
            let s = snapshot();
            assert_eq!(s.current_total(), 12 * 1024);
            assert_eq!(s.peak_total, 12 * 1024);
        }
        let s = snapshot();
        assert_eq!(s.current_total(), 4 * 1024);
        assert_eq!(s.peak_total, 12 * 1024);
        assert_eq!(s.at_peak[Category::Weights.index()], 4 * 1024);
        assert_eq!(s.at_peak[Category::Intermediates.index()], 8 * 1024);
        drop(w);
        assert_eq!(snapshot().current_total(), 0);
    }

    #[test]
    fn scoped_category_applies() {
        reset();
        assert_eq!(default_category(), Category::Intermediates);
        {
            let _g = ScopedCategory::new(Category::Trainable);
            assert_eq!(default_category(), Category::Trainable);
            {
                let _g2 = ScopedCategory::new(Category::Gradients);
                assert_eq!(default_category(), Category::Gradients);
            }
            assert_eq!(default_category(), Category::Trainable);
        }
        assert_eq!(default_category(), Category::Intermediates);
    }

    #[test]
    fn reset_peak_keeps_live_allocations() {
        reset();
        let _w = TrackedVec::zeros(1024, Category::Weights);
        {
            let _tmp = TrackedVec::zeros(4096, Category::Intermediates);
        }
        assert_eq!(snapshot().peak_total, 4 * 1024 + 16 * 1024);
        reset_peak();
        let s = snapshot();
        assert_eq!(s.peak_total, 4 * 1024);
        assert_eq!(s.alloc_count, 0);
    }

    #[test]
    fn alloc_count_counts_allocations() {
        reset();
        let _a = TrackedVec::zeros(8, Category::Other);
        let _b = TrackedVec::zeros(8, Category::Other);
        assert_eq!(snapshot().alloc_count, 2);
    }

    #[test]
    fn job_delta_roundtrip_captures_and_clears() {
        reset();
        {
            let _tmp = TrackedVec::zeros(256, Category::Intermediates); // 1 KiB
        }
        let d = take_job_delta();
        assert_eq!(d.peak_total, 1024);
        assert_eq!(d.at_peak[Category::Intermediates.index()], 1024);
        assert_eq!(d.alloc_count, 1);
        // the tracker was reset by the capture
        assert_eq!(snapshot().peak_total, 0);
        assert_eq!(snapshot().alloc_count, 0);
    }

    #[test]
    fn merged_delta_stacks_on_live_bytes() {
        reset();
        let _live = TrackedVec::zeros(512, Category::Weights); // 2 KiB live
        let mut d = WorkerDelta {
            peak_total: 4096,
            at_peak: [0, 0, 0, 4096, 0, 0, 0],
            peak_by_cat: [0, 0, 0, 4096, 0, 0, 0],
            alloc_count: 3,
        };
        // two concurrent jobs: absorb doubles the worker-side peak
        let d2 = d;
        d.absorb(&d2);
        merge_worker_delta(&d);
        let s = snapshot();
        assert_eq!(s.peak_total, 2048 + 8192, "worker peak stacks on live bytes");
        assert_eq!(s.at_peak[Category::Weights.index()], 2048);
        assert_eq!(s.at_peak[Category::Intermediates.index()], 8192);
        assert_eq!(s.peak_by_cat[Category::Intermediates.index()], 8192);
        assert_eq!(s.alloc_count, 7, "1 live alloc + 2×3 job allocs");
        // at_peak still sums to peak_total (report consistency invariant)
        assert_eq!(s.at_peak.iter().sum::<usize>(), s.peak_total);
        // empty deltas are no-ops
        merge_worker_delta(&WorkerDelta::default());
        assert_eq!(snapshot().peak_total, s.peak_total);
    }

    #[test]
    fn delta_merge_caps_modeled_concurrency_at_lane_count() {
        reset();
        let d = |peak: usize, allocs: usize| WorkerDelta {
            peak_total: peak,
            at_peak: [0, 0, 0, peak, 0, 0, 0],
            peak_by_cat: [0, 0, 0, peak, 0, 0, 0],
            alloc_count: allocs,
        };
        // 4 jobs on 2 lanes: only the two largest peaks stack; every
        // allocation is still counted.
        merge_worker_deltas(&[d(100, 1), d(400, 1), d(200, 1), d(300, 1)], 2);
        let s = snapshot();
        assert_eq!(s.peak_total, 700, "top-2 peaks only (400 + 300)");
        assert_eq!(s.alloc_count, 4);
        assert_eq!(s.at_peak.iter().sum::<usize>(), s.peak_total);
    }

    #[test]
    fn registration_tracks_and_untracks_bytes() {
        reset();
        {
            let r = Registration::new(100, Category::Trainable);
            assert_eq!(snapshot().current[Category::Trainable.index()], 100);
            let r2 = r.clone();
            assert_eq!(snapshot().current[Category::Trainable.index()], 200);
            drop(r);
            assert_eq!(snapshot().current[Category::Trainable.index()], 100);
            drop(r2);
        }
        assert_eq!(snapshot().current_total(), 0);
        assert_eq!(snapshot().peak_total, 200);
    }
}
