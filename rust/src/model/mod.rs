//! Full-model memory accounting (Table 2).
//!
//! The paper's Table 2 measures peak GPU memory while fine-tuning
//! LLaMA2-7B (GSM8K config, bf16 forward) and RoBERTa-large (MRPC config,
//! fp32). We cannot run those models on this testbed, so this module
//! provides the *analytical* decomposition the paper itself uses —
//! `model + trainable + gradient + others` — parameterised by the real
//! architectures, with the method-dependent `others` term derived from the
//! same per-operator allocation rules our measured single-layer substrate
//! obeys (fft: complex out-of-place intermediates; rfft: half-spectrum
//! out-of-place; rdFFT: none). The single-layer rules are validated
//! byte-exactly by `memtrack` measurements (Table 1), which is what makes
//! this extrapolation credible; see DESIGN.md §2.

use crate::autograd::layers::Backend;
use crate::autograd::train::Method;

/// A transformer architecture, with the training-time precision choices
/// the paper reports.
#[derive(Debug, Clone)]
pub struct ArchSpec {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    /// Bytes per base-model parameter (2 = bf16, 4 = fp32).
    pub param_bytes: usize,
    /// Bytes per gradient element of *trainable* params (paper: LLaMA
    /// stores grads in fp32 even with bf16 forward; RoBERTa is fp32
    /// throughout).
    pub grad_bytes: usize,
    /// Bytes per activation element in the forward pass.
    pub act_bytes: usize,
    /// Number of adapted projections per layer (the paper's BCA setup
    /// adapts the attention q/v projections).
    pub adapted_per_layer: usize,
    /// MLP matrices per layer (LLaMA's SwiGLU has 3, classic FFN has 2).
    pub mlp_mats: usize,
}

impl ArchSpec {
    /// LLaMA2-7B with the paper's GSM8K configuration
    /// (per-device batch 2, bf16 forward, fp32 grads).
    pub fn llama2_7b() -> Self {
        ArchSpec {
            name: "LLaMA2-7B",
            n_layers: 32,
            d_model: 4096,
            d_ff: 11008,
            n_heads: 32,
            vocab: 32000,
            seq_len: 512,
            batch: 2,
            param_bytes: 2,
            grad_bytes: 4,
            act_bytes: 2,
            adapted_per_layer: 2,
            mlp_mats: 3,
        }
    }

    /// RoBERTa-large with the paper's MRPC configuration
    /// (batch 32, fp32 throughout).
    pub fn roberta_large() -> Self {
        ArchSpec {
            name: "RoBERTa-Large",
            n_layers: 24,
            d_model: 1024,
            d_ff: 4096,
            n_heads: 16,
            vocab: 50265,
            seq_len: 128,
            batch: 32,
            param_bytes: 4,
            grad_bytes: 4,
            act_bytes: 4,
            adapted_per_layer: 2,
            mlp_mats: 2,
        }
    }

    /// Total base parameters (standard transformer counting; attention
    /// uses 4 d² matrices, MLP 2·d·ff, embeddings vocab·d).
    pub fn num_params(&self) -> usize {
        let per_layer = 4 * self.d_model * self.d_model
            + self.mlp_mats * self.d_model * self.d_ff
            // layernorm scales/biases
            + 4 * self.d_model;
        self.n_layers * per_layer + self.vocab * self.d_model + self.seq_len * self.d_model
    }

    /// Trainable parameter count for a method.
    pub fn trainable_params(&self, method: Method) -> usize {
        match method {
            Method::FullFinetune => self.num_params(),
            Method::Lora { rank } => {
                // LoRA on the same adapted projections: A (r×d) + B (d×r)
                self.n_layers * self.adapted_per_layer * 2 * rank * self.d_model
            }
            Method::Circulant { p, .. } => {
                // each adapted d×d projection: (d/p)² blocks × p params
                self.n_layers * self.adapted_per_layer * (self.d_model / p) * (self.d_model / p)
                    * p
            }
        }
    }

    /// Baseline activation footprint of one training step (everything
    /// saved for backward that is *method independent*): per layer the
    /// standard set ≈ 14·B·T·d + 2·B·H·T² attention maps, plus logits.
    pub fn base_activation_bytes(&self) -> usize {
        let btd = self.batch * self.seq_len * self.d_model;
        let att = self.batch * self.n_heads * self.seq_len * self.seq_len;
        let per_layer = 14 * btd + 2 * att;
        let logits = self.batch * self.seq_len * self.vocab;
        (self.n_layers * per_layer + logits + 2 * btd) * self.act_bytes
    }

    /// Method-dependent transient bytes per step — the FFT intermediates
    /// of the adapted projections. Derived from the allocation rules the
    /// Table 1 substrate measures:
    /// * fft:  promote x,c to complex (2·4B per scalar), product + accum +
    ///         inverse all complex out-of-place, plus `.real` extraction.
    /// * rfft: half-spectra (n+2 reals per n), products out-of-place.
    /// * ours: zero.
    pub fn method_transient_bytes(&self, method: Method) -> usize {
        match method {
            Method::FullFinetune => 0,
            Method::Lora { rank } => {
                // saved xAᵀ per adapted projection (fwd) at act precision
                self.n_layers
                    * self.adapted_per_layer
                    * self.batch
                    * self.seq_len
                    * rank
                    * self.act_bytes
            }
            Method::Circulant { backend, p } => {
                let blocks = self.d_model / p; // per projection, per token
                let tok = self.batch * self.seq_len;
                // spectra live in fp32 complex (torch upcasts bf16 — the
                // paper's "fft and rfft do not support bf16 arithmetic")
                let per_proj = match backend {
                    Backend::Fft => {
                        // x̂ (complex 8B·d) + ĉ (8B·d·blocks) + ŷ acc (8B·d)
                        // + product temp (8B·p) + real() copy (4B·d)
                        tok * (8 * self.d_model * 2 + 4 * self.d_model)
                            + 8 * self.d_model * blocks
                    }
                    Backend::Rfft => {
                        // half spectra: (p/2+1) complex per block ≈ (n+2)/2n
                        let half = |n: usize| (n / p) * (p / 2 + 1) * 8;
                        tok * (2 * half(self.d_model)) + half(self.d_model) * blocks
                            + tok * 4 * self.d_model
                    }
                    Backend::RdFft => 0,
                };
                self.n_layers * self.adapted_per_layer * per_proj
            }
        }
    }
}

/// One Table-2 row: the paper's five columns, in bytes.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub method: String,
    pub model_bytes: usize,
    pub trainable_bytes: usize,
    pub gradient_bytes: usize,
    pub others_bytes: usize,
}

impl Table2Row {
    pub fn total_bytes(&self) -> usize {
        self.model_bytes + self.trainable_bytes + self.gradient_bytes + self.others_bytes
    }
}

/// Compute a full Table-2 row for `method` on `arch`.
pub fn table2_row(arch: &ArchSpec, method: Method) -> Table2Row {
    let trainable = arch.trainable_params(method);
    let (trainable_bytes, gradient_bytes) = match method {
        // full fine-tuning updates the base weights in place: no separate
        // trainable tensor, but full-size gradients
        Method::FullFinetune => (0, arch.num_params() * arch.grad_bytes),
        _ => (trainable * 4, trainable * arch.grad_bytes),
    };
    Table2Row {
        method: method.label(),
        model_bytes: arch.num_params() * arch.param_bytes,
        trainable_bytes,
        gradient_bytes,
        others_bytes: arch.base_activation_bytes() + arch.method_transient_bytes(method),
    }
}

/// The small-transformer config used by the end-to-end example — kept
/// here so Rust-side tooling can reason about the model the artifacts
/// contain without re-parsing Python.
#[derive(Debug, Clone)]
pub struct SmallConfig {
    pub d_model: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub vocab: usize,
}

impl SmallConfig {
    pub fn from_manifest(m: &crate::runtime::Manifest) -> Self {
        SmallConfig { d_model: m.d_model, n_layers: m.n_layers, d_ff: 0, vocab: m.vocab }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

    #[test]
    fn llama_param_count_is_about_7b() {
        let n = ArchSpec::llama2_7b().num_params();
        assert!((6.0e9..8.0e9).contains(&(n as f64)), "{n}");
    }

    #[test]
    fn roberta_param_count_is_about_355m() {
        let n = ArchSpec::roberta_large().num_params();
        assert!((3.0e8..4.5e8).contains(&(n as f64)), "{n}");
    }

    #[test]
    fn llama_base_model_close_to_paper() {
        // paper: 12.61 GB in bf16
        let gb = ArchSpec::llama2_7b().num_params() as f64 * 2.0 / GIB;
        assert!((11.5..14.0).contains(&gb), "{gb}");
    }

    #[test]
    fn circulant_trainable_scales_inversely_with_p() {
        let arch = ArchSpec::llama2_7b();
        let m512 = arch.trainable_params(Method::Circulant { backend: Backend::RdFft, p: 512 });
        let m1024 = arch.trainable_params(Method::Circulant { backend: Backend::RdFft, p: 1024 });
        assert_eq!(m512, 2 * m1024, "halving p doubles params");
    }

    #[test]
    fn llama_gradients_twice_trainable_bytes() {
        // paper: grads fp32, trainable counted in the table as fp32 too,
        // but gradient MB == 2x trainable MB because forward runs bf16
        let arch = ArchSpec::llama2_7b();
        let row = table2_row(&arch, Method::Circulant { backend: Backend::RdFft, p: 512 });
        assert_eq!(row.gradient_bytes, row.trainable_bytes);
        // (both fp32 here; the paper's 2x is bf16-trainable vs fp32-grad —
        // our table reports fp32 trainable, see EXPERIMENTS.md note)
    }

    #[test]
    fn method_ordering_matches_paper() {
        for arch in [ArchSpec::llama2_7b(), ArchSpec::roberta_large()] {
            let p = 512;
            let fft = table2_row(&arch, Method::Circulant { backend: Backend::Fft, p });
            let rfft = table2_row(&arch, Method::Circulant { backend: Backend::Rfft, p });
            let ours = table2_row(&arch, Method::Circulant { backend: Backend::RdFft, p });
            let ff = table2_row(&arch, Method::FullFinetune);
            assert!(fft.total_bytes() > rfft.total_bytes(), "{}", arch.name);
            assert!(rfft.total_bytes() > ours.total_bytes(), "{}", arch.name);
            assert!(ff.total_bytes() > ours.total_bytes(), "{}", arch.name);
        }
    }

    #[test]
    fn ours_beats_lora_at_full_model_scale() {
        let arch = ArchSpec::llama2_7b();
        let lora = table2_row(&arch, Method::Lora { rank: 32 });
        let ours = table2_row(&arch, Method::Circulant { backend: Backend::RdFft, p: 512 });
        assert!(ours.total_bytes() < lora.total_bytes());
    }
}
