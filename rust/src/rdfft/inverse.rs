//! In-place inverse rdFFT (§4.2 of the paper).
//!
//! The inverse runs the forward butterfly graph **in reverse** (Eq. 7):
//! every stage map is linear and invertible, so we undo stages from
//! `m = n/2` down to `m = 1` and finish with the (involutive) bit-reversal
//! permutation. Each undone butterfly carries a factor of ½ exactly where
//! the forward butterfly summed two values, so the composition accumulates
//! exactly the 1/N normalization of the IFFT — no separate scaling pass.
//!
//! Like the forward path this touches only the symmetric 4-element groups,
//! performs zero allocations, and leaves the result in the original real
//! buffer.

use super::plan::Plan;

/// Transform `buf` (length `plan.n()`) from the packed spectrum back to the
/// real signal, in place. Exact inverse of [`super::rdfft_inplace`]
/// (including normalization).
// audit: no_alloc
pub fn irdfft_inplace(plan: &Plan, buf: &mut [f32]) {
    assert_eq!(buf.len(), plan.n(), "buffer length must equal plan size");
    inverse_stages(plan, buf);
    plan.bit_reverse(buf);
}

/// Batched variant of [`irdfft_inplace`] over contiguous rows, routed
/// through the batch-major [`super::engine`] and its runtime-dispatched
/// SIMD lane kernels; bit-identical to the per-row scalar path on the
/// forced-scalar and portable arms, within the n-scaled tolerance on the
/// AVX2+FMA arm. Sizes at or above `EngineConfig::fourstep_threshold`
/// take the four-step (Bailey) large-n tier ([`super::fourstep`]).
pub fn irdfft_batch(plan: &Plan, buf: &mut [f32]) {
    super::engine::inverse_batch(plan, buf);
}

/// The pre-engine serial row loop (equivalence/ablation reference; the
/// bitwise oracle for `EngineConfig::force_scalar`).
pub fn irdfft_batch_scalar(plan: &Plan, buf: &mut [f32]) {
    let n = plan.n();
    assert!(buf.len() % n == 0, "buffer length must be a multiple of plan size");
    for row in buf.chunks_exact_mut(n) {
        irdfft_inplace(plan, row);
    }
}

/// All inverse butterfly stages (output still bit-reversed). Exposed for
/// the ablation bench.
// audit: no_alloc
#[inline]
pub fn inverse_stages(plan: &Plan, buf: &mut [f32]) {
    let n = plan.n();
    let mut m = n / 2;
    while m >= 1 {
        let tw = plan.stage_inv_twiddles(m);
        let two_m = 2 * m;
        let mut s = 0usize;
        while s < n {
            // k = 0 lane: forward was (e,o) -> (e+o, e-o).
            let a = buf[s];
            let b = buf[s + m];
            buf[s] = 0.5 * (a + b);
            buf[s + m] = 0.5 * (a - b);
            if m >= 2 {
                // k = m/2 lane: forward flipped the sign of the Im slot.
                let idx = s + m + m / 2;
                buf[idx] = -buf[idx];
            }
            // 1 <= k < m/2: undo the 4-group butterfly.
            //
            // SAFETY: same in-block bounds argument as the forward stage
            // (see forward.rs); unchecked access shaves the bounds-check
            // cost recorded in EXPERIMENTS.md §Perf.
            unsafe {
                let blk = buf.get_unchecked_mut(s..s + two_m);
                // hr/hi are the pre-halved twiddles (wr/2, wi/2), so
                // O = T·conj(W)/2 comes out directly from (a−b), (c+d).
                for (k, &(hr, hi)) in (1..m / 2).zip(tw.iter()) {
                    let a = *blk.get_unchecked(k); //          er + tr
                    let b = *blk.get_unchecked(m - k); //      er - tr
                    let c = *blk.get_unchecked(two_m - k); //  ei + ti
                    let d = *blk.get_unchecked(m + k); //      ti - ei
                    let er = 0.5 * (a + b);
                    let ei = 0.5 * (c - d);
                    let or_ = (a - b) * hr + (c + d) * hi;
                    let oi = (c + d) * hr - (a - b) * hi;
                    *blk.get_unchecked_mut(k) = er;
                    *blk.get_unchecked_mut(m - k) = ei;
                    *blk.get_unchecked_mut(m + k) = or_;
                    *blk.get_unchecked_mut(two_m - k) = oi;
                }
            }
            s += two_m;
        }
        m /= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::super::forward::rdfft_inplace;
    use super::*;

    #[test]
    fn two_point_inverse() {
        let plan = Plan::new(2);
        let mut buf = [8.0f32, -2.0];
        irdfft_inplace(&plan, &mut buf);
        assert_eq!(buf, [3.0, 5.0]);
    }

    #[test]
    fn inverse_of_flat_spectrum_is_impulse() {
        let n = 32;
        let plan = Plan::new(n);
        // packed all-ones spectrum == FFT(delta)
        let mut buf = vec![0.0f32; n];
        for k in 0..=n / 2 {
            buf[k] = 1.0;
        }
        irdfft_inplace(&plan, &mut buf);
        assert!((buf[0] - 1.0).abs() < 1e-5);
        for i in 1..n {
            assert!(buf[i].abs() < 1e-5, "i={i}");
        }
    }

    #[test]
    fn normalization_is_exactly_one_over_n() {
        // IFFT(FFT(x)) == x implies the DC path is divided by n overall:
        // spectrum = [n, 0, ..] must invert to all-ones.
        let n = 64;
        let plan = Plan::new(n);
        let mut buf = vec![0.0f32; n];
        buf[0] = n as f32;
        irdfft_inplace(&plan, &mut buf);
        for i in 0..n {
            assert!((buf[i] - 1.0).abs() < 1e-5, "i={i} -> {}", buf[i]);
        }
    }

    #[test]
    fn inverse_then_forward_is_identity_too() {
        // forward∘inverse = id (the other composition order from mod.rs).
        let n = 512;
        let plan = Plan::new(n);
        let orig: Vec<f32> = (0..n).map(|i| ((i * 37 + 11) % 101) as f32 / 50.0 - 1.0).collect();
        let mut buf = orig.clone();
        irdfft_inplace(&plan, &mut buf);
        rdfft_inplace(&plan, &mut buf);
        for i in 0..n {
            assert!((buf[i] - orig[i]).abs() < 1e-3, "i={i}");
        }
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let plan = Plan::new(8);
        let mut buf = [0.0f32; 16];
        irdfft_inplace(&plan, &mut buf);
    }
}
