//! Transform plans: twiddle factors and the bit-reversal schedule.
//!
//! A [`Plan`] is created once per transform size and shared by every
//! forward/inverse call (the paper's CUDA implementation likewise bakes
//! twiddles into constant memory). Plans are *read-only* at transform time,
//! so the transform itself stays allocation-free — the property Table 1
//! measures.

// BTreeMap, not HashMap: the cache lives in a determinism-scoped module
// and ordered iteration keeps anything that ever walks it (debug dumps,
// future eviction) reproducible. Lookup keys are a handful of
// power-of-two sizes, so the O(log k) vs O(1) difference is noise.
use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

/// Smallest `n` for which a plan *can* carry four-step (Bailey)
/// factorization tables ([`Plan::fourstep_lazy`] materializes them on
/// the first four-step dispatch; plans that only ever run the direct
/// tier never pay for them). Deliberately far below the engine's default
/// dispatch threshold (`EngineConfig::fourstep_threshold`, ~16k) so
/// tests can exercise the four-step path at cheap sizes by lowering the
/// config knob; the tables for a 1k plan cost ~n·8 bytes — noise next to
/// the plan's existing O(n) arrays.
pub const FOURSTEP_MIN_N: usize = 1024;

/// Four-step (Bailey) factorization tables for an `n = n1 × n2` plan
/// (`n2 ≥ n1`, both powers of two).
///
/// The direct engine runs stages `m = 1 .. n/2` over the whole row; the
/// four-step split runs stages `m ≤ n2/2` chunk-locally as `n1`
/// independent `n2`-point sub-transforms (sharing one cached `n2` plan,
/// bit-for-bit the same arithmetic), then the `log2(n1)` *late* stages
/// `m = n2·2^t` through gathered column tiles. A late-stage twiddle
/// factorizes exactly over the `(q, r)` digit split of `k = q·n2 + r`:
///
/// `W_{2m}^{q·n2+r} = A_t[q] · B_t[r]`,
/// `A_t[q] = (cos πq/M, −sin πq/M)`, `B_t[r] = (cos πr/(M·n2), −sin …)`,
/// `M = 2^t` — so the per-stage table is O(M/2 + n2) instead of O(m/2),
/// and the whole late-stage table set is O(n1 + n2·log2 n1) instead of
/// the O(n) a direct plan would need. The one numeric delta vs the
/// direct path: the complex product rounds once more (~1 ulp), applied
/// identically regardless of thread count.
#[derive(Debug, Clone)]
pub struct FourStep {
    n1: usize,
    n2: usize,
    /// Shared `n2`-point sub-plan for the chunk-local early stages.
    sub: Arc<Plan>,
    /// Outer factors `A_t[q]`, stage-major; stage `t` holds
    /// `q = 0 .. (M/2).max(1)` at `outer_off[t]`.
    outer: Vec<(f32, f32)>,
    outer_off: Vec<usize>,
    /// Inner factors `B_t[r]`, `r = 0 .. n2`, stage `t` at offset `t·n2`.
    /// The full `r` range (not just `r < n2/2`) keeps the mirror column
    /// family (`k = q·n2 + (n2 − r)`) table-driven with no conjugation
    /// special case in the kernel.
    inner: Vec<(f32, f32)>,
    /// Pre-halved inner factors `(cos/2, −sin/2)` (computed in f64, then
    /// rounded once) for the inverse butterfly: `(A·B)/2 = A·(B/2)`, so
    /// halving the inner factor alone yields the pre-halved product the
    /// inverse kernels need — same trick as `inv_twiddles`.
    inner_inv: Vec<(f32, f32)>,
}

impl FourStep {
    fn new(n: usize, log2n: u32) -> Self {
        let shift = ((log2n + 1) / 2) as usize;
        let n2 = 1usize << shift;
        let n1 = n >> shift;
        debug_assert!(n1 >= 2 && n2 >= n1 && n1 * n2 == n);
        let stages = n1.trailing_zeros() as usize;
        let mut outer = Vec::new();
        let mut outer_off = Vec::with_capacity(stages);
        let mut inner = Vec::with_capacity(stages * n2);
        let mut inner_inv = Vec::with_capacity(stages * n2);
        for t in 0..stages {
            let m_cap = 1usize << t; // M = 2^t
            outer_off.push(outer.len());
            for q in 0..(m_cap / 2).max(1) {
                let theta = std::f64::consts::PI * q as f64 / m_cap as f64;
                outer.push((theta.cos() as f32, (-theta.sin()) as f32));
            }
            for r in 0..n2 {
                let theta = std::f64::consts::PI * r as f64 / (m_cap * n2) as f64;
                inner.push((theta.cos() as f32, (-theta.sin()) as f32));
                inner_inv.push(((0.5 * theta.cos()) as f32, (-0.5 * theta.sin()) as f32));
            }
        }
        FourStep { n1, n2, sub: cached(n2), outer, outer_off, inner, inner_inv }
    }

    /// Number of rows in the `n1 × n2` view (= column length).
    #[inline]
    pub fn n1(&self) -> usize {
        self.n1
    }

    /// Number of columns (= chunk length of the early sub-transforms).
    #[inline]
    pub fn n2(&self) -> usize {
        self.n2
    }

    /// The shared `n2`-point plan the chunk-local early stages run on.
    #[inline]
    pub fn sub(&self) -> &Plan {
        &self.sub
    }

    /// Number of late stages (= `log2 n1`).
    #[inline]
    pub fn stages(&self) -> usize {
        self.outer_off.len()
    }

    /// Outer factors `A_t[q]` for late stage `t` (`q = 0 .. (M/2).max(1)`).
    #[inline]
    pub fn stage_outer(&self, t: usize) -> &[(f32, f32)] {
        let start = self.outer_off[t];
        let end = self.outer_off.get(t + 1).copied().unwrap_or(self.outer.len());
        &self.outer[start..end]
    }

    /// Inner factors `B_t[r]` for late stage `t` (`r = 0 .. n2`).
    #[inline]
    pub fn stage_inner(&self, t: usize) -> &[(f32, f32)] {
        &self.inner[t * self.n2..(t + 1) * self.n2]
    }

    /// Pre-halved inner factors for the inverse late stage `t`.
    #[inline]
    pub fn stage_inner_inv(&self, t: usize) -> &[(f32, f32)] {
        &self.inner_inv[t * self.n2..(t + 1) * self.n2]
    }

    /// Heap bytes of the factorization tables, including the shared
    /// `n2` sub-plan (an `Arc` — plans for the same `n2` share one copy
    /// process-wide, so summing over many large plans over-counts it).
    pub fn heap_bytes(&self) -> usize {
        (self.outer.len() + self.inner.len() + self.inner_inv.len()) * 8
            + self.outer_off.len() * 8
            + self.sub.heap_bytes()
    }
}

/// Precomputed data for an `n`-point rdFFT (`n` a power of two ≥ 2).
#[derive(Debug, Clone)]
pub struct Plan {
    n: usize,
    log2n: u32,
    /// Swap pairs `(i, j)` with `i < j` realizing the bit-reversal
    /// permutation in-place. Involutive: applying twice is the identity.
    swaps: Vec<(u32, u32)>,
    /// Twiddles for every stage, flattened. Stage with half-block `m`
    /// (combining two packed `m`-blocks into one `2m`-block) uses entries
    /// `k = 1 .. m/2-1`: `W_{2m}^k = (cos θ, -sin θ)`, `θ = 2πk / (2m)`.
    /// `stage_off[s]` is the base index for stage `s` (where `m = 2^{s}`).
    twiddles: Vec<(f32, f32)>,
    /// Inverse-stage *half*-twiddles `(wr/2, wi/2)`, same layout: the
    /// inverse butterfly needs `((a−b)·wr + (c+d)·wi) / 2` per output, so
    /// pre-halving the twiddle removes two multiplies per 4-group
    /// (EXPERIMENTS.md §Perf iteration 2).
    inv_twiddles: Vec<(f32, f32)>,
    stage_off: Vec<usize>,
    /// Full bit-reversal table: `rev[i]` is the bit-reverse of `i`. The
    /// batch engine's fused permutation pass needs per-index targets (the
    /// pairwise `swaps` list cannot be interleaved with butterflies).
    rev: Vec<u32>,
    /// SoA twiddles, stage-major in **lane-padded** order: real and
    /// imaginary parts in separate slices (stride-1 for the innermost
    /// engine loops), each stage's run indexed `k − 1` and zero-padded to
    /// a multiple of [`super::simd::LANES`] so every stage starts at a
    /// lane-aligned offset (`lane_off`) and the SIMD quad loops sweep
    /// exact width-4 chunks of one contiguous stream. The scalar SoA
    /// accessors return pad-free subslices of the same storage — one
    /// copy serves both the legacy and the lane kernels.
    lane_wr: Vec<f32>,
    lane_wi: Vec<f32>,
    /// Lane-padded pre-halved inverse twiddles (`wr/2`, `wi/2`), same
    /// layout.
    lane_inv_wr: Vec<f32>,
    lane_inv_wi: Vec<f32>,
    /// Per-stage base offsets into the `lane_*` arrays (stage `s` has
    /// half-block `m = 2^s`); every entry is a multiple of the lane width.
    lane_off: Vec<usize>,
    /// Four-step factorization tables, materialized **lazily** on the
    /// first four-step dispatch (via [`Self::fourstep_lazy`]) and only
    /// for `n ≥ FOURSTEP_MIN_N`. Eager construction used to charge every
    /// cached plan at `n ∈ [1 Ki, 16 Ki)` permanent `heap_bytes` for
    /// tables the default dispatch threshold never runs — a real cost in
    /// a memory-efficiency repro. `OnceLock` keeps materialization
    /// race-free across pool workers and `heap_bytes` accurate on both
    /// sides of the transition.
    fourstep: OnceLock<FourStep>,
}

impl Plan {
    /// Build a plan for transform size `n`. Panics unless `n` is a power of
    /// two ≥ 2.
    pub fn new(n: usize) -> Self {
        assert!(super::is_supported_size(n), "rdFFT size must be a power of two >= 2, got {n}");
        let log2n = n.trailing_zeros();

        // Bit-reversal swap list + full per-index table (engine).
        let mut swaps = Vec::with_capacity(n / 2);
        let mut rev = Vec::with_capacity(n);
        for i in 0..n {
            let j = (i as u32).reverse_bits() >> (32 - log2n);
            rev.push(j);
            if (i as u32) < j {
                swaps.push((i as u32, j));
            }
        }

        // Twiddles per stage: stage s has m = 2^s, k = 1..m/2-1. Stored
        // both AoS (scalar path) and SoA (batch engine).
        let mut twiddles = Vec::new();
        let mut inv_twiddles = Vec::new();
        let mut stage_off = Vec::with_capacity(log2n as usize);
        let lanes = super::simd::LANES;
        let (mut lane_wr, mut lane_wi) = (Vec::new(), Vec::new());
        let (mut lane_inv_wr, mut lane_inv_wi) = (Vec::new(), Vec::new());
        let mut lane_off = Vec::with_capacity(log2n as usize);
        for s in 0..log2n {
            let m = 1usize << s;
            stage_off.push(twiddles.len());
            lane_off.push(lane_wr.len());
            for k in 1..m / 2 {
                let theta = std::f64::consts::TAU * k as f64 / (2 * m) as f64;
                let (wr, wi) = (theta.cos() as f32, (-theta.sin()) as f32);
                twiddles.push((wr, wi));
                inv_twiddles.push((0.5 * wr, 0.5 * wi));
                lane_wr.push(wr);
                lane_wi.push(wi);
                lane_inv_wr.push(0.5 * wr);
                lane_inv_wi.push(0.5 * wi);
            }
            // Zero-pad the stage run to a whole number of lanes; the quad
            // kernels never *use* pad entries (tails run scalar), the pad
            // only keeps every stage's base lane-aligned.
            while lane_wr.len() % lanes != 0 {
                lane_wr.push(0.0);
                lane_wi.push(0.0);
                lane_inv_wr.push(0.0);
                lane_inv_wi.push(0.0);
            }
        }

        Plan {
            n,
            log2n,
            swaps,
            twiddles,
            inv_twiddles,
            stage_off,
            rev,
            lane_wr,
            lane_wi,
            lane_inv_wr,
            lane_inv_wi,
            lane_off,
            fourstep: OnceLock::new(),
        }
    }

    /// Four-step factorization tables — `Some` only once they have been
    /// materialized by a four-step dispatch ([`Self::fourstep_lazy`]).
    /// Observational: never triggers construction, so `heap_bytes`
    /// callers and tests can probe the current state without paying
    /// for tables.
    #[inline]
    pub fn fourstep(&self) -> Option<&FourStep> {
        self.fourstep.get()
    }

    /// Four-step factorization tables, materializing them on first use —
    /// `Some` for `n ≥ FOURSTEP_MIN_N`, `None` below (the caller must
    /// fall back to the direct sweep). Concurrent first dispatches race
    /// benignly: `OnceLock` keeps exactly one table set and the losers'
    /// work is dropped before publication.
    #[inline]
    pub fn fourstep_lazy(&self) -> Option<&FourStep> {
        if self.n >= FOURSTEP_MIN_N {
            Some(self.fourstep.get_or_init(|| FourStep::new(self.n, self.log2n)))
        } else {
            None
        }
    }

    /// Transform size.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// log2 of the transform size (= number of butterfly stages).
    #[inline]
    pub fn log2n(&self) -> u32 {
        self.log2n
    }

    /// Bit-reversal swap pairs.
    #[inline]
    pub fn swaps(&self) -> &[(u32, u32)] {
        &self.swaps
    }

    /// Full bit-reversal table (`rev[i]` = bit-reverse of `i`).
    #[inline]
    pub fn rev(&self) -> &[u32] {
        &self.rev
    }

    /// SoA forward twiddles `(wr, wi)` for the stage with half-block `m`
    /// (entries for `k = 1 .. m/2-1`, like [`Self::stage_twiddles`]) — a
    /// pad-free view into the lane-padded storage.
    #[inline]
    pub fn stage_twiddles_soa(&self, m: usize) -> (&[f32], &[f32]) {
        let s = m.trailing_zeros() as usize;
        let start = self.lane_off[s];
        let len = (m / 2).saturating_sub(1);
        (&self.lane_wr[start..start + len], &self.lane_wi[start..start + len])
    }

    /// SoA pre-halved inverse twiddles `(wr/2, wi/2)` for the stage with
    /// half-block `m` (pad-free view into the lane-padded storage).
    #[inline]
    pub fn stage_inv_twiddles_soa(&self, m: usize) -> (&[f32], &[f32]) {
        let s = m.trailing_zeros() as usize;
        let start = self.lane_off[s];
        let len = (m / 2).saturating_sub(1);
        (&self.lane_inv_wr[start..start + len], &self.lane_inv_wi[start..start + len])
    }

    /// Lane-padded SoA forward twiddles for the stage with half-block `m`:
    /// entries for `k = 1 .. m/2-1` at index `k − 1` (identical values to
    /// [`Self::stage_twiddles_soa`]), zero-padded to a multiple of the
    /// lane width. The SIMD quad kernels read full width-4 chunks of
    /// these; the pad entries are never consumed (tails run scalar).
    #[inline]
    pub fn stage_lane_twiddles(&self, m: usize) -> (&[f32], &[f32]) {
        let s = m.trailing_zeros() as usize;
        let start = self.lane_off[s];
        let end = self.lane_off.get(s + 1).copied().unwrap_or(self.lane_wr.len());
        (&self.lane_wr[start..end], &self.lane_wi[start..end])
    }

    /// Lane-padded SoA pre-halved inverse twiddles (`wr/2`, `wi/2`) for
    /// the stage with half-block `m` (layout of
    /// [`Self::stage_lane_twiddles`]).
    #[inline]
    pub fn stage_lane_inv_twiddles(&self, m: usize) -> (&[f32], &[f32]) {
        let s = m.trailing_zeros() as usize;
        let start = self.lane_off[s];
        let end = self.lane_off.get(s + 1).copied().unwrap_or(self.lane_inv_wr.len());
        (&self.lane_inv_wr[start..end], &self.lane_inv_wi[start..end])
    }

    /// Twiddle slice for the stage with half-block `m` (entries for
    /// `k = 1 .. m/2-1`, so the slice is empty for `m < 4`).
    #[inline]
    pub fn stage_twiddles(&self, m: usize) -> &[(f32, f32)] {
        let s = m.trailing_zeros() as usize;
        let start = self.stage_off[s];
        let len = (m / 2).saturating_sub(1);
        &self.twiddles[start..start + len]
    }

    /// Half-twiddles `(wr/2, wi/2)` for the inverse stage with half-block
    /// `m` (same indexing as [`Self::stage_twiddles`]).
    #[inline]
    pub fn stage_inv_twiddles(&self, m: usize) -> &[(f32, f32)] {
        let s = m.trailing_zeros() as usize;
        let start = self.stage_off[s];
        let len = (m / 2).saturating_sub(1);
        &self.inv_twiddles[start..start + len]
    }

    /// Apply the bit-reversal permutation to `buf` in place.
    /// Involutive — used by both the forward (before stages) and the
    /// inverse (after stages).
    #[inline]
    pub fn bit_reverse(&self, buf: &mut [f32]) {
        debug_assert_eq!(buf.len(), self.n);
        for &(i, j) in &self.swaps {
            buf.swap(i as usize, j as usize);
        }
    }

    /// Heap bytes consumed by this plan (reported in DESIGN.md's VMEM /
    /// constant-memory estimates; not counted against transform memory).
    /// Includes the four-step factorization tables and their shared `n2`
    /// sub-plan once a four-step dispatch has materialized them (zero
    /// before that — lazy tables must not inflate warm plans that only
    /// ever run the direct tier). The four-step *transpose tiles* are not
    /// here — they are per-worker thread-local scratch
    /// (`fourstep::tile_floats(n1)` f32s per pool thread, grown once on
    /// first large-n use and reused ever after), accounted by the
    /// memtrack zero-alloc invariant test instead.
    pub fn heap_bytes(&self) -> usize {
        self.swaps.len() * 8
            + self.twiddles.len() * 8
            + self.inv_twiddles.len() * 8
            + self.stage_off.len() * 8
            + self.rev.len() * 4
            + (self.lane_wr.len()
                + self.lane_wi.len()
                + self.lane_inv_wr.len()
                + self.lane_inv_wi.len())
                * 4
            + self.lane_off.len() * 8
            + self.fourstep.get().map_or(0, FourStep::heap_bytes)
    }
}

/// Process-wide plan cache. Layers at many sizes share plans; building a
/// plan is O(n log n) and done once. Read-mostly after warmup, so lookups
/// take a shared `RwLock` read guard — concurrent batch-engine workers do
/// not serialize on the cache the way the previous `Mutex` made them.
///
/// Lock poisoning is recovered, not propagated: a bench/test thread that
/// panics while touching the cache must not fail every later transform in
/// the process (`unwrap()` on a poisoned guard would). The map holds only
/// fully-built `Arc<Plan>`s, and `Plan::new` runs *outside* any lock —
/// both the size check and the O(n log n) construction happen before the
/// write guard is taken, so nothing fallible runs mid-insert **and**
/// concurrent first-time builders (the pool jobs of [`warm_cache`], cold
/// starts racing on different sizes) construct in parallel instead of
/// serializing on the write lock. Two threads racing on the *same* new
/// size each build a plan; the loser's copy is dropped and the cache
/// keeps exactly one canonical `Arc`.
pub fn cached(n: usize) -> Arc<Plan> {
    assert!(
        super::is_supported_size(n),
        "rdFFT size must be a power of two >= 2, got {n}"
    );
    static CACHE: OnceLock<RwLock<BTreeMap<usize, Arc<Plan>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| RwLock::new(BTreeMap::new()));
    if let Some(plan) = cache.read().unwrap_or_else(|e| e.into_inner()).get(&n) {
        return plan.clone();
    }
    let built = Arc::new(Plan::new(n));
    let mut map = cache.write().unwrap_or_else(|e| e.into_inner());
    map.entry(n).or_insert(built).clone()
}

/// Pre-build plans for `sizes` as parallel jobs on `ctx`'s worker pool —
/// startup warmup so a model's first training step never pays the
/// O(n log n) plan constructions inside the hot loop (a depth-K stack at
/// mixed block sizes touches several). Sizes are validated up front on
/// the calling thread (a bad size panics here, not inside a worker);
/// already-cached sizes are cheap cache hits, and two jobs racing on the
/// same new size resolve benignly (`cached` keeps exactly one plan).
pub fn warm_cache(sizes: &[usize], ctx: &crate::runtime::pool::ExecCtx) {
    for &n in sizes {
        assert!(
            super::is_supported_size(n),
            "rdFFT size must be a power of two >= 2, got {n}"
        );
    }
    ctx.pool()
        .scope(|sc| {
            for &n in sizes {
                sc.submit(move || {
                    let _ = cached(n);
                });
            }
        })
        .unwrap_or_else(|p| p.resume());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_reverse_is_involutive() {
        let plan = Plan::new(16);
        let orig: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut buf = orig.clone();
        plan.bit_reverse(&mut buf);
        assert_ne!(buf, orig);
        plan.bit_reverse(&mut buf);
        assert_eq!(buf, orig);
    }

    #[test]
    fn bit_reverse_permutation_is_correct() {
        let plan = Plan::new(8);
        let mut buf: Vec<f32> = (0..8).map(|i| i as f32).collect();
        plan.bit_reverse(&mut buf);
        assert_eq!(buf, vec![0.0, 4.0, 2.0, 6.0, 1.0, 5.0, 3.0, 7.0]);
    }

    #[test]
    fn twiddle_counts_per_stage() {
        let plan = Plan::new(16);
        assert_eq!(plan.stage_twiddles(1).len(), 0);
        assert_eq!(plan.stage_twiddles(2).len(), 0);
        assert_eq!(plan.stage_twiddles(4).len(), 1);
        assert_eq!(plan.stage_twiddles(8).len(), 3);
    }

    #[test]
    fn twiddle_values_are_unit_magnitude() {
        let plan = Plan::new(64);
        for m in [4usize, 8, 16, 32] {
            for &(wr, wi) in plan.stage_twiddles(m) {
                let mag = (wr * wr + wi * wi).sqrt();
                assert!((mag - 1.0).abs() < 1e-6);
                assert!(wi <= 0.0, "forward twiddles have non-positive imaginary part");
            }
        }
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        Plan::new(24);
    }

    #[test]
    fn cache_returns_shared_plan() {
        let a = cached(32);
        let b = cached(32);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn cached_rejects_bad_sizes_before_locking() {
        // The panic must fire in the caller (argument validation), never
        // while a cache guard is held — see the poisoning regression
        // below.
        // audit: allow(no-raw-threads) test needs a raw thread to catch a cross-thread panic; no compute dispatch involved
        let joined = std::thread::spawn(|| cached(24)).join();
        assert!(joined.is_err(), "non-power-of-two must panic");
    }

    #[test]
    fn cache_survives_a_panicking_thread() {
        // Regression: one panicking thread (here via the size validation,
        // historically via any panic while a guard was held) must not
        // poison the cache for every later transform.
        // audit: allow(no-raw-threads) test needs a raw thread to catch a cross-thread panic; no compute dispatch involved
        let joined = std::thread::spawn(|| {
            let _ = cached(96); // 96 is not a power of two -> panic
        })
        .join();
        assert!(joined.is_err());
        // Later lookups — including first-time builds — must still work.
        assert_eq!(cached(64).n(), 64);
        assert_eq!(cached(2048).n(), 2048);
    }

    #[test]
    fn cache_is_safe_under_concurrent_lookup() {
        let handles: Vec<_> = (0..8)
            // audit: allow(no-raw-threads) test exercises the cache's cross-thread contract itself, not pooled compute
            .map(|t| std::thread::spawn(move || cached(64 << (t % 3)).n()))
            .collect();
        for h in handles {
            let n = h.join().unwrap();
            assert!(n == 64 || n == 128 || n == 256);
        }
        assert!(Arc::ptr_eq(&cached(64), &cached(64)));
    }

    #[test]
    fn warm_cache_builds_shared_plans_via_the_pool() {
        let ctx = crate::runtime::pool::ExecCtx::with_threads(3);
        warm_cache(&[512, 1024, 512], &ctx);
        assert_eq!(cached(512).n(), 512);
        assert!(Arc::ptr_eq(&cached(1024), &cached(1024)));
    }

    #[test]
    fn soa_twiddles_match_aos() {
        let plan = Plan::new(128);
        for m in [4usize, 8, 16, 32, 64] {
            let aos = plan.stage_twiddles(m);
            let (wr, wi) = plan.stage_twiddles_soa(m);
            let inv = plan.stage_inv_twiddles(m);
            let (hr, hi) = plan.stage_inv_twiddles_soa(m);
            assert_eq!(aos.len(), wr.len());
            for k in 0..aos.len() {
                assert_eq!(aos[k], (wr[k], wi[k]), "m={m} k={k}");
                assert_eq!(inv[k], (hr[k], hi[k]), "m={m} k={k} inv");
            }
        }
    }

    #[test]
    fn rev_table_matches_swap_list() {
        let plan = Plan::new(64);
        let rev = plan.rev();
        assert_eq!(rev.len(), 64);
        for i in 0..64u32 {
            assert_eq!(rev[rev[i as usize] as usize], i, "involution at {i}");
        }
        for &(i, j) in plan.swaps() {
            assert_eq!(rev[i as usize], j);
        }
    }

    #[test]
    fn heap_bytes_counts_soa_twiddle_arrays() {
        let plan = Plan::new(16);
        let tw: usize = [1usize, 2, 4, 8].iter().map(|&m| (m / 2).saturating_sub(1)).sum();
        // Lane arrays pad each stage's run (0, 0, 1, 3 entries) up to a
        // multiple of the lane width: 0 + 0 + 4 + 4 entries.
        let lanes = crate::rdfft::simd::LANES;
        let lane_tw: usize = [1usize, 2, 4, 8]
            .iter()
            .map(|&m| {
                let v = (m / 2).saturating_sub(1);
                (v + lanes - 1) / lanes * lanes
            })
            .sum();
        let expected = plan.swaps().len() * 8     // swap pairs
            + tw * 8 * 2                          // AoS fwd + inv twiddles
            + 4 * 8                               // stage_off
            + 16 * 4                              // rev table
            + lane_tw * 4 * 4                     // lane-padded SoA quads
            + 4 * 8; // lane_off
        assert_eq!(plan.heap_bytes(), expected);
    }

    #[test]
    fn fourstep_tables_built_exactly_from_min_n() {
        // Below the minimum even a forced materialization yields nothing.
        assert!(Plan::new(512).fourstep_lazy().is_none());
        assert!(Plan::new(512).fourstep().is_none());
        let plan = Plan::new(FOURSTEP_MIN_N);
        // Lazy contract: construction alone carries no tables...
        assert!(plan.fourstep().is_none(), "plans must not build tables eagerly");
        // ...the first four-step dispatch materializes them...
        let fs = plan.fourstep_lazy().expect("1024 can carry fourstep tables");
        assert_eq!(fs.n1() * fs.n2(), 1024);
        assert!(fs.n2() >= fs.n1());
        assert_eq!(fs.sub().n(), fs.n2());
        assert_eq!(fs.stages(), fs.n1().trailing_zeros() as usize);
        // ...and afterwards the observational accessor sees them too.
        assert!(plan.fourstep().is_some());
        assert!(plan.heap_bytes() > Plan::new(512).heap_bytes());
    }

    #[test]
    fn warm_plan_carries_no_fourstep_bytes_until_dispatch() {
        // Regression (memory contract): a warm n=4096 plan — above
        // FOURSTEP_MIN_N, below the default 16 Ki dispatch threshold —
        // must carry zero four-step bytes after arbitrary direct-tier
        // use, and materialization must grow heap_bytes by exactly the
        // table cost. Built privately (not via `cached`) so concurrent
        // tests lowering the threshold on the shared cache cannot
        // materialize the tables behind our back.
        let plan = Plan::new(4096);
        let lean = plan.heap_bytes();
        // Warm the plan on the direct tier (default config: 4096 < 16 Ki).
        let mut buf = vec![0.25f32; 2 * 4096];
        crate::rdfft::engine::forward_batch(&plan, &mut buf);
        crate::rdfft::engine::inverse_batch(&plan, &mut buf);
        assert!(plan.fourstep().is_none(), "direct-tier use must not materialize tables");
        assert_eq!(plan.heap_bytes(), lean, "warm plan gained four-step bytes");
        // Transforms on a warm plan stay allocation-free — the lazy
        // tables must not smuggle a per-call cost into the hot path.
        crate::memtrack::reset_peak();
        let before = crate::memtrack::snapshot().alloc_count;
        crate::rdfft::engine::forward_batch(&plan, &mut buf);
        crate::rdfft::engine::inverse_batch(&plan, &mut buf);
        assert_eq!(crate::memtrack::snapshot().alloc_count, before);
        // First four-step dispatch pays exactly the table cost, once.
        let fs_bytes = plan.fourstep_lazy().expect("4096 >= FOURSTEP_MIN_N").heap_bytes();
        assert!(fs_bytes > 0);
        assert_eq!(plan.heap_bytes(), lean + fs_bytes);
        // Re-dispatch is a no-op on the accounting.
        let _ = plan.fourstep_lazy();
        assert_eq!(plan.heap_bytes(), lean + fs_bytes);
    }

    #[test]
    fn fourstep_factorized_twiddles_match_direct_angles() {
        // A_t[q]·B_t[r] must reproduce W_{2m}^{q·n2+r} for m = n2·2^t to
        // within the one extra f32 product rounding.
        let plan = Plan::new(2048);
        let fs = plan.fourstep_lazy().unwrap();
        let (n1, n2) = (fs.n1(), fs.n2());
        assert_eq!((n1, n2), (32, 64));
        for t in 0..fs.stages() {
            let m_cap = 1usize << t;
            let m = n2 * m_cap;
            let outer = fs.stage_outer(t);
            let inner = fs.stage_inner(t);
            assert_eq!(outer.len(), (m_cap / 2).max(1));
            assert_eq!(inner.len(), n2);
            for q in 0..outer.len() {
                for r in 0..n2 {
                    let (ar, ai) = outer[q];
                    let (br, bi) = inner[r];
                    let wr = ar * br - ai * bi;
                    let wi = ar * bi + ai * br;
                    let theta =
                        std::f64::consts::TAU * (q * n2 + r) as f64 / (2 * m) as f64;
                    assert!(
                        (wr as f64 - theta.cos()).abs() < 3e-7
                            && (wi as f64 + theta.sin()).abs() < 3e-7,
                        "t={t} q={q} r={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn fourstep_inner_inv_is_prehalved_inner() {
        let plan = Plan::new(FOURSTEP_MIN_N);
        let fs = plan.fourstep_lazy().unwrap();
        for t in 0..fs.stages() {
            let inner = fs.stage_inner(t);
            let inv = fs.stage_inner_inv(t);
            for r in 0..inner.len() {
                assert!(
                    (inv[r].0 - 0.5 * inner[r].0).abs() <= 1e-7
                        && (inv[r].1 - 0.5 * inner[r].1).abs() <= 1e-7,
                    "t={t} r={r}"
                );
            }
        }
    }

    #[test]
    fn lane_twiddles_match_soa_twiddles_with_zero_pad() {
        let plan = Plan::new(256);
        let lanes = crate::rdfft::simd::LANES;
        for m in [4usize, 8, 16, 32, 64, 128] {
            let (wr, wi) = plan.stage_twiddles_soa(m);
            let (lwr, lwi) = plan.stage_lane_twiddles(m);
            let (hr, hi) = plan.stage_inv_twiddles_soa(m);
            let (lhr, lhi) = plan.stage_lane_inv_twiddles(m);
            assert_eq!(lwr.len() % lanes, 0, "m={m} lane pad");
            assert!(lwr.len() >= wr.len() && lwr.len() < wr.len() + lanes, "m={m}");
            for k in 0..wr.len() {
                assert_eq!((lwr[k], lwi[k]), (wr[k], wi[k]), "m={m} k={k}");
                assert_eq!((lhr[k], lhi[k]), (hr[k], hi[k]), "m={m} k={k} inv");
            }
            for k in wr.len()..lwr.len() {
                assert_eq!((lwr[k], lwi[k], lhr[k], lhi[k]), (0.0, 0.0, 0.0, 0.0), "pad m={m}");
            }
        }
    }
}
