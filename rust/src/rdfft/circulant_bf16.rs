//! bf16 block-circulant operator — the paper's third contribution made
//! concrete at the layer level: fft/rfft libraries reject bf16, so a
//! bf16 training stack must upcast (doubling activation memory); rdFFT
//! runs the whole Eq. 4/5 pipeline on 2-byte storage with f32 butterfly
//! arithmetic, halving every buffer the layer touches.

use super::bf16::{irdfft_inplace_bf16, rdfft_inplace_bf16, Bf16};
use super::plan::{cached, Plan};
use crate::memtrack::{Category, Registration};
use std::sync::Arc;

/// Packed-domain elementwise product over bf16 spectra (math in f32).
pub fn mul_acc_bf16(acc: &mut [Bf16], a: &[Bf16], b: &[Bf16]) {
    let n = acc.len();
    debug_assert_eq!(n, a.len());
    debug_assert_eq!(n, b.len());
    acc[0] = Bf16::from_f32(acc[0].to_f32() + a[0].to_f32() * b[0].to_f32());
    acc[n / 2] = Bf16::from_f32(acc[n / 2].to_f32() + a[n / 2].to_f32() * b[n / 2].to_f32());
    for k in 1..n / 2 {
        let (ar, ai) = (a[k].to_f32(), a[n - k].to_f32());
        let (br, bi) = (b[k].to_f32(), b[n - k].to_f32());
        acc[k] = Bf16::from_f32(acc[k].to_f32() + ar * br - ai * bi);
        acc[n - k] = Bf16::from_f32(acc[n - k].to_f32() + ar * bi + ai * br);
    }
}

/// `acc += conj(a) ⊙ b` over bf16 spectra.
pub fn conj_mul_acc_bf16(acc: &mut [Bf16], a: &[Bf16], b: &[Bf16]) {
    let n = acc.len();
    acc[0] = Bf16::from_f32(acc[0].to_f32() + a[0].to_f32() * b[0].to_f32());
    acc[n / 2] = Bf16::from_f32(acc[n / 2].to_f32() + a[n / 2].to_f32() * b[n / 2].to_f32());
    for k in 1..n / 2 {
        let (ar, ai) = (a[k].to_f32(), a[n - k].to_f32());
        let (br, bi) = (b[k].to_f32(), b[n - k].to_f32());
        acc[k] = Bf16::from_f32(acc[k].to_f32() + ar * br + ai * bi);
        acc[n - k] = Bf16::from_f32(acc[n - k].to_f32() + ar * bi - ai * br);
    }
}

/// bf16 block-circulant operator (storage 2 bytes/scalar throughout).
#[derive(Debug, Clone)]
pub struct BlockCirculantBf16 {
    plan: Arc<Plan>,
    rows: usize,
    cols: usize,
    p: usize,
    c_hat: Vec<Bf16>,
    /// memtrack registration of the bf16 parameter storage (2 bytes per
    /// scalar — half the f32 operator's, asserted tracker-side in
    /// `rust/tests/differential.rs`).
    _mem: Registration,
}

impl BlockCirculantBf16 {
    /// Build from f32 first columns (quantized to bf16 on entry, like a
    /// bf16 checkpoint load).
    pub fn from_block_columns(rows: usize, cols: usize, p: usize, c: &[f32]) -> Self {
        assert!(rows % p == 0 && cols % p == 0);
        let rb = rows / p;
        let cb = cols / p;
        assert_eq!(c.len(), rb * cb * p);
        let plan = cached(p);
        let mut c_hat: Vec<Bf16> = c.iter().map(|&v| Bf16::from_f32(v)).collect();
        for blk in c_hat.chunks_exact_mut(p) {
            rdfft_inplace_bf16(&plan, blk);
        }
        let mem = Registration::new(c_hat.len() * 2, Category::Trainable);
        BlockCirculantBf16 { plan, rows, cols, p, c_hat, _mem: mem }
    }

    pub fn num_params(&self) -> usize {
        self.c_hat.len()
    }

    /// Bytes of parameter storage (half the f32 operator's).
    pub fn param_bytes(&self) -> usize {
        self.c_hat.len() * 2
    }

    /// Forward product, in place on the bf16 input blocks (which then
    /// hold x̂, the saved-for-backward tensor — same discipline as f32),
    /// via the fused block sweep ([`block_sweep_bf16`]), mirroring
    /// [`crate::rdfft::engine::block_circulant_forward_batch`].
    pub fn forward_inplace(&self, x: &mut [Bf16], out: &mut [Bf16]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        block_sweep_bf16(&self.plan, x, out, &self.c_hat, self.cols / self.p, false);
    }

    /// Backward pass (Eq. 5) on bf16 buffers; `dc` accumulates in the
    /// frequency domain like the f32 operator. The transpose sweep turns
    /// `g` into ĝ in place and produces `dx` in the same pass, mirroring
    /// [`crate::rdfft::engine::block_circulant_transpose_batch`].
    pub fn backward(&self, x_hat: &[Bf16], g: &mut [Bf16], dx: &mut [Bf16], dc: &mut [Bf16]) {
        assert_eq!(x_hat.len(), self.cols);
        assert_eq!(g.len(), self.rows);
        assert_eq!(dx.len(), self.cols);
        assert_eq!(dc.len(), self.c_hat.len());
        let p = self.p;
        let cb = self.cols / p;
        block_sweep_bf16(&self.plan, g, dx, &self.c_hat, cb, true);
        for (i, gb) in g.chunks_exact(p).enumerate() {
            for (j, xb) in x_hat.chunks_exact(p).enumerate() {
                let d = &mut dc[(i * cb + j) * p..][..p];
                conj_mul_acc_bf16(d, xb, gb);
            }
        }
    }
}

/// The bf16 mirror of the engine's fused block-circulant sweep: transform
/// the input blocks in place (they end holding their packed spectra),
/// accumulate the packed products into each output block and inverse it
/// immediately — one pass over the operand, zero allocations, storage
/// 2 bytes/scalar throughout with f32 butterfly arithmetic. The
/// butterflies inherit the width-4 lane dispatch through
/// [`rdfft_inplace_bf16`]/[`irdfft_inplace_bf16`] (quads of widened
/// 4-groups); the products stay per-element because every
/// multiply-accumulate rounds through bf16 storage.
/// `transpose` selects the Eq. 5 direction (`conj(ĉ_ij) ⊙ ĝ_i` into
/// input-grad block j) over the Eq. 4 forward (`ĉ_ij ⊙ x̂_j` into output
/// block i); `cb` is the weight layout's column-block count.
fn block_sweep_bf16(
    plan: &Plan,
    input: &mut [Bf16],
    out: &mut [Bf16],
    c_hat: &[Bf16],
    cb: usize,
    transpose: bool,
) {
    let p = plan.n();
    for xb in input.chunks_exact_mut(p) {
        rdfft_inplace_bf16(plan, xb);
    }
    for (oi, ob) in out.chunks_exact_mut(p).enumerate() {
        ob.fill(Bf16::ZERO);
        for (ii, xb) in input.chunks_exact(p).enumerate() {
            let (i, j) = if transpose { (ii, oi) } else { (oi, ii) };
            let ch = &c_hat[(i * cb + j) * p..][..p];
            if transpose {
                conj_mul_acc_bf16(ob, ch, xb);
            } else {
                mul_acc_bf16(ob, ch, xb);
            }
        }
        irdfft_inplace_bf16(plan, ob);
    }
}

#[cfg(test)]
mod tests {
    use super::super::circulant::BlockCirculant;
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(99);
                ((s >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn bf16_forward_tracks_f32_forward() {
        let (rows, cols, p) = (32, 32, 16);
        let c = rand_vec((rows / p) * (cols / p) * p, 1);
        let x = rand_vec(cols, 2);
        let f32_op = BlockCirculant::from_block_columns(rows, cols, p, &c);
        let bf_op = BlockCirculantBf16::from_block_columns(rows, cols, p, &c);

        let mut xf = x.clone();
        let mut out_f = vec![0.0f32; rows];
        f32_op.forward_inplace(&mut xf, &mut out_f);

        let mut xb: Vec<Bf16> = x.iter().map(|&v| Bf16::from_f32(v)).collect();
        let mut out_b = vec![Bf16::ZERO; rows];
        bf_op.forward_inplace(&mut xb, &mut out_b);

        let scale = out_f.iter().map(|v| v.abs()).fold(0.1f32, f32::max);
        for i in 0..rows {
            let err = (out_b[i].to_f32() - out_f[i]).abs();
            assert!(err < 0.1 * scale, "i={i}: {} vs {}", out_b[i].to_f32(), out_f[i]);
        }
    }

    #[test]
    fn bf16_storage_is_half_of_f32() {
        let op = BlockCirculantBf16::from_block_columns(64, 64, 16, &rand_vec(4 * 4 * 16, 3));
        assert_eq!(op.param_bytes(), op.num_params() * 2);
    }

    #[test]
    fn bf16_backward_produces_finite_grads_tracking_f32() {
        let (rows, cols, p) = (16, 16, 8);
        let c = rand_vec(2 * 2 * 8, 4);
        let x = rand_vec(cols, 5);
        let g0 = rand_vec(rows, 6);

        let f32_op = BlockCirculant::from_block_columns(rows, cols, p, &c);
        let mut xf = x.clone();
        let mut of = vec![0.0f32; rows];
        f32_op.forward_inplace(&mut xf, &mut of);
        let mut gf = g0.clone();
        let mut dxf = vec![0.0f32; cols];
        let mut dcf = vec![0.0f32; f32_op.num_params()];
        f32_op.backward(&xf, &mut gf, &mut dxf, &mut dcf);

        let bf_op = BlockCirculantBf16::from_block_columns(rows, cols, p, &c);
        let mut xb: Vec<Bf16> = x.iter().map(|&v| Bf16::from_f32(v)).collect();
        let mut ob = vec![Bf16::ZERO; rows];
        bf_op.forward_inplace(&mut xb, &mut ob);
        let mut gb: Vec<Bf16> = g0.iter().map(|&v| Bf16::from_f32(v)).collect();
        let mut dxb = vec![Bf16::ZERO; cols];
        let mut dcb = vec![Bf16::ZERO; bf_op.num_params()];
        bf_op.backward(&xb, &mut gb, &mut dxb, &mut dcb);

        let scale = dxf.iter().map(|v| v.abs()).fold(0.1f32, f32::max);
        for i in 0..cols {
            assert!(
                (dxb[i].to_f32() - dxf[i]).abs() < 0.15 * scale,
                "dx i={i}: {} vs {}",
                dxb[i].to_f32(),
                dxf[i]
            );
        }
        let scale = dcf.iter().map(|v| v.abs()).fold(0.1f32, f32::max);
        for i in 0..dcf.len() {
            assert!(
                (dcb[i].to_f32() - dcf[i]).abs() < 0.15 * scale,
                "dc i={i}: {} vs {}",
                dcb[i].to_f32(),
                dcf[i]
            );
        }
    }

    #[test]
    fn zero_input_gives_zero_output() {
        let op = BlockCirculantBf16::from_block_columns(16, 16, 8, &rand_vec(2 * 2 * 8, 7));
        let mut x = vec![Bf16::ZERO; 16];
        let mut out = vec![Bf16::from_f32(9.0); 16];
        op.forward_inplace(&mut x, &mut out);
        for v in out {
            assert_eq!(v.to_f32(), 0.0);
        }
    }
}
