//! 2-D in-place rdFFT — the paper's "broader classes of structured
//! transformations" future-work direction (FourierFT-style fine-tuning
//! uses 2-D spectra).
//!
//! A real `(rows × cols)` matrix is transformed inside its own buffer:
//! first every row gets the packed 1-D transform, then every *column* of
//! the packed representation is transformed with the same engine. Because
//! the 1-D packed transform is linear, the column pass applied to packed
//! row coefficients yields a fully real-representable 2-D encoding:
//!
//! `X2[u, k]` holds the packed-in-`u` transform of the per-row packed
//! coefficient stream — `unpack_col(unpack_row(X2))` reconstructs the
//! complex 2-D DFT's non-redundant quadrant (see tests).
//!
//! The inverse runs the passes in the opposite order, each exactly
//! inverting its 1-D transform, so `irdfft2(rdfft2(x)) == x` holds to
//! float precision with zero auxiliary allocation beyond one column
//! scratch of `rows` floats (the strided-access analogue of the CUDA
//! kernel's shared-memory tile; allocate it once via [`Plan2`]).

use super::forward::rdfft_inplace;
use super::inverse::irdfft_inplace;
use super::plan::{cached, Plan};
use std::sync::Arc;

/// Plan for a 2-D transform, including the reusable column scratch.
pub struct Plan2 {
    rows: usize,
    cols: usize,
    row_plan: Arc<Plan>,
    col_plan: Arc<Plan>,
}

impl Plan2 {
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(super::is_supported_size(rows) && super::is_supported_size(cols));
        Plan2 { rows, cols, row_plan: cached(cols), col_plan: cached(rows) }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Forward 2-D packed transform, in place (plus one `rows`-float
    /// column scratch supplied by the caller, reusable across calls).
    pub fn forward_inplace(&self, buf: &mut [f32], col_scratch: &mut [f32]) {
        assert_eq!(buf.len(), self.rows * self.cols);
        assert_eq!(col_scratch.len(), self.rows);
        for row in buf.chunks_exact_mut(self.cols) {
            rdfft_inplace(&self.row_plan, row);
        }
        for c in 0..self.cols {
            for r in 0..self.rows {
                col_scratch[r] = buf[r * self.cols + c];
            }
            rdfft_inplace(&self.col_plan, col_scratch);
            for r in 0..self.rows {
                buf[r * self.cols + c] = col_scratch[r];
            }
        }
    }

    /// Exact inverse of [`Self::forward_inplace`].
    pub fn inverse_inplace(&self, buf: &mut [f32], col_scratch: &mut [f32]) {
        assert_eq!(buf.len(), self.rows * self.cols);
        assert_eq!(col_scratch.len(), self.rows);
        for c in 0..self.cols {
            for r in 0..self.rows {
                col_scratch[r] = buf[r * self.cols + c];
            }
            irdfft_inplace(&self.col_plan, col_scratch);
            for r in 0..self.rows {
                buf[r * self.cols + c] = col_scratch[r];
            }
        }
        for row in buf.chunks_exact_mut(self.cols) {
            irdfft_inplace(&self.row_plan, row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Vec<f32> {
        let mut state = seed | 1;
        (0..r * c)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn roundtrip_2d() {
        for (r, c) in [(4usize, 8usize), (8, 8), (16, 32), (64, 16)] {
            let plan = Plan2::new(r, c);
            let x = rand_mat(r, c, (r * c) as u64);
            let mut buf = x.clone();
            let mut scratch = vec![0.0f32; r];
            plan.forward_inplace(&mut buf, &mut scratch);
            assert_ne!(buf, x, "transform must change the buffer");
            plan.inverse_inplace(&mut buf, &mut scratch);
            for i in 0..r * c {
                assert!((buf[i] - x[i]).abs() < 1e-3, "({r}x{c}) i={i}");
            }
        }
    }

    #[test]
    fn dc_term_is_total_sum() {
        let (r, c) = (8, 16);
        let plan = Plan2::new(r, c);
        let x = rand_mat(r, c, 5);
        let sum: f32 = x.iter().sum();
        let mut buf = x;
        let mut scratch = vec![0.0f32; r];
        plan.forward_inplace(&mut buf, &mut scratch);
        assert!((buf[0] - sum).abs() < 1e-3 * (r * c) as f32);
    }

    #[test]
    fn separable_signal_has_separable_spectrum() {
        // x[r][c] = f[r] * g[c]  =>  2D spectrum = outer(F, G); check DC row
        let (r, c) = (8, 8);
        let f: Vec<f32> = (0..r).map(|i| (i as f32 * 0.3).cos()).collect();
        let g: Vec<f32> = (0..c).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut x = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                x[i * c + j] = f[i] * g[j];
            }
        }
        let plan = Plan2::new(r, c);
        let mut scratch = vec![0.0f32; r];
        let mut buf = x.clone();
        plan.forward_inplace(&mut buf, &mut scratch);

        // row-0 of the 2D packed transform equals sum over rows of f times
        // packed(g): check against direct computation
        let sum_f: f32 = f.iter().sum();
        let mut pg = g.clone();
        rdfft_inplace(&cached(c), &mut pg);
        for j in 0..c {
            assert!(
                (buf[j] - sum_f * pg[j]).abs() < 1e-3,
                "j={j}: {} vs {}",
                buf[j],
                sum_f * pg[j]
            );
        }
    }

    #[test]
    fn linearity_2d() {
        let (r, c) = (16, 8);
        let plan = Plan2::new(r, c);
        let a = rand_mat(r, c, 1);
        let b = rand_mat(r, c, 2);
        let mut scratch = vec![0.0f32; r];
        let mut fa = a.clone();
        plan.forward_inplace(&mut fa, &mut scratch);
        let mut fb = b.clone();
        plan.forward_inplace(&mut fb, &mut scratch);
        let mut sum: Vec<f32> = (0..r * c).map(|i| 2.0 * a[i] - 0.5 * b[i]).collect();
        plan.forward_inplace(&mut sum, &mut scratch);
        for i in 0..r * c {
            assert!((sum[i] - (2.0 * fa[i] - 0.5 * fb[i])).abs() < 1e-2);
        }
    }
}
