//! 2-D in-place rdFFT — the paper's "broader classes of structured
//! transformations" future-work direction (FourierFT-style fine-tuning
//! uses 2-D spectra).
//!
//! A real `(rows × cols)` matrix is transformed inside its own buffer:
//! first every row gets the packed 1-D transform, then every *column* of
//! the packed representation is transformed with the same engine. Because
//! the 1-D packed transform is linear, the column pass applied to packed
//! row coefficients yields a fully real-representable 2-D encoding:
//!
//! `X2[u, k]` holds the packed-in-`u` transform of the per-row packed
//! coefficient stream — `unpack_col(unpack_row(X2))` reconstructs the
//! complex 2-D DFT's non-redundant quadrant (see tests).
//!
//! Both passes run through the batch-major [`super::engine`]: the row
//! pass is one engine call over all `rows` contiguous rows, and the
//! column pass gathers columns into a fixed transpose tile (the
//! strided-access analogue of the CUDA kernel's shared-memory tile,
//! allocated once in [`Plan2::new`], moved through the shared
//! [`super::tiling`] gather/scatter helpers the four-step large-n engine
//! also uses) so columns also transform as contiguous engine batches. The inverse runs the passes in the opposite
//! order, so `irdfft2(rdfft2(x)) == x` holds to float precision with zero
//! allocation beyond the plan's persistent tile.
//!
//! Both passes inherit the engine's SIMD lane dispatch (and its
//! `force_scalar` escape hatch) for free: the row pass and every gathered
//! column tile are plain engine batch calls, so 2-D transforms run the
//! width-4 butterfly quads without any 2-D-specific kernel code.

use super::engine;
use super::plan::{cached, Plan};
use super::tiling;
use crate::runtime::pool::ExecCtx;
use std::sync::Arc;

/// Columns gathered per transpose tile in the column pass.
const COL_TILE: usize = 8;

/// Plan for a 2-D transform, including the persistent transpose tile.
pub struct Plan2 {
    rows: usize,
    cols: usize,
    row_plan: Arc<Plan>,
    col_plan: Arc<Plan>,
    /// `tile_cols × rows` transpose scratch, column-major per gathered
    /// column, reused across calls (allocated once here, never per call).
    tile: Vec<f32>,
}

impl Plan2 {
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(super::is_supported_size(rows) && super::is_supported_size(cols));
        let tile_cols = COL_TILE.min(cols);
        Plan2 {
            rows,
            cols,
            row_plan: cached(cols),
            col_plan: cached(rows),
            tile: vec![0.0; rows * tile_cols],
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Forward 2-D packed transform, in place (`&mut self` for the
    /// reusable transpose tile). Dispatches on the default engine
    /// runtime (the global pool).
    pub fn forward_inplace(&mut self, buf: &mut [f32]) {
        assert_eq!(buf.len(), self.rows * self.cols);
        engine::forward_batch(&self.row_plan, buf);
        self.col_pass(buf, true, None);
    }

    /// Exact inverse of [`Self::forward_inplace`].
    pub fn inverse_inplace(&mut self, buf: &mut [f32]) {
        assert_eq!(buf.len(), self.rows * self.cols);
        self.col_pass(buf, false, None);
        engine::inverse_batch(&self.row_plan, buf);
    }

    /// [`Self::forward_inplace`] under an explicit [`ExecCtx`]: both the
    /// row pass and the tiled column pass run on that context's pool with
    /// its engine tuning. Bit-identical to the default path.
    pub fn forward_inplace_ctx(&mut self, buf: &mut [f32], ctx: &ExecCtx) {
        assert_eq!(buf.len(), self.rows * self.cols);
        engine::forward_batch_ctx(&self.row_plan, buf, ctx);
        self.col_pass(buf, true, Some(ctx));
    }

    /// [`Self::inverse_inplace`] under an explicit [`ExecCtx`].
    pub fn inverse_inplace_ctx(&mut self, buf: &mut [f32], ctx: &ExecCtx) {
        assert_eq!(buf.len(), self.rows * self.cols);
        self.col_pass(buf, false, Some(ctx));
        engine::inverse_batch_ctx(&self.row_plan, buf, ctx);
    }

    /// Transform every column: gather up to `COL_TILE` columns into the
    /// persistent tile (each becoming one contiguous engine row), run one
    /// batched transform, scatter back. `ctx = None` uses the default
    /// engine runtime.
    fn col_pass(&mut self, buf: &mut [f32], forward: bool, ctx: Option<&ExecCtx>) {
        let (r, c) = (self.rows, self.cols);
        let tile_cols = self.tile.len() / r;
        let mut c0 = 0usize;
        while c0 < c {
            let tc = tile_cols.min(c - c0);
            tiling::gather_cols(&mut self.tile, buf, r, c, c0, tc);
            let seg = &mut self.tile[..tc * r];
            match (forward, ctx) {
                (true, None) => engine::forward_batch(&self.col_plan, seg),
                (false, None) => engine::inverse_batch(&self.col_plan, seg),
                (true, Some(cx)) => engine::forward_batch_ctx(&self.col_plan, seg, cx),
                (false, Some(cx)) => engine::inverse_batch_ctx(&self.col_plan, seg, cx),
            }
            tiling::scatter_cols(&self.tile, buf, r, c, c0, tc);
            c0 += tc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::forward::rdfft_inplace;
    use super::*;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Vec<f32> {
        let mut state = seed | 1;
        (0..r * c)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn roundtrip_2d() {
        for (r, c) in [(4usize, 8usize), (8, 8), (16, 32), (64, 16), (8, 4)] {
            let mut plan = Plan2::new(r, c);
            let x = rand_mat(r, c, (r * c) as u64);
            let mut buf = x.clone();
            plan.forward_inplace(&mut buf);
            assert_ne!(buf, x, "transform must change the buffer");
            plan.inverse_inplace(&mut buf);
            for i in 0..r * c {
                assert!((buf[i] - x[i]).abs() < 1e-3, "({r}x{c}) i={i}");
            }
        }
    }

    #[test]
    fn ctx_passes_match_default_passes_bitwise() {
        let ctx = ExecCtx::with_threads(3);
        let (r, c) = (32usize, 64usize);
        let x = rand_mat(r, c, 77);
        let mut plan_a = Plan2::new(r, c);
        let mut a = x.clone();
        plan_a.forward_inplace(&mut a);
        let mut plan_b = Plan2::new(r, c);
        let mut b = x.clone();
        plan_b.forward_inplace_ctx(&mut b, &ctx);
        assert_eq!(a, b, "forward ctx pass must be bit-identical");
        plan_a.inverse_inplace(&mut a);
        plan_b.inverse_inplace_ctx(&mut b, &ctx);
        assert_eq!(a, b, "inverse ctx pass must be bit-identical");
    }

    #[test]
    fn dc_term_is_total_sum() {
        let (r, c) = (8, 16);
        let mut plan = Plan2::new(r, c);
        let x = rand_mat(r, c, 5);
        let sum: f32 = x.iter().sum();
        let mut buf = x;
        plan.forward_inplace(&mut buf);
        assert!((buf[0] - sum).abs() < 1e-3 * (r * c) as f32);
    }

    #[test]
    fn separable_signal_has_separable_spectrum() {
        // x[r][c] = f[r] * g[c]  =>  2D spectrum = outer(F, G); check DC row
        let (r, c) = (8, 8);
        let f: Vec<f32> = (0..r).map(|i| (i as f32 * 0.3).cos()).collect();
        let g: Vec<f32> = (0..c).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut x = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                x[i * c + j] = f[i] * g[j];
            }
        }
        let mut plan = Plan2::new(r, c);
        let mut buf = x.clone();
        plan.forward_inplace(&mut buf);

        // row-0 of the 2D packed transform equals sum over rows of f times
        // packed(g): check against direct computation
        let sum_f: f32 = f.iter().sum();
        let mut pg = g.clone();
        rdfft_inplace(&cached(c), &mut pg);
        for j in 0..c {
            assert!(
                (buf[j] - sum_f * pg[j]).abs() < 1e-3,
                "j={j}: {} vs {}",
                buf[j],
                sum_f * pg[j]
            );
        }
    }

    #[test]
    fn linearity_2d() {
        let (r, c) = (16, 8);
        let mut plan = Plan2::new(r, c);
        let a = rand_mat(r, c, 1);
        let b = rand_mat(r, c, 2);
        let mut fa = a.clone();
        plan.forward_inplace(&mut fa);
        let mut fb = b.clone();
        plan.forward_inplace(&mut fb);
        let mut sum: Vec<f32> = (0..r * c).map(|i| 2.0 * a[i] - 0.5 * b[i]).collect();
        plan.forward_inplace(&mut sum);
        for i in 0..r * c {
            assert!((sum[i] - (2.0 * fa[i] - 0.5 * fb[i])).abs() < 1e-2);
        }
    }

    #[test]
    fn column_tiling_matches_untiled_column_loop() {
        // wide matrix exercises multiple tiles, including a partial one.
        // The 2-D pass runs on the forced-scalar arm so the comparison
        // against the per-row/per-column legacy scalar loop stays
        // bitwise; the auto arm's drift is bounded by the differential
        // suite at the 1-D level.
        let ctx = ExecCtx::serial()
            .with_engine_config(crate::rdfft::EngineConfig::forced_scalar_serial());
        let (r, c) = (16usize, 32usize);
        let mut plan = Plan2::new(r, c);
        let x = rand_mat(r, c, 9);
        let mut got = x.clone();
        plan.forward_inplace_ctx(&mut got, &ctx);

        // reference: row pass + one-column-at-a-time scalar column pass
        let mut want = x;
        for row in want.chunks_exact_mut(c) {
            rdfft_inplace(&cached(c), row);
        }
        let col_plan = cached(r);
        let mut scratch = vec![0.0f32; r];
        for j in 0..c {
            for i in 0..r {
                scratch[i] = want[i * c + j];
            }
            rdfft_inplace(&col_plan, &mut scratch);
            for i in 0..r {
                want[i * c + j] = scratch[i];
            }
        }
        for i in 0..r * c {
            assert_eq!(got[i], want[i], "i={i}");
        }
    }
}
