//! In-place forward rdFFT (§4.1 of the paper).
//!
//! Decimation-in-time Cooley–Tukey over the *packed* real layout. After the
//! bit-reversal permutation, stage `m` (m = 1, 2, 4, … n/2) combines pairs
//! of packed `m`-point spectra sitting in adjacent halves of each
//! `2m`-block into one packed `2m`-point spectrum, entirely in place:
//!
//! * `k = 0` — DC/Nyquist lane: `(e, o) → (e+o, e−o)`, both real.
//! * `k = m/2` — sub-Nyquist lane: `y_{m/2} = e − i·o`; `e` is already in
//!   its slot, `o` just flips sign in the mirrored slot.
//! * `1 ≤ k < m/2` — the symmetric **4-element group** of Proposition 1,
//!   `{s+k, s+m−k, s+m+k, s+2m−k}`: read `(E.re, E.im, O.re, O.im)`,
//!   apply the twiddle to `O`, write `(y_k.re, y_{m−k}.re, y_{m−k}.im,
//!   y_k.im)` back to the *same four slots*.
//!
//! No element outside the 4-group is touched, so the transform performs
//! zero allocations and zero out-of-buffer writes — the property the
//! memory experiments (Table 1 / Fig 2) depend on.

use super::plan::Plan;

/// Transform `buf` (length `plan.n()`) from a real signal to the packed
/// spectrum, in place.
// audit: no_alloc
pub fn rdfft_inplace(plan: &Plan, buf: &mut [f32]) {
    assert_eq!(buf.len(), plan.n(), "buffer length must equal plan size");
    plan.bit_reverse(buf);
    forward_stages(plan, buf);
}

/// Batched variant: `buf` holds `batch` contiguous rows of length
/// `plan.n()`; each row is transformed independently, in place. Routed
/// through the batch-major [`super::engine`] (fused first stages, SoA
/// twiddles, pooled row chunks above the work threshold, and the
/// runtime-dispatched SIMD lane kernels of [`super::simd`]). Output is
/// bit-identical to the per-row scalar path on the forced-scalar and
/// portable arms; the AVX2+FMA arm agrees within the n-scaled tolerance
/// (EXPERIMENTS.md §Perf iteration 6). Sizes at or above
/// `EngineConfig::fourstep_threshold` take the four-step (Bailey) large-n
/// tier ([`super::fourstep`]) — same packed layout, ~1 ulp twiddle delta
/// (EXPERIMENTS.md §Perf iteration 7).
pub fn rdfft_batch(plan: &Plan, buf: &mut [f32]) {
    super::engine::forward_batch(plan, buf);
}

/// The pre-engine serial row loop, kept as the equivalence/ablation
/// reference: per-row scalar transforms, nothing fused, nothing batched,
/// no SIMD — the oracle `EngineConfig::force_scalar` must reproduce
/// bit-for-bit (rust/tests/differential.rs pins that contract).
pub fn rdfft_batch_scalar(plan: &Plan, buf: &mut [f32]) {
    let n = plan.n();
    assert!(buf.len() % n == 0, "buffer length must be a multiple of plan size");
    for row in buf.chunks_exact_mut(n) {
        rdfft_inplace(plan, row);
    }
}

/// All butterfly stages (input already bit-reversed). Exposed for the
/// ablation bench that separates permutation cost from butterfly cost.
// audit: no_alloc
#[inline]
pub fn forward_stages(plan: &Plan, buf: &mut [f32]) {
    let n = plan.n();
    let mut m = 1usize;
    while m < n {
        let tw = plan.stage_twiddles(m);
        let two_m = 2 * m;
        let mut s = 0usize;
        while s < n {
            // k = 0: DC/Nyquist lane.
            let e = buf[s];
            let o = buf[s + m];
            buf[s] = e + o;
            buf[s + m] = e - o;
            if m >= 2 {
                // k = m/2: y_{m/2} = e - i*o; Re stays, Im slot flips sign.
                let idx = s + m + m / 2;
                buf[idx] = -buf[idx];
            }
            // 1 <= k < m/2: symmetric four-element groups.
            //
            // SAFETY: all four indices lie inside [s, s+2m): the loop
            // guarantees 1 <= k < m/2, and `s + two_m <= n` by the outer
            // loop bound, so unchecked access is in range. Bounds checks
            // here cost ~25% of the transform (see EXPERIMENTS.md §Perf).
            unsafe {
                let blk = buf.get_unchecked_mut(s..s + two_m);
                for (k, &(wr, wi)) in (1..m / 2).zip(tw.iter()) {
                    let er = *blk.get_unchecked(k);
                    let ei = *blk.get_unchecked(m - k);
                    let or_ = *blk.get_unchecked(m + k);
                    let oi = *blk.get_unchecked(two_m - k);
                    // T = W * O
                    let tr = wr * or_ - wi * oi;
                    let ti = wr * oi + wi * or_;
                    *blk.get_unchecked_mut(k) = er + tr; //       Re y_k
                    *blk.get_unchecked_mut(two_m - k) = ei + ti; // Im y_k
                    *blk.get_unchecked_mut(m - k) = er - tr; //    Re y_{m-k}
                    *blk.get_unchecked_mut(m + k) = ti - ei; //    Im y_{m-k}
                }
            }
            s += two_m;
        }
        m = two_m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_point_transform() {
        let plan = Plan::new(2);
        let mut buf = [3.0f32, 5.0];
        rdfft_inplace(&plan, &mut buf);
        assert_eq!(buf, [8.0, -2.0]); // [DC, Nyquist]
    }

    #[test]
    fn four_point_transform() {
        // FFT([1,2,3,4]) = [10, -2+2i, -2, -2-2i]
        // packed: [10, -2, -2, 2]
        let plan = Plan::new(4);
        let mut buf = [1.0f32, 2.0, 3.0, 4.0];
        rdfft_inplace(&plan, &mut buf);
        assert!((buf[0] - 10.0).abs() < 1e-6);
        assert!((buf[1] - -2.0).abs() < 1e-6);
        assert!((buf[2] - -2.0).abs() < 1e-6);
        assert!((buf[3] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn impulse_has_flat_spectrum() {
        let n = 64;
        let plan = Plan::new(n);
        let mut buf = vec![0.0f32; n];
        buf[0] = 1.0;
        rdfft_inplace(&plan, &mut buf);
        // FFT(delta) = all-ones: packed layout is re=1 everywhere, im=0.
        for k in 0..=n / 2 {
            assert!((buf[k] - 1.0).abs() < 1e-6, "k={k}");
        }
        for k in n / 2 + 1..n {
            assert!(buf[k].abs() < 1e-6, "k={k}");
        }
    }

    #[test]
    fn constant_signal_is_pure_dc() {
        let n = 32;
        let plan = Plan::new(n);
        let mut buf = vec![2.0f32; n];
        rdfft_inplace(&plan, &mut buf);
        assert!((buf[0] - 64.0).abs() < 1e-5);
        for k in 1..n {
            assert!(buf[k].abs() < 1e-5, "k={k} -> {}", buf[k]);
        }
    }

    #[test]
    fn single_cosine_lands_on_one_bin() {
        let n = 128;
        let f = 5usize;
        let plan = Plan::new(n);
        let mut buf: Vec<f32> = (0..n)
            .map(|i| (std::f64::consts::TAU * f as f64 * i as f64 / n as f64).cos() as f32)
            .collect();
        rdfft_inplace(&plan, &mut buf);
        // cos(2π f t/n): y_f = n/2, y_{n-f} = n/2, everything else 0.
        assert!((buf[f] - n as f32 / 2.0).abs() < 1e-3);
        for k in 0..n {
            if k != f {
                assert!(buf[k].abs() < 1e-3, "k={k} -> {}", buf[k]);
            }
        }
    }

    #[test]
    fn single_sine_lands_on_one_imag_bin() {
        let n = 128;
        let f = 9usize;
        let plan = Plan::new(n);
        let mut buf: Vec<f32> = (0..n)
            .map(|i| (std::f64::consts::TAU * f as f64 * i as f64 / n as f64).sin() as f32)
            .collect();
        rdfft_inplace(&plan, &mut buf);
        // sin: y_f = -i n/2 → Im(y_f) = -n/2 stored at index n-f.
        assert!((buf[n - f] + n as f32 / 2.0).abs() < 1e-3);
        for k in 0..n {
            if k != n - f {
                assert!(buf[k].abs() < 1e-3, "k={k} -> {}", buf[k]);
            }
        }
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let plan = Plan::new(8);
        let mut buf = [0.0f32; 4];
        rdfft_inplace(&plan, &mut buf);
    }
}
