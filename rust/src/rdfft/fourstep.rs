//! Four-step (Bailey) large-n execution tier.
//!
//! The direct engine ([`super::engine`]) sweeps the whole buffer once per
//! butterfly stage — `log2 n` full passes. Below ~16 Ki points the row
//! tile is cache-resident and those passes are free; above it every late
//! stage streams the full transform from DRAM and the engine goes
//! memory-bandwidth bound. This tier restructures a length-`n = n1 × n2`
//! transform (`n2 ≥ n1`, both powers of two — tables in
//! [`FourStep`](super::plan::FourStep)) into three phases with a *bounded*
//! number of full-buffer sweeps, strictly in place and preserving the
//! packed conjugate-symmetric layout bit-for-bit in meaning (DC at 0,
//! `Re(y_k)` at `k`, `Im(y_k)` at `n − k`), so `circulant_apply_batch`
//! and the fused block sweeps consume its spectra unchanged:
//!
//! 1. **Rows** — the fused full-`n` bit-reversal + trivial stages
//!    `m = 1, 2` per row (one pass, identical code to the direct path).
//! 2. **Sub-transforms** — stages `m = 4 .. n2/2` only ever combine
//!    elements inside one contiguous `n2`-chunk, so each of the `n1`
//!    chunks per row is an independent cache-resident `n2`-point
//!    continuation: one tiled sweep with the shared cached `n2` plan,
//!    bit-identical arithmetic to the direct path's early stages.
//! 3. **Column panels** — the `log2 n1` *late* stages `m = n2·2^t` only
//!    ever combine slots whose column index (`slot mod n2`) lies in the
//!    closed pair `{r, n2 − r}` (or the special pair `{0, n2/2}`). Each
//!    pair is gathered once into a cache-resident transpose tile (the
//!    shared [`super::tiling`] helpers `twod` also uses), **all** late
//!    stages run inside the tile with the twiddle correction fused in
//!    (the factorized `A_t[q]·B_t[r]` product — see
//!    [`super::plan::FourStep`]), and the pair scatters back: one
//!    strided pass total instead of `log2 n1` streaming passes.
//!
//! Numerics: phases 1–2 are bit-identical to the direct engine; phase 3
//! rounds each twiddle product once more (~1 ulp) — the only delta, and
//! it is applied identically regardless of worker count, so results stay
//! bitwise deterministic across thread counts, pool-vs-scoped dispatch,
//! and repeats (asserted in tests here and in `tests/golden.rs`).
//!
//! Parallelism reuses the engine's dispatch: phases 1–2 split contiguous
//! row chunks via [`engine::dispatch_rows`]; phase 3's units are
//! `(row, panel)` pairs sharing the buffer through disjoint column sets,
//! strided over workers via [`engine::dispatch_span`]. Each worker owns a
//! thread-local `2·n1`-float tile ([`tile_floats`]), grown on first use
//! and reused forever after — after warm-up the whole tier allocates
//! nothing (asserted in `tests/memory_invariants.rs`).

use std::cell::RefCell;

use super::engine::{self, Dispatch, EngineConfig};
use super::plan::{FourStep, Plan};
use super::simd::{self, Kernels};
use super::tiling;

/// Column pairs processed per phase-3 dispatch unit. Purely a dispatch
/// granularity knob (the tile still holds one pair at a time): larger
/// panels amortize unit bookkeeping, smaller panels balance better.
const PANEL_PAIRS: usize = 4;

/// Thread-local scratch floats one phase-3 worker needs for a plan with
/// `n1` rows in its `n1 × n2` view: one gathered column pair. Exposed so
/// `Plan::heap_bytes` docs can account for it.
pub const fn tile_floats(n1: usize) -> usize {
    2 * n1
}

thread_local! {
    /// Per-thread transpose tile for the phase-3 column kernels. Grows to
    /// the largest `tile_floats(n1)` the thread has seen, then persists —
    /// pool workers park with their tile warm, so steady-state transforms
    /// allocate nothing.
    static TILE: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// Run `f` on this thread's tile, grown to at least `len` floats.
fn with_tile<F: FnOnce(&mut [f32])>(len: usize, f: F) {
    TILE.with(|t| {
        let mut v = t.borrow_mut();
        if v.len() < len {
            v.resize(len, 0.0);
        }
        f(&mut v[..len]);
    });
}

/// Raw buffer base shared by phase-3 workers. Units partition the buffer
/// by `(row, column-panel)`: every unit touches only its own row's slots
/// whose column index falls in the unit's panel, and panels are disjoint
/// column sets, so no two units ever alias an element.
#[derive(Clone, Copy)]
struct BufPtr(*mut f32);
// SAFETY: the pointer is only dereferenced inside phase-3 unit kernels,
// which access disjoint `(row, column-panel)` element sets (see BufPtr
// doc); the dispatch scope joins all workers before the buffer borrow
// ends.
unsafe impl Send for BufPtr {}
// SAFETY: same disjoint-partition argument as Send.
unsafe impl Sync for BufPtr {}

/// Four-step batched transform: every contiguous length-`plan.n()` row of
/// `buf`, in place. Forward runs phases rows → sub-transforms → column
/// panels; inverse runs the exact mirror (panels → sub → rows). Called by
/// the engine's size dispatch ([`super::engine::forward_batch_with`] and
/// friends) when `n ≥ cfg.fourstep_threshold` and the plan carries
/// factorization tables.
pub(crate) fn run_fourstep(
    plan: &Plan,
    fs: &FourStep,
    buf: &mut [f32],
    cfg: &EngineConfig,
    disp: Dispatch<'_>,
    forward: bool,
) {
    let n = plan.n();
    assert!(buf.len() % n == 0, "buffer length must be a multiple of plan size");
    debug_assert_eq!(fs.n1() * fs.n2(), n);
    if buf.is_empty() {
        return;
    }
    // One arm for the whole call, same precedence as the direct engine
    // (`force_scalar` > width cap > env > detection): every chunk of
    // every phase runs identical float ops.
    let kern = simd::select_width(cfg.force_scalar, cfg.max_simd_width);
    if forward {
        phase_rows(plan, buf, cfg, disp, true);
        phase_sub(fs, buf, cfg, disp, kern, true);
        phase_panels(fs, buf, cfg, disp, true);
    } else {
        phase_panels(fs, buf, cfg, disp, false);
        phase_sub(fs, buf, cfg, disp, kern, false);
        phase_rows(plan, buf, cfg, disp, false);
    }
}

/// Phase 1 (forward) / phase 3 (inverse): the per-full-row passes shared
/// verbatim with the direct engine — fused bit-reversal + stages
/// `m = 1, 2` forward; fused stage `2, 1` undo + bit-reversal inverse.
fn phase_rows(plan: &Plan, buf: &mut [f32], cfg: &EngineConfig, disp: Dispatch<'_>, forward: bool) {
    let n = plan.n();
    let rows = buf.len() / n;
    let job = move |chunk: &mut [f32], _out: Option<&mut [f32]>| {
        for row in chunk.chunks_exact_mut(n) {
            if forward {
                engine::fused_bitrev_stage12(plan, row);
            } else {
                engine::fused_inverse_stage21(row, n);
                plan.bit_reverse(row);
            }
        }
    };
    let workers = engine::planned_workers(rows, n, cfg);
    if workers <= 1 {
        job(buf, None);
        return;
    }
    let chunk_rows = (rows + workers - 1) / workers;
    engine::dispatch_rows(disp, buf, None, chunk_rows * n, 0, job);
}

/// Phase 2 (both directions): stages `m = 4 .. n2/2`, chunk-local — the
/// whole batch viewed as `rows·n1` contiguous sub-rows of length `n2`,
/// swept with the shared cached `n2` plan's tiled stage kernels. Chunk
/// and tile boundaries never change per-row float ops (rows are
/// independent transforms), so this phase is bitwise thread-count
/// invariant exactly like the direct engine's stage sweep.
fn phase_sub(
    fs: &FourStep,
    buf: &mut [f32],
    cfg: &EngineConfig,
    disp: Dispatch<'_>,
    kern: Kernels,
    forward: bool,
) {
    let sub = fs.sub();
    let n2 = fs.n2();
    let sub_rows = buf.len() / n2;
    let tile_rows = cfg.tile_rows.max(1);
    let job = move |chunk: &mut [f32], _out: Option<&mut [f32]>| {
        for tile in chunk.chunks_mut(tile_rows * n2) {
            if forward {
                engine::forward_stages_tile(sub, tile, kern);
            } else {
                engine::inverse_stages_tile(sub, tile, kern);
            }
        }
    };
    let workers = engine::planned_workers(sub_rows, n2, cfg);
    if workers <= 1 {
        job(buf, None);
        return;
    }
    let chunk_rows = (sub_rows + workers - 1) / workers;
    engine::dispatch_rows(disp, buf, None, chunk_rows * n2, 0, job);
}

/// Phase 3 (forward) / phase 1 (inverse): the `log2 n1` late stages
/// `m = n2·2^t`, run per `(row, panel)` unit through the thread-local
/// transpose tile. Panel 0 is the self-conjugate column pair
/// `{0, n2/2}`; panel `p ≥ 1` covers [`PANEL_PAIRS`] conjugate column
/// pairs `{r, n2 − r}`.
fn phase_panels(fs: &FourStep, buf: &mut [f32], cfg: &EngineConfig, disp: Dispatch<'_>, forward: bool) {
    let (n1, n2) = (fs.n1(), fs.n2());
    let n = n1 * n2;
    let rows = buf.len() / n;
    let pairs = n2 / 2 - 1;
    let npanels = 1 + (pairs + PANEL_PAIRS - 1) / PANEL_PAIRS;
    let units = rows * npanels;
    let workers = engine::planned_workers(units, n / npanels, cfg).max(1);
    let base = BufPtr(buf.as_mut_ptr());
    engine::dispatch_span(disp, workers, move |w| {
        let mut u = w;
        while u < units {
            let row = u / npanels;
            let panel = u % npanels;
            // SAFETY: `row < rows`, so the offset stays inside `buf`;
            // the unit only dereferences slots of this row whose column
            // lies in its own panel's disjoint set (see BufPtr).
            let row_ptr = unsafe { base.0.add(row * n) };
            with_tile(tile_floats(n1), |tile| {
                if panel == 0 {
                    // SAFETY: exclusive access to columns {0, n2/2} of
                    // this row for the duration of the unit.
                    unsafe { run_special(row_ptr, fs, tile, forward) };
                } else {
                    let r0 = (panel - 1) * PANEL_PAIRS + 1;
                    let r1 = (r0 + PANEL_PAIRS).min(n2 / 2);
                    // SAFETY: exclusive access to columns {r, n2 − r}
                    // for r in r0..r1 of this row.
                    unsafe { run_pairs(row_ptr, fs, tile, r0, r1, forward) };
                }
            });
            u += workers;
        }
    });
}

/// Gather–transform–scatter for the conjugate column pairs `r0..r1` of
/// one row's `n1 × n2` view: column `r` in `tile[..n1]`, column `n2 − r`
/// in `tile[n1..]`, all late stages in-tile, then scatter back.
///
/// # Safety
/// `row` must point at one full length-`n1·n2` transform row, with
/// exclusive access to columns `{r, n2 − r}` for every `r` in `r0..r1`
/// for the duration of the call; `tile.len() ≥ tile_floats(fs.n1())` and
/// `1 ≤ r0 ≤ r1 ≤ n2/2`.
unsafe fn run_pairs(
    row: *mut f32,
    fs: &FourStep,
    tile: &mut [f32],
    r0: usize,
    r1: usize,
    forward: bool,
) {
    let (n1, n2) = (fs.n1(), fs.n2());
    for r in r0..r1 {
        // SAFETY: caller grants exclusive access to columns r and n2 - r
        // of this row; tile holds 2·n1 floats.
        unsafe {
            tiling::gather_col_ptr(tile.as_mut_ptr(), row, n1, n2, r);
            tiling::gather_col_ptr(tile.as_mut_ptr().add(n1), row, n1, n2, n2 - r);
        }
        {
            let (a, b) = tile.split_at_mut(n1);
            if forward {
                fwd_pair(fs, a, b, r);
            } else {
                inv_pair(fs, a, b, r);
            }
        }
        // SAFETY: same exclusive-access grant as the gather above.
        unsafe {
            tiling::scatter_col_ptr(tile.as_ptr(), row, n1, n2, r);
            tiling::scatter_col_ptr(tile.as_ptr().add(n1), row, n1, n2, n2 - r);
        }
    }
}

/// Gather–transform–scatter for the self-conjugate special columns
/// `{0, n2/2}` of one row's view (the panel holding the DC/Nyquist-like
/// lanes of every late stage).
///
/// # Safety
/// Same contract as [`run_pairs`] with the column set `{0, n2/2}`.
unsafe fn run_special(row: *mut f32, fs: &FourStep, tile: &mut [f32], forward: bool) {
    let (n1, n2) = (fs.n1(), fs.n2());
    // SAFETY: caller grants exclusive access to columns 0 and n2/2 of
    // this row; tile holds 2·n1 floats.
    unsafe {
        tiling::gather_col_ptr(tile.as_mut_ptr(), row, n1, n2, 0);
        tiling::gather_col_ptr(tile.as_mut_ptr().add(n1), row, n1, n2, n2 / 2);
    }
    {
        let (c0, c1) = tile.split_at_mut(n1);
        if forward {
            fwd_special(fs, c0, c1);
        } else {
            inv_special(fs, c0, c1);
        }
    }
    // SAFETY: same exclusive-access grant as the gather above.
    unsafe {
        tiling::scatter_col_ptr(tile.as_ptr(), row, n1, n2, 0);
        tiling::scatter_col_ptr(tile.as_ptr().add(n1), row, n1, n2, n2 / 2);
    }
}

// ---------------------------------------------------------------------
// In-tile late-stage kernels
//
// Coordinates: late stage t has half-block m_abs = M·n2 with M = 2^t.
// In chunk units (one chunk = one of the n1 rows of the n1 × n2 view, a
// gathered column's index), blocks start at s = b·2M. For a butterfly
// lane k = q·n2 + r of block s the four packed slots land at:
//
//   k       -> col r        chunk s + q
//   m  - k  -> col n2 - r   chunk s + M  - q - 1     (r ≥ 1)
//   m  + k  -> col r        chunk s + M  + q
//   2m - k  -> col n2 - r   chunk s + 2M - q - 1     (r ≥ 1)
//
// so a {r, n2 − r} pair is closed under every late stage. The mirror
// family (lanes k ≡ n2 − r mod n2) swaps the roles of the two columns;
// for r = 0 the −1 chunk offsets vanish and everything stays in column
// 0; for r = n2/2 both columns coincide. Within a stage all families
// and the trivial lanes touch disjoint slots, so their order is free;
// across stages order is ascending (forward) / descending (inverse).
// Twiddles: W_{2m}^{q·n2+r} = A_t[q]·B_t[r] (factorized tables, see
// `plan::FourStep`); the inverse uses the pre-halved inner table so the
// product is directly the half-twiddle the inverse butterfly needs.
// ---------------------------------------------------------------------

/// Forward late stages for one conjugate column pair (`a` = column `r`,
/// `b` = column `n2 − r`, both `n1` chunks long, `1 ≤ r < n2/2`).
// audit: no_alloc
fn fwd_pair(fs: &FourStep, a: &mut [f32], b: &mut [f32], r: usize) {
    let n1 = fs.n1();
    let n2 = fs.n2();
    for t in 0..fs.stages() {
        let m = 1usize << t;
        let outer = fs.stage_outer(t);
        let inner = fs.stage_inner(t);
        let (bra, bia) = inner[r];
        let (brb, bib) = inner[n2 - r];
        let mut s = 0;
        while s < n1 {
            // Lane family k = q·n2 + r: even Re/odd Re in `a`, the
            // conjugate-mirror Im slots in `b`.
            for q in 0..(m / 2).max(1) {
                let (ar, ai) = outer[q];
                let wr = ar * bra - ai * bia;
                let wi = ar * bia + ai * bra;
                let er = a[s + q];
                let ei = b[s + m - q - 1];
                let or_ = a[s + m + q];
                let oi = b[s + 2 * m - q - 1];
                let tr = wr * or_ - wi * oi;
                let ti = wr * oi + wi * or_;
                a[s + q] = er + tr;
                b[s + 2 * m - q - 1] = ei + ti;
                b[s + m - q - 1] = er - tr;
                a[s + m + q] = ti - ei;
            }
            // Mirror family k = (q+1)·n2 − r: roles of a/b swap; the
            // full-range inner table keeps this branch-free.
            for q in 0..m / 2 {
                let (ar, ai) = outer[q];
                let wr = ar * brb - ai * bib;
                let wi = ar * bib + ai * brb;
                let er = b[s + q];
                let ei = a[s + m - q - 1];
                let or_ = b[s + m + q];
                let oi = a[s + 2 * m - q - 1];
                let tr = wr * or_ - wi * oi;
                let ti = wr * oi + wi * or_;
                b[s + q] = er + tr;
                a[s + 2 * m - q - 1] = ei + ti;
                a[s + m - q - 1] = er - tr;
                b[s + m + q] = ti - ei;
            }
            s += 2 * m;
        }
    }
}

/// Exact inverse of [`fwd_pair`]: stages descend, each butterfly is the
/// algebraic inverse with the halving folded into the pre-halved inner
/// twiddle table (and explicit `0.5` on the twiddle-free terms).
// audit: no_alloc
fn inv_pair(fs: &FourStep, a: &mut [f32], b: &mut [f32], r: usize) {
    let n1 = fs.n1();
    let n2 = fs.n2();
    for t in (0..fs.stages()).rev() {
        let m = 1usize << t;
        let outer = fs.stage_outer(t);
        let inner_inv = fs.stage_inner_inv(t);
        let (ira, iia) = inner_inv[r];
        let (irb, iib) = inner_inv[n2 - r];
        let mut s = 0;
        while s < n1 {
            for q in 0..(m / 2).max(1) {
                let (ar, ai) = outer[q];
                let hr = ar * ira - ai * iia;
                let hi = ar * iia + ai * ira;
                let va = a[s + q];
                let vb = b[s + m - q - 1];
                let vc = b[s + 2 * m - q - 1];
                let vd = a[s + m + q];
                a[s + q] = 0.5 * (va + vb);
                b[s + m - q - 1] = 0.5 * (vc - vd);
                a[s + m + q] = (va - vb) * hr + (vc + vd) * hi;
                b[s + 2 * m - q - 1] = (vc + vd) * hr - (va - vb) * hi;
            }
            for q in 0..m / 2 {
                let (ar, ai) = outer[q];
                let hr = ar * irb - ai * iib;
                let hi = ar * iib + ai * irb;
                let va = b[s + q];
                let vb = a[s + m - q - 1];
                let vc = a[s + 2 * m - q - 1];
                let vd = b[s + m + q];
                b[s + q] = 0.5 * (va + vb);
                a[s + m - q - 1] = 0.5 * (vc - vd);
                b[s + m + q] = (va - vb) * hr + (vc + vd) * hi;
                a[s + 2 * m - q - 1] = (vc + vd) * hr - (va - vb) * hi;
            }
            s += 2 * m;
        }
    }
}

/// Forward late stages for the self-conjugate columns (`c0` = column 0,
/// `c1` = column `n2/2`): the per-stage trivial k = 0 lane and
/// sign-flip lane live here, plus the purely-real column-0 family and
/// the self-mirror column-`n2/2` family.
// audit: no_alloc
fn fwd_special(fs: &FourStep, c0: &mut [f32], c1: &mut [f32]) {
    let n1 = fs.n1();
    let n2 = fs.n2();
    for t in 0..fs.stages() {
        let m = 1usize << t;
        let outer = fs.stage_outer(t);
        let inner = fs.stage_inner(t);
        // inner[0] = (1, -0): the product below reduces exactly to the
        // outer factor, so column 0 needs no special-cased twiddle path.
        let (br0, bi0) = inner[0];
        let (brh, bih) = inner[n2 / 2];
        let mut s = 0;
        while s < n1 {
            // k = 0 lane: both packed DCs, trivial twiddle +1.
            let x = c0[s];
            let y = c0[s + m];
            c0[s] = x + y;
            c0[s + m] = x - y;
            // k = m/2 lane (twiddle −i): Re slot unchanged, Im slot is
            // the odd half's Nyquist, sign-flipped. Slot m + m/2 sits in
            // column n2/2 when M = 1, column 0 otherwise.
            if m == 1 {
                c1[s + 1] = -c1[s + 1];
            } else {
                c0[s + m + m / 2] = -c0[s + m + m / 2];
            }
            // Column-0 family k = q·n2, q ≥ 1: r = 0 kills the −1 chunk
            // offsets — all four slots in c0.
            for q in 1..m / 2 {
                let (ar, ai) = outer[q];
                let wr = ar * br0 - ai * bi0;
                let wi = ar * bi0 + ai * br0;
                let er = c0[s + q];
                let ei = c0[s + m - q];
                let or_ = c0[s + m + q];
                let oi = c0[s + 2 * m - q];
                let tr = wr * or_ - wi * oi;
                let ti = wr * oi + wi * or_;
                c0[s + q] = er + tr;
                c0[s + 2 * m - q] = ei + ti;
                c0[s + m - q] = er - tr;
                c0[s + m + q] = ti - ei;
            }
            // Column-n2/2 family k = q·n2 + n2/2: self-mirror — all
            // four slots in c1, with the pair family's −1 offsets.
            for q in 0..m / 2 {
                let (ar, ai) = outer[q];
                let wr = ar * brh - ai * bih;
                let wi = ar * bih + ai * brh;
                let er = c1[s + q];
                let ei = c1[s + m - q - 1];
                let or_ = c1[s + m + q];
                let oi = c1[s + 2 * m - q - 1];
                let tr = wr * or_ - wi * oi;
                let ti = wr * oi + wi * or_;
                c1[s + q] = er + tr;
                c1[s + 2 * m - q - 1] = ei + ti;
                c1[s + m - q - 1] = er - tr;
                c1[s + m + q] = ti - ei;
            }
            s += 2 * m;
        }
    }
}

/// Exact inverse of [`fwd_special`].
// audit: no_alloc
fn inv_special(fs: &FourStep, c0: &mut [f32], c1: &mut [f32]) {
    let n1 = fs.n1();
    let n2 = fs.n2();
    for t in (0..fs.stages()).rev() {
        let m = 1usize << t;
        let outer = fs.stage_outer(t);
        let inner_inv = fs.stage_inner_inv(t);
        let (ir0, ii0) = inner_inv[0];
        let (irh, iih) = inner_inv[n2 / 2];
        let mut s = 0;
        while s < n1 {
            let x = c0[s];
            let y = c0[s + m];
            c0[s] = 0.5 * (x + y);
            c0[s + m] = 0.5 * (x - y);
            // The sign flip is self-inverse (the forward −i lane moved
            // no magnitude between slots).
            if m == 1 {
                c1[s + 1] = -c1[s + 1];
            } else {
                c0[s + m + m / 2] = -c0[s + m + m / 2];
            }
            for q in 1..m / 2 {
                let (ar, ai) = outer[q];
                let hr = ar * ir0 - ai * ii0;
                let hi = ar * ii0 + ai * ir0;
                let va = c0[s + q];
                let vb = c0[s + m - q];
                let vc = c0[s + 2 * m - q];
                let vd = c0[s + m + q];
                c0[s + q] = 0.5 * (va + vb);
                c0[s + m - q] = 0.5 * (vc - vd);
                c0[s + m + q] = (va - vb) * hr + (vc + vd) * hi;
                c0[s + 2 * m - q] = (vc + vd) * hr - (va - vb) * hi;
            }
            for q in 0..m / 2 {
                let (ar, ai) = outer[q];
                let hr = ar * irh - ai * iih;
                let hi = ar * iih + ai * irh;
                let va = c1[s + q];
                let vb = c1[s + m - q - 1];
                let vc = c1[s + 2 * m - q - 1];
                let vd = c1[s + m + q];
                c1[s + q] = 0.5 * (va + vb);
                c1[s + m - q - 1] = 0.5 * (vc - vd);
                c1[s + m + q] = (va - vb) * hr + (vc + vd) * hi;
                c1[s + 2 * m - q - 1] = (vc + vd) * hr - (va - vb) * hi;
            }
            s += 2 * m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{self, EngineConfig};
    use super::super::plan::cached;
    use super::*;

    fn rand_rows(n: usize, rows: usize, seed: u64) -> Vec<f32> {
        let mut state = seed | 1;
        (0..n * rows)
            .map(|_| {
                state = state
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    /// Always take the four-step tier (every plan ≥ FOURSTEP_MIN_N has
    /// tables, so threshold 1 forces the tier for those sizes).
    fn four_cfg() -> EngineConfig {
        let mut c = EngineConfig::new();
        c.fourstep_threshold = 1;
        c
    }

    /// Never take the four-step tier.
    fn direct_cfg() -> EngineConfig {
        let mut c = EngineConfig::new();
        c.fourstep_threshold = usize::MAX;
        c
    }

    #[test]
    fn fourstep_matches_direct_spectrum_within_tolerance() {
        // Covers the square split (1024 = 32×32) and the rectangular
        // one (2048 = 32×64). Only the fused twiddle product may differ
        // from the direct path (~1 ulp per late stage).
        for n in [1024usize, 2048] {
            let plan = cached(n);
            assert!(plan.fourstep_lazy().is_some());
            let x = rand_rows(n, 3, 0xF0F0 + n as u64);
            let mut four = x.clone();
            engine::forward_batch_with(&plan, &mut four, &four_cfg());
            let mut direct = x.clone();
            engine::forward_batch_with(&plan, &mut direct, &direct_cfg());
            assert_ne!(four, x, "four-step must transform the buffer");
            for i in 0..four.len() {
                let tol = 1e-4 * (1.0 + direct[i].abs());
                assert!(
                    (four[i] - direct[i]).abs() <= tol,
                    "n={n} i={i}: four-step {} vs direct {}",
                    four[i],
                    direct[i]
                );
            }
        }
    }

    #[test]
    fn fourstep_roundtrip_recovers_input() {
        for n in [1024usize, 2048] {
            let plan = cached(n);
            let x = rand_rows(n, 2, 42 + n as u64);
            let mut buf = x.clone();
            engine::forward_batch_with(&plan, &mut buf, &four_cfg());
            engine::inverse_batch_with(&plan, &mut buf, &four_cfg());
            for i in 0..buf.len() {
                assert!((buf[i] - x[i]).abs() < 1e-3, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn mixed_tier_roundtrip_recovers_input() {
        // Forward on the four-step tier, inverse on the direct tier (and
        // vice versa): both tiers must realize the *same* transform, not
        // merely be self-consistent.
        let n = 2048usize;
        let plan = cached(n);
        let x = rand_rows(n, 2, 7);
        let mut buf = x.clone();
        engine::forward_batch_with(&plan, &mut buf, &four_cfg());
        engine::inverse_batch_with(&plan, &mut buf, &direct_cfg());
        for i in 0..buf.len() {
            assert!((buf[i] - x[i]).abs() < 1e-3, "four->direct i={i}");
        }
        let mut buf = x.clone();
        engine::forward_batch_with(&plan, &mut buf, &direct_cfg());
        engine::inverse_batch_with(&plan, &mut buf, &four_cfg());
        for i in 0..buf.len() {
            assert!((buf[i] - x[i]).abs() < 1e-3, "direct->four i={i}");
        }
    }

    #[test]
    fn dc_term_is_row_sum() {
        let n = 1024usize;
        let plan = cached(n);
        let x = rand_rows(n, 2, 99);
        let mut buf = x.clone();
        engine::forward_batch_with(&plan, &mut buf, &four_cfg());
        for row in 0..2 {
            let sum: f32 = x[row * n..(row + 1) * n].iter().sum();
            assert!(
                (buf[row * n] - sum).abs() < 1e-2 * (1.0 + sum.abs()),
                "row={row}: {} vs {}",
                buf[row * n],
                sum
            );
        }
    }

    #[test]
    fn forced_scalar_fourstep_is_bitwise_deterministic_across_thread_counts() {
        // Thresholds lowered so every phase actually fans out; scoped
        // dispatch keeps the comparison off the global pool. The panel
        // phase's unit striding and the row/sub chunking must never
        // change per-element float ops.
        let n = 2048usize;
        let plan = cached(n);
        let x = rand_rows(n, 8, 11);
        let run = |threads: usize, forward: bool, buf: &mut [f32]| {
            let mut c = EngineConfig::forced_scalar();
            c.fourstep_threshold = 1;
            c.par_min_rows = 1;
            c.par_min_elems = 1;
            c.par_chunk_elems = 1;
            c.max_threads = threads;
            if forward {
                engine::forward_batch_scoped(&plan, buf, &c);
            } else {
                engine::inverse_batch_scoped(&plan, buf, &c);
            }
        };
        let mut one = x.clone();
        run(1, true, &mut one);
        let mut four = x.clone();
        run(4, true, &mut four);
        assert_eq!(one, four, "forward must not depend on thread count");
        run(1, false, &mut one);
        run(4, false, &mut four);
        assert_eq!(one, four, "inverse must not depend on thread count");
        for i in 0..one.len() {
            assert!((one[i] - x[i]).abs() < 1e-3, "threaded roundtrip i={i}");
        }
    }

    #[test]
    fn width_cap_matches_forced_scalar_bitwise() {
        // max_simd_width 1..=3 must select the legacy scalar loops —
        // bit-identical to force_scalar on every phase of the tier.
        let n = 1024usize;
        let plan = cached(n);
        let x = rand_rows(n, 2, 23);
        let mut capped = x.clone();
        let mut c = four_cfg();
        c.max_simd_width = 2;
        engine::forward_batch_with(&plan, &mut capped, &c);
        let mut scalar = x.clone();
        let mut cs = four_cfg();
        cs.force_scalar = true;
        engine::forward_batch_with(&plan, &mut scalar, &cs);
        assert_eq!(capped, scalar);
    }
}
