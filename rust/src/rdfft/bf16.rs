//! Software bfloat16 and the bf16 rdFFT path.
//!
//! The paper emphasizes that FFTW/cuFFT (and `torch.fft.*`) do not support
//! bfloat16, while modern training runs in bf16 — rdFFT supports it
//! natively. We mirror the hardware practice: storage is bf16 (2 bytes),
//! butterfly arithmetic runs in f32 (exactly what TPU/VPU and CUDA
//! `__nv_bfloat16` FMA paths do), results round back to bf16 per element.
//!
//! The butterfly **math** routes through the same width-4 lane kernels as
//! the f32 engine ([`super::simd`]): four 4-groups' values are widened to
//! f32 lane arrays, run one quad butterfly ([`super::simd::fwd_quad_arrays`] /
//! [`super::simd::inv_quad_arrays`]), and round back per element — so the
//! AVX2+FMA arm fuses the complex multiplies here too, while the
//! forced-scalar arm reproduces the legacy per-element loop bit-for-bit
//! (conversion order and rounding are unchanged on every arm; only FMA
//! contraction inside the f32 math can differ, far below bf16's own
//! rounding).

use super::plan::Plan;
use super::simd::{self, Kernels};

/// bfloat16: the top 16 bits of an IEEE-754 f32.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);

    /// Round-to-nearest-even conversion from f32 (the conversion hardware
    /// implements; simple truncation loses ~0.5 bit of accuracy).
    #[inline]
    pub fn from_f32(v: f32) -> Self {
        let bits = v.to_bits();
        if v.is_nan() {
            // quiet NaN, preserving sign
            return Bf16(((bits >> 16) | 0x0040) as u16);
        }
        // Round-half-to-even via the standard bias trick: add 0x7FFF plus
        // the LSB of the truncated result, then truncate.
        let bias = 0x7FFFu32 + ((bits >> 16) & 1);
        Bf16(((bits + bias) >> 16) as u16)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

impl From<f32> for Bf16 {
    fn from(v: f32) -> Self {
        Bf16::from_f32(v)
    }
}

impl From<Bf16> for f32 {
    fn from(v: Bf16) -> f32 {
        v.to_f32()
    }
}

/// In-place forward rdFFT over a bf16 buffer (storage bf16, math f32).
pub fn rdfft_inplace_bf16(plan: &Plan, buf: &mut [Bf16]) {
    assert_eq!(buf.len(), plan.n());
    let kern = simd::active();
    for &(i, j) in plan.swaps() {
        buf.swap(i as usize, j as usize);
    }
    let n = plan.n();
    let mut m = 1usize;
    while m < n {
        let tw = plan.stage_twiddles(m);
        let two_m = 2 * m;
        let mut s = 0usize;
        while s < n {
            let e = buf[s].to_f32();
            let o = buf[s + m].to_f32();
            buf[s] = Bf16::from_f32(e + o);
            buf[s + m] = Bf16::from_f32(e - o);
            if m >= 2 {
                let idx = s + m + m / 2;
                buf[idx] = Bf16::from_f32(-buf[idx].to_f32());
            }
            let half = m / 2;
            let mut k = 1usize;
            // Quad groups through the lane kernels (widen → quad → round).
            if kern != Kernels::LegacyScalar {
                while k + 4 <= half {
                    let mut er = [0.0f32; 4];
                    let mut ei = [0.0f32; 4];
                    let mut or_ = [0.0f32; 4];
                    let mut oi = [0.0f32; 4];
                    let mut wr4 = [0.0f32; 4];
                    let mut wi4 = [0.0f32; 4];
                    for l in 0..4 {
                        er[l] = buf[s + k + l].to_f32();
                        ei[l] = buf[s + m - k - l].to_f32();
                        or_[l] = buf[s + m + k + l].to_f32();
                        oi[l] = buf[s + two_m - k - l].to_f32();
                        let (wr, wi) = tw[k - 1 + l];
                        wr4[l] = wr;
                        wi4[l] = wi;
                    }
                    let (rk, ik, rm, im) = simd::fwd_quad_arrays(kern, er, ei, or_, oi, wr4, wi4);
                    for l in 0..4 {
                        buf[s + k + l] = Bf16::from_f32(rk[l]);
                        buf[s + two_m - k - l] = Bf16::from_f32(ik[l]);
                        buf[s + m - k - l] = Bf16::from_f32(rm[l]);
                        buf[s + m + k + l] = Bf16::from_f32(im[l]);
                    }
                    k += 4;
                }
            }
            // Scalar tail (and the whole sweep on the forced-scalar arm).
            while k < half {
                let (wr, wi) = tw[k - 1];
                let (er, ei) = (buf[s + k].to_f32(), buf[s + m - k].to_f32());
                let (or_, oi) = (buf[s + m + k].to_f32(), buf[s + two_m - k].to_f32());
                let tr = wr * or_ - wi * oi;
                let ti = wr * oi + wi * or_;
                buf[s + k] = Bf16::from_f32(er + tr);
                buf[s + two_m - k] = Bf16::from_f32(ei + ti);
                buf[s + m - k] = Bf16::from_f32(er - tr);
                buf[s + m + k] = Bf16::from_f32(ti - ei);
                k += 1;
            }
            s += two_m;
        }
        m = two_m;
    }
}

/// In-place inverse rdFFT over a bf16 buffer.
pub fn irdfft_inplace_bf16(plan: &Plan, buf: &mut [Bf16]) {
    assert_eq!(buf.len(), plan.n());
    let kern = simd::active();
    let n = plan.n();
    let mut m = n / 2;
    while m >= 1 {
        let tw = plan.stage_twiddles(m);
        let two_m = 2 * m;
        let mut s = 0usize;
        while s < n {
            let a = buf[s].to_f32();
            let b = buf[s + m].to_f32();
            buf[s] = Bf16::from_f32(0.5 * (a + b));
            buf[s + m] = Bf16::from_f32(0.5 * (a - b));
            if m >= 2 {
                let idx = s + m + m / 2;
                buf[idx] = Bf16::from_f32(-buf[idx].to_f32());
            }
            let half = m / 2;
            let mut k = 1usize;
            if kern != Kernels::LegacyScalar {
                while k + 4 <= half {
                    let mut av = [0.0f32; 4];
                    let mut bv = [0.0f32; 4];
                    let mut cv = [0.0f32; 4];
                    let mut dv = [0.0f32; 4];
                    let mut wr4 = [0.0f32; 4];
                    let mut wi4 = [0.0f32; 4];
                    for l in 0..4 {
                        av[l] = buf[s + k + l].to_f32();
                        bv[l] = buf[s + m - k - l].to_f32();
                        cv[l] = buf[s + two_m - k - l].to_f32();
                        dv[l] = buf[s + m + k + l].to_f32();
                        let (wr, wi) = tw[k - 1 + l];
                        wr4[l] = wr;
                        wi4[l] = wi;
                    }
                    let (er, ei, or_, oi) = simd::inv_quad_arrays(kern, av, bv, cv, dv, wr4, wi4);
                    for l in 0..4 {
                        buf[s + k + l] = Bf16::from_f32(er[l]);
                        buf[s + m - k - l] = Bf16::from_f32(ei[l]);
                        buf[s + m + k + l] = Bf16::from_f32(or_[l]);
                        buf[s + two_m - k - l] = Bf16::from_f32(oi[l]);
                    }
                    k += 4;
                }
            }
            while k < half {
                let (wr, wi) = tw[k - 1];
                let a = buf[s + k].to_f32();
                let b = buf[s + m - k].to_f32();
                let c = buf[s + two_m - k].to_f32();
                let d = buf[s + m + k].to_f32();
                let er = 0.5 * (a + b);
                let tr = 0.5 * (a - b);
                let ti = 0.5 * (c + d);
                let ei = 0.5 * (c - d);
                let or_ = tr * wr + ti * wi;
                let oi = ti * wr - tr * wi;
                buf[s + k] = Bf16::from_f32(er);
                buf[s + m - k] = Bf16::from_f32(ei);
                buf[s + m + k] = Bf16::from_f32(or_);
                buf[s + two_m - k] = Bf16::from_f32(oi);
                k += 1;
            }
            s += two_m;
        }
        m /= 2;
    }
    for &(i, j) in plan.swaps() {
        buf.swap(i as usize, j as usize);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversion_roundtrip_exact_for_bf16_values() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -3.25, 1e20, -1e-20] {
            let b = Bf16::from_f32(v);
            let back = b.to_f32();
            // values representable in bf16 roundtrip exactly
            assert_eq!(Bf16::from_f32(back), b);
        }
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between bf16(1.0) and the next
        // value; round-half-even keeps 1.0 (even mantissa).
        let half_up = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(half_up).to_f32(), 1.0);
        // slightly above halfway rounds up
        let above = f32::from_bits(0x3F80_8001);
        assert!(Bf16::from_f32(above).to_f32() > 1.0);
    }

    #[test]
    fn nan_and_inf_survive() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
    }

    #[test]
    fn bf16_transform_tracks_f32_transform() {
        let n = 256;
        let plan = Plan::new(n);
        let x: Vec<f32> = (0..n).map(|i| ((i * 31 + 7) % 64) as f32 / 32.0 - 1.0).collect();
        let mut f32_buf = x.clone();
        super::super::forward::rdfft_inplace(&plan, &mut f32_buf);
        let mut bf_buf: Vec<Bf16> = x.iter().map(|&v| Bf16::from_f32(v)).collect();
        rdfft_inplace_bf16(&plan, &mut bf_buf);
        let scale = x.iter().map(|v| v.abs()).fold(0.0f32, f32::max) * n as f32;
        for i in 0..n {
            let err = (bf_buf[i].to_f32() - f32_buf[i]).abs();
            assert!(err < 0.02 * scale, "i={i}: {} vs {}", bf_buf[i].to_f32(), f32_buf[i]);
        }
    }

    #[test]
    fn bf16_roundtrip_within_bf16_tolerance() {
        let n = 512;
        let plan = Plan::new(n);
        let x: Vec<f32> = (0..n).map(|i| ((i * 13 + 3) % 41) as f32 / 20.0 - 1.0).collect();
        let mut buf: Vec<Bf16> = x.iter().map(|&v| Bf16::from_f32(v)).collect();
        rdfft_inplace_bf16(&plan, &mut buf);
        irdfft_inplace_bf16(&plan, &mut buf);
        for i in 0..n {
            // log2(512)=9 stages of bf16 rounding each way: tolerance ~ 5%
            assert!(
                (buf[i].to_f32() - x[i]).abs() < 0.05 * (1.0 + x[i].abs()),
                "i={i}: {} vs {}",
                buf[i].to_f32(),
                x[i]
            );
        }
    }
}
