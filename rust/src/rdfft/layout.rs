//! Packed-spectrum layout helpers.
//!
//! The packed layout stores a conjugate-symmetric length-`n` spectrum in
//! `n` reals: `Re(y_k)` at `k`, `Im(y_k)` at `n-k` (`1 ≤ k < n/2`), plus the
//! real DC / Nyquist terms at `0` / `n/2`. The paper calls out (Limitations)
//! that *explicit* complex access requires decoding; these helpers are that
//! decode/encode logic, plus the in-place operations (conjugation, reads)
//! that do **not** require leaving the packed form.

/// Read the complex coefficient `y_k` (`0 ≤ k ≤ n/2`) from a packed buffer.
#[inline]
pub fn get(buf: &[f32], k: usize) -> (f32, f32) {
    let n = buf.len();
    debug_assert!(k <= n / 2);
    if k == 0 {
        (buf[0], 0.0)
    } else if k == n / 2 {
        (buf[n / 2], 0.0)
    } else {
        (buf[k], buf[n - k])
    }
}

/// Write the complex coefficient `y_k` into a packed buffer. Panics (debug)
/// if asked to write a non-zero imaginary part into the DC/Nyquist slots.
#[inline]
pub fn set(buf: &mut [f32], k: usize, re: f32, im: f32) {
    let n = buf.len();
    debug_assert!(k <= n / 2);
    if k == 0 || k == n / 2 {
        debug_assert!(im == 0.0, "DC/Nyquist coefficients are real");
        buf[k] = re;
    } else {
        buf[k] = re;
        buf[n - k] = im;
    }
}

/// Conjugate a packed spectrum in place: negate the imaginary half
/// (indices `n/2+1 .. n-1`). This is how Eq. 5's `conj(FFT(·))` is realized
/// with zero allocation.
#[inline]
pub fn conj_inplace(buf: &mut [f32]) {
    let n = buf.len();
    for v in &mut buf[n / 2 + 1..] {
        *v = -*v;
    }
}

/// Decode a packed spectrum into the full complex spectrum
/// (length `n` of `(re, im)`), reconstructing the conjugate half.
/// **Allocates** — only for tests/diagnostics, never on the training path.
pub fn unpack_full(buf: &[f32]) -> Vec<(f32, f32)> {
    let n = buf.len();
    let mut out = vec![(0.0f32, 0.0f32); n];
    out[0] = (buf[0], 0.0);
    out[n / 2] = (buf[n / 2], 0.0);
    for k in 1..n / 2 {
        let (re, im) = (buf[k], buf[n - k]);
        out[k] = (re, im);
        out[n - k] = (re, -im);
    }
    out
}

/// Decode a packed spectrum into rFFT form: `n/2 + 1` complex values
/// occupying `n + 2` reals — the dimension-mismatched format the paper's
/// baselines use. **Allocates.**
pub fn unpack_rfft(buf: &[f32]) -> Vec<(f32, f32)> {
    let n = buf.len();
    let mut out = Vec::with_capacity(n / 2 + 1);
    for k in 0..=n / 2 {
        out.push(get(buf, k));
    }
    out
}

/// Encode rFFT-format complex coefficients (`n/2+1` values) into a packed
/// buffer of length `n`. Inverse of [`unpack_rfft`]. The imaginary parts of
/// the DC and Nyquist coefficients must be (numerically) zero.
pub fn pack_from_rfft(coeffs: &[(f32, f32)], out: &mut [f32]) {
    let n = out.len();
    assert_eq!(coeffs.len(), n / 2 + 1);
    out[0] = coeffs[0].0;
    out[n / 2] = coeffs[n / 2].0;
    for k in 1..n / 2 {
        out[k] = coeffs[k].0;
        out[n - k] = coeffs[k].1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_roundtrip() {
        let mut buf = vec![0.0f32; 8];
        set(&mut buf, 0, 5.0, 0.0);
        set(&mut buf, 4, -3.0, 0.0);
        set(&mut buf, 1, 1.5, -2.5);
        set(&mut buf, 3, 0.25, 0.75);
        assert_eq!(get(&buf, 0), (5.0, 0.0));
        assert_eq!(get(&buf, 4), (-3.0, 0.0));
        assert_eq!(get(&buf, 1), (1.5, -2.5));
        assert_eq!(get(&buf, 3), (0.25, 0.75));
        // physical layout: im(y_1) at index 7, im(y_3) at index 5
        assert_eq!(buf[7], -2.5);
        assert_eq!(buf[5], 0.75);
    }

    #[test]
    fn conj_negates_only_imag_half() {
        let mut buf: Vec<f32> = (1..=8).map(|i| i as f32).collect();
        conj_inplace(&mut buf);
        assert_eq!(buf, vec![1.0, 2.0, 3.0, 4.0, 5.0, -6.0, -7.0, -8.0]);
        // double conjugation is identity
        conj_inplace(&mut buf);
        assert_eq!(buf[5], 6.0);
    }

    #[test]
    fn unpack_full_reconstructs_hermitian_half() {
        let buf = vec![10.0f32, -2.0, -2.0, 2.0]; // packed FFT([1,2,3,4])
        let full = unpack_full(&buf);
        assert_eq!(full[0], (10.0, 0.0));
        assert_eq!(full[1], (-2.0, 2.0));
        assert_eq!(full[2], (-2.0, 0.0));
        assert_eq!(full[3], (-2.0, -2.0)); // conj of full[1]
    }

    #[test]
    fn rfft_pack_unpack_roundtrip() {
        let buf = vec![10.0f32, -2.0, -2.0, 2.0];
        let rf = unpack_rfft(&buf);
        assert_eq!(rf.len(), 3); // n/2+1 complex == n+2 reals
        let mut back = vec![0.0f32; 4];
        pack_from_rfft(&rf, &mut back);
        assert_eq!(back, buf);
    }
}
