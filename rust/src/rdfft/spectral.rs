//! Packed-domain elementwise spectral operations.
//!
//! The circulant layer (Eq. 4) multiplies two spectra elementwise, and its
//! backward pass (Eq. 5) multiplies by a *conjugated* spectrum. Because
//! `conj(A·B) = conj(A)·conj(B)`, the product of two conjugate-symmetric
//! spectra is itself conjugate-symmetric (§4.2 "Symmetry in Circulant
//! Matrix based Training"), so all of these ops stay inside the packed
//! layout and run fully in place on real buffers.

/// `a ⊙= b` — elementwise complex product of two packed spectra, written
/// into `a`. Zero allocation.
#[inline]
pub fn mul_inplace(a: &mut [f32], b: &[f32]) {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    a[0] *= b[0];
    a[n / 2] *= b[n / 2];
    for k in 1..n / 2 {
        let (ar, ai) = (a[k], a[n - k]);
        let (br, bi) = (b[k], b[n - k]);
        a[k] = ar * br - ai * bi;
        a[n - k] = ar * bi + ai * br;
    }
}

/// `a = conj(a) ⊙ b` — the backward-pass product of Eq. 5, fused so the
/// conjugation costs nothing (no separate negation pass, no allocation).
#[inline]
pub fn conj_mul_inplace(a: &mut [f32], b: &[f32]) {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    a[0] *= b[0];
    a[n / 2] *= b[n / 2];
    for k in 1..n / 2 {
        let (ar, ai) = (a[k], a[n - k]);
        let (br, bi) = (b[k], b[n - k]);
        // (ar - i·ai)(br + i·bi)
        a[k] = ar * br + ai * bi;
        a[n - k] = ar * bi - ai * br;
    }
}

/// `a ⊙= conj(b)` — elementwise product with the conjugate of `b`
/// (equivalently `conj(b) ⊙ a`): the Eq. 5 product when the conjugated
/// factor is the *other* operand. Zero allocation.
#[inline]
pub fn mul_conjb_inplace(a: &mut [f32], b: &[f32]) {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    a[0] *= b[0];
    a[n / 2] *= b[n / 2];
    for k in 1..n / 2 {
        let (ar, ai) = (a[k], a[n - k]);
        let (br, bi) = (b[k], b[n - k]);
        // (ar + i·ai)(br - i·bi)
        a[k] = ar * br + ai * bi;
        a[n - k] = ai * br - ar * bi;
    }
}

/// `acc += a ⊙ b` — multiply-accumulate of packed spectra, used by the
/// block-circulant layer to sum block products in the frequency domain
/// before a single inverse transform. Zero allocation.
#[inline]
pub fn mul_acc(acc: &mut [f32], a: &[f32], b: &[f32]) {
    let n = acc.len();
    debug_assert_eq!(n, a.len());
    debug_assert_eq!(n, b.len());
    acc[0] += a[0] * b[0];
    acc[n / 2] += a[n / 2] * b[n / 2];
    for k in 1..n / 2 {
        let (ar, ai) = (a[k], a[n - k]);
        let (br, bi) = (b[k], b[n - k]);
        acc[k] += ar * br - ai * bi;
        acc[n - k] += ar * bi + ai * br;
    }
}

/// `acc += conj(a) ⊙ b` — multiply-accumulate with conjugation (backward
/// pass of the block-circulant layer). Zero allocation.
#[inline]
pub fn conj_mul_acc(acc: &mut [f32], a: &[f32], b: &[f32]) {
    let n = acc.len();
    debug_assert_eq!(n, a.len());
    debug_assert_eq!(n, b.len());
    acc[0] += a[0] * b[0];
    acc[n / 2] += a[n / 2] * b[n / 2];
    for k in 1..n / 2 {
        let (ar, ai) = (a[k], a[n - k]);
        let (br, bi) = (b[k], b[n - k]);
        acc[k] += ar * br + ai * bi;
        acc[n - k] += ar * bi - ai * br;
    }
}

/// Scale a packed spectrum (or any real buffer) in place.
#[inline]
pub fn scale_inplace(a: &mut [f32], s: f32) {
    for v in a {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rdfft::layout::{get, unpack_full};

    fn cmul(a: (f32, f32), b: (f32, f32)) -> (f32, f32) {
        (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
    }

    fn packed(vals: &[(f32, f32)]) -> Vec<f32> {
        // vals = y_0 .. y_{n/2}
        let n = (vals.len() - 1) * 2;
        let mut buf = vec![0.0f32; n];
        crate::rdfft::layout::pack_from_rfft(vals, &mut buf);
        buf
    }

    #[test]
    fn mul_matches_complex_multiplication() {
        let a = packed(&[(2.0, 0.0), (1.0, -3.0), (0.5, 2.0), (-1.0, 0.0)]);
        let b = packed(&[(-1.0, 0.0), (2.0, 1.0), (0.0, -1.0), (4.0, 0.0)]);
        let mut out = a.clone();
        mul_inplace(&mut out, &b);
        for k in 0..=3 {
            let expect = cmul(get(&a, k), get(&b, k));
            let got = get(&out, k);
            assert!((got.0 - expect.0).abs() < 1e-6, "k={k}");
            assert!((got.1 - expect.1).abs() < 1e-6, "k={k}");
        }
    }

    #[test]
    fn conj_mul_matches_conjugated_multiplication() {
        let a = packed(&[(2.0, 0.0), (1.0, -3.0), (0.5, 2.0), (-1.0, 0.0)]);
        let b = packed(&[(-1.0, 0.0), (2.0, 1.0), (0.0, -1.0), (4.0, 0.0)]);
        let mut out = a.clone();
        conj_mul_inplace(&mut out, &b);
        for k in 0..=3 {
            let (ar, ai) = get(&a, k);
            let expect = cmul((ar, -ai), get(&b, k));
            let got = get(&out, k);
            assert!((got.0 - expect.0).abs() < 1e-6, "k={k}");
            assert!((got.1 - expect.1).abs() < 1e-6, "k={k}");
        }
    }

    #[test]
    fn product_preserves_hermitian_symmetry() {
        let a = packed(&[(1.0, 0.0), (2.0, -1.0), (3.0, 0.5), (0.0, 0.0)]);
        let b = packed(&[(0.5, 0.0), (-1.0, 2.0), (1.0, 1.0), (2.0, 0.0)]);
        let mut out = a.clone();
        mul_inplace(&mut out, &b);
        let full = unpack_full(&out);
        let n = full.len();
        for k in 1..n / 2 {
            assert!((full[k].0 - full[n - k].0).abs() < 1e-6);
            assert!((full[k].1 + full[n - k].1).abs() < 1e-6);
        }
    }

    #[test]
    fn mul_acc_accumulates() {
        let a = packed(&[(1.0, 0.0), (1.0, 1.0), (2.0, 0.0)]);
        let b = packed(&[(2.0, 0.0), (3.0, -1.0), (1.0, 0.0)]);
        let mut acc = vec![0.0f32; 4];
        mul_acc(&mut acc, &a, &b);
        mul_acc(&mut acc, &a, &b);
        let mut once = a.clone();
        mul_inplace(&mut once, &b);
        for i in 0..4 {
            assert!((acc[i] - 2.0 * once[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn conj_mul_acc_matches_conj_mul() {
        let a = packed(&[(1.0, 0.0), (1.0, 1.0), (2.0, 0.0)]);
        let b = packed(&[(2.0, 0.0), (3.0, -1.0), (1.0, 0.0)]);
        let mut acc = vec![0.0f32; 4];
        conj_mul_acc(&mut acc, &a, &b);
        let mut direct = a.clone();
        conj_mul_inplace(&mut direct, &b);
        for i in 0..4 {
            assert!((acc[i] - direct[i]).abs() < 1e-6);
        }
    }
}
