//! Packed-domain elementwise spectral operations.
//!
//! The circulant layer (Eq. 4) multiplies two spectra elementwise, and its
//! backward pass (Eq. 5) multiplies by a *conjugated* spectrum. Because
//! `conj(A·B) = conj(A)·conj(B)`, the product of two conjugate-symmetric
//! spectra is itself conjugate-symmetric (§4.2 "Symmetry in Circulant
//! Matrix based Training"), so all of these ops stay inside the packed
//! layout and run fully in place on real buffers.

/// `a ⊙= b` — elementwise complex product of two packed spectra, written
/// into `a`. Zero allocation.
// audit: no_alloc
#[inline]
pub fn mul_inplace(a: &mut [f32], b: &[f32]) {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    a[0] *= b[0];
    a[n / 2] *= b[n / 2];
    for k in 1..n / 2 {
        let (ar, ai) = (a[k], a[n - k]);
        let (br, bi) = (b[k], b[n - k]);
        a[k] = ar * br - ai * bi;
        a[n - k] = ar * bi + ai * br;
    }
}

/// `a = conj(a) ⊙ b` — the backward-pass product of Eq. 5, fused so the
/// conjugation costs nothing (no separate negation pass, no allocation).
// audit: no_alloc
#[inline]
pub fn conj_mul_inplace(a: &mut [f32], b: &[f32]) {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    a[0] *= b[0];
    a[n / 2] *= b[n / 2];
    for k in 1..n / 2 {
        let (ar, ai) = (a[k], a[n - k]);
        let (br, bi) = (b[k], b[n - k]);
        // (ar - i·ai)(br + i·bi)
        a[k] = ar * br + ai * bi;
        a[n - k] = ar * bi - ai * br;
    }
}

/// `a ⊙= conj(b)` — elementwise product with the conjugate of `b`
/// (equivalently `conj(b) ⊙ a`): the Eq. 5 product when the conjugated
/// factor is the *other* operand. Zero allocation.
// audit: no_alloc
#[inline]
pub fn mul_conjb_inplace(a: &mut [f32], b: &[f32]) {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    a[0] *= b[0];
    a[n / 2] *= b[n / 2];
    for k in 1..n / 2 {
        let (ar, ai) = (a[k], a[n - k]);
        let (br, bi) = (b[k], b[n - k]);
        // (ar + i·ai)(br - i·bi)
        a[k] = ar * br + ai * bi;
        a[n - k] = ai * br - ar * bi;
    }
}

/// `acc += a ⊙ b` — multiply-accumulate of packed spectra, used by the
/// block-circulant layer to sum block products in the frequency domain
/// before a single inverse transform. Zero allocation.
// audit: no_alloc
#[inline]
pub fn mul_acc(acc: &mut [f32], a: &[f32], b: &[f32]) {
    let n = acc.len();
    debug_assert_eq!(n, a.len());
    debug_assert_eq!(n, b.len());
    acc[0] += a[0] * b[0];
    acc[n / 2] += a[n / 2] * b[n / 2];
    for k in 1..n / 2 {
        let (ar, ai) = (a[k], a[n - k]);
        let (br, bi) = (b[k], b[n - k]);
        acc[k] += ar * br - ai * bi;
        acc[n - k] += ar * bi + ai * br;
    }
}

/// `acc += conj(a) ⊙ b` — multiply-accumulate with conjugation (backward
/// pass of the block-circulant layer). Zero allocation.
// audit: no_alloc
#[inline]
pub fn conj_mul_acc(acc: &mut [f32], a: &[f32], b: &[f32]) {
    let n = acc.len();
    debug_assert_eq!(n, a.len());
    debug_assert_eq!(n, b.len());
    acc[0] += a[0] * b[0];
    acc[n / 2] += a[n / 2] * b[n / 2];
    for k in 1..n / 2 {
        let (ar, ai) = (a[k], a[n - k]);
        let (br, bi) = (b[k], b[n - k]);
        acc[k] += ar * br + ai * bi;
        acc[n - k] += ar * bi - ai * br;
    }
}

/// Scale a packed spectrum (or any real buffer) in place.
// audit: no_alloc
#[inline]
pub fn scale_inplace(a: &mut [f32], s: f32) {
    for v in a {
        *v *= s;
    }
}

// ---------------------------------------------------------------------
// Row-tile kernels (the product stage of the fused circulant pipeline)
// ---------------------------------------------------------------------
//
// The row kernels are where the packed products run hot (one shared
// spectrum against a cache-resident tile of row spectra), so they
// dispatch onto the SIMD lane kernels ([`crate::rdfft::simd`]): width-4
// quads over the `k`-ascending / `(n−k)`-descending streams, scalar
// tails. The per-row functions above stay pure legacy scalar — they are
// the differential oracle the `force_scalar` arm must reproduce
// bit-for-bit.

use super::simd::{self, Kernels};

/// `row ⊙= spec` for every contiguous length-`spec.len()` row of `tile` —
/// the tile-level product stage of the fused circulant pipeline
/// ([`crate::rdfft::engine::circulant_apply_batch`]), auto-dispatched onto
/// the active SIMD arm. Zero allocation.
#[inline]
pub fn mul_rows_inplace(tile: &mut [f32], spec: &[f32]) {
    mul_rows_with(simd::active(), tile, spec);
}

/// `row ⊙= conj(spec)` for every row of `tile` — the transpose/backward
/// (Eq. 5) product stage of the fused pipeline, auto-dispatched. Zero
/// allocation.
#[inline]
pub fn mul_conjb_rows_inplace(tile: &mut [f32], spec: &[f32]) {
    mul_conjb_rows_with(simd::active(), tile, spec);
}

/// [`mul_rows_inplace`] on an explicit kernel arm (the engine resolves
/// the arm once per batch call from `EngineConfig::force_scalar`).
// audit: no_alloc
#[inline]
pub fn mul_rows_with(kern: Kernels, tile: &mut [f32], spec: &[f32]) {
    let n = spec.len();
    debug_assert!(n >= 2 && tile.len() % n == 0);
    for row in tile.chunks_exact_mut(n) {
        simd::mul_inplace_with(kern, row, spec);
    }
}

/// [`mul_conjb_rows_inplace`] on an explicit kernel arm.
// audit: no_alloc
#[inline]
pub fn mul_conjb_rows_with(kern: Kernels, tile: &mut [f32], spec: &[f32]) {
    let n = spec.len();
    debug_assert!(n >= 2 && tile.len() % n == 0);
    for row in tile.chunks_exact_mut(n) {
        simd::mul_conjb_inplace_with(kern, row, spec);
    }
}

/// [`mul_acc`] on an explicit kernel arm (the block sweeps' product
/// stage; `Kernels::LegacyScalar` is exactly [`mul_acc`]).
// audit: no_alloc
#[inline]
pub fn mul_acc_with(kern: Kernels, acc: &mut [f32], a: &[f32], b: &[f32]) {
    simd::mul_acc_with(kern, acc, a, b);
}

/// [`conj_mul_acc`] on an explicit kernel arm.
// audit: no_alloc
#[inline]
pub fn conj_mul_acc_with(kern: Kernels, acc: &mut [f32], a: &[f32], b: &[f32]) {
    simd::conj_mul_acc_with(kern, acc, a, b);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rdfft::layout::{get, unpack_full};

    fn cmul(a: (f32, f32), b: (f32, f32)) -> (f32, f32) {
        (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
    }

    fn packed(vals: &[(f32, f32)]) -> Vec<f32> {
        // vals = y_0 .. y_{n/2}
        let n = (vals.len() - 1) * 2;
        let mut buf = vec![0.0f32; n];
        crate::rdfft::layout::pack_from_rfft(vals, &mut buf);
        buf
    }

    #[test]
    fn mul_matches_complex_multiplication() {
        let a = packed(&[(2.0, 0.0), (1.0, -3.0), (0.5, 2.0), (-1.0, 0.0)]);
        let b = packed(&[(-1.0, 0.0), (2.0, 1.0), (0.0, -1.0), (4.0, 0.0)]);
        let mut out = a.clone();
        mul_inplace(&mut out, &b);
        for k in 0..=3 {
            let expect = cmul(get(&a, k), get(&b, k));
            let got = get(&out, k);
            assert!((got.0 - expect.0).abs() < 1e-6, "k={k}");
            assert!((got.1 - expect.1).abs() < 1e-6, "k={k}");
        }
    }

    #[test]
    fn conj_mul_matches_conjugated_multiplication() {
        let a = packed(&[(2.0, 0.0), (1.0, -3.0), (0.5, 2.0), (-1.0, 0.0)]);
        let b = packed(&[(-1.0, 0.0), (2.0, 1.0), (0.0, -1.0), (4.0, 0.0)]);
        let mut out = a.clone();
        conj_mul_inplace(&mut out, &b);
        for k in 0..=3 {
            let (ar, ai) = get(&a, k);
            let expect = cmul((ar, -ai), get(&b, k));
            let got = get(&out, k);
            assert!((got.0 - expect.0).abs() < 1e-6, "k={k}");
            assert!((got.1 - expect.1).abs() < 1e-6, "k={k}");
        }
    }

    #[test]
    fn product_preserves_hermitian_symmetry() {
        let a = packed(&[(1.0, 0.0), (2.0, -1.0), (3.0, 0.5), (0.0, 0.0)]);
        let b = packed(&[(0.5, 0.0), (-1.0, 2.0), (1.0, 1.0), (2.0, 0.0)]);
        let mut out = a.clone();
        mul_inplace(&mut out, &b);
        let full = unpack_full(&out);
        let n = full.len();
        for k in 1..n / 2 {
            assert!((full[k].0 - full[n - k].0).abs() < 1e-6);
            assert!((full[k].1 + full[n - k].1).abs() < 1e-6);
        }
    }

    #[test]
    fn mul_acc_accumulates() {
        let a = packed(&[(1.0, 0.0), (1.0, 1.0), (2.0, 0.0)]);
        let b = packed(&[(2.0, 0.0), (3.0, -1.0), (1.0, 0.0)]);
        let mut acc = vec![0.0f32; 4];
        mul_acc(&mut acc, &a, &b);
        mul_acc(&mut acc, &a, &b);
        let mut once = a.clone();
        mul_inplace(&mut once, &b);
        for i in 0..4 {
            assert!((acc[i] - 2.0 * once[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn conj_mul_acc_matches_conj_mul() {
        let a = packed(&[(1.0, 0.0), (1.0, 1.0), (2.0, 0.0)]);
        let b = packed(&[(2.0, 0.0), (3.0, -1.0), (1.0, 0.0)]);
        let mut acc = vec![0.0f32; 4];
        conj_mul_acc(&mut acc, &a, &b);
        let mut direct = a.clone();
        conj_mul_inplace(&mut direct, &b);
        for i in 0..4 {
            assert!((acc[i] - direct[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn rows_kernels_match_per_row_kernels() {
        let n = 16;
        let rows = 5;
        let mut rng = crate::autograd::tensor::Rng::new(77);
        let spec = spectrum_of(&(0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect::<Vec<_>>());
        let tile: Vec<f32> = (0..rows * n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        for conj in [false, true] {
            // Forced-scalar rows kernel ≡ per-row legacy kernel, bitwise.
            let mut forced = tile.clone();
            let mut reference = tile.clone();
            if conj {
                mul_conjb_rows_with(Kernels::LegacyScalar, &mut forced, &spec);
                for row in reference.chunks_exact_mut(n) {
                    mul_conjb_inplace(row, &spec);
                }
            } else {
                mul_rows_with(Kernels::LegacyScalar, &mut forced, &spec);
                for row in reference.chunks_exact_mut(n) {
                    mul_inplace(row, &spec);
                }
            }
            assert_eq!(forced, reference, "conj={conj}");
            // Auto-dispatched rows kernel agrees within FMA slack (exact
            // on non-FMA arms).
            let mut auto = tile.clone();
            if conj {
                mul_conjb_rows_inplace(&mut auto, &spec);
            } else {
                mul_rows_inplace(&mut auto, &spec);
            }
            for i in 0..auto.len() {
                assert!(
                    (auto[i] - reference[i]).abs() <= 1e-5 * (1.0 + reference[i].abs()),
                    "conj={conj} i={i}"
                );
            }
        }
    }

    // ---------------- randomized spectral-algebra properties ----------------
    //
    // Seeds are pinned (fixed constants per case index) so CI runs are
    // deterministic; tolerances are n-scaled (see `n_tol`) rather than
    // fixed epsilons, since f32 butterfly error grows with the stage
    // count (~O(log n)) and coefficient magnitude (~O(√n)).

    use crate::autograd::tensor::Rng as PRng;

    /// n-scaled absolute tolerance for values carrying one transform's
    /// worth of f32 rounding: `base · √n · (log2 n + 1)`.
    fn n_tol(n: usize, base: f32) -> f32 {
        base * (n as f32).sqrt() * ((n as f32).log2() + 1.0)
    }

    /// `n` uniform draws in (-1, 1) from the crate's shared deterministic
    /// RNG.
    fn rand_vec(rng: &mut PRng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    fn spectrum_of(x: &[f32]) -> Vec<f32> {
        let plan = crate::rdfft::plan::cached(x.len());
        let mut s = x.to_vec();
        crate::rdfft::forward::rdfft_inplace(&plan, &mut s);
        s
    }

    /// Energy of a packed spectrum under Parseval's theorem
    /// (`||x||² = (y₀² + y_{n/2}² + 2·Σ(re²+im²)) / n`).
    fn packed_energy(s: &[f32]) -> f64 {
        let n = s.len();
        let mut e = (s[0] as f64).powi(2) + (s[n / 2] as f64).powi(2);
        for k in 1..n / 2 {
            e += 2.0 * ((s[k] as f64).powi(2) + (s[n - k] as f64).powi(2));
        }
        e / n as f64
    }

    #[test]
    fn prop_parseval_energy_preserved_by_packed_encoding() {
        for case in 0..60u64 {
            let mut rng = PRng::new(100 + case);
            let n = [4usize, 8, 16, 64, 256, 1024][(case % 6) as usize];
            let x = rand_vec(&mut rng, n);
            let et: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
            let ef = packed_energy(&spectrum_of(&x));
            assert!(
                (et - ef).abs() <= 1e-4 * et.max(1.0),
                "case={case} n={n}: {et} vs {ef}"
            );
        }
    }

    #[test]
    fn prop_packed_products_match_full_complex_products() {
        // The packed kernels assume the product of two conjugate-symmetric
        // spectra is itself conjugate-symmetric (§4.2 of the paper). Check
        // both halves of that claim against an independent computation in
        // the full complex domain: the full product must be Hermitian, and
        // the packed kernel's lanes must equal the full product's.
        for case in 0..40u64 {
            let mut rng = PRng::new(200 + case);
            let n = [8usize, 16, 64, 256][(case % 4) as usize];
            let a = spectrum_of(&rand_vec(&mut rng, n));
            let b = spectrum_of(&rand_vec(&mut rng, n));
            let fa = unpack_full(&a);
            let fb = unpack_full(&b);
            for variant in 0..3 {
                let mut out = a.clone();
                let full_prod: Vec<(f32, f32)> = match variant {
                    0 => {
                        mul_inplace(&mut out, &b);
                        (0..n).map(|k| cmul(fa[k], fb[k])).collect()
                    }
                    1 => {
                        conj_mul_inplace(&mut out, &b);
                        (0..n).map(|k| cmul((fa[k].0, -fa[k].1), fb[k])).collect()
                    }
                    _ => {
                        mul_conjb_inplace(&mut out, &b);
                        (0..n).map(|k| cmul(fa[k], (fb[k].0, -fb[k].1))).collect()
                    }
                };
                let tol = n_tol(n, 3e-6).max(1e-4)
                    * (1.0
                        + full_prod.iter().fold(0.0f32, |m, &(r, i)| m.max(r.abs()).max(i.abs())));
                for k in 1..n / 2 {
                    // Hermitian symmetry of the independent full product...
                    assert!(
                        (full_prod[k].0 - full_prod[n - k].0).abs() < tol
                            && (full_prod[k].1 + full_prod[n - k].1).abs() < tol,
                        "case={case} variant={variant} n={n} k={k} symmetry"
                    );
                }
                // ...and lane-for-lane agreement of the packed kernel.
                for k in 0..=n / 2 {
                    let (gr, gi) = get(&out, k);
                    assert!(
                        (gr - full_prod[k].0).abs() < tol && (gi - full_prod[k].1).abs() < tol,
                        "case={case} variant={variant} n={n} k={k}: ({gr},{gi}) vs {:?}",
                        full_prod[k]
                    );
                }
            }
        }
    }

    #[test]
    fn prop_mul_conj_mul_roundtrip_scales_by_energy() {
        // conj_mul(mul(a, b), b) computes conj(a·b)·b = conj(a)·|b|²
        // lane-wise: every packed lane of the result must equal
        // conj(a)_k · |b_k|².
        for case in 0..40u64 {
            let mut rng = PRng::new(300 + case);
            let n = [8usize, 16, 64][(case % 3) as usize];
            let a = spectrum_of(&rand_vec(&mut rng, n));
            let b = spectrum_of(&rand_vec(&mut rng, n));
            let mut out = a.clone();
            mul_inplace(&mut out, &b);
            conj_mul_inplace(&mut out, &b);
            for k in 0..=n / 2 {
                let (ar, ai) = get(&a, k);
                let (br, bi) = get(&b, k);
                let mag2 = br * br + bi * bi;
                let (gr, gi) = get(&out, k);
                assert!(
                    (gr - ar * mag2).abs() < 1e-4 * (1.0 + mag2),
                    "case={case} n={n} k={k} re"
                );
                assert!(
                    (gi + ai * mag2).abs() < 1e-4 * (1.0 + mag2),
                    "case={case} n={n} k={k} im"
                );
            }
        }
    }

    #[test]
    fn prop_conj_mul_is_conjugate_of_mul_conjb() {
        // conj(a)·b and a·conj(b) are complex conjugates of each other,
        // so the two fused kernels must agree up to an imaginary-half
        // sign flip.
        for case in 0..40u64 {
            let mut rng = PRng::new(400 + case);
            let n = [8usize, 32, 128][(case % 3) as usize];
            let a = spectrum_of(&rand_vec(&mut rng, n));
            let b = spectrum_of(&rand_vec(&mut rng, n));
            let mut lhs = a.clone();
            conj_mul_inplace(&mut lhs, &b);
            let mut rhs = a.clone();
            mul_conjb_inplace(&mut rhs, &b);
            crate::rdfft::layout::conj_inplace(&mut rhs);
            for i in 0..n {
                assert!(
                    (lhs[i] - rhs[i]).abs() < n_tol(n, 1e-6),
                    "case={case} n={n} i={i}: {} vs {}",
                    lhs[i],
                    rhs[i]
                );
            }
        }
    }

    #[test]
    fn prop_mul_by_delta_spectrum_is_identity() {
        // FFT(δ) is the all-ones spectrum — the ⊙ identity element; a
        // mul/IFFT roundtrip through it must reproduce the signal.
        for case in 0..20u64 {
            let mut rng = PRng::new(500 + case);
            let n = [8usize, 64, 512][(case % 3) as usize];
            let mut delta = vec![0.0f32; n];
            delta[0] = 1.0;
            let one = spectrum_of(&delta);
            let x = rand_vec(&mut rng, n);
            let mut s = spectrum_of(&x);
            mul_inplace(&mut s, &one);
            let plan = crate::rdfft::plan::cached(n);
            crate::rdfft::inverse::irdfft_inplace(&plan, &mut s);
            for i in 0..n {
                assert!((s[i] - x[i]).abs() < n_tol(n, 1e-5), "case={case} n={n} i={i}");
            }
        }
    }
}
