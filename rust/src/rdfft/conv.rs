//! Convolution utilities on top of rdFFT — the downstream API surface a
//! user of the paper's operator actually wants (spectral convolution is
//! one of the FFT-in-NN use cases the related-work section lists).
//!
//! * [`circular_convolve_inplace`] — the raw Eq. 4 primitive.
//! * [`linear_convolve`] — zero-padded full linear convolution.
//! * [`OverlapAdd`] — streaming linear convolution with a fixed FIR
//!   filter: O(log p) per sample, constant memory, suitable for
//!   arbitrarily long streams.
//!
//! **Allocation contract, per entry point.** The FFT work itself is
//! always in-place, but convenience wrappers allocate staging buffers;
//! callers on a zero-allocation budget must pick the right variant:
//!
//! * Allocation-free on every call (given caller buffers):
//!   [`circular_convolve_with_spectrum`],
//!   [`circular_convolve_inplace_with_scratch`],
//!   [`linear_convolve_batch_with_scratch`], and
//!   [`OverlapAdd::process`]/[`OverlapAdd::finish`] after construction
//!   (the steady-state guarantee the alloc-count tests pin).
//! * Allocate per call (scratch and/or output): [`circular_convolve_inplace`]
//!   (a spectrum copy of `b`), [`linear_convolve`] (two padded buffers,
//!   one of which becomes the returned output), and
//!   [`linear_convolve_batch`] (filter spectrum + padded row buffer +
//!   output).
//! * Allocate at construction only: [`OverlapAdd::new`].
//!
//! Every path here is a thin composition of engine batch calls, so the
//! convolutions inherit the SIMD lane dispatch (and `--force-scalar`)
//! without any conv-specific kernel code.

use super::engine::{self, SpectralOp};
use super::forward::rdfft_inplace;
use super::plan::{cached, Plan};
use std::sync::Arc;

/// `a := a ⊛ b` (circular convolution; `a` may hold one row or any number
/// of contiguous length-`plan.n()` rows). `b_spec` must already be in the
/// packed frequency domain. Runs the fused single-sweep circulant
/// pipeline — forward stages, packed product, inverse stages per
/// cache-resident tile.
pub fn circular_convolve_with_spectrum(plan: &Plan, a: &mut [f32], b_spec: &[f32]) {
    engine::circulant_apply_batch(plan, a, b_spec, SpectralOp::Mul);
}

/// `a := a ⊛ b` (circular convolution) with both operands in the time
/// domain; `b` is transformed into a freshly **allocated** scratch copy
/// per call. Hot paths that already own a scratch buffer should use
/// [`circular_convolve_inplace_with_scratch`] instead.
pub fn circular_convolve_inplace(a: &mut [f32], b: &[f32]) {
    let mut b_spec = b.to_vec();
    circular_convolve_inplace_with_scratch(a, b, &mut b_spec);
}

/// [`circular_convolve_inplace`] without the per-call allocation:
/// `scratch` (same length as `b`) receives a copy of `b`, is transformed
/// in place, and ends holding the packed spectrum `b̂` — which the caller
/// may reuse with [`circular_convolve_with_spectrum`] for further rows.
/// Allocation-free once the size's plan exists in the process cache.
pub fn circular_convolve_inplace_with_scratch(a: &mut [f32], b: &[f32], scratch: &mut [f32]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(scratch.len(), b.len(), "scratch must match the operand length");
    let plan = cached(a.len());
    scratch.copy_from_slice(b);
    rdfft_inplace(&plan, scratch);
    circular_convolve_with_spectrum(&plan, a, scratch);
}

/// The FFT size the linear-convolution paths pad to for a signal of
/// `x_len` against a filter of `h_len`: the next power of two ≥ the
/// `x_len + h_len - 1` output (so the circular product aliases nothing).
/// Callers of [`linear_convolve_batch_with_scratch`] size their buffers
/// with this.
pub fn linear_convolve_fft_size(x_len: usize, h_len: usize) -> usize {
    assert!(x_len > 0 && h_len > 0);
    (x_len + h_len - 1).next_power_of_two().max(2)
}

/// Full linear convolution (`len = x.len() + h.len() - 1`) by zero-padding
/// to the next power of two. Allocates the output (unavoidable: the
/// result is longer than either input).
pub fn linear_convolve(x: &[f32], h: &[f32]) -> Vec<f32> {
    let out_len = x.len() + h.len() - 1;
    let n = linear_convolve_fft_size(x.len(), h.len());
    let plan = cached(n);
    let mut xa = vec![0.0f32; n];
    xa[..x.len()].copy_from_slice(x);
    let mut ha = vec![0.0f32; n];
    ha[..h.len()].copy_from_slice(h);
    // Size-dispatched (four-step at large n) — see
    // [`linear_convolve_batch_with_scratch`] on the tier-crossing seam.
    engine::forward_batch(&plan, &mut ha);
    circular_convolve_with_spectrum(&plan, &mut xa, &ha);
    xa.truncate(out_len);
    xa
}

/// Batched full linear convolution: `rows` equal-length signals
/// (concatenated row-major in `xs`) against one filter `h`, through the
/// fused circulant pipeline — one single-sweep pass per row tile instead
/// of `rows` independent transform pairs or three full batch passes.
/// Returns the outputs concatenated row-major, each
/// `x_len + h.len() - 1` long. Allocates the filter spectrum, the padded
/// row buffer, and the output per call; steady-state callers should hold
/// those buffers themselves and use
/// [`linear_convolve_batch_with_scratch`].
pub fn linear_convolve_batch(xs: &[f32], rows: usize, h: &[f32]) -> Vec<f32> {
    assert!(rows > 0, "need at least one signal row");
    assert!(xs.len() % rows == 0, "xs must hold `rows` equal-length signals");
    assert!(!h.is_empty());
    let x_len = xs.len() / rows;
    assert!(x_len > 0, "signal rows must be non-empty");
    let out_len = x_len + h.len() - 1;
    let n = linear_convolve_fft_size(x_len, h.len());
    let mut h_spec = vec![0.0f32; n];
    let mut buf = vec![0.0f32; rows * n];
    linear_convolve_batch_with_scratch(xs, rows, h, &mut buf, &mut h_spec);
    let mut out = Vec::with_capacity(rows * out_len);
    for r in 0..rows {
        out.extend_from_slice(&buf[r * n..r * n + out_len]);
    }
    out
}

/// Zero-allocation core of [`linear_convolve_batch`]: the caller owns
/// both staging buffers. `h_spec` (length
/// `n = linear_convolve_fft_size(x_len, h.len())`) receives the
/// zero-padded filter and ends holding its packed spectrum — reusable
/// across calls with the same filter by pre-transforming once and
/// calling [`circular_convolve_with_spectrum`] on a padded buffer
/// directly. `buf` (length `rows · n`) receives the zero-padded signal
/// rows and ends holding each row's full circular product; the linear
/// result is the first `x_len + h.len() - 1` samples of each padded row
/// (the remainder is the zero-padding tail, ≈ 0 to transform precision).
/// Allocation-free once the size's plan exists in the process cache —
/// this is the hot-path shape `LongConvLayer` builds on.
pub fn linear_convolve_batch_with_scratch(
    xs: &[f32],
    rows: usize,
    h: &[f32],
    buf: &mut [f32],
    h_spec: &mut [f32],
) {
    assert!(rows > 0, "need at least one signal row");
    assert!(xs.len() % rows == 0, "xs must hold `rows` equal-length signals");
    assert!(!h.is_empty());
    let x_len = xs.len() / rows;
    assert!(x_len > 0, "signal rows must be non-empty");
    let n = linear_convolve_fft_size(x_len, h.len());
    assert_eq!(h_spec.len(), n, "h_spec must be linear_convolve_fft_size long");
    assert_eq!(buf.len(), rows * n, "buf must hold `rows` padded rows");
    let plan = cached(n);
    h_spec[..h.len()].copy_from_slice(h);
    h_spec[h.len()..].fill(0.0);
    // Size-dispatched forward for the filter: at n ≥ the engine's
    // four-step threshold the spectrum is produced by the large-n tier
    // and then consumed by the direct fused sweep below — the
    // tier-crossing seam the differential tests pin.
    engine::forward_batch(&plan, h_spec);
    for (row, x) in buf.chunks_exact_mut(n).zip(xs.chunks_exact(x_len)) {
        row[..x_len].copy_from_slice(x);
        row[x_len..].fill(0.0);
    }
    engine::circulant_apply_batch(&plan, buf, h_spec, SpectralOp::Mul);
}

/// Streaming linear convolution with a fixed filter via overlap-add.
///
/// Block size `n` is chosen as the smallest power of two ≥ 2·h.len();
/// each [`Self::process`] call consumes up to `n - h.len() + 1` samples
/// and appends the convolved output to the caller's sink. Steady state
/// reuses two internal buffers — zero allocation per block.
pub struct OverlapAdd {
    plan: Arc<Plan>,
    h_spec: Vec<f32>,
    h_len: usize,
    /// samples consumed per block
    pub hop: usize,
    block: Vec<f32>,
    tail: Vec<f32>,
}

impl OverlapAdd {
    pub fn new(h: &[f32]) -> Self {
        assert!(!h.is_empty());
        let n = (2 * h.len()).next_power_of_two().max(2);
        let plan = cached(n);
        let mut h_spec = vec![0.0f32; n];
        h_spec[..h.len()].copy_from_slice(h);
        rdfft_inplace(&plan, &mut h_spec);
        let hop = n - h.len() + 1;
        OverlapAdd {
            plan,
            h_spec,
            h_len: h.len(),
            hop,
            block: vec![0.0; n],
            tail: vec![0.0; h.len() - 1],
        }
    }

    /// FFT block size in use.
    pub fn block_size(&self) -> usize {
        self.block.len()
    }

    /// Convolve one chunk (`chunk.len() <= self.hop`) and append
    /// `chunk.len()` output samples to `out` (steady-state latency 0:
    /// outputs are finalized as soon as their overlap resolves).
    pub fn process(&mut self, chunk: &[f32], out: &mut Vec<f32>) {
        assert!(chunk.len() <= self.hop, "feed at most `hop` samples per call");
        let n = self.block.len();
        self.block[..chunk.len()].copy_from_slice(chunk);
        self.block[chunk.len()..].fill(0.0);
        // Fused convolve: one sweep over the block instead of three.
        engine::circulant_apply_batch(&self.plan, &mut self.block, &self.h_spec, SpectralOp::Mul);
        // add the carried tail
        for (b, t) in self.block.iter_mut().zip(self.tail.iter()) {
            *b += t;
        }
        // emit chunk.len() samples; carry the next h_len-1 as the new tail
        out.extend_from_slice(&self.block[..chunk.len()]);
        let tail_len = self.h_len - 1;
        debug_assert!(chunk.len() + tail_len <= n);
        for i in 0..tail_len {
            self.tail[i] = self.block[chunk.len() + i];
        }
    }

    /// Flush the trailing `h.len()-1` samples of the stream.
    pub fn finish(&mut self, out: &mut Vec<f32>) {
        out.extend_from_slice(&self.tail);
        self.tail.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_linear(x: &[f32], h: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; x.len() + h.len() - 1];
        for (i, &xi) in x.iter().enumerate() {
            for (j, &hj) in h.iter().enumerate() {
                out[i + j] += xi * hj;
            }
        }
        out
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((s >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn circular_convolution_matches_naive() {
        let n = 64;
        let a = rand_vec(n, 1);
        let b = rand_vec(n, 2);
        let mut got = a.clone();
        circular_convolve_inplace(&mut got, &b);
        for i in 0..n {
            let want: f32 = (0..n).map(|j| a[j] * b[(i + n - j) % n]).sum();
            assert!((got[i] - want).abs() < 1e-3, "i={i}");
        }
    }

    #[test]
    fn linear_convolution_matches_naive() {
        for (nx, nh) in [(10usize, 4usize), (100, 17), (33, 33), (1, 5)] {
            let x = rand_vec(nx, nx as u64);
            let h = rand_vec(nh, nh as u64 + 7);
            let got = linear_convolve(&x, &h);
            let want = naive_linear(&x, &h);
            assert_eq!(got.len(), want.len());
            for i in 0..want.len() {
                assert!((got[i] - want[i]).abs() < 1e-3, "({nx},{nh}) i={i}");
            }
        }
    }

    #[test]
    fn batched_convolution_matches_per_row() {
        let (rows, x_len, h_len) = (5usize, 40usize, 9usize);
        let h = rand_vec(h_len, 100);
        let xs = rand_vec(rows * x_len, 101);
        let got = linear_convolve_batch(&xs, rows, &h);
        let out_len = x_len + h_len - 1;
        assert_eq!(got.len(), rows * out_len);
        for r in 0..rows {
            let want = linear_convolve(&xs[r * x_len..(r + 1) * x_len], &h);
            for i in 0..out_len {
                assert!(
                    (got[r * out_len + i] - want[i]).abs() < 1e-3,
                    "row={r} i={i}"
                );
            }
        }
    }

    #[test]
    fn overlap_add_matches_batch_linear_convolution() {
        let h = rand_vec(13, 3);
        let x = rand_vec(500, 4);
        let mut ola = OverlapAdd::new(&h);
        let mut out = Vec::new();
        let mut i = 0;
        // feed uneven chunk sizes to exercise the boundary logic
        let chunks = [ola.hop, 7, ola.hop, 1, ola.hop - 3];
        let mut c = 0;
        while i < x.len() {
            let take = chunks[c % chunks.len()].min(x.len() - i).min(ola.hop);
            let mut piece = Vec::new();
            ola.process(&x[i..i + take], &mut piece);
            out.extend_from_slice(&piece);
            i += take;
            c += 1;
        }
        ola.finish(&mut out);
        let want = naive_linear(&x, &h);
        assert_eq!(out.len(), want.len());
        for i in 0..want.len() {
            assert!((out[i] - want[i]).abs() < 1e-2, "i={i}: {} vs {}", out[i], want[i]);
        }
    }

    #[test]
    fn overlap_add_steady_state_allocates_nothing() {
        let h = rand_vec(31, 5);
        let mut ola = OverlapAdd::new(&h);
        let x = rand_vec(ola.hop, 6);
        let mut out = Vec::with_capacity(8 * ola.hop);
        ola.process(&x, &mut out); // warm the output Vec
        out.clear();
        out.reserve(8 * ola.hop);
        crate::memtrack::reset_peak();
        let before = crate::memtrack::snapshot().alloc_count;
        for _ in 0..5 {
            ola.process(&x, &mut out);
        }
        assert_eq!(crate::memtrack::snapshot().alloc_count, before);
    }

    #[test]
    fn circular_convolve_scratch_variant_matches_and_allocates_nothing() {
        let n = 64;
        let a0 = rand_vec(n, 21);
        let b = rand_vec(n, 22);
        let mut reference = a0.clone();
        circular_convolve_inplace(&mut reference, &b);
        let mut got = a0.clone();
        let mut scratch = vec![0.0f32; n];
        circular_convolve_inplace_with_scratch(&mut got, &b, &mut scratch);
        assert_eq!(got, reference, "scratch variant must be bit-identical");
        // The scratch ends holding b̂ — reusable with the fused sweep.
        let plan = cached(n);
        let mut via_spec = a0.clone();
        circular_convolve_with_spectrum(&plan, &mut via_spec, &scratch);
        assert_eq!(via_spec, reference);
        // Warm (plan cached, buffers owned): the hot path must not touch
        // the allocator at all.
        crate::memtrack::reset_peak();
        let before = crate::memtrack::snapshot().alloc_count;
        for _ in 0..4 {
            circular_convolve_inplace_with_scratch(&mut got, &b, &mut scratch);
        }
        assert_eq!(crate::memtrack::snapshot().alloc_count, before);
    }

    #[test]
    fn batch_scratch_variant_matches_and_allocates_nothing() {
        let (rows, x_len, h_len) = (4usize, 40usize, 9usize);
        let h = rand_vec(h_len, 200);
        let xs = rand_vec(rows * x_len, 201);
        let reference = linear_convolve_batch(&xs, rows, &h);
        let n = linear_convolve_fft_size(x_len, h_len);
        let out_len = x_len + h_len - 1;
        let mut buf = vec![0.0f32; rows * n];
        let mut h_spec = vec![0.0f32; n];
        linear_convolve_batch_with_scratch(&xs, rows, &h, &mut buf, &mut h_spec);
        for r in 0..rows {
            for i in 0..out_len {
                assert_eq!(
                    buf[r * n + i],
                    reference[r * out_len + i],
                    "row={r} i={i}: scratch variant must be bit-identical"
                );
            }
        }
        // Warm: repeated calls with caller-owned buffers allocate nothing.
        crate::memtrack::reset_peak();
        let before = crate::memtrack::snapshot().alloc_count;
        for _ in 0..3 {
            linear_convolve_batch_with_scratch(&xs, rows, &h, &mut buf, &mut h_spec);
        }
        assert_eq!(crate::memtrack::snapshot().alloc_count, before);
    }

    #[test]
    fn fft_size_helper_matches_padding_rule() {
        assert_eq!(linear_convolve_fft_size(1, 1), 2);
        assert_eq!(linear_convolve_fft_size(10, 4), 16);
        assert_eq!(linear_convolve_fft_size(33, 33), 128);
        assert_eq!(linear_convolve_fft_size(16_000, 400), 32_768);
    }

    #[test]
    fn tier_crossing_linear_convolution_matches_naive() {
        // The four-step-produced spectrum consumed by the direct fused
        // sweep: at n ≥ the default 16 Ki threshold the filter forward
        // runs the large-n tier while the row sweep stays on the direct
        // fused kernels. Differential vs the O(n²) oracle at sizes
        // straddling the threshold, with n-scaled tolerances, plus a
        // tier-count assertion that the crossing actually happened (the
        // engaged-tier telemetry this PR adds).
        use crate::rdfft::engine::tier_counts;
        for (x_len, h_len) in [(8_000usize, 100usize), (16_000, 400)] {
            let n = linear_convolve_fft_size(x_len, h_len);
            let x = rand_vec(x_len, x_len as u64);
            let h = rand_vec(h_len, h_len as u64 + 3);
            let t0 = tier_counts();
            let got = linear_convolve(&x, &h);
            let d = tier_counts().since(t0);
            if n >= 16_384 {
                assert!(d.fourstep >= 1, "n={n}: filter forward must engage four-step");
            } else {
                assert_eq!(d.fourstep, 0, "n={n}: below threshold must stay direct");
            }
            assert_eq!(d.fallback, 0, "n={n}: no silent fallback on this path");
            let want = naive_linear(&x, &h);
            assert_eq!(got.len(), want.len());
            // Absolute error scales with the intermediate spectral
            // magnitudes (~ sqrt(n·h_len) for unit-variance inputs).
            let tol = 2e-5 * (n as f32).sqrt() * (h_len as f32).sqrt();
            for i in 0..want.len() {
                assert!(
                    (got[i] - want[i]).abs() <= tol * (1.0 + want[i].abs()),
                    "({x_len},{h_len}) i={i}: {} vs {} (tol {tol})",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn tier_crossing_spectrum_agrees_with_direct_pinned_leg() {
        // Same convolution computed twice at a four-step-sized n: once
        // with the default size dispatch (large-n tier builds the filter
        // spectrum) and once with the tier pinned off. The direct fused
        // sweep consumes both spectra; outputs must agree to the
        // tier-drift tolerance (fused twiddle product, ~1 ulp/late
        // stage), far tighter than the naive-oracle bound.
        use crate::rdfft::engine::{self as eng, EngineConfig, SpectralOp};
        let (x_len, h_len) = (16_000usize, 400usize);
        let n = linear_convolve_fft_size(x_len, h_len);
        assert!(n >= 16_384, "case must sit on the four-step leg");
        let x = rand_vec(x_len, 77);
        let h = rand_vec(h_len, 78);
        let plan = cached(n);
        let direct_cfg = EngineConfig { fourstep_threshold: usize::MAX, ..EngineConfig::new() };

        let fourstep_leg = linear_convolve(&x, &h);

        let mut h_spec = vec![0.0f32; n];
        h_spec[..h_len].copy_from_slice(&h);
        eng::forward_batch_with(&plan, &mut h_spec, &direct_cfg);
        let mut buf = vec![0.0f32; n];
        buf[..x_len].copy_from_slice(&x);
        eng::circulant_apply_batch(&plan, &mut buf, &h_spec, SpectralOp::Mul);

        let tol = 1e-5 * (n as f32).sqrt() * (h_len as f32).sqrt();
        for i in 0..x_len + h_len - 1 {
            assert!(
                (fourstep_leg[i] - buf[i]).abs() <= tol * (1.0 + buf[i].abs()),
                "i={i}: four-step leg {} vs direct leg {}",
                fourstep_leg[i],
                buf[i]
            );
        }
    }

    #[test]
    fn impulse_filter_is_identity() {
        let mut ola = OverlapAdd::new(&[1.0]);
        let x = rand_vec(100, 9);
        let mut out = Vec::new();
        for chunk in x.chunks(ola.hop) {
            ola.process(chunk, &mut out);
        }
        ola.finish(&mut out);
        for i in 0..x.len() {
            assert!((out[i] - x[i]).abs() < 1e-4);
        }
    }
}
