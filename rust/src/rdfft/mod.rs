//! rdFFT — the paper's contribution: a real-domain, **fully in-place** FFT.
//!
//! An `n`-point real input is transformed inside its own buffer of `n` f32s
//! (no auxiliary storage, no `n+2` expansion, no complex dtype) into the
//! *packed* spectrum layout of §4.1 of the paper:
//!
//! ```text
//! index:   0      1       2     ...  n/2-1    n/2     n/2+1  ...  n-1
//! value:  y0.re  y1.re  y2.re  ...          y_{n/2}.re       ...  y1.im
//!                                                    y_{n/2-1}.im
//! ```
//!
//! i.e. `Re(y_k)` lives at index `k` and `Im(y_k)` at the conjugate-symmetric
//! index `n-k`; the always-real DC (`y_0`) and Nyquist (`y_{n/2}`) terms each
//! occupy one slot. The inverse transform consumes the same layout and
//! restores the original real signal, again fully in place.
//!
//! Submodules:
//! * [`plan`]      — precomputed twiddle factors + bit-reversal schedule
//! * [`layout`]    — packed-format helpers (pack/unpack/conjugate/views)
//! * [`forward`]   — in-place forward transform (§4.1, Proposition 1)
//! * [`inverse`]   — in-place inverse transform (§4.2, Eq. 7)
//! * [`engine`]    — batch-major execution engine (fused stages, SoA
//!   twiddles, scoped-thread batches) behind every batched entry point,
//!   including the fused circulant pipeline
//!   ([`engine::circulant_apply_batch`] and the block-circulant sweeps):
//!   forward stages → packed spectral product → inverse stages in one
//!   cache-resident sweep per tile instead of three full passes
//! * [`fourstep`]  — four-step (Bailey) large-n tier behind the same
//!   batch entry points (`n ≥ EngineConfig::fourstep_threshold`):
//!   chunk-local sub-transforms plus column-pair late stages through a
//!   transpose tile, O(1) full-buffer sweeps instead of O(log n)
//! * [`tiling`]    — shared transpose-tile gather/scatter helpers
//!   (the 2-D column pass and the four-step panels both use them)
//! * [`spectral`]  — packed-domain elementwise complex ops (⊙, conj-⊙)
//! * [`simd`]      — width-4 lane micro-kernels (butterfly 4-groups,
//!   packed products) with runtime dispatch: AVX2+FMA on x86_64, a
//!   bit-identical portable quad arm elsewhere, and the legacy scalar
//!   loops behind `force_scalar` as the differential oracle
//! * [`circulant`] — circulant & block-circulant products + gradients (Eq. 4/5)
//! * [`bf16`]      — software bfloat16 and the bf16 transform path

pub mod bf16;
pub mod circulant;
pub mod circulant_bf16;
pub mod conv;
pub mod engine;
pub mod forward;
pub mod fourstep;
pub mod inverse;
pub mod layout;
pub mod plan;
pub mod simd;
pub mod spectral;
pub mod tiling;
pub mod twod;

pub use circulant::{BlockCirculant, Circulant};
pub use engine::{
    block_circulant_forward_batch, block_circulant_forward_residual_batch,
    block_circulant_transpose_batch, circulant_apply_batch, circulant_apply_batch_ctx,
    forward_batch, forward_batch_ctx, inverse_batch, inverse_batch_ctx, tier_counts,
    EngineConfig, SpectralOp, Tier, TierCounts,
};
pub use simd::Kernels;
pub use forward::{rdfft_batch, rdfft_inplace};
pub use inverse::{irdfft_batch, irdfft_inplace};
pub use plan::Plan;

/// True iff `n` is a supported transform size (power of two, ≥ 2).
pub fn is_supported_size(n: usize) -> bool {
    n >= 2 && n.is_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::naive_dft;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn forward_matches_naive_dft_all_sizes() {
        for log_n in 1..=12 {
            let n = 1usize << log_n;
            let plan = Plan::new(n);
            let x = rand_vec(n, 42 + log_n as u64);
            let mut buf = x.clone();
            rdfft_inplace(&plan, &mut buf);
            let spec = naive_dft(&x);
            // DC and Nyquist
            let tol = 1e-4 * (n as f32).sqrt();
            assert!((buf[0] - spec[0].0).abs() < tol, "n={n} DC");
            assert!((buf[n / 2] - spec[n / 2].0).abs() < tol, "n={n} nyquist");
            for k in 1..n / 2 {
                assert!((buf[k] - spec[k].0).abs() < tol, "n={n} k={k} re: {} vs {}", buf[k], spec[k].0);
                assert!((buf[n - k] - spec[k].1).abs() < tol, "n={n} k={k} im: {} vs {}", buf[n - k], spec[k].1);
            }
        }
    }

    #[test]
    fn roundtrip_is_identity() {
        for log_n in 1..=13 {
            let n = 1usize << log_n;
            let plan = Plan::new(n);
            let x = rand_vec(n, 7 * log_n as u64 + 1);
            let mut buf = x.clone();
            rdfft_inplace(&plan, &mut buf);
            irdfft_inplace(&plan, &mut buf);
            for i in 0..n {
                assert!(
                    (buf[i] - x[i]).abs() < 1e-4,
                    "n={n} i={i}: {} vs {}",
                    buf[i],
                    x[i]
                );
            }
        }
    }

    #[test]
    fn batch_roundtrip() {
        let n = 256;
        let b = 5;
        let plan = Plan::new(n);
        let x = rand_vec(n * b, 99);
        let mut buf = x.clone();
        rdfft_batch(&plan, &mut buf);
        irdfft_batch(&plan, &mut buf);
        for i in 0..n * b {
            assert!((buf[i] - x[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn parseval_holds_in_packed_layout() {
        // ||x||^2 == (y0^2 + y_{n/2}^2 + 2*sum_k (re^2+im^2)) / n
        let n = 1024;
        let plan = Plan::new(n);
        let x = rand_vec(n, 3);
        let energy_time: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let mut buf = x.clone();
        rdfft_inplace(&plan, &mut buf);
        let mut energy_freq = (buf[0] as f64).powi(2) + (buf[n / 2] as f64).powi(2);
        for k in 1..n / 2 {
            energy_freq += 2.0 * ((buf[k] as f64).powi(2) + (buf[n - k] as f64).powi(2));
        }
        energy_freq /= n as f64;
        assert!(
            (energy_time - energy_freq).abs() / energy_time < 1e-5,
            "{energy_time} vs {energy_freq}"
        );
    }

    #[test]
    fn linearity() {
        let n = 128;
        let plan = Plan::new(n);
        let x = rand_vec(n, 11);
        let y = rand_vec(n, 12);
        let (a, b) = (0.7f32, -1.3f32);
        let mut fx = x.clone();
        let mut fy = y.clone();
        rdfft_inplace(&plan, &mut fx);
        rdfft_inplace(&plan, &mut fy);
        let mut z: Vec<f32> = (0..n).map(|i| a * x[i] + b * y[i]).collect();
        rdfft_inplace(&plan, &mut z);
        for i in 0..n {
            assert!((z[i] - (a * fx[i] + b * fy[i])).abs() < 1e-3);
        }
    }
}
