//! Circulant and block-circulant matrix products via the packed spectrum
//! (§3.3 / Eq. 4, gradients Eq. 5 — the paper's training integration).
//!
//! A circulant matrix `C ∈ R^{n×n}` defined by its first column `c`
//! satisfies `Cx = IFFT(FFT(c) ⊙ FFT(x))`. A block-circulant matrix
//! (Block Circulant Adapter, [10] in the paper) with partition size `p`
//! tiles a `(rows × cols)` weight into `(rows/p) × (cols/p)` circulant
//! blocks and sums the per-block spectral products before a *single*
//! inverse transform per output block.
//!
//! Everything here follows the paper's in-place discipline:
//! * the input is transformed **inside its own buffer** (the transformed
//!   input doubles as the saved-for-backward tensor),
//! * products accumulate directly into the output / gradient buffers
//!   (which any training method must allocate anyway),
//! * conjugations (Eq. 5) are fused sign-flips, never materialized.

use super::engine::{self, SpectralOp};
use super::forward::rdfft_inplace;
use super::inverse::irdfft_inplace;
use super::simd;
use super::plan::{cached, Plan};
use super::spectral;
use crate::memtrack::{Category, Registration};
use std::sync::Arc;

/// Square circulant operator, parameterised by the packed spectrum of its
/// first column.
#[derive(Debug, Clone)]
pub struct Circulant {
    plan: Arc<Plan>,
    /// Packed FFT of the first column `c`.
    c_hat: Vec<f32>,
}

impl Circulant {
    /// Build from the first column `c` (length must be a power of two).
    pub fn from_first_column(c: &[f32]) -> Self {
        let plan = cached(c.len());
        let mut c_hat = c.to_vec();
        rdfft_inplace(&plan, &mut c_hat);
        Circulant { plan, c_hat }
    }

    /// Build directly from a packed spectrum.
    pub fn from_spectrum(c_hat: Vec<f32>) -> Self {
        let plan = cached(c_hat.len());
        Circulant { plan, c_hat }
    }

    pub fn n(&self) -> usize {
        self.plan.n()
    }

    pub fn spectrum(&self) -> &[f32] {
        &self.c_hat
    }

    /// `x := C x`, fully in place (Eq. 4), through the fused single-sweep
    /// pipeline ([`engine::circulant_apply_batch`]). Zero allocation.
    pub fn matvec_inplace(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.n(), "use matvec_batch_inplace for multiple rows");
        engine::circulant_apply_batch(&self.plan, x, &self.c_hat, SpectralOp::Mul);
    }

    /// Batched matvec: `x` holds any number of contiguous length-`n`
    /// rows, each transformed `row := C row` in one fused sweep per row
    /// tile. Zero allocation.
    pub fn matvec_batch_inplace(&self, x: &mut [f32]) {
        engine::circulant_apply_batch(&self.plan, x, &self.c_hat, SpectralOp::Mul);
    }

    /// [`Self::matvec_batch_inplace`] under an explicit
    /// [`crate::runtime::pool::ExecCtx`] (that context's pool + engine
    /// tuning; bit-identical results).
    pub fn matvec_batch_inplace_ctx(&self, x: &mut [f32], ctx: &crate::runtime::pool::ExecCtx) {
        engine::circulant_apply_batch_ctx(&self.plan, x, &self.c_hat, SpectralOp::Mul, ctx);
    }

    /// `g := Cᵀ g` — the input-gradient product of Eq. 5
    /// (`∂L/∂x = IFFT(conj(ĉ) ⊙ FFT(g))`), fully in place, fused.
    pub fn matvec_transpose_inplace(&self, g: &mut [f32]) {
        assert_eq!(g.len(), self.n(), "use matvec_transpose_batch_inplace for multiple rows");
        engine::circulant_apply_batch(&self.plan, g, &self.c_hat, SpectralOp::MulConjB);
    }

    /// Batched transpose matvec: any number of contiguous length-`n`
    /// rows, each `row := Cᵀ row`, one fused sweep per row tile.
    pub fn matvec_transpose_batch_inplace(&self, g: &mut [f32]) {
        engine::circulant_apply_batch(&self.plan, g, &self.c_hat, SpectralOp::MulConjB);
    }

    /// The pre-fusion three-pass matvec (forward → product → inverse),
    /// kept as the differential oracle for the fused path.
    pub fn matvec_inplace_unfused(&self, x: &mut [f32]) {
        rdfft_inplace(&self.plan, x);
        spectral::mul_inplace(x, &self.c_hat);
        irdfft_inplace(&self.plan, x);
    }

    /// Materialize the dense `n×n` matrix (row-major). **Allocates** —
    /// test/diagnostic use only.
    pub fn to_dense(&self) -> Vec<f32> {
        let n = self.n();
        // Recover c by inverse-transforming the spectrum.
        let mut c = self.c_hat.clone();
        irdfft_inplace(&self.plan, &mut c);
        let mut m = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                m[i * n + j] = c[(i + n - j) % n];
            }
        }
        m
    }
}

/// Block-circulant operator: `rows × cols` weight partitioned into
/// `p × p` circulant blocks. Spectra are stored packed and contiguous:
/// block `(i, j)` at `ĉ[(i*cb + j)*p .. ][..p]` with `rb = rows/p`,
/// `cb = cols/p`.
#[derive(Debug, Clone)]
pub struct BlockCirculant {
    plan: Arc<Plan>,
    rows: usize,
    cols: usize,
    p: usize,
    /// Packed spectra of all blocks' first columns, `rb * cb * p` reals —
    /// exactly the trainable-parameter count the paper reports.
    c_hat: Vec<f32>,
    /// memtrack registration of the parameter storage (4 bytes/scalar),
    /// so operator-level bf16-vs-f32 byte comparisons are tracker-backed.
    _mem: Registration,
}

impl BlockCirculant {
    /// Build from per-block first columns laid out `[(i*cb + j)*p ..]`.
    /// `rows` and `cols` must be multiples of `p`; `p` a power of two.
    pub fn from_block_columns(rows: usize, cols: usize, p: usize, c: &[f32]) -> Self {
        assert!(rows % p == 0 && cols % p == 0, "rows/cols must be multiples of p");
        let rb = rows / p;
        let cb = cols / p;
        assert_eq!(c.len(), rb * cb * p);
        let plan = cached(p);
        let mut c_hat = c.to_vec();
        // All rb*cb block columns are contiguous length-p rows: one
        // batch-major engine call transforms the lot.
        engine::forward_batch(&plan, &mut c_hat);
        let mem = Registration::new(c_hat.len() * 4, Category::Trainable);
        BlockCirculant { plan, rows, cols, p, c_hat, _mem: mem }
    }

    /// Build a zero-initialised adapter (zero spectrum ⇒ zero matrix), the
    /// standard adapter init (like LoRA's zero-B) so fine-tuning starts at
    /// the base model.
    pub fn zeros(rows: usize, cols: usize, p: usize) -> Self {
        assert!(rows % p == 0 && cols % p == 0);
        let plan = cached(p);
        let len = (rows / p) * (cols / p) * p;
        let mem = Registration::new(len * 4, Category::Trainable);
        BlockCirculant { plan, rows, cols, p, c_hat: vec![0.0; len], _mem: mem }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn p(&self) -> usize {
        self.p
    }
    pub fn row_blocks(&self) -> usize {
        self.rows / self.p
    }
    pub fn col_blocks(&self) -> usize {
        self.cols / self.p
    }
    pub fn num_params(&self) -> usize {
        self.c_hat.len()
    }
    /// Bytes of parameter storage (4 bytes per f32 scalar; the bf16
    /// operator's [`super::circulant_bf16::BlockCirculantBf16::param_bytes`]
    /// is exactly half).
    pub fn param_bytes(&self) -> usize {
        self.c_hat.len() * 4
    }
    pub fn spectra(&self) -> &[f32] {
        &self.c_hat
    }
    pub fn spectra_mut(&mut self) -> &mut [f32] {
        &mut self.c_hat
    }
    pub fn plan(&self) -> &Arc<Plan> {
        &self.plan
    }

    /// Forward product `out = W x` (Eq. 4 blockwise), through the fused
    /// single-sweep pipeline ([`engine::block_circulant_forward_batch`]).
    ///
    /// `x` (length `cols`) is transformed **in place** — on return it holds
    /// the packed spectra of its blocks, which is exactly the tensor the
    /// backward pass needs (`x̂` in Eq. 5), so nothing extra is saved.
    /// `out` (length `rows`) is overwritten (zeroed inside the sweep):
    /// spectra accumulate into it and the inverse stages finish each
    /// output block while it is still cache-resident.
    pub fn forward_inplace(&self, x: &mut [f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        engine::block_circulant_forward_batch(
            &self.plan,
            x,
            out,
            &self.c_hat,
            self.row_blocks(),
            self.col_blocks(),
        );
    }

    /// The pre-fusion three-pass forward (forward batch → product sweep →
    /// inverse batch), kept as the differential oracle for
    /// [`Self::forward_inplace`]. `out` must be zeroed by the caller.
    pub fn forward_inplace_unfused(&self, x: &mut [f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        let p = self.p;
        let cb = self.col_blocks();
        // x̂: all cb input blocks in one batch-major pass.
        engine::forward_batch(&self.plan, x);
        for (i, ob) in out.chunks_exact_mut(p).enumerate() {
            for (j, xb) in x.chunks_exact(p).enumerate() {
                let ch = &self.c_hat[(i * cb + j) * p..][..p];
                // Same dispatched product as the fused sweep, so the
                // fused-vs-unfused differential stays bit-exact per arm.
                spectral::mul_acc_with(simd::active(), ob, ch, xb);
            }
        }
        // One batched inverse over all rb accumulated output blocks.
        engine::inverse_batch(&self.plan, out);
    }

    /// Backward pass (Eq. 5).
    ///
    /// * `x_hat` — the block spectra of the forward input (i.e. the input
    ///   buffer after [`Self::forward_inplace`]).
    /// * `g` — grad w.r.t. the output (length `rows`). Transformed in
    ///   place to its block spectra, then **overwritten at the final
    ///   stage** with the grad w.r.t. the input (length `cols` must equal
    ///   `rows` for the pure in-place overwrite; otherwise pass `dx`).
    /// * `dc` — gradient accumulator for the block spectra parameters
    ///   (length `num_params()`), accumulated (+=) in the frequency domain.
    ///
    /// Returns the input gradient in `dx`.
    pub fn backward(&self, x_hat: &[f32], g: &mut [f32], dx: &mut [f32], dc: &mut [f32]) {
        assert_eq!(x_hat.len(), self.cols);
        assert_eq!(g.len(), self.rows);
        assert_eq!(dx.len(), self.cols);
        assert_eq!(dc.len(), self.c_hat.len());
        let p = self.p;
        let cb = self.col_blocks();

        // Fused transpose sweep: transforms g -> ĝ in place AND produces
        // dx = IFFT(Σ_i conj(ĉ_ij) ⊙ ĝ_i) in one pass over the sample.
        engine::block_circulant_transpose_batch(
            &self.plan,
            g,
            dx,
            &self.c_hat,
            self.row_blocks(),
            cb,
        );
        // dĉ_ij += conj(x̂_j) ⊙ ĝ_i  — accumulated in the frequency domain
        // from the ĝ the sweep left behind (lane-dispatched like every
        // other product); the optimizer step works on spectra directly so
        // no inverse here.
        for (i, gb) in g.chunks_exact(p).enumerate() {
            for (j, xb) in x_hat.chunks_exact(p).enumerate() {
                let d = &mut dc[(i * cb + j) * p..][..p];
                spectral::conj_mul_acc_with(simd::active(), d, xb, gb);
            }
        }
    }

    /// The pre-fusion three-pass backward, kept as the differential
    /// oracle for [`Self::backward`].
    pub fn backward_unfused(&self, x_hat: &[f32], g: &mut [f32], dx: &mut [f32], dc: &mut [f32]) {
        assert_eq!(x_hat.len(), self.cols);
        assert_eq!(g.len(), self.rows);
        assert_eq!(dx.len(), self.cols);
        assert_eq!(dc.len(), self.c_hat.len());
        let p = self.p;
        let cb = self.col_blocks();

        // ĝ: transform grad-output blocks in place, batch-major.
        engine::forward_batch(&self.plan, g);
        // dĉ_ij += conj(x̂_j) ⊙ ĝ_i
        for (i, gb) in g.chunks_exact(p).enumerate() {
            for (j, xb) in x_hat.chunks_exact(p).enumerate() {
                let d = &mut dc[(i * cb + j) * p..][..p];
                spectral::conj_mul_acc_with(simd::active(), d, xb, gb);
            }
        }
        // dx_j = IFFT( Σ_i conj(ĉ_ij) ⊙ ĝ_i ): accumulate every block,
        // then a single batched inverse over all cb of them.
        for (j, dxb) in dx.chunks_exact_mut(p).enumerate() {
            dxb.fill(0.0);
            for (i, gb) in g.chunks_exact(p).enumerate() {
                let ch = &self.c_hat[(i * cb + j) * p..][..p];
                spectral::conj_mul_acc_with(simd::active(), dxb, ch, gb);
            }
        }
        engine::inverse_batch(&self.plan, dx);
    }

    /// Apply an SGD step directly on the spectra parameters:
    /// `ĉ -= lr * dĉ`. Operating in the frequency domain is valid because
    /// the transform is linear and fixed.
    pub fn sgd_step(&mut self, dc: &[f32], lr: f32) {
        assert_eq!(dc.len(), self.c_hat.len());
        for (w, g) in self.c_hat.iter_mut().zip(dc) {
            *w -= lr * g;
        }
    }

    /// Materialize the dense `rows × cols` matrix. **Allocates** —
    /// test/diagnostic use only.
    pub fn to_dense(&self) -> Vec<f32> {
        let p = self.p;
        let cb = self.col_blocks();
        let mut m = vec![0.0f32; self.rows * self.cols];
        for bi in 0..self.row_blocks() {
            for bj in 0..cb {
                let mut c = self.c_hat[(bi * cb + bj) * p..][..p].to_vec();
                irdfft_inplace(&self.plan, &mut c);
                for i in 0..p {
                    for j in 0..p {
                        m[(bi * p + i) * self.cols + bj * p + j] = c[(i + p - j) % p];
                    }
                }
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(12345);
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    fn dense_matvec(m: &[f32], x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        (0..rows).map(|i| (0..cols).map(|j| m[i * cols + j] * x[j]).sum()).collect()
    }

    #[test]
    fn circulant_matvec_matches_dense() {
        let n = 64;
        let c = rand_vec(n, 1);
        let x = rand_vec(n, 2);
        let circ = Circulant::from_first_column(&c);
        let dense = circ.to_dense();
        let want = dense_matvec(&dense, &x, n, n);
        let mut got = x.clone();
        circ.matvec_inplace(&mut got);
        for i in 0..n {
            assert!((got[i] - want[i]).abs() < 1e-3, "i={i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn circulant_transpose_matches_dense_transpose() {
        let n = 32;
        let c = rand_vec(n, 3);
        let g = rand_vec(n, 4);
        let circ = Circulant::from_first_column(&c);
        let dense = circ.to_dense();
        // transpose matvec
        let want: Vec<f32> =
            (0..n).map(|j| (0..n).map(|i| dense[i * n + j] * g[i]).sum()).collect();
        let mut got = g.clone();
        circ.matvec_transpose_inplace(&mut got);
        for i in 0..n {
            assert!((got[i] - want[i]).abs() < 1e-3, "i={i}");
        }
    }

    #[test]
    fn dense_reconstruction_is_circulant() {
        let c = [1.0f32, 2.0, 3.0, 4.0];
        let circ = Circulant::from_first_column(&c);
        let m = circ.to_dense();
        // first column is c; each column is a rotation
        for i in 0..4 {
            assert!((m[i * 4] - c[i]).abs() < 1e-5);
        }
        assert!((m[0 * 4 + 1] - c[3]).abs() < 1e-5); // C[0][1] = c[-1 mod 4]
    }

    #[test]
    fn block_circulant_forward_matches_dense() {
        let (rows, cols, p) = (32, 64, 16);
        let rb = rows / p;
        let cb = cols / p;
        let c = rand_vec(rb * cb * p, 5);
        let bc = BlockCirculant::from_block_columns(rows, cols, p, &c);
        let dense = bc.to_dense();
        let x = rand_vec(cols, 6);
        let want = dense_matvec(&dense, &x, rows, cols);
        let mut xbuf = x.clone();
        let mut out = vec![0.0f32; rows];
        bc.forward_inplace(&mut xbuf, &mut out);
        for i in 0..rows {
            assert!((out[i] - want[i]).abs() < 1e-3, "i={i}: {} vs {}", out[i], want[i]);
        }
    }

    #[test]
    fn block_circulant_zero_init_is_zero_matrix() {
        let bc = BlockCirculant::zeros(16, 16, 8);
        let x = rand_vec(16, 7);
        let mut xbuf = x.clone();
        let mut out = vec![0.0f32; 16];
        bc.forward_inplace(&mut xbuf, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn backward_input_grad_matches_dense_transpose() {
        let (rows, cols, p) = (32, 32, 8);
        let c = rand_vec((rows / p) * (cols / p) * p, 8);
        let bc = BlockCirculant::from_block_columns(rows, cols, p, &c);
        let dense = bc.to_dense();
        let x = rand_vec(cols, 9);
        let g0 = rand_vec(rows, 10);

        let mut x_hat = x.clone();
        let mut out = vec![0.0f32; rows];
        bc.forward_inplace(&mut x_hat, &mut out);

        let mut g = g0.clone();
        let mut dx = vec![0.0f32; cols];
        let mut dc = vec![0.0f32; bc.num_params()];
        bc.backward(&x_hat, &mut g, &mut dx, &mut dc);

        let want: Vec<f32> =
            (0..cols).map(|j| (0..rows).map(|i| dense[i * cols + j] * g0[i]).sum()).collect();
        for j in 0..cols {
            assert!((dx[j] - want[j]).abs() < 1e-3, "j={j}: {} vs {}", dx[j], want[j]);
        }
    }

    #[test]
    fn backward_param_grad_matches_finite_differences() {
        // Loss L = sum(out ⊙ g0). dL/dĉ computed by Eq.5 must match
        // numerical differentiation through the forward pass.
        let (rows, cols, p) = (16, 16, 8);
        let c = rand_vec((rows / p) * (cols / p) * p, 11);
        let mut bc = BlockCirculant::from_block_columns(rows, cols, p, &c);
        let x = rand_vec(cols, 12);
        let g0 = rand_vec(rows, 13);

        let fwd = |bc: &BlockCirculant| -> f32 {
            let mut xb = x.clone();
            let mut out = vec![0.0f32; rows];
            bc.forward_inplace(&mut xb, &mut out);
            out.iter().zip(&g0).map(|(o, g)| o * g).sum()
        };

        let mut x_hat = x.clone();
        let mut out = vec![0.0f32; rows];
        bc.forward_inplace(&mut x_hat, &mut out);
        let mut g = g0.clone();
        let mut dx = vec![0.0f32; cols];
        let mut dc = vec![0.0f32; bc.num_params()];
        bc.backward(&x_hat, &mut g, &mut dx, &mut dc);

        // Analytical dc is in the spectrum domain, but with a subtlety: our
        // packed slots for k in 1..p/2 represent BOTH y_k and conj(y_{p-k});
        // perturbing slot re(k) changes both. Finite differences on the
        // spectra parameters capture exactly that packed-parameterization
        // gradient, and Eq.5's conj_mul_acc must agree once the shared-slot
        // factor 2 is accounted for: d/d re_k = 2*Re(dŷ_k), d/d im_k = 2*Im.
        let eps = 1e-2f32;
        for idx in 0..bc.num_params() {
            let orig = bc.spectra()[idx];
            bc.spectra_mut()[idx] = orig + eps;
            let lp = fwd(&bc);
            bc.spectra_mut()[idx] = orig - eps;
            let lm = fwd(&bc);
            bc.spectra_mut()[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            let k = idx % p;
            let scale = if k == 0 || k == p / 2 { 1.0 } else { 2.0 };
            let analytic = scale * dc[idx] / p as f32;
            assert!(
                (num - analytic).abs() < 2e-2 * (1.0 + num.abs()),
                "idx={idx}: fd={num} analytic={analytic}"
            );
        }
    }

    #[test]
    fn fused_matvec_matches_unfused_oracle() {
        // The unfused oracle runs the fully-scalar per-row legacy path,
        // so the forced-scalar fused sweep must reproduce it bit-for-bit;
        // the auto-dispatched sweep may differ only by FMA contraction.
        for n in [4usize, 16, 64, 512] {
            let circ = Circulant::from_first_column(&rand_vec(n, n as u64));
            let x = rand_vec(n, 2 * n as u64 + 1);
            let mut reference = x.clone();
            circ.matvec_inplace_unfused(&mut reference);
            let mut forced = x.clone();
            engine::circulant_apply_batch_with(
                &cached(n),
                &mut forced,
                circ.spectrum(),
                SpectralOp::Mul,
                &crate::rdfft::EngineConfig::forced_scalar(),
            );
            assert_eq!(forced, reference, "forced n={n}");
            let mut auto = x.clone();
            circ.matvec_inplace(&mut auto);
            let tol = 1e-4 * (n as f32).sqrt();
            for i in 0..n {
                assert!(
                    (auto[i] - reference[i]).abs() <= tol * (1.0 + reference[i].abs()),
                    "auto n={n} i={i}"
                );
            }
        }
    }

    #[test]
    fn batched_matvec_matches_per_row_matvec() {
        let n = 64;
        let b = 7;
        let circ = Circulant::from_first_column(&rand_vec(n, 9));
        let xs = rand_vec(n * b, 10);
        let mut batched = xs.clone();
        circ.matvec_batch_inplace(&mut batched);
        for r in 0..b {
            let mut row = xs[r * n..(r + 1) * n].to_vec();
            circ.matvec_inplace(&mut row);
            assert_eq!(&batched[r * n..(r + 1) * n], &row[..], "row {r}");
        }
    }

    #[test]
    fn fused_block_forward_matches_unfused_oracle() {
        for (rows, cols, p) in [(16usize, 16usize, 8usize), (32, 64, 16), (64, 32, 16)] {
            let c = rand_vec((rows / p) * (cols / p) * p, (rows + cols) as u64);
            let bc = BlockCirculant::from_block_columns(rows, cols, p, &c);
            let x = rand_vec(cols, (rows * 3) as u64);

            let mut x_fused = x.clone();
            let mut out_fused = vec![0.0f32; rows];
            bc.forward_inplace(&mut x_fused, &mut out_fused);

            let mut x_ref = x.clone();
            let mut out_ref = vec![0.0f32; rows];
            bc.forward_inplace_unfused(&mut x_ref, &mut out_ref);

            assert_eq!(out_fused, out_ref, "{rows}x{cols} p={p}");
            assert_eq!(x_fused, x_ref, "saved x-hat {rows}x{cols} p={p}");
        }
    }

    #[test]
    fn fused_block_backward_matches_unfused_oracle() {
        for (rows, cols, p) in [(16usize, 16usize, 8usize), (32, 64, 16)] {
            let c = rand_vec((rows / p) * (cols / p) * p, (rows ^ cols) as u64 + 5);
            let bc = BlockCirculant::from_block_columns(rows, cols, p, &c);
            let x = rand_vec(cols, 77);
            let g0 = rand_vec(rows, 78);

            let mut x_hat = x.clone();
            let mut out = vec![0.0f32; rows];
            bc.forward_inplace(&mut x_hat, &mut out);

            let mut g_f = g0.clone();
            let mut dx_f = vec![0.0f32; cols];
            let mut dc_f = vec![0.0f32; bc.num_params()];
            bc.backward(&x_hat, &mut g_f, &mut dx_f, &mut dc_f);

            let mut g_u = g0.clone();
            let mut dx_u = vec![0.0f32; cols];
            let mut dc_u = vec![0.0f32; bc.num_params()];
            bc.backward_unfused(&x_hat, &mut g_u, &mut dx_u, &mut dc_u);

            assert_eq!(dx_f, dx_u, "dx {rows}x{cols} p={p}");
            assert_eq!(dc_f, dc_u, "dc {rows}x{cols} p={p}");
            assert_eq!(g_f, g_u, "g-hat {rows}x{cols} p={p}");
        }
    }

    #[test]
    fn fused_block_forward_allocates_nothing() {
        let (rows, cols, p) = (64usize, 64usize, 16usize);
        let c = rand_vec((rows / p) * (cols / p) * p, 13);
        let bc = BlockCirculant::from_block_columns(rows, cols, p, &c);
        let mut x = rand_vec(cols, 14);
        let mut out = vec![0.0f32; rows];
        crate::memtrack::reset_peak();
        let before = crate::memtrack::snapshot().alloc_count;
        bc.forward_inplace(&mut x, &mut out);
        let mut g = rand_vec(rows, 15);
        let mut dx = vec![0.0f32; cols];
        let mut dc = vec![0.0f32; bc.num_params()];
        bc.backward(&x, &mut g, &mut dx, &mut dc);
        assert_eq!(crate::memtrack::snapshot().alloc_count, before);
    }
}
