//! Batch-major rdFFT execution engine.
//!
//! The scalar paths in [`super::forward`] / [`super::inverse`] transform
//! one row at a time: a bit-reversal pass, then one pass per butterfly
//! stage. This module is the batched hot path every multi-row consumer
//! (block-circulant layers, 2-D transforms, conv batches, the trainer's
//! per-step block sweeps) routes through. Three ideas, all composing with
//! the paper's in-place discipline (zero allocations, zero out-of-buffer
//! writes):
//!
//! 1. **Fused permutation + first two stages.** The `m = 1` and `m = 2`
//!    stages have trivial twiddles (±1, ∓i), and the in-place bit-reversal
//!    swap loop finalizes positions in ascending order, so each aligned
//!    4-block can run both stages *immediately after* its four swaps while
//!    the values are in registers — one pass over the buffer instead of
//!    three. (Correctness argument in [`fused_bitrev_stage12`].)
//!
//! 2. **SoA twiddles + tiled batch-major stages.** Remaining stages sweep
//!    a *tile* of rows, reusing each stage's twiddles across every row in
//!    the tile; twiddles live in separate `wr`/`wi` slices
//!    ([`Plan::stage_twiddles_soa`]) so the innermost loops read stride-1
//!    lanes. Small stages iterate rows innermost at a fixed `(stage, k)`
//!    to amortize twiddle loads; large stages iterate `k` innermost so the
//!    four element streams stay stride-±1 for the autovectorizer.
//!
//! 3. **Pooled row parallelism.** Batches above a tunable work threshold
//!    split into contiguous row chunks dispatched as jobs on a persistent
//!    [`WorkerPool`] (parked OS threads, no external crates) — by default
//!    the process-wide pool, or the one carried by an explicit
//!    [`ExecCtx`] (`*_ctx` entry points). Thresholds are chosen so
//!    `batch = 1` latency never touches the pool, and every worker chunk
//!    has enough rows to amortize a wakeup. The pre-pool per-call
//!    [`std::thread::scope`] path survives as the `*_scoped` fallback
//!    oracle (benches compare pool-vs-scoped; tests assert bitwise
//!    agreement).
//!
//! 4. **SIMD lane kernels with runtime dispatch.** Inside every row tile,
//!    the 4-group butterflies and the packed spectral products run as
//!    width-4 lane quads ([`super::simd`]): AVX2+FMA on x86_64 when the
//!    CPU has it, a bit-identical portable quad arm otherwise, and the
//!    legacy scalar loops behind [`EngineConfig::force_scalar`] (or the
//!    process-wide `--force-scalar` / `RDFFT_FORCE_SCALAR=1` overrides)
//!    as the always-available differential oracle. The arm is resolved
//!    once per call, so results are deterministic across repeats, pool
//!    sizes, and thread counts.
//!
//! See `EXPERIMENTS.md` §Perf for the measured ablation and
//! `BENCH_rdfft.json` for the machine-readable numbers.

use super::plan::Plan;
use super::simd::{self, Kernels};
use super::spectral;
use crate::runtime::pool::{ExecCtx, WorkerPool};

/// Tuning knobs for the batch engine. [`EngineConfig::default`] is what
/// the public batch entry points use; benches and tests construct
/// explicit configs to pin a specific execution mode.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Rows per cache tile in the batch-major stage sweep.
    pub tile_rows: usize,
    /// Minimum total elements (`rows * n`) before threads are considered.
    pub par_min_elems: usize,
    /// Minimum rows before threads are considered (also the floor that
    /// keeps single-row latency on the spawn-free path).
    pub par_min_rows: usize,
    /// Target elements per worker chunk: the batch is split into at most
    /// `total_elems / par_chunk_elems` chunks (capped by core count).
    pub par_chunk_elems: usize,
    /// Hard cap on parallel chunks per call (including the calling
    /// thread's). 0 = `available_parallelism()`; an explicit value is
    /// trusted as-is so `--threads N` means N on every machine.
    pub max_threads: usize,
    /// Route every butterfly/product kernel of this call through the
    /// legacy scalar loops instead of the runtime-dispatched SIMD lanes
    /// ([`crate::rdfft::simd`]) — the differential oracle, bit-identical
    /// to the pre-SIMD engine. The process-wide overrides (`--force-scalar`,
    /// `RDFFT_FORCE_SCALAR=1`) force the same arm for calls that never see
    /// a config.
    pub force_scalar: bool,
    /// Transform sizes `n ≥` this run the four-step (Bailey) large-n path
    /// ([`super::fourstep`]) instead of the direct stage sweep — provided
    /// the plan carries factorization tables
    /// ([`crate::rdfft::plan::FOURSTEP_MIN_N`]). Default 16 Ki: below it
    /// the direct tile sweep is cache-resident and faster; above it the
    /// per-stage full-buffer streams go memory-bandwidth bound. Tests pin
    /// `1` (always four-step) or `usize::MAX` (always direct).
    pub fourstep_threshold: usize,
    /// Cap on the SIMD lane width this call may dispatch (0 = no cap):
    /// `4` demotes the 256-bit width-8 arm to the 128-bit quad arm,
    /// `1..=3` forces the legacy scalar loops. The `simd8_vs_simd4` bench
    /// rows pin widths with this; `force_scalar` still wins.
    pub max_simd_width: usize,
}

impl EngineConfig {
    /// Default thresholds: threads only when there are ≥ 4 rows and the
    /// whole batch is ≥ 32 Ki elements (≈ 128 KiB), with ≥ 16 Ki elements
    /// of work per spawned worker.
    pub const fn new() -> Self {
        EngineConfig {
            tile_rows: 8,
            par_min_elems: 1 << 15,
            par_min_rows: 4,
            par_chunk_elems: 1 << 14,
            max_threads: 0,
            force_scalar: false,
            fourstep_threshold: 1 << 14,
            max_simd_width: 0,
        }
    }

    /// A config that never spawns threads (pure batch-major execution);
    /// used by the ablation bench to separate layout wins from
    /// parallelism wins.
    pub const fn serial() -> Self {
        EngineConfig {
            tile_rows: 8,
            par_min_elems: 1 << 15,
            par_min_rows: usize::MAX,
            par_chunk_elems: 1 << 14,
            max_threads: 0,
            force_scalar: false,
            fourstep_threshold: 1 << 14,
            max_simd_width: 0,
        }
    }

    /// Default tuning with the SIMD dispatch disabled: every kernel runs
    /// the legacy scalar loops. This is the per-call oracle knob the
    /// differential suite and the `simd_vs_scalar` bench rows use.
    pub const fn forced_scalar() -> Self {
        EngineConfig {
            tile_rows: 8,
            par_min_elems: 1 << 15,
            par_min_rows: 4,
            par_chunk_elems: 1 << 14,
            max_threads: 0,
            force_scalar: true,
            fourstep_threshold: 1 << 14,
            max_simd_width: 0,
        }
    }

    /// Serial tuning with SIMD disabled (scalar kernels, no threads).
    pub const fn forced_scalar_serial() -> Self {
        EngineConfig {
            tile_rows: 8,
            par_min_elems: 1 << 15,
            par_min_rows: usize::MAX,
            par_chunk_elems: 1 << 14,
            max_threads: 0,
            force_scalar: true,
            fourstep_threshold: 1 << 14,
            max_simd_width: 0,
        }
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig::new()
    }
}

/// Stages with half-block `m` at or below this bound run rows innermost
/// (twiddle-amortizing); larger stages run `k` innermost (stride-1 SIMD
/// lanes). `m = 32` keeps the row-inner working set per block within a
/// few cache lines per row.
const SMALL_M: usize = 32;

/// Forward-transform `batch` contiguous rows of length `plan.n()` in
/// place with default tuning. Equivalent to per-row
/// [`super::forward::rdfft_inplace`] (bit-for-bit: the same float ops in
/// the same per-element order).
pub fn forward_batch(plan: &Plan, buf: &mut [f32]) {
    forward_batch_with(plan, buf, &EngineConfig::new());
}

/// Inverse-transform `batch` contiguous rows in place, default tuning.
pub fn inverse_batch(plan: &Plan, buf: &mut [f32]) {
    inverse_batch_with(plan, buf, &EngineConfig::new());
}

/// [`forward_batch`] with explicit tuning (dispatched on the global pool).
pub fn forward_batch_with(plan: &Plan, buf: &mut [f32], cfg: &EngineConfig) {
    run_transform(plan, buf, cfg, Dispatch::global(), true);
}

/// [`inverse_batch`] with explicit tuning (dispatched on the global pool).
pub fn inverse_batch_with(plan: &Plan, buf: &mut [f32], cfg: &EngineConfig) {
    run_transform(plan, buf, cfg, Dispatch::global(), false);
}

/// [`forward_batch`] under an explicit [`ExecCtx`]: that context's pool
/// and engine tuning decide the dispatch.
pub fn forward_batch_ctx(plan: &Plan, buf: &mut [f32], ctx: &ExecCtx) {
    run_transform(plan, buf, ctx.engine_config(), Dispatch::from_ctx(ctx), true);
}

/// [`inverse_batch`] under an explicit [`ExecCtx`].
pub fn inverse_batch_ctx(plan: &Plan, buf: &mut [f32], ctx: &ExecCtx) {
    run_transform(plan, buf, ctx.engine_config(), Dispatch::from_ctx(ctx), false);
}

/// [`forward_batch_with`] on per-call scoped threads — the pre-pool
/// execution path, kept as the differential oracle and as the bench
/// baseline the pool rows are judged against. Numerics are identical to
/// the pooled path (same chunking, same kernels; only *where* a chunk
/// runs differs).
pub fn forward_batch_scoped(plan: &Plan, buf: &mut [f32], cfg: &EngineConfig) {
    run_transform(plan, buf, cfg, Dispatch::Scoped, true);
}

/// [`inverse_batch_with`] on per-call scoped threads (fallback oracle).
pub fn inverse_batch_scoped(plan: &Plan, buf: &mut [f32], cfg: &EngineConfig) {
    run_transform(plan, buf, cfg, Dispatch::Scoped, false);
}

/// Which execution tier a size-dispatched transform actually ran —
/// the answer to "did the bench row measure what its label claims?".
/// The silent-fallback bug this fixes: `run_transform` used to route to
/// the direct sweep with no signal when `n ≥ cfg.fourstep_threshold`
/// but the plan cannot carry tables (`n < FOURSTEP_MIN_N`), so a bench
/// grid pinning `fourstep_threshold: 1` at small n would time
/// direct-vs-direct and report it as a four-step speedup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Four-step (Bailey) large-n path: threshold met, tables engaged.
    FourStep,
    /// Direct tile sweep: `n < cfg.fourstep_threshold` (the intended
    /// small-n route).
    Direct,
    /// Direct tile sweep reached as a **fallback**: the threshold asked
    /// for four-step but `n < FOURSTEP_MIN_N` has no factorization, so
    /// the call cannot engage the tier it was configured for.
    DirectFallback,
}

impl Tier {
    /// Stable label for bench rows / JSON (`"fourstep"`, `"direct"`,
    /// `"direct_fallback"`).
    pub fn name(self) -> &'static str {
        match self {
            Tier::FourStep => "fourstep",
            Tier::Direct => "direct",
            Tier::DirectFallback => "direct_fallback",
        }
    }
}

/// Per-thread tally of which tiers [`run_transform`] dispatched.
/// Thread-local on purpose: the counters exist so a *measuring* caller
/// (bench cell, smoke check, test) can assert what ran on its own
/// thread, without cross-test races or atomic traffic on the hot path.
/// Note the tier decision happens on the submitting thread before any
/// pool fan-out, so the submitting thread's tally sees every dispatch
/// it caused.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TierCounts {
    /// Transforms that ran the four-step tier.
    pub fourstep: usize,
    /// Transforms that ran the direct sweep by size choice.
    pub direct: usize,
    /// Transforms that *asked* for four-step but fell back to direct.
    pub fallback: usize,
}

impl TierCounts {
    /// Counts accumulated since an `earlier` snapshot.
    pub fn since(self, earlier: TierCounts) -> TierCounts {
        TierCounts {
            fourstep: self.fourstep - earlier.fourstep,
            direct: self.direct - earlier.direct,
            fallback: self.fallback - earlier.fallback,
        }
    }
}

thread_local! {
    static TIERS: std::cell::Cell<TierCounts> = const { std::cell::Cell::new(TierCounts {
        fourstep: 0,
        direct: 0,
        fallback: 0,
    }) };
}

/// Snapshot of this thread's tier dispatch tally (monotonic; diff two
/// snapshots with [`TierCounts::since`] to attribute a measured region).
pub fn tier_counts() -> TierCounts {
    TIERS.with(|t| t.get())
}

#[inline]
fn note_tier(tier: Tier) {
    TIERS.with(|t| {
        let mut c = t.get();
        match tier {
            Tier::FourStep => c.fourstep += 1,
            Tier::Direct => c.direct += 1,
            Tier::DirectFallback => c.fallback += 1,
        }
        t.set(c);
    });
}

/// Size-dispatched transform behind every plain batch entry point: the
/// four-step (Bailey) tier when `n ≥ cfg.fourstep_threshold` and the
/// plan can carry factorization tables (materialized lazily on this
/// first dispatch), the direct tile sweep otherwise. Returns — and
/// tallies, per thread — the [`Tier`] that actually ran, so measuring
/// callers can detect the threshold-met-but-no-tables fallback instead
/// of silently timing the wrong kernel.
/// The fused circulant/block sweeps stay on the direct kernels — they
/// operate *on* the packed spectra both tiers produce, so the large-n
/// tier composes with them unchanged.
fn run_transform(
    plan: &Plan,
    buf: &mut [f32],
    cfg: &EngineConfig,
    disp: Dispatch<'_>,
    forward: bool,
) -> Tier {
    let tier = if plan.n() >= cfg.fourstep_threshold {
        if let Some(fs) = plan.fourstep_lazy() {
            super::fourstep::run_fourstep(plan, fs, buf, cfg, disp, forward);
            note_tier(Tier::FourStep);
            return Tier::FourStep;
        }
        Tier::DirectFallback
    } else {
        Tier::Direct
    };
    if forward {
        run_batch(plan, buf, cfg, disp, forward_rows_with);
    } else {
        run_batch(plan, buf, cfg, disp, inverse_rows_with);
    }
    note_tier(tier);
    tier
}

// ---------------------------------------------------------------------
// Fused circulant pipeline
// ---------------------------------------------------------------------

/// Which packed spectral product the fused circulant pipeline applies
/// between the forward and inverse butterfly stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpectralOp {
    /// `roŵ ⊙ spec` — the Eq. 4 forward product.
    Mul,
    /// `roŵ ⊙ conj(spec)` — the Eq. 5 transpose/backward product.
    MulConjB,
}

/// Fused circulant application: every contiguous length-`plan.n()` row of
/// `buf` becomes `IFFT(op(spec) ⊙ FFT(row))`, sweeping each row tile
/// **once** — forward butterfly stages, packed conjugate-symmetric
/// product, inverse stages, all while the tile is cache-resident —
/// instead of the unfused pipeline's three full passes over the buffer
/// (forward batch, product sweep, inverse batch). Numerics are
/// bit-identical to the unfused path (same float ops per element, same
/// order), and nothing is allocated after plan construction.
pub fn circulant_apply_batch(plan: &Plan, buf: &mut [f32], spec: &[f32], op: SpectralOp) {
    circulant_apply_batch_with(plan, buf, spec, op, &EngineConfig::new());
}

/// [`circulant_apply_batch`] with explicit tuning (global pool).
pub fn circulant_apply_batch_with(
    plan: &Plan,
    buf: &mut [f32],
    spec: &[f32],
    op: SpectralOp,
    cfg: &EngineConfig,
) {
    circulant_apply_dispatch(plan, buf, spec, op, cfg, Dispatch::global());
}

/// [`circulant_apply_batch`] under an explicit [`ExecCtx`].
pub fn circulant_apply_batch_ctx(
    plan: &Plan,
    buf: &mut [f32],
    spec: &[f32],
    op: SpectralOp,
    ctx: &ExecCtx,
) {
    circulant_apply_dispatch(plan, buf, spec, op, ctx.engine_config(), Dispatch::from_ctx(ctx));
}

/// [`circulant_apply_batch_with`] on per-call scoped threads (fallback
/// oracle / bench baseline).
pub fn circulant_apply_batch_scoped(
    plan: &Plan,
    buf: &mut [f32],
    spec: &[f32],
    op: SpectralOp,
    cfg: &EngineConfig,
) {
    circulant_apply_dispatch(plan, buf, spec, op, cfg, Dispatch::Scoped);
}

fn circulant_apply_dispatch(
    plan: &Plan,
    buf: &mut [f32],
    spec: &[f32],
    op: SpectralOp,
    cfg: &EngineConfig,
    disp: Dispatch<'_>,
) {
    assert_eq!(spec.len(), plan.n(), "spectrum length must equal plan size");
    run_batch(
        plan,
        buf,
        cfg,
        disp,
        move |plan: &Plan, chunk: &mut [f32], tile_rows: usize, kern: Kernels| {
            circulant_rows(plan, chunk, tile_rows, spec, op, kern);
        },
    );
}

/// One worker's share of the fused pipeline: per tile, forward stages →
/// packed product → inverse stages in a single sweep. Composes the same
/// [`forward_rows_with`]/[`inverse_rows_with`] kernels as the plain batch
/// paths (each tile is exactly one of their tiles) on the same dispatch
/// arm, so the fused path can never diverge from
/// `forward_batch`/`inverse_batch` numerics.
fn circulant_rows(
    plan: &Plan,
    buf: &mut [f32],
    tile_rows: usize,
    spec: &[f32],
    op: SpectralOp,
    kern: Kernels,
) {
    let n = plan.n();
    for tile in buf.chunks_mut(tile_rows.max(1) * n) {
        forward_rows_with(plan, tile, tile_rows, kern);
        match op {
            SpectralOp::Mul => spectral::mul_rows_with(kern, tile, spec),
            SpectralOp::MulConjB => spectral::mul_conjb_rows_with(kern, tile, spec),
        }
        inverse_rows_with(plan, tile, tile_rows, kern);
    }
}

/// Fused **block-circulant** forward sweep (Eq. 4 blockwise): `x` holds
/// one or more samples of `cb` contiguous length-`n` input blocks, `out`
/// the matching samples of `rb` output blocks, and `specs` the packed
/// block spectra `ĉ[(i·cb + j)·n ..][..n]`. Per sample, in one
/// cache-resident sweep: the sample's input blocks are forward-staged in
/// place (so `x` ends holding x̂ — exactly the saved-for-backward tensor),
/// the packed products accumulate into the sample's output blocks (zeroed
/// here), and the output blocks are inverse-staged. Zero allocations.
pub fn block_circulant_forward_batch(
    plan: &Plan,
    x: &mut [f32],
    out: &mut [f32],
    specs: &[f32],
    rb: usize,
    cb: usize,
) {
    block_apply(plan, x, out, specs, rb, cb, false, false, &EngineConfig::new(), Dispatch::global());
}

/// [`block_circulant_forward_batch`] with explicit tuning (global pool).
pub fn block_circulant_forward_batch_with(
    plan: &Plan,
    x: &mut [f32],
    out: &mut [f32],
    specs: &[f32],
    rb: usize,
    cb: usize,
    cfg: &EngineConfig,
) {
    block_apply(plan, x, out, specs, rb, cb, false, false, cfg, Dispatch::global());
}

/// [`block_circulant_forward_batch`] under an explicit [`ExecCtx`].
pub fn block_circulant_forward_batch_ctx(
    plan: &Plan,
    x: &mut [f32],
    out: &mut [f32],
    specs: &[f32],
    rb: usize,
    cb: usize,
    ctx: &ExecCtx,
) {
    block_apply(
        plan, x, out, specs, rb, cb, false, false,
        ctx.engine_config(), Dispatch::from_ctx(ctx),
    );
}

/// [`block_circulant_forward_batch`] with the frequency-domain residual
/// `out_j += x̂_j` added before the inverse stages — computes
/// `out = x + W x` per sample with **no** time-domain skip copy (the
/// transform is linear, so adding spectra before one shared inverse is
/// exact up to float rounding). Requires a square block layout
/// (`rb == cb`).
pub fn block_circulant_forward_residual_batch(
    plan: &Plan,
    x: &mut [f32],
    out: &mut [f32],
    specs: &[f32],
    rb: usize,
    cb: usize,
) {
    assert_eq!(rb, cb, "the freq-domain residual needs a square block layout");
    block_apply(plan, x, out, specs, rb, cb, false, true, &EngineConfig::new(), Dispatch::global());
}

/// [`block_circulant_forward_residual_batch`] under an explicit
/// [`ExecCtx`].
pub fn block_circulant_forward_residual_batch_ctx(
    plan: &Plan,
    x: &mut [f32],
    out: &mut [f32],
    specs: &[f32],
    rb: usize,
    cb: usize,
    ctx: &ExecCtx,
) {
    assert_eq!(rb, cb, "the freq-domain residual needs a square block layout");
    block_apply(
        plan, x, out, specs, rb, cb, false, true,
        ctx.engine_config(), Dispatch::from_ctx(ctx),
    );
}

/// Fused block-circulant **transpose** sweep (the Eq. 5 input-gradient
/// product): `g` holds samples of `rb` grad-output blocks, `dx` the
/// matching samples of `cb` input-gradient blocks. Per sample, one sweep:
/// `g`'s blocks are forward-staged in place (so `g` ends holding ĝ —
/// which the caller's dĉ accumulation needs anyway), the conjugated
/// products `conj(ĉ_ij) ⊙ ĝ_i` accumulate into the zeroed `dx` blocks,
/// and the `dx` blocks are inverse-staged. Zero allocations.
pub fn block_circulant_transpose_batch(
    plan: &Plan,
    g: &mut [f32],
    dx: &mut [f32],
    specs: &[f32],
    rb: usize,
    cb: usize,
) {
    block_apply(plan, g, dx, specs, rb, cb, true, false, &EngineConfig::new(), Dispatch::global());
}

/// [`block_circulant_transpose_batch`] under an explicit [`ExecCtx`].
pub fn block_circulant_transpose_batch_ctx(
    plan: &Plan,
    g: &mut [f32],
    dx: &mut [f32],
    specs: &[f32],
    rb: usize,
    cb: usize,
    ctx: &ExecCtx,
) {
    block_apply(
        plan, g, dx, specs, rb, cb, true, false,
        ctx.engine_config(), Dispatch::from_ctx(ctx),
    );
}

/// Shared fused block sweep behind the three public block entries.
/// `transpose` selects direction (input blocks = rb grad blocks, output
/// blocks = cb input-grad blocks, conjugated products); `residual` adds
/// the input spectra into the matching output blocks before the inverse.
#[allow(clippy::too_many_arguments)]
fn block_apply(
    plan: &Plan,
    input: &mut [f32],
    out: &mut [f32],
    specs: &[f32],
    rb: usize,
    cb: usize,
    transpose: bool,
    residual: bool,
    cfg: &EngineConfig,
    disp: Dispatch<'_>,
) {
    let n = plan.n();
    let (in_blocks, out_blocks) = if transpose { (rb, cb) } else { (cb, rb) };
    assert!(in_blocks > 0 && out_blocks > 0, "block counts must be positive");
    assert_eq!(specs.len(), rb * cb * n, "spec length must be rb*cb*n");
    assert!(input.len() % (in_blocks * n) == 0, "input must be whole samples");
    let samples = input.len() / (in_blocks * n);
    assert_eq!(out.len(), samples * out_blocks * n, "output/input sample counts must match");
    if residual {
        assert_eq!(in_blocks, out_blocks, "residual requires square block layout");
    }
    if samples == 0 {
        return;
    }
    let in_row = in_blocks * n;
    let out_row = out_blocks * n;
    // Thread planning counts the whole sweep's row-transform work
    // (in + out blocks per sample), capped by the sample count since
    // samples are the split unit. The kernel arm is resolved once here
    // and shared by every chunk, so all workers run identical float ops.
    let kern = simd::select_width(cfg.force_scalar, cfg.max_simd_width);
    let workers =
        planned_workers(samples * (in_blocks + out_blocks), n, cfg).min(samples);
    let sweep = move |xs: &mut [f32], os: Option<&mut [f32]>| {
        let os = os.expect("block sweep chunks always pair input with output");
        for (s_in, s_out) in xs.chunks_exact_mut(in_row).zip(os.chunks_exact_mut(out_row)) {
            block_apply_sample(plan, s_in, s_out, specs, cb, transpose, residual, kern);
        }
    };
    if workers <= 1 {
        sweep(input, Some(out));
        return;
    }
    let chunk = (samples + workers - 1) / workers;
    dispatch_rows(disp, input, Some(out), chunk * in_row, chunk * out_row, sweep);
}

/// One sample of the fused block sweep: forward-stage the input blocks
/// (kept as spectra), product-accumulate into the zeroed output blocks
/// (+ optional freq-domain residual), inverse-stage the output blocks —
/// all while the sample is cache-resident. Butterflies and products all
/// run on the one `kern` arm the caller resolved.
#[allow(clippy::too_many_arguments)]
fn block_apply_sample(
    plan: &Plan,
    input: &mut [f32],
    out: &mut [f32],
    specs: &[f32],
    cb: usize,
    transpose: bool,
    residual: bool,
    kern: Kernels,
) {
    let n = plan.n();
    let in_blocks = input.len() / n;
    forward_rows_with(plan, input, in_blocks.max(1), kern);
    out.fill(0.0);
    for (oi, ob) in out.chunks_exact_mut(n).enumerate() {
        for (ii, xb) in input.chunks_exact(n).enumerate() {
            // Weight-layout spec index: row block i, column block j.
            let (i, j) = if transpose { (ii, oi) } else { (oi, ii) };
            let ch = &specs[(i * cb + j) * n..][..n];
            if transpose {
                spectral::conj_mul_acc_with(kern, ob, ch, xb);
            } else {
                spectral::mul_acc_with(kern, ob, ch, xb);
            }
        }
        if residual {
            let xb = &input[oi * n..(oi + 1) * n];
            for (o, v) in ob.iter_mut().zip(xb) {
                *o += v;
            }
        }
    }
    let out_blocks = out.len() / n;
    inverse_rows_with(plan, out, out_blocks.max(1), kern);
}

/// Execution backend for one threaded engine call. The pool is the
/// production path; per-call scoped threads are the pre-pool fallback
/// oracle, kept for differential benches/tests.
#[derive(Clone, Copy)]
pub(crate) enum Dispatch<'a> {
    /// Jobs on the process-wide pool, **resolved only at fan-out time**:
    /// serial calls (below the work thresholds) never spawn it.
    Global,
    /// Jobs on a specific persistent [`WorkerPool`].
    Pool(&'a WorkerPool),
    /// One `std::thread::scope` spawn per chunk (the old behaviour).
    Scoped,
}

impl<'a> Dispatch<'a> {
    /// The process-wide default pool (lazy).
    pub(crate) fn global() -> Dispatch<'static> {
        Dispatch::Global
    }

    /// A context's dispatch: its dedicated pool, or the lazy global one.
    pub(crate) fn from_ctx(ctx: &'a ExecCtx) -> Dispatch<'a> {
        match ctx.dedicated_pool() {
            Some(p) => Dispatch::Pool(p),
            None => Dispatch::Global,
        }
    }
}

/// Shared driver: validate, decide serial vs parallel execution, resolve
/// the kernel arm, dispatch `kernel` over contiguous row chunks. Generic
/// so the fused circulant pipeline can close over its spectrum without
/// boxing. The arm is resolved **once per call** — every chunk of the
/// batch, on every worker, runs identical float ops.
fn run_batch<K>(plan: &Plan, buf: &mut [f32], cfg: &EngineConfig, disp: Dispatch<'_>, kernel: K)
where
    K: Fn(&Plan, &mut [f32], usize, Kernels) + Copy + Send + Sync,
{
    let n = plan.n();
    assert!(buf.len() % n == 0, "buffer length must be a multiple of plan size");
    let rows = buf.len() / n;
    if rows == 0 {
        return;
    }
    let kern = simd::select_width(cfg.force_scalar, cfg.max_simd_width);
    let workers = planned_workers(rows, n, cfg);
    let tile_rows = cfg.tile_rows;
    if workers <= 1 {
        kernel(plan, buf, tile_rows, kern);
        return;
    }
    // Contiguous row chunks; `ceil` so the chunk count never exceeds
    // `workers`. Jobs may borrow `buf` and `plan` directly: both the
    // pool scope and thread::scope guarantee completion before return.
    let chunk_rows = (rows + workers - 1) / workers;
    dispatch_rows(disp, buf, None, chunk_rows * n, 0, move |chunk, _| {
        kernel(plan, chunk, tile_rows, kern)
    });
}

/// The one chunking/dispatch loop behind every threaded engine path
/// (deduplicating the two near-identical spawn loops `run_batch` and
/// `block_apply` used to carry): split `input` — and, for the block
/// sweeps, the parallel `out` buffer — into contiguous chunks of
/// `chunk_in`/`chunk_out` elements, run all but the last chunk on the
/// selected backend, and the final chunk on the calling thread (one
/// fewer dispatch; on the pool path the calling thread additionally
/// helps drain its own queued chunks while waiting).
pub(crate) fn dispatch_rows<J>(
    disp: Dispatch<'_>,
    input: &mut [f32],
    out: Option<&mut [f32]>,
    chunk_in: usize,
    chunk_out: usize,
    job: J,
) where
    J: Fn(&mut [f32], Option<&mut [f32]>) + Copy + Send + Sync,
{
    debug_assert!(chunk_in > 0, "chunk size must be positive");
    match disp {
        // Resolve (and, on first use, spawn) the process-wide pool only
        // here — a call that stays serial never reaches this point.
        Dispatch::Global => dispatch_rows(
            Dispatch::Pool(WorkerPool::global().as_ref()),
            input,
            out,
            chunk_in,
            chunk_out,
            job,
        ),
        // audit: allow(no-raw-threads) the scoped arm is the differential oracle the pool path is verified against; it must stay on std scoped threads
        Dispatch::Scoped => std::thread::scope(|s| {
            let (ri, ro) = split_chunks(input, out, chunk_in, chunk_out, |ci, co| {
                s.spawn(move || job(ci, co));
            });
            job(ri, ro);
        }),
        Dispatch::Pool(pool) => {
            let done = pool.scope(|sc| {
                let (ri, ro) = split_chunks(input, out, chunk_in, chunk_out, |ci, co| {
                    sc.submit(move || job(ci, co));
                });
                job(ri, ro);
            });
            if let Err(p) = done {
                // Mirror thread::scope: a panicking chunk kernel panics
                // the submitting call (the pool itself stays healthy).
                p.resume();
            }
        }
    }
}

/// The chunk-splitting walk shared by both dispatch backends (so the
/// scoped oracle and the pool path can never drift apart in how they
/// pair input/output chunks): hands every full chunk to `spawn` and
/// returns the final (possibly short) chunk for the calling thread.
fn split_chunks<'a>(
    mut rest_in: &'a mut [f32],
    mut rest_out: Option<&'a mut [f32]>,
    chunk_in: usize,
    chunk_out: usize,
    mut spawn: impl FnMut(&'a mut [f32], Option<&'a mut [f32]>),
) -> (&'a mut [f32], Option<&'a mut [f32]>) {
    while rest_in.len() > chunk_in {
        let (ci, ti) = std::mem::take(&mut rest_in).split_at_mut(chunk_in);
        let co = match rest_out.take() {
            Some(o) => {
                let (co, to) = o.split_at_mut(chunk_out);
                rest_out = Some(to);
                Some(co)
            }
            None => None,
        };
        spawn(ci, co);
        rest_in = ti;
    }
    (rest_in, rest_out)
}

/// Indexed sibling of [`dispatch_rows`] for callers whose parallel units
/// are not contiguous buffer chunks (the four-step panel sweep: a worker
/// owns a strided set of `(row, panel)` units sharing one buffer through
/// disjoint columns). Runs `job(w)` for every `w` in `0..workers` on the
/// selected backend — the last index on the calling thread, the rest as
/// pool jobs / scoped spawns. `workers` is expected to be small (it is a
/// thread count, not a unit count).
pub(crate) fn dispatch_span<J>(disp: Dispatch<'_>, workers: usize, job: J)
where
    J: Fn(usize) + Copy + Send + Sync,
{
    if workers <= 1 {
        if workers == 1 {
            job(0);
        }
        return;
    }
    match disp {
        Dispatch::Global => {
            dispatch_span(Dispatch::Pool(WorkerPool::global().as_ref()), workers, job)
        }
        // audit: allow(no-raw-threads) the scoped arm is the differential oracle the pool path is verified against; it must stay on std scoped threads
        Dispatch::Scoped => std::thread::scope(|s| {
            for w in 0..workers - 1 {
                s.spawn(move || job(w));
            }
            job(workers - 1);
        }),
        Dispatch::Pool(pool) => {
            let done = pool.scope(|sc| {
                for w in 0..workers - 1 {
                    sc.submit(move || job(w));
                }
                job(workers - 1);
            });
            if let Err(p) = done {
                // Mirror thread::scope: a panicking unit panics the
                // submitting call (the pool itself stays healthy).
                p.resume();
            }
        }
    }
}

/// True when a batch of `rows` length-`n` rows would split across worker
/// threads under default tuning. Fused per-sample callers that cannot
/// parallelize internally (shared accumulators/workspaces) use this to
/// fall back to the threaded whole-tensor passes on big batches instead
/// of silently serializing them.
pub fn default_would_thread(rows: usize, n: usize) -> bool {
    planned_workers(rows, n, &EngineConfig::new()) > 1
}

/// How many workers (including the calling thread) the batch should use.
pub(crate) fn planned_workers(rows: usize, n: usize, cfg: &EngineConfig) -> usize {
    let total = rows * n;
    if rows < cfg.par_min_rows || total < cfg.par_min_elems {
        return 1;
    }
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    // An explicit cap is trusted as-is (not clamped to the core count):
    // the thread-scaling bench grid and `ExecCtx::with_threads(N)` must
    // mean N on every machine, and chunks beyond the pool's capacity
    // simply queue.
    let cap = if cfg.max_threads == 0 { cores } else { cfg.max_threads };
    let by_work = (total / cfg.par_chunk_elems.max(1)).max(1);
    by_work.min(cap).min(rows)
}

// ---------------------------------------------------------------------
// Per-chunk kernels
// ---------------------------------------------------------------------

/// Forward kernel over one contiguous chunk of rows: fused bit-reversal +
/// first two stages per row, then tiled batch-major stages. Public so
/// fused consumers (the circulant pipeline, the layer backward) can
/// compose it with their own product stages without a thread dispatch;
/// runs on the auto-dispatched kernel arm ([`simd::active`]).
pub fn forward_rows(plan: &Plan, buf: &mut [f32], tile_rows: usize) {
    forward_rows_with(plan, buf, tile_rows, simd::active());
}

/// [`forward_rows`] on an explicit kernel arm (what `run_batch` resolves
/// from [`EngineConfig::force_scalar`]).
// audit: no_alloc
pub fn forward_rows_with(plan: &Plan, buf: &mut [f32], tile_rows: usize, kern: Kernels) {
    let n = plan.n();
    // Pass 1 (per row): fused bit-reversal + stages m = 1, 2. Trivial
    // twiddles (±1, ∓i) — identical scalar ops on every dispatch arm.
    for row in buf.chunks_exact_mut(n) {
        fused_bitrev_stage12(plan, row);
    }
    // Pass 2 (per row tile): remaining stages, batch-major.
    if n > 4 {
        for tile in buf.chunks_mut(tile_rows.max(1) * n) {
            forward_stages_tile(plan, tile, kern);
        }
    }
}

/// Inverse kernel over one contiguous chunk of rows. Mirrors
/// [`forward_rows`] in reverse: tiled stages down to m = 4, then a fused
/// per-row undo of stages m = 2, 1, then the bit-reversal. Public for the
/// same fused consumers as [`forward_rows`]; auto-dispatched arm.
pub fn inverse_rows(plan: &Plan, buf: &mut [f32], tile_rows: usize) {
    inverse_rows_with(plan, buf, tile_rows, simd::active());
}

/// [`inverse_rows`] on an explicit kernel arm.
// audit: no_alloc
pub fn inverse_rows_with(plan: &Plan, buf: &mut [f32], tile_rows: usize, kern: Kernels) {
    let n = plan.n();
    if n > 4 {
        for tile in buf.chunks_mut(tile_rows.max(1) * n) {
            inverse_stages_tile(plan, tile, kern);
        }
    }
    for row in buf.chunks_exact_mut(n) {
        fused_inverse_stage21(row, n);
        // The trailing permutation cannot be interleaved with the
        // butterfly undo (a swap may read a 4-block that is not yet
        // undone), so the inverse keeps it as its own pass.
        plan.bit_reverse(row);
    }
}

/// One pass over `row`: the in-place bit-reversal fused with the m = 1
/// and m = 2 butterfly stages.
///
/// Correctness of the interleave: in the ascending in-place swap loop
/// (`swap(i, rev(i))` iff `i < rev(i)`), every position `p` changes
/// exactly once, at step `min(p, rev(p))` — so after the four swaps of an
/// aligned 4-block `[4u, 4u+4)` the block holds its final pre-stage
/// values, and no later swap step `i' > 4u+3` can read or write inside
/// the block again (a swap touches `i'` and `rev(i') > i'` only). The two
/// trivial-twiddle stages can therefore run on the block immediately,
/// while its values are hot.
// audit: no_alloc
pub fn fused_bitrev_stage12(plan: &Plan, row: &mut [f32]) {
    let n = plan.n();
    debug_assert_eq!(row.len(), n);
    if n == 2 {
        let (a, b) = (row[0], row[1]);
        row[0] = a + b;
        row[1] = a - b;
        return;
    }
    let rev = plan.rev();
    let mut s = 0usize;
    while s < n {
        for i in s..s + 4 {
            let j = rev[i] as usize;
            if i < j {
                row.swap(i, j);
            }
        }
        let (x0, x1, x2, x3) = (row[s], row[s + 1], row[s + 2], row[s + 3]);
        // m = 1 on pairs: packed 2-point spectra [DC, Nyquist].
        let (a, b) = (x0 + x1, x0 - x1);
        let (c, d) = (x2 + x3, x2 - x3);
        // m = 2: k = 0 lane combines the two DCs; the sub-Nyquist lane
        // (y_1 = e - i·o) flips the sign of the odd block's Nyquist slot.
        row[s] = a + c;
        row[s + 1] = b;
        row[s + 2] = a - c;
        row[s + 3] = -d;
        s += 4;
    }
}

/// One pass over `row`: undo stage m = 2 then m = 1 (the exact inverse of
/// the butterfly half of [`fused_bitrev_stage12`]; the caller applies the
/// bit-reversal afterwards).
// audit: no_alloc
pub fn fused_inverse_stage21(row: &mut [f32], n: usize) {
    debug_assert_eq!(row.len(), n);
    if n == 2 {
        let (a, b) = (row[0], row[1]);
        row[0] = 0.5 * (a + b);
        row[1] = 0.5 * (a - b);
        return;
    }
    let mut s = 0usize;
    while s < n {
        let (y0, y1, y2, y3) = (row[s], row[s + 1], row[s + 2], row[s + 3]);
        // Undo m = 2: recover the two packed 2-point spectra.
        let a = 0.5 * (y0 + y2);
        let c = 0.5 * (y0 - y2);
        let b = y1;
        let d = -y3;
        // Undo m = 1 on both pairs.
        row[s] = 0.5 * (a + b);
        row[s + 1] = 0.5 * (a - b);
        row[s + 2] = 0.5 * (c + d);
        row[s + 3] = 0.5 * (c - d);
        s += 4;
    }
}

/// Forward stages m = 4 .. n/2 over a tile of rows, batch-major.
///
/// Two kernel arms: [`Kernels::LegacyScalar`] runs the pre-SIMD loops
/// byte-for-byte (row-inner below [`SMALL_M`], k-inner above); the lane
/// arms hand each row block's 4-group sweep to the width-4 quad kernels
/// ([`simd::fwd_groups_dispatch`]) fed by the plan's lane-padded
/// stage-major twiddles. Groups at different `k` are slot-disjoint, so
/// the quad split never reorders any per-element op — the portable lane
/// arm stays bit-identical to the scalar one; only FMA contraction on
/// the AVX arm can differ (within the documented tolerance).
// audit: no_alloc
pub(crate) fn forward_stages_tile(plan: &Plan, tile: &mut [f32], kern: Kernels) {
    let n = plan.n();
    let rows = tile.len() / n;
    debug_assert_eq!(tile.len(), rows * n);
    let mut m = 4usize;
    while m < n {
        let (wr, wi) = plan.stage_twiddles_soa(m);
        let two_m = 2 * m;
        let half = m / 2;
        let mut s = 0usize;
        while s < n {
            // Trivial lanes (k = 0 DC/Nyquist combine, k = m/2 sign
            // flip), per row — scalar on every arm.
            for r in 0..rows {
                let base = r * n + s;
                let e = tile[base];
                let o = tile[base + m];
                tile[base] = e + o;
                tile[base + m] = e - o;
                let idx = base + m + half;
                tile[idx] = -tile[idx];
            }
            // Symmetric 4-groups, 1 <= k < m/2.
            //
            // SAFETY: identical bounds argument to the scalar
            // forward_stages (all four indices lie in [base, base+two_m),
            // and base + two_m <= rows*n because s + two_m <= n), lifted
            // over `rows` rows. Bounds checks cost ~25% here (see
            // EXPERIMENTS.md §Perf).
            unsafe {
                if kern != Kernels::LegacyScalar {
                    let (lwr, lwi) = plan.stage_lane_twiddles(m);
                    for r in 0..rows {
                        let blk = tile.get_unchecked_mut(r * n + s..r * n + s + two_m);
                        simd::fwd_groups_dispatch(kern, blk, m, lwr, lwi);
                    }
                } else if m <= SMALL_M {
                    // Row-inner: one twiddle load serves every row in the
                    // tile at this (stage, k).
                    for k in 1..half {
                        let wrk = *wr.get_unchecked(k - 1);
                        let wik = *wi.get_unchecked(k - 1);
                        for r in 0..rows {
                            let blk = tile.get_unchecked_mut(r * n + s..r * n + s + two_m);
                            bf4_forward(blk, m, two_m, k, wrk, wik);
                        }
                    }
                } else {
                    // k-inner: stride-1 SoA twiddles and stride-±1
                    // element streams for the autovectorizer.
                    for r in 0..rows {
                        let blk = tile.get_unchecked_mut(r * n + s..r * n + s + two_m);
                        for k in 1..half {
                            bf4_forward(
                                blk,
                                m,
                                two_m,
                                k,
                                *wr.get_unchecked(k - 1),
                                *wi.get_unchecked(k - 1),
                            );
                        }
                    }
                }
            }
            s += two_m;
        }
        m = two_m;
    }
}

/// Inverse stages m = n/2 .. 4 over a tile of rows, batch-major (same
/// two-arm structure as [`forward_stages_tile`]).
// audit: no_alloc
pub(crate) fn inverse_stages_tile(plan: &Plan, tile: &mut [f32], kern: Kernels) {
    let n = plan.n();
    let rows = tile.len() / n;
    debug_assert_eq!(tile.len(), rows * n);
    let mut m = n / 2;
    while m >= 4 {
        let (hr, hi) = plan.stage_inv_twiddles_soa(m);
        let two_m = 2 * m;
        let half = m / 2;
        let mut s = 0usize;
        while s < n {
            for r in 0..rows {
                let base = r * n + s;
                let a = tile[base];
                let b = tile[base + m];
                tile[base] = 0.5 * (a + b);
                tile[base + m] = 0.5 * (a - b);
                let idx = base + m + half;
                tile[idx] = -tile[idx];
            }
            // SAFETY: same bounds argument as forward_stages_tile.
            unsafe {
                if kern != Kernels::LegacyScalar {
                    let (lhr, lhi) = plan.stage_lane_inv_twiddles(m);
                    for r in 0..rows {
                        let blk = tile.get_unchecked_mut(r * n + s..r * n + s + two_m);
                        simd::inv_groups_dispatch(kern, blk, m, lhr, lhi);
                    }
                } else if m <= SMALL_M {
                    for k in 1..half {
                        let hrk = *hr.get_unchecked(k - 1);
                        let hik = *hi.get_unchecked(k - 1);
                        for r in 0..rows {
                            let blk = tile.get_unchecked_mut(r * n + s..r * n + s + two_m);
                            bf4_inverse(blk, m, two_m, k, hrk, hik);
                        }
                    }
                } else {
                    for r in 0..rows {
                        let blk = tile.get_unchecked_mut(r * n + s..r * n + s + two_m);
                        for k in 1..half {
                            bf4_inverse(
                                blk,
                                m,
                                two_m,
                                k,
                                *hr.get_unchecked(k - 1),
                                *hi.get_unchecked(k - 1),
                            );
                        }
                    }
                }
            }
            s += two_m;
        }
        m /= 2;
    }
}

/// The forward symmetric 4-group butterfly (same float ops, same order as
/// the scalar path — batch outputs stay bit-identical to per-row ones).
///
/// # Safety
/// `blk` must have length `two_m` and `1 <= k < m/2` with `two_m = 2*m`.
// audit: no_alloc
#[inline(always)]
unsafe fn bf4_forward(blk: &mut [f32], m: usize, two_m: usize, k: usize, wr: f32, wi: f32) {
    debug_assert!(k >= 1 && k < m / 2 && blk.len() == two_m);
    let er = *blk.get_unchecked(k);
    let ei = *blk.get_unchecked(m - k);
    let or_ = *blk.get_unchecked(m + k);
    let oi = *blk.get_unchecked(two_m - k);
    let tr = wr * or_ - wi * oi;
    let ti = wr * oi + wi * or_;
    *blk.get_unchecked_mut(k) = er + tr;
    *blk.get_unchecked_mut(two_m - k) = ei + ti;
    *blk.get_unchecked_mut(m - k) = er - tr;
    *blk.get_unchecked_mut(m + k) = ti - ei;
}

/// The inverse symmetric 4-group butterfly (pre-halved twiddles `hr`,
/// `hi`; see [`super::inverse`]).
///
/// # Safety
/// `blk` must have length `two_m` and `1 <= k < m/2` with `two_m = 2*m`.
// audit: no_alloc
#[inline(always)]
unsafe fn bf4_inverse(blk: &mut [f32], m: usize, two_m: usize, k: usize, hr: f32, hi: f32) {
    debug_assert!(k >= 1 && k < m / 2 && blk.len() == two_m);
    let a = *blk.get_unchecked(k);
    let b = *blk.get_unchecked(m - k);
    let c = *blk.get_unchecked(two_m - k);
    let d = *blk.get_unchecked(m + k);
    let er = 0.5 * (a + b);
    let ei = 0.5 * (c - d);
    let or_ = (a - b) * hr + (c + d) * hi;
    let oi = (c + d) * hr - (a - b) * hi;
    *blk.get_unchecked_mut(k) = er;
    *blk.get_unchecked_mut(m - k) = ei;
    *blk.get_unchecked_mut(m + k) = or_;
    *blk.get_unchecked_mut(two_m - k) = oi;
}

#[cfg(test)]
mod tests {
    use super::super::forward::{rdfft_inplace, rdfft_batch_scalar};
    use super::super::inverse::{irdfft_inplace, irdfft_batch_scalar};
    use super::super::plan::cached;
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    /// A config that forces the threaded path even for tiny batches.
    fn force_threads() -> EngineConfig {
        EngineConfig {
            par_min_rows: 2,
            par_min_elems: 0,
            par_chunk_elems: 1,
            max_threads: 3,
            ..EngineConfig::new()
        }
    }

    #[test]
    fn fused_first_pass_equals_bitrev_plus_two_stages() {
        for n in [4usize, 8, 16, 64, 256] {
            let plan = cached(n);
            let x = rand_vec(n, n as u64);
            let mut fused = x.clone();
            fused_bitrev_stage12(&plan, &mut fused);
            // reference: explicit permutation, then scalar stages m=1,2
            let mut r = x.clone();
            plan.bit_reverse(&mut r);
            for blk in r.chunks_exact_mut(2) {
                let (e, o) = (blk[0], blk[1]);
                blk[0] = e + o;
                blk[1] = e - o;
            }
            if n >= 4 {
                for blk in r.chunks_exact_mut(4) {
                    let (e, o) = (blk[0], blk[2]);
                    blk[0] = e + o;
                    blk[2] = e - o;
                    blk[3] = -blk[3];
                }
            }
            assert_eq!(fused, r, "n={n}");
        }
    }

    #[test]
    fn forced_scalar_forward_batch_matches_scalar_rows_exactly() {
        // The force_scalar arm is the pre-SIMD engine, bit-for-bit equal
        // to the per-row scalar loop; the auto arm agrees within the FMA
        // tolerance (and bitwise whenever FMA lanes are not active).
        for (n, b) in [(2usize, 3usize), (4, 5), (16, 1), (64, 7), (256, 9), (1024, 4)] {
            let plan = cached(n);
            let x = rand_vec(n * b, (n + b) as u64);
            let mut scalar = x.clone();
            rdfft_batch_scalar(&plan, &mut scalar);
            let mut forced = x.clone();
            forward_batch_with(&plan, &mut forced, &EngineConfig::forced_scalar());
            assert_eq!(forced, scalar, "n={n} b={b}");
            let mut auto = x.clone();
            forward_batch(&plan, &mut auto);
            if !simd::active().uses_fma() {
                assert_eq!(auto, scalar, "non-FMA arm must be bitwise n={n} b={b}");
            }
            for i in 0..n * b {
                assert!(
                    (auto[i] - scalar[i]).abs() <= 1e-4 * (n as f32).sqrt(),
                    "n={n} b={b} i={i}"
                );
            }
        }
    }

    #[test]
    fn forced_scalar_inverse_batch_matches_scalar_rows_exactly() {
        for (n, b) in [(2usize, 3usize), (4, 5), (16, 1), (64, 7), (256, 9), (1024, 4)] {
            let plan = cached(n);
            let x = rand_vec(n * b, (2 * n + b) as u64);
            let mut scalar = x.clone();
            irdfft_batch_scalar(&plan, &mut scalar);
            let mut forced = x.clone();
            inverse_batch_with(&plan, &mut forced, &EngineConfig::forced_scalar());
            assert_eq!(forced, scalar, "n={n} b={b}");
            let mut auto = x.clone();
            inverse_batch(&plan, &mut auto);
            if !simd::active().uses_fma() {
                assert_eq!(auto, scalar, "non-FMA arm must be bitwise n={n} b={b}");
            }
            for i in 0..n * b {
                assert!(
                    (auto[i] - scalar[i]).abs() <= 1e-4 * (n as f32).sqrt().max(1.0),
                    "n={n} b={b} i={i}"
                );
            }
        }
    }

    #[test]
    fn threaded_path_matches_serial_path() {
        let cfg = force_threads();
        for (n, b) in [(8usize, 5usize), (64, 13), (256, 6)] {
            let plan = cached(n);
            let x = rand_vec(n * b, 77 + n as u64);
            let mut serial = x.clone();
            forward_batch_with(&plan, &mut serial, &EngineConfig::serial());
            let mut threaded = x.clone();
            forward_batch_with(&plan, &mut threaded, &cfg);
            assert_eq!(serial, threaded, "fwd n={n} b={b}");
            inverse_batch_with(&plan, &mut serial, &EngineConfig::serial());
            inverse_batch_with(&plan, &mut threaded, &cfg);
            assert_eq!(serial, threaded, "inv n={n} b={b}");
        }
    }

    #[test]
    fn pool_scoped_and_serial_paths_agree_bitwise() {
        // The pool is the production dispatcher, scoped threads the
        // fallback oracle: same chunking, same kernels, so all three
        // execution backends must agree bit-for-bit.
        let cfg = force_threads();
        let ctx = crate::runtime::pool::ExecCtx::with_threads(3).with_engine_config(cfg);
        for (n, b) in [(8usize, 5usize), (64, 13), (256, 6)] {
            let plan = cached(n);
            let x = rand_vec(n * b, 4242 + n as u64);
            let mut serial = x.clone();
            forward_batch_with(&plan, &mut serial, &EngineConfig::serial());
            let mut scoped = x.clone();
            forward_batch_scoped(&plan, &mut scoped, &cfg);
            let mut pooled = x.clone();
            forward_batch_ctx(&plan, &mut pooled, &ctx);
            assert_eq!(serial, scoped, "fwd scoped n={n} b={b}");
            assert_eq!(serial, pooled, "fwd pooled n={n} b={b}");
            inverse_batch_with(&plan, &mut serial, &EngineConfig::serial());
            inverse_batch_scoped(&plan, &mut scoped, &cfg);
            inverse_batch_ctx(&plan, &mut pooled, &ctx);
            assert_eq!(serial, scoped, "inv scoped n={n} b={b}");
            assert_eq!(serial, pooled, "inv pooled n={n} b={b}");
        }
    }

    #[test]
    fn pooled_block_sweeps_match_default_path() {
        let ctx = crate::runtime::pool::ExecCtx::with_threads(3)
            .with_engine_config(force_threads());
        let (rb, cb, n, samples) = (2usize, 2usize, 16usize, 7usize);
        let plan = cached(n);
        let mut specs = rand_vec(rb * cb * n, 17);
        forward_batch(&plan, &mut specs);
        let x0 = rand_vec(samples * cb * n, 18);

        let mut x_ref = x0.clone();
        let mut out_ref = vec![0.0f32; samples * rb * n];
        block_circulant_forward_batch(&plan, &mut x_ref, &mut out_ref, &specs, rb, cb);

        let mut x_pool = x0.clone();
        let mut out_pool = vec![0.0f32; samples * rb * n];
        block_circulant_forward_batch_ctx(&plan, &mut x_pool, &mut out_pool, &specs, rb, cb, &ctx);
        assert_eq!(out_pool, out_ref);
        assert_eq!(x_pool, x_ref);

        let g0 = rand_vec(samples * rb * n, 19);
        let mut g_ref = g0.clone();
        let mut dx_ref = vec![0.0f32; samples * cb * n];
        block_circulant_transpose_batch(&plan, &mut g_ref, &mut dx_ref, &specs, rb, cb);
        let mut g_pool = g0.clone();
        let mut dx_pool = vec![0.0f32; samples * cb * n];
        block_circulant_transpose_batch_ctx(&plan, &mut g_pool, &mut dx_pool, &specs, rb, cb, &ctx);
        assert_eq!(dx_pool, dx_ref);
        assert_eq!(g_pool, g_ref);
    }

    #[test]
    fn roundtrip_identity_across_tile_boundaries() {
        // batch sizes straddling the default tile (8 rows) and odd counts
        for b in [1usize, 7, 8, 9, 17] {
            let n = 128;
            let plan = cached(n);
            let x = rand_vec(n * b, 1000 + b as u64);
            let mut buf = x.clone();
            forward_batch(&plan, &mut buf);
            inverse_batch(&plan, &mut buf);
            for i in 0..n * b {
                assert!((buf[i] - x[i]).abs() < 1e-4, "b={b} i={i}");
            }
        }
    }

    #[test]
    fn engine_agrees_with_single_row_transform() {
        let n = 512;
        let plan = cached(n);
        let x = rand_vec(n, 5);
        let mut scalar = x.clone();
        rdfft_inplace(&plan, &mut scalar);
        let mut engine = x.clone();
        forward_batch_with(&plan, &mut engine, &EngineConfig::forced_scalar());
        assert_eq!(engine, scalar);
        irdfft_inplace(&plan, &mut scalar);
        inverse_batch_with(&plan, &mut engine, &EngineConfig::forced_scalar());
        assert_eq!(engine, scalar);
    }

    #[test]
    fn worker_planning_respects_thresholds() {
        let cfg = EngineConfig::new();
        // single row never threads
        assert_eq!(planned_workers(1, 1 << 20, &cfg), 1);
        // tiny total work never threads
        assert_eq!(planned_workers(8, 256, &cfg), 1);
        // serial config never threads
        assert_eq!(planned_workers(1024, 4096, &EngineConfig::serial()), 1);
        // big batches thread up to the core/row caps
        let w = planned_workers(64, 4096, &cfg);
        assert!(w >= 1 && w <= 64);
    }

    #[test]
    #[should_panic]
    fn ragged_buffer_rejected() {
        let plan = cached(8);
        let mut buf = vec![0.0f32; 12];
        forward_batch(&plan, &mut buf);
    }

    /// A unit spectrum of size n: the packed FFT of δ (all-ones lanes),
    /// the ⊙ identity — keeps repeated fused applications bounded.
    fn delta_spectrum(n: usize) -> Vec<f32> {
        let mut s = vec![0.0f32; n];
        s[0] = 1.0;
        forward_batch(&cached(n), &mut s);
        s
    }

    /// Unfused three-pass reference: forward batch, row-product sweep,
    /// inverse batch — the differential oracle for the fused pipeline's
    /// *structure*. All three passes run on the same auto-dispatched
    /// kernel arm as the fused sweep, so fused-vs-unfused stays a
    /// bit-exact comparison on every arm (scalar-vs-SIMD drift is bounded
    /// separately in rust/tests/differential.rs).
    fn unfused_apply(plan: &super::super::plan::Plan, buf: &mut [f32], spec: &[f32], op: SpectralOp) {
        forward_batch(plan, buf);
        match op {
            SpectralOp::Mul => crate::rdfft::spectral::mul_rows_inplace(buf, spec),
            SpectralOp::MulConjB => crate::rdfft::spectral::mul_conjb_rows_inplace(buf, spec),
        }
        inverse_batch(plan, buf);
    }

    #[test]
    fn fused_circulant_apply_is_bit_identical_to_unfused() {
        for (n, b) in [(2usize, 3usize), (4, 5), (16, 7), (64, 9), (256, 13), (1024, 3)] {
            let plan = cached(n);
            let mut spec = rand_vec(n, 31 + n as u64);
            forward_batch(&plan, &mut spec);
            for op in [SpectralOp::Mul, SpectralOp::MulConjB] {
                let x = rand_vec(n * b, (n * b) as u64);
                let mut fused = x.clone();
                circulant_apply_batch_with(&plan, &mut fused, &spec, op, &EngineConfig::serial());
                let mut reference = x.clone();
                unfused_apply(&plan, &mut reference, &spec, op);
                assert_eq!(fused, reference, "n={n} b={b} op={op:?}");
            }
        }
    }

    #[test]
    fn fused_circulant_apply_threaded_matches_serial() {
        let cfg = force_threads();
        for (n, b) in [(16usize, 9usize), (128, 11)] {
            let plan = cached(n);
            let spec = delta_spectrum(n);
            let x = rand_vec(n * b, 500 + n as u64);
            let mut serial = x.clone();
            circulant_apply_batch_with(&plan, &mut serial, &spec, SpectralOp::Mul, &EngineConfig::serial());
            let mut threaded = x.clone();
            circulant_apply_batch_with(&plan, &mut threaded, &spec, SpectralOp::Mul, &cfg);
            assert_eq!(serial, threaded, "n={n} b={b}");
        }
    }

    #[test]
    fn fused_apply_with_delta_spectrum_is_identity() {
        let n = 128;
        let plan = cached(n);
        let spec = delta_spectrum(n);
        for b in [1usize, 7, 8, 9] {
            let x = rand_vec(n * b, 900 + b as u64);
            let mut buf = x.clone();
            circulant_apply_batch(&plan, &mut buf, &spec, SpectralOp::Mul);
            for i in 0..n * b {
                assert!((buf[i] - x[i]).abs() < 1e-4, "b={b} i={i}");
            }
        }
    }

    #[test]
    fn fused_apply_allocates_nothing_after_plan_construction() {
        let n = 256;
        let plan = cached(n);
        let spec = delta_spectrum(n);
        let mut buf = rand_vec(n * 8, 42);
        crate::memtrack::reset();
        let before = crate::memtrack::snapshot().alloc_count;
        circulant_apply_batch_with(&plan, &mut buf, &spec, SpectralOp::Mul, &EngineConfig::serial());
        circulant_apply_batch_with(&plan, &mut buf, &spec, SpectralOp::MulConjB, &EngineConfig::serial());
        assert_eq!(crate::memtrack::snapshot().alloc_count, before);
    }

    #[test]
    fn block_forward_sweep_matches_three_pass_reference() {
        // rb x cb block grid over several samples: the fused sweep must be
        // bit-identical to forward-batch + per-sample product loops +
        // inverse-batch (the pre-fusion BlockCirculant pipeline).
        for (rb, cb, n, samples) in [(1usize, 1usize, 16usize, 3usize), (2, 2, 8, 5), (2, 4, 16, 2)] {
            let plan = cached(n);
            let mut specs = rand_vec(rb * cb * n, (rb * 13 + cb) as u64);
            forward_batch(&plan, &mut specs);
            let x0 = rand_vec(samples * cb * n, (n + samples) as u64);

            let mut x_ref = x0.clone();
            forward_batch(&plan, &mut x_ref);
            let mut out_ref = vec![0.0f32; samples * rb * n];
            for s in 0..samples {
                let xrow = &x_ref[s * cb * n..(s + 1) * cb * n];
                let orow = &mut out_ref[s * rb * n..(s + 1) * rb * n];
                for i in 0..rb {
                    for j in 0..cb {
                        // Same dispatched product as the fused sweep, so
                        // the comparison stays bit-exact on every arm.
                        crate::rdfft::spectral::mul_acc_with(
                            simd::active(),
                            &mut orow[i * n..(i + 1) * n],
                            &specs[(i * cb + j) * n..][..n],
                            &xrow[j * n..(j + 1) * n],
                        );
                    }
                }
            }
            inverse_batch(&plan, &mut out_ref);

            let mut x_fused = x0.clone();
            let mut out_fused = vec![0.0f32; samples * rb * n];
            block_circulant_forward_batch(&plan, &mut x_fused, &mut out_fused, &specs, rb, cb);
            assert_eq!(out_fused, out_ref, "rb={rb} cb={cb} n={n}");
            // and the input holds the same saved spectra
            assert_eq!(x_fused, x_ref, "saved x-hat rb={rb} cb={cb} n={n}");
        }
    }

    #[test]
    fn block_transpose_sweep_matches_three_pass_reference() {
        for (rb, cb, n, samples) in [(2usize, 2usize, 8usize, 3usize), (4, 2, 16, 2)] {
            let plan = cached(n);
            let mut specs = rand_vec(rb * cb * n, (rb * 7 + cb) as u64);
            forward_batch(&plan, &mut specs);
            let g0 = rand_vec(samples * rb * n, (n * 3 + samples) as u64);

            let mut g_ref = g0.clone();
            forward_batch(&plan, &mut g_ref);
            let mut dx_ref = vec![0.0f32; samples * cb * n];
            for s in 0..samples {
                let grow = &g_ref[s * rb * n..(s + 1) * rb * n];
                let dxrow = &mut dx_ref[s * cb * n..(s + 1) * cb * n];
                for j in 0..cb {
                    for i in 0..rb {
                        crate::rdfft::spectral::conj_mul_acc_with(
                            simd::active(),
                            &mut dxrow[j * n..(j + 1) * n],
                            &specs[(i * cb + j) * n..][..n],
                            &grow[i * n..(i + 1) * n],
                        );
                    }
                }
            }
            inverse_batch(&plan, &mut dx_ref);

            let mut g_fused = g0.clone();
            let mut dx_fused = vec![0.0f32; samples * cb * n];
            block_circulant_transpose_batch(&plan, &mut g_fused, &mut dx_fused, &specs, rb, cb);
            assert_eq!(dx_fused, dx_ref, "rb={rb} cb={cb} n={n}");
            assert_eq!(g_fused, g_ref, "saved g-hat rb={rb} cb={cb} n={n}");
        }
    }

    #[test]
    fn block_residual_sweep_computes_x_plus_wx() {
        let (rb, cb, n, samples) = (2usize, 2usize, 16usize, 3usize);
        let plan = cached(n);
        let mut specs = rand_vec(rb * cb * n, 99);
        forward_batch(&plan, &mut specs);
        let x0 = rand_vec(samples * cb * n, 101);

        let mut x_plain = x0.clone();
        let mut wx = vec![0.0f32; samples * rb * n];
        block_circulant_forward_batch(&plan, &mut x_plain, &mut wx, &specs, rb, cb);

        let mut x_res = x0.clone();
        let mut out = vec![0.0f32; samples * rb * n];
        block_circulant_forward_residual_batch(&plan, &mut x_res, &mut out, &specs, rb, cb);

        // out must equal x + Wx to transform-roundtrip precision
        for i in 0..out.len() {
            let want = x0[i] + wx[i];
            assert!(
                (out[i] - want).abs() < 1e-3 * (1.0 + want.abs()),
                "i={i}: {} vs {}",
                out[i],
                want
            );
        }
    }

    #[test]
    fn transforms_survive_a_panicked_engine_worker_thread() {
        // A thread that panics after touching the plan cache and the
        // engine must not poison anything for later transforms
        // (regression for the plan-cache RwLock poisoning bug).
        // audit: allow(no-raw-threads) the test needs a raw thread precisely so its panic cannot touch the pool
        let joined = std::thread::spawn(|| {
            let plan = cached(64);
            let mut buf = vec![0.25f32; 64 * 4];
            forward_batch(&plan, &mut buf);
            panic!("injected worker panic");
        })
        .join();
        assert!(joined.is_err(), "worker must have panicked");
        let plan = cached(64);
        let mut buf = vec![0.5f32; 64 * 5];
        forward_batch(&plan, &mut buf);
        inverse_batch(&plan, &mut buf);
        for v in buf {
            assert!((v - 0.5).abs() < 1e-4);
        }
    }

    #[test]
    fn simd_arm_roundtrips_and_tracks_forced_scalar() {
        // Auto-dispatch (whatever arm this machine resolves) must
        // round-trip and stay within the n-scaled FMA tolerance of the
        // forced-scalar oracle across sizes straddling the quad width.
        for (n, b) in [(4usize, 3usize), (8, 5), (16, 7), (64, 9), (512, 4), (4096, 2)] {
            let plan = cached(n);
            let x = rand_vec(n * b, 7777 + n as u64);
            let mut auto = x.clone();
            forward_batch(&plan, &mut auto);
            let mut forced = x.clone();
            forward_batch_with(&plan, &mut forced, &EngineConfig::forced_scalar());
            let tol = 1e-5 * (n as f32).sqrt() * ((n as f32).log2() + 1.0);
            for i in 0..n * b {
                assert!(
                    (auto[i] - forced[i]).abs() <= tol,
                    "fwd n={n} b={b} i={i}: {} vs {}",
                    auto[i],
                    forced[i]
                );
            }
            inverse_batch(&plan, &mut auto);
            for i in 0..n * b {
                assert!((auto[i] - x[i]).abs() < 1e-3, "roundtrip n={n} b={b} i={i}");
            }
        }
    }

    #[test]
    fn fused_simd_apply_tracks_forced_scalar_apply() {
        let (n, b) = (256usize, 9usize);
        let plan = cached(n);
        let mut spec = rand_vec(n, 4242);
        forward_batch_with(&plan, &mut spec, &EngineConfig::forced_scalar());
        for op in [SpectralOp::Mul, SpectralOp::MulConjB] {
            let x = rand_vec(n * b, 999 + n as u64);
            let mut auto = x.clone();
            circulant_apply_batch(&plan, &mut auto, &spec, op);
            let mut forced = x.clone();
            circulant_apply_batch_with(&plan, &mut forced, &spec, op, &EngineConfig::forced_scalar());
            let tol = 1e-4 * (n as f32).sqrt();
            for i in 0..n * b {
                assert!(
                    (auto[i] - forced[i]).abs() <= tol * (1.0 + forced[i].abs()),
                    "op={op:?} i={i}"
                );
            }
        }
    }

    #[test]
    fn dispatch_is_identical_across_pool_thread_counts() {
        // Auto-dispatch resolves the arm once per call from a cached
        // process-wide decision, so results are identical whichever pool
        // executes the chunks and however many workers it has.
        let (n, b) = (128usize, 13usize);
        let plan = cached(n);
        let x = rand_vec(n * b, 31337);
        let cfg = force_threads();
        let mut lanes1 = x.clone();
        let ctx1 = crate::runtime::pool::ExecCtx::with_threads(1).with_engine_config(cfg);
        forward_batch_ctx(&plan, &mut lanes1, &ctx1);
        let mut lanes4 = x.clone();
        let ctx4 = crate::runtime::pool::ExecCtx::with_threads(4).with_engine_config(cfg);
        forward_batch_ctx(&plan, &mut lanes4, &ctx4);
        assert_eq!(lanes1, lanes4, "thread count must not change SIMD results");
        // Repeated runs on the same machine are bit-identical too.
        let mut again = x.clone();
        forward_batch_ctx(&plan, &mut again, &ctx4);
        assert_eq!(lanes4, again, "repeat run must be bit-identical");
    }

    #[test]
    fn tier_counters_distinguish_fallback_from_fourstep() {
        use super::super::plan::Plan;
        // Regression for the silent-mismeasure bug: with
        // `fourstep_threshold: 1`, a small-n transform *asks* for the
        // four-step tier but no plan below FOURSTEP_MIN_N can carry
        // tables — the direct sweep runs, and the tally must record a
        // FALLBACK (not a clean direct dispatch) so bench cells labelled
        // "fourstep" can hard-fail instead of timing direct-vs-direct.
        // Thread-local counters + private plans keep the exact-count
        // asserts safe under the parallel test runner.
        let four_cfg = EngineConfig { fourstep_threshold: 1, ..EngineConfig::new() };
        let small = Plan::new(64);
        let mut buf = rand_vec(64 * 2, 11);
        let t0 = tier_counts();
        forward_batch_with(&small, &mut buf, &four_cfg);
        let d = tier_counts().since(t0);
        assert_eq!((d.fourstep, d.direct, d.fallback), (0, 0, 1), "small-n must tally a fallback");

        // n = 1024 under the same config: the tier genuinely engages
        // (and materializes the lazy tables on this first dispatch).
        let big = Plan::new(1024);
        let mut buf = rand_vec(1024 * 2, 12);
        assert!(big.fourstep().is_none());
        let t0 = tier_counts();
        forward_batch_with(&big, &mut buf, &four_cfg);
        let d = tier_counts().since(t0);
        assert_eq!((d.fourstep, d.direct, d.fallback), (1, 0, 0), "large-n must tally four-step");
        assert!(big.fourstep().is_some(), "first four-step dispatch materializes tables");

        // Default config at n = 1024 (< 16 Ki threshold): the intended
        // direct route — a size choice, not a fallback.
        let t0 = tier_counts();
        inverse_batch(&big, &mut buf);
        let d = tier_counts().since(t0);
        assert_eq!((d.fourstep, d.direct, d.fallback), (0, 1, 0), "default small-n is direct");

        assert_eq!(Tier::FourStep.name(), "fourstep");
        assert_eq!(Tier::Direct.name(), "direct");
        assert_eq!(Tier::DirectFallback.name(), "direct_fallback");
    }
}
