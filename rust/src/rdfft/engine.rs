//! Batch-major rdFFT execution engine.
//!
//! The scalar paths in [`super::forward`] / [`super::inverse`] transform
//! one row at a time: a bit-reversal pass, then one pass per butterfly
//! stage. This module is the batched hot path every multi-row consumer
//! (block-circulant layers, 2-D transforms, conv batches, the trainer's
//! per-step block sweeps) routes through. Three ideas, all composing with
//! the paper's in-place discipline (zero allocations, zero out-of-buffer
//! writes):
//!
//! 1. **Fused permutation + first two stages.** The `m = 1` and `m = 2`
//!    stages have trivial twiddles (±1, ∓i), and the in-place bit-reversal
//!    swap loop finalizes positions in ascending order, so each aligned
//!    4-block can run both stages *immediately after* its four swaps while
//!    the values are in registers — one pass over the buffer instead of
//!    three. (Correctness argument in [`fused_bitrev_stage12`].)
//!
//! 2. **SoA twiddles + tiled batch-major stages.** Remaining stages sweep
//!    a *tile* of rows, reusing each stage's twiddles across every row in
//!    the tile; twiddles live in separate `wr`/`wi` slices
//!    ([`Plan::stage_twiddles_soa`]) so the innermost loops read stride-1
//!    lanes. Small stages iterate rows innermost at a fixed `(stage, k)`
//!    to amortize twiddle loads; large stages iterate `k` innermost so the
//!    four element streams stay stride-±1 for the autovectorizer.
//!
//! 3. **Scoped-thread row parallelism.** Batches above a tunable work
//!    threshold split into contiguous row chunks under
//!    [`std::thread::scope`] (no external crates). Thresholds are chosen
//!    so `batch = 1` latency never pays a spawn, and every worker has
//!    enough rows to amortize one. See `EXPERIMENTS.md` §Perf for the
//!    measured ablation and `BENCH_rdfft.json` for the machine-readable
//!    numbers.

use super::plan::Plan;

/// Tuning knobs for the batch engine. [`EngineConfig::default`] is what
/// the public batch entry points use; benches and tests construct
/// explicit configs to pin a specific execution mode.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Rows per cache tile in the batch-major stage sweep.
    pub tile_rows: usize,
    /// Minimum total elements (`rows * n`) before threads are considered.
    pub par_min_elems: usize,
    /// Minimum rows before threads are considered (also the floor that
    /// keeps single-row latency on the spawn-free path).
    pub par_min_rows: usize,
    /// Target elements per worker chunk: the batch is split into at most
    /// `total_elems / par_chunk_elems` chunks (capped by core count).
    pub par_chunk_elems: usize,
    /// Hard cap on worker threads. 0 = `available_parallelism()`.
    pub max_threads: usize,
}

impl EngineConfig {
    /// Default thresholds: threads only when there are ≥ 4 rows and the
    /// whole batch is ≥ 32 Ki elements (≈ 128 KiB), with ≥ 16 Ki elements
    /// of work per spawned worker.
    pub const fn new() -> Self {
        EngineConfig {
            tile_rows: 8,
            par_min_elems: 1 << 15,
            par_min_rows: 4,
            par_chunk_elems: 1 << 14,
            max_threads: 0,
        }
    }

    /// A config that never spawns threads (pure batch-major execution);
    /// used by the ablation bench to separate layout wins from
    /// parallelism wins.
    pub const fn serial() -> Self {
        EngineConfig {
            tile_rows: 8,
            par_min_elems: 1 << 15,
            par_min_rows: usize::MAX,
            par_chunk_elems: 1 << 14,
            max_threads: 0,
        }
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig::new()
    }
}

/// Stages with half-block `m` at or below this bound run rows innermost
/// (twiddle-amortizing); larger stages run `k` innermost (stride-1 SIMD
/// lanes). `m = 32` keeps the row-inner working set per block within a
/// few cache lines per row.
const SMALL_M: usize = 32;

/// Forward-transform `batch` contiguous rows of length `plan.n()` in
/// place with default tuning. Equivalent to per-row
/// [`super::forward::rdfft_inplace`] (bit-for-bit: the same float ops in
/// the same per-element order).
pub fn forward_batch(plan: &Plan, buf: &mut [f32]) {
    forward_batch_with(plan, buf, &EngineConfig::new());
}

/// Inverse-transform `batch` contiguous rows in place, default tuning.
pub fn inverse_batch(plan: &Plan, buf: &mut [f32]) {
    inverse_batch_with(plan, buf, &EngineConfig::new());
}

/// [`forward_batch`] with explicit tuning.
pub fn forward_batch_with(plan: &Plan, buf: &mut [f32], cfg: &EngineConfig) {
    run_batch(plan, buf, cfg, forward_rows);
}

/// [`inverse_batch`] with explicit tuning.
pub fn inverse_batch_with(plan: &Plan, buf: &mut [f32], cfg: &EngineConfig) {
    run_batch(plan, buf, cfg, inverse_rows);
}

/// Shared driver: validate, decide serial vs scoped-thread execution,
/// dispatch `kernel` over contiguous row chunks.
fn run_batch(
    plan: &Plan,
    buf: &mut [f32],
    cfg: &EngineConfig,
    kernel: fn(&Plan, &mut [f32], usize),
) {
    let n = plan.n();
    assert!(buf.len() % n == 0, "buffer length must be a multiple of plan size");
    let rows = buf.len() / n;
    if rows == 0 {
        return;
    }
    let workers = planned_workers(rows, n, cfg);
    if workers <= 1 {
        kernel(plan, buf, cfg.tile_rows);
        return;
    }
    // Contiguous row chunks; `ceil` so the chunk count never exceeds
    // `workers`. Scoped threads may borrow `buf` and `plan` directly.
    let chunk_rows = (rows + workers - 1) / workers;
    let tile_rows = cfg.tile_rows;
    std::thread::scope(|s| {
        let mut rest = buf;
        while rest.len() > chunk_rows * n {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(chunk_rows * n);
            s.spawn(move || kernel(plan, chunk, tile_rows));
            rest = tail;
        }
        // Run the final chunk on the calling thread: one fewer spawn.
        kernel(plan, rest, tile_rows);
    });
}

/// How many workers (including the calling thread) the batch should use.
fn planned_workers(rows: usize, n: usize, cfg: &EngineConfig) -> usize {
    let total = rows * n;
    if rows < cfg.par_min_rows || total < cfg.par_min_elems {
        return 1;
    }
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let cap = if cfg.max_threads == 0 { cores } else { cfg.max_threads.min(cores) };
    let by_work = (total / cfg.par_chunk_elems.max(1)).max(1);
    by_work.min(cap).min(rows)
}

// ---------------------------------------------------------------------
// Per-chunk kernels
// ---------------------------------------------------------------------

/// Forward kernel over one contiguous chunk of rows.
fn forward_rows(plan: &Plan, buf: &mut [f32], tile_rows: usize) {
    let n = plan.n();
    // Pass 1 (per row): fused bit-reversal + stages m = 1, 2.
    for row in buf.chunks_exact_mut(n) {
        fused_bitrev_stage12(plan, row);
    }
    // Pass 2 (per row tile): remaining stages, batch-major.
    if n > 4 {
        for tile in buf.chunks_mut(tile_rows.max(1) * n) {
            forward_stages_tile(plan, tile);
        }
    }
}

/// Inverse kernel over one contiguous chunk of rows. Mirrors
/// [`forward_rows`] in reverse: tiled stages down to m = 4, then a fused
/// per-row undo of stages m = 2, 1, then the bit-reversal.
fn inverse_rows(plan: &Plan, buf: &mut [f32], tile_rows: usize) {
    let n = plan.n();
    if n > 4 {
        for tile in buf.chunks_mut(tile_rows.max(1) * n) {
            inverse_stages_tile(plan, tile);
        }
    }
    for row in buf.chunks_exact_mut(n) {
        fused_inverse_stage21(row, n);
        // The trailing permutation cannot be interleaved with the
        // butterfly undo (a swap may read a 4-block that is not yet
        // undone), so the inverse keeps it as its own pass.
        plan.bit_reverse(row);
    }
}

/// One pass over `row`: the in-place bit-reversal fused with the m = 1
/// and m = 2 butterfly stages.
///
/// Correctness of the interleave: in the ascending in-place swap loop
/// (`swap(i, rev(i))` iff `i < rev(i)`), every position `p` changes
/// exactly once, at step `min(p, rev(p))` — so after the four swaps of an
/// aligned 4-block `[4u, 4u+4)` the block holds its final pre-stage
/// values, and no later swap step `i' > 4u+3` can read or write inside
/// the block again (a swap touches `i'` and `rev(i') > i'` only). The two
/// trivial-twiddle stages can therefore run on the block immediately,
/// while its values are hot.
pub fn fused_bitrev_stage12(plan: &Plan, row: &mut [f32]) {
    let n = plan.n();
    debug_assert_eq!(row.len(), n);
    if n == 2 {
        let (a, b) = (row[0], row[1]);
        row[0] = a + b;
        row[1] = a - b;
        return;
    }
    let rev = plan.rev();
    let mut s = 0usize;
    while s < n {
        for i in s..s + 4 {
            let j = rev[i] as usize;
            if i < j {
                row.swap(i, j);
            }
        }
        let (x0, x1, x2, x3) = (row[s], row[s + 1], row[s + 2], row[s + 3]);
        // m = 1 on pairs: packed 2-point spectra [DC, Nyquist].
        let (a, b) = (x0 + x1, x0 - x1);
        let (c, d) = (x2 + x3, x2 - x3);
        // m = 2: k = 0 lane combines the two DCs; the sub-Nyquist lane
        // (y_1 = e - i·o) flips the sign of the odd block's Nyquist slot.
        row[s] = a + c;
        row[s + 1] = b;
        row[s + 2] = a - c;
        row[s + 3] = -d;
        s += 4;
    }
}

/// One pass over `row`: undo stage m = 2 then m = 1 (the exact inverse of
/// the butterfly half of [`fused_bitrev_stage12`]; the caller applies the
/// bit-reversal afterwards).
pub fn fused_inverse_stage21(row: &mut [f32], n: usize) {
    debug_assert_eq!(row.len(), n);
    if n == 2 {
        let (a, b) = (row[0], row[1]);
        row[0] = 0.5 * (a + b);
        row[1] = 0.5 * (a - b);
        return;
    }
    let mut s = 0usize;
    while s < n {
        let (y0, y1, y2, y3) = (row[s], row[s + 1], row[s + 2], row[s + 3]);
        // Undo m = 2: recover the two packed 2-point spectra.
        let a = 0.5 * (y0 + y2);
        let c = 0.5 * (y0 - y2);
        let b = y1;
        let d = -y3;
        // Undo m = 1 on both pairs.
        row[s] = 0.5 * (a + b);
        row[s + 1] = 0.5 * (a - b);
        row[s + 2] = 0.5 * (c + d);
        row[s + 3] = 0.5 * (c - d);
        s += 4;
    }
}

/// Forward stages m = 4 .. n/2 over a tile of rows, batch-major.
fn forward_stages_tile(plan: &Plan, tile: &mut [f32]) {
    let n = plan.n();
    let rows = tile.len() / n;
    debug_assert_eq!(tile.len(), rows * n);
    let mut m = 4usize;
    while m < n {
        let (wr, wi) = plan.stage_twiddles_soa(m);
        let two_m = 2 * m;
        let half = m / 2;
        let mut s = 0usize;
        while s < n {
            // Trivial lanes (k = 0 DC/Nyquist combine, k = m/2 sign
            // flip), per row.
            for r in 0..rows {
                let base = r * n + s;
                let e = tile[base];
                let o = tile[base + m];
                tile[base] = e + o;
                tile[base + m] = e - o;
                let idx = base + m + half;
                tile[idx] = -tile[idx];
            }
            // Symmetric 4-groups, 1 <= k < m/2.
            //
            // SAFETY: identical bounds argument to the scalar
            // forward_stages (all four indices lie in [base, base+two_m),
            // and base + two_m <= rows*n because s + two_m <= n), lifted
            // over `rows` rows. Bounds checks cost ~25% here (see
            // EXPERIMENTS.md §Perf).
            unsafe {
                if m <= SMALL_M {
                    // Row-inner: one twiddle load serves every row in the
                    // tile at this (stage, k).
                    for k in 1..half {
                        let wrk = *wr.get_unchecked(k - 1);
                        let wik = *wi.get_unchecked(k - 1);
                        for r in 0..rows {
                            let blk = tile.get_unchecked_mut(r * n + s..r * n + s + two_m);
                            bf4_forward(blk, m, two_m, k, wrk, wik);
                        }
                    }
                } else {
                    // k-inner: stride-1 SoA twiddles and stride-±1
                    // element streams for the autovectorizer.
                    for r in 0..rows {
                        let blk = tile.get_unchecked_mut(r * n + s..r * n + s + two_m);
                        for k in 1..half {
                            bf4_forward(
                                blk,
                                m,
                                two_m,
                                k,
                                *wr.get_unchecked(k - 1),
                                *wi.get_unchecked(k - 1),
                            );
                        }
                    }
                }
            }
            s += two_m;
        }
        m = two_m;
    }
}

/// Inverse stages m = n/2 .. 4 over a tile of rows, batch-major.
fn inverse_stages_tile(plan: &Plan, tile: &mut [f32]) {
    let n = plan.n();
    let rows = tile.len() / n;
    debug_assert_eq!(tile.len(), rows * n);
    let mut m = n / 2;
    while m >= 4 {
        let (hr, hi) = plan.stage_inv_twiddles_soa(m);
        let two_m = 2 * m;
        let half = m / 2;
        let mut s = 0usize;
        while s < n {
            for r in 0..rows {
                let base = r * n + s;
                let a = tile[base];
                let b = tile[base + m];
                tile[base] = 0.5 * (a + b);
                tile[base + m] = 0.5 * (a - b);
                let idx = base + m + half;
                tile[idx] = -tile[idx];
            }
            // SAFETY: same bounds argument as forward_stages_tile.
            unsafe {
                if m <= SMALL_M {
                    for k in 1..half {
                        let hrk = *hr.get_unchecked(k - 1);
                        let hik = *hi.get_unchecked(k - 1);
                        for r in 0..rows {
                            let blk = tile.get_unchecked_mut(r * n + s..r * n + s + two_m);
                            bf4_inverse(blk, m, two_m, k, hrk, hik);
                        }
                    }
                } else {
                    for r in 0..rows {
                        let blk = tile.get_unchecked_mut(r * n + s..r * n + s + two_m);
                        for k in 1..half {
                            bf4_inverse(
                                blk,
                                m,
                                two_m,
                                k,
                                *hr.get_unchecked(k - 1),
                                *hi.get_unchecked(k - 1),
                            );
                        }
                    }
                }
            }
            s += two_m;
        }
        m /= 2;
    }
}

/// The forward symmetric 4-group butterfly (same float ops, same order as
/// the scalar path — batch outputs stay bit-identical to per-row ones).
///
/// # Safety
/// `blk` must have length `two_m` and `1 <= k < m/2` with `two_m = 2*m`.
#[inline(always)]
unsafe fn bf4_forward(blk: &mut [f32], m: usize, two_m: usize, k: usize, wr: f32, wi: f32) {
    debug_assert!(k >= 1 && k < m / 2 && blk.len() == two_m);
    let er = *blk.get_unchecked(k);
    let ei = *blk.get_unchecked(m - k);
    let or_ = *blk.get_unchecked(m + k);
    let oi = *blk.get_unchecked(two_m - k);
    let tr = wr * or_ - wi * oi;
    let ti = wr * oi + wi * or_;
    *blk.get_unchecked_mut(k) = er + tr;
    *blk.get_unchecked_mut(two_m - k) = ei + ti;
    *blk.get_unchecked_mut(m - k) = er - tr;
    *blk.get_unchecked_mut(m + k) = ti - ei;
}

/// The inverse symmetric 4-group butterfly (pre-halved twiddles `hr`,
/// `hi`; see [`super::inverse`]).
///
/// # Safety
/// `blk` must have length `two_m` and `1 <= k < m/2` with `two_m = 2*m`.
#[inline(always)]
unsafe fn bf4_inverse(blk: &mut [f32], m: usize, two_m: usize, k: usize, hr: f32, hi: f32) {
    debug_assert!(k >= 1 && k < m / 2 && blk.len() == two_m);
    let a = *blk.get_unchecked(k);
    let b = *blk.get_unchecked(m - k);
    let c = *blk.get_unchecked(two_m - k);
    let d = *blk.get_unchecked(m + k);
    let er = 0.5 * (a + b);
    let ei = 0.5 * (c - d);
    let or_ = (a - b) * hr + (c + d) * hi;
    let oi = (c + d) * hr - (a - b) * hi;
    *blk.get_unchecked_mut(k) = er;
    *blk.get_unchecked_mut(m - k) = ei;
    *blk.get_unchecked_mut(m + k) = or_;
    *blk.get_unchecked_mut(two_m - k) = oi;
}

#[cfg(test)]
mod tests {
    use super::super::forward::{rdfft_inplace, rdfft_batch_scalar};
    use super::super::inverse::{irdfft_inplace, irdfft_batch_scalar};
    use super::super::plan::cached;
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    /// A config that forces the threaded path even for tiny batches.
    fn force_threads() -> EngineConfig {
        EngineConfig {
            par_min_rows: 2,
            par_min_elems: 0,
            par_chunk_elems: 1,
            max_threads: 3,
            ..EngineConfig::new()
        }
    }

    #[test]
    fn fused_first_pass_equals_bitrev_plus_two_stages() {
        for n in [4usize, 8, 16, 64, 256] {
            let plan = cached(n);
            let x = rand_vec(n, n as u64);
            let mut fused = x.clone();
            fused_bitrev_stage12(&plan, &mut fused);
            // reference: explicit permutation, then scalar stages m=1,2
            let mut r = x.clone();
            plan.bit_reverse(&mut r);
            for blk in r.chunks_exact_mut(2) {
                let (e, o) = (blk[0], blk[1]);
                blk[0] = e + o;
                blk[1] = e - o;
            }
            if n >= 4 {
                for blk in r.chunks_exact_mut(4) {
                    let (e, o) = (blk[0], blk[2]);
                    blk[0] = e + o;
                    blk[2] = e - o;
                    blk[3] = -blk[3];
                }
            }
            assert_eq!(fused, r, "n={n}");
        }
    }

    #[test]
    fn forward_batch_matches_scalar_rows_exactly() {
        for (n, b) in [(2usize, 3usize), (4, 5), (16, 1), (64, 7), (256, 9), (1024, 4)] {
            let plan = cached(n);
            let x = rand_vec(n * b, (n + b) as u64);
            let mut scalar = x.clone();
            rdfft_batch_scalar(&plan, &mut scalar);
            let mut engine = x.clone();
            forward_batch(&plan, &mut engine);
            assert_eq!(engine, scalar, "n={n} b={b}");
        }
    }

    #[test]
    fn inverse_batch_matches_scalar_rows_exactly() {
        for (n, b) in [(2usize, 3usize), (4, 5), (16, 1), (64, 7), (256, 9), (1024, 4)] {
            let plan = cached(n);
            let x = rand_vec(n * b, (2 * n + b) as u64);
            let mut scalar = x.clone();
            irdfft_batch_scalar(&plan, &mut scalar);
            let mut engine = x.clone();
            inverse_batch(&plan, &mut engine);
            assert_eq!(engine, scalar, "n={n} b={b}");
        }
    }

    #[test]
    fn threaded_path_matches_serial_path() {
        let cfg = force_threads();
        for (n, b) in [(8usize, 5usize), (64, 13), (256, 6)] {
            let plan = cached(n);
            let x = rand_vec(n * b, 77 + n as u64);
            let mut serial = x.clone();
            forward_batch_with(&plan, &mut serial, &EngineConfig::serial());
            let mut threaded = x.clone();
            forward_batch_with(&plan, &mut threaded, &cfg);
            assert_eq!(serial, threaded, "fwd n={n} b={b}");
            inverse_batch_with(&plan, &mut serial, &EngineConfig::serial());
            inverse_batch_with(&plan, &mut threaded, &cfg);
            assert_eq!(serial, threaded, "inv n={n} b={b}");
        }
    }

    #[test]
    fn roundtrip_identity_across_tile_boundaries() {
        // batch sizes straddling the default tile (8 rows) and odd counts
        for b in [1usize, 7, 8, 9, 17] {
            let n = 128;
            let plan = cached(n);
            let x = rand_vec(n * b, 1000 + b as u64);
            let mut buf = x.clone();
            forward_batch(&plan, &mut buf);
            inverse_batch(&plan, &mut buf);
            for i in 0..n * b {
                assert!((buf[i] - x[i]).abs() < 1e-4, "b={b} i={i}");
            }
        }
    }

    #[test]
    fn engine_agrees_with_single_row_transform() {
        let n = 512;
        let plan = cached(n);
        let x = rand_vec(n, 5);
        let mut scalar = x.clone();
        rdfft_inplace(&plan, &mut scalar);
        let mut engine = x.clone();
        forward_batch(&plan, &mut engine);
        assert_eq!(engine, scalar);
        irdfft_inplace(&plan, &mut scalar);
        inverse_batch(&plan, &mut engine);
        assert_eq!(engine, scalar);
    }

    #[test]
    fn worker_planning_respects_thresholds() {
        let cfg = EngineConfig::new();
        // single row never threads
        assert_eq!(planned_workers(1, 1 << 20, &cfg), 1);
        // tiny total work never threads
        assert_eq!(planned_workers(8, 256, &cfg), 1);
        // serial config never threads
        assert_eq!(planned_workers(1024, 4096, &EngineConfig::serial()), 1);
        // big batches thread up to the core/row caps
        let w = planned_workers(64, 4096, &cfg);
        assert!(w >= 1 && w <= 64);
    }

    #[test]
    #[should_panic]
    fn ragged_buffer_rejected() {
        let plan = cached(8);
        let mut buf = vec![0.0f32; 12];
        forward_batch(&plan, &mut buf);
    }
}
