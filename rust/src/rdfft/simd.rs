//! SIMD micro-kernels for the butterfly and packed-spectral hot loops.
//!
//! Every rdFFT hot loop bottoms out in one of two shapes:
//!
//! * the symmetric **4-group butterfly** of Proposition 1 (forward and
//!   inverse), sweeping `k = 1 .. m/2` inside a `2m`-block with two
//!   ascending and two descending stride-1 element streams, and
//! * the **packed conjugate-symmetric product** (Eq. 4/5), sweeping
//!   `k = 1 .. n/2` of a packed row against a shared spectrum with the
//!   same two-ascending / two-descending access pattern.
//!
//! Groups at different `k` touch disjoint slots (`{k, m−k, m+k, 2m−k}`
//! partitions the block; `{k, n−k}` partitions the row), so four
//! consecutive groups can run as one width-4 f32 lane operation with no
//! cross-lane dependency. This module implements both shapes **once**
//! against the tiny [`Lanes4`] trait and instantiates them twice:
//!
//! * [`ScalarQuad`] — portable scalar quads, plain mul/add (no FMA). The
//!   per-element operations and their order are *identical* to the legacy
//!   scalar loops, so this arm is **bit-for-bit equal** to the pre-SIMD
//!   kernels on every platform.
//! * `AvxFma` (x86_64) — 128-bit SSE lanes compiled with AVX2+FMA
//!   enabled, selected at runtime via `is_x86_feature_detected!`. FMA
//!   contracts `a·b ± c·d` into one rounding, so this arm may differ
//!   from the scalar oracle by a few ulps per butterfly — the
//!   differential suite bounds the drift with the n-scaled tolerance
//!   (EXPERIMENTS.md §Perf iteration 6, "tolerance policy").
//!
//! On top of the width-4 tier sits a **width-8 tier** ([`Lanes8`],
//! EXPERIMENTS.md §Perf iteration 7): the same kernels instantiated with
//! an 8-lane main loop that falls through to the width-4 loop and then
//! the scalar tail for the remainder. Its two arms mirror the quad tier:
//!
//! * [`ScalarOct`] — portable scalar octs. Each 8-lane op is exactly two
//!   [`ScalarQuad`] ops laid side by side (same per-element expressions,
//!   no FMA), and the groups/products at different `k` touch disjoint
//!   slots, so this arm is **bit-for-bit equal** to the quad arm — and
//!   therefore to the legacy scalar loops (asserted in tests).
//! * `AvxFma256` (x86_64) — full-width 256-bit `__m256` lanes with
//!   AVX2+FMA, preferred by auto-detection over the 128-bit arm. FMA
//!   contraction remains the **only** numeric delta vs the scalar
//!   oracle, identical in kind to the 128-bit arm (same tolerance
//!   policy; lane *width* never changes which ops run per element).
//!
//! [`crate::rdfft::engine::EngineConfig::max_simd_width`] clamps the
//! resolved arm back down ([`clamp_width`]) so benches can measure the
//! width-8-vs-width-4 delta on one machine.
//!
//! Dispatch is resolved **once per engine call** ([`select`]) from three
//! inputs, in priority order: the process-wide override (the CLI's
//! `--force-scalar`, [`force_scalar_global`]), the `RDFFT_FORCE_SCALAR`
//! environment variable (the CI matrix's force-scalar leg), and the
//! per-call [`crate::rdfft::engine::EngineConfig::force_scalar`] flag.
//! The legacy scalar loops stay reachable through all three, so the
//! pre-SIMD kernels remain available as the differential oracle
//! (`rust/tests/differential.rs` asserts the forced arm is bitwise
//! identical to them). Selection is deterministic for the life of the
//! process: the same arm runs on every call, every pool worker, every
//! repetition — the dispatch-determinism proptests depend on that.

use std::sync::atomic::{AtomicU8, Ordering};

/// Lane width of the width-4 kernel tier.
pub const LANES: usize = 4;

/// Lane width of the width-8 kernel tier ([`Lanes8`]).
pub const LANES8: usize = 8;

/// Which kernel arm a call executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernels {
    /// The pre-SIMD scalar loops, bit-for-bit — the differential oracle.
    LegacyScalar,
    /// Portable width-4 scalar quads (no FMA); bitwise identical to
    /// [`Kernels::LegacyScalar`], structured as straight-line lane code.
    Portable,
    /// x86_64 128-bit lanes compiled with AVX2+FMA (runtime-detected).
    /// Never selected on other architectures.
    AvxFma,
    /// x86_64 256-bit `__m256` lanes with AVX2+FMA — the full register
    /// width, preferred by auto-detection over [`Kernels::AvxFma`]
    /// (which survives as the explicit width-4 FMA arm behind
    /// [`clamp_width`]). Never selected on other architectures.
    AvxFma256,
}

impl Kernels {
    /// True for the arms whose butterflies/products contract `a·b ± c·d`
    /// with FMA — the only arms allowed to drift (within tolerance) from
    /// the scalar oracle. Tests gate their bitwise assertions on this
    /// instead of comparing against one specific FMA arm.
    #[inline]
    pub fn uses_fma(self) -> bool {
        matches!(self, Kernels::AvxFma | Kernels::AvxFma256)
    }
}

// Cached dispatch decision: 0 = unresolved, then Kernels + 1.
const K_UNRESOLVED: u8 = 0;
const K_SCALAR: u8 = 1;
const K_PORTABLE: u8 = 2;
const K_AVXFMA: u8 = 3;
const K_AVXFMA256: u8 = 4;
static ACTIVE: AtomicU8 = AtomicU8::new(K_UNRESOLVED);

fn decode(v: u8) -> Kernels {
    match v {
        K_SCALAR => Kernels::LegacyScalar,
        K_AVXFMA => Kernels::AvxFma,
        K_AVXFMA256 => Kernels::AvxFma256,
        _ => Kernels::Portable,
    }
}

/// Cached CPU capability check (independent of the dispatch override, so
/// the safe entry points can sanitize a caller-supplied arm even when the
/// auto decision was forced to scalar).
#[cfg(target_arch = "x86_64")]
fn avx_fma_available() -> bool {
    use std::sync::OnceLock;
    static AVAIL: OnceLock<bool> = OnceLock::new();
    *AVAIL.get_or_init(|| is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma"))
}

#[cfg(not(target_arch = "x86_64"))]
fn avx_fma_available() -> bool {
    false
}

/// Downgrade an arm the current CPU cannot execute: `AvxFma` on a machine
/// without AVX2+FMA becomes `Portable` (numerically identical to the
/// scalar oracle). This is what keeps the safe dispatchers sound —
/// `Kernels` is a plain public enum, so a safe caller may hand us any
/// variant.
#[inline]
fn sanitize(kern: Kernels) -> Kernels {
    if kern.uses_fma() && !avx_fma_available() {
        Kernels::Portable
    } else {
        kern
    }
}

fn resolve() -> u8 {
    if std::env::var("RDFFT_FORCE_SCALAR")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false)
    {
        return K_SCALAR;
    }
    if avx_fma_available() {
        // Full register width by default; `clamp_width` steps back down
        // to the 128-bit arm for the width-ablation benches.
        return K_AVXFMA256;
    }
    K_PORTABLE
}

/// The arm auto-dispatch runs (resolved once, then cached). Honors the
/// process-wide overrides but not per-call `EngineConfig::force_scalar` —
/// engine entry points combine both via [`select`].
pub fn active() -> Kernels {
    let v = ACTIVE.load(Ordering::Relaxed);
    if v != K_UNRESOLVED {
        return decode(v);
    }
    let r = resolve();
    ACTIVE.store(r, Ordering::Relaxed);
    decode(r)
}

/// Resolve the arm for one engine call: a per-call force wins, otherwise
/// the cached auto decision (which itself honors the global overrides).
pub fn select(force_scalar: bool) -> Kernels {
    if force_scalar {
        Kernels::LegacyScalar
    } else {
        active()
    }
}

/// Clamp a resolved arm to a maximum lane width (the
/// [`crate::rdfft::engine::EngineConfig::max_simd_width`] knob):
/// `0` or `>= 8` leaves the arm alone, `4..=7` steps the 256-bit arm
/// down to the 128-bit one (same FMA numerics, half the width), and
/// `< 4` falls all the way back to the legacy scalar loops. Widths
/// never *widen* an arm.
pub fn clamp_width(kern: Kernels, max_width: usize) -> Kernels {
    match max_width {
        0 => kern,
        1..=3 => Kernels::LegacyScalar,
        4..=7 if kern == Kernels::AvxFma256 => Kernels::AvxFma,
        _ => kern,
    }
}

/// [`select`] followed by [`clamp_width`] — the one-stop per-call
/// resolution the engine uses (force > env/global override > detection,
/// then the config's width cap).
pub fn select_width(force_scalar: bool, max_width: usize) -> Kernels {
    clamp_width(select(force_scalar), max_width)
}

/// Process-wide kill switch (the CLI's `--force-scalar`): every later
/// [`active`]/[`select`] resolves to the legacy scalar loops. Call before
/// the first transform; flipping mid-run is safe but makes earlier and
/// later calls incomparable bitwise.
pub fn force_scalar_global() {
    ACTIVE.store(K_SCALAR, Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// The lane abstraction
// ---------------------------------------------------------------------

/// Four f32 lanes: loads/stores over contiguous (optionally reversed)
/// quads plus the arithmetic the butterfly and product kernels need.
///
/// All methods are `unsafe`: pointer variants trust the caller's bounds
/// reasoning (the kernels document theirs), and the x86 implementation
/// additionally requires AVX2+FMA to be present at runtime — guaranteed
/// by [`select`] before any lane kernel runs.
pub trait Lanes4: Copy {
    type V: Copy;
    /// # Safety
    /// No memory access; unsafe only for the arm-wide feature contract.
    unsafe fn splat(v: f32) -> Self::V;
    /// Lanes `[p[0], p[1], p[2], p[3]]`.
    ///
    /// # Safety
    /// `p..p+4` must be readable f32s.
    unsafe fn load(p: *const f32) -> Self::V;
    /// Lanes `[p[3], p[2], p[1], p[0]]` — the descending-stream load.
    ///
    /// # Safety
    /// `p..p+4` must be readable f32s.
    unsafe fn load_rev(p: *const f32) -> Self::V;
    /// # Safety
    /// `p..p+4` must be writable f32s.
    unsafe fn store(p: *mut f32, v: Self::V);
    /// Store lane `i` to `p[3 - i]` (inverse of [`Lanes4::load_rev`]).
    ///
    /// # Safety
    /// `p..p+4` must be writable f32s.
    unsafe fn store_rev(p: *mut f32, v: Self::V);
    /// # Safety
    /// Lane math only (feature contract).
    unsafe fn add(a: Self::V, b: Self::V) -> Self::V;
    /// # Safety
    /// Lane math only (feature contract).
    unsafe fn sub(a: Self::V, b: Self::V) -> Self::V;
    /// # Safety
    /// Lane math only (feature contract).
    unsafe fn mul(a: Self::V, b: Self::V) -> Self::V;
    /// `a·b + c` — fused on the FMA arm, two-rounding on the portable arm
    /// (matching the scalar oracle exactly).
    ///
    /// # Safety
    /// Lane math only (feature contract).
    unsafe fn mla(a: Self::V, b: Self::V, c: Self::V) -> Self::V;
    /// `a·b − c` — fused on the FMA arm.
    ///
    /// # Safety
    /// Lane math only (feature contract).
    unsafe fn mls(a: Self::V, b: Self::V, c: Self::V) -> Self::V;
}

/// Portable quad arm: plain f32 scalar ops on `[f32; 4]`, bitwise equal
/// to the legacy scalar loops lane-for-lane.
#[derive(Clone, Copy)]
pub struct ScalarQuad;

impl Lanes4 for ScalarQuad {
    type V = [f32; 4];

    // SAFETY: no memory access — plain lane arithmetic.
    #[inline(always)]
    unsafe fn splat(v: f32) -> [f32; 4] {
        [v; 4]
    }

    // SAFETY: caller guarantees p..p+4 readable (trait contract).
    #[inline(always)]
    unsafe fn load(p: *const f32) -> [f32; 4] {
        [*p, *p.add(1), *p.add(2), *p.add(3)]
    }

    // SAFETY: caller guarantees p..p+4 readable (trait contract).
    #[inline(always)]
    unsafe fn load_rev(p: *const f32) -> [f32; 4] {
        [*p.add(3), *p.add(2), *p.add(1), *p]
    }

    // SAFETY: caller guarantees p..p+4 writable (trait contract).
    #[inline(always)]
    unsafe fn store(p: *mut f32, v: [f32; 4]) {
        *p = v[0];
        *p.add(1) = v[1];
        *p.add(2) = v[2];
        *p.add(3) = v[3];
    }

    // SAFETY: caller guarantees p..p+4 writable (trait contract).
    #[inline(always)]
    unsafe fn store_rev(p: *mut f32, v: [f32; 4]) {
        *p.add(3) = v[0];
        *p.add(2) = v[1];
        *p.add(1) = v[2];
        *p = v[3];
    }

    // SAFETY: no memory access — plain lane arithmetic.
    #[inline(always)]
    unsafe fn add(a: [f32; 4], b: [f32; 4]) -> [f32; 4] {
        [a[0] + b[0], a[1] + b[1], a[2] + b[2], a[3] + b[3]]
    }

    // SAFETY: no memory access — plain lane arithmetic.
    #[inline(always)]
    unsafe fn sub(a: [f32; 4], b: [f32; 4]) -> [f32; 4] {
        [a[0] - b[0], a[1] - b[1], a[2] - b[2], a[3] - b[3]]
    }

    // SAFETY: no memory access — plain lane arithmetic.
    #[inline(always)]
    unsafe fn mul(a: [f32; 4], b: [f32; 4]) -> [f32; 4] {
        [a[0] * b[0], a[1] * b[1], a[2] * b[2], a[3] * b[3]]
    }

    // SAFETY: no memory access — plain lane arithmetic.
    #[inline(always)]
    unsafe fn mla(a: [f32; 4], b: [f32; 4], c: [f32; 4]) -> [f32; 4] {
        // Deliberately NOT f32::mul_add: the portable arm must round the
        // product and the sum separately, like the scalar oracle.
        [
            a[0] * b[0] + c[0],
            a[1] * b[1] + c[1],
            a[2] * b[2] + c[2],
            a[3] * b[3] + c[3],
        ]
    }

    // SAFETY: no memory access — plain lane arithmetic.
    #[inline(always)]
    unsafe fn mls(a: [f32; 4], b: [f32; 4], c: [f32; 4]) -> [f32; 4] {
        [
            a[0] * b[0] - c[0],
            a[1] * b[1] - c[1],
            a[2] * b[2] - c[2],
            a[3] * b[3] - c[3],
        ]
    }
}

/// Eight f32 lanes — the width-8 tier's analogue of [`Lanes4`], with the
/// same method contracts lifted to 8-element spans. Implementations must
/// keep the per-lane expressions of their width-4 sibling so widening
/// never changes which float ops run on an element (portable: bitwise
/// identical; AVX: FMA contraction only).
pub trait Lanes8: Copy {
    type V: Copy;
    /// # Safety
    /// No memory access; unsafe only for the arm-wide feature contract.
    unsafe fn splat(v: f32) -> Self::V;
    /// Lanes `[p[0], .., p[7]]`.
    ///
    /// # Safety
    /// `p..p+8` must be readable f32s.
    unsafe fn load(p: *const f32) -> Self::V;
    /// Lanes `[p[7], .., p[0]]` — the descending-stream load.
    ///
    /// # Safety
    /// `p..p+8` must be readable f32s.
    unsafe fn load_rev(p: *const f32) -> Self::V;
    /// # Safety
    /// `p..p+8` must be writable f32s.
    unsafe fn store(p: *mut f32, v: Self::V);
    /// Store lane `i` to `p[7 - i]` (inverse of [`Lanes8::load_rev`]).
    ///
    /// # Safety
    /// `p..p+8` must be writable f32s.
    unsafe fn store_rev(p: *mut f32, v: Self::V);
    /// # Safety
    /// Lane math only (feature contract).
    unsafe fn add(a: Self::V, b: Self::V) -> Self::V;
    /// # Safety
    /// Lane math only (feature contract).
    unsafe fn sub(a: Self::V, b: Self::V) -> Self::V;
    /// # Safety
    /// Lane math only (feature contract).
    unsafe fn mul(a: Self::V, b: Self::V) -> Self::V;
    /// `a·b + c` — fused on the FMA arm, two-rounding portably.
    ///
    /// # Safety
    /// Lane math only (feature contract).
    unsafe fn mla(a: Self::V, b: Self::V, c: Self::V) -> Self::V;
    /// `a·b − c` — fused on the FMA arm.
    ///
    /// # Safety
    /// Lane math only (feature contract).
    unsafe fn mls(a: Self::V, b: Self::V, c: Self::V) -> Self::V;
}

/// Portable oct arm: plain f32 scalar ops on `[f32; 8]`. Every method is
/// exactly two [`ScalarQuad`] calls on the low/high halves, so this arm
/// is bitwise identical to the quad arm lane-for-lane (and therefore to
/// the legacy scalar loops).
#[derive(Clone, Copy)]
pub struct ScalarOct;

impl Lanes8 for ScalarOct {
    type V = [[f32; 4]; 2];

    // SAFETY: no memory access — delegates to the quad lane arithmetic.
    #[inline(always)]
    unsafe fn splat(v: f32) -> Self::V {
        [ScalarQuad::splat(v), ScalarQuad::splat(v)]
    }

    // SAFETY: caller guarantees p..p+8 readable (trait contract), which
    // covers both quad halves at p and p+4.
    #[inline(always)]
    unsafe fn load(p: *const f32) -> Self::V {
        [ScalarQuad::load(p), ScalarQuad::load(p.add(4))]
    }

    // SAFETY: caller guarantees p..p+8 readable (trait contract); the
    // halves swap so lane i reads p[7 - i].
    #[inline(always)]
    unsafe fn load_rev(p: *const f32) -> Self::V {
        // Lane 0 must read p[7]: the reversed high half comes first.
        [ScalarQuad::load_rev(p.add(4)), ScalarQuad::load_rev(p)]
    }

    // SAFETY: caller guarantees p..p+8 writable (trait contract), which
    // covers both quad halves at p and p+4.
    #[inline(always)]
    unsafe fn store(p: *mut f32, v: Self::V) {
        ScalarQuad::store(p, v[0]);
        ScalarQuad::store(p.add(4), v[1]);
    }

    // SAFETY: caller guarantees p..p+8 writable (trait contract); the
    // halves swap so lane i lands at p[7 - i].
    #[inline(always)]
    unsafe fn store_rev(p: *mut f32, v: Self::V) {
        // Lane 0 lands at p[7] (inverse of load_rev).
        ScalarQuad::store_rev(p.add(4), v[0]);
        ScalarQuad::store_rev(p, v[1]);
    }

    // SAFETY: no memory access — delegates to the quad lane arithmetic.
    #[inline(always)]
    unsafe fn add(a: Self::V, b: Self::V) -> Self::V {
        [ScalarQuad::add(a[0], b[0]), ScalarQuad::add(a[1], b[1])]
    }

    // SAFETY: no memory access — delegates to the quad lane arithmetic.
    #[inline(always)]
    unsafe fn sub(a: Self::V, b: Self::V) -> Self::V {
        [ScalarQuad::sub(a[0], b[0]), ScalarQuad::sub(a[1], b[1])]
    }

    // SAFETY: no memory access — delegates to the quad lane arithmetic.
    #[inline(always)]
    unsafe fn mul(a: Self::V, b: Self::V) -> Self::V {
        [ScalarQuad::mul(a[0], b[0]), ScalarQuad::mul(a[1], b[1])]
    }

    // SAFETY: no memory access — delegates to the quad lane arithmetic.
    #[inline(always)]
    unsafe fn mla(a: Self::V, b: Self::V, c: Self::V) -> Self::V {
        [ScalarQuad::mla(a[0], b[0], c[0]), ScalarQuad::mla(a[1], b[1], c[1])]
    }

    // SAFETY: no memory access — delegates to the quad lane arithmetic.
    #[inline(always)]
    unsafe fn mls(a: Self::V, b: Self::V, c: Self::V) -> Self::V {
        [ScalarQuad::mls(a[0], b[0], c[0]), ScalarQuad::mls(a[1], b[1], c[1])]
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{Lanes4, Lanes8};
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// 128-bit f32x4 lanes with FMA. The wrappers that instantiate the
    /// generic kernels with this type carry
    /// `#[target_feature(enable = "avx2,fma")]`, so these intrinsics
    /// inline into feature-enabled code.
    #[derive(Clone, Copy)]
    pub struct AvxFma;

    impl Lanes4 for AvxFma {
        type V = __m128;

        // SAFETY: SSE set1, no memory access; features per arm contract.
        #[inline(always)]
        unsafe fn splat(v: f32) -> __m128 {
            _mm_set1_ps(v)
        }

        // SAFETY: unaligned load; caller guarantees p..p+4 readable.
        #[inline(always)]
        unsafe fn load(p: *const f32) -> __m128 {
            _mm_loadu_ps(p)
        }

        // SAFETY: unaligned load; caller guarantees p..p+4 readable.
        #[inline(always)]
        unsafe fn load_rev(p: *const f32) -> __m128 {
            let v = _mm_loadu_ps(p);
            _mm_shuffle_ps(v, v, 0x1B) // lanes [3,2,1,0]
        }

        // SAFETY: unaligned store; caller guarantees p..p+4 writable.
        #[inline(always)]
        unsafe fn store(p: *mut f32, v: __m128) {
            _mm_storeu_ps(p, v)
        }

        // SAFETY: unaligned store; caller guarantees p..p+4 writable.
        #[inline(always)]
        unsafe fn store_rev(p: *mut f32, v: __m128) {
            _mm_storeu_ps(p, _mm_shuffle_ps(v, v, 0x1B))
        }

        // SAFETY: register math only; features per arm contract.
        #[inline(always)]
        unsafe fn add(a: __m128, b: __m128) -> __m128 {
            _mm_add_ps(a, b)
        }

        // SAFETY: register math only; features per arm contract.
        #[inline(always)]
        unsafe fn sub(a: __m128, b: __m128) -> __m128 {
            _mm_sub_ps(a, b)
        }

        // SAFETY: register math only; features per arm contract.
        #[inline(always)]
        unsafe fn mul(a: __m128, b: __m128) -> __m128 {
            _mm_mul_ps(a, b)
        }

        // SAFETY: FMA register math; features per arm contract.
        #[inline(always)]
        unsafe fn mla(a: __m128, b: __m128, c: __m128) -> __m128 {
            _mm_fmadd_ps(a, b, c)
        }

        // SAFETY: FMA register math; features per arm contract.
        #[inline(always)]
        unsafe fn mls(a: __m128, b: __m128, c: __m128) -> __m128 {
            _mm_fmsub_ps(a, b, c)
        }
    }

    /// 256-bit f32x8 lanes with FMA — the full register width of the
    /// AVX2 hardware the 128-bit arm only half-uses. Same wrapper
    /// discipline: instantiating functions carry
    /// `#[target_feature(enable = "avx2,fma")]`.
    #[derive(Clone, Copy)]
    pub struct AvxFma256;

    impl Lanes8 for AvxFma256 {
        type V = __m256;

        // SAFETY: AVX set1, no memory access; features per arm contract.
        #[inline(always)]
        unsafe fn splat(v: f32) -> __m256 {
            _mm256_set1_ps(v)
        }

        // SAFETY: unaligned load; caller guarantees p..p+8 readable.
        #[inline(always)]
        unsafe fn load(p: *const f32) -> __m256 {
            _mm256_loadu_ps(p)
        }

        // SAFETY: unaligned load; caller guarantees p..p+8 readable.
        #[inline(always)]
        unsafe fn load_rev(p: *const f32) -> __m256 {
            // Reverse within each 128-bit half, then swap the halves:
            // [0..7] -> [3,2,1,0,7,6,5,4] -> [7,6,5,4,3,2,1,0].
            let v = _mm256_loadu_ps(p);
            let r = _mm256_shuffle_ps(v, v, 0x1B);
            _mm256_permute2f128_ps(r, r, 0x01)
        }

        // SAFETY: unaligned store; caller guarantees p..p+8 writable.
        #[inline(always)]
        unsafe fn store(p: *mut f32, v: __m256) {
            _mm256_storeu_ps(p, v)
        }

        // SAFETY: unaligned store; caller guarantees p..p+8 writable.
        #[inline(always)]
        unsafe fn store_rev(p: *mut f32, v: __m256) {
            let r = _mm256_shuffle_ps(v, v, 0x1B);
            _mm256_storeu_ps(p, _mm256_permute2f128_ps(r, r, 0x01))
        }

        // SAFETY: register math only; features per arm contract.
        #[inline(always)]
        unsafe fn add(a: __m256, b: __m256) -> __m256 {
            _mm256_add_ps(a, b)
        }

        // SAFETY: register math only; features per arm contract.
        #[inline(always)]
        unsafe fn sub(a: __m256, b: __m256) -> __m256 {
            _mm256_sub_ps(a, b)
        }

        // SAFETY: register math only; features per arm contract.
        #[inline(always)]
        unsafe fn mul(a: __m256, b: __m256) -> __m256 {
            _mm256_mul_ps(a, b)
        }

        // SAFETY: FMA register math; features per arm contract.
        #[inline(always)]
        unsafe fn mla(a: __m256, b: __m256, c: __m256) -> __m256 {
            _mm256_fmadd_ps(a, b, c)
        }

        // SAFETY: FMA register math; features per arm contract.
        #[inline(always)]
        unsafe fn mls(a: __m256, b: __m256, c: __m256) -> __m256 {
            _mm256_fmsub_ps(a, b, c)
        }
    }
}

// ---------------------------------------------------------------------
// Butterfly group kernels
// ---------------------------------------------------------------------

/// One quad of forward symmetric 4-groups (`k = k0 .. k0+3`) of a
/// `2m`-block at `blk`. Lane `i` computes group `k0 + i`, with the exact
/// per-element expression of the scalar butterfly.
///
/// # Safety
/// `blk` points at a block of `two_m = 2m` f32s; `1 ≤ k0` and
/// `k0 + 3 < m/2`; `wr`/`wi` hold the stage twiddles indexed `k − 1` with
/// at least `k0 + 2` entries readable from `k0 − 1`.
#[inline(always)]
unsafe fn fwd_quad<L: Lanes4>(
    blk: *mut f32,
    m: usize,
    two_m: usize,
    k0: usize,
    wr: *const f32,
    wi: *const f32,
) {
    let er = L::load(blk.add(k0)); //                E.re, ascending
    let ei = L::load_rev(blk.add(m - k0 - 3)); //    E.im, descending
    let or_ = L::load(blk.add(m + k0)); //           O.re, ascending
    let oi = L::load_rev(blk.add(two_m - k0 - 3)); //O.im, descending
    let w_r = L::load(wr.add(k0 - 1));
    let w_i = L::load(wi.add(k0 - 1));
    // T = W·O
    let tr = L::mls(w_r, or_, L::mul(w_i, oi)); // wr*or − wi*oi
    let ti = L::mla(w_r, oi, L::mul(w_i, or_)); // wr*oi + wi*or
    L::store(blk.add(k0), L::add(er, tr)); //              Re y_k
    L::store_rev(blk.add(two_m - k0 - 3), L::add(ei, ti)); // Im y_k
    L::store_rev(blk.add(m - k0 - 3), L::sub(er, tr)); //  Re y_{m−k}
    L::store(blk.add(m + k0), L::sub(ti, ei)); //          Im y_{m−k}
}

/// One quad of inverse symmetric 4-groups (pre-halved twiddles `hr`/`hi`,
/// see [`crate::rdfft::inverse`]).
///
/// # Safety
/// Same contract as [`fwd_quad`].
#[inline(always)]
unsafe fn inv_quad<L: Lanes4>(
    blk: *mut f32,
    m: usize,
    two_m: usize,
    k0: usize,
    hr: *const f32,
    hi: *const f32,
) {
    let a = L::load(blk.add(k0)); //                 er + tr
    let b = L::load_rev(blk.add(m - k0 - 3)); //     er − tr
    let c = L::load_rev(blk.add(two_m - k0 - 3)); // ei + ti
    let d = L::load(blk.add(m + k0)); //             ti − ei
    let h_r = L::load(hr.add(k0 - 1));
    let h_i = L::load(hi.add(k0 - 1));
    let half = L::splat(0.5);
    let apb = L::add(a, b);
    let amb = L::sub(a, b);
    let cpd = L::add(c, d);
    let cmd = L::sub(c, d);
    let er = L::mul(half, apb); //               0.5·(a+b)
    let ei = L::mul(half, cmd); //               0.5·(c−d)
    let or_ = L::mla(amb, h_r, L::mul(cpd, h_i)); // (a−b)·hr + (c+d)·hi
    let oi = L::mls(cpd, h_r, L::mul(amb, h_i)); //  (c+d)·hr − (a−b)·hi
    L::store(blk.add(k0), er);
    L::store_rev(blk.add(m - k0 - 3), ei);
    L::store(blk.add(m + k0), or_);
    L::store_rev(blk.add(two_m - k0 - 3), oi);
}

/// One oct of forward symmetric 4-groups (`k = k0 .. k0+7`) — the
/// width-8 twin of [`fwd_quad`], same per-lane expressions.
///
/// # Safety
/// `blk` points at a block of `two_m = 2m` f32s; `1 ≤ k0` and
/// `k0 + 7 < m/2`; `wr`/`wi` hold the stage twiddles indexed `k − 1` with
/// at least `k0 + 6` entries readable from `k0 − 1`.
#[inline(always)]
unsafe fn fwd_oct<L: Lanes8>(
    blk: *mut f32,
    m: usize,
    two_m: usize,
    k0: usize,
    wr: *const f32,
    wi: *const f32,
) {
    let er = L::load(blk.add(k0)); //                E.re, ascending
    let ei = L::load_rev(blk.add(m - k0 - 7)); //    E.im, descending
    let or_ = L::load(blk.add(m + k0)); //           O.re, ascending
    let oi = L::load_rev(blk.add(two_m - k0 - 7)); //O.im, descending
    let w_r = L::load(wr.add(k0 - 1));
    let w_i = L::load(wi.add(k0 - 1));
    // T = W·O
    let tr = L::mls(w_r, or_, L::mul(w_i, oi)); // wr*or − wi*oi
    let ti = L::mla(w_r, oi, L::mul(w_i, or_)); // wr*oi + wi*or
    L::store(blk.add(k0), L::add(er, tr)); //              Re y_k
    L::store_rev(blk.add(two_m - k0 - 7), L::add(ei, ti)); // Im y_k
    L::store_rev(blk.add(m - k0 - 7), L::sub(er, tr)); //  Re y_{m−k}
    L::store(blk.add(m + k0), L::sub(ti, ei)); //          Im y_{m−k}
}

/// One oct of inverse symmetric 4-groups (pre-halved twiddles; the
/// width-8 twin of [`inv_quad`]).
///
/// # Safety
/// Same contract as [`fwd_oct`].
#[inline(always)]
unsafe fn inv_oct<L: Lanes8>(
    blk: *mut f32,
    m: usize,
    two_m: usize,
    k0: usize,
    hr: *const f32,
    hi: *const f32,
) {
    let a = L::load(blk.add(k0)); //                 er + tr
    let b = L::load_rev(blk.add(m - k0 - 7)); //     er − tr
    let c = L::load_rev(blk.add(two_m - k0 - 7)); // ei + ti
    let d = L::load(blk.add(m + k0)); //             ti − ei
    let h_r = L::load(hr.add(k0 - 1));
    let h_i = L::load(hi.add(k0 - 1));
    let half = L::splat(0.5);
    let apb = L::add(a, b);
    let amb = L::sub(a, b);
    let cpd = L::add(c, d);
    let cmd = L::sub(c, d);
    let er = L::mul(half, apb); //               0.5·(a+b)
    let ei = L::mul(half, cmd); //               0.5·(c−d)
    let or_ = L::mla(amb, h_r, L::mul(cpd, h_i)); // (a−b)·hr + (c+d)·hi
    let oi = L::mls(cpd, h_r, L::mul(amb, h_i)); //  (c+d)·hr − (a−b)·hi
    L::store(blk.add(k0), er);
    L::store_rev(blk.add(m - k0 - 7), ei);
    L::store(blk.add(m + k0), or_);
    L::store_rev(blk.add(two_m - k0 - 7), oi);
}

/// The scalar forward 4-group (identical float ops to the legacy kernel;
/// the quad loops' tail).
///
/// # Safety
/// `blk` has length `2m`; `1 ≤ k < m/2`.
#[inline(always)]
unsafe fn fwd_group_scalar(blk: *mut f32, m: usize, two_m: usize, k: usize, wr: f32, wi: f32) {
    let er = *blk.add(k);
    let ei = *blk.add(m - k);
    let or_ = *blk.add(m + k);
    let oi = *blk.add(two_m - k);
    let tr = wr * or_ - wi * oi;
    let ti = wr * oi + wi * or_;
    *blk.add(k) = er + tr;
    *blk.add(two_m - k) = ei + ti;
    *blk.add(m - k) = er - tr;
    *blk.add(m + k) = ti - ei;
}

/// The scalar inverse 4-group (legacy ops; the quad loops' tail).
///
/// # Safety
/// `blk` has length `2m`; `1 ≤ k < m/2`.
#[inline(always)]
unsafe fn inv_group_scalar(blk: *mut f32, m: usize, two_m: usize, k: usize, hr: f32, hi: f32) {
    let a = *blk.add(k);
    let b = *blk.add(m - k);
    let c = *blk.add(two_m - k);
    let d = *blk.add(m + k);
    let er = 0.5 * (a + b);
    let ei = 0.5 * (c - d);
    let or_ = (a - b) * hr + (c + d) * hi;
    let oi = (c + d) * hr - (a - b) * hi;
    *blk.add(k) = er;
    *blk.add(m - k) = ei;
    *blk.add(m + k) = or_;
    *blk.add(two_m - k) = oi;
}

/// All forward 4-groups of one `2m`-block: vector quads, then a scalar
/// tail of up to `LANES − 1` groups (plus everything when `m/2 − 1 < 4`).
///
/// # Safety
/// `blk.len() == 2m`; `wr`/`wi` hold at least `m/2 − 1` stage-twiddle
/// entries (index `k − 1`).
#[inline(always)]
unsafe fn fwd_groups<L: Lanes4>(blk: &mut [f32], m: usize, wr: &[f32], wi: &[f32]) {
    let two_m = 2 * m;
    debug_assert_eq!(blk.len(), two_m);
    let half = m / 2;
    debug_assert!(half == 0 || wr.len() >= half - 1);
    let p = blk.as_mut_ptr();
    let (wrp, wip) = (wr.as_ptr(), wi.as_ptr());
    let mut k = 1usize;
    while k + LANES <= half {
        fwd_quad::<L>(p, m, two_m, k, wrp, wip);
        k += LANES;
    }
    while k < half {
        fwd_group_scalar(p, m, two_m, k, *wrp.add(k - 1), *wip.add(k - 1));
        k += 1;
    }
}

/// All inverse 4-groups of one `2m`-block (quads + scalar tail).
///
/// # Safety
/// Same contract as [`fwd_groups`] with pre-halved twiddles.
#[inline(always)]
unsafe fn inv_groups<L: Lanes4>(blk: &mut [f32], m: usize, hr: &[f32], hi: &[f32]) {
    let two_m = 2 * m;
    debug_assert_eq!(blk.len(), two_m);
    let half = m / 2;
    debug_assert!(half == 0 || hr.len() >= half - 1);
    let p = blk.as_mut_ptr();
    let (hrp, hip) = (hr.as_ptr(), hi.as_ptr());
    let mut k = 1usize;
    while k + LANES <= half {
        inv_quad::<L>(p, m, two_m, k, hrp, hip);
        k += LANES;
    }
    while k < half {
        inv_group_scalar(p, m, two_m, k, *hrp.add(k - 1), *hip.add(k - 1));
        k += 1;
    }
}

/// All forward 4-groups of one `2m`-block on the width-8 tier: oct main
/// loop, width-4 step, scalar tail. Grouping never reorders any
/// per-element op (slot-disjoint groups), so `<ScalarOct, ScalarQuad>`
/// is bitwise identical to [`fwd_groups`]`::<ScalarQuad>`.
///
/// # Safety
/// Same contract as [`fwd_groups`].
#[inline(always)]
unsafe fn fwd_groups8<L8: Lanes8, L4: Lanes4>(blk: &mut [f32], m: usize, wr: &[f32], wi: &[f32]) {
    let two_m = 2 * m;
    debug_assert_eq!(blk.len(), two_m);
    let half = m / 2;
    debug_assert!(half == 0 || wr.len() >= half - 1);
    let p = blk.as_mut_ptr();
    let (wrp, wip) = (wr.as_ptr(), wi.as_ptr());
    let mut k = 1usize;
    while k + LANES8 <= half {
        fwd_oct::<L8>(p, m, two_m, k, wrp, wip);
        k += LANES8;
    }
    while k + LANES <= half {
        fwd_quad::<L4>(p, m, two_m, k, wrp, wip);
        k += LANES;
    }
    while k < half {
        fwd_group_scalar(p, m, two_m, k, *wrp.add(k - 1), *wip.add(k - 1));
        k += 1;
    }
}

/// All inverse 4-groups of one `2m`-block on the width-8 tier (oct main
/// loop, quad step, scalar tail).
///
/// # Safety
/// Same contract as [`fwd_groups`] with pre-halved twiddles.
#[inline(always)]
unsafe fn inv_groups8<L8: Lanes8, L4: Lanes4>(blk: &mut [f32], m: usize, hr: &[f32], hi: &[f32]) {
    let two_m = 2 * m;
    debug_assert_eq!(blk.len(), two_m);
    let half = m / 2;
    debug_assert!(half == 0 || hr.len() >= half - 1);
    let p = blk.as_mut_ptr();
    let (hrp, hip) = (hr.as_ptr(), hi.as_ptr());
    let mut k = 1usize;
    while k + LANES8 <= half {
        inv_oct::<L8>(p, m, two_m, k, hrp, hip);
        k += LANES8;
    }
    while k + LANES <= half {
        inv_quad::<L4>(p, m, two_m, k, hrp, hip);
        k += LANES;
    }
    while k < half {
        inv_group_scalar(p, m, two_m, k, *hrp.add(k - 1), *hip.add(k - 1));
        k += 1;
    }
}

// Monomorphic feature-gated instantiations: `#[inline(always)]` generics
// inline *into* the target_feature wrapper, which is what lets the
// intrinsics fuse into straight-line AVX2+FMA code.

// SAFETY: same contract as fwd_groups; ScalarQuad needs no CPU features.
unsafe fn fwd_groups_portable(blk: &mut [f32], m: usize, wr: &[f32], wi: &[f32]) {
    fwd_groups::<ScalarQuad>(blk, m, wr, wi)
}

// SAFETY: same contract as inv_groups; ScalarQuad needs no CPU features.
unsafe fn inv_groups_portable(blk: &mut [f32], m: usize, hr: &[f32], hi: &[f32]) {
    inv_groups::<ScalarQuad>(blk, m, hr, hi)
}

// SAFETY: same contract as fwd_groups, plus AVX2+FMA present at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn fwd_groups_avx(blk: &mut [f32], m: usize, wr: &[f32], wi: &[f32]) {
    fwd_groups::<x86::AvxFma>(blk, m, wr, wi)
}

// SAFETY: same contract as inv_groups, plus AVX2+FMA present at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn inv_groups_avx(blk: &mut [f32], m: usize, hr: &[f32], hi: &[f32]) {
    inv_groups::<x86::AvxFma>(blk, m, hr, hi)
}

// SAFETY: same contract as fwd_groups8; the portable oct arm needs no
// CPU features.
unsafe fn fwd_groups8_portable(blk: &mut [f32], m: usize, wr: &[f32], wi: &[f32]) {
    fwd_groups8::<ScalarOct, ScalarQuad>(blk, m, wr, wi)
}

// SAFETY: same contract as inv_groups8; no CPU features needed.
unsafe fn inv_groups8_portable(blk: &mut [f32], m: usize, hr: &[f32], hi: &[f32]) {
    inv_groups8::<ScalarOct, ScalarQuad>(blk, m, hr, hi)
}

// SAFETY: same contract as fwd_groups8, plus AVX2+FMA present at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn fwd_groups8_avx(blk: &mut [f32], m: usize, wr: &[f32], wi: &[f32]) {
    fwd_groups8::<x86::AvxFma256, x86::AvxFma>(blk, m, wr, wi)
}

// SAFETY: same contract as inv_groups8, plus AVX2+FMA present at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn inv_groups8_avx(blk: &mut [f32], m: usize, hr: &[f32], hi: &[f32]) {
    inv_groups8::<x86::AvxFma256, x86::AvxFma>(blk, m, hr, hi)
}

/// Dispatch the forward 4-group sweep of one block onto `kern`.
///
/// # Safety
/// `blk.len() == 2m`; `wr`/`wi` hold at least `m/2 − 1` entries; when
/// `kern` is [`Kernels::AvxFma`] the CPU must support AVX2+FMA (guaranteed
/// when the value came from [`select`]).
#[inline(always)]
pub unsafe fn fwd_groups_dispatch(kern: Kernels, blk: &mut [f32], m: usize, wr: &[f32], wi: &[f32]) {
    match kern {
        Kernels::LegacyScalar => {
            let two_m = 2 * m;
            let p = blk.as_mut_ptr();
            for k in 1..m / 2 {
                fwd_group_scalar(p, m, two_m, k, wr[k - 1], wi[k - 1]);
            }
        }
        Kernels::Portable => fwd_groups_portable(blk, m, wr, wi),
        Kernels::AvxFma => {
            #[cfg(target_arch = "x86_64")]
            fwd_groups_avx(blk, m, wr, wi);
            #[cfg(not(target_arch = "x86_64"))]
            fwd_groups_portable(blk, m, wr, wi);
        }
        Kernels::AvxFma256 => {
            #[cfg(target_arch = "x86_64")]
            fwd_groups8_avx(blk, m, wr, wi);
            #[cfg(not(target_arch = "x86_64"))]
            fwd_groups8_portable(blk, m, wr, wi);
        }
    }
}

/// Dispatch the inverse 4-group sweep of one block onto `kern`.
///
/// # Safety
/// Same contract as [`fwd_groups_dispatch`] with pre-halved twiddles.
#[inline(always)]
pub unsafe fn inv_groups_dispatch(kern: Kernels, blk: &mut [f32], m: usize, hr: &[f32], hi: &[f32]) {
    match kern {
        Kernels::LegacyScalar => {
            let two_m = 2 * m;
            let p = blk.as_mut_ptr();
            for k in 1..m / 2 {
                inv_group_scalar(p, m, two_m, k, hr[k - 1], hi[k - 1]);
            }
        }
        Kernels::Portable => inv_groups_portable(blk, m, hr, hi),
        Kernels::AvxFma => {
            #[cfg(target_arch = "x86_64")]
            inv_groups_avx(blk, m, hr, hi);
            #[cfg(not(target_arch = "x86_64"))]
            inv_groups_portable(blk, m, hr, hi);
        }
        Kernels::AvxFma256 => {
            #[cfg(target_arch = "x86_64")]
            inv_groups8_avx(blk, m, hr, hi);
            #[cfg(not(target_arch = "x86_64"))]
            inv_groups8_portable(blk, m, hr, hi);
        }
    }
}

// ---------------------------------------------------------------------
// Packed conjugate-symmetric product kernels
// ---------------------------------------------------------------------

/// `a ⊙= b` over one packed row (quads + scalar tail; DC/Nyquist scalar).
///
/// # Safety
/// `a.len() == b.len()`, even, ≥ 2.
#[inline(always)]
unsafe fn mul_row<L: Lanes4>(a: &mut [f32], b: &[f32]) {
    let n = a.len();
    debug_assert!(n >= 2 && n % 2 == 0 && b.len() == n);
    let half = n / 2;
    let ap = a.as_mut_ptr();
    let bp = b.as_ptr();
    *ap *= *bp;
    *ap.add(half) *= *bp.add(half);
    let mut k = 1usize;
    while k + LANES <= half {
        let ar = L::load(ap.add(k));
        let ai = L::load_rev(ap.add(n - k - 3));
        let br = L::load(bp.add(k));
        let bi = L::load_rev(bp.add(n - k - 3));
        let re = L::mls(ar, br, L::mul(ai, bi)); // ar·br − ai·bi
        let im = L::mla(ar, bi, L::mul(ai, br)); // ar·bi + ai·br
        L::store(ap.add(k), re);
        L::store_rev(ap.add(n - k - 3), im);
        k += LANES;
    }
    while k < half {
        let (ar, ai) = (*ap.add(k), *ap.add(n - k));
        let (br, bi) = (*bp.add(k), *bp.add(n - k));
        *ap.add(k) = ar * br - ai * bi;
        *ap.add(n - k) = ar * bi + ai * br;
        k += 1;
    }
}

/// `a ⊙= conj(b)` over one packed row.
///
/// # Safety
/// `a.len() == b.len()`, even, ≥ 2.
#[inline(always)]
unsafe fn mul_conjb_row<L: Lanes4>(a: &mut [f32], b: &[f32]) {
    let n = a.len();
    debug_assert!(n >= 2 && n % 2 == 0 && b.len() == n);
    let half = n / 2;
    let ap = a.as_mut_ptr();
    let bp = b.as_ptr();
    *ap *= *bp;
    *ap.add(half) *= *bp.add(half);
    let mut k = 1usize;
    while k + LANES <= half {
        let ar = L::load(ap.add(k));
        let ai = L::load_rev(ap.add(n - k - 3));
        let br = L::load(bp.add(k));
        let bi = L::load_rev(bp.add(n - k - 3));
        let re = L::mla(ar, br, L::mul(ai, bi)); // ar·br + ai·bi
        let im = L::mls(ai, br, L::mul(ar, bi)); // ai·br − ar·bi
        L::store(ap.add(k), re);
        L::store_rev(ap.add(n - k - 3), im);
        k += LANES;
    }
    while k < half {
        let (ar, ai) = (*ap.add(k), *ap.add(n - k));
        let (br, bi) = (*bp.add(k), *bp.add(n - k));
        *ap.add(k) = ar * br + ai * bi;
        *ap.add(n - k) = ai * br - ar * bi;
        k += 1;
    }
}

/// `acc += a ⊙ b` over one packed row.
///
/// # Safety
/// All three slices share one even length ≥ 2.
#[inline(always)]
unsafe fn mul_acc_row<L: Lanes4>(acc: &mut [f32], a: &[f32], b: &[f32]) {
    let n = acc.len();
    debug_assert!(n >= 2 && n % 2 == 0 && a.len() == n && b.len() == n);
    let half = n / 2;
    let cp = acc.as_mut_ptr();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    *cp += *ap * *bp;
    *cp.add(half) += *ap.add(half) * *bp.add(half);
    let mut k = 1usize;
    while k + LANES <= half {
        let ar = L::load(ap.add(k));
        let ai = L::load_rev(ap.add(n - k - 3));
        let br = L::load(bp.add(k));
        let bi = L::load_rev(bp.add(n - k - 3));
        let re = L::mls(ar, br, L::mul(ai, bi));
        let im = L::mla(ar, bi, L::mul(ai, br));
        L::store(cp.add(k), L::add(L::load(cp.add(k)), re));
        let ci = L::load_rev(cp.add(n - k - 3));
        L::store_rev(cp.add(n - k - 3), L::add(ci, im));
        k += LANES;
    }
    while k < half {
        let (ar, ai) = (*ap.add(k), *ap.add(n - k));
        let (br, bi) = (*bp.add(k), *bp.add(n - k));
        *cp.add(k) += ar * br - ai * bi;
        *cp.add(n - k) += ar * bi + ai * br;
        k += 1;
    }
}

/// `acc += conj(a) ⊙ b` over one packed row.
///
/// # Safety
/// All three slices share one even length ≥ 2.
#[inline(always)]
unsafe fn conj_mul_acc_row<L: Lanes4>(acc: &mut [f32], a: &[f32], b: &[f32]) {
    let n = acc.len();
    debug_assert!(n >= 2 && n % 2 == 0 && a.len() == n && b.len() == n);
    let half = n / 2;
    let cp = acc.as_mut_ptr();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    *cp += *ap * *bp;
    *cp.add(half) += *ap.add(half) * *bp.add(half);
    let mut k = 1usize;
    while k + LANES <= half {
        let ar = L::load(ap.add(k));
        let ai = L::load_rev(ap.add(n - k - 3));
        let br = L::load(bp.add(k));
        let bi = L::load_rev(bp.add(n - k - 3));
        let re = L::mla(ar, br, L::mul(ai, bi)); // ar·br + ai·bi
        let im = L::mls(ar, bi, L::mul(ai, br)); // ar·bi − ai·br
        L::store(cp.add(k), L::add(L::load(cp.add(k)), re));
        let ci = L::load_rev(cp.add(n - k - 3));
        L::store_rev(cp.add(n - k - 3), L::add(ci, im));
        k += LANES;
    }
    while k < half {
        let (ar, ai) = (*ap.add(k), *ap.add(n - k));
        let (br, bi) = (*bp.add(k), *bp.add(n - k));
        *cp.add(k) += ar * br + ai * bi;
        *cp.add(n - k) += ar * bi - ai * br;
        k += 1;
    }
}

/// `a ⊙= b` over one packed row on the width-8 tier (octs, then quads,
/// then the scalar tail; DC/Nyquist scalar). Same per-element
/// expressions as [`mul_row`].
///
/// # Safety
/// `a.len() == b.len()`, even, ≥ 2.
#[inline(always)]
unsafe fn mul_row8<L8: Lanes8, L4: Lanes4>(a: &mut [f32], b: &[f32]) {
    let n = a.len();
    debug_assert!(n >= 2 && n % 2 == 0 && b.len() == n);
    let half = n / 2;
    let ap = a.as_mut_ptr();
    let bp = b.as_ptr();
    *ap *= *bp;
    *ap.add(half) *= *bp.add(half);
    let mut k = 1usize;
    while k + LANES8 <= half {
        let ar = L8::load(ap.add(k));
        let ai = L8::load_rev(ap.add(n - k - 7));
        let br = L8::load(bp.add(k));
        let bi = L8::load_rev(bp.add(n - k - 7));
        let re = L8::mls(ar, br, L8::mul(ai, bi)); // ar·br − ai·bi
        let im = L8::mla(ar, bi, L8::mul(ai, br)); // ar·bi + ai·br
        L8::store(ap.add(k), re);
        L8::store_rev(ap.add(n - k - 7), im);
        k += LANES8;
    }
    while k + LANES <= half {
        let ar = L4::load(ap.add(k));
        let ai = L4::load_rev(ap.add(n - k - 3));
        let br = L4::load(bp.add(k));
        let bi = L4::load_rev(bp.add(n - k - 3));
        let re = L4::mls(ar, br, L4::mul(ai, bi));
        let im = L4::mla(ar, bi, L4::mul(ai, br));
        L4::store(ap.add(k), re);
        L4::store_rev(ap.add(n - k - 3), im);
        k += LANES;
    }
    while k < half {
        let (ar, ai) = (*ap.add(k), *ap.add(n - k));
        let (br, bi) = (*bp.add(k), *bp.add(n - k));
        *ap.add(k) = ar * br - ai * bi;
        *ap.add(n - k) = ar * bi + ai * br;
        k += 1;
    }
}

/// `a ⊙= conj(b)` over one packed row on the width-8 tier.
///
/// # Safety
/// `a.len() == b.len()`, even, ≥ 2.
#[inline(always)]
unsafe fn mul_conjb_row8<L8: Lanes8, L4: Lanes4>(a: &mut [f32], b: &[f32]) {
    let n = a.len();
    debug_assert!(n >= 2 && n % 2 == 0 && b.len() == n);
    let half = n / 2;
    let ap = a.as_mut_ptr();
    let bp = b.as_ptr();
    *ap *= *bp;
    *ap.add(half) *= *bp.add(half);
    let mut k = 1usize;
    while k + LANES8 <= half {
        let ar = L8::load(ap.add(k));
        let ai = L8::load_rev(ap.add(n - k - 7));
        let br = L8::load(bp.add(k));
        let bi = L8::load_rev(bp.add(n - k - 7));
        let re = L8::mla(ar, br, L8::mul(ai, bi)); // ar·br + ai·bi
        let im = L8::mls(ai, br, L8::mul(ar, bi)); // ai·br − ar·bi
        L8::store(ap.add(k), re);
        L8::store_rev(ap.add(n - k - 7), im);
        k += LANES8;
    }
    while k + LANES <= half {
        let ar = L4::load(ap.add(k));
        let ai = L4::load_rev(ap.add(n - k - 3));
        let br = L4::load(bp.add(k));
        let bi = L4::load_rev(bp.add(n - k - 3));
        let re = L4::mla(ar, br, L4::mul(ai, bi));
        let im = L4::mls(ai, br, L4::mul(ar, bi));
        L4::store(ap.add(k), re);
        L4::store_rev(ap.add(n - k - 3), im);
        k += LANES;
    }
    while k < half {
        let (ar, ai) = (*ap.add(k), *ap.add(n - k));
        let (br, bi) = (*bp.add(k), *bp.add(n - k));
        *ap.add(k) = ar * br + ai * bi;
        *ap.add(n - k) = ai * br - ar * bi;
        k += 1;
    }
}

/// `acc += a ⊙ b` over one packed row on the width-8 tier.
///
/// # Safety
/// All three slices share one even length ≥ 2.
#[inline(always)]
unsafe fn mul_acc_row8<L8: Lanes8, L4: Lanes4>(acc: &mut [f32], a: &[f32], b: &[f32]) {
    let n = acc.len();
    debug_assert!(n >= 2 && n % 2 == 0 && a.len() == n && b.len() == n);
    let half = n / 2;
    let cp = acc.as_mut_ptr();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    *cp += *ap * *bp;
    *cp.add(half) += *ap.add(half) * *bp.add(half);
    let mut k = 1usize;
    while k + LANES8 <= half {
        let ar = L8::load(ap.add(k));
        let ai = L8::load_rev(ap.add(n - k - 7));
        let br = L8::load(bp.add(k));
        let bi = L8::load_rev(bp.add(n - k - 7));
        let re = L8::mls(ar, br, L8::mul(ai, bi));
        let im = L8::mla(ar, bi, L8::mul(ai, br));
        L8::store(cp.add(k), L8::add(L8::load(cp.add(k)), re));
        let ci = L8::load_rev(cp.add(n - k - 7));
        L8::store_rev(cp.add(n - k - 7), L8::add(ci, im));
        k += LANES8;
    }
    while k + LANES <= half {
        let ar = L4::load(ap.add(k));
        let ai = L4::load_rev(ap.add(n - k - 3));
        let br = L4::load(bp.add(k));
        let bi = L4::load_rev(bp.add(n - k - 3));
        let re = L4::mls(ar, br, L4::mul(ai, bi));
        let im = L4::mla(ar, bi, L4::mul(ai, br));
        L4::store(cp.add(k), L4::add(L4::load(cp.add(k)), re));
        let ci = L4::load_rev(cp.add(n - k - 3));
        L4::store_rev(cp.add(n - k - 3), L4::add(ci, im));
        k += LANES;
    }
    while k < half {
        let (ar, ai) = (*ap.add(k), *ap.add(n - k));
        let (br, bi) = (*bp.add(k), *bp.add(n - k));
        *cp.add(k) += ar * br - ai * bi;
        *cp.add(n - k) += ar * bi + ai * br;
        k += 1;
    }
}

/// `acc += conj(a) ⊙ b` over one packed row on the width-8 tier.
///
/// # Safety
/// All three slices share one even length ≥ 2.
#[inline(always)]
unsafe fn conj_mul_acc_row8<L8: Lanes8, L4: Lanes4>(acc: &mut [f32], a: &[f32], b: &[f32]) {
    let n = acc.len();
    debug_assert!(n >= 2 && n % 2 == 0 && a.len() == n && b.len() == n);
    let half = n / 2;
    let cp = acc.as_mut_ptr();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    *cp += *ap * *bp;
    *cp.add(half) += *ap.add(half) * *bp.add(half);
    let mut k = 1usize;
    while k + LANES8 <= half {
        let ar = L8::load(ap.add(k));
        let ai = L8::load_rev(ap.add(n - k - 7));
        let br = L8::load(bp.add(k));
        let bi = L8::load_rev(bp.add(n - k - 7));
        let re = L8::mla(ar, br, L8::mul(ai, bi)); // ar·br + ai·bi
        let im = L8::mls(ar, bi, L8::mul(ai, br)); // ar·bi − ai·br
        L8::store(cp.add(k), L8::add(L8::load(cp.add(k)), re));
        let ci = L8::load_rev(cp.add(n - k - 7));
        L8::store_rev(cp.add(n - k - 7), L8::add(ci, im));
        k += LANES8;
    }
    while k + LANES <= half {
        let ar = L4::load(ap.add(k));
        let ai = L4::load_rev(ap.add(n - k - 3));
        let br = L4::load(bp.add(k));
        let bi = L4::load_rev(bp.add(n - k - 3));
        let re = L4::mla(ar, br, L4::mul(ai, bi));
        let im = L4::mls(ar, bi, L4::mul(ai, br));
        L4::store(cp.add(k), L4::add(L4::load(cp.add(k)), re));
        let ci = L4::load_rev(cp.add(n - k - 3));
        L4::store_rev(cp.add(n - k - 3), L4::add(ci, im));
        k += LANES;
    }
    while k < half {
        let (ar, ai) = (*ap.add(k), *ap.add(n - k));
        let (br, bi) = (*bp.add(k), *bp.add(n - k));
        *cp.add(k) += ar * br + ai * bi;
        *cp.add(n - k) += ar * bi - ai * br;
        k += 1;
    }
}

// SAFETY: same contract as mul_row, plus AVX2+FMA present at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mul_row_avx(a: &mut [f32], b: &[f32]) {
    mul_row::<x86::AvxFma>(a, b)
}

// SAFETY: same contract as mul_conjb_row, plus AVX2+FMA at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mul_conjb_row_avx(a: &mut [f32], b: &[f32]) {
    mul_conjb_row::<x86::AvxFma>(a, b)
}

// SAFETY: same contract as mul_acc_row, plus AVX2+FMA at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mul_acc_row_avx(acc: &mut [f32], a: &[f32], b: &[f32]) {
    mul_acc_row::<x86::AvxFma>(acc, a, b)
}

// SAFETY: same contract as conj_mul_acc_row, plus AVX2+FMA at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn conj_mul_acc_row_avx(acc: &mut [f32], a: &[f32], b: &[f32]) {
    conj_mul_acc_row::<x86::AvxFma>(acc, a, b)
}

// SAFETY: same contract as mul_row8, plus AVX2+FMA present at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mul_row8_avx(a: &mut [f32], b: &[f32]) {
    mul_row8::<x86::AvxFma256, x86::AvxFma>(a, b)
}

// SAFETY: same contract as mul_conjb_row8, plus AVX2+FMA at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mul_conjb_row8_avx(a: &mut [f32], b: &[f32]) {
    mul_conjb_row8::<x86::AvxFma256, x86::AvxFma>(a, b)
}

// SAFETY: same contract as mul_acc_row8, plus AVX2+FMA at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mul_acc_row8_avx(acc: &mut [f32], a: &[f32], b: &[f32]) {
    mul_acc_row8::<x86::AvxFma256, x86::AvxFma>(acc, a, b)
}

// SAFETY: same contract as conj_mul_acc_row8, plus AVX2+FMA at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn conj_mul_acc_row8_avx(acc: &mut [f32], a: &[f32], b: &[f32]) {
    conj_mul_acc_row8::<x86::AvxFma256, x86::AvxFma>(acc, a, b)
}

/// `a ⊙= b` (packed) on the selected arm. Legacy arm is
/// [`crate::rdfft::spectral::mul_inplace`] bit-for-bit; the portable arm
/// matches it too; AVX2+FMA agrees within the n-scaled tolerance.
pub fn mul_inplace_with(kern: Kernels, a: &mut [f32], b: &[f32]) {
    match sanitize(kern) {
        Kernels::LegacyScalar => crate::rdfft::spectral::mul_inplace(a, b),
        // SAFETY: packed rows share one even length >= 2 (spectral layout
        // invariant, debug-asserted in mul_row); no CPU features needed.
        Kernels::Portable => unsafe { mul_row::<ScalarQuad>(a, b) },
        // SAFETY: same row contract; the AvxFma arm is only ever produced
        // by select() after runtime AVX2+FMA detection.
        Kernels::AvxFma => unsafe {
            #[cfg(target_arch = "x86_64")]
            mul_row_avx(a, b);
            #[cfg(not(target_arch = "x86_64"))]
            mul_row::<ScalarQuad>(a, b);
        },
        // SAFETY: same row contract; AvxFma256 only comes from resolve()
        // after runtime AVX2+FMA detection (256-bit regs included).
        Kernels::AvxFma256 => unsafe {
            #[cfg(target_arch = "x86_64")]
            mul_row8_avx(a, b);
            #[cfg(not(target_arch = "x86_64"))]
            mul_row8::<ScalarOct, ScalarQuad>(a, b);
        },
    }
}

/// `a ⊙= conj(b)` (packed) on the selected arm.
pub fn mul_conjb_inplace_with(kern: Kernels, a: &mut [f32], b: &[f32]) {
    match sanitize(kern) {
        Kernels::LegacyScalar => crate::rdfft::spectral::mul_conjb_inplace(a, b),
        // SAFETY: packed rows share one even length >= 2 (debug-asserted
        // in mul_conjb_row); no CPU features needed on this arm.
        Kernels::Portable => unsafe { mul_conjb_row::<ScalarQuad>(a, b) },
        // SAFETY: same row contract; AvxFma only comes from select()
        // after runtime AVX2+FMA detection.
        Kernels::AvxFma => unsafe {
            #[cfg(target_arch = "x86_64")]
            mul_conjb_row_avx(a, b);
            #[cfg(not(target_arch = "x86_64"))]
            mul_conjb_row::<ScalarQuad>(a, b);
        },
        // SAFETY: same row contract; AvxFma256 only comes from resolve()
        // after runtime AVX2+FMA detection (256-bit regs included).
        Kernels::AvxFma256 => unsafe {
            #[cfg(target_arch = "x86_64")]
            mul_conjb_row8_avx(a, b);
            #[cfg(not(target_arch = "x86_64"))]
            mul_conjb_row8::<ScalarOct, ScalarQuad>(a, b);
        },
    }
}

/// `acc += a ⊙ b` (packed) on the selected arm.
pub fn mul_acc_with(kern: Kernels, acc: &mut [f32], a: &[f32], b: &[f32]) {
    match sanitize(kern) {
        Kernels::LegacyScalar => crate::rdfft::spectral::mul_acc(acc, a, b),
        // SAFETY: all three rows share one even length >= 2 (debug-
        // asserted in mul_acc_row); no CPU features needed on this arm.
        Kernels::Portable => unsafe { mul_acc_row::<ScalarQuad>(acc, a, b) },
        // SAFETY: same row contract; AvxFma only comes from select()
        // after runtime AVX2+FMA detection.
        Kernels::AvxFma => unsafe {
            #[cfg(target_arch = "x86_64")]
            mul_acc_row_avx(acc, a, b);
            #[cfg(not(target_arch = "x86_64"))]
            mul_acc_row::<ScalarQuad>(acc, a, b);
        },
        // SAFETY: same row contract; AvxFma256 only comes from resolve()
        // after runtime AVX2+FMA detection (256-bit regs included).
        Kernels::AvxFma256 => unsafe {
            #[cfg(target_arch = "x86_64")]
            mul_acc_row8_avx(acc, a, b);
            #[cfg(not(target_arch = "x86_64"))]
            mul_acc_row8::<ScalarOct, ScalarQuad>(acc, a, b);
        },
    }
}

/// `acc += conj(a) ⊙ b` (packed) on the selected arm.
pub fn conj_mul_acc_with(kern: Kernels, acc: &mut [f32], a: &[f32], b: &[f32]) {
    match sanitize(kern) {
        Kernels::LegacyScalar => crate::rdfft::spectral::conj_mul_acc(acc, a, b),
        // SAFETY: all three rows share one even length >= 2 (debug-
        // asserted in conj_mul_acc_row); no CPU features needed here.
        Kernels::Portable => unsafe { conj_mul_acc_row::<ScalarQuad>(acc, a, b) },
        // SAFETY: same row contract; AvxFma only comes from select()
        // after runtime AVX2+FMA detection.
        Kernels::AvxFma => unsafe {
            #[cfg(target_arch = "x86_64")]
            conj_mul_acc_row_avx(acc, a, b);
            #[cfg(not(target_arch = "x86_64"))]
            conj_mul_acc_row::<ScalarQuad>(acc, a, b);
        },
        // SAFETY: same row contract; AvxFma256 only comes from resolve()
        // after runtime AVX2+FMA detection (256-bit regs included).
        Kernels::AvxFma256 => unsafe {
            #[cfg(target_arch = "x86_64")]
            conj_mul_acc_row8_avx(acc, a, b);
            #[cfg(not(target_arch = "x86_64"))]
            conj_mul_acc_row8::<ScalarOct, ScalarQuad>(acc, a, b);
        },
    }
}

// ---------------------------------------------------------------------
// bf16 twin: lane math on pre-widened quads
// ---------------------------------------------------------------------

/// One forward butterfly quad on pre-widened f32 lane arrays — the bf16
/// twin gathers four 4-groups' values (`to_f32`), runs this, and rounds
/// the four outputs back per element. Returns
/// `(re_k, im_k, re_mk, im_mk)` lane arrays.
pub fn fwd_quad_arrays(
    kern: Kernels,
    er: [f32; 4],
    ei: [f32; 4],
    or_: [f32; 4],
    oi: [f32; 4],
    wr: [f32; 4],
    wi: [f32; 4],
) -> ([f32; 4], [f32; 4], [f32; 4], [f32; 4]) {
    // SAFETY: all loads/stores hit the local fixed-size [f32; 4] arrays;
    // unsafe only carries the lane arms' feature contract.
    #[inline(always)]
    unsafe fn go<L: Lanes4>(
        er: [f32; 4],
        ei: [f32; 4],
        or_: [f32; 4],
        oi: [f32; 4],
        wr: [f32; 4],
        wi: [f32; 4],
    ) -> ([f32; 4], [f32; 4], [f32; 4], [f32; 4]) {
        let (erv, eiv) = (L::load(er.as_ptr()), L::load(ei.as_ptr()));
        let (orv, oiv) = (L::load(or_.as_ptr()), L::load(oi.as_ptr()));
        let (wrv, wiv) = (L::load(wr.as_ptr()), L::load(wi.as_ptr()));
        let tr = L::mls(wrv, orv, L::mul(wiv, oiv));
        let ti = L::mla(wrv, oiv, L::mul(wiv, orv));
        let mut out = ([0.0f32; 4], [0.0f32; 4], [0.0f32; 4], [0.0f32; 4]);
        L::store(out.0.as_mut_ptr(), L::add(erv, tr));
        L::store(out.1.as_mut_ptr(), L::add(eiv, ti));
        L::store(out.2.as_mut_ptr(), L::sub(erv, tr));
        L::store(out.3.as_mut_ptr(), L::sub(ti, eiv));
        out
    }
    // SAFETY: same as go, plus AVX2+FMA present at runtime.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn go_avx(
        er: [f32; 4],
        ei: [f32; 4],
        or_: [f32; 4],
        oi: [f32; 4],
        wr: [f32; 4],
        wi: [f32; 4],
    ) -> ([f32; 4], [f32; 4], [f32; 4], [f32; 4]) {
        go::<x86::AvxFma>(er, ei, or_, oi, wr, wi)
    }
    match sanitize(kern) {
        // SAFETY: local arrays only; AvxFma arm only comes from select()
        // after runtime AVX2+FMA detection.
        // (the bf16 twin's quads stay 128-bit even on the width-8 arm —
        // the gather is [f32; 4]-shaped, so AvxFma256 reuses the AvxFma
        // lane math, which has the identical FMA contraction behavior)
        Kernels::AvxFma | Kernels::AvxFma256 => unsafe {
            #[cfg(target_arch = "x86_64")]
            return go_avx(er, ei, or_, oi, wr, wi);
            #[cfg(not(target_arch = "x86_64"))]
            return go::<ScalarQuad>(er, ei, or_, oi, wr, wi);
        },
        // SAFETY: local arrays only; ScalarQuad needs no CPU features.
        _ => unsafe { go::<ScalarQuad>(er, ei, or_, oi, wr, wi) },
    }
}

/// One inverse butterfly quad on pre-widened lane arrays, with **full**
/// (not pre-halved) twiddles — the op shape of the bf16 inverse twin:
/// `er = ½(a+b)`, `ei = ½(c−d)`, `or = ½(a−b)·wr + ½(c+d)·wi`,
/// `oi = ½(c+d)·wr − ½(a−b)·wi`. Returns `(er, ei, or, oi)`.
pub fn inv_quad_arrays(
    kern: Kernels,
    a: [f32; 4],
    b: [f32; 4],
    c: [f32; 4],
    d: [f32; 4],
    wr: [f32; 4],
    wi: [f32; 4],
) -> ([f32; 4], [f32; 4], [f32; 4], [f32; 4]) {
    // SAFETY: all loads/stores hit the local fixed-size [f32; 4] arrays;
    // unsafe only carries the lane arms' feature contract.
    #[inline(always)]
    unsafe fn go<L: Lanes4>(
        a: [f32; 4],
        b: [f32; 4],
        c: [f32; 4],
        d: [f32; 4],
        wr: [f32; 4],
        wi: [f32; 4],
    ) -> ([f32; 4], [f32; 4], [f32; 4], [f32; 4]) {
        let (av, bv) = (L::load(a.as_ptr()), L::load(b.as_ptr()));
        let (cv, dv) = (L::load(c.as_ptr()), L::load(d.as_ptr()));
        let (wrv, wiv) = (L::load(wr.as_ptr()), L::load(wi.as_ptr()));
        let half = L::splat(0.5);
        let er = L::mul(half, L::add(av, bv));
        let tr = L::mul(half, L::sub(av, bv));
        let ti = L::mul(half, L::add(cv, dv));
        let ei = L::mul(half, L::sub(cv, dv));
        let or_ = L::mla(tr, wrv, L::mul(ti, wiv));
        let oi = L::mls(ti, wrv, L::mul(tr, wiv));
        let mut out = ([0.0f32; 4], [0.0f32; 4], [0.0f32; 4], [0.0f32; 4]);
        L::store(out.0.as_mut_ptr(), er);
        L::store(out.1.as_mut_ptr(), ei);
        L::store(out.2.as_mut_ptr(), or_);
        L::store(out.3.as_mut_ptr(), oi);
        out
    }
    // SAFETY: same as go, plus AVX2+FMA present at runtime.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn go_avx(
        a: [f32; 4],
        b: [f32; 4],
        c: [f32; 4],
        d: [f32; 4],
        wr: [f32; 4],
        wi: [f32; 4],
    ) -> ([f32; 4], [f32; 4], [f32; 4], [f32; 4]) {
        go::<x86::AvxFma>(a, b, c, d, wr, wi)
    }
    match sanitize(kern) {
        // SAFETY: local arrays only; AvxFma arm only comes from select()
        // after runtime AVX2+FMA detection.
        // (see fwd_quad_arrays: [f32; 4]-shaped gathers reuse the 128-bit
        // FMA lane math on the width-8 arm)
        Kernels::AvxFma | Kernels::AvxFma256 => unsafe {
            #[cfg(target_arch = "x86_64")]
            return go_avx(a, b, c, d, wr, wi);
            #[cfg(not(target_arch = "x86_64"))]
            return go::<ScalarQuad>(a, b, c, d, wr, wi);
        },
        // SAFETY: local arrays only; ScalarQuad needs no CPU features.
        _ => unsafe { go::<ScalarQuad>(a, b, c, d, wr, wi) },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn selection_is_cached_and_deterministic() {
        let a = active();
        for _ in 0..4 {
            assert_eq!(active(), a);
        }
        assert_eq!(select(true), Kernels::LegacyScalar);
        assert_eq!(select(false), a);
    }

    #[test]
    fn scalar_quad_load_store_roundtrip_and_reversal() {
        let src = [1.0f32, 2.0, 3.0, 4.0];
        // SAFETY: src/out are 4-element locals — exactly one quad.
        unsafe {
            let v = ScalarQuad::load(src.as_ptr());
            let r = ScalarQuad::load_rev(src.as_ptr());
            assert_eq!(v, [1.0, 2.0, 3.0, 4.0]);
            assert_eq!(r, [4.0, 3.0, 2.0, 1.0]);
            let mut out = [0.0f32; 4];
            ScalarQuad::store_rev(out.as_mut_ptr(), v);
            assert_eq!(out, [4.0, 3.0, 2.0, 1.0]);
            // store_rev ∘ load_rev == identity
            ScalarQuad::store_rev(out.as_mut_ptr(), r);
            assert_eq!(out, src);
        }
    }

    #[test]
    fn portable_forward_groups_match_legacy_scalar_bitwise() {
        // One 2m-block per m; portable quads must equal the scalar loop
        // bit-for-bit (same ops, same order, lane-disjoint groups).
        for m in [8usize, 16, 32, 64, 128] {
            let two_m = 2 * m;
            let wr = rand_vec(m / 2 - 1, m as u64);
            let wi = rand_vec(m / 2 - 1, 7 * m as u64);
            let base = rand_vec(two_m, 13 * m as u64);
            let mut scalar = base.clone();
            let mut quad = base.clone();
            // SAFETY: blocks are exactly 2m long with m/2 - 1 twiddles.
            unsafe {
                fwd_groups_dispatch(Kernels::LegacyScalar, &mut scalar, m, &wr, &wi);
                fwd_groups_dispatch(Kernels::Portable, &mut quad, m, &wr, &wi);
            }
            assert_eq!(scalar, quad, "m={m}");
        }
    }

    #[test]
    fn portable_inverse_groups_match_legacy_scalar_bitwise() {
        for m in [8usize, 16, 32, 64, 128] {
            let two_m = 2 * m;
            let hr = rand_vec(m / 2 - 1, 3 * m as u64);
            let hi = rand_vec(m / 2 - 1, 11 * m as u64);
            let base = rand_vec(two_m, 17 * m as u64);
            let mut scalar = base.clone();
            let mut quad = base.clone();
            // SAFETY: blocks are exactly 2m long with m/2 - 1 twiddles.
            unsafe {
                inv_groups_dispatch(Kernels::LegacyScalar, &mut scalar, m, &hr, &hi);
                inv_groups_dispatch(Kernels::Portable, &mut quad, m, &hr, &hi);
            }
            assert_eq!(scalar, quad, "m={m}");
        }
    }

    #[test]
    fn inverse_groups_undo_forward_groups() {
        // With matching (wr,wi) and pre-halved (wr/2, wi/2), the inverse
        // group sweep must undo the forward one to f32 precision.
        let m = 64usize;
        let theta = |k: usize| std::f64::consts::TAU * k as f64 / (2 * m) as f64;
        let wr: Vec<f32> = (1..m / 2).map(|k| theta(k).cos() as f32).collect();
        let wi: Vec<f32> = (1..m / 2).map(|k| (-theta(k).sin()) as f32).collect();
        let hr: Vec<f32> = wr.iter().map(|v| 0.5 * v).collect();
        let hi: Vec<f32> = wi.iter().map(|v| 0.5 * v).collect();
        for kern in [Kernels::LegacyScalar, Kernels::Portable, active()] {
            let base = rand_vec(2 * m, 29);
            let mut buf = base.clone();
            // SAFETY: buf is exactly 2m long with m/2 - 1 twiddles; kern
            // came from active()/the fixed safe arms.
            unsafe {
                fwd_groups_dispatch(kern, &mut buf, m, &wr, &wi);
                inv_groups_dispatch(kern, &mut buf, m, &hr, &hi);
            }
            for i in 0..2 * m {
                // k = 0 and k = m/2 lanes are untouched by the group
                // kernels, so every index must round-trip.
                assert!((buf[i] - base[i]).abs() < 1e-4, "kern={kern:?} i={i}");
            }
        }
    }

    #[test]
    fn portable_products_match_legacy_scalar_bitwise() {
        for n in [4usize, 8, 16, 64, 256] {
            let a0 = rand_vec(n, n as u64);
            let b = rand_vec(n, 2 * n as u64);
            let acc0 = rand_vec(n, 3 * n as u64);

            let mut s = a0.clone();
            crate::rdfft::spectral::mul_inplace(&mut s, &b);
            let mut q = a0.clone();
            mul_inplace_with(Kernels::Portable, &mut q, &b);
            assert_eq!(s, q, "mul n={n}");

            let mut s = a0.clone();
            crate::rdfft::spectral::mul_conjb_inplace(&mut s, &b);
            let mut q = a0.clone();
            mul_conjb_inplace_with(Kernels::Portable, &mut q, &b);
            assert_eq!(s, q, "conjb n={n}");

            let mut s = acc0.clone();
            crate::rdfft::spectral::mul_acc(&mut s, &a0, &b);
            let mut q = acc0.clone();
            mul_acc_with(Kernels::Portable, &mut q, &a0, &b);
            assert_eq!(s, q, "mul_acc n={n}");

            let mut s = acc0.clone();
            crate::rdfft::spectral::conj_mul_acc(&mut s, &a0, &b);
            let mut q = acc0.clone();
            conj_mul_acc_with(Kernels::Portable, &mut q, &a0, &b);
            assert_eq!(s, q, "conj_mul_acc n={n}");
        }
    }

    #[test]
    fn active_arm_products_agree_with_scalar_within_tolerance() {
        // On AVX2+FMA machines the auto arm re-associates via FMA; the
        // drift per lane is a few ulps of the operand magnitudes.
        let kern = active();
        for n in [16usize, 64, 1024] {
            let a0 = rand_vec(n, 5 + n as u64);
            let b = rand_vec(n, 9 + n as u64);
            let mut s = a0.clone();
            crate::rdfft::spectral::mul_inplace(&mut s, &b);
            let mut q = a0.clone();
            mul_inplace_with(kern, &mut q, &b);
            for i in 0..n {
                assert!((s[i] - q[i]).abs() <= 1e-5 * (1.0 + s[i].abs()), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn clamp_width_enforces_max_simd_width() {
        assert_eq!(clamp_width(Kernels::AvxFma256, 0), Kernels::AvxFma256);
        assert_eq!(clamp_width(Kernels::AvxFma256, 8), Kernels::AvxFma256);
        assert_eq!(clamp_width(Kernels::AvxFma256, 4), Kernels::AvxFma);
        assert_eq!(clamp_width(Kernels::AvxFma, 4), Kernels::AvxFma);
        assert_eq!(clamp_width(Kernels::Portable, 4), Kernels::Portable);
        assert_eq!(clamp_width(Kernels::AvxFma256, 1), Kernels::LegacyScalar);
        assert_eq!(clamp_width(Kernels::Portable, 2), Kernels::LegacyScalar);
        assert_eq!(select_width(true, 0), Kernels::LegacyScalar);
    }

    #[test]
    fn scalar_oct_is_bitwise_two_scalar_quads() {
        // The width-8 portable group sweep must be bit-identical to the
        // width-4 portable sweep (ScalarOct is two ScalarQuad halves and
        // the groups are lane-disjoint, so coverage order cannot matter).
        for m in [16usize, 32, 64, 128, 256] {
            let two_m = 2 * m;
            let wr = rand_vec(m / 2 - 1, 19 * m as u64);
            let wi = rand_vec(m / 2 - 1, 23 * m as u64);
            let base = rand_vec(two_m, 31 * m as u64);
            let mut quad = base.clone();
            let mut oct = base.clone();
            // SAFETY: blocks are exactly 2m long with m/2 - 1 twiddles.
            unsafe {
                fwd_groups_dispatch(Kernels::Portable, &mut quad, m, &wr, &wi);
                fwd_groups8_portable(&mut oct, m, &wr, &wi);
            }
            assert_eq!(quad, oct, "fwd m={m}");

            let mut quad = base.clone();
            let mut oct = base.clone();
            // SAFETY: same block contract as above.
            unsafe {
                inv_groups_dispatch(Kernels::Portable, &mut quad, m, &wr, &wi);
                inv_groups8_portable(&mut oct, m, &wr, &wi);
            }
            assert_eq!(quad, oct, "inv m={m}");
        }
    }

    #[test]
    fn portable_oct_products_match_legacy_scalar_bitwise() {
        for n in [4usize, 8, 16, 64, 256, 1024] {
            let a0 = rand_vec(n, 41 + n as u64);
            let b = rand_vec(n, 43 + n as u64);
            let acc0 = rand_vec(n, 47 + n as u64);

            let mut s = a0.clone();
            crate::rdfft::spectral::mul_inplace(&mut s, &b);
            let mut o = a0.clone();
            // SAFETY: packed rows share one even length >= 2.
            unsafe { mul_row8::<ScalarOct, ScalarQuad>(&mut o, &b) };
            assert_eq!(s, o, "mul n={n}");

            let mut s = a0.clone();
            crate::rdfft::spectral::mul_conjb_inplace(&mut s, &b);
            let mut o = a0.clone();
            // SAFETY: packed rows share one even length >= 2.
            unsafe { mul_conjb_row8::<ScalarOct, ScalarQuad>(&mut o, &b) };
            assert_eq!(s, o, "conjb n={n}");

            let mut s = acc0.clone();
            crate::rdfft::spectral::mul_acc(&mut s, &a0, &b);
            let mut o = acc0.clone();
            // SAFETY: all three packed rows share one even length >= 2.
            unsafe { mul_acc_row8::<ScalarOct, ScalarQuad>(&mut o, &a0, &b) };
            assert_eq!(s, o, "mul_acc n={n}");

            let mut s = acc0.clone();
            crate::rdfft::spectral::conj_mul_acc(&mut s, &a0, &b);
            let mut o = acc0.clone();
            // SAFETY: all three packed rows share one even length >= 2.
            unsafe { conj_mul_acc_row8::<ScalarOct, ScalarQuad>(&mut o, &a0, &b) };
            assert_eq!(s, o, "conj_mul_acc n={n}");
        }
    }

    #[test]
    fn active_width8_arm_groups_agree_with_scalar_within_tolerance() {
        // Exercises the real AvxFma256 arm when the host has it (and is a
        // portable no-op check otherwise): only FMA contraction may move
        // lanes relative to the scalar oracle.
        let kern = active();
        for m in [64usize, 256] {
            let two_m = 2 * m;
            let wr = rand_vec(m / 2 - 1, 53 * m as u64);
            let wi = rand_vec(m / 2 - 1, 59 * m as u64);
            let base = rand_vec(two_m, 61 * m as u64);
            let mut s = base.clone();
            let mut v = base.clone();
            // SAFETY: blocks are exactly 2m long with m/2 - 1 twiddles;
            // kern came from active() (runtime-detected).
            unsafe {
                fwd_groups_dispatch(Kernels::LegacyScalar, &mut s, m, &wr, &wi);
                fwd_groups_dispatch(kern, &mut v, m, &wr, &wi);
            }
            for i in 0..two_m {
                assert!((s[i] - v[i]).abs() <= 1e-5 * (1.0 + s[i].abs()), "m={m} i={i}");
            }
        }
    }

    #[test]
    fn fwd_quad_arrays_matches_scalar_groups() {
        let er = [0.5f32, -1.0, 2.0, 0.25];
        let ei = [1.5f32, 0.0, -0.5, 1.0];
        let or_ = [-0.75f32, 0.3, 1.1, -2.0];
        let oi = [0.2f32, -0.6, 0.9, 0.4];
        let wr = [1.0f32, 0.7071, 0.0, -0.7071];
        let wi = [0.0f32, -0.7071, -1.0, -0.7071];
        let (rk, ik, rm, im) = fwd_quad_arrays(Kernels::Portable, er, ei, or_, oi, wr, wi);
        for l in 0..4 {
            let tr = wr[l] * or_[l] - wi[l] * oi[l];
            let ti = wr[l] * oi[l] + wi[l] * or_[l];
            assert_eq!(rk[l], er[l] + tr);
            assert_eq!(ik[l], ei[l] + ti);
            assert_eq!(rm[l], er[l] - tr);
            assert_eq!(im[l], ti - ei[l]);
        }
    }
}
