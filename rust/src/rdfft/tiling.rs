//! Shared transpose-tile gather/scatter helpers.
//!
//! Strided column access is the common denominator of the 2-D column
//! pass ([`super::twod::Plan2`]) and the four-step large-n engine
//! ([`super::fourstep`]): both view a flat buffer as a row-major
//! `rows × row_stride` matrix and need whole columns contiguous in a
//! small cache-resident tile — the software analogue of a shared-memory
//! transpose tile. The helpers here own that access pattern once, so
//! both callers stay strictly in-place (the tile is persistent scratch
//! allocated by the caller's plan, never per call).
//!
//! The safe pair works on slices and is what `twod` uses. The `_ptr`
//! pair is the raw-element variant the four-step panel kernels use:
//! panels of one row are processed by different closure invocations that
//! share the row through a raw base pointer (columns are disjoint, so
//! there is no aliasing — see `fourstep.rs`), which rules out `&mut`
//! slice reborrows.

/// Gather `tc` contiguous columns `[c0, c0 + tc)` of the row-major
/// `rows × row_stride` matrix in `buf` into `tile`, column-major:
/// column `c0 + t` lands contiguously at `tile[t·rows .. (t+1)·rows]`.
// audit: no_alloc
#[inline]
pub fn gather_cols(tile: &mut [f32], buf: &[f32], rows: usize, row_stride: usize, c0: usize, tc: usize) {
    debug_assert!(c0 + tc <= row_stride);
    debug_assert!(tile.len() >= tc * rows && buf.len() >= rows * row_stride);
    for t in 0..tc {
        for i in 0..rows {
            tile[t * rows + i] = buf[i * row_stride + c0 + t];
        }
    }
}

/// Exact inverse of [`gather_cols`]: scatter the tile's columns back
/// into the row-major matrix.
// audit: no_alloc
#[inline]
pub fn scatter_cols(tile: &[f32], buf: &mut [f32], rows: usize, row_stride: usize, c0: usize, tc: usize) {
    debug_assert!(c0 + tc <= row_stride);
    debug_assert!(tile.len() >= tc * rows && buf.len() >= rows * row_stride);
    for t in 0..tc {
        for i in 0..rows {
            buf[i * row_stride + c0 + t] = tile[t * rows + i];
        }
    }
}

/// Gather one column `col` of the row-major `rows × row_stride` matrix
/// at `buf` into the contiguous `dst` (length ≥ `rows`).
///
/// # Safety
/// `buf` must be valid for reads of `rows · row_stride` elements,
/// `col < row_stride`, `dst` valid for writes of `rows` elements, and
/// the caller must hold exclusive access to the column's elements for
/// the duration of the call (no other thread may touch
/// `buf[i·row_stride + col]` concurrently).
// audit: no_alloc
#[inline]
pub unsafe fn gather_col_ptr(dst: *mut f32, buf: *const f32, rows: usize, row_stride: usize, col: usize) {
    debug_assert!(col < row_stride);
    for i in 0..rows {
        *dst.add(i) = *buf.add(i * row_stride + col);
    }
}

/// Exact inverse of [`gather_col_ptr`].
///
/// # Safety
/// Same contract as [`gather_col_ptr`] with `src` valid for reads of
/// `rows` elements and `buf` valid for writes.
// audit: no_alloc
#[inline]
pub unsafe fn scatter_col_ptr(src: *const f32, buf: *mut f32, rows: usize, row_stride: usize, col: usize) {
    debug_assert!(col < row_stride);
    for i in 0..rows {
        *buf.add(i * row_stride + col) = *src.add(i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iota(n: usize) -> Vec<f32> {
        (0..n).map(|i| i as f32).collect()
    }

    #[test]
    fn gather_scatter_roundtrip_and_layout() {
        let (rows, cols) = (5usize, 7usize);
        let buf = iota(rows * cols);
        let mut tile = vec![0.0f32; rows * 3];
        gather_cols(&mut tile, &buf, rows, cols, 2, 3);
        for t in 0..3 {
            for i in 0..rows {
                assert_eq!(tile[t * rows + i], buf[i * cols + 2 + t], "t={t} i={i}");
            }
        }
        let mut back = vec![-1.0f32; rows * cols];
        scatter_cols(&tile, &mut back, rows, cols, 2, 3);
        for i in 0..rows {
            for j in 0..cols {
                let want = if (2..5).contains(&j) { buf[i * cols + j] } else { -1.0 };
                assert_eq!(back[i * cols + j], want, "i={i} j={j}");
            }
        }
    }

    #[test]
    fn ptr_variants_match_slice_variants() {
        let (rows, cols) = (6usize, 4usize);
        let buf = iota(rows * cols);
        for col in 0..cols {
            let mut a = vec![0.0f32; rows];
            let mut b = vec![0.0f32; rows];
            gather_cols(&mut a, &buf, rows, cols, col, 1);
            // SAFETY: buf holds rows·cols elements, col < cols, b holds
            // rows elements, and this thread has exclusive access.
            unsafe { gather_col_ptr(b.as_mut_ptr(), buf.as_ptr(), rows, cols, col) };
            assert_eq!(a, b, "col={col}");

            let mut back_a = vec![0.0f32; rows * cols];
            let mut back_b = vec![0.0f32; rows * cols];
            scatter_cols(&a, &mut back_a, rows, cols, col, 1);
            // SAFETY: same bounds as above, exclusive access to back_b.
            unsafe { scatter_col_ptr(b.as_ptr(), back_b.as_mut_ptr(), rows, cols, col) };
            assert_eq!(back_a, back_b, "col={col}");
        }
    }
}
