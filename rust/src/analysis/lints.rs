//! The repo's static invariant checker: five repo-specific lints over
//! the token stream of [`crate::analysis::lexer`], plus the suppression
//! / marker grammar. Everything here is pure (`&str` in, findings out)
//! so the fixture tests can feed inline snippets through the exact code
//! path `repro audit` runs on the real tree.
//!
//! Lints
//! -----
//! * `unsafe-needs-safety-comment` — every `unsafe` occurrence (block,
//!   fn, impl, trait) must carry a `SAFETY:` comment or a `# Safety`
//!   doc section on the same line or in the contiguous comment /
//!   attribute block directly above it.
//! * `no-raw-threads` — `std::thread::{spawn, scope, Builder}` is
//!   forbidden outside `runtime/pool.rs` (the pool owns all compute
//!   threads) and `runtime/server.rs::spawn_session` (the one dedicated
//!   serve thread). Bypassing [`ExecCtx`](crate::runtime::pool::ExecCtx)
//!   breaks memtrack worker accounting and the bit-identity contracts.
//! * `lock-poison-policy` — `.lock()/.read()/.write()` immediately
//!   chained with `.unwrap()/.expect()` is forbidden; recover from
//!   poison with `unwrap_or_else(|p| p.into_inner())` (the PR 3
//!   plan-cache policy) so a panicking holder can't wedge waiters.
//! * `no-alloc-in-hot-path` — a fn whose signature is preceded by the
//!   `no_alloc` marker (see below) must contain no allocation
//!   constructs: `Vec::new`, `vec![…]`, `with_capacity`, `to_vec`,
//!   `.collect`, `Box::new`, `format!`, `.clone()`. This is the static
//!   complement of the memtrack `steady_state_allocs == 0` runtime gate.
//! * `determinism-lint` — `HashMap`/`HashSet` (iteration order),
//!   `Instant`/`SystemTime` (timing), and entropy-seeded RNG constructs
//!   are forbidden in the result-affecting modules: `rdfft/`,
//!   `autograd/`, and `runtime/server.rs`.
//!
//! Directive grammar (comments whose trimmed text starts with the word
//! "audit" followed by a colon):
//!
//! ```text
//! // audit: no_alloc                      marker: next fn is a hot path
//! // audit: allow(<lint-name>) <reason>   suppress <lint-name> findings
//! //                                      on this line (trailing) or on
//! //                                      the next code line (standalone)
//! ```
//!
//! A reason-less `allow` — or one naming an unknown lint — is itself a
//! violation (`allow-needs-reason`), and cannot be suppressed.

use crate::analysis::lexer::{lex, Tok, Token};

/// Canonical lint names, as they appear in `allow(...)` and AUDIT.json.
pub const LINT_UNSAFE: &str = "unsafe-needs-safety-comment";
pub const LINT_THREADS: &str = "no-raw-threads";
pub const LINT_LOCK: &str = "lock-poison-policy";
pub const LINT_ALLOC: &str = "no-alloc-in-hot-path";
pub const LINT_DETERMINISM: &str = "determinism-lint";
/// Meta-lint: malformed suppression (missing reason / unknown lint).
pub const LINT_BAD_ALLOW: &str = "allow-needs-reason";

/// Every suppressible lint (what `allow(...)` may name).
pub const SUPPRESSIBLE: [&str; 5] =
    [LINT_UNSAFE, LINT_THREADS, LINT_LOCK, LINT_ALLOC, LINT_DETERMINISM];

/// One unsuppressed violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub lint: &'static str,
    pub message: String,
}

/// One violation silenced by a well-formed `allow` — kept in the report
/// so AUDIT.json records every waiver together with its reason.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Suppression {
    pub file: String,
    pub line: usize,
    pub lint: &'static str,
    pub reason: String,
}

/// Per-file analysis result.
#[derive(Debug, Default)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Suppression>,
}

/// A parsed `allow` directive: which lint it silences, the code line it
/// targets, and the mandatory reason.
struct Allow {
    lint: &'static str,
    target: Option<usize>,
    reason: String,
}

/// Analyze one source text. `path_label` is the (repo-relative or
/// absolute) path used for reporting *and* for the path-scoped rules:
/// the `no-raw-threads` allowlist and the `determinism-lint` module
/// scope both match on it, so fixture tests pick their scope by label.
pub fn analyze_source(path_label: &str, src: &str) -> FileReport {
    let norm = path_label.replace('\\', "/");
    let tokens = lex(src);
    let lines = Lines::build(src, &tokens);

    let mut report = FileReport::default();
    let mut allows: Vec<Allow> = Vec::new();

    for t in &tokens {
        let Tok::Comment(text) = &t.kind else { continue };
        match parse_directive(text) {
            Directive::None => {}
            // Markers are re-discovered by lookback inside `scan`; no
            // side table needed here.
            Directive::NoAlloc => {}
            Directive::Allow { lint, reason } => allows.push(Allow {
                lint,
                target: lines.directive_target(t.line, t.end_line),
                reason,
            }),
            Directive::Malformed(why) => report.findings.push(Finding {
                file: path_label.to_string(),
                line: t.line,
                lint: LINT_BAD_ALLOW,
                message: why,
            }),
        }
    }

    let raw = scan(&norm, &tokens, &lines);

    // Split raw findings into suppressed vs live: a well-formed allow
    // silences same-lint findings on its target line.
    for f in raw {
        let hit = allows.iter().find(|a| a.lint == f.lint && a.target == Some(f.line));
        match hit {
            Some(a) => report.suppressed.push(Suppression {
                file: path_label.to_string(),
                line: f.line,
                lint: f.lint,
                reason: a.reason.clone(),
            }),
            None => report.findings.push(Finding {
                file: path_label.to_string(),
                line: f.line,
                lint: f.lint,
                message: f.message,
            }),
        }
    }
    report.findings.sort_by_key(|f| f.line);
    report.suppressed.sort_by_key(|s| s.line);
    report
}

/// A raw (not yet file-labelled) finding from the token scan.
struct RawFinding {
    line: usize,
    lint: &'static str,
    message: String,
}

enum Directive {
    None,
    NoAlloc,
    Allow { lint: &'static str, reason: String },
    Malformed(String),
}

/// Parse a comment body for an audit directive. Only comments whose
/// trimmed text *starts* with the directive keyword participate, so
/// prose that merely mentions the grammar (like this module's docs)
/// never becomes a directive by accident.
fn parse_directive(text: &str) -> Directive {
    // Doc comments arrive as "/ …" / "! …" (the third slash / bang is
    // part of the captured text); strip those before matching.
    let t = text.trim_start_matches(['/', '!']).trim();
    let Some(rest) = t.strip_prefix("audit:") else {
        return Directive::None;
    };
    let rest = rest.trim();
    if rest == "no_alloc" || rest.starts_with("no_alloc ") {
        return Directive::NoAlloc;
    }
    if let Some(after) = rest.strip_prefix("allow(") {
        let Some(close) = after.find(')') else {
            return Directive::Malformed("audit: allow(...) is missing its `)`".to_string());
        };
        let name = after[..close].trim();
        let reason = after[close + 1..].trim();
        let Some(lint) = SUPPRESSIBLE.iter().find(|l| **l == name) else {
            return Directive::Malformed(format!(
                "audit: allow names unknown lint {name:?} (known: {})",
                SUPPRESSIBLE.join(", ")
            ));
        };
        if reason.is_empty() {
            return Directive::Malformed(format!(
                "audit: allow({lint}) needs a reason — a bare waiver is itself a violation"
            ));
        }
        return Directive::Allow { lint, reason: reason.to_string() };
    }
    Directive::Malformed(format!(
        "unrecognized audit directive {rest:?} (expected `no_alloc` or `allow(<lint>) <reason>`)"
    ))
}

/// Per-line classification tables used by directive targeting and the
/// SAFETY / marker lookback.
struct Lines {
    n: usize,
    /// Line has at least one non-comment token.
    code: Vec<bool>,
    /// Line's first code token is `#` (an attribute line).
    attr: Vec<bool>,
    /// Comment indices (into the token list) overlapping each line.
    comments: Vec<Vec<usize>>,
    /// Token-list indices of comments, to read their text back.
    texts: Vec<String>,
}

impl Lines {
    fn build(src: &str, tokens: &[Token]) -> Lines {
        let n = src.lines().count().max(tokens.iter().map(|t| t.end_line).max().unwrap_or(0));
        let mut code = vec![false; n + 2];
        let mut attr = vec![false; n + 2];
        let mut seen_code = vec![false; n + 2];
        let mut comments = vec![Vec::new(); n + 2];
        let mut texts = Vec::new();
        for t in tokens {
            match &t.kind {
                Tok::Comment(text) => {
                    let idx = texts.len();
                    texts.push(text.clone());
                    for l in t.line..=t.end_line.min(n + 1) {
                        comments[l].push(idx);
                    }
                }
                kind => {
                    for l in t.line..=t.end_line.min(n + 1) {
                        if !seen_code[l] {
                            seen_code[l] = true;
                            attr[l] = matches!(kind, Tok::Punct('#'));
                        }
                        code[l] = true;
                    }
                }
            }
        }
        Lines { n, code, attr, comments, texts }
    }

    /// The code line a standalone directive comment governs: the
    /// comment's own line if it trails code, else the next code line
    /// (skipping blanks, further comments, and attribute lines).
    fn directive_target(&self, start: usize, end: usize) -> Option<usize> {
        if self.code.get(start).copied().unwrap_or(false) {
            return Some(start);
        }
        let mut l = end + 1;
        while l <= self.n {
            if self.code[l] && !self.attr[l] {
                return Some(l);
            }
            if self.code[l] && self.attr[l] {
                l += 1;
                continue;
            }
            l += 1; // blank or comment-only
        }
        None
    }

    /// True if `pred` matches any comment on `line` itself or in the
    /// contiguous run of blank / comment-only / attribute lines directly
    /// above it. This is how `SAFETY:` comments, `# Safety` doc
    /// sections, and `no_alloc` markers attach to the code below them —
    /// attributes like `#[inline(always)]` between a doc block and its
    /// fn are skipped, matching rustdoc's attachment rules.
    fn lookback(&self, line: usize, pred: impl Fn(&str) -> bool) -> bool {
        let check = |l: usize| -> bool {
            self.comments.get(l).map_or(false, |ids| ids.iter().any(|&i| pred(&self.texts[i])))
        };
        if check(line) {
            return true;
        }
        let mut l = line;
        while l > 1 {
            l -= 1;
            if check(l) {
                return true;
            }
            let blank = !self.code[l] && self.comments[l].is_empty();
            let comment_only = !self.code[l] && !self.comments[l].is_empty();
            let attr_only = self.code[l] && self.attr[l];
            if !(blank || comment_only || attr_only) {
                return false; // hit real code: the contiguous block ended
            }
        }
        false
    }
}

fn has_safety_text(c: &str) -> bool {
    c.contains("SAFETY") || c.contains("# Safety")
}

/// Is `path` inside the determinism-scoped modules?
fn determinism_scope(norm: &str) -> bool {
    (norm.contains("rdfft/") || norm.contains("autograd/") || norm.ends_with("runtime/server.rs"))
        && !norm.contains("tests/")
}

/// The token-stream scan: all five lints in one pass, tracking brace
/// depth and the enclosing-fn stack (for the `spawn_session` carve-out
/// and the `no_alloc` fn bodies).
fn scan(norm: &str, tokens: &[Token], lines: &Lines) -> Vec<RawFinding> {
    let ct: Vec<&Token> = tokens.iter().filter(|t| t.is_code()).collect();
    // A marker governs the fn whose signature starts at `line` when it
    // sits on that line or in the contiguous block above it.
    let marker_at =
        |line: usize| lines.lookback(line, |c| matches!(parse_directive(c), Directive::NoAlloc));
    let in_det_scope = determinism_scope(norm);
    let pool_file = norm.ends_with("runtime/pool.rs");
    let server_file = norm.ends_with("runtime/server.rs");

    let mut out = Vec::new();
    let mut depth = 0usize;
    // (name, body depth, is_no_alloc) for each entered fn body.
    let mut fn_stack: Vec<(String, usize, bool)> = Vec::new();
    let mut pending_fn: Option<(String, bool)> = None;

    let ident = |i: usize| -> &str { ct.get(i).and_then(|t| t.ident()).unwrap_or("") };
    let punct = |i: usize, c: char| -> bool { ct.get(i).map_or(false, |t| t.is_punct(c)) };

    for i in 0..ct.len() {
        let t = ct[i];
        match &t.kind {
            Tok::Punct('{') => {
                depth += 1;
                if let Some((name, no_alloc)) = pending_fn.take() {
                    fn_stack.push((name, depth, no_alloc));
                }
            }
            Tok::Punct('}') => {
                if fn_stack.last().map_or(false, |(_, d, _)| *d == depth) {
                    fn_stack.pop();
                }
                depth = depth.saturating_sub(1);
            }
            Tok::Punct(';') => {
                pending_fn = None; // trait method declaration without body
            }
            Tok::Punct('.') => {
                // lock-poison-policy: `.lock().unwrap()` and friends.
                let m = ident(i + 1);
                if matches!(m, "lock" | "read" | "write")
                    && punct(i + 2, '(')
                    && punct(i + 3, ')')
                    && punct(i + 4, '.')
                    && matches!(ident(i + 5), "unwrap" | "expect")
                {
                    out.push(RawFinding {
                        line: ct[i + 1].line,
                        lint: LINT_LOCK,
                        message: format!(
                            ".{m}().{}() can wedge waiters if the holder panicked — \
                             recover with unwrap_or_else(|p| p.into_inner())",
                            ident(i + 5)
                        ),
                    });
                }
                if let Some((name, _, true)) = fn_stack.last() {
                    // no-alloc-in-hot-path: `.collect` / `.clone()`.
                    if ident(i + 1) == "collect" {
                        out.push(alloc_finding(ct[i + 1].line, ".collect", name));
                    }
                    if ident(i + 1) == "clone" && punct(i + 2, '(') && punct(i + 3, ')') {
                        out.push(alloc_finding(ct[i + 1].line, ".clone()", name));
                    }
                }
            }
            Tok::Ident(w) => match w.as_str() {
                "fn" => {
                    if let Some(name) = ct.get(i + 1).and_then(|t| t.ident()) {
                        pending_fn = Some((name.to_string(), marker_at(t.line)));
                    }
                }
                "unsafe" => {
                    if !lines.lookback(t.line, has_safety_text) {
                        out.push(RawFinding {
                            line: t.line,
                            lint: LINT_UNSAFE,
                            message: "unsafe without a SAFETY: comment or `# Safety` doc \
                                      section in the contiguous comment/attribute block above"
                                .to_string(),
                        });
                    }
                }
                "thread" => {
                    if punct(i + 1, ':')
                        && punct(i + 2, ':')
                        && matches!(ident(i + 3), "spawn" | "scope" | "Builder")
                    {
                        let in_spawn_session = server_file
                            && fn_stack.iter().any(|(n, _, _)| n == "spawn_session");
                        if !pool_file && !in_spawn_session {
                            out.push(RawFinding {
                                line: t.line,
                                lint: LINT_THREADS,
                                message: format!(
                                    "raw std::thread::{} outside runtime/pool.rs / \
                                     spawn_session — route compute through ExecCtx so \
                                     memtrack accounting and bit-identity hold",
                                    ident(i + 3)
                                ),
                            });
                        }
                    }
                }
                "Vec" | "Box" => {
                    if let Some((name, _, true)) = fn_stack.last() {
                        if punct(i + 1, ':') && punct(i + 2, ':') && ident(i + 3) == "new" {
                            out.push(alloc_finding(t.line, &format!("{w}::new"), name));
                        }
                    }
                }
                "with_capacity" | "to_vec" => {
                    if let Some((name, _, true)) = fn_stack.last() {
                        out.push(alloc_finding(t.line, w, name));
                    }
                }
                "vec" | "format" => {
                    if let Some((name, _, true)) = fn_stack.last() {
                        if punct(i + 1, '!') {
                            out.push(alloc_finding(t.line, &format!("{w}!"), name));
                        }
                    }
                }
                "HashMap" | "HashSet" => {
                    if in_det_scope {
                        out.push(det_finding(t.line, w, "iteration order is nondeterministic"));
                    }
                }
                "Instant" | "SystemTime" => {
                    if in_det_scope {
                        out.push(det_finding(t.line, w, "wall-clock time must not reach results"));
                    }
                }
                "thread_rng" | "ThreadRng" | "OsRng" | "from_entropy" | "getrandom" => {
                    if in_det_scope {
                        out.push(det_finding(t.line, w, "entropy-seeded RNG breaks replay"));
                    }
                }
                _ => {}
            },
            _ => {}
        }
    }
    out
}

fn alloc_finding(line: usize, construct: &str, fn_name: &str) -> RawFinding {
    RawFinding {
        line,
        lint: LINT_ALLOC,
        message: format!(
            "allocation construct `{construct}` inside no_alloc fn `{fn_name}` — hot paths \
             must reuse caller-owned buffers (memtrack steady_state_allocs == 0)"
        ),
    }
}

fn det_finding(line: usize, what: &str, why: &str) -> RawFinding {
    RawFinding {
        line,
        lint: LINT_DETERMINISM,
        message: format!(
            "`{what}` in a determinism-scoped module ({why}); results must be a pure \
             function of (parameters, inputs)"
        ),
    }
}
