//! Dependency-free static analysis over the repo's own sources —
//! `repro audit`.
//!
//! The paper's core claims (fully in-place transforms, zero-allocation
//! hot paths, bit-identical results at any thread count) are enforced
//! dynamically by memtrack gates and the differential suites — but only
//! *when the code runs*. This module makes the load-bearing invariants
//! checkable without running anything: a comment/string-aware token
//! scanner ([`lexer`]) feeds a lint engine ([`lints`]) with five
//! repo-specific rules (unsafe hygiene, thread discipline, lock-poison
//! recovery, hot-path allocation bans, determinism scoping), and this
//! module walks `rust/src` + `rust/tests`, aggregates per-file reports,
//! and renders them human-readable plus machine-readable (`AUDIT.json`).
//! `scripts/ci.sh` runs it as a hard gate before the test suite.

pub mod lexer;
pub mod lints;

pub use lints::{analyze_source, FileReport, Finding, Suppression};

use std::fmt::Write as _;
use std::io;
use std::path::{Path, PathBuf};

/// Aggregated result of auditing a set of root directories.
#[derive(Debug, Default)]
pub struct AuditReport {
    /// The roots that were walked (as given).
    pub roots: Vec<PathBuf>,
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Unsuppressed violations — any entry here fails the gate.
    pub findings: Vec<Finding>,
    /// Violations waived by a well-formed `audit: allow(..) <reason>`.
    pub suppressed: Vec<Suppression>,
}

impl AuditReport {
    /// True when the tree passes: zero unsuppressed violations (a
    /// reason-less allow counts as a violation, so it fails too).
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable rendering: one line per violation, then a summary
    /// (suppression count included so waivers stay visible).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            let _ = writeln!(s, "{}:{}: [{}] {}", f.file, f.line, f.lint, f.message);
        }
        let _ = writeln!(
            s,
            "[audit] {} file(s), {} violation(s), {} suppression(s){}",
            self.files,
            self.findings.len(),
            self.suppressed.len(),
            if self.clean() { " — clean" } else { "" },
        );
        s
    }

    /// Machine-readable rendering (`AUDIT.json`, schema `audit/v1`).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"schema\": \"audit/v1\",\n  \"roots\": [");
        for (i, r) in self.roots.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            let _ = write!(s, "{}", json_str(&r.to_string_lossy()));
        }
        let _ = write!(
            s,
            "],\n  \"files_scanned\": {},\n  \"violations\": {},\n  \"suppressions\": {},\n",
            self.files,
            self.findings.len(),
            self.suppressed.len()
        );
        s.push_str("  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            s.push_str(if i > 0 { ",\n    " } else { "\n    " });
            let _ = write!(
                s,
                "{{\"file\": {}, \"line\": {}, \"lint\": {}, \"message\": {}}}",
                json_str(&f.file),
                f.line,
                json_str(f.lint),
                json_str(&f.message)
            );
        }
        s.push_str(if self.findings.is_empty() { "],\n" } else { "\n  ],\n" });
        s.push_str("  \"suppressed\": [");
        for (i, p) in self.suppressed.iter().enumerate() {
            s.push_str(if i > 0 { ",\n    " } else { "\n    " });
            let _ = write!(
                s,
                "{{\"file\": {}, \"line\": {}, \"lint\": {}, \"reason\": {}}}",
                json_str(&p.file),
                p.line,
                json_str(p.lint),
                json_str(&p.reason)
            );
        }
        s.push_str(if self.suppressed.is_empty() { "]\n}\n" } else { "\n  ]\n}\n" });
        s
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Audit every `.rs` file under the given roots. Missing roots are
/// skipped silently (e.g. a crate without a `tests/` directory); at
/// least one root must exist or this errors.
pub fn audit_paths(roots: &[PathBuf]) -> io::Result<AuditReport> {
    let mut report = AuditReport { roots: roots.to_vec(), ..Default::default() };
    let mut files: Vec<PathBuf> = Vec::new();
    let mut any_root = false;
    for root in roots {
        if root.is_dir() {
            any_root = true;
            collect_rs_files(root, &mut files)?;
        }
    }
    if !any_root {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("no audit roots exist among {roots:?}"),
        ));
    }
    files.sort();
    for path in &files {
        let src = std::fs::read_to_string(path)?;
        let label = path.to_string_lossy();
        let fr = analyze_source(&label, &src);
        report.files += 1;
        report.findings.extend(fr.findings);
        report.suppressed.extend(fr.suppressed);
    }
    Ok(report)
}

/// Recursively collect `.rs` files. The caller sorts the combined list,
/// so report order is deterministic regardless of directory iteration
/// order.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().map_or(false, |e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Resolve the default audit roots relative to `base`: the repo layout
/// (`rust/src` + `rust/tests`) when invoked from the repo root, or the
/// crate layout (`src` + `tests`) when invoked from inside `rust/`.
pub fn default_roots(base: &Path) -> io::Result<Vec<PathBuf>> {
    let repo = [base.join("rust/src"), base.join("rust/tests")];
    if repo[0].is_dir() {
        return Ok(repo.to_vec());
    }
    let krate = [base.join("src"), base.join("tests")];
    if krate[0].is_dir() {
        return Ok(krate.to_vec());
    }
    Err(io::Error::new(
        io::ErrorKind::NotFound,
        format!(
            "no sources to audit under {} (expected rust/src or src; pass --root DIR)",
            base.display()
        ),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escapes_quotes_and_controls() {
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn report_json_is_well_formed_when_empty() {
        let r = AuditReport::default();
        let j = r.to_json();
        assert!(j.contains("\"schema\": \"audit/v1\""));
        assert!(j.contains("\"findings\": []"));
        assert!(j.contains("\"suppressed\": []"));
    }
}
