//! A small comment/string-aware Rust token scanner — just enough lexer
//! for the repo's static invariant checker ([`crate::analysis::lints`]).
//!
//! This is deliberately **not** a full Rust lexer: it only has to
//! classify source text into identifiers, punctuation, literals, and
//! comments with correct line numbers, so the lint pass never mistakes
//! the word `unwrap` inside a string or a doc comment for a call. The
//! constructs that matter for that distinction are all handled:
//!
//! * line comments (`//`, `///`, `//!`) and **nested** block comments,
//! * string / byte-string literals with escapes (`"a \" b"`, `b"…"`),
//! * raw strings with arbitrary hash fences (`r"…"`, `r#"…"#`, `br#…`),
//! * char literals vs lifetimes (`'x'` / `'\n'` vs `'a` in `&'a T`),
//! * numeric literals loose enough for `0xcbf2_9ce4`, `1.5e-3`, `4.max`.
//!
//! Everything the lints don't need (float suffix grammar, shebangs,
//! frontmatter) is out of scope; unknown bytes degrade to punctuation
//! tokens rather than failing, so the pass always produces *a* stream.

/// Token kind. Literal payloads are discarded (the lints only care that
/// a region *is* a literal); comment text is kept verbatim because the
/// `audit:` directive grammar and `SAFETY:` detection read it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (`unsafe`, `fn`, `thread`, `HashMap`, …).
    Ident(String),
    /// Single punctuation character (`::` arrives as two `:` tokens).
    Punct(char),
    /// String / raw-string / byte-string / char / numeric literal.
    Literal,
    /// `//…` or `/*…*/` text, **without** the comment markers trimmed —
    /// the full text between the opener and the end of line / closer.
    Comment(String),
}

/// One token plus its position: `line` is the 1-based line the token
/// starts on, `end_line` the line it ends on (equal except for
/// multi-line block comments and multi-line string literals).
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: Tok,
    pub line: usize,
    pub end_line: usize,
}

impl Token {
    /// True for tokens that are *code* (everything but comments).
    pub fn is_code(&self) -> bool {
        !matches!(self.kind, Tok::Comment(_))
    }

    /// The identifier text, if this is an identifier token.
    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    /// True if this token is the given punctuation character.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == Tok::Punct(c)
    }
}

/// Tokenize `src`. Infallible: malformed input (unterminated strings or
/// comments) simply ends the current token at EOF.
pub fn lex(src: &str) -> Vec<Token> {
    Lexer { b: src.as_bytes(), i: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: usize,
    out: Vec<Token>,
}

impl<'a> Lexer<'a> {
    fn peek(&self, off: usize) -> u8 {
        *self.b.get(self.i + off).unwrap_or(&0)
    }

    /// Advance one byte, tracking line numbers.
    fn bump(&mut self) -> u8 {
        let c = self.b[self.i];
        self.i += 1;
        if c == b'\n' {
            self.line += 1;
        }
        c
    }

    fn push(&mut self, kind: Tok, start_line: usize) {
        self.out.push(Token { kind, line: start_line, end_line: self.line });
    }

    fn run(mut self) -> Vec<Token> {
        while self.i < self.b.len() {
            let c = self.peek(0);
            let start = self.line;
            match c {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                }
                b'/' if self.peek(1) == b'/' => self.line_comment(start),
                b'/' if self.peek(1) == b'*' => self.block_comment(start),
                b'"' => self.string(start),
                b'\'' => self.char_or_lifetime(start),
                b'0'..=b'9' => self.number(start),
                b'a'..=b'z' | b'A'..=b'Z' | b'_' => self.ident(start),
                _ => {
                    self.bump();
                    self.push(Tok::Punct(c as char), start);
                }
            }
        }
        self.out
    }

    fn line_comment(&mut self, start: usize) {
        self.bump();
        self.bump();
        let from = self.i;
        while self.i < self.b.len() && self.peek(0) != b'\n' {
            self.bump();
        }
        let text = String::from_utf8_lossy(&self.b[from..self.i]).into_owned();
        self.push(Tok::Comment(text), start);
    }

    fn block_comment(&mut self, start: usize) {
        self.bump();
        self.bump();
        let from = self.i;
        let mut depth = 1usize;
        let mut to = self.i;
        while self.i < self.b.len() {
            if self.peek(0) == b'/' && self.peek(1) == b'*' {
                self.bump();
                self.bump();
                depth += 1;
            } else if self.peek(0) == b'*' && self.peek(1) == b'/' {
                to = self.i;
                self.bump();
                self.bump();
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else {
                to = self.i + 1;
                self.bump();
            }
        }
        let text = String::from_utf8_lossy(&self.b[from..to.min(self.b.len())]).into_owned();
        self.push(Tok::Comment(text), start);
    }

    /// Cooked string with `\` escapes; consumes the closing quote.
    fn string(&mut self, start: usize) {
        self.bump();
        while self.i < self.b.len() {
            match self.bump() {
                b'\\' => {
                    if self.i < self.b.len() {
                        self.bump();
                    }
                }
                b'"' => break,
                _ => {}
            }
        }
        self.push(Tok::Literal, start);
    }

    /// Raw string body: `###"` fence already consumed up to and including
    /// the opening quote; scans to `"` followed by `hashes` `#`s.
    fn raw_string_body(&mut self, hashes: usize, start: usize) {
        while self.i < self.b.len() {
            if self.bump() == b'"' {
                let mut k = 0;
                while k < hashes && self.peek(0) == b'#' {
                    self.bump();
                    k += 1;
                }
                if k == hashes {
                    break;
                }
            }
        }
        self.push(Tok::Literal, start);
    }

    /// `'x'`, `'\n'` → char literal; `'a` (no closing quote) → lifetime,
    /// emitted as nothing the lints care about (skipped entirely).
    fn char_or_lifetime(&mut self, start: usize) {
        self.bump(); // the opening quote
        if self.peek(0) == b'\\' {
            // escaped char literal: '\n', '\'', '\\', '\u{..}'
            self.bump();
            while self.i < self.b.len() && self.peek(0) != b'\'' {
                self.bump();
            }
            if self.i < self.b.len() {
                self.bump();
            }
            self.push(Tok::Literal, start);
            return;
        }
        let is_name = self.peek(0).is_ascii_alphanumeric() || self.peek(0) == b'_';
        if is_name && self.peek(1) != b'\'' {
            // lifetime: consume the name, emit nothing
            while self.peek(0).is_ascii_alphanumeric() || self.peek(0) == b'_' {
                self.bump();
            }
            return;
        }
        // plain char literal 'x' (or the degenerate '' — consume what's there)
        if self.i < self.b.len() {
            self.bump();
        }
        if self.peek(0) == b'\'' {
            self.bump();
        }
        self.push(Tok::Literal, start);
    }

    /// Loose numeric literal: digits, letters, `_`, and `.` only when
    /// followed by a digit (so `4.max(x)` and `1..n` don't get eaten).
    fn number(&mut self, start: usize) {
        while self.i < self.b.len() {
            let c = self.peek(0);
            let take = c.is_ascii_alphanumeric()
                || c == b'_'
                || (c == b'.' && self.peek(1).is_ascii_digit());
            if !take {
                break;
            }
            self.bump();
        }
        self.push(Tok::Literal, start);
    }

    fn ident(&mut self, start: usize) {
        let from = self.i;
        while self.i < self.b.len() {
            let c = self.peek(0);
            if c.is_ascii_alphanumeric() || c == b'_' {
                self.bump();
            } else {
                break;
            }
        }
        let name = String::from_utf8_lossy(&self.b[from..self.i]).into_owned();
        // Raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#, rb…
        match name.as_str() {
            "r" | "br" | "rb" if self.peek(0) == b'"' || self.peek(0) == b'#' => {
                let mut hashes = 0;
                while self.peek(0) == b'#' {
                    self.bump();
                    hashes += 1;
                }
                if self.peek(0) == b'"' {
                    self.bump();
                    self.raw_string_body(hashes, start);
                } else {
                    // `r#ident` raw identifier: the hashes were consumed;
                    // fall through by emitting the prefix as an ident
                    // (the raw-ident name will lex as its own ident next).
                    self.push(Tok::Ident(name), start);
                }
            }
            "b" if self.peek(0) == b'"' => {
                // `string` consumes the opening quote itself.
                self.string(start);
            }
            "b" if self.peek(0) == b'\'' => {
                self.char_or_lifetime(start);
            }
            _ => self.push(Tok::Ident(name), start),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter_map(|t| match t.kind {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn strings_and_comments_hide_identifiers() {
        let src = r##"
            let a = "unsafe lock unwrap"; // unsafe in a comment
            /* thread::spawn in a block
               comment */
            let b = r#"HashMap::new() in a raw string"#;
            let c = 'x';
            fn f<'a>(p: &'a str) {}
        "##;
        let ids = idents(src);
        assert!(!ids.iter().any(|s| s == "unsafe"));
        assert!(!ids.iter().any(|s| s == "thread"));
        assert!(!ids.iter().any(|s| s == "HashMap"));
        assert!(ids.iter().any(|s| s == "fn"));
    }

    #[test]
    fn line_numbers_track_multiline_tokens() {
        let src = "a\n/* x\ny */\nb";
        let toks = lex(src);
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2);
        assert_eq!(toks[1].end_line, 3);
        assert_eq!(toks[2].line, 4);
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let toks = lex("/* outer /* inner */ still comment */ code");
        assert!(matches!(toks[0].kind, Tok::Comment(_)));
        assert_eq!(toks[1].ident(), Some("code"));
    }

    #[test]
    fn numbers_do_not_eat_method_calls_or_ranges() {
        let ids = idents("let x = 4.max(1); for i in 0..n {}");
        assert!(ids.iter().any(|s| s == "max"));
        assert!(ids.iter().any(|s| s == "n"));
    }

    #[test]
    fn escaped_quotes_stay_inside_the_string() {
        let toks = lex(r#"let s = "a \" unsafe"; done"#);
        assert!(toks.iter().any(|t| t.ident() == Some("done")));
        assert!(!toks.iter().any(|t| t.ident() == Some("unsafe")));
    }
}
