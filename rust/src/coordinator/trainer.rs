//! End-to-end trainer: drives the AOT-compiled train step from Rust.
//!
//! The loop is pure Rust + PJRT: batches come from [`crate::data`], the
//! step executes the HLO module produced by `aot.py` (L2 model + L1
//! Pallas rdFFT kernels), parameters thread output→input, metrics stream
//! to stdout and to a CSV the experiments record in EXPERIMENTS.md.

use crate::data::{Batcher, CorpusGen};
use crate::runtime::Runtime;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Trainer configuration (data + loop control; the model/optimizer config
/// is baked into the artifacts).
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub steps: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub corpus_bytes: usize,
    pub seed: u64,
    pub log_csv: Option<PathBuf>,
    pub checkpoint: Option<PathBuf>,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            steps: 300,
            eval_every: 50,
            eval_batches: 4,
            corpus_bytes: 1 << 20,
            seed: 0,
            log_csv: None,
            checkpoint: None,
        }
    }
}

/// Summary of a finished run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub first_loss: f32,
    pub final_loss: f32,
    pub final_eval_loss: Option<f32>,
    pub tokens_per_sec: f64,
    pub losses: Vec<(usize, f32)>,
}

/// The training orchestrator.
pub struct Trainer {
    runtime: Runtime,
    cfg: TrainerConfig,
}

impl Trainer {
    pub fn new(artifacts: &Path, cfg: TrainerConfig) -> Result<Self> {
        let runtime = Runtime::load(artifacts)?;
        Ok(Trainer { runtime, cfg })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Run the training loop; prints progress and returns the report.
    pub fn run(&mut self) -> Result<TrainReport> {
        let m = &self.runtime.manifest;
        let (batch, seq) = (m.batch, m.seq_len);
        println!(
            "[train] platform={} model: d={} layers={} p={} | {} trainable / {} frozen params",
            self.runtime.platform(),
            m.d_model,
            m.n_layers,
            m.p,
            m.num_trainable_params,
            m.num_frozen_params
        );
        let text = CorpusGen::new(self.cfg.seed).text(self.cfg.corpus_bytes);
        let mut batcher = Batcher::new(&text, batch, seq, self.cfg.seed + 1);
        let eval_text = CorpusGen::new(self.cfg.seed + 7777).text(64 * 1024);
        let eval_batcher = Batcher::new(&eval_text, batch, seq, 0);

        let mut csv = match &self.cfg.log_csv {
            Some(p) => Some(super::open_csv(p, "step,loss,eval_loss,tokens_per_sec")?),
            None => None,
        };

        let mut losses = Vec::new();
        let mut first_loss = None;
        let mut final_eval = None;
        let t0 = Instant::now();
        let mut tokens_seen = 0usize;

        for step in 1..=self.cfg.steps {
            let (toks, tgts) = batcher.next_batch()?;
            let loss = self.runtime.train_step(&toks, &tgts)?;
            tokens_seen += batch * seq;
            first_loss.get_or_insert(loss);
            losses.push((step, loss));

            let do_eval = step % self.cfg.eval_every == 0 || step == self.cfg.steps;
            let mut eval_loss = None;
            if do_eval {
                let mut acc = 0.0f32;
                for i in 0..self.cfg.eval_batches {
                    let (et, eg) = eval_batcher.eval_batch(i)?;
                    acc += self.runtime.eval_step(&et, &eg)?;
                }
                let e = acc / self.cfg.eval_batches as f32;
                eval_loss = Some(e);
                final_eval = Some(e);
                let tps = tokens_seen as f64 / t0.elapsed().as_secs_f64();
                println!(
                    "[train] step {step:>5}  loss {loss:.4}  eval {e:.4}  {:.0} tok/s",
                    tps
                );
            }
            if let Some(f) = csv.as_mut() {
                writeln!(
                    f,
                    "{step},{loss},{},{:.1}",
                    eval_loss.map(|e| e.to_string()).unwrap_or_default(),
                    tokens_seen as f64 / t0.elapsed().as_secs_f64()
                )?;
            }
        }

        if let Some(ck) = &self.cfg.checkpoint {
            let flat = self.runtime.trainable_flat()?;
            let mut bytes = Vec::with_capacity(flat.len() * 4);
            for v in &flat {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            // Atomic protocol (temp → fsync → rename): a crash mid-write
            // must never leave a torn file under the checkpoint name.
            crate::runtime::checkpoint::atomic_write(ck, &bytes)
                .with_context(|| format!("writing checkpoint {}", ck.display()))?;
            println!("[train] checkpoint: {} ({} params)", ck.display(), flat.len());
        }

        let secs = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            steps: self.cfg.steps,
            first_loss: first_loss.unwrap_or(f32::NAN),
            final_loss: losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN),
            final_eval_loss: final_eval,
            tokens_per_sec: tokens_seen as f64 / secs,
            losses,
        })
    }
}
